"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here; pytest
asserts allclose between kernel and oracle across shapes/dtypes (including
hypothesis sweeps). These oracles are also what the L2 model uses on paths
where a kernel would be overkill (e.g. single-token decode steps).
"""

import jax.numpy as jnp


def attention(q, k, v, *, causal=True):
    """Scaled dot-product attention.

    q: [Sq, H, D], k/v: [Sk, H, D] -> [Sq, H, D].
    """
    sq, h, d = q.shape
    sk = k.shape[0]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=jnp.float32))
    logits = (
        jnp.einsum("qhd,khd->hqk", q.astype(jnp.float32), k.astype(jnp.float32))
        * scale
    )
    if causal:
        mask = jnp.tril(jnp.ones((sq, sk), dtype=bool), k=sk - sq)
        logits = jnp.where(mask[None, :, :], logits, -1e30)
    p = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("hqk,khd->qhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def selective_scan(x, dt, a, b, c):
    """Mamba-style selective state-space scan (sequential reference).

    x:  [S, DI]   input sequence (post in-proj/conv/silu)
    dt: [S, DI]   positive step sizes
    a:  [DI, N]   state decay (negative values; used inside exp)
    b:  [S, N]    input projection per step
    c:  [S, N]    output projection per step
    returns (y [S, DI], h_final [DI, N] float32)
    """
    s, di = x.shape
    n = a.shape[1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    cf = c.astype(jnp.float32)
    af = a.astype(jnp.float32)

    h = jnp.zeros((di, n), dtype=jnp.float32)
    ys = []
    for t in range(s):
        da = jnp.exp(dtf[t][:, None] * af)  # [DI, N]
        h = da * h + (dtf[t] * xf[t])[:, None] * bf[t][None, :]
        ys.append(h @ cf[t])  # [DI]
    y = jnp.stack(ys, axis=0)
    return y.astype(x.dtype), h


def selective_scan_step(h, x_t, dt_t, a, b_t, c_t):
    """One decode-time scan step. h: [DI, N] -> (y [DI], h')."""
    hf = h.astype(jnp.float32)
    da = jnp.exp(dt_t.astype(jnp.float32)[:, None] * a.astype(jnp.float32))
    h2 = da * hf + (dt_t.astype(jnp.float32) * x_t.astype(jnp.float32))[:, None] * b_t.astype(jnp.float32)[None, :]
    y = h2 @ c_t.astype(jnp.float32)
    return y.astype(x_t.dtype), h2


def exponent_histogram(bits_u16):
    """256-bin histogram of the BF16 exponent field.

    bits_u16: int32 array of raw BF16 bit patterns (0..65535).
    Returns int32[256] counts.
    """
    exps = (bits_u16 >> 7) & 0xFF
    return jnp.bincount(exps.reshape(-1), length=256).astype(jnp.int32)
