"""Pallas selective-scan (Mamba) kernel (L1).

HARDWARE ADAPTATION: CUDA selective-scan implementations assign channel
chunks to threadblocks and keep the recurrent state in registers/shared
memory. The TPU mapping tiles the channel dimension across the grid and
keeps each tile's [BD, N] state resident in VMEM while the kernel walks
the sequence with `fori_loop` — HBM traffic is exactly one read of
(x, dt, B, C) and one write of y per step, the roofline for a recurrence.

interpret=True: see attention.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BD = 128


def _scan_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, h_ref, *, seq: int):
    """One channel-tile program: sequential scan with VMEM-resident state."""
    bd, n = a_ref.shape
    a = a_ref[...].astype(jnp.float32)  # [BD, N]

    def body(t, h):
        x_t = pl.load(x_ref, (pl.dslice(t, 1), slice(None)))[0].astype(jnp.float32)
        dt_t = pl.load(dt_ref, (pl.dslice(t, 1), slice(None)))[0].astype(jnp.float32)
        b_t = pl.load(b_ref, (pl.dslice(t, 1), slice(None)))[0].astype(jnp.float32)
        c_t = pl.load(c_ref, (pl.dslice(t, 1), slice(None)))[0].astype(jnp.float32)
        da = jnp.exp(dt_t[:, None] * a)  # [BD, N]
        h = da * h + (dt_t * x_t)[:, None] * b_t[None, :]
        y_t = (h * c_t[None, :]).sum(axis=1)  # [BD]
        pl.store(y_ref, (pl.dslice(t, 1), slice(None)), y_t[None, :].astype(y_ref.dtype))
        return h

    h = jnp.zeros((bd, n), dtype=jnp.float32)
    h = jax.lax.fori_loop(0, seq, body, h)
    h_ref[...] = h


def selective_scan(x, dt, a, b, c, *, bd=DEFAULT_BD):
    """Tiled selective scan.

    x/dt: [S, DI], a: [DI, N], b/c: [S, N] -> (y [S, DI], h [DI, N] f32).
    DI must be a multiple of the channel tile `bd`.
    """
    s, di = x.shape
    n = a.shape[1]
    bd = min(bd, di)
    assert di % bd == 0, f"DI={di} not a multiple of BD={bd}"

    kernel = functools.partial(_scan_kernel, seq=s)
    y, h = pl.pallas_call(
        kernel,
        grid=(di // bd,),
        in_specs=[
            pl.BlockSpec((s, bd), lambda i: (0, i)),   # x
            pl.BlockSpec((s, bd), lambda i: (0, i)),   # dt
            pl.BlockSpec((bd, n), lambda i: (i, 0)),   # a
            pl.BlockSpec((s, n), lambda i: (0, 0)),    # b (shared)
            pl.BlockSpec((s, n), lambda i: (0, 0)),    # c (shared)
        ],
        out_specs=[
            pl.BlockSpec((s, bd), lambda i: (0, i)),   # y
            pl.BlockSpec((bd, n), lambda i: (i, 0)),   # h
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s, di), x.dtype),
            jax.ShapeDtypeStruct((di, n), jnp.float32),
        ],
        interpret=True,
    )(x, dt, a, b, c)
    return y, h


def vmem_bytes(bd=DEFAULT_BD, n=16, seq=128, dtype_bytes=2):
    """Estimated VMEM residency per program (DESIGN.md §Perf input)."""
    state = bd * n * 4
    a_tile = bd * n * 4
    io_tiles = seq * bd * dtype_bytes * 2 + 2 * seq * n * dtype_bytes
    return state + a_tile + io_tiles
