"""Pallas BF16 exponent-histogram kernel (L1, profiling path).

The L3 profiler needs exponent histograms of every tensor it logs (paper
§3.1). This kernel computes the 256-bin histogram of the exponent field
from raw BF16 bit patterns, tiled so each program reduces a chunk into a
partial histogram and partials are summed — the same map-reduce shape the
hardware's M-lane counting circuit uses (lexi-hw::histogram_unit).

interpret=True: see attention.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_CHUNK = 2048


def _hist_kernel(bits_ref, hist_ref):
    """One chunk program: one-hot reduce into a 256-bin partial."""
    bits = bits_ref[...]
    exps = (bits >> 7) & 0xFF  # [CHUNK]
    bins = jax.lax.iota(jnp.int32, 256)
    onehot = (exps[:, None] == bins[None, :]).astype(jnp.int32)
    hist_ref[...] = onehot.sum(axis=0)


def exponent_histogram(bits_u16, *, chunk=DEFAULT_CHUNK):
    """256-bin exponent histogram of BF16 bit patterns.

    bits_u16: int32[N] of raw patterns; N padded to `chunk` internally
    (padding uses pattern 0, whose count is corrected afterwards).
    """
    flat = bits_u16.reshape(-1).astype(jnp.int32)
    n = flat.shape[0]
    pad = (-n) % chunk
    padded = jnp.pad(flat, (0, pad), constant_values=0)
    nchunks = padded.shape[0] // chunk

    partials = pl.pallas_call(
        functools.partial(_hist_kernel),
        grid=(nchunks,),
        in_specs=[pl.BlockSpec((chunk,), lambda i: (i,))],
        out_specs=pl.BlockSpec((None, 256), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nchunks, 256), jnp.int32),
        interpret=True,
    )(padded)
    hist = partials.sum(axis=0)
    # Padding contributed `pad` counts of exponent 0.
    return hist.at[0].add(-pad)
