"""Pallas tiled causal attention kernel (L1).

HARDWARE ADAPTATION (DESIGN.md §Hardware-Adaptation): the usual GPU
flash-attention tiles for shared memory per threadblock; on TPU the tiling
targets VMEM and the MXU. The grid iterates (head, q-block); each program
holds a [BQ, D] query tile resident in VMEM and streams K/V in [BK, D]
tiles through an online-softmax accumulator, so VMEM footprint is
O(BQ·D + BK·D) regardless of sequence length and every dot hits the MXU.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls (see /opt/xla-example/README.md); real-TPU numbers are
estimated from the BlockSpec footprint in DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VMEM-friendly tile sizes (small enough for the tiny models' shapes to
# divide evenly after padding; multiples of 8 for TPU lane alignment).
DEFAULT_BQ = 32
DEFAULT_BK = 32


def _attn_kernel(
    q_ref, k_ref, v_ref, o_ref, *, bk: int, sk: int, causal: bool, q_start_mult: int, q_offset: int
):
    """One (head, q-block) program: online softmax over K/V tiles."""
    bq, d = q_ref.shape
    q = q_ref[...].astype(jnp.float32) * (1.0 / (d**0.5))
    qi = pl.program_id(1)  # q-block index

    m = jnp.full((bq,), -1e30, dtype=jnp.float32)
    l = jnp.zeros((bq,), dtype=jnp.float32)
    acc = jnp.zeros((bq, d), dtype=jnp.float32)

    nkb = sk // bk

    def body(kb, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (pl.dslice(kb * bk, bk), slice(None))).astype(jnp.float32)
        v = pl.load(v_ref, (pl.dslice(kb * bk, bk), slice(None))).astype(jnp.float32)
        s = q @ k.T  # [BQ, BK] -> MXU
        if causal:
            # Queries are the last Sq positions of the Sk-length context
            # (matches ref.attention's tril(k=Sk-Sq)).
            q_pos = qi * q_start_mult + jax.lax.iota(jnp.int32, bq) + q_offset
            k_pos = kb * bk + jax.lax.iota(jnp.int32, bk)
            mask = q_pos[:, None] >= k_pos[None, :]
            s = jnp.where(mask, s, -1e30)
        m2 = jnp.maximum(m, s.max(axis=1))
        p = jnp.exp(s - m2[:, None])
        alpha = jnp.exp(m - m2)
        l2 = alpha * l + p.sum(axis=1)
        acc2 = acc * alpha[:, None] + p @ v
        return m2, l2, acc2

    m, l, acc = jax.lax.fori_loop(0, nkb, body, (m, l, acc))
    o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)


def attention(q, k, v, *, causal=True, bq=DEFAULT_BQ, bk=DEFAULT_BK):
    """Tiled causal attention. q: [Sq, H, D], k/v: [Sk, H, D] -> [Sq, H, D].

    Sequence lengths must be multiples of the tile sizes (the L2 model pads
    to tiles); head count is the outer grid dimension.
    """
    sq, h, d = q.shape
    sk = k.shape[0]
    assert sq % bq == 0, f"Sq={sq} not a multiple of BQ={bq}"
    assert sk % bk == 0, f"Sk={sk} not a multiple of BK={bk}"

    # [H, S, D] layout so each head is a contiguous block.
    qh = jnp.swapaxes(q, 0, 1)
    kh = jnp.swapaxes(k, 0, 1)
    vh = jnp.swapaxes(v, 0, 1)

    kernel = functools.partial(
        _attn_kernel, bk=bk, sk=sk, causal=causal, q_start_mult=bq, q_offset=sk - sq
    )
    out = pl.pallas_call(
        kernel,
        grid=(h, sq // bq),
        in_specs=[
            pl.BlockSpec((None, bq, d), lambda hh, qq: (hh, qq, 0)),
            pl.BlockSpec((None, sk, d), lambda hh, qq: (hh, 0, 0)),
            pl.BlockSpec((None, sk, d), lambda hh, qq: (hh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, d), lambda hh, qq: (hh, qq, 0)),
        out_shape=jax.ShapeDtypeStruct((h, sq, d), q.dtype),
        interpret=True,
    )(qh, kh, vh)
    return jnp.swapaxes(out, 0, 1)


def vmem_bytes(bq=DEFAULT_BQ, bk=DEFAULT_BK, d=64, dtype_bytes=2):
    """Estimated VMEM residency per program (DESIGN.md §Perf input)."""
    q_tile = bq * d * dtype_bytes
    kv_tiles = 2 * bk * d * dtype_bytes
    acc = bq * d * 4 + 2 * bq * 4  # f32 accumulator + m/l vectors
    return q_tile + kv_tiles + acc
