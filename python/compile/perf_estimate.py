"""L1 kernel performance estimation (DESIGN.md §Perf).

Pallas interpret mode gives CPU-numpy timings, which say nothing about
TPU performance — so the L1 perf story is *structural*: VMEM residency per
program, MXU-shaped contraction fractions, and HBM traffic vs the
roofline. This script computes those estimates from the BlockSpecs and
prints the table DESIGN.md §Perf references.

Usage: python -m compile.perf_estimate
"""

from . import model as M
from .kernels import attention, mamba_scan

VMEM_BYTES = 16 * 1024 * 1024  # one TPU core's VMEM
MXU_DIM = 128  # systolic array edge


def attention_report(bq=attention.DEFAULT_BQ, bk=attention.DEFAULT_BK, d=64, seq=M.SEQ_IN):
    vmem = attention.vmem_bytes(bq, bk, d)
    # FLOPs per program: 2 matmuls over all kv tiles.
    nkb = seq // bk
    flops = nkb * (2 * bq * bk * d) * 2
    # HBM bytes per program: q tile once, k/v streamed once, o once.
    hbm = (bq * d + 2 * seq * d + bq * d) * 2
    # MXU utilization estimate: contraction dims vs the 128x128 array.
    mxu_fill = min(bq, MXU_DIM) * min(d, MXU_DIM) / (MXU_DIM * MXU_DIM)
    return {
        "kernel": f"attention bq={bq} bk={bk} d={d} S={seq}",
        "vmem_kib": vmem / 1024,
        "vmem_pct": vmem / VMEM_BYTES * 100,
        "arith_intensity": flops / hbm,
        "mxu_fill": mxu_fill,
    }


def scan_report(bd=mamba_scan.DEFAULT_BD, n=16, seq=M.SEQ_IN):
    vmem = mamba_scan.vmem_bytes(bd, n, seq)
    # Per step: state update (3 bd*n mults) + output reduce (bd*n).
    flops = seq * 4 * bd * n
    hbm = (2 * seq * bd + 2 * seq * n + bd * n) * 2
    return {
        "kernel": f"selective_scan bd={bd} N={n} S={seq}",
        "vmem_kib": vmem / 1024,
        "vmem_pct": vmem / VMEM_BYTES * 100,
        "arith_intensity": flops / hbm,
        # Elementwise recurrence: VPU-bound, MXU unused by design.
        "mxu_fill": 0.0,
    }


def main():
    rows = [attention_report(), attention_report(bq=128, bk=128, d=128, seq=1024), scan_report()]
    header = f"{'kernel':44} {'VMEM KiB':>9} {'% VMEM':>7} {'FLOP/B':>7} {'MXU fill':>9}"
    print(header)
    print("-" * len(header))
    for r in rows:
        print(
            f"{r['kernel']:44} {r['vmem_kib']:9.1f} {r['vmem_pct']:7.2f} "
            f"{r['arith_intensity']:7.1f} {r['mxu_fill']:9.2f}"
        )
    print(
        "\nnotes: interpret=True means no TPU wallclock; these are the BlockSpec-"
        "\nderived structure metrics DESIGN.md §Perf tracks. The attention tiles"
        "\nstay <0.5% of VMEM, so real-TPU block sizes can grow 16x (bq=bk=128)"
        "\nto fill the MXU — shown in the second row."
    )


if __name__ == "__main__":
    main()
