"""L2: the hybrid transformer–Mamba(–MoE) model in JAX.

Tiny, architecture-faithful variants of the paper's three models
(Jamba / Zamba / Qwen), dimension-matched to `lexi-models`' `Tiny`
configs. The forward pass calls the L1 Pallas kernels (attention,
selective scan) on the prefill path, and exposes exactly the tensors LEXI
compresses — per-block boundary activations (BF16-quantized), KV caches,
and SSM/conv states — as outputs, so the Rust L3 coordinator owns the
decode loop and the caches transit the (simulated) interconnect.

BF16 semantics: compute runs in f32 for CPU-PJRT stability, but every
logged tensor is passed through a bf16 round-trip (`quantize`), so its
f32 bits are exactly bf16-representable and the Rust profiler recovers
the true exponent streams losslessly.
"""

from dataclasses import dataclass, field
from typing import Dict, List

import jax
import jax.numpy as jnp

from .kernels import attention as attn_k
from .kernels import mamba_scan
from .kernels import ref

# Sequence geometry shared with the Rust runtime (manifest.json records it).
SEQ_IN = 128
OUT_MAX = 64
MAX_SEQ = SEQ_IN + OUT_MAX


@dataclass
class TinyConfig:
    """Dimensions mirror lexi-models' ModelScale::Tiny configs."""

    name: str
    d_model: int = 128
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 512
    d_ff_expert: int = 256
    n_experts: int = 0
    top_k: int = 2
    d_state: int = 16
    d_inner: int = 256
    d_conv: int = 4
    vocab: int = 1024
    blocks: List[str] = field(default_factory=list)

    @property
    def head_dim(self):
        return self.d_model // self.n_heads

    @property
    def kv_dim(self):
        return self.n_kv_heads * self.head_dim

    @property
    def attn_layers(self):
        return [i for i, b in enumerate(self.blocks) if b == "attention"]

    @property
    def mamba_layers(self):
        return [i for i, b in enumerate(self.blocks) if b == "mamba"]


def jamba_tiny() -> TinyConfig:
    return TinyConfig(
        name="jamba-tiny",
        n_kv_heads=2,
        n_experts=4,
        blocks=["mamba", "attention", "moe", "mamba"],
    )


def zamba_tiny() -> TinyConfig:
    return TinyConfig(
        name="zamba-tiny",
        blocks=["mamba", "mamba", "mamba", "mamba", "attention"],
    )


def qwen_tiny() -> TinyConfig:
    return TinyConfig(
        name="qwen-tiny",
        d_state=0,
        d_inner=0,
        d_conv=1,
        blocks=["attention", "mlp", "attention", "mlp", "attention", "mlp"],
    )


ALL_MODELS = {"jamba": jamba_tiny, "zamba": zamba_tiny, "qwen": qwen_tiny}


def quantize(x):
    """BF16 round-trip: every logged tensor is bf16-representable."""
    return x.astype(jnp.bfloat16).astype(jnp.float32)


# --- parameter init --------------------------------------------------------


def init_params(cfg: TinyConfig, seed: int = 0) -> Dict:
    """Seeded parameter pytree, bf16-quantized (weights ship compressed)."""
    key = jax.random.PRNGKey(seed)

    def nxt():
        nonlocal key
        key, sub = jax.random.split(key)
        return sub

    def dense(fan_in, shape):
        return quantize(jax.random.normal(nxt(), shape) / jnp.sqrt(fan_in))

    p: Dict = {"embed": dense(cfg.d_model, (cfg.vocab, cfg.d_model)), "blocks": []}
    d = cfg.d_model
    for kind in cfg.blocks:
        if kind == "attention":
            blk = {
                "wq": dense(d, (d, d)),
                "wk": dense(d, (d, cfg.kv_dim)),
                "wv": dense(d, (d, cfg.kv_dim)),
                "wo": dense(d, (d, d)),
                "norm": jnp.ones((d,), jnp.float32),
            }
        elif kind == "mamba":
            di, n = cfg.d_inner, cfg.d_state
            blk = {
                "in_x": dense(d, (d, di)),
                "in_z": dense(d, (d, di)),
                "conv": dense(cfg.d_conv, (cfg.d_conv, di)),
                "w_dt": dense(d, (di,)) * 0.0 - 4.0,  # softplus bias ≈ small dt
                "wx_dt": dense(d, (d, di)),
                "wb": dense(d, (d, n)),
                "wc": dense(d, (d, n)),
                "a_log": jnp.log(
                    jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (di, 1))
                ),
                "out": dense(di, (di, d)),
                "norm": jnp.ones((d,), jnp.float32),
            }
        elif kind == "moe":
            e, dfe = cfg.n_experts, cfg.d_ff_expert
            blk = {
                "router": dense(d, (d, e)),
                "w1": dense(d, (e, d, dfe)),
                "w3": dense(d, (e, d, dfe)),
                "w2": dense(dfe, (e, dfe, d)),
                "norm": jnp.ones((d,), jnp.float32),
            }
        elif kind == "mlp":
            blk = {
                "w1": dense(d, (d, cfg.d_ff)),
                "w3": dense(d, (d, cfg.d_ff)),
                "w2": dense(cfg.d_ff, (cfg.d_ff, d)),
                "norm": jnp.ones((d,), jnp.float32),
            }
        else:
            raise ValueError(kind)
        p["blocks"].append(blk)
    p["final_norm"] = jnp.ones((d,), jnp.float32)
    return p


# --- building blocks -------------------------------------------------------


def rmsnorm(x, w):
    v = jnp.mean(x.astype(jnp.float32) ** 2, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(v + 1e-6) * w).astype(x.dtype)


def silu(x):
    return x * jax.nn.sigmoid(x)


def _repeat_kv(kv, n_rep):
    """[S, KVH, D] -> [S, KVH*n_rep, D] (grouped-query attention)."""
    if n_rep == 1:
        return kv
    s, h, dd = kv.shape
    return jnp.repeat(kv, n_rep, axis=1)


def attn_prefill(cfg, blk, x):
    """Full-sequence attention via the Pallas kernel. x: [S, D]."""
    s, d = x.shape
    h = rmsnorm(x, blk["norm"])
    q = (h @ blk["wq"]).reshape(s, cfg.n_heads, cfg.head_dim)
    k = (h @ blk["wk"]).reshape(s, cfg.n_kv_heads, cfg.head_dim)
    v = (h @ blk["wv"]).reshape(s, cfg.n_kv_heads, cfg.head_dim)
    rep = cfg.n_heads // cfg.n_kv_heads
    o = attn_k.attention(q, _repeat_kv(k, rep), _repeat_kv(v, rep))
    y = o.reshape(s, d) @ blk["wo"]
    kv = jnp.stack([k.reshape(s, cfg.kv_dim), v.reshape(s, cfg.kv_dim)], axis=0)
    return x + y, quantize(kv)  # kv: [2, S, KVDIM]


def attn_decode(cfg, blk, x, kv_cache, pos):
    """Single-token attention over the cache. x: [D], kv_cache [2,MAX,KVDIM]."""
    d = cfg.d_model
    h = rmsnorm(x, blk["norm"])
    q = (h @ blk["wq"]).reshape(cfg.n_heads, cfg.head_dim)
    k_new = (h @ blk["wk"]).reshape(cfg.kv_dim)
    v_new = (h @ blk["wv"]).reshape(cfg.kv_dim)
    kv_cache = jax.lax.dynamic_update_slice(
        kv_cache, quantize(jnp.stack([k_new, v_new]))[:, None, :], (0, pos, 0)
    )
    ks = kv_cache[0].reshape(MAX_SEQ, cfg.n_kv_heads, cfg.head_dim)
    vs = kv_cache[1].reshape(MAX_SEQ, cfg.n_kv_heads, cfg.head_dim)
    rep = cfg.n_heads // cfg.n_kv_heads
    ks = _repeat_kv(ks, rep)
    vs = _repeat_kv(vs, rep)
    scale = 1.0 / jnp.sqrt(jnp.asarray(cfg.head_dim, jnp.float32))
    logits = jnp.einsum("hd,shd->hs", q, ks) * scale
    mask = jnp.arange(MAX_SEQ) <= pos
    logits = jnp.where(mask[None, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("hs,shd->hd", p, vs).reshape(d)
    return x + o @ blk["wo"], kv_cache


def _mamba_proj(cfg, blk, h):
    """Shared projections for scan inputs. h: [.., D]."""
    xm = h @ blk["in_x"]
    z = h @ blk["in_z"]
    dt = jax.nn.softplus(h @ blk["wx_dt"] + blk["w_dt"])
    b = h @ blk["wb"]
    c = h @ blk["wc"]
    return xm, z, dt, b, c


def mamba_prefill(cfg, blk, x):
    """Full-sequence Mamba via the Pallas scan. x: [S, D]."""
    s, d = x.shape
    h = rmsnorm(x, blk["norm"])
    xm, z, dt, b, c = _mamba_proj(cfg, blk, h)
    # Causal depthwise conv over the sequence.
    conv_in = jnp.pad(xm, ((cfg.d_conv - 1, 0), (0, 0)))
    xc = sum(
        conv_in[i : i + s] * blk["conv"][i][None, :] for i in range(cfg.d_conv)
    )
    xc = silu(xc)
    a = -jnp.exp(blk["a_log"])
    y, h_final = mamba_scan.selective_scan(xc, dt, a, b, c)
    y = y * silu(z)
    out = x + y @ blk["out"]
    conv_state = conv_in[s : s + cfg.d_conv - 1]  # last d_conv-1 inputs
    # conv state must be the last (d_conv-1) xm rows:
    conv_state = xm[s - (cfg.d_conv - 1) :]
    return out, quantize(h_final), quantize(conv_state)


def mamba_decode(cfg, blk, x, h_state, conv_state):
    """Single-token Mamba step. x: [D], h_state [DI,N], conv [K-1,DI]."""
    h = rmsnorm(x, blk["norm"])
    xm, z, dt, b, c = _mamba_proj(cfg, blk, h)
    window = jnp.concatenate([conv_state, xm[None, :]], axis=0)  # [K, DI]
    xc = silu((window * blk["conv"]).sum(axis=0))
    a = -jnp.exp(blk["a_log"])
    y, h2 = ref.selective_scan_step(h_state, xc, dt, a, b, c)
    y = y * silu(z)
    out = x + y @ blk["out"]
    return out, quantize(h2), quantize(window[1:])


def moe_block(cfg, blk, x):
    """Top-k MoE; dense evaluation of all experts (tiny sizes). x: [.., D]."""
    h = rmsnorm(x, blk["norm"])
    gate_logits = h @ blk["router"]  # [.., E]
    gates = jax.nn.softmax(gate_logits, axis=-1)
    # Top-k mask.
    thresh = jnp.sort(gates, axis=-1)[..., -cfg.top_k][..., None]
    mask = gates >= thresh
    gates = jnp.where(mask, gates, 0.0)
    gates = gates / gates.sum(axis=-1, keepdims=True)
    # Dense expert evaluation: y_e = (silu(h w1_e) * (h w3_e)) w2_e.
    hh = jnp.einsum("...d,edf->...ef", h, blk["w1"])
    gg = jnp.einsum("...d,edf->...ef", h, blk["w3"])
    yy = silu(hh) * gg
    y = jnp.einsum("...ef,efd->...ed", yy, blk["w2"])
    y = (y * gates[..., None]).sum(axis=-2)
    return x + y


def mlp_block(cfg, blk, x):
    h = rmsnorm(x, blk["norm"])
    y = (silu(h @ blk["w1"]) * (h @ blk["w3"])) @ blk["w2"]
    return x + y


# --- full model ------------------------------------------------------------


def prefill(cfg: TinyConfig, params, tokens):
    """Prefill over `tokens` [SEQ_IN] i32.

    Returns (logits [vocab], acts [L, SEQ_IN, D], kv [A,2,MAX_SEQ,KVDIM],
             ssm [M,DI,N], conv [M,K-1,DI]) — every tensor bf16-quantized.
    """
    x = params["embed"][tokens]  # [S, D]
    acts = []
    kvs = []
    ssms = []
    convs = []
    for kind, blk in zip(cfg.blocks, params["blocks"]):
        if kind == "attention":
            x, kv = attn_prefill(cfg, blk, x)
            pad = jnp.zeros((2, MAX_SEQ - SEQ_IN, cfg.kv_dim), jnp.float32)
            kvs.append(jnp.concatenate([kv, pad], axis=1))
        elif kind == "mamba":
            x, h_final, conv_state = mamba_prefill(cfg, blk, x)
            ssms.append(h_final)
            convs.append(conv_state)
        elif kind == "moe":
            x = moe_block(cfg, blk, x)
        else:
            x = mlp_block(cfg, blk, x)
        x = quantize(x)
        acts.append(x)
    x = rmsnorm(x, params["final_norm"])
    logits = x[-1] @ params["embed"].T
    return (
        quantize(logits),
        jnp.stack(acts, axis=0),
        jnp.stack(kvs, axis=0) if kvs else jnp.zeros((0, 2, MAX_SEQ, cfg.kv_dim)),
        jnp.stack(ssms, axis=0) if ssms else jnp.zeros((0, max(cfg.d_inner, 1), max(cfg.d_state, 1))),
        jnp.stack(convs, axis=0) if convs else jnp.zeros((0, max(cfg.d_conv - 1, 1), max(cfg.d_inner, 1))),
    )


def decode_step(cfg: TinyConfig, params, token, pos, kv, ssm, conv):
    """One decode step.

    token: i32[], pos: i32[] (absolute position), caches as from prefill.
    Returns (logits, acts [L, D], kv', ssm', conv').
    """
    x = params["embed"][token]  # [D]
    acts = []
    ai = 0
    mi = 0
    kv_out = kv
    ssm_out = ssm
    conv_out = conv
    for kind, blk in zip(cfg.blocks, params["blocks"]):
        if kind == "attention":
            x, new_kv = attn_decode(cfg, blk, x, kv_out[ai], pos)
            kv_out = kv_out.at[ai].set(new_kv)
            ai += 1
        elif kind == "mamba":
            x, h2, c2 = mamba_decode(cfg, blk, x, ssm_out[mi], conv_out[mi])
            ssm_out = ssm_out.at[mi].set(h2)
            conv_out = conv_out.at[mi].set(c2)
            mi += 1
        elif kind == "moe":
            x = moe_block(cfg, blk, x)
        else:
            x = mlp_block(cfg, blk, x)
        x = quantize(x)
        acts.append(x)
    x = rmsnorm(x, params["final_norm"])
    logits = x @ params["embed"].T
    return quantize(logits), jnp.stack(acts, axis=0), kv_out, ssm_out, conv_out
