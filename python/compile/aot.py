"""AOT pipeline: lower the L2 model to HLO text for the Rust runtime.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
≥ 0.5 emits protos with 64-bit instruction ids that xla_extension 0.5.1
(behind the rust `xla` 0.1.6 crate) rejects; the text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

Per model we emit two executables — `{model}_prefill` and `{model}_decode`
— with parameters baked in as constants (the Rust coordinator feeds only
tokens/positions/caches), plus `manifest.json` describing every input and
output shape so the runtime can build literals without guessing.

Usage: python -m compile.aot --out-dir ../artifacts [--models jamba,zamba,qwen]
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants: the default print options elide weight
    # constants as `{...}`, which parses back as garbage — the baked-in
    # parameters must survive the text round-trip.
    text = comp.as_hlo_text(print_large_constants=True)
    assert "{...}" not in text, "HLO text still elides constants"
    return text


def shape_of(x):
    return {"shape": list(x.shape), "dtype": str(x.dtype)}


def build_model(name: str, seed: int):
    cfg = M.ALL_MODELS[name]()
    params = M.init_params(cfg, seed=seed)

    def prefill_fn(tokens):
        return M.prefill(cfg, params, tokens)

    def decode_fn(token, pos, kv, ssm, conv):
        return M.decode_step(cfg, params, token, pos, kv, ssm, conv)

    return cfg, params, prefill_fn, decode_fn


def lower_model(name: str, out_dir: str, seed: int) -> dict:
    cfg, params, prefill_fn, decode_fn = build_model(name, seed)

    tokens_spec = jax.ShapeDtypeStruct((M.SEQ_IN,), jnp.int32)
    lowered_pre = jax.jit(prefill_fn).lower(tokens_spec)
    pre_path = os.path.join(out_dir, f"{name}_prefill.hlo.txt")
    with open(pre_path, "w") as f:
        f.write(to_hlo_text(lowered_pre))

    # Concrete prefill outputs pin the cache shapes for decode lowering.
    out = jax.jit(prefill_fn)(jnp.zeros((M.SEQ_IN,), jnp.int32))
    logits, acts, kv, ssm, conv = out

    tok_spec = jax.ShapeDtypeStruct((), jnp.int32)
    pos_spec = jax.ShapeDtypeStruct((), jnp.int32)
    lowered_dec = jax.jit(decode_fn).lower(
        tok_spec,
        pos_spec,
        jax.ShapeDtypeStruct(kv.shape, kv.dtype),
        jax.ShapeDtypeStruct(ssm.shape, ssm.dtype),
        jax.ShapeDtypeStruct(conv.shape, conv.dtype),
    )
    dec_path = os.path.join(out_dir, f"{name}_decode.hlo.txt")
    with open(dec_path, "w") as f:
        f.write(to_hlo_text(lowered_dec))

    return {
        "seq_in": M.SEQ_IN,
        "out_max": M.OUT_MAX,
        "max_seq": M.MAX_SEQ,
        "vocab": cfg.vocab,
        "d_model": cfg.d_model,
        "blocks": cfg.blocks,
        "prefill": {
            "file": os.path.basename(pre_path),
            "inputs": [shape_of(jnp.zeros((M.SEQ_IN,), jnp.int32))],
            "outputs": [shape_of(x) for x in out],
            "output_names": ["logits", "acts", "kv", "ssm", "conv"],
        },
        "decode": {
            "file": os.path.basename(dec_path),
            "inputs": [
                shape_of(jnp.zeros((), jnp.int32)),
                shape_of(jnp.zeros((), jnp.int32)),
                shape_of(kv),
                shape_of(ssm),
                shape_of(conv),
            ],
            "input_names": ["token", "pos", "kv", "ssm", "conv"],
            "outputs": [
                shape_of(logits),
                {"shape": [len(cfg.blocks), cfg.d_model], "dtype": "float32"},
                shape_of(kv),
                shape_of(ssm),
                shape_of(conv),
            ],
            "output_names": ["logits", "acts", "kv", "ssm", "conv"],
        },
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default="jamba,zamba,qwen")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {}
    for name in args.models.split(","):
        name = name.strip()
        print(f"lowering {name} ...", flush=True)
        manifest[name] = lower_model(name, args.out_dir, args.seed)
    man_path = os.path.join(args.out_dir, "manifest.json")
    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {man_path}")


if __name__ == "__main__":
    main()
