"""L2 model tests: shapes, bf16-representability, decode/prefill coherence."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


@pytest.fixture(scope="module", params=list(M.ALL_MODELS))
def model(request):
    cfg = M.ALL_MODELS[request.param]()
    params = M.init_params(cfg, seed=0)
    return cfg, params


def _prefill(cfg, params, seed=0):
    tokens = (jnp.arange(M.SEQ_IN, dtype=jnp.int32) * 7 + seed) % cfg.vocab
    return tokens, M.prefill(cfg, params, tokens)


def test_prefill_shapes(model):
    cfg, params = model
    _, (logits, acts, kv, ssm, conv) = _prefill(cfg, params)
    assert logits.shape == (cfg.vocab,)
    assert acts.shape == (len(cfg.blocks), M.SEQ_IN, cfg.d_model)
    assert kv.shape[0] == len(cfg.attn_layers)
    assert kv.shape[1:] == (2, M.MAX_SEQ, cfg.kv_dim)
    assert ssm.shape[0] == len(cfg.mamba_layers)
    assert conv.shape[0] == len(cfg.mamba_layers)


def test_all_outputs_finite(model):
    cfg, params = model
    _, outs = _prefill(cfg, params)
    for o in outs:
        assert np.isfinite(np.asarray(o)).all()


def test_outputs_are_bf16_representable(model):
    """The contract with the Rust profiler: every logged tensor's f32 bits
    must survive a bf16 round-trip unchanged (LEXI's lossless premise)."""
    cfg, params = model
    _, (logits, acts, kv, ssm, conv) = _prefill(cfg, params)
    for name, t in [("logits", logits), ("acts", acts), ("kv", kv), ("ssm", ssm), ("conv", conv)]:
        a = np.asarray(t, dtype=np.float32)
        rt = a.astype(np.dtype("bfloat16") if hasattr(np, "bfloat16") else None) if False else None
        # numpy lacks bf16; emulate the round-trip via bit masking.
        bits = a.view(np.uint32)
        assert (bits & 0xFFFF == 0).all(), f"{name} not bf16-representable"


def test_decode_advances_cache(model):
    cfg, params = model
    _, (logits, _, kv, ssm, conv) = _prefill(cfg, params)
    tok = jnp.argmax(logits).astype(jnp.int32)
    pos = jnp.asarray(M.SEQ_IN, jnp.int32)
    l2, a2, kv2, ssm2, conv2 = M.decode_step(cfg, params, tok, pos, kv, ssm, conv)
    assert l2.shape == (cfg.vocab,)
    assert a2.shape == (len(cfg.blocks), cfg.d_model)
    if len(cfg.attn_layers) > 0:
        # The new KV slot must be written at `pos`.
        assert not np.allclose(np.asarray(kv2[0, :, M.SEQ_IN]), 0.0)
        # Earlier slots unchanged.
        np.testing.assert_array_equal(
            np.asarray(kv2[0, :, : M.SEQ_IN]), np.asarray(kv[0, :, : M.SEQ_IN])
        )
    if len(cfg.mamba_layers) > 0:
        assert not np.allclose(np.asarray(ssm2), np.asarray(ssm))


def test_decode_is_deterministic(model):
    cfg, params = model
    _, (logits, _, kv, ssm, conv) = _prefill(cfg, params)
    tok = jnp.argmax(logits).astype(jnp.int32)
    pos = jnp.asarray(M.SEQ_IN, jnp.int32)
    out1 = M.decode_step(cfg, params, tok, pos, kv, ssm, conv)
    out2 = M.decode_step(cfg, params, tok, pos, kv, ssm, conv)
    for a, b in zip(out1, out2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_multi_step_decode_stable(model):
    cfg, params = model
    _, (logits, _, kv, ssm, conv) = _prefill(cfg, params)
    tok = jnp.argmax(logits).astype(jnp.int32)
    for step in range(4):
        pos = jnp.asarray(M.SEQ_IN + step, jnp.int32)
        logits, _, kv, ssm, conv = M.decode_step(cfg, params, tok, pos, kv, ssm, conv)
        assert np.isfinite(np.asarray(logits)).all()
        tok = jnp.argmax(logits).astype(jnp.int32)


def test_different_tokens_give_different_logits(model):
    cfg, params = model
    _, (l1, *_rest) = _prefill(cfg, params, seed=0)
    _, (l2, *_rest2) = _prefill(cfg, params, seed=3)
    assert not np.allclose(np.asarray(l1), np.asarray(l2))


def test_exponent_entropy_of_real_activations(model):
    """Fig 1a on real tensors: activation exponent streams carry well
    under 8 bits — the compressibility LEXI exploits."""
    cfg, params = model
    _, (_, acts, _, _, _) = _prefill(cfg, params)
    a = np.asarray(acts, dtype=np.float32)
    exps = (a.view(np.uint32) >> 23) & 0xFF  # f32 exponent == bf16 exponent
    hist = np.bincount(exps.reshape(-1), minlength=256)
    p = hist / hist.sum()
    p = p[p > 0]
    entropy = -(p * np.log2(p)).sum()
    assert entropy < 4.5, f"activation exponent entropy {entropy}"
