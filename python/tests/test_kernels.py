"""L1 kernel correctness: Pallas vs pure-jnp oracles (pytest + hypothesis)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Property tests must survive environments without hypothesis (ISSUE 9
# satellite): fall back to the vendored deterministic mini-runner so
# they still execute (seeded, fixed example count) instead of skipping.
try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, st

from compile.kernels import attention as attn_k
from compile.kernels import exp_hist, mamba_scan, ref

# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def _rand_qkv(rng, sq, sk, h, d, dtype):
    q = jnp.asarray(rng.normal(size=(sq, h, d)), dtype=dtype)
    k = jnp.asarray(rng.normal(size=(sk, h, d)), dtype=dtype)
    v = jnp.asarray(rng.normal(size=(sk, h, d)), dtype=dtype)
    return q, k, v


@pytest.mark.parametrize("sq,sk", [(32, 32), (64, 64), (128, 128), (32, 96)])
@pytest.mark.parametrize("h,d", [(1, 16), (4, 32), (2, 64)])
def test_attention_matches_ref(sq, sk, h, d):
    rng = np.random.default_rng(sq * 1000 + sk + h * 7 + d)
    q, k, v = _rand_qkv(rng, sq, sk, h, d, jnp.float32)
    out = attn_k.attention(q, k, v)
    expect = ref.attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-5, rtol=2e-5)


def test_attention_causality():
    # Output at position t must not depend on k/v beyond t.
    rng = np.random.default_rng(0)
    q, k, v = _rand_qkv(rng, 64, 64, 2, 32, jnp.float32)
    base = attn_k.attention(q, k, v)
    k2 = k.at[40:].set(999.0)
    v2 = v.at[40:].set(-999.0)
    pert = attn_k.attention(q, k2, v2)
    np.testing.assert_allclose(
        np.asarray(base[:40]), np.asarray(pert[:40]), atol=1e-5
    )


def test_attention_bf16_inputs():
    rng = np.random.default_rng(3)
    q, k, v = _rand_qkv(rng, 32, 32, 2, 32, jnp.bfloat16)
    out = attn_k.attention(q, k, v)
    expect = ref.attention(q, k, v)
    np.testing.assert_allclose(
        np.asarray(out, dtype=np.float32),
        np.asarray(expect, dtype=np.float32),
        atol=3e-2,
        rtol=3e-2,
    )


@settings(max_examples=15, deadline=None)
@given(
    sq_blocks=st.integers(1, 3),
    h=st.integers(1, 4),
    d=st.sampled_from([16, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_hypothesis(sq_blocks, h, d, seed):
    sq = 32 * sq_blocks
    rng = np.random.default_rng(seed)
    q, k, v = _rand_qkv(rng, sq, sq, h, d, jnp.float32)
    out = attn_k.attention(q, k, v)
    expect = ref.attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# selective scan
# ---------------------------------------------------------------------------


def _rand_scan(rng, s, di, n, dtype=jnp.float32):
    x = jnp.asarray(rng.normal(size=(s, di)), dtype=dtype)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(s, di)), dtype=dtype)
    a = jnp.asarray(-rng.uniform(0.3, 2.0, size=(di, n)), dtype=jnp.float32)
    b = jnp.asarray(rng.normal(size=(s, n)), dtype=dtype)
    c = jnp.asarray(rng.normal(size=(s, n)), dtype=dtype)
    return x, dt, a, b, c


@pytest.mark.parametrize("s,di,n", [(8, 128, 8), (16, 128, 16), (32, 256, 16)])
def test_scan_matches_ref(s, di, n):
    rng = np.random.default_rng(s + di + n)
    x, dt, a, b, c = _rand_scan(rng, s, di, n)
    y1, h1 = mamba_scan.selective_scan(x, dt, a, b, c)
    y2, h2 = ref.selective_scan(x, dt, a, b, c)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-5, rtol=1e-5)


def test_scan_step_consistency():
    # Running the step oracle S times must equal the full scan.
    rng = np.random.default_rng(9)
    s, di, n = 12, 128, 8
    x, dt, a, b, c = _rand_scan(rng, s, di, n)
    y_full, h_full = mamba_scan.selective_scan(x, dt, a, b, c)
    h = jnp.zeros((di, n), jnp.float32)
    ys = []
    for t in range(s):
        y, h = ref.selective_scan_step(h, x[t], dt[t], a, b[t], c[t])
        ys.append(y)
    np.testing.assert_allclose(
        np.asarray(jnp.stack(ys)), np.asarray(y_full), atol=1e-5, rtol=1e-5
    )
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_full), atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(
    s=st.sampled_from([4, 8, 24]),
    di_mult=st.integers(1, 2),
    n=st.sampled_from([4, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_scan_hypothesis(s, di_mult, n, seed):
    di = 128 * di_mult
    rng = np.random.default_rng(seed)
    x, dt, a, b, c = _rand_scan(rng, s, di, n)
    y1, h1 = mamba_scan.selective_scan(x, dt, a, b, c)
    y2, h2 = ref.selective_scan(x, dt, a, b, c)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-5, rtol=2e-5)


def test_scan_state_decays():
    # With negative a and positive dt, an impulse decays — no blow-ups.
    rng = np.random.default_rng(4)
    x, dt, a, b, c = _rand_scan(rng, 64, 128, 8)
    y, h = mamba_scan.selective_scan(x, dt, a, b, c)
    assert np.isfinite(np.asarray(y)).all()
    assert np.isfinite(np.asarray(h)).all()


# ---------------------------------------------------------------------------
# exponent histogram
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [1, 100, 2048, 5000])
def test_hist_matches_ref(n):
    rng = np.random.default_rng(n)
    bits = jnp.asarray(rng.integers(0, 65536, size=n), dtype=jnp.int32)
    h1 = exp_hist.exponent_histogram(bits)
    h2 = ref.exponent_histogram(bits)
    np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))
    assert int(h1.sum()) == n


def test_hist_counts_real_bf16_exponents():
    vals = jnp.asarray(np.random.default_rng(1).normal(0, 0.02, 4096), jnp.bfloat16)
    bits = jnp.asarray(np.asarray(vals).view(np.uint16), jnp.int32)
    h = exp_hist.exponent_histogram(bits)
    # Gaussian σ=0.02: all exponents well below 127 (values < 1).
    assert int(h[128:].sum()) == 0
    assert int(h.sum()) == 4096


@settings(max_examples=10, deadline=None)
@given(n=st.integers(1, 4096), seed=st.integers(0, 2**31 - 1))
def test_hist_hypothesis(n, seed):
    rng = np.random.default_rng(seed)
    bits = jnp.asarray(rng.integers(0, 65536, size=n), dtype=jnp.int32)
    h1 = exp_hist.exponent_histogram(bits)
    h2 = ref.exponent_histogram(bits)
    np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))


# ---------------------------------------------------------------------------
# structural perf estimates (DESIGN.md §Perf)
# ---------------------------------------------------------------------------


def test_perf_estimates_within_vmem():
    from compile import perf_estimate as pe

    for r in [pe.attention_report(), pe.attention_report(bq=128, bk=128, d=128, seq=1024), pe.scan_report()]:
        assert r["vmem_pct"] < 50.0, r
        assert r["arith_intensity"] > 0
