"""Deterministic stand-in for the slice of the hypothesis API that
test_kernels.py uses (ISSUE 9 satellite): `@given` with keyword
strategies, `@settings(max_examples=, deadline=)`, `st.integers`, and
`st.sampled_from`.

The real hypothesis is used when installed; this module only loads when
the import fails, so property tests still *run* (seeded, fixed example
count) instead of being skipped wholesale in hermetic containers. No
shrinking, no example database — a failure reports the drawn kwargs in
the assertion context and is exactly reproducible from the test name.
"""

import functools
import random
import zlib


class _Strategy:
    """A draw function over a `random.Random`."""

    def __init__(self, draw):
        self.draw = draw


class st:
    """Mirror of `hypothesis.strategies` for the two strategies used."""

    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda r: r.randint(min_value, max_value))

    @staticmethod
    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda r: r.choice(elements))


def settings(max_examples=100, deadline=None, **_ignored):
    """Record `max_examples` on the (already-@given-wrapped) test.

    `deadline` and anything else hypothesis-specific is accepted and
    ignored — this runner has no timing machinery.
    """

    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return deco


def given(**strategies_kwargs):
    """Run the test once per example with kwargs drawn from the
    strategies. The RNG is seeded from the test's name, so every run
    (and every machine) sees the same example sequence.
    """

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_fallback_max_examples", 20)
            rng = random.Random(zlib.crc32(fn.__name__.encode()))
            for i in range(n):
                drawn = {
                    name: s.draw(rng)
                    for name, s in sorted(strategies_kwargs.items())
                }
                try:
                    fn(*args, **drawn, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"{fn.__name__} failed on fallback example "
                        f"{i + 1}/{n}: {drawn}"
                    ) from e

        # functools.wraps sets __wrapped__, which pytest follows when
        # collecting the test's signature — it would then demand the
        # strategy kwargs as fixtures. The wrapper must present its own
        # (*args, **kwargs) signature, exactly like hypothesis does.
        del wrapper.__wrapped__
        return wrapper

    return deco
