"""AOT round-trip: HLO text parses back and reproduces jax numerics.

This is the build-time guarantee that the Rust runtime (which loads the
same text through xla_extension's parser) sees correct weights — large
constants must survive `as_hlo_text(print_large_constants=True)`.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot
from compile import model as M

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


_CLIENT = None


def _client():
    global _CLIENT
    if _CLIENT is None:
        _CLIENT = xc.make_cpu_client()
    return _CLIENT


def _roundtrip_execute(hlo_text, args):
    """Parse HLO text (the same parser the rust runtime's xla_extension
    uses) → stablehlo → compile → execute on the PJRT CPU client."""
    import jaxlib._jax as jx

    client = _client()
    mod = xc._xla.hlo_module_from_text(hlo_text)
    shlo = xc._xla.mlir.hlo_to_stablehlo(mod.as_serialized_hlo_module_proto())
    exe = client.compile_and_load(shlo, jx.DeviceList(tuple(client.devices()[:1])))
    bufs = [client.buffer_from_pyval(np.asarray(a)) for a in args]
    out = exe.execute(bufs)
    flat = []
    for o in out:
        if isinstance(o, (list, tuple)):
            flat.extend(np.asarray(x) for x in o)
        else:
            flat.append(np.asarray(o))
    return flat


def test_text_roundtrip_small_function():
    # Known environment skew (ROADMAP §Parked): some jax installs pair a
    # jaxlib that does not expose the private `jaxlib._jax` module
    # `_roundtrip_execute` needs — skip on those rather than fail, like
    # the artifact tests skip when artifacts are absent.
    pytest.importorskip(
        "jaxlib._jax", reason="jax/jaxlib skew: jaxlib._jax unavailable"
    )

    def fn(x):
        w = jnp.arange(16.0, dtype=jnp.float32).reshape(4, 4)
        return (x @ w + 1.0,)

    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((4, 4), jnp.float32))
    text = aot.to_hlo_text(lowered)
    x = np.random.default_rng(0).normal(size=(4, 4)).astype(np.float32)
    got = _roundtrip_execute(text, [x])
    expect = np.asarray(fn(jnp.asarray(x))[0])
    np.testing.assert_allclose(got[0], expect, atol=1e-6)


@pytest.mark.parametrize("name", ["jamba", "zamba", "qwen"])
def test_prefill_artifact_matches_jax(name):
    path = os.path.join(ARTIFACTS, f"{name}_prefill.hlo.txt")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    cfg, params, prefill_fn, _ = aot.build_model(name, seed=0)
    tokens = np.asarray((np.arange(M.SEQ_IN) * 3) % cfg.vocab, dtype=np.int32)
    expect = [np.asarray(o) for o in prefill_fn(jnp.asarray(tokens))]
    got = _roundtrip_execute(open(path).read(), [tokens])
    assert len(got) == len(expect)
    for g, e in zip(got, expect):
        # The text path and the direct path fuse dots differently; one-ulp
        # f32 differences flip bf16 buckets after quantize(), so compare at
        # bf16 granularity (immaterial for exponent statistics).
        np.testing.assert_allclose(g, e, atol=0.05, rtol=0.05)
        if g.size > 0:
            exact = np.mean(g == e)
            assert exact > 0.2, f"only {exact:.2%} exactly equal"


@pytest.mark.parametrize("name", ["jamba"])
def test_decode_artifact_matches_jax(name):
    path = os.path.join(ARTIFACTS, f"{name}_decode.hlo.txt")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    cfg, params, prefill_fn, decode_fn = aot.build_model(name, seed=0)
    tokens = jnp.zeros((M.SEQ_IN,), jnp.int32)
    logits, acts, kv, ssm, conv = prefill_fn(tokens)
    tok = np.asarray(jnp.argmax(logits), dtype=np.int32)
    pos = np.asarray(M.SEQ_IN, dtype=np.int32)
    expect = [
        np.asarray(o)
        for o in decode_fn(jnp.asarray(tok), jnp.asarray(pos), kv, ssm, conv)
    ]
    got = _roundtrip_execute(
        open(path).read(),
        [tok, pos, np.asarray(kv), np.asarray(ssm), np.asarray(conv)],
    )
    for g, e in zip(got, expect):
        np.testing.assert_allclose(g, e, atol=0.05, rtol=0.05)


def test_manifest_consistent():
    man = os.path.join(ARTIFACTS, "manifest.json")
    if not os.path.exists(man):
        pytest.skip("artifacts not built")
    import json

    m = json.load(open(man))
    for name, entry in m.items():
        assert entry["seq_in"] == M.SEQ_IN
        assert os.path.exists(os.path.join(ARTIFACTS, entry["prefill"]["file"]))
        assert os.path.exists(os.path.join(ARTIFACTS, entry["decode"]["file"]))
        assert entry["prefill"]["output_names"][0] == "logits"
