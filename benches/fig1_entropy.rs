//! Fig 1 regenerator — exponent statistics profiling and volume savings.
//!
//! (a) per-field entropy and distinct-exponent counts on the Jamba model
//!     over a WikiText-2-shaped workload;
//! (b) exponent volume before/after LEXI for weights vs activations+caches;
//! (c) per-block-kind (Mamba / Transformer / MoE) communication reduction.
//!
//! Paper reference values: entropy <3 bits / <32 distinct; 422→151 MB and
//! 360→155 MB (1.47× / 1.39× overall value-volume reduction); 40/39/36%
//! comm reduction for Mamba/Transformer/MoE blocks.

use lexi::models::activations;
use lexi::models::config::BlockKind;
use lexi::models::corpus::Corpus;
use lexi::models::traffic::{self, TransferKind};
use lexi::models::weights::WeightStream;
use lexi::models::{ModelConfig, ModelScale};
use lexi::sim::compression::{CompressionMode, CrTable};
use lexi_bench::Table;
use lexi_core::huffman;
use lexi_core::stats::Histogram;

fn main() {
    let cfg = ModelConfig::jamba(ModelScale::Paper);
    let corpus = Corpus::wikitext2();

    // ---- (a) per-field statistics ------------------------------------
    println!("Fig 1a — field statistics (jamba, wikitext-2 shaped):");
    let mut ta = Table::new(&["stream", "H(exp) bits", "distinct exps", "H(mant) bits"]);
    for (name, exps) in [
        (
            "weights/L0",
            WeightStream::sample_exponents(&cfg, 0, 42, 400_000),
        ),
        (
            "activations/L2",
            activations::sample_exponents(&cfg, 2, TransferKind::Activation, 42, 400_000),
        ),
        (
            "kv-cache/L4",
            activations::sample_exponents(&cfg, 4, TransferKind::KvCache, 42, 400_000),
        ),
        (
            "ssm-state/L0",
            activations::sample_exponents(&cfg, 0, TransferKind::SsmState, 42, 400_000),
        ),
    ] {
        let h = Histogram::from_bytes(&exps);
        // Mantissas of well-scaled data are ~uniform: report the measured
        // value from a synthetic full-value stream.
        let mut rng = lexi_core::prng::Rng::new(1);
        let mant: Vec<u8> = (0..exps.len()).map(|_| (rng.next_u32() & 0x7f) as u8).collect();
        let hm = Histogram::from_bytes(&mant);
        ta.row(vec![
            name.into(),
            format!("{:.2}", h.entropy_bits()),
            h.distinct().to_string(),
            format!("{:.2}", hm.entropy_bits()),
        ]);
    }
    ta.print();

    // ---- (b) exponent volume before/after ------------------------------
    println!("\nFig 1b — exponent volume (whole inference, jamba @ paper scale):");
    let transfers = traffic::full_inference(&cfg, &corpus);
    let mut weights_bytes = 0u64;
    let mut act_bytes = 0u64;
    for t in &transfers {
        match t.kind {
            TransferKind::Weights => weights_bytes += t.bytes,
            _ => act_bytes += t.bytes,
        }
    }
    // Exponent share of BF16 volume = 8/16.
    let w_exp_mb = weights_bytes as f64 / 2.0 / 1e6;
    let a_exp_mb = act_bytes as f64 / 2.0 / 1e6;
    let cr_w = {
        let e = WeightStream::sample_exponents(&cfg, 0, 42, 400_000);
        huffman::compress_exponents(&e).expect("non-empty").ratio()
    };
    let cr_a = {
        let e = activations::sample_exponents(&cfg, 1, TransferKind::Activation, 42, 400_000);
        huffman::compress_exponents(&e).expect("non-empty").ratio()
    };
    let mut tb = Table::new(&["stream", "before (MB)", "after (MB)", "value-volume red."]);
    tb.row(vec![
        "weights exponents".into(),
        format!("{w_exp_mb:.0}"),
        format!("{:.0}", w_exp_mb / cr_w),
        format!("{:.2}x", 16.0 / (8.0 + 8.0 / cr_w)),
    ]);
    tb.row(vec![
        "act+cache exponents".into(),
        format!("{a_exp_mb:.0}"),
        format!("{:.0}", a_exp_mb / cr_a),
        format!("{:.2}x", 16.0 / (8.0 + 8.0 / cr_a)),
    ]);
    tb.print();
    println!("(paper: 422->151 MB weights, 360->155 MB act/caches; 1.47x / 1.39x)");

    // ---- (c) per-block communication reduction --------------------------
    println!("\nFig 1c — runtime comm reduction by block kind (jamba):");
    let crs = CrTable::measure(&cfg, 42);
    let by_block = traffic::volume_by_block_kind(&cfg, &transfers);
    let mut tc = Table::new(&["block kind", "uncompressed (MB)", "LEXI (MB)", "reduction"]);
    let mut rows: Vec<(&str, BlockKind)> = vec![
        ("Mamba", BlockKind::Mamba),
        ("Transformer", BlockKind::Attention),
        ("MoE", BlockKind::Moe),
        ("MLP", BlockKind::Mlp),
    ];
    rows.retain(|&(_, k)| by_block.contains_key(&k));
    for (name, kind) in rows {
        let unc = by_block[&kind];
        // Apply the measured per-kind wire ratios transfer-by-transfer.
        let lexi: u64 = transfers
            .iter()
            .filter(|t| {
                cfg.blocks[t.layer] == kind
                    && t.phase != lexi::models::traffic::Phase::WeightLoad
            })
            .map(|t| crs.wire_bytes(t.bytes, t.kind, CompressionMode::Lexi))
            .sum();
        tc.row(vec![
            name.into(),
            format!("{:.1}", unc as f64 / 1e6),
            format!("{:.1}", lexi as f64 / 1e6),
            format!("{:.1}%", (1.0 - lexi as f64 / unc as f64) * 100.0),
        ]);
    }
    tc.print();
    println!("(paper: 40% Mamba, 39% Transformer, 36% MoE)");
}
