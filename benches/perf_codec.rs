//! §Perf — software codec hot-path throughput.
//!
//! Targets (DESIGN.md §Perf): ≥100 M exponents/s single-core encode on the
//! table-driven path; decode within 2× of encode. Used for the
//! before/after iteration log in EXPERIMENTS.md §Perf.

use lexi::models::activations;
use lexi::models::traffic::TransferKind;
use lexi::models::{ModelConfig, ModelScale};
use lexi_bench::{bench, Table};
use lexi_core::bf16::FieldStreams;
use lexi_core::bitstream::{BitReader, BitWriter};
use lexi_core::flit::{self, FlitFormat};
use lexi_core::huffman::{self, CodeBook};
use lexi_core::stats::Histogram;
use lexi_core::Bf16;

const N: usize = 1_000_000;

fn main() {
    let cfg = ModelConfig::jamba(ModelScale::Paper);
    let exps = activations::sample_exponents(&cfg, 0, TransferKind::Activation, 42, N);
    let hist = Histogram::from_bytes(&exps);
    let book = CodeBook::lexi_default(&hist).expect("non-empty");

    let mut t = Table::new(&["path", "median", "throughput"]);

    // Histogram construction.
    let h = bench("histogram", 1, 7, || Histogram::from_bytes(&exps));
    t.row(vec![
        "histogram (1M exps)".into(),
        format!("{:?}", h.median()),
        format!("{:.0} M/s", h.throughput(N as u64) / 1e6),
    ]);

    // Codebook build.
    let cb = bench("codebook", 1, 7, || CodeBook::lexi_default(&hist).unwrap());
    t.row(vec![
        "codebook build".into(),
        format!("{:?}", cb.median()),
        format!("{:.0} books/s", cb.throughput(1)),
    ]);

    // Encode.
    let enc = bench("encode", 1, 7, || {
        let mut w = BitWriter::new();
        for &e in &exps {
            book.encode_symbol(e, &mut w);
        }
        w
    });
    t.row(vec![
        "encode (1M exps)".into(),
        format!("{:?}", enc.median()),
        format!("{:.0} M exps/s", enc.throughput(N as u64) / 1e6),
    ]);

    // Decode.
    let mut w = BitWriter::new();
    for &e in &exps {
        book.encode_symbol(e, &mut w);
    }
    let bits = w.len_bits();
    let bytes = w.into_bytes();
    let dec_book = book.clone();
    let dec = bench("decode", 1, 7, || {
        let d = dec_book.decoder();
        let mut r = BitReader::with_len(&bytes, bits);
        let mut out = Vec::with_capacity(N);
        for _ in 0..N {
            out.push(d.decode(&mut r).unwrap());
        }
        out
    });
    t.row(vec![
        "decode (1M exps)".into(),
        format!("{:?}", dec.median()),
        format!("{:.0} M exps/s", dec.throughput(N as u64) / 1e6),
    ]);

    // End-to-end block compress (hist + book + encode).
    let blk = bench("compress_exponents", 1, 5, || {
        huffman::compress_exponents(&exps).unwrap()
    });
    t.row(vec![
        "compress_exponents".into(),
        format!("{:?}", blk.median()),
        format!("{:.0} M exps/s", blk.throughput(N as u64) / 1e6),
    ]);

    // Flit pack (values, not just exponents).
    let mut rng = lexi_core::prng::Rng::new(3);
    let values: Vec<Bf16> = exps
        .iter()
        .map(|&e| {
            Bf16::from_fields(
                (rng.next_u32() & 1) as u8,
                e,
                (rng.next_u32() & 0x7f) as u8,
            )
        })
        .collect();
    let streams = FieldStreams::split(&values);
    let format = FlitFormat::new(128).expect("valid");
    let pk = bench("flit pack", 1, 5, || {
        flit::pack(&streams, &book, format).unwrap()
    });
    t.row(vec![
        "flit pack (1M values)".into(),
        format!("{:?}", pk.median()),
        format!("{:.0} M vals/s", pk.throughput(N as u64) / 1e6),
    ]);

    t.print();

    let enc_rate = enc.throughput(N as u64) / 1e6;
    println!(
        "\nencode throughput {enc_rate:.0} M exps/s (target ≥100 M/s) — {}",
        if enc_rate >= 100.0 { "PASS" } else { "BELOW TARGET" }
    );
}
