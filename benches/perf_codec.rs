//! §Perf — software codec hot-path throughput.
//!
//! Targets (DESIGN.md §Perf): ≥100 M exponents/s single-core encode on the
//! batch path (≥3× the scalar path); batch decode within 2× of encode and
//! ≥2× the scalar decode; multi-symbol LUT decode (ISSUE 4) ≥2× batch
//! decode and ≥1.5× the scalar lockstep at 8 lanes (`decode lut`,
//! `decode lockstep lut=8` rows; `lut build` keeps the table-fill cost
//! visible); SWAR grouped lockstep (ISSUE 8, `decode swar=8`) ≥1.3× the
//! per-lane LUT loop and the sharded parallel rows (`decode par={1,2,8}`,
//! `encode par=8`, `compress_exponents par=8`) ≥4× single-thread at 8
//! threads — both report-only, with GB/s alongside M/s (1-byte
//! exponents). Scalar rows are kept as the before/after baseline. Emits
//! `BENCH_perf_codec.json` (path → median ns, M/s, GB/s) so the bench
//! trajectory accumulates across PRs.
//!
//! `LEXI_BENCH_N` overrides the stream length (ci.sh smoke-runs this file
//! as an example with debug assertions on and a small N).

use lexi::models::activations;
use lexi::models::traffic::TransferKind;
use lexi::models::{ModelConfig, ModelScale};
use lexi_bench::{bench, Table, Timing};
use lexi_core::batch::{BatchEncoder, LaneCodec, LaneDecoders};
use lexi_core::bf16::FieldStreams;
use lexi_core::bitstream::{BitReader, BitWriter};
use lexi_core::flit::{self, FlitFormat};
use lexi_core::huffman::{self, CodeBook};
use lexi_core::lut::MultiDecodeTable;
use lexi_core::stats::Histogram;
use lexi_core::Bf16;

struct Row {
    name: String,
    median_ns: f64,
    m_per_s: f64,
    gb_per_s: f64,
}

fn record(t: &mut Table, rows: &mut Vec<Row>, timing: &Timing, name: &str, items: u64, unit: &str) -> f64 {
    let m_per_s = timing.throughput(items) / 1e6;
    // Exponents (and BF16 value streams' exponent planes) are one byte
    // per item, so GB/s = M items/s / 1000 — the memory-bandwidth-facing
    // number ISSUE 8's SWAR/parallel rows are judged in.
    let gb_per_s = m_per_s / 1000.0;
    t.row(vec![
        name.into(),
        format!("{:?}", timing.median()),
        format!("{m_per_s:.0} M {unit}/s"),
        format!("{gb_per_s:.2} GB/s"),
    ]);
    rows.push(Row {
        name: name.into(),
        median_ns: timing.median().as_nanos() as f64,
        m_per_s,
        gb_per_s,
    });
    m_per_s
}

fn main() {
    let n: usize = std::env::var("LEXI_BENCH_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1_000_000)
        .max(1024);
    let cfg = ModelConfig::jamba(ModelScale::Paper);
    let exps = activations::sample_exponents(&cfg, 0, TransferKind::Activation, 42, n);
    let hist = Histogram::from_bytes(&exps);
    let book = CodeBook::lexi_default(&hist).expect("non-empty");
    let payload_bits = book.payload_bits(&hist);

    let mut t = Table::new(&["path", "median", "throughput", "bandwidth"]);
    let mut rows: Vec<Row> = Vec::new();

    // Histogram construction.
    let h = bench("histogram", 1, 7, || Histogram::from_bytes(&exps));
    record(&mut t, &mut rows, &h, "histogram", n as u64, "exps");

    // Codebook build.
    let cb = bench("codebook", 1, 7, || CodeBook::lexi_default(&hist).unwrap());
    t.row(vec![
        "codebook build".into(),
        format!("{:?}", cb.median()),
        format!("{:.0} books/s", cb.throughput(1)),
        "-".into(),
    ]);
    rows.push(Row {
        name: "codebook build".into(),
        median_ns: cb.median().as_nanos() as f64,
        m_per_s: cb.throughput(1) / 1e6,
        gb_per_s: 0.0,
    });

    // --- encode: scalar baseline vs batch vs lanes ----------------------
    let enc_scalar = bench("encode scalar", 1, 7, || {
        let mut w = BitWriter::new();
        for &e in &exps {
            book.encode_symbol(e, &mut w);
        }
        w
    });
    let enc_scalar_mps = record(&mut t, &mut rows, &enc_scalar, "encode scalar", n as u64, "exps");

    let batch_enc = BatchEncoder::new(&book);
    let enc_batch = bench("encode batch", 1, 7, || {
        let mut w = BitWriter::new();
        w.reserve_bits(payload_bits);
        batch_enc.encode_block(&exps, &mut w);
        w
    });
    let enc_batch_mps = record(&mut t, &mut rows, &enc_batch, "encode batch", n as u64, "exps");

    let lane4 = LaneCodec::new(4).expect("valid");
    let enc_lanes = bench("encode lanes=4", 1, 7, || lane4.encode(&exps, &book));
    record(&mut t, &mut rows, &enc_lanes, "encode lanes=4", n as u64, "exps");

    // --- decode: scalar baseline vs batch vs lanes ----------------------
    let mut w = BitWriter::new();
    batch_enc.encode_block(&exps, &mut w);
    let bits = w.len_bits();
    let bytes = w.into_bytes();

    let dec_scalar = bench("decode scalar", 1, 7, || {
        let d = book.decoder();
        let mut r = BitReader::with_len(&bytes, bits);
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(d.decode(&mut r).unwrap());
        }
        out
    });
    let dec_scalar_mps = record(&mut t, &mut rows, &dec_scalar, "decode scalar", n as u64, "exps");

    let dec_batch = bench("decode batch", 1, 7, || {
        let d = book.decoder();
        let mut r = BitReader::with_len(&bytes, bits);
        let mut out = vec![0u8; n];
        d.decode_block_into(&mut r, &mut out).unwrap();
        out
    });
    let dec_batch_mps = record(&mut t, &mut rows, &dec_batch, "decode batch", n as u64, "exps");

    // --- multi-symbol LUT decode (ISSUE 4 tentpole) ---------------------
    // Table construction has its own row (like `codebook build`) so the
    // fill cost stays visible; the decode row then amortizes it the way
    // real transfers do (one table, millions of symbols).
    let lb = bench("lut build", 1, 7, || MultiDecodeTable::new(&book));
    t.row(vec![
        "lut build".into(),
        format!("{:?}", lb.median()),
        format!("{:.0} tables/s", lb.throughput(1)),
        "-".into(),
    ]);
    rows.push(Row {
        name: "lut build".into(),
        median_ns: lb.median().as_nanos() as f64,
        m_per_s: lb.throughput(1) / 1e6,
        gb_per_s: 0.0,
    });

    let lut_dec = book.lut_decoder();
    let dec_lut = bench("decode lut", 1, 7, || {
        let mut r = BitReader::with_len(&bytes, bits);
        let mut out = vec![0u8; n];
        lut_dec.decode_block_into(&mut r, &mut out).unwrap();
        out
    });
    let dec_lut_mps = record(&mut t, &mut rows, &dec_lut, "decode lut", n as u64, "exps");

    let lane_stream = lane4.encode(&exps, &book);
    let dec_lanes = bench("decode lanes=4", 1, 7, || {
        LaneCodec::decode(&lane_stream, &book).unwrap()
    });
    record(&mut t, &mut rows, &dec_lanes, "decode lanes=4", n as u64, "exps");

    // --- lockstep vs lane-at-a-time (ISSUE 2 tentpole) ------------------
    let lane8 = LaneCodec::new(8).expect("valid");
    let lane_stream8 = lane8.encode(&exps, &book);
    let dec_lanes8 = bench("decode lanes=8", 1, 7, || {
        LaneCodec::decode(&lane_stream8, &book).unwrap()
    });
    let dec_lanes8_mps =
        record(&mut t, &mut rows, &dec_lanes8, "decode lanes=8", n as u64, "exps");

    // The `decode lockstep={4,8}` rows keep measuring the ISSUE 2 scalar
    // kernel (one symbol per lane visit) — the baseline the multi-LUT
    // lockstep row below is judged against.
    let dec_lock4 = bench("decode lockstep=4", 1, 7, || {
        LaneCodec::decode_lockstep_scalar(&lane_stream, &book).unwrap()
    });
    record(&mut t, &mut rows, &dec_lock4, "decode lockstep=4", n as u64, "exps");

    let dec_lock8 = bench("decode lockstep=8", 1, 7, || {
        LaneCodec::decode_lockstep_scalar(&lane_stream8, &book).unwrap()
    });
    let dec_lock8_mps =
        record(&mut t, &mut rows, &dec_lock8, "decode lockstep=8", n as u64, "exps");

    // Production lockstep path (ISSUE 4): each lane visit drains up to
    // LUT_MAX_SYMS symbols per multi-LUT probe. Forced via explicit LUT
    // decoders so a small LEXI_BENCH_N can't silently drop the row back
    // to the scalar kernel through decode_lockstep's size threshold.
    let lut_decs8 = LaneDecoders::for_stream_lut(&lane_stream8, &book);
    let dec_lock_lut8 = bench("decode lockstep lut=8", 1, 7, || {
        LaneCodec::decode_lockstep_with(&lane_stream8, &lut_decs8).unwrap()
    });
    let dec_lock_lut8_mps = record(
        &mut t,
        &mut rows,
        &dec_lock_lut8,
        "decode lockstep lut=8",
        n as u64,
        "exps",
    );

    // --- SWAR grouped lockstep + sharded parallel codec (ISSUE 8) ------
    // `decode swar=8` is the production lockstep dispatch target: grouped
    // SWAR refill gating + gather-style LUT probes over 8 lanes. Judged
    // against `decode lockstep lut=8` (the per-lane visit loop it
    // replaces). Report-only target: ≥1.3×.
    let dec_swar8 = bench("decode swar=8", 1, 7, || {
        LaneCodec::decode_lockstep_swar(&lane_stream8, &lut_decs8).unwrap()
    });
    let dec_swar8_mps =
        record(&mut t, &mut rows, &dec_swar8, "decode swar=8", n as u64, "exps");

    // Sharded lane-parallel decode (`lexi-core::pool`): par=1 runs the
    // shard kernel inline (the single-thread baseline for the speedup),
    // par=T spawns T scoped threads. Outputs are thread-count invariant;
    // these rows measure wall-clock only and are NEVER fed back into the
    // hw cycle model's calibration (see `CrTable::measure`).
    let dec_par1 = bench("decode par=1", 1, 7, || {
        LaneCodec::decode_par(&lane_stream8, &book, 1).unwrap()
    });
    let dec_par1_mps = record(&mut t, &mut rows, &dec_par1, "decode par=1", n as u64, "exps");

    let dec_par2 = bench("decode par=2", 1, 7, || {
        LaneCodec::decode_par(&lane_stream8, &book, 2).unwrap()
    });
    record(&mut t, &mut rows, &dec_par2, "decode par=2", n as u64, "exps");

    let dec_par8 = bench("decode par=8", 1, 7, || {
        LaneCodec::decode_par(&lane_stream8, &book, 8).unwrap()
    });
    let dec_par8_mps = record(&mut t, &mut rows, &dec_par8, "decode par=8", n as u64, "exps");

    let enc_lanes8 = bench("encode lanes=8", 1, 7, || lane8.encode(&exps, &book));
    let enc_lanes8_mps =
        record(&mut t, &mut rows, &enc_lanes8, "encode lanes=8", n as u64, "exps");

    let enc_par8 = bench("encode par=8", 1, 7, || {
        lane8.encode_par(&exps, &book, 8)
    });
    let enc_par8_mps = record(&mut t, &mut rows, &enc_par8, "encode par=8", n as u64, "exps");

    // Block-granular parallel one-shot compress (PAR_BLOCK_SYMBOLS
    // shards; thread-count invariant bytes).
    let blk_par = bench("compress_exponents par=8", 1, 5, || {
        huffman::compress_exponents_par(&exps, 8).unwrap()
    });
    record(
        &mut t,
        &mut rows,
        &blk_par,
        "compress_exponents par=8",
        n as u64,
        "exps",
    );

    // Cross-path equivalence sanity (cheap; the test suites pin this
    // property-style).
    {
        assert_eq!(
            LaneCodec::decode_lockstep_swar(&lane_stream8, &lut_decs8).unwrap(),
            exps,
            "SWAR lockstep decode must be bit-exact"
        );
        assert_eq!(
            LaneCodec::decode_par(&lane_stream8, &book, 8).unwrap(),
            exps,
            "parallel lane decode must be bit-exact"
        );
        assert_eq!(
            lane8.encode_par(&exps, &book, 8).bytes,
            lane_stream8.bytes,
            "parallel encode must be byte-identical to sequential"
        );
        let d = book.decoder();
        let mut r = BitReader::with_len(&bytes, bits);
        let mut out = vec![0u8; n];
        d.decode_block_into(&mut r, &mut out).unwrap();
        assert_eq!(out, exps, "batch decode must be bit-exact");
        assert_eq!(
            LaneCodec::decode(&lane_stream, &book).unwrap(),
            exps,
            "lane decode must be bit-exact"
        );
        assert_eq!(
            LaneCodec::decode_lockstep_scalar(&lane_stream8, &book).unwrap(),
            exps,
            "lockstep decode must be bit-exact"
        );
        let mut r = BitReader::with_len(&bytes, bits);
        let mut out = vec![0u8; n];
        lut_dec.decode_block_into(&mut r, &mut out).unwrap();
        assert_eq!(out, exps, "multi-LUT decode must be bit-exact");
        assert_eq!(
            LaneCodec::decode_lockstep_with(&lane_stream8, &lut_decs8).unwrap(),
            exps,
            "multi-LUT lockstep decode must be bit-exact"
        );
    }

    // --- BDI baseline (ISSUE 3: the codec is now swappable; track the
    // alternative backend's throughput alongside the Huffman engine) ----
    let bdi_enc = bench("bdi encode", 1, 7, || lexi_core::bdi::compress(&exps));
    record(&mut t, &mut rows, &bdi_enc, "bdi encode", n as u64, "exps");

    let bdi_block = lexi_core::bdi::compress(&exps);
    let bdi_dec = bench("bdi decode", 1, 7, || {
        lexi_core::bdi::decompress(&bdi_block).unwrap()
    });
    record(&mut t, &mut rows, &bdi_dec, "bdi decode", n as u64, "exps");
    assert_eq!(
        lexi_core::bdi::decompress(&bdi_block).unwrap(),
        exps,
        "bdi decode must be lossless"
    );

    // End-to-end block compress (hist + book + batch encode).
    let blk = bench("compress_exponents", 1, 5, || {
        huffman::compress_exponents(&exps).unwrap()
    });
    record(&mut t, &mut rows, &blk, "compress_exponents", n as u64, "exps");

    // Flit pack (values, not just exponents).
    let mut rng = lexi_core::prng::Rng::new(3);
    let values: Vec<Bf16> = exps
        .iter()
        .map(|&e| {
            Bf16::from_fields(
                (rng.next_u32() & 1) as u8,
                e,
                (rng.next_u32() & 0x7f) as u8,
            )
        })
        .collect();
    let streams = FieldStreams::split(&values);
    let format = FlitFormat::new(128).expect("valid");
    let pk = bench("flit pack", 1, 5, || {
        flit::pack(&streams, &book, format).unwrap()
    });
    record(&mut t, &mut rows, &pk, "flit pack", n as u64, "vals");

    let transfer = flit::pack(&streams, &book, format).unwrap();
    let up = bench("flit unpack", 1, 5, || flit::unpack(&transfer).unwrap());
    record(&mut t, &mut rows, &up, "flit unpack", n as u64, "vals");

    t.print();

    let enc_speedup = enc_batch_mps / enc_scalar_mps;
    let dec_speedup = dec_batch_mps / dec_scalar_mps;
    let lockstep_speedup = dec_lock8_mps / dec_lanes8_mps.max(1e-9);
    let lut_speedup = dec_lut_mps / dec_batch_mps.max(1e-9);
    let lockstep_lut_speedup = dec_lock_lut8_mps / dec_lock8_mps.max(1e-9);
    // ISSUE 8 report-only targets (never gated — see tools/perf_gate.py):
    // SWAR grouped lockstep ≥1.3× the per-lane LUT loop; 8-thread
    // parallel ≥4× its own single-thread (par=1 / sequential) baseline.
    let swar_speedup = dec_swar8_mps / dec_lock_lut8_mps.max(1e-9);
    let dec_par_speedup = dec_par8_mps / dec_par1_mps.max(1e-9);
    let enc_par_speedup = enc_par8_mps / enc_lanes8_mps.max(1e-9);
    println!(
        "\nbatch encode {enc_batch_mps:.0} M exps/s (target ≥100 M/s, ≥3× scalar {enc_scalar_mps:.0}) — {}",
        if enc_batch_mps >= 100.0 && enc_speedup >= 3.0 { "PASS" } else { "BELOW TARGET" }
    );
    println!(
        "batch decode {dec_batch_mps:.0} M exps/s (target ≥2× scalar {dec_scalar_mps:.0}) — {}",
        if dec_speedup >= 2.0 { "PASS" } else { "BELOW TARGET" }
    );
    println!(
        "lockstep decode {dec_lock8_mps:.0} M exps/s at 8 lanes (target ≥1.5× lane-at-a-time {dec_lanes8_mps:.0}, measured {lockstep_speedup:.2}×) — {}",
        if lockstep_speedup >= 1.5 { "PASS" } else { "BELOW TARGET" }
    );
    println!(
        "multi-LUT decode {dec_lut_mps:.0} M exps/s (target ≥2× batch {dec_batch_mps:.0}, measured {lut_speedup:.2}×) — {}",
        if lut_speedup >= 2.0 { "PASS" } else { "BELOW TARGET" }
    );
    println!(
        "multi-LUT lockstep {dec_lock_lut8_mps:.0} M exps/s at 8 lanes (target ≥1.5× scalar lockstep {dec_lock8_mps:.0}, measured {lockstep_lut_speedup:.2}×) — {}",
        if lockstep_lut_speedup >= 1.5 { "PASS" } else { "BELOW TARGET" }
    );
    println!(
        "SWAR lockstep {dec_swar8_mps:.0} M exps/s at 8 lanes (target ≥1.3× lockstep-lut {dec_lock_lut8_mps:.0}, measured {swar_speedup:.2}×) — {}",
        if swar_speedup >= 1.3 { "PASS" } else { "BELOW TARGET" }
    );
    println!(
        "parallel decode {dec_par8_mps:.0} M exps/s at 8 threads (target ≥4× single-thread {dec_par1_mps:.0}, measured {dec_par_speedup:.2}×) — {}",
        if dec_par_speedup >= 4.0 { "PASS" } else { "BELOW TARGET" }
    );
    println!(
        "parallel encode {enc_par8_mps:.0} M exps/s at 8 threads (target ≥4× single-thread {enc_lanes8_mps:.0}, measured {enc_par_speedup:.2}×) — {}",
        if enc_par_speedup >= 4.0 { "PASS" } else { "BELOW TARGET" }
    );
    println!(
        "decode/encode ratio {:.2} (informal goal: decode within 2× of encode)",
        enc_batch_mps / dec_batch_mps.max(1e-9)
    );

    // Machine-readable trajectory row (hand-rolled JSON: no serde offline).
    let mut json = String::from("{\n");
    json.push_str(&format!("  \"bench\": \"perf_codec\",\n  \"n\": {n},\n"));
    json.push_str(&format!(
        "  \"encode_batch_speedup\": {enc_speedup:.3},\n  \"decode_batch_speedup\": {dec_speedup:.3},\n"
    ));
    json.push_str(&format!(
        "  \"lockstep_speedup_8\": {lockstep_speedup:.3},\n"
    ));
    json.push_str(&format!(
        "  \"lut_speedup\": {lut_speedup:.3},\n  \"lockstep_lut_speedup_8\": {lockstep_lut_speedup:.3},\n"
    ));
    json.push_str(&format!(
        "  \"swar_speedup_8\": {swar_speedup:.3},\n  \"decode_par_speedup_8\": {dec_par_speedup:.3},\n  \"encode_par_speedup_8\": {enc_par_speedup:.3},\n"
    ));
    json.push_str("  \"rows\": {\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    \"{}\": {{\"median_ns\": {:.0}, \"m_per_s\": {:.3}, \"gb_per_s\": {:.4}}}{}\n",
            r.name,
            r.median_ns,
            r.m_per_s,
            r.gb_per_s,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  }\n}\n");
    let out_path = "BENCH_perf_codec.json";
    match std::fs::write(out_path, &json) {
        Ok(()) => println!("\nwrote {out_path}"),
        Err(e) => eprintln!("\ncould not write {out_path}: {e}"),
    }
}
