//! Extensions beyond the paper's evaluation:
//!
//! 1. **Interconnect energy** — the paper reports codec area/power
//!    overhead (0.09%); this bench closes the loop: per-hop link energy
//!    saved vs codec energy burned, per model × mode.
//! 2. **Serving throughput** — multi-request decode sharing the NoI:
//!    LEXI raises the link-saturation ceiling by ~the wire ratio, the
//!    claim that matters for batched serving.
//! 3. **Load–latency curve** (ISSUE 9) — the open-loop trace-driven
//!    serving simulator swept across offered load: tail latency
//!    (p50/p99/p999) and goodput with and without LEXI, under
//!    deadline-aware admission. The wire-ratio win shows up as the
//!    knee of the curve moving right.

use lexi::models::corpus::Corpus;
use lexi::models::ModelConfig;
use lexi::sim::compression::{CompressionMode, CrTable};
use lexi::sim::energy::EnergyModel;
use lexi::sim::engine::Engine;
use lexi::sim::serving::{ServingConfig, ServingSim};
use lexi_bench::{fmt_ns, Table};

fn main() {
    let engine = Engine::paper_default();
    let corpus = Corpus::wikitext2();
    let models = ModelConfig::paper_models();

    // ---- 1. energy --------------------------------------------------------
    println!("Extension 1 — interconnect energy per inference (wikitext-2):");
    let mut te = Table::new(&["model", "mode", "link (mJ)", "codec (mJ)", "total (mJ)", "saved"]);
    let em = EnergyModel::default();
    for cfg in &models {
        let crs = CrTable::measure(cfg, 42);
        let unc = em.run(
            &engine.system,
            cfg,
            &corpus,
            CompressionMode::Uncompressed,
            &crs,
        );
        for mode in CompressionMode::ALL {
            let r = em.run(&engine.system, cfg, &corpus, mode, &crs);
            te.row(vec![
                cfg.name.into(),
                format!("{mode:?}"),
                format!("{:.2}", r.link_uj / 1e3),
                format!("{:.3}", r.codec_uj / 1e3),
                format!("{:.2}", r.total_uj() / 1e3),
                format!("{:.1}%", (1.0 - r.total_uj() / unc.total_uj()) * 100.0),
            ]);
        }
    }
    te.print();

    // ---- 2. serving throughput ---------------------------------------------
    println!("\nExtension 2 — concurrent decode throughput (qwen, tokens/s):");
    let cfg = &models[2];
    let crs = CrTable::measure(cfg, 42);
    let mut ts = Table::new(&["requests", "uncompressed", "LEXI", "gain"]);
    for n in [1usize, 2, 4, 8, 16, 32, 64] {
        let unc = engine.run_concurrent(cfg, &corpus, CompressionMode::Uncompressed, &crs, n);
        let lexi = engine.run_concurrent(cfg, &corpus, CompressionMode::Lexi, &crs, n);
        ts.row(vec![
            n.to_string(),
            format!("{:.0}", unc.tokens_per_s),
            format!("{:.0}", lexi.tokens_per_s),
            format!("{:.2}x", lexi.tokens_per_s / unc.tokens_per_s),
        ]);
    }
    ts.print();
    println!("(at saturation the gain approaches the measured wire ratio)");

    // ---- 3. load-latency curve (ISSUE 9) -----------------------------------
    println!("\nExtension 3 — serving load-latency curve (Poisson trace, mixed fleet):");
    let mut tl = Table::new(&[
        "load",
        "mode",
        "delivered",
        "shed",
        "p50",
        "p99",
        "p999",
        "goodput/s",
    ]);
    for load in [0.3, 0.5, 0.7, 0.9, 1.1] {
        for mode in [CompressionMode::Uncompressed, CompressionMode::Lexi] {
            let mut sc = ServingConfig::paper_default();
            sc.requests = 3000;
            sc.load = load;
            sc.mode = mode;
            let s = ServingSim::new(sc).run();
            tl.row(vec![
                format!("{load:.1}"),
                format!("{mode:?}"),
                s.delivered.to_string(),
                s.shed.to_string(),
                fmt_ns(s.p50_ns as f64),
                fmt_ns(s.p99_ns as f64),
                fmt_ns(s.p999_ns as f64),
                format!("{:.0}", s.goodput_rps),
            ]);
        }
    }
    tl.print();
    println!("(goodput = on-time deliveries/s; sheds are typed admission refusals)");
}
