//! Table 2 regenerator — exponent compression ratio of RLE / BDI / LEXI on
//! the three models' weights.
//!
//! Paper reference: LEXI 3.07–3.14×, BDI 2.36–2.43×, RLE 0.62–0.65×
//! (expansion). Our synthetic Gaussian weights land LEXI ≈ 3.1× and RLE
//! ≈ 0.63×; BDI reads ~2.1× (midrange-base variant) — same ordering,
//! same conclusion: frequency redundancy, not run or delta locality, is
//! the exploitable structure.
//!
//! Huffman and BDI both dispatch through the `ExpCodec` registry
//! (ISSUE 3) — the same trait path `CrTable`, `flit`, and the engine
//! use — so this table pins the trait route, not a parallel direct one.
//! RLE is a Table 2-only baseline and stays a direct call.

use lexi::models::weights::WeightStream;
use lexi::models::ModelConfig;
use lexi_bench::{fmt_ratio, Table};
use lexi_core::codec::CodecKind;
use lexi_core::rle;

fn main() {
    println!("Table 2 — exponent CR by method (weights):");
    let mut t = Table::new(&["model", "Base", "RLE", "BDI", "LEXI"]);
    for cfg in ModelConfig::paper_models() {
        let layers = [0usize, cfg.blocks.len() / 2, cfg.blocks.len() - 1];
        let (mut l, mut r, mut b) = (0.0, 0.0, 0.0);
        for &layer in &layers {
            let exps = WeightStream::sample_exponents(&cfg, layer, 42, 300_000);
            l += CodecKind::Huffman
                .codec()
                .encode(&exps)
                .expect("non-empty")
                .ratio();
            r += rle::coding_ratio(&exps);
            b += CodecKind::Bdi.codec().coding_ratio(&exps);
        }
        let n = layers.len() as f64;
        let (l, r, b) = (l / n, r / n, b / n);
        assert!(l > b && b > 1.0 && r < 1.0, "method ordering must hold");
        assert!((2.5..3.8).contains(&l), "LEXI CR {l}");
        assert_eq!(
            CodecKind::Raw.codec().coding_ratio(&[1, 2, 3]),
            1.0,
            "Base column is the Raw codec by definition"
        );
        t.row(vec![
            cfg.name.into(),
            "1.00×".into(),
            fmt_ratio(r),
            fmt_ratio(b),
            fmt_ratio(l),
        ]);
    }
    t.print();
    println!("(paper: RLE 0.62-0.65x, BDI 2.36-2.43x, LEXI 3.07-3.14x)");
}
