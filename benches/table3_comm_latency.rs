//! Table 3 regenerator — communication latency (ms) for uncompressed /
//! compressed-weights / LEXI, per model × dataset.
//!
//! Paper reference (WikiText-2): Jamba 86.70 → 80.62 → 47.35 ms (-45.4%);
//! Zamba -33.5%; Qwen -38.3%. C4: -42.0 / -34.0 / -39.2%. Absolute values
//! depend on the authors' testbed calibration; the reproduction targets
//! the *reductions* and the weights-only-barely-helps effect.

use lexi::models::corpus::Corpus;
use lexi::models::ModelConfig;
use lexi::sim::compression::{CompressionMode, CrTable};
use lexi::sim::engine::Engine;
use lexi_bench::Table;

fn main() {
    let engine = Engine::paper_default();
    println!("Table 3 — communication latency (ms):");
    let mut t = Table::new(&["dataset", "method", "jamba", "zamba", "qwen"]);
    let models = ModelConfig::paper_models();
    let tables: Vec<CrTable> = models.iter().map(|m| CrTable::measure(m, 42)).collect();

    for corpus in Corpus::all() {
        for mode in CompressionMode::ALL {
            let mut row = vec![corpus.name.to_string(), format!("{mode:?}")];
            for (cfg, crs) in models.iter().zip(&tables) {
                let r = engine.run(cfg, &corpus, mode, crs);
                row.push(format!("{:.2}", r.comm_ms()));
            }
            t.row(row);
        }
    }
    t.print();

    println!("\nreductions vs uncompressed:");
    let mut tr = Table::new(&["dataset", "method", "jamba", "zamba", "qwen"]);
    for corpus in Corpus::all() {
        for mode in [CompressionMode::WeightsOnly, CompressionMode::Lexi] {
            let mut row = vec![corpus.name.to_string(), format!("{mode:?}")];
            for (cfg, crs) in models.iter().zip(&tables) {
                let unc = engine.run(cfg, &corpus, CompressionMode::Uncompressed, crs);
                let m = engine.run(cfg, &corpus, mode, crs);
                let red = (1.0 - m.comm_ns / unc.comm_ns) * 100.0;
                if mode == CompressionMode::Lexi {
                    assert!(
                        (25.0..50.0).contains(&red),
                        "{} {}: LEXI reduction {red:.1}% out of band",
                        cfg.name,
                        corpus.name
                    );
                } else {
                    assert!(red < 10.0, "weights-only should barely help ({red:.1}%)");
                }
                row.push(format!("{red:.1}%"));
            }
            tr.row(row);
        }
    }
    tr.print();
    println!("(paper LEXI reductions: wt2 45.4/33.5/38.3%, c4 42.0/34.0/39.2%)");
}
