//! Fig 7 regenerator — normalized end-to-end latency per model × dataset.
//!
//! Paper reference: LEXI lowers end-to-end latency by 31/32/30% (wt2) and
//! 35/32/31% (c4) for Jamba/Zamba/Qwen; communication is 68–95% of the
//! uncompressed end-to-end time.

use lexi::models::corpus::Corpus;
use lexi::models::ModelConfig;
use lexi::sim::compression::{CompressionMode, CrTable};
use lexi::sim::engine::Engine;
use lexi_bench::Table;

fn main() {
    let engine = Engine::paper_default();
    let models = ModelConfig::paper_models();
    let tables: Vec<CrTable> = models.iter().map(|m| CrTable::measure(m, 42)).collect();

    println!("Fig 7 — normalized end-to-end latency (uncompressed = 1.00):");
    let mut t = Table::new(&[
        "dataset",
        "model",
        "uncomp (ms)",
        "comm share",
        "weights-only",
        "LEXI",
        "e2e red.",
    ]);
    for corpus in Corpus::all() {
        for (cfg, crs) in models.iter().zip(&tables) {
            let unc = engine.run(cfg, &corpus, CompressionMode::Uncompressed, crs);
            let wo = engine.run(cfg, &corpus, CompressionMode::WeightsOnly, crs);
            let lexi = engine.run(cfg, &corpus, CompressionMode::Lexi, crs);
            let red = (1.0 - lexi.e2e_ns() / unc.e2e_ns()) * 100.0;
            assert!(
                (20.0..45.0).contains(&red),
                "{} {}: e2e reduction {red:.1}% out of band",
                cfg.name,
                corpus.name
            );
            assert!(
                unc.comm_fraction() > 0.55,
                "comm must dominate ({:.2})",
                unc.comm_fraction()
            );
            t.row(vec![
                corpus.name.into(),
                cfg.name.into(),
                format!("{:.1}", unc.e2e_ms()),
                format!("{:.0}%", unc.comm_fraction() * 100.0),
                format!("{:.3}", wo.e2e_ns() / unc.e2e_ns()),
                format!("{:.3}", lexi.e2e_ns() / unc.e2e_ns()),
                format!("{red:.1}%"),
            ]);
        }
    }
    t.print();
    println!("(paper: 30-35% e2e reduction; comm 68-95% of uncompressed e2e)");
}
