//! Fig 4 regenerator — lane-cache hit rate vs cache depth, per model, on
//! WikiText-2-shaped streams.
//!
//! Paper reference: 8-entry caches exceed 90% average hit rate on all
//! three models, with diminishing returns beyond.

use lexi::hw::lane_cache::LaneCache;
use lexi::models::activations;
use lexi::models::traffic::TransferKind;
use lexi::models::ModelConfig;
use lexi_bench::Table;

fn main() {
    println!("Fig 4 — local-cache hit rate vs depth (activation streams, wikitext-2):");
    let models = ModelConfig::paper_models();
    let mut t = Table::new(&["depth", "jamba", "zamba", "qwen"]);
    let mut depth8 = Vec::new();
    for depth in [1usize, 2, 4, 6, 8, 12, 16, 24, 32] {
        let mut row = vec![depth.to_string()];
        for cfg in &models {
            // Average across layers, mixing activation + cache streams the
            // way the egress codec sees them.
            let mut hits = 0u64;
            let mut total = 0u64;
            for layer in [0, cfg.blocks.len() / 2, cfg.blocks.len() - 1] {
                for kind in [TransferKind::Activation, TransferKind::KvCache] {
                    let exps = activations::sample_exponents(cfg, layer, kind, 42, 100_000);
                    let mut cache = LaneCache::new(depth);
                    for &e in &exps {
                        cache.access(e);
                    }
                    hits += cache.hits;
                    total += cache.hits + cache.misses;
                }
            }
            let rate = hits as f64 / total as f64;
            if depth == 8 {
                depth8.push(rate);
            }
            row.push(format!("{:.1}%", rate * 100.0));
        }
        t.row(row);
    }
    t.print();
    println!(
        "\ndepth-8 rates: {} (paper: >90% for all models)",
        depth8
            .iter()
            .map(|r| format!("{:.1}%", r * 100.0))
            .collect::<Vec<_>>()
            .join(" / ")
    );
    assert!(depth8.iter().all(|&r| r > 0.88), "depth-8 hit-rate claim");
}
