//! Fig 5 regenerator — codebook-generation latency vs total cache size
//! (lanes × depth sweep, 512-activation window).
//!
//! Paper reference points: 1 lane × depth 4 ≈ 788 ns; 10 lanes × depth 8
//! ≈ 55 ns at 0.625 KiB (chosen); 32 lanes × depth 16 ≈ 17 ns at 4 KiB.
//! Our arbiter model charges the full 3-cycle exclusive grant per
//! mid-stream eviction, so absolute numbers sit slightly above the
//! paper's — the curve shape and the chosen-point ordering match.

use lexi::hw::histogram_unit::{HistConfig, HistogramUnit};
use lexi::hw::tree_builder;
use lexi::models::activations;
use lexi::models::traffic::TransferKind;
use lexi::models::{ModelConfig, ModelScale};
use lexi_bench::Table;

fn main() {
    let cfg = ModelConfig::jamba(ModelScale::Paper);
    let window = activations::sample_exponents(&cfg, 0, TransferKind::Activation, 42, 512);

    println!("Fig 5 — codebook generation latency vs cache size (512 activations):");
    let mut t = Table::new(&[
        "lanes",
        "depth",
        "cache KiB",
        "hist ns",
        "tree ns",
        "total ns",
    ]);
    let sweep: &[(usize, usize)] = &[
        (1, 4),
        (1, 8),
        (1, 16),
        (2, 8),
        (4, 4),
        (4, 8),
        (8, 8),
        (10, 8),
        (16, 8),
        (16, 16),
        (32, 8),
        (32, 16),
    ];
    let mut chosen_total = 0u64;
    let mut extremes = (0u64, 0u64);
    for &(lanes, depth) in sweep {
        let hc = HistConfig { lanes, depth };
        let r = HistogramUnit::new(hc).run(&window);
        let tree = tree_builder::build_codebook(&r.histogram, 32).expect("codebook");
        let total = r.cycles + tree.total_cycles();
        if (lanes, depth) == (10, 8) {
            chosen_total = total;
        }
        if (lanes, depth) == (1, 4) {
            extremes.0 = total;
        }
        if (lanes, depth) == (32, 16) {
            extremes.1 = total;
        }
        let mark = if (lanes, depth) == (10, 8) { " <- chosen" } else { "" };
        t.row(vec![
            format!("{lanes}{mark}"),
            depth.to_string(),
            format!("{:.3}", hc.cache_bytes() as f64 / 1024.0),
            r.cycles.to_string(),
            tree.total_cycles().to_string(),
            total.to_string(),
        ]);
    }
    t.print();
    println!(
        "\nchosen point (10x8): {chosen_total} ns total (paper ~55 ns histogram-phase; \
         extremes 1x4={} vs 32x16={} — paper 788 vs 17 ns)",
        extremes.0, extremes.1
    );
    assert!(extremes.0 > 5 * extremes.1, "sweep must span ~an order of magnitude");
    assert!(chosen_total < extremes.0 / 3, "chosen point is near the knee");
}
