//! §Perf — NoC simulator throughput and analytic-model validation.
//!
//! Targets (DESIGN.md §Perf): ≥10 M flit-hops/s on the per-cycle router
//! loop; codec-tagged stepping through the egress decoder ports within
//! 1.3× of codec-blind stepping (cycles/s); analytic engine within 15%
//! of the cycle simulator on uncongested transfers (the `sim::xval`
//! band); an attached-but-inert fault model (ISSUE 6) within 1.05× of
//! the plain egress row (the zero-BER hot path pays one branch per
//! step, nothing per flit).
//!
//! Emits `BENCH_perf_noc.json` (row → median ns, M cycles/s) so
//! `tools/perf_gate.py` can diff runs against the committed baseline,
//! exactly like `BENCH_perf_codec.json` (ISSUE 5 satellite).

use lexi::models::corpus::Corpus;
use lexi::models::{ModelConfig, ModelScale};
use lexi::noc::traffic::{self, MAX_PACKET_BITS};
use lexi::noc::{
    EgressCodecConfig, FaultModel, IngressCodecConfig, Mesh, MultiPackage, Network, NetworkConfig,
    PacketSpec, Topo,
};
use lexi::sim::compression::{CompressionMode, CrTable};
use lexi::sim::engine::Engine;
use lexi::sim::serving::{ServingConfig, ServingSim};
use lexi::sim::xval;
use lexi_bench::{bench, Table};
use lexi_core::codec::CodecKind;

struct Row {
    name: &'static str,
    median_ns: f64,
    m_per_s: f64,
}

/// Time one traffic pattern; returns (M cycles/s, M flit-hops/s).
#[allow(clippy::too_many_arguments)]
fn run_pattern(
    name: &'static str,
    cfg: NetworkConfig,
    specs: &[PacketSpec],
    egress: Option<EgressCodecConfig>,
    ingress: Option<IngressCodecConfig>,
    watchdog: Option<u64>,
    fault: Option<FaultModel>,
    t: &mut Table,
    rows: &mut Vec<Row>,
) -> (f64, f64) {
    let mut cycles = 0u64;
    let mut hops = 0u64;
    let run = bench(name, 1, 5, || {
        let mut net = match egress {
            Some(e) => Network::with_egress(cfg, e),
            None => Network::new(cfg),
        };
        if let Some(i) = ingress {
            net.set_ingress_config(i);
        }
        if let Some(w) = watchdog {
            net.set_watchdog(w);
        }
        if let Some(f) = &fault {
            net.set_fault_model(f.clone());
        }
        net.schedule_packets(specs);
        let stats = net.run_to_completion(10_000_000);
        cycles = stats.cycles;
        hops = stats.flit_hops;
        stats.cycles
    });
    let secs = run.median().as_secs_f64();
    let mcycles = cycles as f64 / secs / 1e6;
    t.row(vec![
        format!("{name} ({hops} flit-hops, {cycles} cycles)"),
        format!("{:?}", run.median()),
        format!(
            "{mcycles:.2} M cycles/s, {:.1} M flit-hops/s",
            hops as f64 / secs / 1e6
        ),
    ]);
    rows.push(Row {
        name,
        median_ns: run.median().as_nanos() as f64,
        m_per_s: mcycles,
    });
    (mcycles, hops as f64 / secs / 1e6)
}

fn main() {
    let cfg = NetworkConfig {
        topo: Topo::Mesh(Mesh::new(6, 6)),
        vcs: 1,
        flit_bits: 128,
        link_gbps: 100.0,
        buf_depth: 4,
    };
    let mut t = Table::new(&["case", "median", "rate"]);
    let mut rows: Vec<Row> = Vec::new();

    // Saturated uniform-random load: measures the router loop; the
    // egress variant tags every packet (~10 wire bits per exponent
    // symbol at the paper wire ratio) and drains through the codec
    // ports.
    let mut rng = lexi_core::prng::Rng::new(1);
    let uniform = traffic::uniform_random(cfg.topo, 2000, 128 * 32, 2.0, &mut rng);
    let mut uniform_tagged = uniform.clone();
    traffic::tag_packets(&mut uniform_tagged, CodecKind::Huffman, 10.0, true);
    let ecfg = EgressCodecConfig::paper_default();

    let (blind_u, hops_rate) = run_pattern(
        "noc uniform", cfg, &uniform, None, None, None, None, &mut t, &mut rows,
    );
    let (egress_u, _) = run_pattern(
        "noc uniform egress",
        cfg,
        &uniform_tagged,
        Some(ecfg),
        None,
        None,
        None,
        &mut t,
        &mut rows,
    );
    // ISSUE 6: an attached-but-inert fault model (all rates zero) must
    // keep the per-step overhead at one branch — pinned ≤1.05× the
    // egress row below. Baseline-less new row: the gate only arms it
    // once this JSON is committed.
    let (fault_off_u, _) = run_pattern(
        "noc uniform fault-off",
        cfg,
        &uniform_tagged,
        Some(ecfg),
        None,
        None,
        Some(FaultModel::new(0xFA17)),
        &mut t,
        &mut rows,
    );
    // ISSUE 7: full duplex codec ports — injection paced by the ingress
    // encoder on top of the egress decoder drain.
    let (ingress_u, _) = run_pattern(
        "noc uniform ingress",
        cfg,
        &uniform_tagged,
        Some(ecfg),
        Some(IngressCodecConfig::paper_default()),
        None,
        None,
        &mut t,
        &mut rows,
    );
    // ISSUE 7: an aggressive watchdog window must not slow stepping —
    // the per-cycle progress check is O(1) counters; the heavy credit
    // audit runs only on fire. Pinned ≤1.05× the egress row below.
    let (watchdog_u, _) = run_pattern(
        "noc uniform watchdog-on",
        cfg,
        &uniform_tagged,
        Some(ecfg),
        None,
        Some(1_000),
        None,
        &mut t,
        &mut rows,
    );

    // ISSUE 10: the VC router on the same uniform load. vcs=1 is the
    // pinned stat-identical operating point (its rate is the honest
    // baseline for the VC-overhead scalar); vcs=2/4 pay the per-lane
    // request cache + flat round-robin arbitration, bounded by the
    // vcs2_overhead gate below. Buffer depth scales with the lane count
    // so every VC keeps ≥ 2 credits (line rate needs one in flight plus
    // one returning).
    let mut vc_rates = Vec::new();
    for vcs in [1u8, 2, 4] {
        let vcfg = NetworkConfig {
            vcs,
            buf_depth: cfg.buf_depth.max(2 * vcs as u32),
            ..cfg
        };
        let name: &'static str = match vcs {
            1 => "noc uniform vcs=1",
            2 => "noc uniform vcs=2",
            _ => "noc uniform vcs=4",
        };
        let (rate, _) = run_pattern(
            name, vcfg, &uniform, None, None, None, None, &mut t, &mut rows,
        );
        vc_rates.push(rate);
    }

    // ISSUE 10: 2 stitched 6x6 packages, 2 VCs — uniform load over all
    // 72 endpoints, so ~half the packets cross the gateway stitches and
    // the escape fallback path stays hot. Report-only row.
    let mp_topo = Topo::MultiPackage(MultiPackage::new(2, 6, 6));
    let mp_cfg = NetworkConfig {
        topo: mp_topo,
        vcs: 2,
        ..cfg
    };
    let mut mp_rng = lexi_core::prng::Rng::new(2);
    let mp_uniform = traffic::uniform_random(mp_topo, 2000, 128 * 32, 2.0, &mut mp_rng);
    run_pattern(
        "noc multipackage uniform",
        mp_cfg,
        &mp_uniform,
        None,
        None,
        None,
        None,
        &mut t,
        &mut rows,
    );

    // Hotspot (worst-case arbitration pressure + one shared egress port).
    let hot = traffic::hotspot(cfg.topo, lexi::noc::NodeId(14), 128 * 64);
    let mut hot_tagged = hot.clone();
    traffic::tag_packets(&mut hot_tagged, CodecKind::Huffman, 10.0, true);
    let (blind_h, _) = run_pattern(
        "noc hotspot", cfg, &hot, None, None, None, None, &mut t, &mut rows,
    );
    let (egress_h, _) = run_pattern(
        "noc hotspot egress",
        cfg,
        &hot_tagged,
        Some(ecfg),
        None,
        None,
        None,
        &mut t,
        &mut rows,
    );

    // Analytic engine speed at paper scale (full Table 3 cell).
    let model = ModelConfig::qwen(ModelScale::Paper);
    let corpus = Corpus::wikitext2();
    let crs = CrTable::measure(&model, 42);
    let engine = Engine::paper_default();
    let an = bench("analytic e2e", 1, 5, || {
        engine.run(&model, &corpus, CompressionMode::Lexi, &crs)
    });
    t.row(vec![
        "analytic e2e (qwen, wt2)".into(),
        format!("{:?}", an.median()),
        format!("{:.1} runs/s", an.throughput(1)),
    ]);
    rows.push(Row {
        name: "analytic e2e",
        median_ns: an.median().as_nanos() as f64,
        // Unscaled runs/s: dividing by 1e6 would round to 0.000 in the
        // {:.3} JSON serialization and perf_gate.py would silently drop
        // the row (it only gates rows with m_per_s > 0). The gate
        // compares ratios, so the unit just has to be consistent.
        m_per_s: an.throughput(1),
    });

    // ISSUE 9: trace-driven serving throughput. The admission layer
    // (deadline prediction, typed sheds, capped-backoff retries) must
    // cost ≤1.05× the shed-off baseline at moderate load — the run is
    // the same arrival trace either way, so the delta isolates the
    // bookkeeping. `run()` resets all state, so one sim per row is
    // benched repeatedly.
    let serving_cfg = |load: f64, admission: bool| {
        let mut c = ServingConfig::paper_default();
        c.requests = 2000;
        c.load = load;
        c.admission = admission;
        c
    };
    let mut serving_rows = Vec::new();
    for (name, load, admission) in [
        ("serving load=0.5", 0.5, true),
        ("serving load=0.9", 0.9, true),
        ("serving shed-off", 0.5, false),
    ] {
        let mut sim = ServingSim::new(serving_cfg(load, admission));
        let mut delivered = 0u64;
        let run = bench(name, 1, 5, || {
            let s = sim.run();
            delivered = s.delivered;
            s.offered
        });
        t.row(vec![
            format!("{name} ({delivered} delivered)"),
            format!("{:?}", run.median()),
            format!("{:.1} runs/s", run.throughput(1)),
        ]);
        serving_rows.push(run.median().as_nanos() as f64);
        rows.push(Row {
            name,
            median_ns: run.median().as_nanos() as f64,
            // runs/s, unscaled — same convention as "analytic e2e".
            m_per_s: run.throughput(1),
        });
    }
    t.print();

    // Codec-tagged stepping target: ≤1.3× slowdown vs codec-blind.
    let slow_u = blind_u / egress_u;
    let slow_h = blind_h / egress_h;
    println!(
        "\negress stepping slowdown: uniform {slow_u:.2}x, hotspot {slow_h:.2}x \
         (target <=1.30x) — {}",
        if slow_u <= 1.3 && slow_h <= 1.3 {
            "PASS"
        } else {
            "BELOW TARGET"
        }
    );

    // Fault-model-off overhead target (ISSUE 6): the inert model's
    // per-step branch must keep stepping within 1.05× of the plain
    // egress row.
    let slow_f = egress_u / fault_off_u;
    println!(
        "fault-model-off stepping overhead: {slow_f:.3}x vs egress (target <=1.05x) — {}",
        if slow_f <= 1.05 { "PASS" } else { "BELOW TARGET" }
    );

    // Ingress codec ports (ISSUE 7): duplex stepping stays near the
    // egress-only rate — the encoder check is one branch plus a f64
    // compare per injected flit. Reported; the gate bounds drift via
    // the committed baseline row.
    let slow_i = egress_u / ingress_u;
    println!(
        "ingress (duplex) stepping slowdown: {slow_i:.3}x vs egress (target <=1.30x) — {}",
        if slow_i <= 1.3 { "PASS" } else { "BELOW TARGET" }
    );

    // Watchdog overhead target (ISSUE 7): progress tracking is O(1)
    // per step, so an armed tight window must be free.
    let slow_w = egress_u / watchdog_u;
    println!(
        "watchdog-on stepping overhead: {slow_w:.3}x vs egress (target <=1.05x) — {}",
        if slow_w <= 1.05 { "PASS" } else { "BELOW TARGET" }
    );

    // VC router overhead (ISSUE 10): the 2-VC request cache + flat
    // round-robin arbitration must stay within 1.10× of the vcs=1 rate
    // on the same load (gated via vcs2_overhead); vcs=4 is report-only.
    let slow_v2 = vc_rates[0] / vc_rates[1];
    let slow_v4 = vc_rates[0] / vc_rates[2];
    println!(
        "vcs=2 stepping overhead: {slow_v2:.3}x vs vcs=1 (target <=1.10x) — {}; \
         vcs=4: {slow_v4:.3}x (report-only)",
        if slow_v2 <= 1.10 { "PASS" } else { "BELOW TARGET" }
    );

    // Serving admission overhead (ISSUE 9): load-0.5 with admission on
    // vs the shed-off baseline on the identical arrival trace.
    let slow_s = serving_rows[0] / serving_rows[2];
    println!(
        "serving admission overhead: {slow_s:.3}x vs shed-off (target <=1.05x) — {}",
        if slow_s <= 1.05 { "PASS" } else { "BELOW TARGET" }
    );

    // Serving goodput gain (ISSUE 9, report-only): on-time deliveries
    // per second at load 0.9, LEXI wire format vs uncompressed — the
    // serving-level restatement of the paper's latency win.
    let goodput_at = |mode: CompressionMode| {
        let mut c = serving_cfg(0.9, true);
        c.mode = mode;
        ServingSim::new(c).run().goodput_rps
    };
    let gain = goodput_at(CompressionMode::Lexi) / goodput_at(CompressionMode::Uncompressed);
    println!("serving goodput gain at load 0.9 (LEXI vs uncompressed): {gain:.2}x (report-only)");

    // Cross-validation (sim::xval): analytic vs tagged cycle sim on
    // uncongested sizable transfers, every mode (target <15%).
    let tiny = ModelConfig::jamba(ModelScale::Tiny);
    let tiny_crs = CrTable::measure(&tiny, 42);
    let transfers = lexi::models::traffic::decode_step(&tiny, &corpus, 0);
    let window: Vec<_> = transfers
        .iter()
        .filter(|t| t.bytes > 4096)
        .take(3)
        .copied()
        .collect();
    println!("\nanalytic vs cycle-accurate (sim::xval, target <15% uncongested):");
    let mut worst: f64 = 0.0;
    for mode in CompressionMode::ALL {
        for r in xval::cross_validate(&engine, &tiny_crs, &window, mode) {
            worst = worst.max(r.rel_err());
            println!("  {}", r.row());
        }
    }
    println!(
        "worst uncongested error {:.1}% — {}",
        worst * 100.0,
        if worst < 0.15 { "PASS" } else { "BELOW TARGET" }
    );
    println!(
        "router-loop rate {hops_rate:.1} M flit-hops/s (target >=10 M/s) — {}",
        if hops_rate >= 10.0 { "PASS" } else { "BELOW TARGET" }
    );

    // Machine-readable dump for tools/perf_gate.py (same shape as
    // BENCH_perf_codec.json; rows present in only one file never fail
    // the gate, so this lands against older baselines cleanly).
    let mut json = String::from("{\n  \"bench\": \"perf_noc\",\n");
    json.push_str(&format!(
        "  \"egress_slowdown_uniform\": {slow_u:.3},\n  \"egress_slowdown_hotspot\": {slow_h:.3},\n"
    ));
    json.push_str(&format!("  \"fault_off_overhead\": {slow_f:.3},\n"));
    json.push_str(&format!("  \"ingress_slowdown_uniform\": {slow_i:.3},\n"));
    json.push_str(&format!("  \"watchdog_overhead\": {slow_w:.3},\n"));
    json.push_str(&format!("  \"vcs2_overhead\": {slow_v2:.3},\n"));
    json.push_str(&format!("  \"vcs4_overhead\": {slow_v4:.3},\n"));
    json.push_str(&format!("  \"serving_shed_off_overhead\": {slow_s:.3},\n"));
    json.push_str(&format!("  \"serving_goodput_gain\": {gain:.3},\n"));
    json.push_str(&format!("  \"xval_worst_err\": {worst:.4},\n"));
    json.push_str("  \"rows\": {\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    \"{}\": {{\"median_ns\": {:.0}, \"m_per_s\": {:.3}}}{}\n",
            r.name,
            r.median_ns,
            r.m_per_s,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  }\n}\n");
    let out_path = "BENCH_perf_noc.json";
    match std::fs::write(out_path, &json) {
        Ok(()) => println!("\nwrote {out_path}"),
        Err(e) => println!("\nWARNING: could not write {out_path}: {e}"),
    }
    // Sanity: the segmentation helpers the engine's concurrent pricing
    // shares with this simulator stay in sync (cheap, every run).
    assert_eq!(
        traffic::transfer_flits(MAX_PACKET_BITS + 1, cfg.flit_bits, MAX_PACKET_BITS),
        MAX_PACKET_BITS / cfg.flit_bits as u64 + 1
    );
}
