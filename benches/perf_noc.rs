//! §Perf — NoC simulator throughput and analytic-model validation.
//!
//! Targets (DESIGN.md §Perf): ≥10 M flit-hops/s on the per-cycle router
//! loop; analytic engine within 20% of the cycle simulator on uncongested
//! transfers.

use lexi::models::corpus::Corpus;
use lexi::models::{ModelConfig, ModelScale};
use lexi::noc::traffic::{self, MAX_PACKET_BITS};
use lexi::noc::{Mesh, Network, NetworkConfig};
use lexi::sim::compression::{CompressionMode, CrTable};
use lexi::sim::engine::Engine;
use lexi_bench::{bench, Table};

fn main() {
    let cfg = NetworkConfig {
        mesh: Mesh::new(6, 6),
        flit_bits: 128,
        link_gbps: 100.0,
        buf_depth: 4,
    };

    // Saturated uniform-random load: measures the router loop.
    let mut rng = lexi_core::prng::Rng::new(1);
    let specs = traffic::uniform_random(cfg.mesh, 2000, 128 * 32, 2.0, &mut rng);

    let mut t = Table::new(&["case", "median", "rate"]);
    let mut hops_done = 0u64;
    let run = bench("noc uniform", 1, 5, || {
        let mut net = Network::new(cfg);
        net.schedule_packets(&specs);
        let stats = net.run_to_completion(10_000_000);
        hops_done = stats.flit_hops;
        stats.cycles
    });
    let rate = hops_done as f64 / run.median().as_secs_f64() / 1e6;
    t.row(vec![
        format!("uniform 2000 pkts ({hops_done} flit-hops)"),
        format!("{:?}", run.median()),
        format!("{rate:.1} M flit-hops/s"),
    ]);

    // Hotspot (worst-case arbitration pressure).
    let hot = traffic::hotspot(cfg.mesh, lexi::noc::NodeId(14), 128 * 64);
    let mut hops2 = 0u64;
    let run2 = bench("noc hotspot", 1, 5, || {
        let mut net = Network::new(cfg);
        net.schedule_packets(&hot);
        let stats = net.run_to_completion(10_000_000);
        hops2 = stats.flit_hops;
        stats.cycles
    });
    t.row(vec![
        format!("hotspot ({hops2} flit-hops)"),
        format!("{:?}", run2.median()),
        format!(
            "{:.1} M flit-hops/s",
            hops2 as f64 / run2.median().as_secs_f64() / 1e6
        ),
    ]);

    // Analytic engine speed at paper scale (full Table 3 cell).
    let model = ModelConfig::qwen(ModelScale::Paper);
    let corpus = Corpus::wikitext2();
    let crs = CrTable::measure(&model, 42);
    let engine = Engine::paper_default();
    let an = bench("analytic e2e", 1, 5, || {
        engine.run(&model, &corpus, CompressionMode::Lexi, &crs)
    });
    t.row(vec![
        "analytic e2e (qwen, wt2)".into(),
        format!("{:?}", an.median()),
        format!("{:.1} runs/s", an.throughput(1)),
    ]);
    t.print();

    // Validation: analytic vs cycle on a single transfer.
    let tiny = ModelConfig::jamba(ModelScale::Tiny);
    let transfers = lexi::models::traffic::decode_step(&tiny, &corpus, 0);
    let tr = transfers.iter().find(|t| t.bytes > 4096).expect("sizable");
    let analytic = engine.transfer_ns(tr, CompressionMode::Uncompressed, &crs);
    let src = engine.system.resolve(tr.src, tr.layer);
    let dst = engine.system.resolve(tr.dst, tr.layer);
    let specs = traffic::segment_transfer(src, dst, tr.bytes * 8, 0, MAX_PACKET_BITS);
    let mut net = Network::new(cfg);
    net.schedule_packets(&specs);
    let stats = net.run_to_completion(10_000_000);
    let cycle = stats.cycles as f64 * cfg.cycle_ns();
    let err = (analytic - cycle).abs() / cycle * 100.0;
    println!(
        "\nanalytic {analytic:.0} ns vs cycle-accurate {cycle:.0} ns — {err:.1}% error \
         (target <20%)"
    );
    println!(
        "router-loop rate {rate:.1} M flit-hops/s (target ≥10 M/s) — {}",
        if rate >= 10.0 { "PASS" } else { "BELOW TARGET" }
    );
}
