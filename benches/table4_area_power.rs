//! Table 4 regenerator — area and power breakdown of the LEXI codec in
//! GF 22 nm, with Stillmaker–Baas scaling to the 16 nm Simba node.
//!
//! Paper reference: 14 995.2 µm² and 45.43 mW total; 5 452.8 µm² @16 nm =
//! 0.09% of a 6 mm² Simba chiplet.

use lexi::hw::area_power::{AreaPower, LexiHwConfig};
use lexi_bench::Table;

fn main() {
    let bp = AreaPower::of(&LexiHwConfig::paper_default());
    println!("Table 4 — area/power breakdown (GF 22 nm):");
    let mut t = Table::new(&[
        "component",
        "area µm²",
        "power mW",
        "count",
        "total µm²",
        "total mW",
    ]);
    for i in &bp.items {
        t.row(vec![
            i.name.into(),
            format!("{:.2}", i.unit_area_um2),
            format!("{:.2}", i.unit_power_mw),
            format!("×{}", i.count),
            format!("{:.1}", i.total_area_um2()),
            format!("{:.2}", i.total_power_mw()),
        ]);
    }
    t.print();

    let area = bp.total_area_um2();
    let power = bp.total_power_mw();
    let scaled = bp.total_area_16nm_um2();
    let pct = bp.chiplet_overhead_pct();
    println!(
        "\ntotal {area:.1} µm², {power:.2} mW; scaled to 16 nm {scaled:.1} µm²; \
         {pct:.3}% of a 6 mm² Simba chiplet"
    );
    println!("(paper: 14995.2 µm², 45.43 mW, 5452.8 µm², 0.09%)");
    assert!((area - 14995.2).abs() / 14995.2 < 0.01);
    assert!((power - 45.43).abs() / 45.43 < 0.02);
    assert!((pct - 0.0909).abs() < 0.005);

    // Sensitivity: how the overhead scales with the main knobs.
    println!("\nknob sensitivity (total area µm² @22nm):");
    let mut ts = Table::new(&["lanes", "depth", "area µm²", "chiplet %"]);
    for (lanes, depth) in [(4usize, 8usize), (10, 8), (10, 16), (20, 8), (32, 16)] {
        let mut cfg = LexiHwConfig::paper_default();
        cfg.lanes = lanes;
        cfg.cache_depth = depth;
        cfg.decode_lanes = lanes;
        let b = AreaPower::of(&cfg);
        ts.row(vec![
            lanes.to_string(),
            depth.to_string(),
            format!("{:.1}", b.total_area_um2()),
            format!("{:.3}%", b.chiplet_overhead_pct()),
        ]);
    }
    ts.print();
}
