//! Ablations for the design choices DESIGN.md calls out (paper §4.1/§4.2):
//!
//! 1. **Per-layer vs global codebooks** — the paper's locality argument
//!    for regenerating the Huffman tree atevery layer boundary.
//! 2. **Alphabet cap** (16 / 32 / 64 dedicated symbols) — why 32.
//! 3. **Sampling window** (128 / 512 / 2048 activations) — why 512.
//! 4. **Escape policy** — adaptive-weight ESC vs paper's rare-ESC
//!    assumption under distribution shift.

use lexi::models::activations;
use lexi::models::traffic::TransferKind;
use lexi::models::{ModelConfig, ModelScale};
use lexi_bench::{fmt_ratio, Table};
use lexi_core::huffman::{compress_with_book, CodeBook};
use lexi_core::stats::Histogram;

fn layer_streams(cfg: &ModelConfig, n_per_layer: usize) -> Vec<Vec<u8>> {
    (0..cfg.blocks.len())
        .map(|l| activations::sample_exponents(cfg, l, TransferKind::Activation, 42, n_per_layer))
        .collect()
}

fn main() {
    let cfg = ModelConfig::jamba(ModelScale::Paper);
    let streams = layer_streams(&cfg, 100_000);

    // ---- 1. per-layer vs global codebook --------------------------------
    println!("Ablation 1 — codebook granularity (jamba activations):");
    let per_layer_bits: u64 = streams
        .iter()
        .map(|s| {
            let hist = Histogram::from_bytes(s);
            let book = CodeBook::lexi_default(&hist).expect("non-empty");
            book.payload_bits(&hist) + book.header_bits()
        })
        .sum();
    let global_bits: u64 = {
        let mut hist = Histogram::default();
        for s in &streams {
            hist.merge(&Histogram::from_bytes(s));
        }
        let book = CodeBook::lexi_default(&hist).expect("non-empty");
        streams
            .iter()
            .map(|s| book.payload_bits(&Histogram::from_bytes(s)))
            .sum::<u64>()
            + book.header_bits()
    };
    let total_syms: u64 = streams.iter().map(|s| s.len() as u64).sum();
    let mut t1 = Table::new(&["codebook", "bits/exp", "CR"]);
    for (name, bits) in [("per-layer (LEXI)", per_layer_bits), ("single global", global_bits)] {
        t1.row(vec![
            name.into(),
            format!("{:.3}", bits as f64 / total_syms as f64),
            fmt_ratio(total_syms as f64 * 8.0 / bits as f64),
        ]);
    }
    t1.print();
    assert!(
        per_layer_bits < global_bits,
        "per-layer codebooks must win (paper §4.1)"
    );

    // ---- 2. alphabet cap --------------------------------------------------
    println!("\nAblation 2 — encode-LUT alphabet cap:");
    let mut t2 = Table::new(&["max symbols", "CR", "escape rate"]);
    let sample = &streams[0];
    for cap in [8usize, 16, 32, 64] {
        let hist = Histogram::from_bytes(sample);
        let book = CodeBook::from_histogram(&hist, cap, 24).expect("non-empty");
        let blk = compress_with_book(sample, &book).expect("encodes");
        let escapes = sample.iter().filter(|&&e| book.code(e).is_none()).count();
        t2.row(vec![
            cap.to_string(),
            fmt_ratio(blk.ratio()),
            format!("{:.3}%", escapes as f64 / sample.len() as f64 * 100.0),
        ]);
    }
    t2.print();

    // ---- 3. sampling window -----------------------------------------------
    println!("\nAblation 3 — codebook sampling window (codebook from first W, applied to 100k):");
    let mut t3 = Table::new(&["window", "CR vs oracle", "startup cycles"]);
    let oracle = {
        let hist = Histogram::from_bytes(sample);
        let book = CodeBook::lexi_default(&hist).expect("non-empty");
        compress_with_book(sample, &book).expect("encodes").ratio()
    };
    for window in [64usize, 128, 256, 512, 1024, 2048] {
        let hist = Histogram::from_bytes(&sample[..window]);
        let book = CodeBook::lexi_default(&hist).expect("non-empty");
        let blk = compress_with_book(sample, &book).expect("encodes");
        // Startup = window ingestion at 10 lanes + tree pipeline.
        let startup = (window as u64).div_ceil(10) + 81;
        t3.row(vec![
            window.to_string(),
            format!("{:.1}% ({})", blk.ratio() / oracle * 100.0, fmt_ratio(blk.ratio())),
            startup.to_string(),
        ]);
    }
    t3.print();
    println!("(512 captures ≥99% of the oracle CR at ~130-cycle startup — the paper's pick)");

    // ---- 4. escape behaviour under distribution shift ----------------------
    println!("\nAblation 4 — distribution shift after the sampling window:");
    let mut shifted = sample[..512].to_vec();
    // Later activations drift to a disjoint exponent range.
    shifted.extend(
        activations::sample_exponents(&cfg, 0, TransferKind::SsmState, 99, 50_000)
            .iter()
            .map(|e| e.wrapping_add(40)),
    );
    let hist = Histogram::from_bytes(&shifted[..512]);
    let book = CodeBook::lexi_default(&hist).expect("non-empty");
    let blk = compress_with_book(&shifted, &book).expect("encodes");
    let escapes = shifted.iter().filter(|&&e| book.code(e).is_none()).count();
    let out = lexi_core::huffman::decompress_exponents(&blk).expect("lossless");
    assert_eq!(out, shifted, "escape fallback must stay lossless");
    println!(
        "stale codebook on shifted stream: CR {} with {:.1}% escapes — degraded but LOSSLESS \
         (the paper's guaranteed-correctness property)",
        fmt_ratio(blk.ratio()),
        escapes as f64 / shifted.len() as f64 * 100.0
    );
}
