//! Fig 6 regenerator — average latency to decode 10 exponents vs decoder
//! area, across multi-stage LUT configurations.
//!
//! Paper reference: the 4-stage 8/16/24/32 decoder reaches 11.6 ns / 10
//! exponents at 98.5 µm²; a monolithic 32-bit LUT is slightly faster
//! (10 ns) but 157.6 µm². Ten decode lanes saturate the 100 Gbps link.

use lexi::hw::area_power::decoder_area_um2;
use lexi::hw::decoder::{parallel_makespan, DecoderConfig, DecoderUnit};
use lexi::models::activations;
use lexi::models::traffic::TransferKind;
use lexi::models::{ModelConfig, ModelScale};
use lexi_bench::Table;
use lexi_core::batch::LaneCodec;
use lexi_core::bitstream::{BitReader, BitWriter};
use lexi_core::huffman::CodeBook;
use lexi_core::stats::Histogram;

fn main() {
    let cfg = ModelConfig::jamba(ModelScale::Paper);
    // Mix several layers so deeper length classes actually occur.
    let mut exps = Vec::new();
    for layer in 0..cfg.blocks.len() {
        exps.extend(activations::sample_exponents(
            &cfg,
            layer,
            TransferKind::Activation,
            42,
            40_000,
        ));
    }
    let hist = Histogram::from_bytes(&exps);
    let book = CodeBook::lexi_default(&hist).expect("non-empty");
    let mut w = BitWriter::new();
    for &e in &exps {
        book.encode_symbol(e, &mut w);
    }
    let bits = w.len_bits();
    let bytes = w.into_bytes();

    println!("Fig 6 — decode latency vs area (codebook with {} symbols):", book.num_symbols());
    let mut t = Table::new(&["decoder", "area µm²", "ns / 10 exps", "stage-1 share"]);
    let configs: Vec<(&str, DecoderConfig)> = vec![
        ("1-stage 32b", DecoderConfig::monolithic()),
        (
            "2-stage 16/32",
            DecoderConfig {
                stage_bits: vec![16, 32],
                entries_per_stage: 16,
            },
        ),
        (
            "3-stage 11/22/32",
            DecoderConfig {
                stage_bits: vec![11, 22, 32],
                entries_per_stage: 11,
            },
        ),
        ("4-stage 8/16/24/32 <- chosen", DecoderConfig::paper_default()),
        (
            "5-stage 7/14/21/28/32",
            DecoderConfig {
                stage_bits: vec![7, 14, 21, 28, 32],
                entries_per_stage: 7,
            },
        ),
        (
            "6-stage 6/12/18/24/30/32",
            DecoderConfig {
                stage_bits: vec![6, 12, 18, 24, 30, 32],
                entries_per_stage: 6,
            },
        ),
    ];
    let mut chosen = (0.0f64, 0.0f64);
    let mut mono = (0.0f64, 0.0f64);
    for (name, dc) in &configs {
        let unit = DecoderUnit::new(dc.clone()).expect("valid config");
        let mut r = BitReader::with_len(&bytes, bits);
        let (out, rep) = unit.decode(&mut r, &book, exps.len()).expect("decodes");
        assert_eq!(out, exps, "decoder must be bit-exact");
        let ns10 = rep.avg_latency() * 10.0;
        let area = decoder_area_um2(dc);
        if name.contains("chosen") {
            chosen = (area, ns10);
        }
        if name.contains("1-stage") {
            mono = (area, ns10);
        }
        t.row(vec![
            name.to_string(),
            format!("{area:.1}"),
            format!("{ns10:.2}"),
            format!(
                "{:.1}%",
                rep.per_stage[0] as f64 / rep.symbols as f64 * 100.0
            ),
        ]);
    }
    t.print();
    println!(
        "\nchosen 4-stage: {:.1} µm² / {:.2} ns vs monolithic {:.1} µm² / {:.2} ns \
         (paper: 98.5/11.6 vs 157.6/10.0)",
        chosen.0, chosen.1, mono.0, mono.1
    );
    assert!(chosen.0 < mono.0, "staging must save area");
    assert!(chosen.1 >= mono.1, "monolithic is the latency floor");

    // Line-rate check: 10 flit-parallel lanes on 10-value flits.
    let per_flit: Vec<u64> = (0..1000u64).map(|_| 10).collect(); // ~1 cycle/val stage-1
    let makespan = parallel_makespan(&per_flit, 10);
    println!(
        "10 decode lanes, 1000 flits x 10 values: makespan {makespan} cycles \
         (line rate = 1000 flit-cycles)"
    );

    // Measured multi-lane makespan through the batch lane format (§4.4):
    // the same stream interleaved across N hardware lanes, decoded by the
    // chosen 4-stage unit per lane.
    let unit = DecoderUnit::new(DecoderConfig::paper_default()).expect("valid config");
    println!("\nmulti-lane decode of {} exponents (4-stage unit per lane):", exps.len());
    let mut lt = Table::new(&[
        "lanes",
        "makespan (cycles)",
        "lockstep (cycles)",
        "eff. cycles/exp",
        "lockstep cycles/exp",
        "lane speedup",
    ]);
    for lanes in [1usize, 2, 4, 8, 10] {
        let stream = LaneCodec::new(lanes).expect("lane count").encode(&exps, &book);
        let (out, rep) = unit.decode_lane_stream(&stream, &book).expect("decodes");
        assert_eq!(out, exps, "lane decode must be bit-exact");
        assert_eq!(
            LaneCodec::decode_lockstep(&stream, &book).expect("decodes"),
            exps,
            "software lockstep must agree with the hw model"
        );
        // Independent lanes finish first; a round-synchronized lockstep
        // scheduler pays for each round's slowest stage.
        assert!(rep.lockstep_cycles >= rep.makespan);
        lt.row(vec![
            lanes.to_string(),
            rep.makespan.to_string(),
            rep.lockstep_cycles.to_string(),
            format!("{:.3}", rep.effective_latency()),
            format!("{:.3}", rep.lockstep_latency()),
            format!("{:.2}x", rep.lane_speedup()),
        ]);
    }
    lt.print();
}
