//! Table 1 regenerator — methodology comparison against related work.
//!
//! The paper's Table 1 is qualitative (lossless? which data? HW/SW?); we
//! make it quantitative where the offline setting allows by *running*
//! the closest software analogue of each lossless scheme on the same
//! weight + activation streams:
//!
//! * **Huff-llm / DFloat11 analogue** — static global Huffman over weight
//!   exponents only (one codebook for the whole model, built offline;
//!   activations/caches shipped raw). SW, weights-only — exactly the gap
//!   LEXI's Table 1 row calls out.
//! * **ZipNN analogue** — byte-wise two-stream split (exponent stream
//!   entropy-coded, mantissa raw), whole-model granularity.
//! * **LEXI** — per-layer dynamic codebooks over weights *and* runtime
//!   streams, HW line-rate (cycle model).
//!
//! Lossy schemes (HACK, KVComp, Ecco) change the numerics and therefore
//! have no lossless-comparable CR; they appear only in the qualitative
//! rows.

use lexi::models::activations;
use lexi::models::traffic::TransferKind;
use lexi::models::weights::WeightStream;
use lexi::models::{ModelConfig, ModelScale};
use lexi_bench::{fmt_ratio, Table};
use lexi_core::huffman::{compress_with_book, CodeBook};
use lexi_core::stats::Histogram;

fn main() {
    let cfg = ModelConfig::jamba(ModelScale::Paper);

    // Streams: per-layer weights + runtime activations/caches.
    let weight_layers: Vec<Vec<u8>> = (0..cfg.blocks.len())
        .map(|l| WeightStream::sample_exponents(&cfg, l, 42, 60_000))
        .collect();
    let runtime_layers: Vec<Vec<u8>> = (0..cfg.blocks.len())
        .flat_map(|l| {
            [TransferKind::Activation, TransferKind::KvCache]
                .into_iter()
                .map(move |k| (l, k))
        })
        .map(|(l, k)| activations::sample_exponents(&cfg, l, k, 42, 60_000))
        .collect();
    let w_total: u64 = weight_layers.iter().map(|s| s.len() as u64 * 8).sum();
    let r_total: u64 = runtime_layers.iter().map(|s| s.len() as u64 * 8).sum();

    // Global static codebook (Huff-llm/DFloat11/ZipNN style): one histogram
    // over all weights, built offline.
    let global_book = {
        let mut h = Histogram::default();
        for s in &weight_layers {
            h.merge(&Histogram::from_bytes(s));
        }
        CodeBook::lexi_default(&h).expect("non-empty")
    };
    let bits_with = |book: &CodeBook, streams: &[Vec<u8>]| -> u64 {
        streams
            .iter()
            .map(|s| compress_with_book(s, book).expect("encodes").bits as u64)
            .sum()
    };
    // Weight-only static schemes: weights compressed, runtime raw.
    let huffllm_bits = bits_with(&global_book, &weight_layers) + r_total;
    // LEXI: per-layer dynamic codebooks on everything.
    let lexi_bits: u64 = weight_layers
        .iter()
        .chain(&runtime_layers)
        .map(|s| {
            let h = Histogram::from_bytes(s);
            let b = CodeBook::lexi_default(&h).expect("non-empty");
            compress_with_book(s, &b).expect("encodes").bits as u64
        })
        .sum();
    let total = w_total + r_total;

    println!("Table 1 — methodology comparison (exponent-stream CR measured where lossless):");
    let mut t = Table::new(&[
        "work",
        "lossless",
        "compressed data",
        "impl",
        "measured exp CR (W+A+C)",
    ]);
    t.row(vec![
        "HACK [45]".into(),
        "no".into(),
        "KV-cache".into(),
        "SW".into(),
        "— (lossy)".into(),
    ]);
    t.row(vec![
        "KVComp [19]".into(),
        "no".into(),
        "KV-cache".into(),
        "SW".into(),
        "— (lossy)".into(),
    ]);
    t.row(vec![
        "Ecco [7]".into(),
        "no".into(),
        "KV/Act/Weight".into(),
        "HW".into(),
        "— (lossy)".into(),
    ]);
    t.row(vec![
        "Huff-llm/DFloat11-style (static, weights-only)".into(),
        "yes".into(),
        "Weight".into(),
        "SW".into(),
        fmt_ratio(total as f64 / huffllm_bits as f64),
    ]);
    t.row(vec![
        "LEXI (per-layer dynamic, all streams)".into(),
        "yes".into(),
        "KV/Act/State/Weight".into(),
        "HW".into(),
        fmt_ratio(total as f64 / lexi_bits as f64),
    ]);
    t.print();

    let weights_only_cr = total as f64 / huffllm_bits as f64;
    let lexi_cr = total as f64 / lexi_bits as f64;
    assert!(
        lexi_cr > 1.8 * weights_only_cr,
        "covering runtime streams must dominate weight-only schemes \
         ({lexi_cr:.2} vs {weights_only_cr:.2})"
    );
    println!(
        "\nweight-only lossless schemes cap at {:.2}x on the whole traffic mix because \
         runtime streams dominate; LEXI reaches {:.2}x by covering them (the paper's \
         Table 1 differentiation, measured).",
        weights_only_cr, lexi_cr
    );
}
