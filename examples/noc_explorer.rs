//! NoC explorer: how LEXI's benefit scales with mesh size and link rate.
//!
//! ```bash
//! cargo run --release --example noc_explorer
//! ```
//!
//! Replays one decode step of the tiny Jamba model through the
//! cycle-accurate NoI under different array sizes and link bandwidths,
//! with and without LEXI — the slower the links and the bigger the mesh,
//! the more the compressed traffic matters.
//!
//! Since ISSUE 5 the replay is codec-aware end to end: wire sizes come
//! from the engine's [`CodecPolicy`] through the `ExpCodec` registry
//! (`wire_bytes_for`), packets carry codec tags, and ejection drains
//! through the egress decoder ports — so the numbers here are the same
//! wire bytes and decoder rates `lexi-sim`'s analytic engine charges.

use lexi::models::corpus::Corpus;
use lexi::models::{ModelConfig, ModelScale};
use lexi::noc::{Mesh, Network, NetworkConfig, PacketSpec};
use lexi::sim::compression::{CompressionMode, CrTable};
use lexi::sim::simba::SimbaSystem;
use lexi::sim::xval;
use lexi::sim::Engine;
use lexi_bench::Table;
use lexi_models::traffic::TransferKind;

fn run_once(
    system: &SimbaSystem,
    engine: &Engine,
    ncfg: NetworkConfig,
    crs: &CrTable,
    mode: CompressionMode,
) -> f64 {
    let cfg = ModelConfig::jamba(ModelScale::Tiny);
    let corpus = Corpus::wikitext2();
    let transfers = lexi::models::traffic::decode_step(&cfg, &corpus, 0);
    let mut specs: Vec<PacketSpec> = Vec::new();
    for tr in &transfers {
        // The explorer sweeps mesh sizes, so endpoints resolve through
        // the local system — everything else (wire bytes through the
        // ExpCodec registry, the tagging rule) is shared with the
        // engine via xval (regression: the legacy `wire_bytes` path
        // ignored the codec policy).
        let src = system.resolve(tr.src, tr.layer);
        let dst = system.resolve(tr.dst, tr.layer);
        specs.extend(xval::tagged_specs_between(engine, crs, tr, mode, src, dst, 0));
    }
    // Egress decoder ports at the engine's measured operating point
    // (per-kind rates differ little; Activation is representative).
    let ecfg = xval::egress_config_for(engine, crs, TransferKind::Activation);
    let mut net = Network::with_egress(ncfg, ecfg);
    net.schedule_packets(&specs);
    let stats = net.run_to_completion(1_000_000_000);
    stats.completion_cycle as f64 * ncfg.cycle_ns()
}

fn main() -> anyhow::Result<()> {
    let cfg = ModelConfig::jamba(ModelScale::Tiny);
    let crs = CrTable::measure(&cfg, 42);
    let engine = Engine::paper_default();

    println!("one decode step of jamba-tiny over the NoI (cycle-accurate):\n");
    let mut t = Table::new(&["mesh", "link Gbps", "uncompressed", "LEXI", "reduction"]);
    for (cols, rows, mem) in [
        (4u16, 4u16, vec![(0u16, 1u16), (3, 2)]),
        (6, 6, vec![(0, 2), (0, 3), (5, 2), (5, 3)]),
        (8, 8, vec![(0, 3), (0, 4), (7, 3), (7, 4)]),
    ] {
        for link_gbps in [50.0f64, 100.0, 200.0] {
            let mesh = Mesh::new(cols, rows);
            let system = SimbaSystem::new(mesh, &mem);
            let ncfg = NetworkConfig {
                topo: lexi::noc::Topo::Mesh(mesh),
                vcs: 1,
                flit_bits: 128,
                link_gbps,
                buf_depth: 4,
            };
            let unc = run_once(&system, &engine, ncfg, &crs, CompressionMode::Uncompressed);
            let lexi = run_once(&system, &engine, ncfg, &crs, CompressionMode::Lexi);
            t.row(vec![
                format!("{cols}x{rows}"),
                format!("{link_gbps:.0}"),
                format!("{:.1} ns", unc),
                format!("{:.1} ns", lexi),
                format!("{:.1}%", (1.0 - lexi / unc) * 100.0),
            ]);
        }
    }
    t.print();

    // Cross-validation corner (ISSUE 5): the same transfers through the
    // analytic engine vs the tagged cycle sim, uncongested.
    println!("\nanalytic vs cycle (uncongested sizable transfers, target <15%):");
    let transfers = lexi::models::traffic::decode_step(&cfg, &Corpus::wikitext2(), 0);
    for tr in transfers.iter().filter(|t| t.bytes > 4096).take(4) {
        let r = xval::replay_transfer(&engine, &crs, tr, CompressionMode::Lexi);
        println!("  {}", r.row());
    }
    Ok(())
}
