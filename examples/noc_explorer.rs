//! NoC explorer: how LEXI's benefit scales with mesh size and link rate.
//!
//! ```bash
//! cargo run --release --example noc_explorer
//! ```
//!
//! Replays one decode step of the tiny Jamba model through the
//! cycle-accurate NoI under different array sizes and link bandwidths,
//! with and without LEXI — the slower the links and the bigger the mesh,
//! the more the compressed traffic matters.

use lexi::models::corpus::Corpus;
use lexi::models::{ModelConfig, ModelScale};
use lexi::noc::traffic::{segment_transfer, MAX_PACKET_BITS};
use lexi::noc::{Mesh, Network, NetworkConfig, PacketSpec};
use lexi::sim::compression::{CompressionMode, CrTable};
use lexi::sim::simba::SimbaSystem;
use lexi_bench::Table;

fn run_once(
    system: &SimbaSystem,
    ncfg: NetworkConfig,
    crs: &CrTable,
    mode: CompressionMode,
) -> f64 {
    let cfg = ModelConfig::jamba(ModelScale::Tiny);
    let corpus = Corpus::wikitext2();
    let transfers = lexi::models::traffic::decode_step(&cfg, &corpus, 0);
    let mut specs: Vec<PacketSpec> = Vec::new();
    for tr in &transfers {
        let src = system.resolve(tr.src, tr.layer);
        let dst = system.resolve(tr.dst, tr.layer);
        let bytes = crs.wire_bytes(tr.bytes, tr.kind, mode);
        specs.extend(segment_transfer(src, dst, bytes * 8, 0, MAX_PACKET_BITS));
    }
    let mut net = Network::new(ncfg);
    net.schedule_packets(&specs);
    let stats = net.run_to_completion(1_000_000_000);
    stats.cycles as f64 * ncfg.cycle_ns()
}

fn main() -> anyhow::Result<()> {
    let cfg = ModelConfig::jamba(ModelScale::Tiny);
    let crs = CrTable::measure(&cfg, 42);

    println!("one decode step of jamba-tiny over the NoI (cycle-accurate):\n");
    let mut t = Table::new(&["mesh", "link Gbps", "uncompressed", "LEXI", "reduction"]);
    for (cols, rows, mem) in [
        (4u16, 4u16, vec![(0u16, 1u16), (3, 2)]),
        (6, 6, vec![(0, 2), (0, 3), (5, 2), (5, 3)]),
        (8, 8, vec![(0, 3), (0, 4), (7, 3), (7, 4)]),
    ] {
        for link_gbps in [50.0f64, 100.0, 200.0] {
            let mesh = Mesh::new(cols, rows);
            let system = SimbaSystem::new(mesh, &mem);
            let ncfg = NetworkConfig {
                mesh,
                flit_bits: 128,
                link_gbps,
                buf_depth: 4,
            };
            let unc = run_once(&system, ncfg, &crs, CompressionMode::Uncompressed);
            let lexi = run_once(&system, ncfg, &crs, CompressionMode::Lexi);
            t.row(vec![
                format!("{cols}x{rows}"),
                format!("{link_gbps:.0}"),
                format!("{:.1} ns", unc),
                format!("{:.1} ns", lexi),
                format!("{:.1}%", (1.0 - lexi / unc) * 100.0),
            ]);
        }
    }
    t.print();
    Ok(())
}
