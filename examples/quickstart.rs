//! Quickstart: compress a BF16 tensor's exponent stream with LEXI.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Shows the core API end to end: split BF16 into field streams, build a
//! per-layer codebook, compress/decompress losslessly, compare against the
//! RLE/BDI baselines, pack into link flits, and cross-check the software
//! codec against the cycle-accurate hardware model.

use lexi::core::bf16::FieldStreams;
use lexi::core::flit::{self, FlitFormat};
use lexi::core::huffman::{self, CodeBook};
use lexi::core::prng::Rng;
use lexi::core::stats::{FieldProfile, Histogram};
use lexi::core::{bdi, rle, Bf16};
use lexi::hw::compressor::{Compressor, CompressorConfig};

fn main() -> anyhow::Result<()> {
    // A synthetic "layer output": 64K well-scaled BF16 values.
    let mut rng = Rng::new(7);
    let values: Vec<Bf16> = (0..65_536)
        .map(|_| Bf16::from_f32(rng.normal_with(0.0, 0.8) as f32))
        .collect();

    // 1. Profile (paper Fig 1a): exponents are low-entropy, mantissas full.
    let profile = FieldProfile::of(&values);
    println!(
        "exponent entropy {:.2} bits over {} distinct values; mantissa {:.2} bits",
        profile.exp_entropy_bits, profile.exp_distinct, profile.mant_entropy_bits
    );

    // 2. Compress the exponent stream (paper Table 2).
    let streams = FieldStreams::split(&values);
    let block = huffman::compress_exponents(&streams.exponents)?;
    println!(
        "LEXI  exponent CR: {:.2}x  (RLE {:.2}x, BDI {:.2}x)",
        block.ratio(),
        rle::coding_ratio(&streams.exponents),
        bdi::coding_ratio(&streams.exponents),
    );

    // 3. Lossless round-trip.
    let back = huffman::decompress_exponents(&block)?;
    assert_eq!(back, streams.exponents);
    println!("round-trip: lossless OK");

    // 4. Flit packetization for a 100 Gbps / 128-bit NoI link (paper §4.3).
    let hist = Histogram::from_bytes(&streams.exponents);
    let book = CodeBook::lexi_default(&hist)?;
    let format = FlitFormat::new(128)?;
    let transfer = flit::pack(&streams, &book, format)?;
    println!(
        "wire: {} flits vs {} uncompressed ({:.2}x fewer)",
        transfer.flits.len(),
        flit::uncompressed_flits(format, values.len()),
        transfer.ratio_vs_uncompressed()
    );
    assert_eq!(flit::unpack(&transfer)?.join(), values);

    // 5. The cycle-accurate hardware pipeline agrees on cost and framing.
    let comp = Compressor::new(CompressorConfig::paper_default());
    let (hw_book, _payload, report) = comp.compress(&streams.exponents)?;
    println!(
        "hw codec: startup {} cycles, {:.1} exponents/cycle steady-state, CR {:.2}x, esc {} of {}",
        report.startup_cycles,
        report.throughput(),
        report.ratio(),
        report.escapes,
        report.count,
    );
    let esc = hw_book.escape();
    assert_eq!(esc.bits, (1 << esc.len) - 1, "escape is the all-ones code");
    Ok(())
}
