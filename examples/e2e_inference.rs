//! End-to-end driver: the full three-layer system on a real workload.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_inference [-- MODEL STEPS]
//! ```
//!
//! 1. **L2/L1 artifacts** — loads the AOT-compiled JAX hybrid model
//!    (Pallas attention + selective-scan kernels) via PJRT.
//! 2. **L3 coordinator** — runs prefill + greedy decode; every boundary
//!    tensor (activations, KV cache, SSM state) passes through Rust.
//! 3. **LEXI codecs** — profiles and compresses the *real* exponent
//!    streams, measuring per-kind compression and wire ratios.
//! 4. **Chiplet system** — feeds the measured ratios into the Simba 6×6
//!    engine for Table 3 / Fig 7-style latency numbers, and replays one
//!    decode step through the cycle-accurate NoI as a cross-check.
//!
//! The headline metric (paper: 33–45% comm, 30–35% e2e reduction) prints
//! at the end; EXPERIMENTS.md records a reference run.

use lexi::coordinator::Session;
use lexi::models::corpus::Corpus;
use lexi::models::{ModelConfig, ModelScale};
use lexi::noc::{Network, NetworkConfig, PacketSpec};
use lexi::runtime::{Manifest, Runtime};
use lexi::sim::compression::CompressionMode;
use lexi::sim::engine::Engine;
use lexi_bench::Table;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().map(String::as_str).unwrap_or("jamba").to_string();
    let steps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(16);

    // --- 1+2: run the real model through the coordinator -----------------
    let manifest = Manifest::load("artifacts")?;
    let rt = Runtime::cpu()?;
    let loaded = rt.load_model(&manifest, &model)?;
    let mm = loaded.manifest.clone();
    let corpus = Corpus::wikitext2();
    let tokens: Vec<i32> = corpus
        .tokens(mm.vocab, 7)
        .iter()
        .take(mm.seq_in)
        .map(|&t| t as i32)
        .collect();
    println!(
        "running {model}: prefill {} tokens + {steps} decode steps on PJRT ({})",
        mm.seq_in,
        rt.platform()
    );
    let session = Session::new(loaded);
    let report = session.run(&tokens, steps)?;
    println!(
        "generated {} tokens; {} tensor streams profiled; mean H(exp) {:.2} bits",
        report.generated.len(),
        report.profiles.len(),
        report.mean_exp_entropy()
    );

    // --- 3: measured ratios ------------------------------------------------
    let crs = report.measured_cr_table();
    let mut t = Table::new(&["codec", "kind", "exponent CR", "wire ratio"]);
    for ((codec, kind), r) in &crs.ratios {
        t.row(vec![
            codec.name().into(),
            format!("{kind:?}"),
            format!("{:.2}x", r.exponent_cr),
            format!("{:.2}x", r.wire_ratio),
        ]);
    }
    t.print();

    // --- 4a: system-level latency with measured ratios ---------------------
    let engine = Engine::paper_default();
    let paper_cfg = match model.as_str() {
        "jamba" => ModelConfig::jamba(ModelScale::Paper),
        "zamba" => ModelConfig::zamba(ModelScale::Paper),
        _ => ModelConfig::qwen(ModelScale::Paper),
    };
    println!("\nSimba 6x6 engine with ratios measured on real tensors:");
    let mut t3 = Table::new(&["method", "comm (ms)", "e2e (ms)"]);
    let mut results = Vec::new();
    for mode in CompressionMode::ALL {
        let r = engine.run(&paper_cfg, &corpus, mode, &crs);
        t3.row(vec![
            format!("{mode:?}"),
            format!("{:.2}", r.comm_ms()),
            format!("{:.2}", r.e2e_ms()),
        ]);
        results.push(r);
    }
    t3.print();
    let comm_red = 1.0 - results[2].comm_ns / results[0].comm_ns;
    let e2e_red = 1.0 - results[2].e2e_ns() / results[0].e2e_ns();

    // --- 4b: cycle-accurate NoI cross-check on one decode step -------------
    let tiny_cfg = match model.as_str() {
        "jamba" => ModelConfig::jamba(ModelScale::Tiny),
        "zamba" => ModelConfig::zamba(ModelScale::Tiny),
        _ => ModelConfig::qwen(ModelScale::Tiny),
    };
    let ncfg = NetworkConfig::paper_default();
    let mut cycle_ns = [0f64; 2];
    for (i, mode) in [CompressionMode::Uncompressed, CompressionMode::Lexi]
        .iter()
        .enumerate()
    {
        let transfers = lexi::models::traffic::decode_step(&tiny_cfg, &corpus, 0);
        // Codec-tagged specs through the ExpCodec registry (ISSUE 5):
        // the replay ships the same wire bytes the engine's policy
        // prices and drains through the egress decoder ports.
        let mut specs: Vec<PacketSpec> = Vec::new();
        for tr in &transfers {
            specs.extend(lexi::sim::xval::tagged_specs(&engine, &crs, tr, *mode, 0));
        }
        let ecfg = lexi::sim::xval::egress_config_for(
            &engine,
            &crs,
            lexi::models::traffic::TransferKind::Activation,
        );
        let mut net = Network::with_egress(ncfg, ecfg);
        net.schedule_packets(&specs);
        let stats = net.run_to_completion(100_000_000);
        cycle_ns[i] = stats.completion_cycle as f64 * ncfg.cycle_ns();
    }
    println!(
        "\ncycle-accurate NoI, one tiny decode step: {:.1} ns uncompressed -> {:.1} ns LEXI ({:.1}% faster)",
        cycle_ns[0],
        cycle_ns[1],
        (1.0 - cycle_ns[1] / cycle_ns[0]) * 100.0
    );

    println!(
        "\nHEADLINE: communication -{:.1}%, end-to-end -{:.1}% (paper: 33-45% / 30-35%), lossless",
        comm_red * 100.0,
        e2e_red * 100.0
    );
    Ok(())
}
