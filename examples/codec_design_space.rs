//! Codec design-space exploration (paper §5.2, Figs 4–6).
//!
//! ```bash
//! cargo run --release --example codec_design_space
//! ```
//!
//! Sweeps the three hardware knobs — lane-cache depth, lane count, and
//! decoder LUT staging — printing latency/area trade-offs and marking the
//! paper's chosen operating points.

use lexi::core::bitstream::{BitReader, BitWriter};
use lexi::core::huffman::CodeBook;
use lexi::core::stats::Histogram;
use lexi::hw::area_power::{decoder_area_um2, AreaPower, LexiHwConfig};
use lexi::hw::decoder::{DecoderConfig, DecoderUnit};
use lexi::hw::histogram_unit::{HistConfig, HistogramUnit};
use lexi::hw::lane_cache::LaneCache;
use lexi::models::weights::WeightStream;
use lexi::models::{ModelConfig, ModelScale};
use lexi_bench::Table;

fn main() -> anyhow::Result<()> {
    let models = ModelConfig::paper_models();

    // --- Fig 4: hit rate vs cache depth --------------------------------
    println!("Fig 4 — lane-cache hit rate vs depth (steady-state streams):");
    let mut t4 = Table::new(&["depth", "jamba", "zamba", "qwen"]);
    for depth in [1usize, 2, 4, 8, 16, 32] {
        let mut row = vec![depth.to_string()];
        for cfg in &models {
            let exps = WeightStream::sample_exponents(cfg, 0, 9, 200_000);
            let mut cache = LaneCache::new(depth);
            for &e in &exps {
                cache.access(e);
            }
            row.push(format!("{:.1}%", cache.hit_rate() * 100.0));
        }
        t4.row(row);
    }
    t4.print();

    // --- Fig 5: codebook-generation latency vs total cache size ---------
    println!("\nFig 5 — codebook generation latency vs cache size (512 samples):");
    let cfg0 = ModelConfig::jamba(ModelScale::Paper);
    let window = WeightStream::sample_exponents(&cfg0, 0, 9, 512);
    let mut t5 = Table::new(&["lanes", "depth", "cache KiB", "latency ns", "hit rate"]);
    for (lanes, depth) in [
        (1usize, 4usize),
        (1, 8),
        (2, 8),
        (4, 8),
        (8, 8),
        (10, 8), // paper's pick
        (16, 8),
        (16, 16),
        (32, 16),
    ] {
        let hc = HistConfig { lanes, depth };
        let r = HistogramUnit::new(hc).run(&window);
        let mark = if lanes == 10 && depth == 8 { " <- paper" } else { "" };
        t5.row(vec![
            format!("{lanes}{mark}"),
            depth.to_string(),
            format!("{:.3}", hc.cache_bytes() as f64 / 1024.0),
            r.cycles.to_string(),
            format!("{:.1}%", r.hit_rate * 100.0),
        ]);
    }
    t5.print();

    // --- Fig 6: decoder latency vs area ----------------------------------
    println!("\nFig 6 — decode latency (per 10 exponents) vs decoder area:");
    let exps = WeightStream::sample_exponents(&cfg0, 0, 9, 100_000);
    let hist = Histogram::from_bytes(&exps);
    let book = CodeBook::lexi_default(&hist)?;
    let mut w = BitWriter::new();
    for &e in &exps {
        book.encode_symbol(e, &mut w);
    }
    let bits = w.len_bits();
    let bytes = w.into_bytes();
    let mut t6 = Table::new(&["decoder", "area µm²", "ns / 10 exps"]);
    for (name, dc) in [
        ("1-stage 32b LUT", DecoderConfig::monolithic()),
        (
            "2-stage 16/32",
            DecoderConfig {
                stage_bits: vec![16, 32],
                entries_per_stage: 16,
            },
        ),
        (
            "3-stage 11/22/32",
            DecoderConfig {
                stage_bits: vec![11, 22, 32],
                entries_per_stage: 11,
            },
        ),
        ("4-stage 8/16/24/32 <- paper", DecoderConfig::paper_default()),
        (
            "5-stage 7/14/21/28/32",
            DecoderConfig {
                stage_bits: vec![7, 14, 21, 28, 32],
                entries_per_stage: 7,
            },
        ),
    ] {
        let unit = DecoderUnit::new(dc.clone())?;
        let mut r = BitReader::with_len(&bytes, bits);
        let (_, rep) = unit.decode(&mut r, &book, exps.len())?;
        t6.row(vec![
            name.into(),
            format!("{:.1}", decoder_area_um2(&dc)),
            format!("{:.2}", rep.avg_latency() * 10.0),
        ]);
    }
    t6.print();

    // --- chosen configuration summary (Table 4) --------------------------
    let bp = AreaPower::of(&LexiHwConfig::paper_default());
    println!(
        "\nchosen design: {:.1} µm² @22nm -> {:.1} µm² @16nm = {:.3}% of a Simba chiplet",
        bp.total_area_um2(),
        bp.total_area_16nm_um2(),
        bp.chiplet_overhead_pct()
    );
    Ok(())
}
