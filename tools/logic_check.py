#!/usr/bin/env python3
"""Logic-level validation of PR 2/3's new Rust arithmetic (no toolchain
in this container). Mirrors the Rust bit-for-bit:

  * BitWriter accumulator/spill       (bitstream.rs, unchanged, needed)
  * BitRefill window                  (bitstream.rs, reference for lanes)
  * LaneWindows SoA refill/consume    (bitstream.rs)
  * CanonicalDecoder tables + decode_from_window (pure kernel)
  * LaneCodec encode / v1+v2 wire format / from_bytes validation
  * lane-at-a-time decode vs lockstep decode
  * hw lockstep cycle model bounds    (decoder.rs)
  * BDI tag/base/delta bit layout     (PR 3: bdi.rs — mirror encode
    vs an independent string-of-bits reference, roundtrip, block-bits
    pricing, truncation + hostile-count-guard arithmetic)
  * Multi-symbol decode LUT           (PR 4: lut.rs — mirror of the
    MultiDecodeTable fill/packing rules vs brute-force enumeration of
    all 2^K probes through the string-of-bits reference codec, plus the
    multi-symbol block-decode loop vs the reference decode)
  * Stream integrity + fault recovery (NEW PR 6 — CRC-16/CCITT-FALSE
    table mirror of integrity.rs vs an independent bitwise LFSR with the
    0x29B1 check-value pin; the v3 checksummed LaneStream wrapper of
    batch.rs with exhaustive single-bit-flip detection and the 2⁻¹⁶
    multi-bit escape bound; the retry_backoff/RETRY_BUDGET link-retry
    accounting of noc/fault.rs + network.rs)
  * Serving robustness                (PR 9 — sim/serving.rs arrival
    traces: inverse-CDF Poisson + the MMPP-2 burst chain with its
    per-arrival update order; the deadline-aware admission / bounded
    queue / capped-backoff retry arithmetic with the resolution
    identity and pathwise-monotone tails; and the two-threshold
    hysteresis DegradeController of models/policy.rs, mirrored
    transition-for-transition against the scripted trace the Rust
    test pins verbatim)
  * Virtual-channel switch allocation (PR 10 — noc/src/vc.rs
    credit_share partitioning, the output_control.rs flat round-robin
    arbiter + wormhole lock/pointer update mirrored state-for-state
    against the scripted 2-VC contention trace the Rust test
    `scripted_two_vc_contention_trace` pins verbatim, the vcs=1
    collapse to the legacy per-port pointer, and the per-VC
    refinement of the credit-conservation audit)

Reference implementations are independent (string-of-bits codec), so a
mirror bug and a reference bug can't cancel.
"""

import math
import random

MASK64 = (1 << 64) - 1
FAST_BITS = 11
FAST_MISS = (1 << 32) - 1
ESC = 256
MAX_LANES = 64
LANE_BOOKS_FLAG = 0x80
MAX_BOOK_HEADER_BITS = 6 + 14 * 63


# --------------------------------------------------------------------------
# Codebook: canonical assignment mirroring huffman.rs::from_canonical.
# Lengths come from an independent reference Huffman (heapq) clamped to 24.
def build_lengths(freqs):
    import heapq
    syms = sorted(freqs.items())
    items = [(c, i, [s]) for i, (s, c) in enumerate(syms)]
    if len(items) == 1:
        return {syms[0][0]: 1}
    heapq.heapify(items)
    depth = {s: 0 for s, _ in syms}
    n = len(items)
    while len(items) > 1:
        a = heapq.heappop(items)
        b = heapq.heappop(items)
        for s in a[2] + b[2]:
            depth[s] += 1
        n += 1
        heapq.heappush(items, (a[0] + b[0], n, a[2] + b[2]))
    if max(depth.values()) > 24:
        return None  # rare; caller retries with other data
    return depth


def make_book(data, max_symbols=32):
    """(codes, esc_code, canonical) with ESC all-ones last, like Rust."""
    freqs = {}
    for b in data:
        freqs[b] = freqs.get(b, 0) + 1
    top = sorted(freqs.items(), key=lambda kv: (-kv[1], kv[0]))[:max_symbols]
    esc_mass = sum(c for s, c in freqs.items() if s not in dict(top))
    w = {s: c for s, c in top}
    w[ESC] = max(esc_mass, 1)
    lengths = build_lengths(w)
    if lengths is None:
        return None
    # ESC must hold the max length (swap like the Rust does).
    lmax = max(lengths.values())
    if lengths[ESC] < lmax:
        other = next(s for s, l in lengths.items() if l == lmax)
        lengths[ESC], lengths[other] = lengths[other], lengths[ESC]
    canonical = sorted(lengths.items(), key=lambda sl: (sl[1], sl[0] == ESC, sl[0]))
    codes = {}
    esc_code = None
    nxt = 0
    prev = canonical[0][1]
    for sym, ln in canonical:
        nxt <<= ln - prev
        prev = ln
        if sym == ESC:
            esc_code = (nxt, ln)
        else:
            codes[sym] = (nxt, ln)
        nxt += 1
    assert esc_code[0] == (1 << esc_code[1]) - 1, "ESC must be all-ones"
    return codes, esc_code, canonical


# --------------------------------------------------------------------------
# Reference codec: plain bit-string operations (independent of the mirror).
def ref_encode(data, book):
    codes, esc, _ = book
    bits = []
    for b in data:
        if b in codes:
            c, l = codes[b]
        else:
            c, l = (esc[0] << 8) | b, esc[1] + 8
        bits.append(format(c, "0{}b".format(l)))
    s = "".join(bits)
    return s


def ref_decode(bitstr, book, count):
    codes, esc, _ = book
    rev = {format(c, "0{}b".format(l)): s for s, (c, l) in codes.items()}
    esc_s = format(esc[0], "0{}b".format(esc[1]))
    out = []
    i = 0
    for _ in range(count):
        for l in range(1, 33):
            pref = bitstr[i : i + l]
            if len(pref) < l:
                return None  # exhausted
            if pref == esc_s:
                raw = bitstr[i + l : i + l + 8]
                if len(raw) < 8:
                    return None
                out.append(int(raw, 2))
                i += l + 8
                break
            if pref in rev:
                out.append(rev[pref])
                i += l
                break
        else:
            return None
    return out, i


# --------------------------------------------------------------------------
# Mirror of BitWriter (put/spill/into_bytes).
class BitWriter:
    def __init__(self):
        self.buf = bytearray()
        self.acc = 0
        self.nbits = 0

    def put(self, value, n):
        assert n <= 56 and value < (1 << n) or n == 0
        self.acc = ((self.acc << n) | value) & MASK64
        self.nbits += n
        if self.nbits >= 8:
            whole = self.nbits & ~7
            rem = self.nbits - whole
            word = ((self.acc >> rem) << (64 - whole)) & MASK64
            self.buf += word.to_bytes(8, "big")[: whole // 8]
            self.nbits = rem

    def len_bits(self):
        return len(self.buf) * 8 + self.nbits

    def into_bytes(self):
        if self.nbits:
            pad = 8 - self.nbits
            self.buf.append((self.acc << pad) & 0xFF)
            self.nbits = 0
        return bytes(self.buf)


# --------------------------------------------------------------------------
# Mirror of BitRefill.
class BitRefill:
    def __init__(self, buf, start, len_bits):
        assert start <= len_bits <= len(buf) * 8
        self.buf = buf
        self.byte_pos = start // 8
        self.bitbuf = 0
        self.navail = 0
        self.len_bits = len_bits
        self.refill()
        sub = start % 8
        self.bitbuf = (self.bitbuf << sub) & MASK64
        self.navail -= sub

    def pos(self):
        return self.byte_pos * 8 - self.navail

    def remaining(self):
        return self.len_bits - self.pos()

    def refill(self):
        if self.byte_pos + 8 <= len(self.buf):
            w = int.from_bytes(self.buf[self.byte_pos : self.byte_pos + 8], "big")
            add = (64 - self.navail) & ~7
            if add > 0:
                chunk = w if add == 64 else ((w >> (64 - add)) << (64 - add)) & MASK64
                self.bitbuf |= chunk >> self.navail
                self.navail += add
                self.byte_pos += add // 8
        else:
            while self.navail <= 56 and self.byte_pos < len(self.buf):
                self.bitbuf |= self.buf[self.byte_pos] << (56 - self.navail)
                self.navail += 8
                self.byte_pos += 1

    def consume(self, n):
        assert n <= self.remaining() and n <= self.navail
        self.bitbuf = (self.bitbuf << n) & MASK64
        self.navail -= n


# --------------------------------------------------------------------------
# Mirror of the NEW LaneWindows (SoA over one shared buffer).
class LaneWindows:
    def __init__(self, buf, spans):
        self.buf = buf
        self.byte_pos = []
        self.window = []
        self.navail = []
        self.end_bits = []
        for (start, end) in spans:
            assert start <= end <= len(buf) * 8
            self.byte_pos.append(start // 8)
            self.window.append(0)
            self.navail.append(0)
            self.end_bits.append(end)
            l = len(self.byte_pos) - 1
            self.refill(l)
            sub = start % 8
            self.window[l] = (self.window[l] << sub) & MASK64
            self.navail[l] -= sub

    def pos(self, l):
        return self.byte_pos[l] * 8 - self.navail[l]

    def remaining(self, l):
        return self.end_bits[l] - self.pos(l)

    def refill(self, l):
        bp = self.byte_pos[l]
        na = self.navail[l]
        if bp + 8 <= len(self.buf):
            w = int.from_bytes(self.buf[bp : bp + 8], "big")
            add = (64 - na) & ~7
            if add > 0:
                chunk = w if add == 64 else ((w >> (64 - add)) << (64 - add)) & MASK64
                self.window[l] |= chunk >> na
                self.navail[l] = na + add
                self.byte_pos[l] = bp + add // 8
        else:
            while self.navail[l] <= 56 and self.byte_pos[l] < len(self.buf):
                self.window[l] |= self.buf[self.byte_pos[l]] << (56 - self.navail[l])
                self.navail[l] += 8
                self.byte_pos[l] += 1

    def consume(self, l, n):
        assert n <= self.remaining(l) and n <= self.navail[l], (l, n)
        self.window[l] = (self.window[l] << n) & MASK64
        self.navail[l] -= n


# --------------------------------------------------------------------------
# Mirror of CanonicalDecoder + the NEW pure decode_from_window kernel.
class Decoder:
    def __init__(self, book):
        _, _, canonical = book
        self.first_code_aligned = []
        self.first_index = []
        self.lengths = []
        self.symbols = []
        self.fast = [FAST_MISS] * (1 << FAST_BITS)
        nxt = 0
        prev = canonical[0][1]
        for i, (sym, ln) in enumerate(canonical):
            nxt <<= ln - prev
            prev = ln
            if not self.lengths or self.lengths[-1] != ln:
                self.lengths.append(ln)
                self.first_index.append(i)
                self.first_code_aligned.append(nxt << (32 - ln))
            self.symbols.append(sym)
            if ln <= FAST_BITS and sym != ESC:
                lo = nxt << (FAST_BITS - ln)
                hi = (nxt + 1) << (FAST_BITS - ln)
                packed = (sym << 8) | ln
                for s in range(lo, hi):
                    self.fast[s] = packed
            nxt += 1

    def decode_from_window(self, window, remaining, pos):
        probe = window >> (64 - FAST_BITS)
        hit = self.fast[probe]
        if hit != FAST_MISS:
            ln = hit & 0xFF
            if remaining >= ln:
                return (hit >> 8, ln)
        return self._slow(window, remaining, pos)

    def _slow(self, window, remaining, pos):
        w32 = window >> 32
        for k in range(len(self.lengths)):
            ln = self.lengths[k]
            upper = (
                self.first_code_aligned[k + 1]
                if k + 1 < len(self.lengths)
                else MASK64
            )
            if w32 < upper:
                if remaining < ln:
                    raise EOFError("exhausted")
                code = w32 >> (32 - ln)
                first = self.first_code_aligned[k] >> (32 - ln)
                idx = self.first_index[k] + (code - first)
                if idx >= len(self.symbols):
                    raise ValueError("invalid codeword")
                sym = self.symbols[idx]
                if sym == ESC:
                    if remaining < ln + 8:
                        raise EOFError("exhausted esc")
                    raw = ((window << ln) & MASK64) >> 56
                    return (raw, ln + 8)
                return (sym, ln)
        raise ValueError("invalid codeword")

    def decode_block(self, buf, start, len_bits, count):
        """Mirror of decode_block_into (single-lane refill loop)."""
        s = BitRefill(buf, start, len_bits)
        out = []
        for _ in range(count):
            if s.navail < 40:
                s.refill()
            sym, used = self.decode_from_window(s.bitbuf, s.remaining(), s.pos())
            s.consume(used)
            out.append(sym)
        return out


# --------------------------------------------------------------------------
# Mirror of LaneCodec encode (v1/v2) + both decode paths + from_bytes.
def book_header_bits(book):
    return 6 + 14 * len(book[2])


def write_book_header(book, w):
    _, _, canonical = book
    w.put(len(canonical), 6)
    for sym, ln in canonical:
        w.put(1 if sym == ESC else 0, 1)
        w.put(sym & 0xFF, 8)
        w.put(ln, 5)


def parse_book_header(buf, off, bits):
    """Mirror of CodeBook::read_header + from_canonical checks."""
    r = BitRefill(bytes(buf[off : off + (bits + 7) // 8]), 0, bits)

    def get(n):
        if r.remaining() < n:
            raise EOFError()
        if r.navail < n:
            r.refill()
        v = r.bitbuf >> (64 - n)
        r.consume(n)
        return v

    count = get(6)
    if count < 1:
        raise ValueError("zero entries")
    canonical = []
    prev = 0
    esc_seen = False
    for i in range(count):
        is_esc = get(1) == 1
        sym = get(8)
        ln = get(5)
        if ln == 0 or ln > 31:
            raise ValueError("length out of range")
        if ln < prev:
            raise ValueError("not canonical order")
        prev = ln
        sym = ESC if is_esc else sym
        if sym == ESC:
            if esc_seen:
                raise ValueError("dup esc")
            esc_seen = True
        canonical.append((sym, ln))
    if not esc_seen or canonical[-1][0] != ESC:
        raise ValueError("esc missing/not last")
    if sum(1 << (32 - l) for _, l in canonical) != 1 << 32:
        raise ValueError("kraft")
    # rebuild codes
    codes = {}
    esc_code = None
    nxt = 0
    prev = canonical[0][1]
    for sym, ln in canonical:
        nxt <<= ln - prev
        prev = ln
        if sym == ESC:
            esc_code = (nxt, ln)
        else:
            if sym in codes:
                raise ValueError("dup sym")
            codes[sym] = (nxt, ln)
        nxt += 1
    return codes, esc_code, canonical


def lane_encode(data, lanes, books, embed):
    """books: list of per-lane book (len==lanes). embed=True → v2."""
    payloads = []
    lane_bits = []
    for l in range(lanes):
        sub = data[l::lanes]
        w = BitWriter()
        codes, esc, _ = books[l]
        for b in sub:
            if b in codes:
                c, ln = codes[b]
            else:
                c, ln = (esc[0] << 8) | b, esc[1] + 8
            w.put(c, ln)
        lane_bits.append(w.len_bits())
        payloads.append(w.into_bytes())
    out = bytearray()
    out.append(lanes | (LANE_BOOKS_FLAG if embed else 0))
    out += len(data).to_bytes(4, "big")
    for b in lane_bits:
        out += b.to_bytes(4, "big")
    book_bits = []
    if embed:
        blobs = []
        for bk in books:
            w = BitWriter()
            write_book_header(bk, w)
            book_bits.append(w.len_bits())
            blobs.append(w.into_bytes())
        for bb in book_bits:
            out += bb.to_bytes(2, "big")
        for blob in blobs:
            out += blob
    for p in payloads:
        out += p
    return bytes(out), lane_bits, book_bits


def lane_len(count, lanes, l):
    return (count + lanes - 1 - l) // lanes


def parse_stream(bytes_):
    """Mirror of from_bytes + validated_lanes. Returns parsed dict."""
    if len(bytes_) < 5:
        raise ValueError("short")
    has_books = bytes_[0] & LANE_BOOKS_FLAG != 0
    lanes = bytes_[0] & ~LANE_BOOKS_FLAG & 0xFF
    if lanes == 0 or lanes > MAX_LANES:
        raise ValueError("lanes")
    count = int.from_bytes(bytes_[1:5], "big")
    header = 5 + 4 * lanes
    if len(bytes_) < header:
        raise ValueError("header trunc")
    lane_bits = [
        int.from_bytes(bytes_[5 + 4 * l : 9 + 4 * l], "big") for l in range(lanes)
    ]
    book_bits, books = [], []
    off = header
    if has_books:
        table_end = header + 2 * lanes
        if len(bytes_) < table_end:
            raise ValueError("book table trunc")
        book_bits = [
            int.from_bytes(bytes_[header + 2 * l : header + 2 * l + 2], "big")
            for l in range(lanes)
        ]
        for bb in book_bits:
            if bb == 0 or bb > MAX_BOOK_HEADER_BITS:
                raise ValueError("book bits range")
        off = table_end
        for bb in book_bits:
            blob = (bb + 7) // 8
            if off + blob > len(bytes_):
                raise ValueError("book blob trunc")
            books.append(parse_book_header(bytes_, off, bb))
            off += blob
    # validated_lanes
    views = []
    for l in range(lanes):
        bits = lane_bits[l]
        end = off + (bits + 7) // 8
        if end > len(bytes_):
            raise ValueError("lane payload")
        symbols = lane_len(count, lanes, l)
        if symbols > bits:
            raise ValueError("symbols>bits")
        views.append((l, off, end, bits, symbols))
        off = end
    return dict(
        lanes=lanes, count=count, lane_bits=lane_bits, books=books, views=views,
        bytes=bytes_,
    )


def decode_lane_at_a_time(stream, shared_book):
    decs = (
        [Decoder(shared_book)]
        if not stream["books"]
        else [Decoder(b) for b in stream["books"]]
    )
    n = stream["lanes"]
    out = [0] * stream["count"]
    for (l, start, end, bits, symbols) in stream["views"]:
        dec = decs[0] if len(decs) == 1 else decs[l]
        # sliced view, exactly like the Rust BitReader::with_len slice
        syms = dec.decode_block(stream["bytes"][start:end], 0, bits, symbols)
        for k, s in enumerate(syms):
            out[l + k * n] = s
    return out


def decode_lockstep(stream, shared_book):
    decs = (
        [Decoder(shared_book)]
        if not stream["books"]
        else [Decoder(b) for b in stream["books"]]
    )
    n = stream["lanes"]
    dec_by_lane = [decs[0] if len(decs) == 1 else decs[l] for l in range(n)]
    out = [0] * stream["count"]
    spans = [(start * 8, start * 8 + bits) for (_, start, _, bits, _) in stream["views"]]
    wins = LaneWindows(stream["bytes"], spans)
    # Merged loop, as in the Rust: the final partial round (active < n)
    # is the scalar tail drain.
    rounds = -(-stream["count"] // n)
    for k in range(rounds):
        base = k * n
        active = min(n, stream["count"] - base)
        for l in range(active):
            if wins.navail[l] < 40:
                wins.refill(l)
            sym, used = dec_by_lane[l].decode_from_window(
                wins.window[l], wins.remaining(l), wins.pos(l)
            )
            out[base + l] = sym
            wins.consume(l, used)
    return out


# --------------------------------------------------------------------------
def gen_data(rng, n, esc_heavy):
    base = rng.randrange(256)
    alpha = rng.randrange(33, 140) if esc_heavy else rng.randrange(1, 32)
    out = []
    for _ in range(n):
        off = 0
        while off + 1 < alpha and rng.random() < 0.45:
            off += 1
        out.append((base + off) % 256)
    return out


# --------------------------------------------------------------------------
# BDI (PR 3): mirror of bdi.rs plus an independent reference for the
# tag/base/delta wire layout:
#
#   compress:    { count:32 | block* }
#   delta block: { tag:3 = width index | base:8 | delta:width x n }
#   raw block:   { tag:3 = 6           | byte:8 x n }
#
# The mirror reproduces the Rust arithmetic (leading-zeros signed width,
# midrange base); the reference builds the bit string independently with
# explicit two's-complement range checks, so a shared bug can't cancel.
BDI_BLOCK = 32
BDI_WIDTHS = [0, 1, 2, 3, 4, 5]
BDI_TAG_BITS = 3
BDI_TAG_RAW = len(BDI_WIDTHS)
BDI_MIN_BLOCK_BITS = BDI_TAG_BITS + 8


def bdi_signed_width(d):
    """Mirror of bdi.rs::signed_width (bit_length == 16 - leading_zeros)."""
    if d == 0:
        return 0
    if d > 0:
        return d.bit_length() + 1
    return (-d - 1).bit_length() + 1


def bdi_pick_base(block):
    mn, mx = min(block), max(block)
    return mn + (mx - mn) // 2


def bdi_pick_width(block, base):
    need = 0
    for v in block:
        need = max(need, bdi_signed_width(v - base))
        if need > BDI_WIDTHS[-1]:
            return None
    for i, w in enumerate(BDI_WIDTHS):
        if w >= need:
            return i
    return None


def bdi_block_bits(block):
    """Mirror of bdi.rs::block_bits (the flit greedy-fill pricer)."""
    base = bdi_pick_base(block)
    wi = bdi_pick_width(block, base)
    if wi is None:
        return BDI_TAG_BITS + 8 * len(block)
    return BDI_MIN_BLOCK_BITS + BDI_WIDTHS[wi] * len(block)


def bdi_mirror_compress(data):
    """Mirror of bdi.rs::compress through the BitWriter mirror."""
    w = BitWriter()
    w.put(len(data), 32)
    for i in range(0, len(data), BDI_BLOCK):
        block = data[i : i + BDI_BLOCK]
        base = bdi_pick_base(block)
        wi = bdi_pick_width(block, base)
        if wi is None:
            w.put(BDI_TAG_RAW, BDI_TAG_BITS)
            for v in block:
                w.put(v, 8)
        else:
            width = BDI_WIDTHS[wi]
            w.put(wi, BDI_TAG_BITS)
            w.put(base, 8)
            if width:
                for v in block:
                    w.put((v - base) & ((1 << width) - 1), width)
    bits = w.len_bits()
    return w.into_bytes(), bits


def bdi_ref_encode(data):
    """Independent reference: bit string with explicit range checks."""
    bits = [format(len(data), "032b")]
    for i in range(0, len(data), BDI_BLOCK):
        block = data[i : i + BDI_BLOCK]
        base = (min(block) + max(block)) // 2  # same value, derived differently
        width = None
        for cand in BDI_WIDTHS:
            lo = -(1 << (cand - 1)) if cand else 0
            hi = (1 << (cand - 1)) - 1 if cand else 0
            if all(lo <= v - base <= hi for v in block):
                width = cand
                break
        if width is None:
            bits.append(format(BDI_TAG_RAW, "03b"))
            bits.extend(format(v, "08b") for v in block)
        else:
            bits.append(format(BDI_WIDTHS.index(width), "03b"))
            bits.append(format(base, "08b"))
            if width:
                bits.extend(
                    format((v - base) & ((1 << width) - 1), "0{}b".format(width))
                    for v in block
                )
    return "".join(bits)


def bdi_ref_decode(bitstr):
    """Reference decode incl. the decompress_bits hostile-count guard."""
    i = 0

    def take(n):
        nonlocal i
        if i + n > len(bitstr):
            raise EOFError("bitstream exhausted")
        v = int(bitstr[i : i + n], 2) if n else 0
        i += n
        return v

    count = take(32)
    blocks = -(-count // BDI_BLOCK)
    if blocks * BDI_MIN_BLOCK_BITS > len(bitstr) - i:
        raise ValueError("hostile count header")
    out = []
    while len(out) < count:
        n = min(count - len(out), BDI_BLOCK)
        tag = take(BDI_TAG_BITS)
        if tag == BDI_TAG_RAW:
            for _ in range(n):
                out.append(take(8))
        elif tag < len(BDI_WIDTHS):
            width = BDI_WIDTHS[tag]
            base = take(8)
            if width == 0:
                out.extend([base] * n)
            else:
                for _ in range(n):
                    raw = take(width)
                    if raw >= 1 << (width - 1):
                        raw -= 1 << width
                    out.append((base + raw) % 256)
        else:
            raise ValueError("invalid tag")
    return out


# --------------------------------------------------------------------------
# Multi-symbol decode LUT (PR 4): mirror of lut.rs::MultiDecodeTable.
#
# Entry layout (one 64-bit word per 2^LUT_BITS probe):
#   bits  0..32  up to 4 decoded exponents, first-decoded in byte 0
#   bits 32..36  symbol count (0 = sentinel, fall back to scalar kernel)
#   bits 40..48  total bits consumed
LUT_BITS = 11
LUT_MAX_SYMS = 4
SCRATCH_MISS = 0xFFFF
SCRATCH_ESC = 0xFFFE


def mirror_multi_table(book):
    """Port of MultiDecodeTable::from_decoder: canonical scratch
    classify, then a greedy shift-reindex pack of up to LUT_MAX_SYMS
    codewords/probe. (The Rust reuses the decoder's fast table as the
    scratch; its MISS sentinel covers ESC and too-long codes, which this
    mirror's SCRATCH_ESC/SCRATCH_MISS split treats identically — both
    stop the pack.)"""
    _, _, canonical = book
    size = 1 << LUT_BITS
    scratch = [SCRATCH_MISS] * size
    nxt = 0
    prev = canonical[0][1]
    for sym, ln in canonical:
        nxt <<= ln - prev
        prev = ln
        if ln <= LUT_BITS:
            lo = nxt << (LUT_BITS - ln)
            hi = (nxt + 1) << (LUT_BITS - ln)
            val = SCRATCH_ESC if sym == ESC else ((sym << 8) | ln)
            for i in range(lo, hi):
                scratch[i] = val
        nxt += 1
    entries = []
    total = 0
    for p in range(size):
        e = 0
        used = 0
        cnt = 0
        while cnt < LUT_MAX_SYMS:
            rem = LUT_BITS - used
            if rem == 0:
                break
            s = scratch[(p << used) & (size - 1)]
            if s >= SCRATCH_ESC:
                break
            ln = s & 0xFF
            if ln > rem:
                break
            e |= (s >> 8) << (8 * cnt)
            used += ln
            cnt += 1
        if cnt:
            e |= (cnt << 32) | (used << 40)
        entries.append(e)
        total += max(cnt, 1)
    return entries, total / size


def ref_multi_entry(rev, esc_s, probe):
    """Independent brute force: decode the probe's bit string greedily
    with the string-of-bits codec, stopping at ESC, at a codeword that
    doesn't fully fit the known bits, or at LUT_MAX_SYMS symbols."""
    bits = format(probe, "0{}b".format(LUT_BITS))
    syms = []
    used = 0
    while len(syms) < LUT_MAX_SYMS:
        hit = None
        for l in range(1, LUT_BITS - used + 1):
            pref = bits[used : used + l]
            if pref == esc_s:
                hit = "esc"
                break
            if pref in rev:
                hit = (rev[pref], l)
                break
        if hit is None or hit == "esc":
            break
        syms.append(hit[0])
        used += hit[1]
    return syms, used


def bdi_gen_data(rng, n):
    mode = rng.randrange(4)
    if mode == 0:  # constant (width-0 blocks)
        return [rng.randrange(256)] * n
    if mode == 1:  # narrow deltas around a wandering base
        base = rng.randrange(256)
        out = []
        for _ in range(n):
            base = (base + rng.randrange(-1, 2)) % 256
            out.append((base + rng.randrange(-3, 4)) % 256)
        return out
    if mode == 2:  # full-range noise (raw fallback blocks)
        return [rng.randrange(256) for _ in range(n)]
    # mixed regimes spliced together
    out = []
    while len(out) < n:
        out.extend(bdi_gen_data(rng, min(n - len(out), rng.randrange(1, 80))))
    return out


# --------------------------------------------------------------------------
# ISSUE 6 mirrors: CRC-16/CCITT-FALSE (core/integrity.rs), the v3
# checksummed LaneStream wrapper (core/batch.rs), and the link
# retry/backoff accounting (noc/fault.rs + noc/network.rs).

CRC16_POLY = 0x1021
CRC16_INIT = 0xFFFF
CRC16_TABLE = []
for _b in range(256):
    _crc = _b << 8
    for _ in range(8):
        _crc = ((_crc << 1) ^ CRC16_POLY if _crc & 0x8000 else _crc << 1) & 0xFFFF
    CRC16_TABLE.append(_crc)


def crc16(data, crc=CRC16_INIT):
    """Table-driven mirror of integrity.rs::crc16_update."""
    for b in data:
        crc = ((crc << 8) & 0xFFFF) ^ CRC16_TABLE[((crc >> 8) ^ b) & 0xFF]
    return crc


def crc16_bitwise(data):
    """Independent bit-at-a-time LFSR reference (the CRC definition, not
    a transcription of the table fill — a table bug can't cancel)."""
    crc = CRC16_INIT
    for b in data:
        crc ^= b << 8
        for _ in range(8):
            crc = ((crc << 1) ^ CRC16_POLY if crc & 0x8000 else crc << 1) & 0xFFFF
    return crc


LANE_CRC_ESCAPE = 0x00


def v3_wrap(wire, lanes, lane_bits, book_bits):
    """Mirror of batch.rs's checksummed encode: wrap a v1/v2 wire into
    the v3 layout — escape byte, the v1/v2 header verbatim, per-lane
    payload CRCs (BE u16), a header CRC over everything emitted so far
    (escape byte and lane-CRC table included), then the payloads."""
    header_len = 5 + 4 * lanes
    if book_bits:
        header_len += 2 * lanes + sum((bb + 7) // 8 for bb in book_bits)
    header, payloads = wire[:header_len], wire[header_len:]
    out = bytearray([LANE_CRC_ESCAPE])
    out += header
    off = 0
    for bits in lane_bits:
        ln = (bits + 7) // 8
        out += crc16(payloads[off : off + ln]).to_bytes(2, "big")
        off += ln
    out += crc16(out).to_bytes(2, "big")
    out += payloads
    return bytes(out)


def v3_parse(bytes_):
    """Mirror of from_bytes' v3 path + validated_lanes: the header CRC is
    verified BEFORE any book-length bound check (a flipped header bit is
    Corrupt, not a bogus length complaint), then the de-escaped body goes
    through the ordinary v1/v2 parser, then each lane payload CRC."""
    if len(bytes_) < 1 or bytes_[0] != LANE_CRC_ESCAPE:
        raise ValueError("not v3")
    if len(bytes_) < 6:
        raise ValueError("short")
    flags = bytes_[1]
    lanes = flags & ~LANE_BOOKS_FLAG & 0xFF
    if lanes == 0 or lanes > MAX_LANES:
        raise ValueError("lanes")
    header = 1 + 5 + 4 * lanes
    if len(bytes_) < header:
        raise ValueError("header trunc")
    crc_at = header
    if flags & LANE_BOOKS_FLAG:
        table_end = header + 2 * lanes
        if len(bytes_) < table_end:
            raise ValueError("corrupt: book table trunc")
        # Extent only — the 0 < bits <= MAX bound check waits until after
        # the header CRC verify, exactly like the Rust.
        blobs = sum(
            (int.from_bytes(bytes_[header + 2 * l : header + 2 * l + 2], "big") + 7)
            // 8
            for l in range(lanes)
        )
        crc_at = table_end + blobs
    crc_end = crc_at + 2 * lanes + 2
    if len(bytes_) < crc_end:
        raise ValueError("corrupt: CRC trailer trunc")
    stored = int.from_bytes(bytes_[crc_at + 2 * lanes : crc_end], "big")
    if crc16(bytes_[: crc_at + 2 * lanes]) != stored:
        raise ValueError("corrupt: header CRC")
    lane_crcs = [
        int.from_bytes(bytes_[crc_at + 2 * l : crc_at + 2 * l + 2], "big")
        for l in range(lanes)
    ]
    # De-escape: splice the CRC trailer out and hand the v1/v2 body to
    # the existing parser (its bound checks are now safe to surface).
    body = bytes_[1:crc_at] + bytes_[crc_end:]
    st = parse_stream(body)
    for (l, start, end, _, _) in st["views"]:
        if crc16(body[start:end]) != lane_crcs[l]:
            raise ValueError(f"corrupt: lane {l} payload CRC")
    return st


RETRY_BUDGET = 4


def retry_backoff(attempt):
    """Mirror of fault.rs::retry_backoff: min(8 · 2^(a−1), 256), 1-based."""
    return min(8 << min(attempt - 1, 32), 256)


def replay_link(corrupt_plan, trip):
    """Cycle-accounting reference for network.rs's NACK-at-egress retry:
    traversal k corrupts iff corrupt_plan[k]. A corrupted packet whose
    attempt count is still under RETRY_BUDGET re-enters after
    1 + backoff(next) cycles; at the budget it is a reported drop.
    Returns (delivered, retries, drops, total_latency)."""
    attempt = 0
    latency = 0
    for corrupted in corrupt_plan:
        latency += trip
        if not corrupted:
            return True, attempt, 0, latency
        if attempt >= RETRY_BUDGET:
            return False, attempt, 1, latency
        attempt += 1
        latency += 1 + retry_backoff(attempt)
    raise AssertionError("corrupt_plan exhausted without a terminal outcome")


def main():
    rng = random.Random(20260729)
    cases = 0

    # 1) Shared-book: reference codec vs mirror kernel, both decode paths,
    #    all lane counts — the tentpole bit-exactness claim.
    for trial in range(120):
        n = rng.randrange(1, 1200)
        data = gen_data(rng, n, rng.random() < 0.4)
        book = make_book(data)
        if book is None:
            continue
        # reference single-stream roundtrip pins the book construction
        enc = ref_encode(data, book)
        ref = ref_decode(enc, book, len(data))
        assert ref is not None and ref[0] == data, "reference codec broken"
        for lanes in (1, 2, 4, 8):
            wire, _, _ = lane_encode(data, lanes, [book] * lanes, embed=False)
            st = parse_stream(wire)
            a = decode_lane_at_a_time(st, book)
            b = decode_lockstep(st, book)
            assert a == data, f"lane-at-a-time mismatch n={n} lanes={lanes}"
            assert b == data, f"lockstep mismatch n={n} lanes={lanes}"
        cases += 1
    print(f"[1] shared-book lockstep==lane-at-a-time==scalar: {cases} cases OK")

    # 2) Per-lane books (v2): tenants with different distributions.
    ok2 = 0
    for trial in range(60):
        lanes = rng.choice((1, 2, 4, 8))
        n = rng.randrange(lanes, 900)
        bases = [rng.randrange(256) for _ in range(lanes)]
        data = []
        for i in range(n):
            off = 0
            while off < 6 and rng.random() < 0.4:
                off += 1
            data.append((bases[i % lanes] + off) % 256)
        books = []
        bad = False
        for l in range(lanes):
            bk = make_book(data[l::lanes] or [0])
            if bk is None:
                bad = True
                break
            books.append(bk)
        if bad:
            continue
        wire, _, bb = lane_encode(data, lanes, books, embed=True)
        assert all(0 < x <= MAX_BOOK_HEADER_BITS for x in bb)
        st = parse_stream(wire)
        assert len(st["books"]) == lanes
        wrong = make_book([1, 2, 3])
        a = decode_lane_at_a_time(st, wrong)
        b = decode_lockstep(st, wrong)
        assert a == data and b == data, "v2 roundtrip mismatch"
        ok2 += 1
    print(f"[2] v2 per-lane-books roundtrip: {ok2} cases OK")

    # 3) Truncated lanes: both paths must error, never 'succeed'.
    ok3 = 0
    for trial in range(60):
        n = rng.randrange(8, 600)
        data = gen_data(rng, n, False)
        book = make_book(data)
        if book is None:
            continue
        lanes = rng.choice((1, 2, 4, 8))
        wire, lane_bits, _ = lane_encode(data, lanes, [book] * lanes, embed=False)
        l = rng.randrange(lanes)
        if lane_bits[l] == 0:
            continue
        cut = rng.randrange(1, lane_bits[l] + 1)
        forged = bytearray(wire)
        forged[5 + 4 * l : 9 + 4 * l] = (lane_bits[l] - cut).to_bytes(4, "big")
        for decoder in (decode_lane_at_a_time, decode_lockstep):
            try:
                st = parse_stream(bytes(forged))
                decoder(st, book)
                assert False, f"truncated lane decoded lanes={lanes} cut={cut}"
            except (ValueError, EOFError, AssertionError) as e:
                if isinstance(e, AssertionError) and "truncated lane" in str(e):
                    raise
        ok3 += 1
    print(f"[3] truncated lanes rejected on both paths: {ok3} cases OK")

    # 4) Hostile v2 book headers: garbled/forged/truncated must not crash
    #    or mis-validate (mirrors prop_hostile_book_headers_rejected_cheaply).
    ok4 = survivors = 0
    for trial in range(200):
        lanes = rng.choice((1, 2, 4))
        n = rng.randrange(lanes, 300)
        data = gen_data(rng, n, False)
        book = make_book(data)
        if book is None:
            continue
        wire, _, bb = lane_encode(data, lanes, [book] * lanes, embed=True)
        forged = bytearray(wire)
        mode = rng.randrange(3)
        header_end = 5 + 4 * lanes + 2 * lanes + sum((x + 7) // 8 for x in bb)
        if mode == 0:
            for _ in range(rng.randrange(1, 6)):
                i = rng.randrange(5 + 4 * lanes, header_end)
                forged[i] ^= rng.randrange(1, 256)
        elif mode == 1:
            l = rng.randrange(lanes)
            v = rng.choice((0, 0xFFFF, MAX_BOOK_HEADER_BITS + rng.randrange(1, 1000)))
            at = 5 + 4 * lanes + 2 * l
            forged[at : at + 2] = v.to_bytes(2, "big")
        else:
            forged = forged[: rng.randrange(5, header_end)]
        try:
            st = parse_stream(bytes(forged))
            survivors += 1  # parsed consistently — allowed
        except (ValueError, EOFError):
            pass
        ok4 += 1
    print(f"[4] hostile book headers: {ok4} fuzz cases, {survivors} consistent survivors, rest rejected")

    # 5) Empty / single-symbol streams across lane counts.
    book = make_book([9, 9, 9, 10])
    for lanes in (1, 2, 4, 8):
        for data in ([], [9]):
            wire, _, _ = lane_encode(data, lanes, [book] * lanes, embed=False)
            st = parse_stream(wire)
            assert decode_lane_at_a_time(st, book) == data
            assert decode_lockstep(st, book) == data
    print("[5] empty/single-symbol streams OK")

    # 6) LaneWindows ≡ per-lane BitRefill on random spans (SoA port check).
    for trial in range(150):
        nbytes = rng.randrange(8, 160)
        buf = bytes(rng.randrange(256) for _ in range(nbytes))
        lanes = rng.randrange(1, 9)
        total = nbytes * 8
        cuts = sorted(rng.randrange(total + 1) for _ in range(lanes - 1))
        spans = list(zip([0] + cuts, cuts + [total]))
        lw = LaneWindows(buf, spans)
        refs = [BitRefill(buf, s, e) for s, e in spans]
        live = True
        while live:
            live = False
            for l in range(lanes):
                if lw.remaining(l) == 0:
                    assert refs[l].remaining() == 0
                    continue
                live = True
                if lw.navail[l] < 40:
                    lw.refill(l)
                if refs[l].navail < 40:
                    refs[l].refill()
                assert lw.pos(l) == refs[l].pos()
                take = rng.randrange(1, min(lw.remaining(l), 32) + 1)
                assert (lw.window[l] >> (64 - take)) == (refs[l].bitbuf >> (64 - take)), (
                    f"window mismatch lane {l} at bit {lw.pos(l)}"
                )
                lw.consume(l, take)
                refs[l].consume(take)
    print("[6] LaneWindows SoA == N independent BitRefills: 150 cases OK")

    # 7) hw lockstep cycle model bounds: makespan <= lockstep <= serial.
    def stage_of(bits):
        for k, w in enumerate((8, 16, 24, 32)):
            if w >= bits:
                return k + 1
        return None

    for trial in range(60):
        n = rng.randrange(1, 1500)
        data = gen_data(rng, n, rng.random() < 0.3)
        book = make_book(data)
        if book is None:
            continue
        for lanes in (1, 2, 4, 8):
            wire, _, _ = lane_encode(data, lanes, [book] * lanes, embed=False)
            st = parse_stream(wire)
            dec = Decoder(book)
            # replay per-lane symbol stages in round order
            per_lane = [0] * lanes
            lockstep = 0
            readers = [
                BitRefill(st["bytes"][s:e], 0, bits)
                for (_, s, e, bits, _) in st["views"]
            ]
            rounds = -(-st["count"] // lanes)
            ok = True
            for k in range(rounds):
                active = min(lanes, st["count"] - k * lanes)
                rmax = 0
                for l in range(active):
                    r = readers[l]
                    if r.navail < 40:
                        r.refill()
                    sym, used = dec.decode_from_window(r.bitbuf, r.remaining(), r.pos())
                    r.consume(used)
                    stg = stage_of(used)
                    per_lane[l] += stg
                    rmax = max(rmax, stg)
                lockstep += rmax
            makespan = max(per_lane) if per_lane else 0
            serial = sum(per_lane)
            assert makespan <= lockstep <= serial, (makespan, lockstep, serial)
            if lanes == 1:
                assert makespan == lockstep == serial
    print("[7] lockstep cycle model bounds hold (makespan<=lockstep<=serial)")

    # 7b) decompress count guard: count bounded by remaining payload bits
    #     (every codeword >= 1 bit) rejects hostile headers and never a
    #     valid block (valid payload always has >= count bits).
    for trial in range(100):
        n = rng.randrange(1, 400)
        data = gen_data(rng, n, False)
        book = make_book(data)
        if book is None:
            continue
        payload_bits = len(ref_encode(data, book))
        assert n <= payload_bits, "valid block rejected by count guard"
        hostile_count = (1 << 32) - 1
        assert hostile_count > payload_bits, "hostile count passes the guard"
    print("[7b] decompress count guard: valid blocks pass, hostile counts rejected")

    # 8) Engine coupling arithmetic: max(wire, decode) + startup algebra.
    for trial in range(2000):
        wire = rng.uniform(0, 1e6)
        decode = rng.uniform(0, 1e6)
        hops = rng.uniform(0, 100)
        startup = 170.0
        ns = wire + hops
        if decode > wire:
            ns += decode - wire
        ns += startup
        assert abs(ns - (max(wire, decode) + hops + startup)) < 1e-6
    print("[8] transfer_ns coupling == max(wire, decode) + hops + startup")

    # 9) BDI (PR 3): mirror bits == independent reference bits, lossless
    #    roundtrip, block-bits pricing exact, truncation rejected, and
    #    the hostile-count guard arithmetic.
    ok9 = 0
    for trial in range(250):
        n = rng.randrange(1, 1500)
        data = bdi_gen_data(rng, n)
        by, bits = bdi_mirror_compress(data)
        mirror_str = "".join(format(b, "08b") for b in by)[:bits]
        ref_str = bdi_ref_encode(data)
        assert mirror_str == ref_str, f"BDI bit layout mismatch n={n}"
        assert bdi_ref_decode(ref_str) == data, f"BDI roundtrip mismatch n={n}"
        # block_bits pricing (flit greedy fill) must equal the writer.
        priced = 32 + sum(
            bdi_block_bits(data[i : i + BDI_BLOCK])
            for i in range(0, len(data), BDI_BLOCK)
        )
        assert priced == bits, f"BDI pricing {priced} != encoded {bits}"
        # Any strict truncation must raise, never mis-decode full-length.
        cut = rng.randrange(1, bits)
        try:
            out = bdi_ref_decode(ref_str[: bits - cut])
            assert out != data, "truncated BDI stream silently decoded"
        except (EOFError, ValueError):
            pass
        # Hostile count: forge the 32-bit header to u32::MAX — the guard
        # (ceil(count/32) blocks x 11 bits > remaining) must fire.
        forged = format((1 << 32) - 1, "032b") + ref_str[32:]
        try:
            bdi_ref_decode(forged)
            assert False, "hostile BDI count passed the guard"
        except ValueError:
            pass
        ok9 += 1
    print(f"[9] BDI mirror == independent reference, roundtrip, pricing, guards: {ok9} cases OK")

    # 10) Multi-symbol decode LUT (PR 4): for known codebooks, rebuild
    #     every entry by brute-force enumeration of all 2^K probes with
    #     the string-of-bits reference codec and assert symbols / count /
    #     consumed-bits match the Rust packing rules, then run the
    #     multi-symbol block-decode loop against the reference decode.
    ok10 = 0
    probes = 1 << LUT_BITS
    for trial in range(20):
        n = rng.randrange(16, 1200)
        data = gen_data(rng, n, rng.random() < 0.35)
        book = make_book(data)
        if book is None:
            continue
        codes, esc, _ = book
        rev = {format(c, "0{}b".format(l)): s for s, (c, l) in codes.items()}
        esc_s = format(esc[0], "0{}b".format(esc[1]))
        entries, avg = mirror_multi_table(book)
        assert 1.0 <= avg <= LUT_MAX_SYMS, f"avg fill {avg} out of range"
        min_len = min(l for _, (c, l) in codes.items()) if codes else LUT_BITS + 1
        for p in range(probes):
            e = entries[p]
            cnt = (e >> 32) & 0xF
            used = (e >> 40) & 0xFF
            syms = [(e >> (8 * j)) & 0xFF for j in range(cnt)]
            rsyms, rused = ref_multi_entry(rev, esc_s, p)
            assert syms == rsyms and used == rused, (
                f"multi entry mismatch probe={p:#0{LUT_BITS + 2}b}: "
                f"mirror ({syms}, {used}) vs reference ({rsyms}, {rused})"
            )
            if min_len <= LUT_BITS:
                assert cnt <= LUT_BITS // min_len, "entry over-packed"
        # Multi-symbol block decode (decode_block_into's LUT loop) must
        # reproduce the reference decode bit-for-bit, fallback included.
        w = BitWriter()
        for b in data:
            if b in codes:
                c, l = codes[b]
            else:
                c, l = (esc[0] << 8) | b, esc[1] + 8
            w.put(c, l)
        payload_bits = w.len_bits()
        buf = w.into_bytes()
        s = BitRefill(buf, 0, payload_bits)
        dec = Decoder(book)
        out = []
        while len(out) < len(data):
            if s.navail < 40:
                s.refill()
            e = entries[s.bitbuf >> (64 - LUT_BITS)]
            cnt = (e >> 32) & 0xF
            used = (e >> 40) & 0xFF
            if cnt and cnt <= len(data) - len(out) and used <= s.remaining():
                out.extend((e >> (8 * j)) & 0xFF for j in range(cnt))
                s.consume(used)
            else:
                sym, u = dec.decode_from_window(s.bitbuf, s.remaining(), s.pos())
                s.consume(u)
                out.append(sym)
        assert out == data, f"multi-symbol decode loop mismatch n={n}"
        ok10 += 1
    print(
        f"[10] multi-symbol LUT: {ok10} books x {probes} probes match brute force, decode loop lossless"
    )

    # 11) Egress codec ports (PR 5): mirror of noc/src/egress.rs — the
    #     ready/accept stall rule on a saturated ejection port.
    #       ready(busy, now)  = busy < now + 1 - eps
    #       accept(busy, now, cost) = max(busy, now) + cost
    #     cost(flit) = symbols_per_flit * cps / ghz / cycle_ns
    #                  (+ startup_ns / cycle_ns on a runtime-Huffman head)
    EPS = 1e-9

    def egress_replay(flits, cost_body, cost_head):
        """Drain `flits` through the port; flit always waiting (the
        saturated case — upstream buffers refill faster than a stalling
        decoder drains). Returns (completion_cycle, stall_cycles)."""
        busy, now, stalls, accepted = 0.0, 0, 0, 0
        while accepted < flits:
            if busy < now + 1 - EPS:  # ready()
                cost = cost_head if accepted == 0 else cost_body
                busy = max(busy, float(now)) + cost  # accept()
                accepted += 1
            else:
                stalls += 1
            now += 1
        return max(now, math.ceil(busy - EPS)), stalls

    for trial in range(400):
        flits = rng.randrange(1, 2000)
        syms_per_flit = rng.uniform(0.0, 40.0)
        cps = rng.uniform(0.0, 2.0)       # effective cycles/symbol, all lanes
        ghz = rng.choice((0.5, 1.0, 2.0))
        cycle_ns = rng.choice((0.64, 1.28, 2.56))
        startup_ns = rng.choice((0.0, 202.0))
        cost = syms_per_flit * cps / ghz / cycle_ns
        startup_cycles = startup_ns / cycle_ns
        done, stalls = egress_replay(flits, cost, cost + startup_cycles)

        decode_cycles = flits * cost + startup_cycles
        if cost <= 1.0 and startup_ns == 0.0:
            # Line rate: the decoder never throttles the link — the
            # paper's egress claim. Zero stalls, 1 flit/cycle.
            assert stalls == 0, f"line-rate port stalled ({cost})"
            assert done == flits, (done, flits)
        if cost > 1.0 + EPS:
            # Decode-bound: completion tracks the decode makespan with
            # fractional pacing (within one flit cost + rounding).
            # Backpressure becomes *visible* (a refused cycle) only once
            # the accumulated excess tops a whole cycle — the first
            # stall lands at flit k ≈ 1/(cost−1), so a short packet with
            # cost barely above 1 can drain stall-free. A lone flit
            # never stalls (nothing behind it).
            if (cost - 1.0) * (flits - 1) > 1.5:
                assert stalls > 0, f"decode-bound port never stalled ({cost})"
            assert decode_cycles - 1 <= done <= decode_cycles + cost + 2, (
                done,
                decode_cycles,
                cost,
            )
        if startup_ns > 0.0 and flits > 1 and cost <= 1.0:
            # Startup stalls the flits behind the head by ~its cycles.
            base_done, base_stalls = egress_replay(flits, cost, cost)
            assert base_stalls == 0
            delta = done - base_done
            assert abs(delta - startup_cycles) <= 2, (delta, startup_cycles)
        # Completion never beats the link (1 flit/cycle floor) and the
        # port conserves flits (accepted == flits by construction).
        assert done >= flits
    # Monotonicity: more symbols per flit can only stall more.
    prev = None
    for spf in (0.0, 4.0, 8.0, 16.0, 32.0):
        done, _ = egress_replay(500, spf * 1.16 / 1.28, spf * 1.16 / 1.28)
        assert prev is None or done >= prev, "completion not monotone in symbols"
        prev = done
    print("[11] egress codec port: ready/accept stall rule — line-rate free, "
          "decode-bound == makespan, startup charged once: 400 cases OK")

    # 12) ISSUE 6 — stream integrity + fault-recovery arithmetic.
    #
    # 12a) CRC-16/CCITT-FALSE: the table-driven mirror vs the independent
    #      bitwise LFSR, the canonical check value, streaming == one-shot.
    assert crc16(b"123456789") == 0x29B1, "CRC-16/CCITT-FALSE check value"
    assert crc16(b"") == CRC16_INIT
    for _ in range(300):
        buf = bytes(rng.randrange(256) for _ in range(rng.randrange(512)))
        assert crc16(buf) == crc16_bitwise(buf), "table != bitwise LFSR"
        cut = rng.randrange(len(buf) + 1)
        assert crc16(buf[cut:], crc16(buf[:cut])) == crc16(buf), "streaming"
    print("[12a] CRC-16/CCITT-FALSE mirror == bitwise LFSR, 0x29B1 check value OK")

    # 12b) v3 checksummed wire format: wrap/parse roundtrip over v1- and
    #      v2-shaped bodies; EVERY single-bit flip from the count field on
    #      is detected (header CRC or a lane CRC — HD ≥ 2 at these
    #      lengths); truncations reject; multi-bit escapes stay ~2⁻¹⁶.
    ok12 = flips = 0
    for trial in range(24):
        lanes = rng.choice((1, 2, 4, 8))
        n = rng.randrange(lanes, 400)
        data = gen_data(rng, n, rng.random() < 0.3)
        book = make_book(data)
        if book is None:
            continue
        embed = rng.random() < 0.5
        wire, lane_bits, book_bits = lane_encode(
            data, lanes, [book] * lanes, embed
        )
        v3 = v3_wrap(wire, lanes, lane_bits, book_bits)
        st = v3_parse(v3)
        assert decode_lane_at_a_time(st, book) == data, "v3 roundtrip"
        assert decode_lockstep(st, book) == data, "v3 lockstep roundtrip"
        for keep in (0, 1, 5, len(v3) - 1):
            try:
                v3_parse(v3[:keep])
                assert False, f"truncation to {keep} bytes parsed"
            except ValueError:
                pass
        # Bits 0..16 (escape + flags) can reshape the parse geometry —
        # the Rust property test pins those separately; from bit 16 on
        # every flip must be caught by a CRC.
        for pos in range(16, len(v3) * 8):
            dirty = bytearray(v3)
            dirty[pos // 8] ^= 1 << (pos % 8)
            try:
                v3_parse(bytes(dirty))
                assert False, f"single-bit flip at bit {pos} escaped"
            except ValueError:
                flips += 1
        ok12 += 1
    buf = bytes((i * 29 + 11) & 0xFF for i in range(96))
    clean = crc16(buf)
    escapes, trials = 0, 30000
    for _ in range(trials):
        dirty = bytearray(buf)
        for _ in range(4):
            p = rng.randrange(len(buf) * 8)
            dirty[p // 8] ^= 1 << (p % 8)
        if bytes(dirty) != buf and crc16(dirty) == clean:
            escapes += 1
    assert escapes <= 5, f"multi-bit escape rate above 2^-16: {escapes}/{trials}"
    print(f"[12b] v3 checksummed wire: {ok12} roundtrips, {flips} single-bit "
          f"flips all detected, {escapes}/{trials} multi-bit escapes")

    # 12c) Link retry/backoff accounting (fault.rs + network.rs): backoff
    #      series and cap, the 120-cycle budget-exhaustion sum, delivered-
    #      exactly-once-or-reported-drop, per-packet latency identity, and
    #      latency monotone in the corruption count.
    assert [retry_backoff(a) for a in range(1, 7)] == [8, 16, 32, 64, 128, 256]
    assert retry_backoff(40) == 256  # cap holds, no shift overflow
    assert sum(retry_backoff(a) for a in range(1, RETRY_BUDGET + 1)) == 120
    for trial in range(200):
        trip = rng.randrange(4, 64)
        k = rng.randrange(0, RETRY_BUDGET + 2)  # corruptions before success
        ok, retries, drops, lat = replay_link([True] * k + [False], trip)
        if k <= RETRY_BUDGET:
            assert ok and drops == 0 and retries == k
            assert lat == sum(
                1 + retry_backoff(a) for a in range(1, k + 1)
            ) + (k + 1) * trip
            assert lat >= trip, "faulty delivery beat the fault-free trip"
        else:
            assert not ok and drops == 1 and retries == RETRY_BUDGET
            assert lat == 120 + RETRY_BUDGET + (RETRY_BUDGET + 1) * trip
    lats = []
    for k in range(RETRY_BUDGET + 1):
        lats.append(replay_link([True] * k + [False], 10)[3])
    assert lats == sorted(lats) and len(set(lats)) == len(lats), "not monotone"
    # A budget-exhausted drop costs exactly as much sim time as the
    # last successful delivery — the failing packet never takes a
    # (RETRY_BUDGET+2)-th trip, it is reported at the budget boundary.
    assert replay_link([True] * (RETRY_BUDGET + 1) + [False], 10)[3] == lats[-1]
    print("[12c] retry/backoff accounting: budget=4, Σbackoff=120 cycles, "
          "delivered-or-reported-drop, latency ≥ fault-free: 200 cases OK")

    # 13) ISSUE 7 — ingress codec ports, bounded-NI admission, and the
    #     watchdog's credit-conservation audit.
    #
    # 13a) Ingress pacing mirrors noc/src/ingress.rs: the NI emits at
    #      most one flit per cycle, each paced by the same ready/accept
    #      rule as egress (§11); the compressor startup (the fixed
    #      codebook-pipeline ns — no LUT-fill share, that half lives at
    #      egress) lands once, on the head flit of a packet's first
    #      attempt.
    def ingress_replay(flits, cost_body, cost_head):
        """Emit `flits` from an always-backlogged NI through the
        encoder. Returns (cycle after the last emission, stall_cycles)."""
        busy, now, stalls, emitted = 0.0, 0, 0, 0
        while emitted < flits:
            if busy < now + 1 - EPS:  # egress::ready (shared helper)
                cost = cost_head if emitted == 0 else cost_body
                busy = max(busy, float(now)) + cost  # egress::accept
                emitted += 1
            else:
                stalls += 1
            now += 1
        return now, stalls

    for trial in range(400):
        flits = rng.randrange(1, 2000)
        syms_per_flit = rng.uniform(0.0, 40.0)
        lanes = rng.choice((1, 2, 4, 8, 10, 16))
        ghz = rng.choice((0.5, 1.0, 2.0))
        cycle_ns = rng.choice((0.64, 1.28, 2.56))
        startup_ns = rng.choice((0.0, 170.0))
        # EncoderUnit::cycles_per_symbol = 1/lanes (single-cycle lanes).
        cost = syms_per_flit * (1.0 / lanes) / ghz / cycle_ns
        startup_cycles = startup_ns / cycle_ns
        done, stalls = ingress_replay(flits, cost, cost + startup_cycles)

        if cost <= 1.0 and startup_ns == 0.0:
            # Line rate: the encoder never throttles injection — the
            # 16-lane paper point. Zero stalls, 1 flit/cycle.
            assert stalls == 0, f"line-rate ingress stalled ({cost})"
            assert done == flits, (done, flits)
        if startup_ns > 0.0 and flits > 1 and cost <= 1.0:
            # Startup delays the followers by ~its cycles, exactly once.
            base_done, base_stalls = ingress_replay(flits, cost, cost)
            assert base_stalls == 0 and base_done == flits
            delta = done - base_done
            assert abs(delta - startup_cycles) <= 2, (delta, startup_cycles)
        if cost > 1.0 + EPS:
            # Encode-bound: emission tracks the encode makespan with
            # fractional pacing; the throttle becomes a visible refused
            # cycle once the accumulated excess tops a whole cycle.
            if (cost - 1.0) * (flits - 1) > 1.5:
                assert stalls > 0, f"encode-bound ingress never stalled ({cost})"
            if flits >= 2:
                enc_last = (cost + startup_cycles) + (flits - 2) * cost
                assert enc_last - 1 <= done <= enc_last + cost + 2, (
                    done,
                    enc_last,
                    cost,
                )
        # Injection never beats the link (1 flit/cycle NI cap).
        assert done >= flits
    print("[13a] ingress codec port: ready/accept pacing — line-rate free, "
          "startup once on the head, encode-bound == makespan: 400 cases OK")

    # 13b) Bounded-NI admission (network.rs step phase 1): the queue
    #      depth never exceeds max_queue, a due spec finding it full is
    #      a counted deferral (never a drop, never unbounded growth),
    #      and saturation occurs iff the offered burst tops the bound.
    def ni_admit(num_packets, flits_each, max_queue):
        """All packets due at cycle 0, drained at 1 flit/cycle.
        Returns (refusals, max_depth, delivered)."""
        pending, queue = num_packets, []
        refusals = max_depth = delivered = 0
        for _ in range(200000):
            if pending == 0 and not queue:
                return refusals, max_depth, delivered
            for _ in range(pending):
                if len(queue) < max_queue:
                    queue.append(flits_each)
                    pending -= 1
                else:
                    refusals += 1
            max_depth = max(max_depth, len(queue))
            if queue:
                queue[0] -= 1
                if queue[0] == 0:
                    queue.pop(0)
                    delivered += 1
        raise AssertionError("bounded NI failed to drain")

    for trial in range(150):
        k = rng.randrange(1, 40)
        f = rng.randrange(1, 20)
        q = rng.randrange(1, 12)
        refusals, max_depth, delivered = ni_admit(k, f, q)
        assert delivered == k, "deferral lost a packet"
        assert max_depth <= q, f"NI depth {max_depth} broke the bound {q}"
        assert (refusals > 0) == (k > q), (refusals, k, q)
    print("[13b] bounded-NI admission: depth <= max_queue, deferrals counted, "
          "saturation iff burst > bound, nothing lost: 150 cases OK")

    # 13c) Credit-conservation audit (network.rs::audit_credits): per
    #      directed link, upstream credits + downstream buffered flits
    #      == buf_depth — invariant under traversals, drains with
    #      credit return, and mid-worm truncation (every discarded flit
    #      returns its credit, which is why a dead link audits clean);
    #      any single-sided mutation is exactly what the audit flags.
    for trial in range(200):
        depth = rng.randrange(1, 8)
        credits, fifo = depth, 0
        for op in range(200):
            r = rng.random()
            if r < 0.4 and credits > 0:
                credits -= 1
                fifo += 1  # flit crosses the link
            elif r < 0.7 and fifo > 0:
                fifo -= 1
                credits += 1  # drain + credit return
            elif fifo > 0:
                cut = rng.randrange(1, fifo + 1)  # truncation returns
                fifo -= cut
                credits += cut  # one credit per discarded flit
            assert credits + fifo == depth, "credit conservation broken"
            assert 0 <= credits <= depth and 0 <= fifo <= depth
        # A leak on either side is precisely what the audit formula
        # catches — no false negatives at distance 1.
        assert (credits - 1) + fifo != depth
        assert credits + (fifo + 1) != depth
    print("[13c] credit-conservation audit: credits + buffered == depth under "
          "traversal/drain/truncation; unit leaks always flagged: 200 cases OK")

    # 14) SWAR grouped lockstep (PR 8): independent mirror of the packed
    #     lane-state arithmetic in rust/core/src/swar.rs + the grouped
    #     decode_lockstep_swar loop in batch.rs.
    #
    # 14a) The byte-wise unsigned-less-than trick
    #      ~((x | 0x8080..) - n*0x0101..) & 0x8080.. flags byte i iff
    #      byte i < n, EXACTLY, whenever all bytes and the threshold stay
    #      below 128 (navail is 0..=64, the refill cadence is 40):
    #      pre-setting each byte's MSB keeps every per-byte difference
    #      non-negative, so no borrow crosses a byte boundary. (This
    #      mirror caught the textbook (x-n*LSB)&~x&MSB form being only an
    #      ANY-byte-below detector — a borrow out of a flagged byte
    #      falsely flags a neighbour equal to n.) Exhaustive over every
    #      (threshold, byte value, byte position), random filler in the
    #      other bytes.
    SWAR_LSB = 0x0101010101010101
    SWAR_MSB = 0x8080808080808080

    def swar_pack(vals):
        p = 0
        for i, v in enumerate(vals):
            assert 0 <= v < 128
            p |= v << (8 * i)
        return p

    def swar_bytes_below(packed, n):
        return ~((packed | SWAR_MSB) - n * SWAR_LSB) & SWAR_MSB & MASK64

    ok14a = 0
    for thresh in range(1, 128):
        for v in range(0, 65):
            pos = rng.randrange(8)
            filler = [rng.randrange(65) for _ in range(8)]
            filler[pos] = v
            mask = swar_bytes_below(swar_pack(filler), thresh)
            for i, b in enumerate(filler):
                got = bool(mask & (0x80 << (8 * i)))
                assert got == (b < thresh), (
                    f"SWAR compare wrong: byte {b} vs {thresh} -> {got}"
                )
            ok14a += 1
    print(f"[14a] SWAR byte-compare exact for all (threshold, navail) pairs: {ok14a} packings OK")

    # 14b) Grouped refill gate == per-lane scalar gate, full-state: drive
    #      two LaneWindows over the same buffer, one gated by the SWAR
    #      mask (ensure_group), one by per-lane `navail < bits`, with
    #      random interleaved consumes. byte_pos/window/navail must stay
    #      identical for EVERY lane — the mask refills exactly the lanes
    #      the scalar gate would.
    for trial in range(60):
        nbytes = rng.randrange(24, 200)
        buf = bytes(rng.randrange(256) for _ in range(nbytes))
        lanes = rng.randrange(1, 12)
        spans = []
        off = 0
        for _ in range(lanes):
            ln = rng.randrange(0, (nbytes * 8 - off) // max(1, lanes) + 1)
            spans.append((off, off + ln))
            off += ln
        a = LaneWindows(buf, spans)
        b = LaneWindows(buf, spans)
        for _ in range(80):
            l0 = rng.randrange(lanes)
            g = min(lanes - l0, rng.randrange(1, 9))
            bits = rng.randrange(1, 65)
            packed = swar_pack([a.navail[l0 + j] for j in range(g)])
            mask = swar_bytes_below(packed, bits)
            for j in range(g):
                if mask & (0x80 << (8 * j)):
                    a.refill(l0 + j)
            for j in range(g):
                if b.navail[l0 + j] < bits:
                    b.refill(l0 + j)
            l = rng.randrange(lanes)
            take = min(a.navail[l], a.remaining(l))
            if take:
                t = rng.randrange(1, take + 1)
                a.consume(l, t)
                b.consume(l, t)
            assert (a.byte_pos, a.window, a.navail) == (b.byte_pos, b.window, b.navail), (
                "grouped refill diverged from scalar gate"
            )
    print("[14b] grouped SWAR refill gate == per-lane scalar gate (full lane state): 60 streams OK")

    # 14c) Grouped lockstep replay (probe-all-then-apply phases, GROUP=8)
    #      == the visit-at-a-time reference decode_lockstep: without a
    #      LUT it must match the reference's output AND every lane's bit
    #      position; with the multi-LUT (shared book) the grouped drain
    #      must still emit the exact symbol stream.
    def decode_lockstep_swar_mirror(stream, shared_book, entries):
        decs = (
            [Decoder(shared_book)]
            if not stream["books"]
            else [Decoder(b) for b in stream["books"]]
        )
        n = stream["lanes"]
        dec_by_lane = [decs[0] if len(decs) == 1 else decs[l] for l in range(n)]
        out = [0] * stream["count"]
        spans = [
            (start * 8, start * 8 + bits)
            for (_, start, _, bits, _) in stream["views"]
        ]
        wins = LaneWindows(stream["bytes"], spans)
        lane_syms = [symbols for (_, _, _, _, symbols) in stream["views"]]
        done = [0] * n
        live = True
        while live:
            live = False
            l0 = 0
            while l0 < n:
                g = min(n - l0, 8)
                # Phase 1: one packed compare gates the group's refills.
                packed = swar_pack([wins.navail[l0 + j] for j in range(g)])
                mask = swar_bytes_below(packed, 40)
                for j in range(g):
                    if mask & (0x80 << (8 * j)):
                        wins.refill(l0 + j)
                # Phase 2: all probes issued before any lane consumes.
                probes = [
                    entries[wins.window[l0 + j] >> (64 - LUT_BITS)]
                    if entries is not None
                    else 0
                    for j in range(g)
                ]
                # Phase 3: apply in lane order (reference visit each).
                for j in range(g):
                    l = l0 + j
                    want = lane_syms[l] - done[l]
                    if want == 0:
                        continue
                    live = True
                    e = probes[j]
                    cnt = (e >> 32) & 0xF
                    used = (e >> 40) & 0xFF
                    if cnt and cnt <= want and used <= wins.remaining(l):
                        for k in range(cnt):
                            out[l + (done[l] + k) * n] = (e >> (8 * k)) & 0xFF
                        wins.consume(l, used)
                        done[l] += cnt
                    else:
                        sym, u = dec_by_lane[l].decode_from_window(
                            wins.window[l], wins.remaining(l), wins.pos(l)
                        )
                        out[l + done[l] * n] = sym
                        wins.consume(l, u)
                        done[l] += 1
                l0 += g
        return out, [wins.pos(l) for l in range(n)]

    ok14c = 0
    for trial in range(120):
        n = rng.randrange(1, 900)
        data = gen_data(rng, n, rng.random() < 0.3)
        book = make_book(data)
        if book is None:
            continue
        lanes = rng.choice([1, 2, 3, 7, 8, 11, 16])
        embed = rng.random() < 0.4
        wire, _, _ = lane_encode(data, lanes, [book] * lanes, embed)
        stream = parse_stream(wire)
        ref = decode_lockstep(stream, book)
        assert ref == data
        # Reference bit positions: replay per lane with the block loop.
        ref_pos = []
        for (l, start, end, bits, symbols) in stream["views"]:
            s = BitRefill(stream["bytes"][start:end], 0, bits)
            dec = Decoder(book)
            for _ in range(symbols):
                if s.navail < 40:
                    s.refill()
                _, u = dec.decode_from_window(s.bitbuf, s.remaining(), s.pos())
                s.consume(u)
            ref_pos.append(start * 8 + s.pos())
        # No LUT: grouped loop must track the scalar reference exactly.
        out, pos = decode_lockstep_swar_mirror(stream, book, None)
        assert out == ref, f"grouped (no LUT) output mismatch n={n} lanes={lanes}"
        assert pos == ref_pos, f"grouped (no LUT) bit positions drifted n={n}"
        # Shared multi-LUT: grouped drain still lossless.
        entries, _ = mirror_multi_table(book)
        out, _ = decode_lockstep_swar_mirror(stream, book, entries)
        assert out == ref, f"grouped LUT output mismatch n={n} lanes={lanes}"
        ok14c += 1
    print(
        f"[14c] grouped SWAR lockstep replay == reference (output + bit positions, "
        f"with and without LUT): {ok14c} streams OK"
    )

    # ----------------------------------------------------------------------
    # 15) Serving robustness mirrors (PR 9): sim/serving.rs arrival +
    #     admission arithmetic and the models/policy.rs hysteresis
    #     controller. These mirror the *arithmetic* (the Rust Rng
    #     differs from Python's), so the checks are structural and
    #     distributional, plus one scripted trace shared verbatim with
    #     the Rust test `hysteresis_round_trip_scripted_trace`.

    # 15a) Arrival traces. Poisson gaps are inverse-CDF exponentials
    #      `-ln(1-u)·mean`. The MMPP-2 burst trace updates its state
    #      per arrival (in_burst: stay iff u>=P_EXIT; enter iff
    #      u<P_ENTER), giving a stationary per-arrival burst fraction
    #      P_ENTER/(P_ENTER+P_EXIT); the calm gap is base·BMF with
    #      burst gaps BURST_FACTOR× shorter, so the expected gap is
    #      base·BMF·(1 - frac·(1-1/BURST_FACTOR)) — and the bursty
    #      switching over-disperses interval counts vs Poisson.
    BURST_FACTOR, P_ENTER, P_EXIT = 4.0, 0.05, 0.2
    BMF = 1.0 + (BURST_FACTOR - 1.0) * (P_ENTER / (P_ENTER + P_EXIT))
    arng = random.Random(0x5E41)
    base_gap = 125.0
    n_arr = 120_000
    gaps_p = [-math.log(1.0 - arng.random()) * base_gap for _ in range(n_arr)]
    mean_p = sum(gaps_p) / n_arr
    assert abs(mean_p - base_gap) / base_gap < 0.02, mean_p
    in_burst = False
    burst_arrivals = 0
    calm = base_gap * BMF
    gaps_b = []
    for _ in range(n_arr):
        u_state = arng.random()
        u_gap = arng.random()
        in_burst = (u_state >= P_EXIT) if in_burst else (u_state < P_ENTER)
        if in_burst:
            burst_arrivals += 1
        g = calm / BURST_FACTOR if in_burst else calm
        gaps_b.append(-math.log(1.0 - u_gap) * g)
    frac = burst_arrivals / n_arr
    stat_frac = P_ENTER / (P_ENTER + P_EXIT)
    assert abs(frac - stat_frac) < 0.015, frac
    want_mean = calm * (1.0 - stat_frac * (1.0 - 1.0 / BURST_FACTOR))
    mean_b = sum(gaps_b) / n_arr
    assert abs(mean_b - want_mean) / want_mean < 0.03, (mean_b, want_mean)

    def dispersion(gaps, window):
        counts = []
        t, nxt, c = 0.0, window, 0
        for g in gaps:
            t += g
            while t >= nxt:
                counts.append(c)
                c, nxt = 0, nxt + window
            c += 1
        mean = sum(counts) / len(counts)
        var = sum((x - mean) ** 2 for x in counts) / len(counts)
        return var / mean

    disp_p = dispersion(gaps_p, 20.0 * base_gap)
    disp_b = dispersion(gaps_b, 20.0 * base_gap)
    assert disp_p < 1.15, disp_p  # Poisson counts: var ≈ mean
    assert disp_b > 1.3 and disp_b > disp_p, (disp_b, disp_p)
    print(
        f"[15a] arrival mirrors: Poisson mean gap {mean_p:.1f}≈{base_gap}, MMPP burst "
        f"fraction {frac:.3f}≈{stat_frac}, dispersion {disp_b:.2f} > {disp_p:.2f} (Poisson)"
    )

    # 15b) Deadline-aware admission (serving.rs::try_admit + the client
    #      retry loop). Mirror: per-node single-server FIFO with lazy
    #      completion pops, completion = max(busy, at) + service;
    #      predicted deadline misses are terminal (waiting never shrinks
    #      an absolute backlog), only queue-full refusals earn the
    #      capped-exponential retry budget (backoff(n) = min(8<<(n-1),
    #      256) units).
    def serve_mirror(reqs, nodes, queue_depth, deadline, admission, retry_budget):
        queues = [[0.0, []] for _ in range(nodes)]  # [busy_until, completions]
        now = 0.0
        delivered = shed = shed_deadline = retries = 0
        lat = []
        max_resident = 0
        for gap, node, service in reqs:
            now += gap
            at = now
            attempt = 0
            while True:
                busy, comp = queues[node]
                while comp and comp[0] <= at:
                    comp.pop(0)
                depth = len(comp)
                completion = max(busy, at) + service
                if admission:
                    over = completion - now > deadline
                    if over or depth >= queue_depth:
                        if over or attempt >= retry_budget:
                            shed += 1
                            shed_deadline += 1 if over else 0
                            break
                        attempt += 1
                        retries += 1
                        at += float(min(8 << min(attempt - 1, 32), 256))
                        continue
                queues[node][0] = completion
                comp.append(completion)
                max_resident = max(max_resident, len(comp))
                delivered += 1
                lat.append(completion - now)
                break
        return delivered, shed, shed_deadline, retries, lat, max_resident

    # Scripted: 1 node, service 100, arrivals every 10. Queue-full path
    # (huge deadline, depth 2): req 3 retries twice (backoff 8 then 16
    # units, neither frees the queue) and sheds queue-full.
    script = [(10.0, 0, 100.0)] * 3
    d, s, sd, r, lat, _ = serve_mirror(script, 1, 2, 1e18, True, 2)
    assert (d, s, sd, r) == (2, 1, 0, 2), (d, s, sd, r)
    # Deadline path (deadline 250): req 3's predicted sojourn is 280 —
    # terminal, no retries consumed.
    d, s, sd, r, lat, _ = serve_mirror(script, 1, 10, 250.0, True, 2)
    assert (d, s, sd, r) == (2, 1, 1, 0), (d, s, sd, r)
    assert lat == [100.0, 190.0], lat
    # Admission off delivers everything, deadline blown.
    d, s, sd, r, lat, _ = serve_mirror(script, 1, 10, 250.0, False, 2)
    assert (d, s) == (3, 0) and lat[-1] == 280.0, (d, s, lat)

    # Property (120 random configs): resolution identity
    # delivered + shed == offered; resident queue never exceeds the
    # bound; every admitted sojourn meets the deadline.
    prng15 = random.Random(0x15B)
    for _ in range(120):
        nodes = prng15.randrange(1, 5)
        depth = prng15.randrange(1, 6)
        deadline = prng15.uniform(200.0, 2000.0)
        budget = prng15.randrange(0, 4)
        n = prng15.randrange(1, 300)
        reqs = [
            (
                -math.log(1.0 - prng15.random()) * prng15.uniform(20.0, 200.0),
                prng15.randrange(nodes),
                prng15.uniform(50.0, 400.0),
            )
            for _ in range(n)
        ]
        d, s, sd, r, lat, resident = serve_mirror(reqs, nodes, depth, deadline, True, budget)
        assert d + s == n, (d, s, n)
        assert sd <= s and resident <= depth
        assert all(x <= deadline + 1e-9 for x in lat)

    # Pathwise monotonicity (the Lindley argument the Rust test
    # `p99_is_monotone_in_load_and_identity_holds` leans on): identical
    # draws, gaps scaled by 1/load, shed-free ⇒ every per-request
    # sojourn (hence p50/p99) is non-decreasing in load.
    draws = [
        (prng15.random(), prng15.randrange(4), prng15.uniform(100.0, 300.0))
        for _ in range(2000)
    ]
    prev = None
    for load in (0.3, 0.6, 0.9, 1.2):
        reqs = [
            (-math.log(1.0 - u) * 200.0 / (4 * load), node, svc)
            for (u, node, svc) in draws
        ]
        d, s, _, _, lat, _ = serve_mirror(reqs, 4, 10**9, 1e18, True, 0)
        assert (d, s) == (len(draws), 0)
        if prev is not None:
            assert all(b >= a - 1e-6 for a, b in zip(prev, lat)), load
        prev = lat
    print(
        "[15b] admission mirror: scripted retry/deadline sheds exact, 120 random "
        "configs hold identity + bounded depth + deadline, sojourns pathwise "
        "monotone in load"
    )

    # 15c) Two-threshold hysteresis controller (policy.rs
    #      DegradeController), mirrored field-for-field.
    class HystMirror:
        def __init__(self, strikes, high, low, sustain, probe_interval, guard):
            self.p = (strikes, high, low, sustain, probe_interval, guard)
            self.degraded = False
            self.clock = 0
            self.last_transition = None
            self.hot = 0
            self.strikes = 0
            self.calm = 0
            self.counts = [0, 0, 0]  # degrades, recoveries, probes

        def guard_open(self):
            return self.last_transition is None or (
                self.clock - self.last_transition >= self.p[5]
            )

        def on_window(self, occ, strikes):
            thr, high, low, sustain, probe_interval, _ = self.p
            self.clock += 1
            guard = self.guard_open()
            if not self.degraded:
                self.strikes += strikes
                self.hot = self.hot + 1 if occ >= high else 0
                if (self.strikes >= thr or self.hot >= sustain) and guard:
                    self.degraded = True
                    self.last_transition = self.clock
                    self.counts[0] += 1
                    self.hot = self.strikes = self.calm = 0
                    return "degrade"
                return "none"
            if strikes > 0 or occ > low:
                self.calm = 0
                return "none"
            self.calm += 1
            if self.calm >= probe_interval and guard:
                self.calm = 0
                self.counts[2] += 1
                return "probe"
            return "none"

        def on_probe_result(self, healthy):
            if not self.degraded or not healthy:
                return "none"
            self.degraded = False
            self.last_transition = self.clock
            self.counts[1] += 1
            self.hot = self.strikes = self.calm = 0
            return "recover"

    # The scripted trace, verbatim from the Rust test
    # `hysteresis_round_trip_scripted_trace` (policy 3/0.85/0.60/3/2/4).
    c = HystMirror(3, 0.85, 0.60, 3, 2, 4)
    script15 = [
        (0.95, 0, "none"),     # hot 1
        (0.50, 0, "none"),     # cooled — hot resets
        (0.95, 0, "none"),     # hot 1
        (0.95, 0, "none"),     # hot 2
        (0.95, 0, "degrade"),  # hot 3 → degrade (window 5)
        (0.95, 0, "none"),     # still hot: no probe while loaded
        (0.50, 0, "none"),     # calm 1
        (0.70, 0, "none"),     # between thresholds — calm resets
        (0.50, 0, "none"),     # calm 1 (window 9 ≥ 5+4: guard open)
        (0.50, 0, "probe"),    # calm 2 → probe
    ]
    for i, (occ, strikes, want) in enumerate(script15):
        got = c.on_window(occ, strikes)
        assert got == want, f"window {i + 1}: {got} != {want}"
    assert c.degraded
    assert c.on_probe_result(True) == "recover"
    assert not c.degraded
    assert c.counts == [1, 1, 1], c.counts
    # Strike path, held by the flap guard until 4 windows past the
    # recovery at window 10.
    assert c.on_window(0.10, 3) == "none"   # window 11: guard closed
    assert c.on_window(0.10, 0) == "none"
    assert c.on_window(0.10, 0) == "none"
    assert c.on_window(0.10, 0) == "degrade"  # window 14: guard opens
    assert c.counts == [2, 1, 1], c.counts

    # No-flap property: worst-case oscillating occupancy with every
    # probe succeeding still spaces transitions ≥ hysteresis_windows
    # apart (mirrors `hysteresis_never_flaps_faster_than_the_window`).
    c = HystMirror(3, 0.85, 0.60, 1, 1, 6)
    transitions = []
    for w in range(1, 201):
        occ = 0.99 if w % 2 == 0 else 0.01
        act = c.on_window(occ, 0)
        if act == "degrade":
            transitions.append(w)
        elif act == "probe" and c.on_probe_result(True) == "recover":
            transitions.append(w)
    assert len(transitions) >= 4, transitions
    assert all(b - a >= 6 for a, b in zip(transitions, transitions[1:])), transitions
    assert c.counts[0] + c.counts[1] <= 200 // 6 + 1, c.counts
    # Randomized: arbitrary occupancy/strike/probe traces never violate
    # the guard, and mid-band occupancy alone never transitions.
    for _ in range(60):
        guard = prng15.randrange(1, 10)
        c = HystMirror(3, 0.85, 0.60, prng15.randrange(1, 4), prng15.randrange(1, 4), guard)
        transitions = []
        for w in range(1, 301):
            occ = prng15.choice([0.0, 0.3, 0.7, 0.9, 1.0])
            strikes = prng15.choice([0, 0, 0, 1, 3])
            act = c.on_window(occ, strikes)
            if act == "degrade":
                transitions.append(w)
            elif act == "probe" and c.on_probe_result(prng15.random() < 0.7) == "recover":
                transitions.append(w)
        assert all(b - a >= guard for a, b in zip(transitions, transitions[1:]))
        mid = HystMirror(3, 0.85, 0.60, 1, 1, 1)
        for w in range(50):
            assert mid.on_window(prng15.uniform(0.601, 0.849), 0) == "none"
    print(
        "[15c] hysteresis mirror: scripted round trip (degrade@5, probe@10, "
        "recover, strike-degrade@14) exact; no-flap spacing holds on oscillating "
        "and 60 random traces; mid-band is inert"
    )

    # ----------------------------------------------------------------------
    # 16) Virtual-channel switch allocation mirrors (PR 10): noc/src/vc.rs
    #     credit_share + the output_control.rs flat round-robin arbiter
    #     and wormhole lock/pointer update, mirrored state-for-state.
    NOC_PORTS = 5  # Local, North, South, East, West (topology.rs order)

    # 16a) credit_share: buf_depth split across VC lanes, remainder to
    #      the lower VCs (the escape channel never gets the short end),
    #      vcs = 1 keeps the whole depth — exhaustive over the small
    #      grid vc.rs tests, including the paper points.
    def vc_credit_share(buf_depth, vcs, v):
        return buf_depth // vcs + (1 if v < buf_depth % vcs else 0)

    for depth in range(1, 17):
        for vcs in range(1, 9):
            shares = [vc_credit_share(depth, vcs, v) for v in range(vcs)]
            assert sum(shares) == depth, (depth, vcs)
            assert shares == sorted(shares, reverse=True)
            assert shares[0] - shares[-1] <= 1
    assert vc_credit_share(4, 1, 0) == 4
    assert [vc_credit_share(4, 2, v) for v in range(2)] == [2, 2]
    assert [vc_credit_share(4, 4, v) for v in range(4)] == [1, 1, 1, 1]
    assert [vc_credit_share(5, 2, v) for v in range(2)] == [3, 2]
    print("[16a] credit_share: exact partition, remainder to low VCs, "
          "vcs=1 keeps full depth: 16x8 grid OK")

    # 16b) The flat round-robin switch allocator + lock update. Flits
    #      are dicts {pid, kind, ready_at}; kind H/B/T/S with
    #      is_head = H|S, is_tail = T|S. State mirrors VcRouter:
    #      fifos[inp][vc], lanes[out][vc] = [locked_to, locked_pid,
    #      credits], rr[out] over flat = inp*vcs + invc.
    def vc_router(buf_depth, vcs):
        return {
            "vcs": vcs,
            "fifos": [[[] for _ in range(vcs)] for _ in range(NOC_PORTS)],
            "lanes": [
                [[None, None, vc_credit_share(buf_depth, vcs, v)]
                 for v in range(vcs)]
                for _ in range(NOC_PORTS)
            ],
            "rr": [0] * NOC_PORTS,
            "forwarded": 0,
        }

    def vc_arbitrate(r, now, desired):
        vcs = r["vcs"]
        flat_len = NOC_PORTS * vcs
        requests = [None] * flat_len
        for inp in range(NOC_PORTS):
            for invc in range(vcs):
                fifo = r["fifos"][inp][invc]
                if not fifo or fifo[0]["ready_at"] > now:
                    continue
                d = desired(inp, invc, fifo[0])
                if d is not None:
                    want, ovc = d
                    requests[inp * vcs + invc] = (
                        want, ovc, fifo[0]["kind"] in "HS", fifo[0]["pid"]
                    )
        grants = [None] * NOC_PORTS
        input_taken = [False] * NOC_PORTS
        for out in range(NOC_PORTS):  # Port::ALL order == index order
            start = r["rr"][out]
            for step in range(flat_len):
                flat = (start + step) % flat_len
                inp, invc = flat // vcs, flat % vcs
                if input_taken[inp] or requests[flat] is None:
                    continue
                want, ovc, is_head, pid = requests[flat]
                if want != out:
                    continue
                lane = r["lanes"][out][ovc]
                eligible = (
                    lane[0] == (inp, invc) and lane[1] == pid
                ) if lane[0] is not None else is_head
                if not eligible:
                    continue
                grants[out] = (inp, invc, ovc)
                input_taken[inp] = True
                break
        return grants

    def vc_update_lock(r, out, out_vc, inp, invc, flit):
        vcs = r["vcs"]
        lane = r["lanes"][out][out_vc]
        if flit["kind"] in "TS":
            lane[0] = lane[1] = None
            r["rr"][out] = (inp * vcs + invc + 1) % (NOC_PORTS * vcs)
        else:
            lane[0] = (inp, invc)
            lane[1] = flit["pid"]

    # The scripted 2-VC contention trace, verbatim from the Rust test
    # `scripted_two_vc_contention_trace` (output_control.rs): one
    # router, vcs = 2, buf_depth = 4 (2 credits per East lane). North
    # VC0 carries a Single (packet 1); North VC1 and West VC1 each a
    # 3-flit worm (packets 2, 3). Scripted credit returns on East VC1:
    # +1 @ cycle 4, +1 @ 6, +2 @ 8. Everything routes East on its own
    # VC index; traversal declines a zero-credit grant untouched.
    N, E, W = 1, 3, 4
    r = vc_router(4, 2)
    r["fifos"][N][0].append({"pid": 1, "kind": "S", "ready_at": 0})
    for kind in "HBT":
        r["fifos"][N][1].append({"pid": 2, "kind": kind, "ready_at": 0})
        r["fifos"][W][1].append({"pid": 3, "kind": kind, "ready_at": 0})
    script16 = [
        # (cycle, credit return, granted (inp, invc), traversed,
        #  East vc0/vc1 credits after, East rr after)
        (0, 0, (N, 0), True, 1, 2, 3),
        (1, 0, (N, 1), True, 1, 1, 3),
        (2, 0, (N, 1), True, 1, 0, 3),
        (3, 0, (N, 1), False, 1, 0, 3),
        (4, 1, (N, 1), True, 1, 0, 4),
        (5, 0, (W, 1), False, 1, 0, 4),
        (6, 1, (W, 1), True, 1, 0, 4),
        (7, 0, (W, 1), False, 1, 0, 4),
        (8, 2, (W, 1), True, 1, 1, 4),
        (9, 0, (W, 1), True, 1, 0, 0),
    ]
    forwarded = 0
    for cyc, ret, want_grant, traversed, c0, c1, rr_after in script16:
        r["lanes"][E][1][2] += ret
        g = vc_arbitrate(r, cyc, lambda inp, invc, f: (E, invc))[E]
        assert g is not None and g[:2] == want_grant, (cyc, g)
        assert g[2] == g[1], "scripted routing keeps the VC index"
        if r["lanes"][E][g[2]][2] == 0:
            assert not traversed, f"cycle {cyc}: should have been declined"
        else:
            assert traversed, f"cycle {cyc}: should have traversed"
            f = r["fifos"][g[0]][g[1]].pop(0)
            r["lanes"][E][g[2]][2] -= 1
            forwarded += 1
            vc_update_lock(r, E, g[2], g[0], g[1], f)
        assert r["lanes"][E][0][2] == c0, f"cycle {cyc}: vc0 credits"
        assert r["lanes"][E][1][2] == c1, f"cycle {cyc}: vc1 credits"
        assert r["rr"][E] == rr_after, f"cycle {cyc}: rr"
    assert forwarded == 7, "1 single + two 3-flit worms"
    assert all(not f for port in r["fifos"] for f in port)
    assert r["lanes"][E][1][0] is None
    print("[16b] flat rr arbiter mirror: scripted 2-VC contention trace "
          "(grants, declines, credits, rr) matches the Rust pin, 7 flits")

    # 16c) vcs = 1 collapse: the tail pointer update reduces to the
    #      legacy (inp + 1) % NUM_PORTS, and on random request/lock
    #      states the flat arbiter picks the same winners as an
    #      independently written legacy per-port round-robin.
    r1 = vc_router(4, 1)
    tail = {"pid": 9, "kind": "T", "ready_at": 0}
    for inp in range(NOC_PORTS):
        vc_update_lock(r1, E, 0, inp, 0, tail)
        assert r1["rr"][E] == (inp + 1) % NOC_PORTS
    body = {"pid": 9, "kind": "B", "ready_at": 0}
    vc_update_lock(r1, E, 0, 2, 0, body)
    assert r1["rr"][E] == 0 and r1["lanes"][E][0][0] == (2, 0)

    def legacy_arbitrate(requests, locks, rr):
        """Independent vcs=1 reference: requests[inp] = (want, is_head,
        pid) | None; locks[out] = (holder_inp, pid) | None."""
        grants = [None] * NOC_PORTS
        taken = [False] * NOC_PORTS
        for out in range(NOC_PORTS):
            for step in range(NOC_PORTS):
                inp = (rr[out] + step) % NOC_PORTS
                if taken[inp] or requests[inp] is None:
                    continue
                want, is_head, pid = requests[inp]
                if want != out:
                    continue
                if locks[out] is not None:
                    if locks[out] != (inp, pid):
                        continue
                elif not is_head:
                    continue
                grants[out] = inp
                taken[inp] = True
                break
        return grants

    for trial in range(200):
        r1 = vc_router(4, 1)
        requests = [None] * NOC_PORTS
        locks = [None] * NOC_PORTS
        for inp in range(NOC_PORTS):
            if rng.random() < 0.7:
                kind = rng.choice("HBTS")
                pid = rng.randrange(1, 5)
                r1["fifos"][inp][0].append(
                    {"pid": pid, "kind": kind, "ready_at": 0}
                )
                requests[inp] = (rng.randrange(NOC_PORTS), kind in "HS", pid)
        for out in range(NOC_PORTS):
            r1["rr"][out] = rng.randrange(NOC_PORTS)
            if rng.random() < 0.4:
                holder = (rng.randrange(NOC_PORTS), rng.randrange(1, 5))
                r1["lanes"][out][0][0] = (holder[0], 0)
                r1["lanes"][out][0][1] = holder[1]
                locks[out] = holder
        want = {i: requests[i][0] for i in range(NOC_PORTS) if requests[i]}
        got = vc_arbitrate(
            r1, 0, lambda inp, invc, f: (want[inp], 0) if inp in want else None
        )
        ref = legacy_arbitrate(requests, locks, [r1["rr"][o] for o in range(NOC_PORTS)])
        assert [g[0] if g else None for g in got] == ref, (trial, got, ref)

    # 16d) Per-VC refinement of the §13c credit-conservation audit: on a
    #      directed link each lane v independently holds
    #      credits_v + buffered_v == credit_share(depth, vcs, v) under
    #      traversal / drain / mid-worm truncation, so the per-link sum
    #      is depth and a unit leak on any single lane is flagged.
    for trial in range(200):
        depth = rng.randrange(1, 12)
        vcs = rng.randrange(1, 9)
        credits = [vc_credit_share(depth, vcs, v) for v in range(vcs)]
        fifo = [0] * vcs
        for op in range(200):
            v = rng.randrange(vcs)
            act = rng.random()
            if act < 0.4 and credits[v] > 0:
                credits[v] -= 1
                fifo[v] += 1  # flit crosses the link on lane v
            elif act < 0.7 and fifo[v] > 0:
                fifo[v] -= 1
                credits[v] += 1  # drain + credit return
            elif fifo[v] > 0:
                cut = rng.randrange(1, fifo[v] + 1)  # truncation returns
                fifo[v] -= cut
                credits[v] += cut
            for u in range(vcs):
                assert credits[u] + fifo[u] == vc_credit_share(depth, vcs, u)
            assert sum(credits) + sum(fifo) == depth
        leak = rng.randrange(vcs)
        assert (credits[leak] - 1) + fifo[leak] != vc_credit_share(depth, vcs, leak)
        assert credits[leak] + (fifo[leak] + 1) != vc_credit_share(depth, vcs, leak)
    print("[16c] vcs=1 collapse: tail pointer == legacy (inp+1)%5, flat "
          "arbiter == independent legacy arbiter on 200 random states")
    print("[16d] per-VC credit audit: lane credits + buffered == "
          "credit_share under traversal/drain/truncation, unit leaks "
          "flagged: 200 links OK")

    print("\nALL LOGIC CHECKS PASSED")


if __name__ == "__main__":
    main()
