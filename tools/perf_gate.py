#!/usr/bin/env python3
"""Perf-regression gate for BENCH_*.json dumps (ISSUE 2 satellite).

Usage: perf_gate.py FRESH BASELINE [--threshold 0.15]

The gate is bench-agnostic: any JSON with a `rows` map of
`name -> {"m_per_s": ...}` works. ci.sh runs it once per bench —
`BENCH_perf_codec.json` (codec hot path) and, since ISSUE 5,
`BENCH_perf_noc.json` (NoC stepping rate ± egress codec ports) — each
diffed against its `git show HEAD:<file>` baseline.

Compares the throughput rows of a freshly produced bench JSON against the
committed baseline and fails (exit 1) if any shared row's `m_per_s`
dropped by more than the threshold. Rows present in only one file are
reported but never fail the gate: new benches (e.g. the `bdi encode` /
`bdi decode` rows ISSUE 3 added, the `noc * egress` rows from ISSUE 5,
or the `decode swar=8` / `decode par={1,2,8}` / `encode par=8` rows from
ISSUE 8) land against an older baseline without a baseline edit, and
removed benches don't block CI. A new row starts gating on the first run
after its JSON is committed as the baseline.

Beyond the row diff, known top-level overhead ratios are checked
against absolute ceilings (`SCALAR_BOUNDS`); the gated ones — the
ISSUE 7 watchdog overhead and the ISSUE 9 serving admission
overhead — fail the run even without a baseline.
Speedup *floors* (`MIN_TARGETS`, ISSUE 8: SWAR ≥1.3x the per-lane LUT
loop, 8-thread parallel ≥4x single-thread) are report-only by design —
thread scaling depends on the container's core count and neighbours, so
they are printed for the record and never fail the run.

Set LEXI_SKIP_PERF_GATE=1 (e.g. in toolchain-less or noisy-neighbour
containers) to skip.
"""

import argparse
import json
import sys

# Absolute ceilings on top-level overhead ratios a bench JSON may
# report. Unlike the row-vs-baseline diff these are unconditional:
# (bound, gated). Gated bounds fail the run; ungated ones are targets
# printed for the record (bench-noise-prone in shared containers).
# `watchdog_overhead` is gated (ISSUE 7): the zero-progress watchdog's
# per-cycle check is O(1) counters and must stay within 1.05x of
# watchdog-default stepping.
SCALAR_BOUNDS = {
    "watchdog_overhead": (1.05, True),
    # ISSUE 9 (gated): deadline-aware admission bookkeeping must stay
    # within 1.05x of the shed-off baseline on the identical trace —
    # pure arithmetic per arrival, no allocation on the hot path.
    "serving_shed_off_overhead": (1.05, True),
    "fault_off_overhead": (1.05, False),
    # ISSUE 10 (gated): the 2-VC router's request cache + flat
    # round-robin arbitration must stay within 1.10x of vcs=1 stepping
    # on the same uniform load; vcs=4 is report-only below.
    "vcs2_overhead": (1.10, True),
    "vcs4_overhead": (1.30, False),
    "ingress_slowdown_uniform": (1.30, False),
    "egress_slowdown_uniform": (1.30, False),
    "egress_slowdown_hotspot": (1.30, False),
    "xval_worst_err": (0.15, False),
}

# Report-only speedup FLOORS (value must be >= target, the mirror image
# of SCALAR_BOUNDS). ISSUE 8: these depend on host core count and
# container neighbours, so they never gate — the row-vs-baseline diff
# above is the regression signal; these just keep the scaling trajectory
# visible in CI logs.
MIN_TARGETS = {
    "swar_speedup_8": 1.3,
    "decode_par_speedup_8": 4.0,
    "encode_par_speedup_8": 4.0,
    # ISSUE 9 (report-only): on-time goodput at load 0.9, LEXI wire
    # format vs uncompressed — should exceed 1.0 whenever the codec's
    # wire-ratio win outruns its port-occupancy cost.
    "serving_goodput_gain": 1.0,
}


def load_data(path):
    with open(path) as f:
        return json.load(f)


def rows_of(data):
    rows = data.get("rows", {})
    return {
        name: row["m_per_s"]
        for name, row in rows.items()
        if isinstance(row, dict) and row.get("m_per_s", 0) > 0
    }


def check_scalar_bounds(data):
    """Return gated violations; print every bounded field present."""
    violations = []
    for name, (bound, gated) in sorted(SCALAR_BOUNDS.items()):
        val = data.get(name)
        if not isinstance(val, (int, float)):
            continue
        ok = val <= bound
        marker = "" if ok else ("  << EXCEEDS BOUND" if gated else "  (above target)")
        print(f"  {name:24s} {val:10.3f} (bound {bound}){marker}")
        if gated and not ok:
            violations.append((name, val, bound))
    return violations


def report_min_targets(data):
    """Print report-only speedup floors; never contributes failures."""
    for name, floor in sorted(MIN_TARGETS.items()):
        val = data.get(name)
        if not isinstance(val, (int, float)):
            continue
        marker = "" if val >= floor else "  (below target, report-only)"
        print(f"  {name:24s} {val:10.3f} (floor {floor}){marker}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("fresh", help="freshly generated BENCH_perf_codec.json")
    ap.add_argument("baseline", help="committed baseline JSON")
    ap.add_argument(
        "--threshold",
        type=float,
        default=0.15,
        help="max tolerated fractional throughput drop (default 0.15)",
    )
    args = ap.parse_args()

    try:
        fresh_data = load_data(args.fresh)
        fresh = rows_of(fresh_data)
    except (OSError, json.JSONDecodeError) as e:
        # ci.sh deletes the stale file before the bench run, so an
        # unreadable fresh file means the bench failed to produce one —
        # that's a gate failure, not a skip (a stale file must never
        # stand in for a fresh run).
        print(f"perf_gate: FAIL (fresh bench output unreadable: {e})")
        return 1

    # Absolute overhead bounds don't need a baseline — check them first.
    bound_violations = check_scalar_bounds(fresh_data)
    report_min_targets(fresh_data)

    try:
        base = rows_of(load_data(args.baseline))
    except (OSError, json.JSONDecodeError) as e:
        if bound_violations:
            print(f"perf_gate: FAIL — scalar bound(s) exceeded: {bound_violations}")
            return 1
        print(f"perf_gate: SKIP (unreadable baseline: {e})")
        return 0

    if not base:
        if bound_violations:
            print(f"perf_gate: FAIL — scalar bound(s) exceeded: {bound_violations}")
            return 1
        print("perf_gate: SKIP (baseline has no throughput rows)")
        return 0

    shared = sorted(set(fresh) & set(base))
    regressions = []
    print(f"perf_gate: {len(shared)} shared rows, threshold {args.threshold:.0%}")
    for name in shared:
        drop = 1.0 - fresh[name] / base[name]
        marker = ""
        if drop > args.threshold:
            regressions.append((name, drop))
            marker = "  << REGRESSION"
        print(
            f"  {name:24s} {base[name]:10.1f} -> {fresh[name]:10.1f} M/s "
            f"({-drop:+8.1%}){marker}"
        )
    for name in sorted(set(fresh) - set(base)):
        print(f"  {name:24s} (new row, no baseline — never fails the gate)")
    for name in sorted(set(base) - set(fresh)):
        print(f"  {name:24s} (baseline row absent from fresh run)")

    if regressions or bound_violations:
        if regressions:
            worst = max(regressions, key=lambda r: r[1])
            print(
                f"perf_gate: FAIL — {len(regressions)} row(s) dropped >"
                f"{args.threshold:.0%} (worst: {worst[0]} {worst[1]:.1%})"
            )
        if bound_violations:
            print(f"perf_gate: FAIL — scalar bound(s) exceeded: {bound_violations}")
        return 1
    print("perf_gate: PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
