#!/usr/bin/env bash
# Tier-1 gate (ROADMAP.md) + §Perf smoke.
#
#   ./ci.sh          full gate: release build, tests, debug-assert smoke
#   ./ci.sh --quick  build + tests only
set -euo pipefail
cd "$(dirname "$0")"

# Toolchain-independent validation first (ISSUE 4 satellite): the Python
# logic mirror runs — and can fail CI — even in containers without a
# Rust toolchain, which previously exited at `cargo build` with zero
# validation done. Tier-1 semantics on toolchain machines are unchanged.
logic_ran=0
if command -v python3 >/dev/null 2>&1; then
    echo "== logic check (tools/logic_check.py, no toolchain needed) =="
    python3 tools/logic_check.py
    logic_ran=1
else
    echo "== logic check: SKIPPED (no python3) =="
fi

if ! command -v cargo >/dev/null 2>&1; then
    if [[ "$logic_ran" == "1" ]]; then
        echo "ci.sh: no Rust toolchain — logic checks passed, but the tier-1" >&2
        echo "gate (cargo build + test) cannot run in this container." >&2
    else
        echo "ci.sh: no Rust toolchain AND no python3 — no validation ran." >&2
    fi
    exit 1
fi

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

if [[ "${1:-}" != "--quick" ]]; then
    # Smoke-run the §Perf codec bench with debug assertions on (dev
    # profile via the example target) and a small stream, so invariant
    # violations in the batch engine fail CI even without a perf run.
    echo "== perf_codec smoke (debug assertions, N=20000) =="
    LEXI_BENCH_N=20000 cargo run --example perf_codec_smoke

    # Full-size release run: prints the before/after table and refreshes
    # BENCH_perf_codec.json (the §Perf trajectory). Remove the checked-out
    # copy first so a silent bench write failure cannot feed the gate a
    # stale file (which, once a baseline is committed, would be the
    # baseline itself — the gate would diff it against itself and pass).
    echo "== perf_codec (release) =="
    rm -f BENCH_perf_codec.json
    cargo bench --bench perf_codec

    # NoC stepping bench (ISSUE 5): uniform/hotspot ± egress codec ports,
    # cycles/s rows + the ≤1.3× codec-tagged slowdown target, dumped to
    # BENCH_perf_noc.json for the same gate. ISSUE 6 adds the
    # "noc uniform fault-off" row (inert fault model, ≤1.05× target);
    # per the PR 3 convention, rows present in only one file never fail
    # the gate, so the new row lands against older baselines cleanly.
    echo "== perf_noc (release) =="
    rm -f BENCH_perf_noc.json
    cargo bench --bench perf_noc

    # Perf-regression gate (ISSUE 2, extended by ISSUE 5): diff each
    # fresh JSON against the committed baseline; >15% throughput drop on
    # any shared row fails. LEXI_SKIP_PERF_GATE=1 skips (toolchain-less
    # or noisy containers); a missing baseline skips with a reminder.
    if [[ "${LEXI_SKIP_PERF_GATE:-0}" == "1" ]]; then
        echo "== perf gate: SKIPPED (LEXI_SKIP_PERF_GATE=1) =="
    elif ! command -v python3 >/dev/null 2>&1; then
        echo "== perf gate: SKIPPED (no python3) =="
    else
        for bench_json in BENCH_perf_codec.json BENCH_perf_noc.json; do
            baseline=$(mktemp)
            if git show "HEAD:$bench_json" > "$baseline" 2>/dev/null; then
                echo "== perf gate: fresh $bench_json vs HEAD baseline =="
                python3 tools/perf_gate.py "$bench_json" "$baseline"
            else
                echo "== perf gate: SKIPPED for $bench_json (no committed baseline —"
                echo "   commit the freshly written one to arm the gate) =="
            fi
            rm -f "$baseline"
        done
    fi
fi

echo "ci.sh: all green"
