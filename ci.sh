#!/usr/bin/env bash
# Tier-1 gate (ROADMAP.md) + §Perf smoke.
#
#   ./ci.sh          full gate: release build, tests, debug-assert smoke
#   ./ci.sh --quick  build + tests only
set -euo pipefail
cd "$(dirname "$0")"

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

if [[ "${1:-}" != "--quick" ]]; then
    # Smoke-run the §Perf codec bench with debug assertions on (dev
    # profile via the example target) and a small stream, so invariant
    # violations in the batch engine fail CI even without a perf run.
    echo "== perf_codec smoke (debug assertions, N=20000) =="
    LEXI_BENCH_N=20000 cargo run --example perf_codec_smoke

    # Full-size release run: prints the before/after table and refreshes
    # BENCH_perf_codec.json (the §Perf trajectory).
    echo "== perf_codec (release) =="
    cargo bench --bench perf_codec
fi

echo "ci.sh: all green"
