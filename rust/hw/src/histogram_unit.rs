//! The parallel histogram front-end: M lanes × local caches, feeding the
//! single-ported global histogram through the arbiter (paper §4.2.1,
//! Fig. 3a).
//!
//! Cycle model:
//! * Each lane accepts at most one exponent per cycle.
//! * A hit costs 1 cycle.
//! * A miss must write its eviction to the global histogram: the lane
//!   requests the arbiter and **stalls** until granted, then the write
//!   itself takes one cycle inside the grant window.
//! * After the last exponent, resident entries drain through the same port.
//!
//! The reported "codebook generation latency" for Fig. 5 is ingestion +
//! drain; the downstream 78-cycle sort/merge/program pipeline is accounted
//! separately in [`crate::compressor`] (the paper pipelines it behind the
//! stream, quoting 55 ns for the 10×8 point on 512 activations).

use crate::arbiter::Arbiter;
use crate::lane_cache::{Access, LaneCache};
use lexi_core::stats::Histogram;

/// Configuration of the histogram unit.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HistConfig {
    /// Number of parallel lanes (paper sweeps 1..32, selects 10).
    pub lanes: usize,
    /// Entries per lane cache (paper sweeps 1..32, selects 8).
    pub depth: usize,
}

impl HistConfig {
    /// The paper's chosen design point: 10 lanes × 8 entries.
    pub fn paper_default() -> Self {
        HistConfig { lanes: 10, depth: 8 }
    }

    /// Total cache bytes (8 B per entry: tag + count + valid/age), the
    /// x-axis of Fig. 5 (10×8 ⇒ 0.625 KiB).
    pub fn cache_bytes(&self) -> usize {
        self.lanes * self.depth * 8
    }
}

/// Outcome of streaming one window of exponents through the unit.
#[derive(Clone, Debug)]
pub struct HistReport {
    /// Cycles from first exponent in to last drain write done.
    pub cycles: u64,
    /// Aggregate lane hit rate.
    pub hit_rate: f64,
    /// Per-lane hit rates.
    pub lane_hit_rates: Vec<f64>,
    /// The completed global histogram.
    pub histogram: Histogram,
    /// Total arbiter grants (≙ global-histogram writes).
    pub global_writes: u64,
}

/// One lane's in-flight state.
struct LaneState {
    cache: LaneCache,
    /// Eviction waiting for the port (exponent, count).
    blocked: Option<(u8, u32)>,
    /// Input cursor into this lane's queue.
    next: usize,
}

/// The assembled histogram unit.
pub struct HistogramUnit {
    cfg: HistConfig,
}

impl HistogramUnit {
    /// New unit with the given config.
    pub fn new(cfg: HistConfig) -> Self {
        assert!(cfg.lanes >= 1);
        HistogramUnit { cfg }
    }

    /// Stream `exponents` through the unit (round-robin lane distribution,
    /// as the PE array feeds all lanes in parallel) and build the global
    /// histogram. Returns the cycle-accurate report.
    pub fn run(&self, exponents: &[u8]) -> HistReport {
        let m = self.cfg.lanes;
        // Round-robin split.
        let mut queues: Vec<Vec<u8>> = vec![Vec::with_capacity(exponents.len() / m + 1); m];
        for (i, &e) in exponents.iter().enumerate() {
            queues[i % m].push(e);
        }

        let mut lanes: Vec<LaneState> = (0..m)
            .map(|_| LaneState {
                cache: LaneCache::new(self.cfg.depth),
                blocked: None,
                next: 0,
            })
            .collect();
        let mut arbiter = Arbiter::new(m);
        let mut hist = Histogram::default();
        let mut global_writes = 0u64;
        let mut cycle = 0u64;

        // --- ingestion ---------------------------------------------------
        loop {
            let mut all_done = true;
            // Lanes with blocked evictions re-raise their requests.
            for (i, lane) in lanes.iter().enumerate() {
                if lane.blocked.is_some() {
                    arbiter.request(i, cycle);
                }
            }
            // Arbiter grants one lane; its eviction write completes.
            if let Some(granted) = arbiter.step(cycle) {
                if let Some((sym, cnt)) = lanes[granted].blocked.take() {
                    hist.add(sym, cnt as u64);
                    global_writes += 1;
                }
            }
            // Each unblocked lane consumes one exponent.
            for (i, lane) in lanes.iter_mut().enumerate() {
                if lane.blocked.is_some() {
                    all_done = false;
                    continue;
                }
                if lane.next < queues[i].len() {
                    all_done = false;
                    let e = queues[i][lane.next];
                    lane.next += 1;
                    if let Access::MissEvicted(sym, cnt) = lane.cache.access(e) {
                        lane.blocked = Some((sym, cnt));
                    }
                }
            }
            cycle += 1;
            if all_done {
                break;
            }
        }

        // --- drain ---------------------------------------------------------
        // End-of-window flush: each lane bursts its resident entries into
        // its own bank of the (banked) global histogram, one entry per
        // cycle, lanes in parallel; the banks merge combinationally at the
        // tree builder's read port. Mid-stream evictions still serialize
        // through the arbiter above — only the terminal flush is banked.
        // (This is what makes the paper's 55 ns @ 10×8 point reachable:
        // a fully serialized 80-entry drain alone would exceed it.)
        let mut max_occupancy = 0u64;
        for lane in &mut lanes {
            let entries = lane.cache.drain();
            max_occupancy = max_occupancy.max(entries.len() as u64);
            for (sym, cnt) in entries {
                hist.add(sym, cnt as u64);
                global_writes += 1;
            }
        }
        cycle += max_occupancy;

        let hits: u64 = lanes.iter().map(|l| l.cache.hits).sum();
        let misses: u64 = lanes.iter().map(|l| l.cache.misses).sum();
        let lane_hit_rates = lanes.iter().map(|l| l.cache.hit_rate()).collect();
        HistReport {
            cycles: cycle,
            hit_rate: if hits + misses == 0 {
                0.0
            } else {
                hits as f64 / (hits + misses) as f64
            },
            lane_hit_rates,
            histogram: hist,
            global_writes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use lexi_core::prng::Rng;
    use lexi_core::proptest::check;
    use lexi_core::Bf16;

    fn gaussian_exponents(n: usize, sigma: f64, seed: u64) -> Vec<u8> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| Bf16::from_f32(rng.normal_with(0.0, sigma) as f32).exponent())
            .collect()
    }

    #[test]
    fn histogram_is_exact_regardless_of_config() {
        check("histogram unit exactness", 40, |g| {
            let a = g.usize(1..24);
            let n = g.usize(1..1200).max(1);
            let data = g.skewed_bytes(n, a);
            let cfg = HistConfig {
                lanes: g.usize(1..16),
                depth: g.usize(1..12),
            };
            let report = HistogramUnit::new(cfg).run(&data);
            assert_eq!(report.histogram, Histogram::from_bytes(&data));
        });
    }

    #[test]
    fn paper_point_latency_band() {
        // Fig 5: 10 lanes × depth 8, 512 activations ⇒ ~55 ns in the paper.
        // Our model charges every mid-stream eviction a full 3-cycle
        // exclusive grant, landing slightly higher (~90 ns) — same order,
        // same shape; EXPERIMENTS.md records the delta.
        let data = gaussian_exponents(512, 0.02, 42);
        let report = HistogramUnit::new(HistConfig::paper_default()).run(&data);
        assert!(
            (45..=110).contains(&report.cycles),
            "cycles {}",
            report.cycles
        );
        // Cold-start misses (up to depth×lanes of the 512 samples) bound
        // the window hit rate below Fig 4's steady-state >90%.
        assert!(report.hit_rate > 0.75, "hit rate {}", report.hit_rate);
    }

    #[test]
    fn single_lane_shallow_cache_is_slow() {
        // Fig 5's other extreme: 1 lane × depth 4 ⇒ ~788 ns (≫ 512).
        let data = gaussian_exponents(512, 0.02, 42);
        let report = HistogramUnit::new(HistConfig { lanes: 1, depth: 4 }).run(&data);
        assert!(report.cycles > 550, "cycles {}", report.cycles);
    }

    #[test]
    fn wide_config_approaches_ideal() {
        // 32 lanes × depth 16 ⇒ ~17 ns on 512 activations.
        let data = gaussian_exponents(512, 0.02, 42);
        let report = HistogramUnit::new(HistConfig {
            lanes: 32,
            depth: 16,
        })
        .run(&data);
        assert!(report.cycles < 60, "cycles {}", report.cycles);
    }

    #[test]
    fn latency_monotone_in_lanes() {
        let data = gaussian_exponents(512, 0.02, 7);
        let mut prev = u64::MAX;
        for lanes in [1usize, 2, 4, 8, 16, 32] {
            let r = HistogramUnit::new(HistConfig { lanes, depth: 8 }).run(&data);
            assert!(
                r.cycles <= prev.saturating_add(8),
                "latency should not grow with lanes: {lanes} lanes -> {} (prev {prev})",
                r.cycles
            );
            prev = r.cycles;
        }
    }

    #[test]
    fn cache_bytes_matches_paper() {
        assert_eq!(HistConfig::paper_default().cache_bytes(), 640); // 0.625 KiB
    }
}
