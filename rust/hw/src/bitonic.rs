//! Parallel bitonic sorting network (paper §4.2.2 step 1, ref. Batcher [4]).
//!
//! The hardware sorts the ≤32 `(exponent, count)` pairs by descending count
//! in a fixed network of compare-exchange stages. For n = 32 the network
//! has log₂(32)·(log₂(32)+1)/2 = 15 stages, one stage per cycle — the "15
//! cycles" in the paper's 78-cycle budget. This module implements the
//! actual network (not a call to `sort`) so stage count and comparator
//! count are measured, and validates it against `std` sorting.

/// Result of a network sort.
#[derive(Clone, Debug)]
pub struct SortReport<T> {
    pub sorted: Vec<T>,
    /// Network stages = cycles at one stage/cycle.
    pub stages: u64,
    /// Total compare-exchange operations (area proxy).
    pub comparators: u64,
}

/// Stages a bitonic network needs for `n` (padded to a power of two).
pub fn stages_for(n: usize) -> u64 {
    if n <= 1 {
        return 0;
    }
    let k = (n.next_power_of_two()).trailing_zeros() as u64;
    k * (k + 1) / 2
}

/// Sort by descending key using an explicit bitonic network.
///
/// `key` maps an element to its sort key (count); ties keep a deterministic
/// order via the secondary key so hardware and software agree bit-exactly.
pub fn sort_desc<T: Clone, K: Ord, F: Fn(&T) -> K>(items: &[T], key: F) -> SortReport<T> {
    let n = items.len();
    if n <= 1 {
        return SortReport {
            sorted: items.to_vec(),
            stages: 0,
            comparators: 0,
        };
    }
    let size = n.next_power_of_two();
    // Pad with None (sorts to the end under descending order).
    let mut v: Vec<Option<T>> = items.iter().cloned().map(Some).collect();
    v.resize(size, None);

    let desc_less = |a: &Option<T>, b: &Option<T>| -> bool {
        // "a should come before b" in descending order; None sinks last.
        match (a, b) {
            (Some(x), Some(y)) => key(x) >= key(y),
            (Some(_), None) => true,
            (None, _) => false,
        }
    };

    let mut stages = 0u64;
    let mut comparators = 0u64;
    let mut k = 2;
    while k <= size {
        let mut j = k / 2;
        while j >= 1 {
            stages += 1;
            for i in 0..size {
                let l = i ^ j;
                if l > i {
                    comparators += 1;
                    let ascending_block = i & k == 0;
                    // For descending output, "ascending blocks" must place
                    // larger first.
                    let in_order = if ascending_block {
                        desc_less(&v[i], &v[l])
                    } else {
                        desc_less(&v[l], &v[i])
                    };
                    if !in_order {
                        v.swap(i, l);
                    }
                }
            }
            j /= 2;
        }
        k *= 2;
    }

    SortReport {
        sorted: v.into_iter().flatten().collect(),
        stages,
        comparators,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use lexi_core::proptest::check;

    #[test]
    fn paper_stage_count_for_32() {
        assert_eq!(stages_for(32), 15);
    }

    #[test]
    fn stage_counts() {
        assert_eq!(stages_for(1), 0);
        assert_eq!(stages_for(2), 1);
        assert_eq!(stages_for(4), 3);
        assert_eq!(stages_for(8), 6);
        assert_eq!(stages_for(16), 10);
        assert_eq!(stages_for(33), 21); // pads to 64
    }

    #[test]
    fn sorts_descending() {
        let items = vec![(3u8, 5u64), (1, 9), (2, 1), (7, 9)];
        let r = sort_desc(&items, |&(sym, cnt)| (cnt, std::cmp::Reverse(sym)));
        assert_eq!(r.sorted, vec![(1, 9), (7, 9), (3, 5), (2, 1)]);
        assert_eq!(r.stages, stages_for(4));
    }

    #[test]
    fn prop_matches_std_sort() {
        check("bitonic == std sort", 150, |g| {
            let n = g.usize(0..40);
            let items: Vec<(u8, u64)> = g.vec(n, |g| (g.u8(), g.u64(0..1000)));
            let r = sort_desc(&items, |&(sym, cnt)| (cnt, std::cmp::Reverse(sym)));
            let mut expect = items.clone();
            expect.sort_by_key(|&(sym, cnt)| (std::cmp::Reverse(cnt), sym));
            assert_eq!(r.sorted, expect);
            assert_eq!(r.stages, stages_for(n));
        });
    }
}
