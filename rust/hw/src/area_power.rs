//! Area/power model, calibrated to the paper's GF 22 nm synthesis
//! (Table 4) with Stillmaker–Baas scaling to the 16 nm Simba node.
//!
//! The paper's published component results pin the model:
//!
//! | component                | area (µm²) | power (mW) | count |
//! |--------------------------|-----------:|-----------:|------:|
//! | local cache (depth 8)    |       9.85 |       0.25 |  ×10  |
//! | global hist + code gen   |     13113  |       5.23 |   ×1  |
//! | encode LUT (32 entries)  |      79.87 |       1.74 |  ×10  |
//! | decode LUT (4-stage)     |       98.5 |       2.03 |  ×10  |
//!
//! Totals: 14 995.2 µm², 45.43 mW; scaled ×0.3636 to 16 nm = 5 452.8 µm²
//! = **0.09 %** of a 6 mm² Simba chiplet.
//!
//! Each component scales parametrically so the design-space sweeps (Figs.
//! 4–6) can price alternative configurations: caches per entry, encode
//! LUTs per entry, decoders per CAM bit (two published decoder points fit
//! `area ≈ k·Σ(entries × window_bits)` with k ≈ 0.1539 µm²/bit and a
//! negligible payload term).

use crate::decoder::DecoderConfig;

/// Area scale factor GF 22 nm → 16 nm (Stillmaker–Baas [36]; the paper's
/// own totals imply exactly 5452.8 / 14995.2).
pub const SCALE_22_TO_16: f64 = 5452.8 / 14995.2;

/// Simba chiplet area in mm² (paper §5.4).
pub const SIMBA_CHIPLET_MM2: f64 = 6.0;

// --- calibration constants (GF 22 nm) -----------------------------------
const CACHE_AREA_PER_ENTRY_UM2: f64 = 9.85 / 8.0;
const CACHE_POWER_PER_ENTRY_MW: f64 = 0.25 / 8.0;
const GLOBAL_HIST_AREA_UM2: f64 = 13113.0;
const GLOBAL_HIST_POWER_MW: f64 = 5.23;
const ENC_LUT_AREA_PER_ENTRY_UM2: f64 = 79.87 / 32.0;
const ENC_LUT_POWER_MW: f64 = 1.74;
/// Decoder CAM cost per (entry × window-bit); fit from the paper's two
/// published decoder points (98.5 µm² 4-stage vs 157.6 µm² monolithic).
const DEC_AREA_PER_CAM_BIT_UM2: f64 = 0.1539;
const DEC_AREA_PER_ENTRY_PAYLOAD_UM2: f64 = 0.002;
/// Decoder power tracks area at the published density (2.03 mW / 98.5 µm²).
const DEC_POWER_PER_UM2_MW: f64 = 2.03 / 98.5;

/// A full LEXI codec hardware configuration.
#[derive(Clone, Debug)]
pub struct LexiHwConfig {
    /// Histogram/encode lanes (paper: 10).
    pub lanes: usize,
    /// Local cache entries per lane (paper: 8).
    pub cache_depth: usize,
    /// Encode LUT entries (alphabet cap; paper: 32).
    pub enc_lut_entries: usize,
    /// Decoder stage configuration (paper: 4-stage 8/16/24/32 × 8).
    pub decoder: DecoderConfig,
    /// Parallel decode lanes (paper: 10).
    pub decode_lanes: usize,
}

impl LexiHwConfig {
    /// The paper's chosen configuration.
    pub fn paper_default() -> Self {
        LexiHwConfig {
            lanes: 10,
            cache_depth: 8,
            enc_lut_entries: 32,
            decoder: DecoderConfig::paper_default(),
            decode_lanes: 10,
        }
    }
}

/// One line of the area/power breakdown.
#[derive(Clone, Debug)]
pub struct BreakdownItem {
    pub name: &'static str,
    /// Area of one instance, µm² @ 22 nm.
    pub unit_area_um2: f64,
    /// Power of one instance, mW.
    pub unit_power_mw: f64,
    pub count: usize,
}

impl BreakdownItem {
    /// Total area across instances.
    pub fn total_area_um2(&self) -> f64 {
        self.unit_area_um2 * self.count as f64
    }

    /// Total power across instances.
    pub fn total_power_mw(&self) -> f64 {
        self.unit_power_mw * self.count as f64
    }
}

/// The full breakdown (Table 4).
#[derive(Clone, Debug)]
pub struct AreaPower {
    pub items: Vec<BreakdownItem>,
}

impl AreaPower {
    /// Evaluate the model for a configuration.
    pub fn of(cfg: &LexiHwConfig) -> Self {
        let items = vec![
            BreakdownItem {
                name: "Local Cache",
                unit_area_um2: CACHE_AREA_PER_ENTRY_UM2 * cfg.cache_depth as f64,
                unit_power_mw: CACHE_POWER_PER_ENTRY_MW * cfg.cache_depth as f64,
                count: cfg.lanes,
            },
            BreakdownItem {
                name: "Global Hist. & Code Gen.",
                unit_area_um2: GLOBAL_HIST_AREA_UM2,
                unit_power_mw: GLOBAL_HIST_POWER_MW,
                count: 1,
            },
            BreakdownItem {
                name: "Enc. LUT",
                unit_area_um2: ENC_LUT_AREA_PER_ENTRY_UM2 * cfg.enc_lut_entries as f64,
                unit_power_mw: ENC_LUT_POWER_MW,
                count: cfg.lanes,
            },
            BreakdownItem {
                name: "Dec. LUT",
                unit_area_um2: decoder_area_um2(&cfg.decoder),
                unit_power_mw: decoder_area_um2(&cfg.decoder) * DEC_POWER_PER_UM2_MW,
                count: cfg.decode_lanes,
            },
        ];
        AreaPower { items }
    }

    /// Total area @ 22 nm, µm².
    pub fn total_area_um2(&self) -> f64 {
        self.items.iter().map(|i| i.total_area_um2()).sum()
    }

    /// Total power, mW.
    pub fn total_power_mw(&self) -> f64 {
        self.items.iter().map(|i| i.total_power_mw()).sum()
    }

    /// Total area scaled to 16 nm, µm².
    pub fn total_area_16nm_um2(&self) -> f64 {
        self.total_area_um2() * SCALE_22_TO_16
    }

    /// Percent of a Simba chiplet occupied at 16 nm.
    pub fn chiplet_overhead_pct(&self) -> f64 {
        self.total_area_16nm_um2() / (SIMBA_CHIPLET_MM2 * 1e6) * 100.0
    }
}

/// Decoder area for any stage configuration (CAM-bit model).
pub fn decoder_area_um2(cfg: &DecoderConfig) -> f64 {
    let cam_bits: f64 = cfg
        .stage_shapes()
        .iter()
        .map(|&(bits, entries)| bits as f64 * entries as f64)
        .sum();
    let entries: f64 = cfg
        .stage_shapes()
        .iter()
        .map(|&(_, e)| e as f64)
        .sum();
    cam_bits * DEC_AREA_PER_CAM_BIT_UM2 + entries * DEC_AREA_PER_ENTRY_PAYLOAD_UM2
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64, tol_pct: f64) -> bool {
        (a - b).abs() <= b.abs() * tol_pct / 100.0
    }

    #[test]
    fn paper_component_areas() {
        let bp = AreaPower::of(&LexiHwConfig::paper_default());
        let by_name = |n: &str| {
            bp.items
                .iter()
                .find(|i| i.name == n)
                .expect("component present")
        };
        assert!(close(by_name("Local Cache").unit_area_um2, 9.85, 1.0));
        assert!(close(
            by_name("Global Hist. & Code Gen.").unit_area_um2,
            13113.0,
            0.1
        ));
        assert!(close(by_name("Enc. LUT").unit_area_um2, 79.87, 1.0));
        assert!(close(by_name("Dec. LUT").unit_area_um2, 98.5, 2.0));
    }

    #[test]
    fn paper_totals() {
        let bp = AreaPower::of(&LexiHwConfig::paper_default());
        assert!(
            close(bp.total_area_um2(), 14995.2, 1.0),
            "area {}",
            bp.total_area_um2()
        );
        assert!(
            close(bp.total_power_mw(), 45.43, 2.0),
            "power {}",
            bp.total_power_mw()
        );
        assert!(
            close(bp.total_area_16nm_um2(), 5452.8, 1.0),
            "16nm {}",
            bp.total_area_16nm_um2()
        );
        assert!(
            close(bp.chiplet_overhead_pct(), 0.09, 5.0),
            "overhead {}",
            bp.chiplet_overhead_pct()
        );
    }

    #[test]
    fn monolithic_decoder_matches_fig6_point() {
        let a = decoder_area_um2(&DecoderConfig::monolithic());
        assert!(close(a, 157.6, 2.0), "area {a}");
    }

    #[test]
    fn area_monotone_in_knobs() {
        let base = AreaPower::of(&LexiHwConfig::paper_default()).total_area_um2();
        let mut wide = LexiHwConfig::paper_default();
        wide.lanes = 20;
        wide.cache_depth = 16;
        assert!(AreaPower::of(&wide).total_area_um2() > base);
    }
}
