//! The assembled LEXI egress pipeline (paper §4.2–§4.3): histogram window
//! → codebook pipeline → streaming encode, with the paper's overlap model
//! (all stages pipeline behind the data stream; the startup cost is paid
//! once per layer).

use crate::encoder::EncoderUnit;
use crate::histogram_unit::{HistConfig, HistogramUnit};
use crate::tree_builder::{self, TreeReport};
use lexi_core::huffman::CodeBook;
use lexi_core::Result;

/// Number of leading activations sampled to build the codebook (paper:
/// "We initiate tree generation with the first 512 activations").
pub const SAMPLE_WINDOW: usize = 512;

/// Full compressor configuration.
#[derive(Clone, Debug)]
pub struct CompressorConfig {
    pub hist: HistConfig,
    /// Alphabet cap for the encode LUTs.
    pub max_symbols: usize,
    /// Sample window for tree generation.
    pub sample_window: usize,
}

impl CompressorConfig {
    /// The paper's chosen design point.
    pub fn paper_default() -> Self {
        CompressorConfig {
            hist: HistConfig::paper_default(),
            max_symbols: 32,
            sample_window: SAMPLE_WINDOW,
        }
    }
}

/// Cycle/size report for compressing one layer's exponent stream.
#[derive(Clone, Debug)]
pub struct CompressReport {
    /// Histogram-phase cycles (ingest + drain of the sample window).
    pub histogram_cycles: u64,
    /// Codebook pipeline cycles (sort + merge + program).
    pub tree_cycles: u64,
    /// Streaming-encode cycles for the whole stream (⌈n/lanes⌉).
    pub encode_cycles: u64,
    /// One-time startup latency before the first codeword can leave.
    pub startup_cycles: u64,
    /// End-to-end cycles with pipelining (startup + encode).
    pub total_cycles: u64,
    /// Compressed payload bits (excluding codebook header).
    pub payload_bits: u64,
    /// Codebook header bits piggybacked on the stream.
    pub header_bits: u64,
    /// Exponents compressed.
    pub count: u64,
    /// Sample-window lane hit rate.
    pub hit_rate: f64,
    /// Escape-coded symbols (rare-exponent fallback).
    pub escapes: u64,
}

impl CompressReport {
    /// Exponent-stream compression ratio, header included.
    pub fn ratio(&self) -> f64 {
        (self.count * 8) as f64 / (self.payload_bits + self.header_bits) as f64
    }

    /// Effective exponents per cycle (line-rate check).
    pub fn throughput(&self) -> f64 {
        self.count as f64 / self.total_cycles as f64
    }
}

/// The assembled compressor.
pub struct Compressor {
    cfg: CompressorConfig,
}

impl Compressor {
    /// Build from a configuration.
    pub fn new(cfg: CompressorConfig) -> Self {
        Compressor { cfg }
    }

    /// Compress one layer's exponent stream. Returns the codebook, the
    /// payload bytes (bit-exact with `lexi-core`), and the cycle report.
    pub fn compress(&self, exponents: &[u8]) -> Result<(CodeBook, Vec<u8>, CompressReport)> {
        let window = exponents.len().min(self.cfg.sample_window);
        // Phase 1: histogram over the sample window through the M lanes.
        let hist_unit = HistogramUnit::new(self.cfg.hist);
        let hist_report = hist_unit.run(&exponents[..window]);

        // Phase 2: codebook generation (bitonic sort → merge → program).
        let tree: TreeReport = tree_builder::build_codebook(&hist_report.histogram, self.cfg.max_symbols)?;

        // Phase 3: stream encode through the M lane LUTs. The sample
        // window is buffered during phases 1–2 and drained first (the
        // paper's non-blocking pipeline), so every exponent flows through
        // the encoder exactly once.
        let encoder = EncoderUnit::new(self.cfg.hist.lanes);
        let (payload, enc_report) = encoder.encode(exponents, &tree.book);

        let startup = hist_report.cycles + tree.total_cycles();
        let report = CompressReport {
            histogram_cycles: hist_report.cycles,
            tree_cycles: tree.total_cycles(),
            encode_cycles: enc_report.cycles,
            startup_cycles: startup,
            total_cycles: startup + enc_report.cycles,
            payload_bits: enc_report.bits,
            header_bits: tree.book.header_bits(),
            count: exponents.len() as u64,
            hit_rate: hist_report.hit_rate,
            escapes: enc_report.escapes,
        };
        Ok((tree.book, payload, report))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use lexi_core::bitstream::BitReader;
    use lexi_core::prng::Rng;
    use lexi_core::proptest::check;
    use lexi_core::Bf16;

    fn gaussian_exponents(n: usize, sigma: f64, seed: u64) -> Vec<u8> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| Bf16::from_f32(rng.normal_with(0.0, sigma) as f32).exponent())
            .collect()
    }

    #[test]
    fn startup_is_amortized() {
        // §4.3: the 78-cycle-class startup is negligible against ~2M
        // activations per layer; throughput approaches `lanes`/cycle.
        let data = gaussian_exponents(200_000, 0.02, 5);
        let comp = Compressor::new(CompressorConfig::paper_default());
        let (_, _, report) = comp.compress(&data).unwrap();
        assert!(report.throughput() > 9.5, "throughput {}", report.throughput());
        assert!(report.startup_cycles < 200, "startup {}", report.startup_cycles);
    }

    #[test]
    fn compresses_gaussian_to_paper_band() {
        let data = gaussian_exponents(100_000, 0.02, 9);
        let comp = Compressor::new(CompressorConfig::paper_default());
        let (_, _, report) = comp.compress(&data).unwrap();
        let cr = report.ratio();
        assert!((2.2..4.5).contains(&cr), "CR {cr}");
    }

    #[test]
    fn stale_window_codebook_remains_lossless() {
        check("compressor lossless with 512-window book", 40, |g| {
            let n = g.usize(600..5000);
            let data = g.vec(n, |g| {
                if g.bool(0.9) {
                    120 + (g.usize(0..8) as u8)
                } else {
                    g.u8() // rare outliers → escapes
                }
            });
            let comp = Compressor::new(CompressorConfig::paper_default());
            let (book, payload, report) = comp.compress(&data).unwrap();
            let mut r = BitReader::with_len(&payload, report.payload_bits as usize);
            let dec = book.decoder();
            let out: Vec<u8> = (0..data.len())
                .map(|_| dec.decode(&mut r).unwrap())
                .collect();
            assert_eq!(out, data);
        });
    }

    #[test]
    fn short_streams_work() {
        // Streams shorter than the sample window.
        let data = gaussian_exponents(17, 0.02, 3);
        let comp = Compressor::new(CompressorConfig::paper_default());
        let (book, payload, report) = comp.compress(&data).unwrap();
        let mut r = BitReader::with_len(&payload, report.payload_bits as usize);
        let dec = book.decoder();
        let out: Vec<u8> = (0..17).map(|_| dec.decode(&mut r).unwrap()).collect();
        assert_eq!(out, data);
    }
}
