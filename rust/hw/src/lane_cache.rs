//! Per-lane local frequency cache (paper §4.2.1).
//!
//! Each of the M histogram lanes owns a small fully-associative cache of
//! `{exponent, count}` entries. A hit increments the local count in one
//! cycle; a miss evicts the **oldest** entry (FIFO, as the paper specifies:
//! "the oldest exponent is evicted") to the global histogram and installs
//! the new exponent with count 1.

/// One cache entry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Entry {
    pub exponent: u8,
    pub count: u32,
    /// Insertion order stamp for FIFO eviction.
    pub inserted_at: u64,
}

/// Result of presenting one exponent to the cache.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Access {
    /// Count incremented locally.
    Hit,
    /// Cache had a free slot; installed without eviction.
    MissInstalled,
    /// Evicted `(exponent, count)` to make room.
    MissEvicted(u8, u32),
}

/// Aggregate pressure counters for one lane cache (ISSUE 9): how hard
/// a shared cache is being worked by competing exponent streams. The
/// serving simulator samples these under multi-tenant codebook churn.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PressureStats {
    pub hits: u64,
    pub misses: u64,
    /// Misses that displaced a resident entry (capacity pressure), as
    /// opposed to cold-start installs into a free slot.
    pub evictions: u64,
    /// Entries currently resident.
    pub occupancy: usize,
    /// Configured depth.
    pub depth: usize,
}

impl PressureStats {
    /// Evicting misses as a share of all accesses — 0.0 while the
    /// working set fits, climbing toward the miss rate when every miss
    /// displaces a live entry.
    pub fn eviction_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.evictions as f64 / total as f64
        }
    }
}

/// A single lane's local frequency cache.
#[derive(Clone, Debug)]
pub struct LaneCache {
    entries: Vec<Entry>,
    depth: usize,
    next_stamp: u64,
    pub hits: u64,
    pub misses: u64,
    /// Misses that evicted a resident entry (subset of `misses`).
    pub evictions: u64,
}

impl LaneCache {
    /// A cache with `depth` entries (paper sweeps 1..32, selects 8).
    pub fn new(depth: usize) -> Self {
        assert!(depth >= 1, "cache needs at least one entry");
        LaneCache {
            entries: Vec::with_capacity(depth),
            depth,
            next_stamp: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    /// Present one exponent; returns what happened.
    pub fn access(&mut self, exponent: u8) -> Access {
        if let Some(e) = self.entries.iter_mut().find(|e| e.exponent == exponent) {
            e.count += 1;
            self.hits += 1;
            return Access::Hit;
        }
        self.misses += 1;
        let stamp = self.next_stamp;
        self.next_stamp += 1;
        if self.entries.len() < self.depth {
            self.entries.push(Entry {
                exponent,
                count: 1,
                inserted_at: stamp,
            });
            return Access::MissInstalled;
        }
        // FIFO: evict the oldest insertion.
        let idx = self
            .entries
            .iter()
            .enumerate()
            .min_by_key(|(_, e)| e.inserted_at)
            .map(|(i, _)| i)
            .expect("cache non-empty");
        let victim = self.entries[idx];
        self.entries[idx] = Entry {
            exponent,
            count: 1,
            inserted_at: stamp,
        };
        self.evictions += 1;
        Access::MissEvicted(victim.exponent, victim.count)
    }

    /// Snapshot the pressure counters (ISSUE 9).
    pub fn pressure(&self) -> PressureStats {
        PressureStats {
            hits: self.hits,
            misses: self.misses,
            evictions: self.evictions,
            occupancy: self.entries.len(),
            depth: self.depth,
        }
    }

    /// Drain all resident entries (end of histogram phase): every entry
    /// must be flushed to the global histogram.
    pub fn drain(&mut self) -> Vec<(u8, u32)> {
        let out = self.entries.iter().map(|e| (e.exponent, e.count)).collect();
        self.entries.clear();
        out
    }

    /// Hit rate so far.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Entries currently resident.
    pub fn occupancy(&self) -> usize {
        self.entries.len()
    }

    /// Configured depth.
    pub fn depth(&self) -> usize {
        self.depth
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use lexi_core::proptest::check;

    #[test]
    fn hit_increments() {
        let mut c = LaneCache::new(4);
        assert_eq!(c.access(10), Access::MissInstalled);
        assert_eq!(c.access(10), Access::Hit);
        assert_eq!(c.access(10), Access::Hit);
        assert_eq!(c.drain(), vec![(10, 3)]);
    }

    #[test]
    fn fifo_eviction_order() {
        let mut c = LaneCache::new(2);
        c.access(1);
        c.access(2);
        // 3 evicts 1 (oldest), not 2.
        assert_eq!(c.access(3), Access::MissEvicted(1, 1));
        // 4 evicts 2.
        assert_eq!(c.access(4), Access::MissEvicted(2, 1));
    }

    #[test]
    fn hit_does_not_refresh_fifo_age() {
        let mut c = LaneCache::new(2);
        c.access(1);
        c.access(2);
        c.access(1); // hit — FIFO age unchanged
        assert_eq!(c.access(3), Access::MissEvicted(1, 2));
    }

    #[test]
    fn skewed_stream_depth8_exceeds_90pct() {
        // Fig 4: 8-entry caches achieve >90% hit rate on exponent streams.
        check("depth-8 hit rate", 30, |g| {
            let data = g.skewed_bytes(4000, 12);
            let mut c = LaneCache::new(8);
            for &e in &data {
                c.access(e);
            }
            assert!(c.hit_rate() > 0.85, "hit rate {}", c.hit_rate());
        });
    }

    #[test]
    fn pressure_counts_evicting_misses_separately() {
        let mut c = LaneCache::new(2);
        c.access(1); // cold install
        c.access(2); // cold install
        c.access(1); // hit
        c.access(3); // evicting miss
        c.access(3); // hit
        let p = c.pressure();
        assert_eq!(
            p,
            PressureStats {
                hits: 2,
                misses: 3,
                evictions: 1,
                occupancy: 2,
                depth: 2,
            }
        );
        assert!((p.eviction_rate() - 0.2).abs() < 1e-12);
        assert!(p.evictions <= p.misses, "evictions are a subset of misses");
        // Drain flushes entries but keeps lifetime counters.
        c.drain();
        assert_eq!(c.pressure().occupancy, 0);
        assert_eq!(c.pressure().evictions, 1);
    }

    #[test]
    fn prop_counts_conserved() {
        // Σ(evicted counts) + Σ(drained counts) == number of accesses.
        check("lane cache conserves counts", 100, |g| {
            let depth = g.usize(1..16);
            let n = g.usize(1..2000);
            let data = g.vec(n, |g| g.u8());
            let mut c = LaneCache::new(depth);
            let mut total: u64 = 0;
            for &e in &data {
                if let Access::MissEvicted(_, cnt) = c.access(e) {
                    total += cnt as u64;
                }
            }
            total += c.drain().iter().map(|&(_, c)| c as u64).sum::<u64>();
            assert_eq!(total, n as u64);
        });
    }

    #[test]
    fn prop_per_symbol_counts_exact() {
        check("lane cache per-symbol histogram exact", 50, |g| {
            let a = g.usize(1..20);
            let n = g.usize(1..1500).max(1);
            let data = g.skewed_bytes(n, a);
            let mut c = LaneCache::new(g.usize(1..10));
            let mut hist = [0u64; 256];
            for &e in &data {
                if let Access::MissEvicted(sym, cnt) = c.access(e) {
                    hist[sym as usize] += cnt as u64;
                }
            }
            for (sym, cnt) in c.drain() {
                hist[sym as usize] += cnt as u64;
            }
            let mut expect = [0u64; 256];
            for &e in &data {
                expect[e as usize] += 1;
            }
            assert_eq!(hist, expect);
        });
    }
}
