//! Global-histogram port arbiter (paper §4.2.1).
//!
//! The global histogram is single-ported; lane evictions compete for it.
//! "The arbiter grants exclusive use to the first arriving request for a
//! fixed duration of three cycles before release." Requests arriving while
//! a grant is active queue FIFO (ties within a cycle resolved by lane id).

/// Grant duration in cycles (paper-fixed).
pub const GRANT_CYCLES: u64 = 3;

/// A cycle-stepped arbiter over `n_lanes` requesters.
#[derive(Clone, Debug)]
pub struct Arbiter {
    /// FIFO of waiting lane ids.
    queue: std::collections::VecDeque<usize>,
    /// Lane currently holding the grant, and the cycle it expires.
    active: Option<(usize, u64)>,
    /// Whether each lane already has a pending request (dedup).
    pending: Vec<bool>,
    /// Stats.
    pub grants: u64,
    pub wait_cycles: u64,
}

impl Arbiter {
    /// New arbiter for `n_lanes` requesters.
    pub fn new(n_lanes: usize) -> Self {
        Arbiter {
            queue: std::collections::VecDeque::new(),
            active: None,
            pending: vec![false; n_lanes],
            grants: 0,
            wait_cycles: 0,
        }
    }

    /// Lane `lane` raises a request at cycle `now`. Idempotent while the
    /// lane already waits.
    pub fn request(&mut self, lane: usize, _now: u64) {
        if !self.pending[lane] {
            self.pending[lane] = true;
            self.queue.push_back(lane);
        }
    }

    /// Advance to cycle `now`; returns the lane granted *this* cycle, if
    /// any. A grant lasts [`GRANT_CYCLES`]; the port is busy meanwhile.
    pub fn step(&mut self, now: u64) -> Option<usize> {
        if let Some((_, expires)) = self.active {
            if now < expires {
                self.wait_cycles += self.queue.len() as u64;
                return None;
            }
            self.active = None;
        }
        if let Some(lane) = self.queue.pop_front() {
            self.pending[lane] = false;
            self.active = Some((lane, now + GRANT_CYCLES));
            self.grants += 1;
            self.wait_cycles += self.queue.len() as u64;
            return Some(lane);
        }
        None
    }

    /// Is the port currently granted?
    pub fn busy(&self, now: u64) -> bool {
        matches!(self.active, Some((_, expires)) if now < expires)
    }

    /// Lanes currently queued.
    pub fn backlog(&self) -> usize {
        self.queue.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_request_granted_immediately() {
        let mut a = Arbiter::new(4);
        a.request(2, 0);
        assert_eq!(a.step(0), Some(2));
        assert!(a.busy(0));
        assert!(a.busy(2));
        assert!(!a.busy(3));
    }

    #[test]
    fn grant_is_exclusive_for_three_cycles() {
        let mut a = Arbiter::new(4);
        a.request(0, 0);
        a.request(1, 0);
        assert_eq!(a.step(0), Some(0));
        assert_eq!(a.step(1), None);
        assert_eq!(a.step(2), None);
        // Cycle 3: lane 0's grant expired; lane 1 gets the port.
        assert_eq!(a.step(3), Some(1));
    }

    #[test]
    fn fifo_order() {
        let mut a = Arbiter::new(8);
        for lane in [5, 1, 7] {
            a.request(lane, 0);
        }
        let mut order = Vec::new();
        let mut now = 0;
        while order.len() < 3 {
            if let Some(l) = a.step(now) {
                order.push(l);
            }
            now += 1;
        }
        assert_eq!(order, vec![5, 1, 7]);
    }

    #[test]
    fn duplicate_requests_dedup() {
        let mut a = Arbiter::new(2);
        a.request(0, 0);
        a.request(0, 0);
        assert_eq!(a.backlog(), 1);
    }

    #[test]
    fn throughput_is_one_grant_per_three_cycles() {
        let mut a = Arbiter::new(16);
        for lane in 0..16 {
            a.request(lane, 0);
        }
        let mut grants = 0;
        for now in 0..48 {
            if a.step(now).is_some() {
                grants += 1;
            }
        }
        assert_eq!(grants, 16);
    }
}
