//! Multi-stage LUT decompression circuit (paper §4.4, Fig. 3b).
//!
//! A naive single LUT indexed by the maximum code length is fast but
//! area-hungry; LEXI segments the codebook by code length across stages
//! with increasing prefix windows (8/16/24/32 bits in the chosen design).
//! Stage k holds up to 8 **length-class** entries `{len, first_code,
//! base_index}` — canonical decoding needs only one entry per code length,
//! and each stage covers 8 lengths, so capacity is exact.
//!
//! A symbol whose codeword (plus raw escape byte, for ESC) fits in the
//! stage-k window resolves in k cycles; short high-frequency codes resolve
//! in stage 1 at line rate. Multiple decode lanes take whole flits
//! round-robin (flit-atomic packing makes them independent).

use lexi_core::batch::{LaneDecoders, LaneStream, LaneView};
use lexi_core::bitstream::BitReader;
use lexi_core::error::{Error, Result};
use lexi_core::huffman::{CanonicalDecoder, CodeBook};
use lexi_core::lut::{MultiDecodeTable, LUT_BITS, LUT_MAX_SYMS};
use lexi_core::pool;

/// A multi-stage decoder configuration.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DecoderConfig {
    /// Cumulative prefix window per stage, strictly increasing (bits).
    pub stage_bits: Vec<u32>,
    /// Length-class entries available per stage.
    pub entries_per_stage: u32,
}

impl DecoderConfig {
    /// The paper's chosen 4-stage design: 8/16/24/32-bit windows, 8
    /// entries per stage.
    pub fn paper_default() -> Self {
        DecoderConfig {
            stage_bits: vec![8, 16, 24, 32],
            entries_per_stage: 8,
        }
    }

    /// The monolithic comparison point: one 32-bit window holding every
    /// length class (Fig. 6's "single 32-bit LUT").
    pub fn monolithic() -> Self {
        DecoderConfig {
            stage_bits: vec![32],
            entries_per_stage: 32,
        }
    }

    /// Validate the config itself.
    pub fn validate(&self) -> Result<()> {
        if self.stage_bits.is_empty() {
            return Err(Error::InvalidParameter("no stages".into()));
        }
        if !self.stage_bits.windows(2).all(|w| w[0] < w[1]) {
            return Err(Error::InvalidParameter(
                "stage windows must strictly increase".into(),
            ));
        }
        if *self.stage_bits.last().expect("non-empty") > 32 {
            return Err(Error::InvalidParameter("windows beyond 32 bits".into()));
        }
        Ok(())
    }

    /// The stage (1-based) that resolves a consumed bit-length, or None if
    /// it exceeds the last window.
    #[inline]
    pub fn stage_of(&self, bits: u32) -> Option<u32> {
        self.stage_bits
            .iter()
            .position(|&b| b >= bits)
            .map(|k| k as u32 + 1)
    }

    /// Check that `book` (including its escape + raw byte) is decodable
    /// and that no stage exceeds its entry capacity.
    pub fn supports(&self, book: &CodeBook) -> Result<()> {
        self.validate()?;
        let worst = book.escape().len + 8;
        if self.stage_of(worst).is_none() {
            return Err(Error::InvalidParameter(format!(
                "escape path needs {worst} bits > last window"
            )));
        }
        // Count length classes per stage.
        let mut classes: Vec<std::collections::BTreeSet<u32>> =
            vec![Default::default(); self.stage_bits.len()];
        for &(_, len) in book.canonical_pairs() {
            let stage = self
                .stage_of(len)
                .ok_or_else(|| Error::InvalidParameter(format!("code length {len} too long")))?;
            classes[stage as usize - 1].insert(len);
        }
        for (k, set) in classes.iter().enumerate() {
            if set.len() as u32 > self.entries_per_stage {
                return Err(Error::InvalidParameter(format!(
                    "stage {} needs {} length classes > capacity {}",
                    k + 1,
                    set.len(),
                    self.entries_per_stage
                )));
            }
        }
        Ok(())
    }

    /// Per-stage (window_bits, entries) — input to the area model.
    pub fn stage_shapes(&self) -> Vec<(u32, u32)> {
        self.stage_bits
            .iter()
            .map(|&b| (b, self.entries_per_stage))
            .collect()
    }
}

/// Cycle report for decoding one stream.
#[derive(Clone, Debug, Default)]
pub struct DecodeReport {
    /// Total decode cycles (Σ per-symbol stage latency).
    pub cycles: u64,
    /// Symbols resolved per stage (index 0 = stage 1).
    pub per_stage: Vec<u64>,
    /// Symbols decoded.
    pub symbols: u64,
}

impl DecodeReport {
    /// Average cycles per symbol.
    pub fn avg_latency(&self) -> f64 {
        if self.symbols == 0 {
            0.0
        } else {
            self.cycles as f64 / self.symbols as f64
        }
    }
}

/// Multi-symbol front-table parameters (ISSUE 4, paper §4.4): a direct
/// `2^LUT_BITS`-entry table in front of the length-class stages that
/// resolves a whole **group of up to [`LUT_MAX_SYMS`] codewords in one
/// cycle** — the hardware twin of `lexi-core`'s
/// [`MultiDecodeTable`]. Probes whose entry is a sentinel (ESC-leading,
/// long-code or partial patterns) fall through to the multi-stage walk
/// and pay its per-stage latency as before.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MultiLutSpec {
    /// Table entries written per cycle during the per-codebook fill
    /// (a 64-bit entry per probe; a 512-bit SRAM write port fills 64
    /// entries/cycle). Bounds the startup latency the sim charges.
    pub fill_entries_per_cycle: u32,
}

impl MultiLutSpec {
    /// Chosen design point: 2^11 × 64-bit entries (16 KiB) filled 64
    /// entries per cycle → 32-cycle fill, invisible next to the
    /// codebook pipeline's sampling window.
    pub fn paper_default() -> Self {
        MultiLutSpec {
            fill_entries_per_cycle: 64,
        }
    }

    /// Cycles to fill the table for one codebook (charged once per
    /// runtime-compressed transfer, alongside the codebook startup).
    pub fn fill_cycles(&self) -> u64 {
        MultiDecodeTable::fill_probes().div_ceil(self.fill_entries_per_cycle.max(1) as u64)
    }

    /// Probe window width (mirrors `lexi-core`'s table).
    pub fn lut_bits(&self) -> u32 {
        LUT_BITS
    }

    /// Maximum symbols a probe resolves per cycle.
    pub fn max_symbols_per_cycle(&self) -> usize {
        LUT_MAX_SYMS
    }
}

/// The multi-stage decoder unit.
pub struct DecoderUnit {
    cfg: DecoderConfig,
    /// Multi-symbol front table; `None` models the ISSUE 2 unit (one
    /// symbol per lane per cycle at best).
    multi: Option<MultiLutSpec>,
}

impl DecoderUnit {
    /// Build a decoder; errors if the config is invalid. No multi-symbol
    /// front table: each symbol pays its stage latency (legacy model).
    pub fn new(cfg: DecoderConfig) -> Result<Self> {
        cfg.validate()?;
        Ok(DecoderUnit { cfg, multi: None })
    }

    /// Build a decoder with the multi-symbol front table (ISSUE 4):
    /// grouped probes resolve in one cycle, sentinel probes fall back to
    /// the staged walk. The table is modeled on the lane path
    /// ([`DecoderUnit::decode_lane_stream`]); the single-stream
    /// [`DecoderUnit::decode`] keeps pure per-stage accounting (see its
    /// doc).
    pub fn with_multi(cfg: DecoderConfig, spec: MultiLutSpec) -> Result<Self> {
        cfg.validate()?;
        Ok(DecoderUnit {
            cfg,
            multi: Some(spec),
        })
    }

    /// The multi-symbol front-table spec, if enabled.
    pub fn multi(&self) -> Option<&MultiLutSpec> {
        self.multi.as_ref()
    }

    /// Nominal symbols per cycle per lane for `book`: the front table's
    /// average fill (uniform-probe mean, sentinels as 1), or 1.0 for the
    /// legacy unit. Builds a table to measure it — a per-book startup
    /// cost, not a per-symbol one.
    pub fn symbols_per_cycle(&self, book: &CodeBook) -> f64 {
        match &self.multi {
            Some(_) => MultiDecodeTable::new(book).avg_fill(),
            None => 1.0,
        }
    }

    /// Nominal decoder **cycles per symbol** per lane for `book` — the
    /// reciprocal of [`DecoderUnit::symbols_per_cycle`]. This is the rate
    /// a `lexi-noc` egress codec port (ISSUE 5) drains tagged flits at;
    /// always > 0 (the front table's average fill is ≥ 1, a legacy unit
    /// reads exactly 1.0).
    pub fn cycles_per_symbol(&self, book: &CodeBook) -> f64 {
        1.0 / self.symbols_per_cycle(book)
    }

    /// Decode `count` exponents from `r` using `book`, with cycle-accurate
    /// stage accounting. Bit-exact with `lexi-core`'s canonical decoder.
    ///
    /// This single-stream path always charges the **staged walk**, even
    /// on units built with [`DecoderUnit::with_multi`]: its
    /// [`DecodeReport::per_stage`] histogram is only meaningful for the
    /// multi-stage pipeline (Fig 6's sweep consumes it), whereas the
    /// front table bypasses the stages entirely. The multi-symbol cycle
    /// model lives on the lane path
    /// ([`DecoderUnit::decode_lane_stream`]), the surface the paper's
    /// link-rate argument — and the sim's makespans — are about.
    pub fn decode(
        &self,
        r: &mut BitReader,
        book: &CodeBook,
        count: usize,
    ) -> Result<(Vec<u8>, DecodeReport)> {
        self.cfg.supports(book)?;
        let dec = book.decoder();
        self.decode_with(&dec, r, count)
    }

    /// Inner decode loop over an already-built canonical decoder, so
    /// multi-lane callers validate and build tables once, not per lane.
    fn decode_with(
        &self,
        dec: &CanonicalDecoder,
        r: &mut BitReader,
        count: usize,
    ) -> Result<(Vec<u8>, DecodeReport)> {
        let mut out = Vec::with_capacity(count);
        let mut report = DecodeReport {
            per_stage: vec![0; self.cfg.stage_bits.len()],
            ..Default::default()
        };
        for _ in 0..count {
            let before = r.pos();
            let sym = dec.decode(r)?;
            let consumed = (r.pos() - before) as u32;
            let stage = self
                .cfg
                .stage_of(consumed)
                .ok_or(Error::InvalidCodeword { offset: before })?;
            report.cycles += stage as u64;
            report.per_stage[stage as usize - 1] += 1;
            report.symbols += 1;
            out.push(sym);
        }
        Ok((out, report))
    }

    /// Config accessor.
    pub fn config(&self) -> &DecoderConfig {
        &self.cfg
    }

    /// Decode an `N`-lane interleaved stream (paper §4.4) with a
    /// **lockstep cycle model**: lanes advance one symbol per round, and
    /// each round's latency is tracked as the *occupancy* of its slowest
    /// lane (the per-round `max` of stage latencies), not as independent
    /// per-lane sums. The report carries both views:
    ///
    /// * [`LaneDecodeReport::makespan`] — slowest lane's summed cycles:
    ///   completion time when the `N` lanes run fully independently (each
    ///   with its own window registers and scheduler).
    /// * [`LaneDecodeReport::lockstep_cycles`] — Σ over rounds of the
    ///   round's slowest stage: completion time for a lockstep
    ///   implementation whose lanes share one round scheduler, the
    ///   structure `LaneCodec::decode_lockstep` mirrors in software.
    ///
    /// Embedded per-lane codebooks (v2 streams) take precedence over the
    /// `book` argument; every book in use must satisfy
    /// [`DecoderConfig::supports`]. Bit-exact with `LaneCodec::decode`
    /// and `LaneCodec::decode_lockstep`.
    ///
    /// Units built via [`DecoderUnit::with_multi`] (ISSUE 4) front every
    /// lane with the multi-symbol LUT: a visit that hits a full-fit
    /// entry emits its whole codeword group for **one** cycle; sentinel
    /// probes fall back to the staged walk. Symbols and errors are
    /// unchanged — only the cycle accounting (and thus makespans) drops.
    pub fn decode_lane_stream(
        &self,
        stream: &LaneStream,
        book: &CodeBook,
    ) -> Result<(Vec<u8>, LaneDecodeReport)> {
        let (views, decs) = self.lane_setup(stream, book)?;
        let n = stream.lanes;
        let dec_by_lane = decs.by_lane(n);
        let results: Vec<LaneKernelResult> = (0..n)
            .map(|l| self.decode_lane_kernel(dec_by_lane[l], stream, &views[l]))
            .collect();
        Self::combine_lane_results(stream, results)
    }

    /// Lane-parallel [`decode_lane_stream`] (ISSUE 8): each lane's
    /// kernel replay runs on its own shard of the dependency-free
    /// [`pool`] — lanes are independent bitstreams, so the per-lane
    /// symbol/cost traces are identical to the sequential run, and the
    /// round-major recombination happens on the caller's thread.
    /// Deterministic and thread-count invariant: outputs, **every
    /// report field** (per-lane cycles, makespan, lockstep cycles), and
    /// the surfaced error all equal the sequential path's exactly
    /// (property-pinned below). This parallelizes the *software* model
    /// wall-clock only — the cycle numbers it reports are the same
    /// single-unit hardware model, never divided by `threads`
    /// (DESIGN.md §SIMD & sharded parallelism).
    ///
    /// [`decode_lane_stream`]: DecoderUnit::decode_lane_stream
    /// [`pool`]: lexi_core::pool
    pub fn decode_lane_stream_par(
        &self,
        stream: &LaneStream,
        book: &CodeBook,
        threads: usize,
    ) -> Result<(Vec<u8>, LaneDecodeReport)> {
        let (views, decs) = self.lane_setup(stream, book)?;
        let n = stream.lanes;
        let dec_by_lane = decs.by_lane(n);
        let results: Vec<LaneKernelResult> = pool::run_sharded(n, threads, |l| {
            self.decode_lane_kernel(dec_by_lane[l], stream, &views[l])
        });
        Self::combine_lane_results(stream, results)
    }

    /// Shared lane-path setup: format validation (one source of truth
    /// with `LaneCodec::decode` — `validated_lanes`), config support for
    /// every book in play, and decoder-table construction. Book
    /// precedence + per-lane indexing live in lexi-core's
    /// [`LaneDecoders`]; a multi unit asks for LUT-carrying decoders, so
    /// the front tables inherit exactly the same precedence rule.
    fn lane_setup(
        &self,
        stream: &LaneStream,
        book: &CodeBook,
    ) -> Result<(Vec<LaneView>, LaneDecoders)> {
        let views = stream.validated_lanes()?;
        if stream.books.is_empty() {
            self.cfg.supports(book)?;
        } else {
            for b in &stream.books {
                self.cfg.supports(b)?;
            }
        }
        let decs = if self.multi.is_some() {
            LaneDecoders::for_stream_lut(stream, book)
        } else {
            LaneDecoders::for_stream(stream, book)
        };
        Ok((views, decs))
    }

    /// Replay one lane to completion: decoded symbols (lane-local order)
    /// plus the per-visit cycle cost trace. Visit `k` of a lane is
    /// exactly round `k` of the round-major loop (every unfinished lane
    /// is visited once per round), so the trace is all the recombiner
    /// needs to rebuild round maxima. Errors carry the failing **visit
    /// index** so the recombiner can reconstruct which failure the
    /// round-major order surfaces first.
    ///
    /// Multi-symbol front tables (ISSUE 4), when the unit has them: a
    /// probe that resolves a full-fit codeword group costs one cycle;
    /// sentinel probes fall back to the staged walk and pay its latency.
    /// With no front table every visit takes the fallback arm, which IS
    /// the legacy one-symbol-per-round model.
    fn decode_lane_kernel(
        &self,
        dec: &CanonicalDecoder,
        stream: &LaneStream,
        view: &LaneView,
    ) -> LaneKernelResult {
        let mut r = BitReader::with_len(&stream.bytes[view.range.clone()], view.bits as usize);
        let mut lane_out = vec![0u8; view.symbols];
        let mut costs: Vec<u64> = Vec::with_capacity(view.symbols);
        let mut done = 0usize;
        while done < view.symbols {
            let want = view.symbols - done;
            let grouped = dec.multi_table().and_then(|table| {
                let e = table.entry_at(r.peek_zeroext(LUT_BITS) as usize);
                let c = MultiDecodeTable::count(e) as usize;
                let used = MultiDecodeTable::consumed(e);
                (c != 0 && c <= want && used as usize <= r.remaining())
                    .then_some((e, c, used))
            });
            let cost = match grouped {
                Some((e, c, used)) => {
                    for k in 0..c {
                        lane_out[done + k] = MultiDecodeTable::symbol(e, k as u32);
                    }
                    r.skip(used).map_err(|e| (costs.len(), e))?;
                    done += c;
                    1 // one direct probe resolves the whole group
                }
                None => {
                    let before = r.pos();
                    let sym = dec.decode(&mut r).map_err(|e| (costs.len(), e))?;
                    let consumed = (r.pos() - before) as u32;
                    let stage = self
                        .cfg
                        .stage_of(consumed)
                        .ok_or((costs.len(), Error::InvalidCodeword { offset: before }))?
                        as u64;
                    lane_out[done] = sym;
                    done += 1;
                    stage
                }
            };
            costs.push(cost);
        }
        Ok((lane_out, costs))
    }

    /// Recombine per-lane kernel traces into the round-major report the
    /// lockstep cycle model defines: `per_lane_cycles[l] = Σ costs[l]`,
    /// `lockstep_cycles = Σ_k max_l costs[l][k]` (round `k`'s slowest
    /// visit), `makespan = max_l per_lane_cycles[l]`. The surfaced error
    /// is the failure with the smallest `(visit index, lane)` — the
    /// first one the sequential round-major loop would have hit.
    fn combine_lane_results(
        stream: &LaneStream,
        results: Vec<LaneKernelResult>,
    ) -> Result<(Vec<u8>, LaneDecodeReport)> {
        let mut first: Option<(usize, usize)> = None;
        for (l, res) in results.iter().enumerate() {
            if let Err((k, _)) = res {
                // Strict `<` keeps the lowest lane on visit-index ties —
                // lane order within a round.
                if first.map_or(true, |(fk, _)| *k < fk) {
                    first = Some((*k, l));
                }
            }
        }
        if let Some((_, fl)) = first {
            for (l, res) in results.into_iter().enumerate() {
                if l == fl {
                    let (_, e) = res.expect_err("failing lane recorded above");
                    return Err(e);
                }
            }
            unreachable!("failing lane index out of range");
        }
        let n = stream.lanes;
        let mut out = vec![0u8; stream.count];
        let mut per_lane_cycles = vec![0u64; n];
        let mut traces: Vec<Vec<u64>> = Vec::with_capacity(n);
        for (l, res) in results.into_iter().enumerate() {
            let (lane_out, costs) = res.expect("no lane failed");
            for (k, &sym) in lane_out.iter().enumerate() {
                out[l + k * n] = sym;
            }
            per_lane_cycles[l] = costs.iter().sum();
            traces.push(costs);
        }
        let rounds = traces.iter().map(Vec::len).max().unwrap_or(0);
        let mut lockstep_cycles = 0u64;
        for k in 0..rounds {
            let round_max = traces
                .iter()
                .filter_map(|t| t.get(k).copied())
                .max()
                .unwrap_or(0);
            lockstep_cycles += round_max;
        }
        let makespan = per_lane_cycles.iter().copied().max().unwrap_or(0);
        Ok((
            out,
            LaneDecodeReport {
                per_lane_cycles,
                makespan,
                lockstep_cycles,
                symbols: stream.count as u64,
            },
        ))
    }
}

/// One lane's kernel replay: `(lane-local symbols, per-visit costs)`, or
/// the failing `(visit index, error)`.
type LaneKernelResult = std::result::Result<(Vec<u8>, Vec<u64>), (usize, Error)>;

/// Cycle report for one multi-lane decode.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct LaneDecodeReport {
    /// Total stage-latency cycles per lane.
    pub per_lane_cycles: Vec<u64>,
    /// Slowest lane — the unit's completion time with fully independent
    /// parallel lanes.
    pub makespan: u64,
    /// Σ over rounds of the round's slowest stage — completion time for
    /// a lockstep implementation (lanes share one round scheduler).
    /// Always ≥ `makespan`; the gap is the cost of round synchronization.
    pub lockstep_cycles: u64,
    /// Symbols decoded across all lanes.
    pub symbols: u64,
}

impl LaneDecodeReport {
    /// Effective cycles per symbol with all lanes running independently.
    /// 0 for an empty stream (no division by a zero symbol count).
    pub fn effective_latency(&self) -> f64 {
        if self.symbols == 0 {
            0.0
        } else {
            self.makespan as f64 / self.symbols as f64
        }
    }

    /// Effective cycles per symbol under the lockstep round scheduler.
    /// 0 for an empty stream.
    pub fn lockstep_latency(&self) -> f64 {
        if self.symbols == 0 {
            0.0
        } else {
            self.lockstep_cycles as f64 / self.symbols as f64
        }
    }

    /// Speedup of the parallel-lane makespan over serializing every lane.
    /// 1.0 when the makespan is zero (empty or zero-cycle streams have
    /// nothing to speed up — guarded, no division by zero).
    pub fn lane_speedup(&self) -> f64 {
        let total: u64 = self.per_lane_cycles.iter().sum();
        if self.makespan == 0 {
            1.0
        } else {
            total as f64 / self.makespan as f64
        }
    }
}

/// L parallel decode lanes consuming independent units (flits) round-robin:
/// makespan = max over lanes of summed latencies. `lanes == 0` is clamped
/// to one (a degenerate caller gets the serial makespan, not a panic) and
/// an empty unit list yields 0.
pub fn parallel_makespan(per_unit_cycles: &[u64], lanes: usize) -> u64 {
    let lanes = lanes.max(1);
    let mut lane_time = vec![0u64; lanes];
    for (i, &c) in per_unit_cycles.iter().enumerate() {
        lane_time[i % lanes] += c;
    }
    lane_time.into_iter().max().unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use lexi_core::bitstream::BitWriter;
    use lexi_core::proptest::check;
    use lexi_core::stats::Histogram;

    fn encode(data: &[u8], book: &CodeBook) -> (Vec<u8>, usize) {
        let mut w = BitWriter::new();
        for &e in data {
            book.encode_symbol(e, &mut w);
        }
        let bits = w.len_bits();
        (w.into_bytes(), bits)
    }

    #[test]
    fn roundtrip_with_stage_accounting() {
        check("multistage decode roundtrip", 80, |g| {
            let n = g.usize(1..2000);
            let data = if g.bool(0.7) {
                let a = g.usize(1..40);
                g.skewed_bytes(n, a)
            } else {
                g.vec(n, |g| g.u8())
            };
            let hist = Histogram::from_bytes(&data);
            let book = CodeBook::lexi_default(&hist).unwrap();
            let (bytes, bits) = encode(&data, &book);
            let mut r = BitReader::with_len(&bytes, bits);
            let unit = DecoderUnit::new(DecoderConfig::paper_default()).unwrap();
            let (out, report) = unit.decode(&mut r, &book, data.len()).unwrap();
            assert_eq!(out, data);
            assert_eq!(report.symbols, data.len() as u64);
            assert_eq!(report.per_stage.iter().sum::<u64>(), data.len() as u64);
        });
    }

    #[test]
    fn skewed_streams_resolve_mostly_in_stage1() {
        // Fig 6: the 4-stage design averages ~1.16 cycles/symbol because
        // high-frequency codes are short.
        check("stage-1 dominance", 30, |g| {
            let data = g.skewed_bytes(4000, 10);
            let hist = Histogram::from_bytes(&data);
            let book = CodeBook::lexi_default(&hist).unwrap();
            let (bytes, bits) = encode(&data, &book);
            let mut r = BitReader::with_len(&bytes, bits);
            let unit = DecoderUnit::new(DecoderConfig::paper_default()).unwrap();
            let (_, report) = unit.decode(&mut r, &book, data.len()).unwrap();
            assert!(
                report.avg_latency() < 1.5,
                "avg latency {}",
                report.avg_latency()
            );
            assert!(report.per_stage[0] * 10 > report.symbols * 8);
        });
    }

    #[test]
    fn monolithic_is_single_cycle() {
        let data: Vec<u8> = (0..1000u32).map(|i| 120 + (i % 6) as u8).collect();
        let hist = Histogram::from_bytes(&data);
        let book = CodeBook::lexi_default(&hist).unwrap();
        let (bytes, bits) = encode(&data, &book);
        let mut r = BitReader::with_len(&bytes, bits);
        let unit = DecoderUnit::new(DecoderConfig::monolithic()).unwrap();
        let (out, report) = unit.decode(&mut r, &book, data.len()).unwrap();
        assert_eq!(out, data);
        assert_eq!(report.avg_latency(), 1.0);
    }

    #[test]
    fn invalid_configs_rejected() {
        assert!(DecoderConfig {
            stage_bits: vec![],
            entries_per_stage: 8
        }
        .validate()
        .is_err());
        assert!(DecoderConfig {
            stage_bits: vec![8, 8],
            entries_per_stage: 8
        }
        .validate()
        .is_err());
        assert!(DecoderConfig {
            stage_bits: vec![16, 40],
            entries_per_stage: 8
        }
        .validate()
        .is_err());
    }

    #[test]
    fn capacity_violation_detected() {
        // A 2-stage 16/32 config with only 4 entries/stage cannot hold
        // >4 length classes below 16 bits.
        let data: Vec<u8> = (0..200u32)
            .flat_map(|i| vec![(i % 20) as u8; (21 - i % 20) as usize])
            .collect();
        let hist = Histogram::from_bytes(&data);
        let book = CodeBook::lexi_default(&hist).unwrap();
        let cfg = DecoderConfig {
            stage_bits: vec![16, 32],
            entries_per_stage: 4,
        };
        // Depending on the histogram this book may have >4 classes ≤16.
        let classes: std::collections::BTreeSet<u32> = book
            .canonical_pairs()
            .iter()
            .map(|&(_, l)| l)
            .filter(|&l| l <= 16)
            .collect();
        if classes.len() > 4 {
            assert!(cfg.supports(&book).is_err());
        }
    }

    #[test]
    fn parallel_lanes_split_work() {
        let units = vec![10u64, 10, 10, 10, 10, 10, 10, 10, 10, 10];
        assert_eq!(parallel_makespan(&units, 1), 100);
        assert_eq!(parallel_makespan(&units, 10), 10);
        assert_eq!(parallel_makespan(&units, 3), 40);
    }

    #[test]
    fn parallel_makespan_degenerate_inputs() {
        // Guards (ISSUE 2 satellite): empty unit lists and a zero lane
        // count must not panic or divide by zero.
        assert_eq!(parallel_makespan(&[], 4), 0);
        assert_eq!(parallel_makespan(&[], 0), 0);
        assert_eq!(parallel_makespan(&[7, 3], 0), 10); // clamped to 1 lane
    }

    #[test]
    fn zero_and_single_symbol_lane_streams_report_safely() {
        use lexi_core::batch::LaneCodec;
        let book = {
            let data = vec![11u8, 11, 12, 13];
            let hist = Histogram::from_bytes(&data);
            CodeBook::lexi_default(&hist).unwrap()
        };
        let unit = DecoderUnit::new(DecoderConfig::paper_default()).unwrap();
        for lanes in [1usize, 4, 8] {
            // Zero symbols: all latencies and speedups are defined.
            let empty = LaneCodec::new(lanes).unwrap().encode(&[], &book);
            let (out, rep) = unit.decode_lane_stream(&empty, &book).unwrap();
            assert!(out.is_empty());
            assert_eq!(rep.symbols, 0);
            assert_eq!(rep.makespan, 0);
            assert_eq!(rep.lockstep_cycles, 0);
            assert_eq!(rep.effective_latency(), 0.0);
            assert_eq!(rep.lockstep_latency(), 0.0);
            assert_eq!(rep.lane_speedup(), 1.0);
            // One symbol: exactly one lane occupied for one stage.
            let one = LaneCodec::new(lanes).unwrap().encode(&[11], &book);
            let (out, rep) = unit.decode_lane_stream(&one, &book).unwrap();
            assert_eq!(out, vec![11]);
            assert_eq!(rep.symbols, 1);
            assert!(rep.makespan >= 1);
            assert_eq!(rep.lockstep_cycles, rep.makespan);
            assert!(rep.effective_latency() >= 1.0);
            assert!(rep.lane_speedup() >= 1.0);
        }
    }

    #[test]
    fn lockstep_cycles_bound_by_makespan_and_serial_sum() {
        // Round-max occupancy sits between the independent-lane makespan
        // and the fully serial sum, at every lane count.
        check("lockstep cycle bounds", 40, |g| {
            use lexi_core::batch::LaneCodec;
            let n = g.usize(1..3000);
            let a = g.usize(1..40);
            let data = g.skewed_bytes(n, a);
            let hist = Histogram::from_bytes(&data);
            let book = CodeBook::lexi_default(&hist).unwrap();
            let unit = DecoderUnit::new(DecoderConfig::paper_default()).unwrap();
            for lanes in [1usize, 2, 4, 8] {
                let stream = LaneCodec::new(lanes).unwrap().encode(&data, &book);
                let (_, rep) = unit.decode_lane_stream(&stream, &book).unwrap();
                let serial: u64 = rep.per_lane_cycles.iter().sum();
                assert!(
                    rep.makespan <= rep.lockstep_cycles,
                    "lanes {lanes}: makespan {} > lockstep {}",
                    rep.makespan,
                    rep.lockstep_cycles
                );
                assert!(
                    rep.lockstep_cycles <= serial,
                    "lanes {lanes}: lockstep {} > serial {serial}",
                    rep.lockstep_cycles
                );
                // With one lane the three collapse.
                if lanes == 1 {
                    assert_eq!(rep.lockstep_cycles, rep.makespan);
                    assert_eq!(rep.makespan, serial);
                }
            }
        });
    }

    #[test]
    fn per_lane_books_flow_through_hw_unit() {
        use lexi_core::batch::LaneCodec;
        // Two tenants with disjoint exponent ranges share a 2-lane link.
        let lanes = 2usize;
        let data: Vec<u8> = (0..600)
            .map(|i| if i % 2 == 0 { 40 + (i / 2 % 3) as u8 } else { 200 + (i / 2 % 5) as u8 })
            .collect();
        let books: Vec<CodeBook> = (0..lanes)
            .map(|l| {
                let lane_syms: Vec<u8> = data.iter().copied().skip(l).step_by(lanes).collect();
                let hist = Histogram::from_bytes(&lane_syms);
                CodeBook::lexi_default(&hist).unwrap()
            })
            .collect();
        let stream = LaneCodec::new(lanes)
            .unwrap()
            .encode_per_lane(&data, &books)
            .unwrap();
        let unit = DecoderUnit::new(DecoderConfig::paper_default()).unwrap();
        // The shared-book argument is ignored when books are embedded.
        let wrong = {
            let hist = Histogram::from_bytes(&[1u8, 2, 3]);
            CodeBook::lexi_default(&hist).unwrap()
        };
        let (out, rep) = unit.decode_lane_stream(&stream, &wrong).unwrap();
        assert_eq!(out, data);
        assert_eq!(rep.symbols, data.len() as u64);
        // And agrees with both software mirrors.
        assert_eq!(LaneCodec::decode(&stream, &wrong).unwrap(), data);
        assert_eq!(LaneCodec::decode_lockstep(&stream, &wrong).unwrap(), data);
    }

    #[test]
    fn lane_stream_decodes_bit_exactly_across_lane_counts() {
        use lexi_core::batch::LaneCodec;
        check("hw lane decode roundtrip", 40, |g| {
            let n = g.usize(1..2000);
            let data = if g.bool(0.7) {
                let a = g.usize(1..36);
                g.skewed_bytes(n, a)
            } else {
                g.vec(n, |g| g.u8())
            };
            let hist = Histogram::from_bytes(&data);
            let book = CodeBook::lexi_default(&hist).unwrap();
            let unit = DecoderUnit::new(DecoderConfig::paper_default()).unwrap();
            for lanes in [1usize, 2, 4, 8] {
                let stream = LaneCodec::new(lanes).unwrap().encode(&data, &book);
                let (out, report) = unit.decode_lane_stream(&stream, &book).unwrap();
                assert_eq!(out, data, "lanes {lanes}");
                assert_eq!(report.symbols, data.len() as u64);
                assert_eq!(report.per_lane_cycles.len(), lanes);
                assert_eq!(
                    report.makespan,
                    report.per_lane_cycles.iter().copied().max().unwrap()
                );
                // Software mirror agrees with the hw model's output.
                assert_eq!(LaneCodec::decode(&stream, &book).unwrap(), data);
            }
        });
    }

    #[test]
    fn multi_unit_is_bit_exact_and_never_slower() {
        use lexi_core::batch::LaneCodec;
        check("multi-symbol unit == legacy symbols, ≤ legacy cycles", 40, |g| {
            let n = g.usize(1..2500);
            let data = match g.usize(0..3) {
                0 => {
                    let a = g.usize(1..24);
                    g.skewed_bytes(n, a)
                }
                1 => {
                    let a = g.usize(33..140);
                    g.skewed_bytes(n, a)
                }
                _ => g.vec(n, |g| g.u8()),
            };
            let hist = Histogram::from_bytes(&data);
            let book = CodeBook::lexi_default(&hist).unwrap();
            let legacy = DecoderUnit::new(DecoderConfig::paper_default()).unwrap();
            let multi = DecoderUnit::with_multi(
                DecoderConfig::paper_default(),
                MultiLutSpec::paper_default(),
            )
            .unwrap();
            for lanes in [1usize, 2, 4, 8] {
                let stream = LaneCodec::new(lanes).unwrap().encode(&data, &book);
                let (a, ra) = legacy.decode_lane_stream(&stream, &book).unwrap();
                let (b, rb) = multi.decode_lane_stream(&stream, &book).unwrap();
                assert_eq!(a, data, "legacy lanes {lanes}");
                assert_eq!(b, data, "multi lanes {lanes}");
                assert_eq!(ra.symbols, rb.symbols);
                // Grouped probes cost 1 cycle for ≥ 1 symbols; fallback
                // costs are identical — the multi unit never loses.
                assert!(
                    rb.makespan <= ra.makespan,
                    "lanes {lanes}: multi makespan {} > legacy {}",
                    rb.makespan,
                    ra.makespan
                );
                // (lockstep_cycles carries no such guarantee: grouping
                // shifts fallback symbols to earlier rounds, which can
                // re-pair round maxima either way. The engine couples to
                // the makespan, which only improves.)
                // Occupancy invariants survive the grouped model.
                let serial: u64 = rb.per_lane_cycles.iter().sum();
                assert!(rb.makespan <= rb.lockstep_cycles);
                assert!(rb.lockstep_cycles <= serial);
            }
        });
    }

    #[test]
    fn multi_unit_beats_one_symbol_per_cycle_on_paper_streams() {
        // The whole point of the front table (paper §4.4): a < 3-bit
        // entropy stream decodes at > 1 symbol per lane-cycle, which the
        // ISSUE 2 unit could never do (every symbol cost ≥ 1 stage).
        let data: Vec<u8> = (0..20_000u32).map(|i| 124 + (i % 100 / 40) as u8).collect();
        let hist = Histogram::from_bytes(&data);
        let book = CodeBook::lexi_default(&hist).unwrap();
        let multi = DecoderUnit::with_multi(
            DecoderConfig::paper_default(),
            MultiLutSpec::paper_default(),
        )
        .unwrap();
        use lexi_core::batch::LaneCodec;
        let stream = LaneCodec::new(1).unwrap().encode(&data, &book);
        let (out, rep) = multi.decode_lane_stream(&stream, &book).unwrap();
        assert_eq!(out, data);
        let sym_per_cycle = rep.symbols as f64 / rep.makespan as f64;
        assert!(
            sym_per_cycle > 1.0,
            "multi unit only reached {sym_per_cycle:.2} symbols/cycle"
        );
        assert!(sym_per_cycle <= LUT_MAX_SYMS as f64);
        // And the nominal estimate agrees in direction.
        assert!(multi.symbols_per_cycle(&book) > 1.0);
        assert_eq!(
            DecoderUnit::new(DecoderConfig::paper_default())
                .unwrap()
                .symbols_per_cycle(&book),
            1.0
        );
        // The egress-port rate is the exact reciprocal (ISSUE 5): < 1
        // cycle/symbol on paper-entropy books, exactly 1.0 legacy.
        let cps = multi.cycles_per_symbol(&book);
        assert!(cps > 0.0 && cps < 1.0, "multi cps {cps}");
        assert!((cps * multi.symbols_per_cycle(&book) - 1.0).abs() < 1e-12);
        assert_eq!(
            DecoderUnit::new(DecoderConfig::paper_default())
                .unwrap()
                .cycles_per_symbol(&book),
            1.0
        );
    }

    #[test]
    fn multi_unit_handles_embedded_books() {
        use lexi_core::batch::LaneCodec;
        let lanes = 2usize;
        let data: Vec<u8> = (0..600)
            .map(|i| if i % 2 == 0 { 40 + (i / 2 % 3) as u8 } else { 200 + (i / 2 % 5) as u8 })
            .collect();
        let books: Vec<CodeBook> = (0..lanes)
            .map(|l| {
                let lane_syms: Vec<u8> = data.iter().copied().skip(l).step_by(lanes).collect();
                CodeBook::lexi_default(&Histogram::from_bytes(&lane_syms)).unwrap()
            })
            .collect();
        let stream = LaneCodec::new(lanes)
            .unwrap()
            .encode_per_lane(&data, &books)
            .unwrap();
        let multi = DecoderUnit::with_multi(
            DecoderConfig::paper_default(),
            MultiLutSpec::paper_default(),
        )
        .unwrap();
        let wrong = CodeBook::lexi_default(&Histogram::from_bytes(&[1u8, 2, 3])).unwrap();
        let (out, rep) = multi.decode_lane_stream(&stream, &wrong).unwrap();
        assert_eq!(out, data);
        assert_eq!(rep.symbols, data.len() as u64);
    }

    #[test]
    fn multi_lut_fill_cycles_are_bounded() {
        let spec = MultiLutSpec::paper_default();
        // 2^11 entries at 64/cycle = 32 cycles — dwarfed by the codebook
        // pipeline's sampling window, but no longer free.
        assert_eq!(spec.fill_cycles(), 32);
        assert_eq!(spec.lut_bits(), LUT_BITS);
        assert_eq!(spec.max_symbols_per_cycle(), LUT_MAX_SYMS);
    }

    #[test]
    fn parallel_lane_decode_is_thread_count_invariant() {
        // ISSUE 8: `decode_lane_stream_par` must match the sequential
        // path bit-for-bit — symbols AND every cycle-model report field
        // — at every thread count, for both the legacy and multi units,
        // across stream versions (plain / checksummed / per-lane books).
        use lexi_core::batch::LaneCodec;
        check("hw par lane decode == sequential", 30, |g| {
            let n = g.usize(1..2500);
            let data = if g.bool(0.7) {
                let a = g.usize(1..36);
                g.skewed_bytes(n, a)
            } else {
                g.vec(n, |g| g.u8())
            };
            let hist = Histogram::from_bytes(&data);
            let book = CodeBook::lexi_default(&hist).unwrap();
            let legacy = DecoderUnit::new(DecoderConfig::paper_default()).unwrap();
            let multi = DecoderUnit::with_multi(
                DecoderConfig::paper_default(),
                MultiLutSpec::paper_default(),
            )
            .unwrap();
            for lanes in [1usize, 3, 8] {
                let mut codec = LaneCodec::new(lanes).unwrap();
                if g.bool(0.3) {
                    codec = codec.with_checksums();
                }
                let stream = if g.bool(0.3) {
                    let books = vec![book.clone(); lanes];
                    codec.encode_per_lane(&data, &books).unwrap()
                } else {
                    codec.encode(&data, &book)
                };
                for unit in [&legacy, &multi] {
                    let (seq_out, seq_rep) = unit.decode_lane_stream(&stream, &book).unwrap();
                    assert_eq!(seq_out, data, "lanes {lanes}");
                    for threads in [1usize, 2, 8] {
                        let (par_out, par_rep) = unit
                            .decode_lane_stream_par(&stream, &book, threads)
                            .unwrap();
                        assert_eq!(par_out, seq_out, "lanes {lanes} threads {threads}");
                        assert_eq!(
                            par_rep, seq_rep,
                            "report diverged: lanes {lanes} threads {threads}"
                        );
                    }
                }
            }
        });
    }

    #[test]
    fn parallel_lane_decode_errors_match_sequential() {
        // Corrupt/truncated streams must surface the SAME typed error as
        // the sequential round-major loop — the recombiner's min
        // (visit, lane) rule — at every thread count.
        use lexi_core::batch::LaneCodec;
        check("hw par lane decode error parity", 40, |g| {
            let n = g.usize(8..1500);
            let a = g.usize(1..36);
            let data = g.skewed_bytes(n, a);
            let hist = Histogram::from_bytes(&data);
            let book = CodeBook::lexi_default(&hist).unwrap();
            let lanes = [1usize, 2, 8][g.usize(0..3)];
            let mut stream = LaneCodec::new(lanes).unwrap().encode(&data, &book);
            // Truncate one lane's bit budget or flip a payload byte.
            if g.bool(0.5) {
                let l = g.usize(0..lanes);
                let cut = 1 + g.usize(0..16) as u32;
                stream.lane_bits[l] = stream.lane_bits[l].saturating_sub(cut);
            } else if !stream.bytes.is_empty() {
                let i = g.usize(0..stream.bytes.len());
                stream.bytes[i] ^= 1 << g.usize(0..8);
            }
            let unit = if g.bool(0.5) {
                DecoderUnit::new(DecoderConfig::paper_default()).unwrap()
            } else {
                DecoderUnit::with_multi(
                    DecoderConfig::paper_default(),
                    MultiLutSpec::paper_default(),
                )
                .unwrap()
            };
            let seq = unit.decode_lane_stream(&stream, &book);
            for threads in [1usize, 2, 8] {
                let par = unit.decode_lane_stream_par(&stream, &book, threads);
                match (&seq, &par) {
                    (Ok((so, sr)), Ok((po, pr))) => {
                        assert_eq!(po, so, "threads {threads}");
                        assert_eq!(pr, sr, "threads {threads}");
                    }
                    (Err(se), Err(pe)) => {
                        assert_eq!(pe, se, "threads {threads}");
                    }
                    _ => panic!(
                        "ok/err divergence at threads {threads}: seq ok={} par ok={}",
                        seq.is_ok(),
                        par.is_ok()
                    ),
                }
            }
        });
    }

    #[test]
    fn more_lanes_never_slow_the_makespan() {
        let data: Vec<u8> = (0..6000u32).map(|i| 118 + (i % 11) as u8).collect();
        let hist = Histogram::from_bytes(&data);
        let book = CodeBook::lexi_default(&hist).unwrap();
        let unit = DecoderUnit::new(DecoderConfig::paper_default()).unwrap();
        let mut prev = u64::MAX;
        for lanes in [1usize, 2, 4, 8] {
            use lexi_core::batch::LaneCodec;
            let stream = LaneCodec::new(lanes).unwrap().encode(&data, &book);
            let (_, report) = unit.decode_lane_stream(&stream, &book).unwrap();
            assert!(
                report.makespan <= prev,
                "lanes {lanes}: makespan {} > previous {prev}",
                report.makespan
            );
            assert!(report.lane_speedup() >= lanes as f64 * 0.8);
            prev = report.makespan;
        }
    }
}
