//! Multi-stage LUT decompression circuit (paper §4.4, Fig. 3b).
//!
//! A naive single LUT indexed by the maximum code length is fast but
//! area-hungry; LEXI segments the codebook by code length across stages
//! with increasing prefix windows (8/16/24/32 bits in the chosen design).
//! Stage k holds up to 8 **length-class** entries `{len, first_code,
//! base_index}` — canonical decoding needs only one entry per code length,
//! and each stage covers 8 lengths, so capacity is exact.
//!
//! A symbol whose codeword (plus raw escape byte, for ESC) fits in the
//! stage-k window resolves in k cycles; short high-frequency codes resolve
//! in stage 1 at line rate. Multiple decode lanes take whole flits
//! round-robin (flit-atomic packing makes them independent).

use lexi_core::batch::{LaneDecoders, LaneStream};
use lexi_core::bitstream::BitReader;
use lexi_core::error::{Error, Result};
use lexi_core::huffman::{CanonicalDecoder, CodeBook};

/// A multi-stage decoder configuration.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DecoderConfig {
    /// Cumulative prefix window per stage, strictly increasing (bits).
    pub stage_bits: Vec<u32>,
    /// Length-class entries available per stage.
    pub entries_per_stage: u32,
}

impl DecoderConfig {
    /// The paper's chosen 4-stage design: 8/16/24/32-bit windows, 8
    /// entries per stage.
    pub fn paper_default() -> Self {
        DecoderConfig {
            stage_bits: vec![8, 16, 24, 32],
            entries_per_stage: 8,
        }
    }

    /// The monolithic comparison point: one 32-bit window holding every
    /// length class (Fig. 6's "single 32-bit LUT").
    pub fn monolithic() -> Self {
        DecoderConfig {
            stage_bits: vec![32],
            entries_per_stage: 32,
        }
    }

    /// Validate the config itself.
    pub fn validate(&self) -> Result<()> {
        if self.stage_bits.is_empty() {
            return Err(Error::InvalidParameter("no stages".into()));
        }
        if !self.stage_bits.windows(2).all(|w| w[0] < w[1]) {
            return Err(Error::InvalidParameter(
                "stage windows must strictly increase".into(),
            ));
        }
        if *self.stage_bits.last().expect("non-empty") > 32 {
            return Err(Error::InvalidParameter("windows beyond 32 bits".into()));
        }
        Ok(())
    }

    /// The stage (1-based) that resolves a consumed bit-length, or None if
    /// it exceeds the last window.
    #[inline]
    pub fn stage_of(&self, bits: u32) -> Option<u32> {
        self.stage_bits
            .iter()
            .position(|&b| b >= bits)
            .map(|k| k as u32 + 1)
    }

    /// Check that `book` (including its escape + raw byte) is decodable
    /// and that no stage exceeds its entry capacity.
    pub fn supports(&self, book: &CodeBook) -> Result<()> {
        self.validate()?;
        let worst = book.escape().len + 8;
        if self.stage_of(worst).is_none() {
            return Err(Error::InvalidParameter(format!(
                "escape path needs {worst} bits > last window"
            )));
        }
        // Count length classes per stage.
        let mut classes: Vec<std::collections::BTreeSet<u32>> =
            vec![Default::default(); self.stage_bits.len()];
        for &(_, len) in book.canonical_pairs() {
            let stage = self
                .stage_of(len)
                .ok_or_else(|| Error::InvalidParameter(format!("code length {len} too long")))?;
            classes[stage as usize - 1].insert(len);
        }
        for (k, set) in classes.iter().enumerate() {
            if set.len() as u32 > self.entries_per_stage {
                return Err(Error::InvalidParameter(format!(
                    "stage {} needs {} length classes > capacity {}",
                    k + 1,
                    set.len(),
                    self.entries_per_stage
                )));
            }
        }
        Ok(())
    }

    /// Per-stage (window_bits, entries) — input to the area model.
    pub fn stage_shapes(&self) -> Vec<(u32, u32)> {
        self.stage_bits
            .iter()
            .map(|&b| (b, self.entries_per_stage))
            .collect()
    }
}

/// Cycle report for decoding one stream.
#[derive(Clone, Debug, Default)]
pub struct DecodeReport {
    /// Total decode cycles (Σ per-symbol stage latency).
    pub cycles: u64,
    /// Symbols resolved per stage (index 0 = stage 1).
    pub per_stage: Vec<u64>,
    /// Symbols decoded.
    pub symbols: u64,
}

impl DecodeReport {
    /// Average cycles per symbol.
    pub fn avg_latency(&self) -> f64 {
        if self.symbols == 0 {
            0.0
        } else {
            self.cycles as f64 / self.symbols as f64
        }
    }
}

/// The multi-stage decoder unit.
pub struct DecoderUnit {
    cfg: DecoderConfig,
}

impl DecoderUnit {
    /// Build a decoder; errors if the config is invalid.
    pub fn new(cfg: DecoderConfig) -> Result<Self> {
        cfg.validate()?;
        Ok(DecoderUnit { cfg })
    }

    /// Decode `count` exponents from `r` using `book`, with cycle-accurate
    /// stage accounting. Bit-exact with `lexi-core`'s canonical decoder.
    pub fn decode(
        &self,
        r: &mut BitReader,
        book: &CodeBook,
        count: usize,
    ) -> Result<(Vec<u8>, DecodeReport)> {
        self.cfg.supports(book)?;
        let dec = book.decoder();
        self.decode_with(&dec, r, count)
    }

    /// Inner decode loop over an already-built canonical decoder, so
    /// multi-lane callers validate and build tables once, not per lane.
    fn decode_with(
        &self,
        dec: &CanonicalDecoder,
        r: &mut BitReader,
        count: usize,
    ) -> Result<(Vec<u8>, DecodeReport)> {
        let mut out = Vec::with_capacity(count);
        let mut report = DecodeReport {
            per_stage: vec![0; self.cfg.stage_bits.len()],
            ..Default::default()
        };
        for _ in 0..count {
            let before = r.pos();
            let sym = dec.decode(r)?;
            let consumed = (r.pos() - before) as u32;
            let stage = self
                .cfg
                .stage_of(consumed)
                .ok_or(Error::InvalidCodeword { offset: before })?;
            report.cycles += stage as u64;
            report.per_stage[stage as usize - 1] += 1;
            report.symbols += 1;
            out.push(sym);
        }
        Ok((out, report))
    }

    /// Config accessor.
    pub fn config(&self) -> &DecoderConfig {
        &self.cfg
    }

    /// Decode an `N`-lane interleaved stream (paper §4.4) with a
    /// **lockstep cycle model**: lanes advance one symbol per round, and
    /// each round's latency is tracked as the *occupancy* of its slowest
    /// lane (the per-round `max` of stage latencies), not as independent
    /// per-lane sums. The report carries both views:
    ///
    /// * [`LaneDecodeReport::makespan`] — slowest lane's summed cycles:
    ///   completion time when the `N` lanes run fully independently (each
    ///   with its own window registers and scheduler).
    /// * [`LaneDecodeReport::lockstep_cycles`] — Σ over rounds of the
    ///   round's slowest stage: completion time for a lockstep
    ///   implementation whose lanes share one round scheduler, the
    ///   structure `LaneCodec::decode_lockstep` mirrors in software.
    ///
    /// Embedded per-lane codebooks (v2 streams) take precedence over the
    /// `book` argument; every book in use must satisfy
    /// [`DecoderConfig::supports`]. Bit-exact with `LaneCodec::decode`
    /// and `LaneCodec::decode_lockstep`.
    pub fn decode_lane_stream(
        &self,
        stream: &LaneStream,
        book: &CodeBook,
    ) -> Result<(Vec<u8>, LaneDecodeReport)> {
        // Format validation is shared with `LaneCodec::decode`: one
        // source of truth for lane bounds, so format changes cannot fix
        // one consumer and miss the other. Config support and decoder
        // tables are likewise checked/built once per book, not per lane.
        let views = stream.validated_lanes()?;
        if stream.books.is_empty() {
            self.cfg.supports(book)?;
        } else {
            for b in &stream.books {
                self.cfg.supports(b)?;
            }
        }
        // Book precedence + per-lane indexing live in lexi-core's
        // LaneDecoders, shared with both software decode paths.
        let decs = LaneDecoders::for_stream(stream, book);
        let n = stream.lanes;
        let mut out = vec![0u8; stream.count];
        let mut readers: Vec<BitReader> = views
            .iter()
            .map(|v| BitReader::with_len(&stream.bytes[v.range.clone()], v.bits as usize))
            .collect();
        let dec_by_lane = decs.by_lane(n);
        let mut per_lane_cycles = vec![0u64; n];
        let mut lockstep_cycles = 0u64;
        // Round-robin rounds, mirroring the software lockstep loop: round
        // k decodes symbols k*n .. k*n + active.
        let rounds = stream.count.div_ceil(n);
        for k in 0..rounds {
            let base = k * n;
            let active = n.min(stream.count - base);
            let mut round_max = 0u64;
            for l in 0..active {
                let r = &mut readers[l];
                let before = r.pos();
                let sym = dec_by_lane[l].decode(r)?;
                let consumed = (r.pos() - before) as u32;
                let stage = self
                    .cfg
                    .stage_of(consumed)
                    .ok_or(Error::InvalidCodeword { offset: before })?
                    as u64;
                per_lane_cycles[l] += stage;
                round_max = round_max.max(stage);
                out[base + l] = sym;
            }
            lockstep_cycles += round_max;
        }
        let makespan = per_lane_cycles.iter().copied().max().unwrap_or(0);
        Ok((
            out,
            LaneDecodeReport {
                per_lane_cycles,
                makespan,
                lockstep_cycles,
                symbols: stream.count as u64,
            },
        ))
    }
}

/// Cycle report for one multi-lane decode.
#[derive(Clone, Debug, Default)]
pub struct LaneDecodeReport {
    /// Total stage-latency cycles per lane.
    pub per_lane_cycles: Vec<u64>,
    /// Slowest lane — the unit's completion time with fully independent
    /// parallel lanes.
    pub makespan: u64,
    /// Σ over rounds of the round's slowest stage — completion time for
    /// a lockstep implementation (lanes share one round scheduler).
    /// Always ≥ `makespan`; the gap is the cost of round synchronization.
    pub lockstep_cycles: u64,
    /// Symbols decoded across all lanes.
    pub symbols: u64,
}

impl LaneDecodeReport {
    /// Effective cycles per symbol with all lanes running independently.
    /// 0 for an empty stream (no division by a zero symbol count).
    pub fn effective_latency(&self) -> f64 {
        if self.symbols == 0 {
            0.0
        } else {
            self.makespan as f64 / self.symbols as f64
        }
    }

    /// Effective cycles per symbol under the lockstep round scheduler.
    /// 0 for an empty stream.
    pub fn lockstep_latency(&self) -> f64 {
        if self.symbols == 0 {
            0.0
        } else {
            self.lockstep_cycles as f64 / self.symbols as f64
        }
    }

    /// Speedup of the parallel-lane makespan over serializing every lane.
    /// 1.0 when the makespan is zero (empty or zero-cycle streams have
    /// nothing to speed up — guarded, no division by zero).
    pub fn lane_speedup(&self) -> f64 {
        let total: u64 = self.per_lane_cycles.iter().sum();
        if self.makespan == 0 {
            1.0
        } else {
            total as f64 / self.makespan as f64
        }
    }
}

/// L parallel decode lanes consuming independent units (flits) round-robin:
/// makespan = max over lanes of summed latencies. `lanes == 0` is clamped
/// to one (a degenerate caller gets the serial makespan, not a panic) and
/// an empty unit list yields 0.
pub fn parallel_makespan(per_unit_cycles: &[u64], lanes: usize) -> u64 {
    let lanes = lanes.max(1);
    let mut lane_time = vec![0u64; lanes];
    for (i, &c) in per_unit_cycles.iter().enumerate() {
        lane_time[i % lanes] += c;
    }
    lane_time.into_iter().max().unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use lexi_core::bitstream::BitWriter;
    use lexi_core::proptest::check;
    use lexi_core::stats::Histogram;

    fn encode(data: &[u8], book: &CodeBook) -> (Vec<u8>, usize) {
        let mut w = BitWriter::new();
        for &e in data {
            book.encode_symbol(e, &mut w);
        }
        let bits = w.len_bits();
        (w.into_bytes(), bits)
    }

    #[test]
    fn roundtrip_with_stage_accounting() {
        check("multistage decode roundtrip", 80, |g| {
            let n = g.usize(1..2000);
            let data = if g.bool(0.7) {
                let a = g.usize(1..40);
                g.skewed_bytes(n, a)
            } else {
                g.vec(n, |g| g.u8())
            };
            let hist = Histogram::from_bytes(&data);
            let book = CodeBook::lexi_default(&hist).unwrap();
            let (bytes, bits) = encode(&data, &book);
            let mut r = BitReader::with_len(&bytes, bits);
            let unit = DecoderUnit::new(DecoderConfig::paper_default()).unwrap();
            let (out, report) = unit.decode(&mut r, &book, data.len()).unwrap();
            assert_eq!(out, data);
            assert_eq!(report.symbols, data.len() as u64);
            assert_eq!(report.per_stage.iter().sum::<u64>(), data.len() as u64);
        });
    }

    #[test]
    fn skewed_streams_resolve_mostly_in_stage1() {
        // Fig 6: the 4-stage design averages ~1.16 cycles/symbol because
        // high-frequency codes are short.
        check("stage-1 dominance", 30, |g| {
            let data = g.skewed_bytes(4000, 10);
            let hist = Histogram::from_bytes(&data);
            let book = CodeBook::lexi_default(&hist).unwrap();
            let (bytes, bits) = encode(&data, &book);
            let mut r = BitReader::with_len(&bytes, bits);
            let unit = DecoderUnit::new(DecoderConfig::paper_default()).unwrap();
            let (_, report) = unit.decode(&mut r, &book, data.len()).unwrap();
            assert!(
                report.avg_latency() < 1.5,
                "avg latency {}",
                report.avg_latency()
            );
            assert!(report.per_stage[0] * 10 > report.symbols * 8);
        });
    }

    #[test]
    fn monolithic_is_single_cycle() {
        let data: Vec<u8> = (0..1000u32).map(|i| 120 + (i % 6) as u8).collect();
        let hist = Histogram::from_bytes(&data);
        let book = CodeBook::lexi_default(&hist).unwrap();
        let (bytes, bits) = encode(&data, &book);
        let mut r = BitReader::with_len(&bytes, bits);
        let unit = DecoderUnit::new(DecoderConfig::monolithic()).unwrap();
        let (out, report) = unit.decode(&mut r, &book, data.len()).unwrap();
        assert_eq!(out, data);
        assert_eq!(report.avg_latency(), 1.0);
    }

    #[test]
    fn invalid_configs_rejected() {
        assert!(DecoderConfig {
            stage_bits: vec![],
            entries_per_stage: 8
        }
        .validate()
        .is_err());
        assert!(DecoderConfig {
            stage_bits: vec![8, 8],
            entries_per_stage: 8
        }
        .validate()
        .is_err());
        assert!(DecoderConfig {
            stage_bits: vec![16, 40],
            entries_per_stage: 8
        }
        .validate()
        .is_err());
    }

    #[test]
    fn capacity_violation_detected() {
        // A 2-stage 16/32 config with only 4 entries/stage cannot hold
        // >4 length classes below 16 bits.
        let data: Vec<u8> = (0..200u32)
            .flat_map(|i| vec![(i % 20) as u8; (21 - i % 20) as usize])
            .collect();
        let hist = Histogram::from_bytes(&data);
        let book = CodeBook::lexi_default(&hist).unwrap();
        let cfg = DecoderConfig {
            stage_bits: vec![16, 32],
            entries_per_stage: 4,
        };
        // Depending on the histogram this book may have >4 classes ≤16.
        let classes: std::collections::BTreeSet<u32> = book
            .canonical_pairs()
            .iter()
            .map(|&(_, l)| l)
            .filter(|&l| l <= 16)
            .collect();
        if classes.len() > 4 {
            assert!(cfg.supports(&book).is_err());
        }
    }

    #[test]
    fn parallel_lanes_split_work() {
        let units = vec![10u64, 10, 10, 10, 10, 10, 10, 10, 10, 10];
        assert_eq!(parallel_makespan(&units, 1), 100);
        assert_eq!(parallel_makespan(&units, 10), 10);
        assert_eq!(parallel_makespan(&units, 3), 40);
    }

    #[test]
    fn parallel_makespan_degenerate_inputs() {
        // Guards (ISSUE 2 satellite): empty unit lists and a zero lane
        // count must not panic or divide by zero.
        assert_eq!(parallel_makespan(&[], 4), 0);
        assert_eq!(parallel_makespan(&[], 0), 0);
        assert_eq!(parallel_makespan(&[7, 3], 0), 10); // clamped to 1 lane
    }

    #[test]
    fn zero_and_single_symbol_lane_streams_report_safely() {
        use lexi_core::batch::LaneCodec;
        let book = {
            let data = vec![11u8, 11, 12, 13];
            let hist = Histogram::from_bytes(&data);
            CodeBook::lexi_default(&hist).unwrap()
        };
        let unit = DecoderUnit::new(DecoderConfig::paper_default()).unwrap();
        for lanes in [1usize, 4, 8] {
            // Zero symbols: all latencies and speedups are defined.
            let empty = LaneCodec::new(lanes).unwrap().encode(&[], &book);
            let (out, rep) = unit.decode_lane_stream(&empty, &book).unwrap();
            assert!(out.is_empty());
            assert_eq!(rep.symbols, 0);
            assert_eq!(rep.makespan, 0);
            assert_eq!(rep.lockstep_cycles, 0);
            assert_eq!(rep.effective_latency(), 0.0);
            assert_eq!(rep.lockstep_latency(), 0.0);
            assert_eq!(rep.lane_speedup(), 1.0);
            // One symbol: exactly one lane occupied for one stage.
            let one = LaneCodec::new(lanes).unwrap().encode(&[11], &book);
            let (out, rep) = unit.decode_lane_stream(&one, &book).unwrap();
            assert_eq!(out, vec![11]);
            assert_eq!(rep.symbols, 1);
            assert!(rep.makespan >= 1);
            assert_eq!(rep.lockstep_cycles, rep.makespan);
            assert!(rep.effective_latency() >= 1.0);
            assert!(rep.lane_speedup() >= 1.0);
        }
    }

    #[test]
    fn lockstep_cycles_bound_by_makespan_and_serial_sum() {
        // Round-max occupancy sits between the independent-lane makespan
        // and the fully serial sum, at every lane count.
        check("lockstep cycle bounds", 40, |g| {
            use lexi_core::batch::LaneCodec;
            let n = g.usize(1..3000);
            let a = g.usize(1..40);
            let data = g.skewed_bytes(n, a);
            let hist = Histogram::from_bytes(&data);
            let book = CodeBook::lexi_default(&hist).unwrap();
            let unit = DecoderUnit::new(DecoderConfig::paper_default()).unwrap();
            for lanes in [1usize, 2, 4, 8] {
                let stream = LaneCodec::new(lanes).unwrap().encode(&data, &book);
                let (_, rep) = unit.decode_lane_stream(&stream, &book).unwrap();
                let serial: u64 = rep.per_lane_cycles.iter().sum();
                assert!(
                    rep.makespan <= rep.lockstep_cycles,
                    "lanes {lanes}: makespan {} > lockstep {}",
                    rep.makespan,
                    rep.lockstep_cycles
                );
                assert!(
                    rep.lockstep_cycles <= serial,
                    "lanes {lanes}: lockstep {} > serial {serial}",
                    rep.lockstep_cycles
                );
                // With one lane the three collapse.
                if lanes == 1 {
                    assert_eq!(rep.lockstep_cycles, rep.makespan);
                    assert_eq!(rep.makespan, serial);
                }
            }
        });
    }

    #[test]
    fn per_lane_books_flow_through_hw_unit() {
        use lexi_core::batch::LaneCodec;
        // Two tenants with disjoint exponent ranges share a 2-lane link.
        let lanes = 2usize;
        let data: Vec<u8> = (0..600)
            .map(|i| if i % 2 == 0 { 40 + (i / 2 % 3) as u8 } else { 200 + (i / 2 % 5) as u8 })
            .collect();
        let books: Vec<CodeBook> = (0..lanes)
            .map(|l| {
                let lane_syms: Vec<u8> = data.iter().copied().skip(l).step_by(lanes).collect();
                let hist = Histogram::from_bytes(&lane_syms);
                CodeBook::lexi_default(&hist).unwrap()
            })
            .collect();
        let stream = LaneCodec::new(lanes)
            .unwrap()
            .encode_per_lane(&data, &books)
            .unwrap();
        let unit = DecoderUnit::new(DecoderConfig::paper_default()).unwrap();
        // The shared-book argument is ignored when books are embedded.
        let wrong = {
            let hist = Histogram::from_bytes(&[1u8, 2, 3]);
            CodeBook::lexi_default(&hist).unwrap()
        };
        let (out, rep) = unit.decode_lane_stream(&stream, &wrong).unwrap();
        assert_eq!(out, data);
        assert_eq!(rep.symbols, data.len() as u64);
        // And agrees with both software mirrors.
        assert_eq!(LaneCodec::decode(&stream, &wrong).unwrap(), data);
        assert_eq!(LaneCodec::decode_lockstep(&stream, &wrong).unwrap(), data);
    }

    #[test]
    fn lane_stream_decodes_bit_exactly_across_lane_counts() {
        use lexi_core::batch::LaneCodec;
        check("hw lane decode roundtrip", 40, |g| {
            let n = g.usize(1..2000);
            let data = if g.bool(0.7) {
                let a = g.usize(1..36);
                g.skewed_bytes(n, a)
            } else {
                g.vec(n, |g| g.u8())
            };
            let hist = Histogram::from_bytes(&data);
            let book = CodeBook::lexi_default(&hist).unwrap();
            let unit = DecoderUnit::new(DecoderConfig::paper_default()).unwrap();
            for lanes in [1usize, 2, 4, 8] {
                let stream = LaneCodec::new(lanes).unwrap().encode(&data, &book);
                let (out, report) = unit.decode_lane_stream(&stream, &book).unwrap();
                assert_eq!(out, data, "lanes {lanes}");
                assert_eq!(report.symbols, data.len() as u64);
                assert_eq!(report.per_lane_cycles.len(), lanes);
                assert_eq!(
                    report.makespan,
                    report.per_lane_cycles.iter().copied().max().unwrap()
                );
                // Software mirror agrees with the hw model's output.
                assert_eq!(LaneCodec::decode(&stream, &book).unwrap(), data);
            }
        });
    }

    #[test]
    fn more_lanes_never_slow_the_makespan() {
        let data: Vec<u8> = (0..6000u32).map(|i| 118 + (i % 11) as u8).collect();
        let hist = Histogram::from_bytes(&data);
        let book = CodeBook::lexi_default(&hist).unwrap();
        let unit = DecoderUnit::new(DecoderConfig::paper_default()).unwrap();
        let mut prev = u64::MAX;
        for lanes in [1usize, 2, 4, 8] {
            use lexi_core::batch::LaneCodec;
            let stream = LaneCodec::new(lanes).unwrap().encode(&data, &book);
            let (_, report) = unit.decode_lane_stream(&stream, &book).unwrap();
            assert!(
                report.makespan <= prev,
                "lanes {lanes}: makespan {} > previous {prev}",
                report.makespan
            );
            assert!(report.lane_speedup() >= lanes as f64 * 0.8);
            prev = report.makespan;
        }
    }
}
