//! Pipelined Huffman-tree construction (paper §4.2.2 step 2).
//!
//! Hardware builds the code tree from the bitonic-sorted frequency list
//! with the classical **two-queue** method: because the inputs arrive
//! sorted, each of the n−1 merges takes one cycle from a priority queue
//! "backed by the sorted frequency list" — 31 cycles worst case for the
//! 32-entry alphabet. The output is per-symbol code *lengths*; canonical
//! code assignment (step 3) turns lengths into bits.
//!
//! Lengths are capped at the escape budget (24 bits) by count-flattening —
//! unreachable with 512-sample histograms (depth ≤ ~13 by the Fibonacci
//! bound) but required for guaranteed functional correctness.

use crate::bitonic;
use lexi_core::huffman::{CodeBook, ESC_SYMBOL, MAX_CODE_LEN};
use lexi_core::stats::Histogram;
use lexi_core::Result;

/// Report from one hardware codebook generation.
#[derive(Clone, Debug)]
pub struct TreeReport {
    /// The canonical codebook (bit-exact with `lexi-core` assignment).
    pub book: CodeBook,
    /// Bitonic sorter cycles (15 for the 32-wide network).
    pub sort_cycles: u64,
    /// One cycle to splice the reserved ESC entry into the sorted list.
    pub esc_insert_cycles: u64,
    /// Tree-merge cycles (n−1; 32 worst case with ESC in the tree).
    pub merge_cycles: u64,
    /// LUT-programming cycles (one per LUT entry; 33 worst case).
    pub program_cycles: u64,
}

impl TreeReport {
    /// Total pipeline occupancy. The paper quotes 78 cycles (15+31+32)
    /// with the escape reserved *outside* the tree; our provably
    /// prefix-free variant carries ESC as a tree leaf, costing ≤3 extra
    /// cycles in the worst case (15+1+32+33 = 81) and fewer in the common
    /// sparse-alphabet case. EXPERIMENTS.md records the delta.
    pub fn total_cycles(&self) -> u64 {
        self.sort_cycles + self.esc_insert_cycles + self.merge_cycles + self.program_cycles
    }
}

/// Build the codebook exactly as the hardware pipeline does:
/// histogram → top-32 select → bitonic sort → ESC splice → two-queue merge
/// → lengths → canonical assignment → LUT program.
pub fn build_codebook(hist: &Histogram, max_symbols: usize) -> Result<TreeReport> {
    let sorted = hist.sorted_symbols();
    let (head, tail) = sorted.split_at(sorted.len().min(max_symbols));
    let escaped: u64 = tail.iter().map(|&(_, c)| c).sum();

    let syms: Vec<(u16, u64)> = head.iter().map(|&(s, c)| (s as u16, c)).collect();

    // Step 1 — bitonic sort of the ≤32 dedicated symbols by descending
    // count (15 stages for the full 32-wide network).
    let sort = bitonic::sort_desc(&syms, |&(sym, cnt)| (cnt, std::cmp::Reverse(sym)));
    let mut descending = sort.sorted;

    // Splice ESC at its weight position (single insertion cycle; ties
    // place ESC after equal-weight symbols so it sinks deepest).
    let esc_weight = escaped.max(1);
    let pos = descending
        .iter()
        .position(|&(_, c)| c < esc_weight)
        .unwrap_or(descending.len());
    descending.insert(pos, (ESC_SYMBOL, esc_weight));

    // Step 2 — two-queue Huffman on the ascending view.
    let (mut lengths, merge_cycles) = two_queue_lengths(&descending);

    // Length cap for the escape budget: repeatedly compress the count
    // dynamic range (integer sqrt — halving preserves Fibonacci-like
    // ratios and would not converge) until the deepest code fits. The
    // fixed point is all-equal counts → a balanced ≤6-deep tree for ≤33
    // symbols, so termination is guaranteed.
    let mut working = descending.clone();
    while lengths.iter().any(|&(_, l)| l > MAX_CODE_LEN) {
        working = working
            .iter()
            .map(|&(s, c)| (s, isqrt(c).max(1)))
            .collect();
        let (l2, _) = two_queue_lengths(&working);
        lengths = l2;
    }

    // Force ESC to hold the maximum length so the canonical all-ones code
    // is the escape (same invariant as lexi-core).
    let lmax = lengths.iter().map(|&(_, l)| l).max().expect("non-empty");
    let esc_pos = lengths
        .iter()
        .position(|&(s, _)| s == ESC_SYMBOL)
        .expect("ESC present");
    if lengths[esc_pos].1 < lmax {
        let j = lengths
            .iter()
            .position(|&(_, l)| l == lmax)
            .expect("max exists");
        let tmp = lengths[esc_pos].1;
        lengths[esc_pos].1 = lengths[j].1;
        lengths[j].1 = tmp;
    }

    // Step 3 — canonical assignment + LUT programming (1 cycle/entry).
    let book = CodeBook::from_lengths(&lengths)?;
    let program_cycles = lengths.len() as u64;

    Ok(TreeReport {
        book,
        sort_cycles: sort.stages,
        esc_insert_cycles: 1,
        merge_cycles,
        program_cycles,
    })
}

/// Integer square root (counts are ≤ the sample window, so u64 is ample).
fn isqrt(x: u64) -> u64 {
    if x < 2 {
        return x;
    }
    let mut r = (x as f64).sqrt() as u64;
    while (r + 1) * (r + 1) <= x {
        r += 1;
    }
    while r * r > x {
        r -= 1;
    }
    r
}

/// Two-queue Huffman: given symbols sorted by **descending** weight,
/// compute code lengths. One merge per cycle.
fn two_queue_lengths(descending: &[(u16, u64)]) -> (Vec<(u16, u32)>, u64) {
    let n = descending.len();
    if n == 1 {
        return (vec![(descending[0].0, 1)], 0);
    }

    // Node arena: leaves then internals.
    #[derive(Clone, Copy)]
    struct Node {
        weight: u64,
        parent: usize, // usize::MAX = none
    }
    let mut nodes: Vec<Node> = descending
        .iter()
        .rev() // ascending weights
        .map(|&(_, w)| Node {
            weight: w,
            parent: usize::MAX,
        })
        .collect();
    // Queue 1: leaves (ascending). Queue 2: internal nodes (created in
    // nondecreasing weight order — a property of Huffman merging).
    let mut q1: std::collections::VecDeque<usize> = (0..n).collect();
    let mut q2: std::collections::VecDeque<usize> = std::collections::VecDeque::new();
    let mut merges = 0u64;

    let pick = |q1: &mut std::collections::VecDeque<usize>,
                q2: &mut std::collections::VecDeque<usize>,
                nodes: &Vec<Node>|
     -> usize {
        match (q1.front(), q2.front()) {
            (Some(&a), Some(&b)) => {
                if nodes[a].weight <= nodes[b].weight {
                    q1.pop_front().expect("front exists")
                } else {
                    q2.pop_front().expect("front exists")
                }
            }
            (Some(_), None) => q1.pop_front().expect("front exists"),
            (None, Some(_)) => q2.pop_front().expect("front exists"),
            (None, None) => unreachable!("queues exhausted early"),
        }
    };

    while q1.len() + q2.len() > 1 {
        let a = pick(&mut q1, &mut q2, &nodes);
        let b = pick(&mut q1, &mut q2, &nodes);
        let idx = nodes.len();
        nodes.push(Node {
            weight: nodes[a].weight + nodes[b].weight,
            parent: usize::MAX,
        });
        nodes[a].parent = idx;
        nodes[b].parent = idx;
        q2.push_back(idx);
        merges += 1;
    }

    // Depth of each leaf = code length. Leaf i corresponds to
    // descending[n-1-i] (we reversed above).
    let mut out = Vec::with_capacity(n);
    for (leaf, &(sym, _)) in descending.iter().rev().enumerate() {
        let mut depth = 0u32;
        let mut cur = leaf;
        while nodes[cur].parent != usize::MAX {
            depth += 1;
            cur = nodes[cur].parent;
        }
        out.push((sym, depth));
    }
    (out, merges)
}

#[cfg(test)]
mod tests {
    use super::*;
    use lexi_core::proptest::check;
    use lexi_core::stats::Histogram;

    #[test]
    fn paper_cycle_budget() {
        // A histogram with ≥32 distinct symbols exercises the full pipeline.
        // Paper: 15 sort + 31 merge + 32 program = 78. Ours carries ESC as
        // a 33rd tree leaf: 15 + 1 + 32 + 33 = 81 worst case.
        let mut hist = Histogram::default();
        for s in 0..40u8 {
            hist.add(s, 1 + (40 - s as u64) * 3);
        }
        let r = build_codebook(&hist, 32).unwrap();
        assert_eq!(r.sort_cycles, 15);
        assert_eq!(r.merge_cycles, 32); // 33 entries (32 + ESC) → 32 merges
        assert_eq!(r.program_cycles, 33);
        assert_eq!(r.total_cycles(), 81);
    }

    #[test]
    fn sparse_alphabet_is_cheaper_than_budget() {
        // The common case (<32 distinct exponents) finishes well under the
        // 78-cycle worst case.
        let mut hist = Histogram::default();
        for s in 120..128u8 {
            hist.add(s, (s as u64 - 119) * 10);
        }
        let r = build_codebook(&hist, 32).unwrap();
        assert!(r.total_cycles() < 78, "total {}", r.total_cycles());
    }

    #[test]
    fn optimality_matches_package_merge_cost() {
        // Hardware Huffman and software package-merge may pick different
        // optimal codes, but their total weighted cost must agree whenever
        // the length cap is not binding.
        check("hw tree cost == sw tree cost", 60, |g| {
            let a = g.usize(2..40);
            let n = g.usize(32..1500);
            let data = g.skewed_bytes(n, a);
            let hist = Histogram::from_bytes(&data);
            let hw = build_codebook(&hist, 32).unwrap();
            let sw = CodeBook::lexi_default(&hist).unwrap();
            assert_eq!(
                hw.book.payload_bits(&hist),
                sw.payload_bits(&hist),
                "hist distinct {}",
                hist.distinct()
            );
        });
    }

    #[test]
    fn hw_book_is_lossless() {
        check("hw codebook roundtrip", 60, |g| {
            let n = g.usize(1..2000);
            let data = if g.bool(0.7) {
                let a = g.usize(1..48);
                g.skewed_bytes(n, a)
            } else {
                g.vec(n, |g| g.u8())
            };
            let hist = Histogram::from_bytes(&data);
            let r = build_codebook(&hist, 32).unwrap();
            let block = lexi_core::huffman::compress_with_book(&data, &r.book).unwrap();
            assert_eq!(
                lexi_core::huffman::decompress_exponents(&block).unwrap(),
                data
            );
        });
    }

    #[test]
    fn hw_book_stream_decodes_via_expcodec_registry() {
        // ISSUE 3 wire-compat: a hardware-encoded transfer is just a
        // Huffman CodedBlock — the pluggable-codec decode path must
        // accept it byte-for-byte, with no hw-specific escape hatch.
        use lexi_core::codec::{CodecKind, CodedBlock};
        let data: Vec<u8> = (0..3000u32).map(|i| 115 + (i % 11) as u8).collect();
        let hist = Histogram::from_bytes(&data);
        let r = build_codebook(&hist, 32).unwrap();
        let block = lexi_core::huffman::compress_with_book(&data, &r.book).unwrap();
        let coded = CodedBlock {
            kind: CodecKind::Huffman,
            bytes: block.bytes,
            bits: block.bits,
            count: block.count,
            crc: None,
        };
        assert_eq!(CodecKind::Huffman.codec().decode(&coded).unwrap(), data);
    }

    #[test]
    fn esc_all_ones_in_hw_book() {
        let data: Vec<u8> = (0..500u32).map(|i| (i % 5) as u8 + 120).collect();
        let hist = Histogram::from_bytes(&data);
        let r = build_codebook(&hist, 32).unwrap();
        let esc = r.book.escape();
        assert_eq!(esc.bits, (1 << esc.len) - 1);
    }

    #[test]
    fn lengths_capped_at_24() {
        // Fibonacci weights explode depth without the cap.
        let mut hist = Histogram::default();
        let (mut a, mut b) = (1u64, 2u64);
        for s in 0..31u8 {
            hist.add(s, a);
            let c = a + b;
            a = b;
            b = c;
        }
        let r = build_codebook(&hist, 32).unwrap();
        assert!(r.book.max_len() <= 24, "max_len {}", r.book.max_len());
    }

    #[test]
    fn two_symbol_tree() {
        let mut hist = Histogram::default();
        hist.add(100, 10);
        hist.add(101, 1);
        let r = build_codebook(&hist, 32).unwrap();
        // 3 entries (2 syms + ESC): merges = 2.
        assert_eq!(r.merge_cycles, 2);
        assert_eq!(r.book.num_symbols(), 2);
    }
}
