//! M-lane LUT encoder (paper §4.2.2 step 3 + §4.3).
//!
//! After codebook generation, the 32-entry encoding LUT is replicated at
//! each of the M lanes; every lane transforms one 8-bit exponent into its
//! codeword per cycle, single-cycle lookup, no contention. Programming all
//! LUT entries takes one cycle per entry (32 worst case), counted in
//! [`crate::tree_builder::TreeReport::program_cycles`].
//!
//! The emitted bitstream is **bit-exact** with `lexi-core`'s
//! `compress_with_book` payload: lanes model throughput, not reordering —
//! the network interface re-serializes codewords in stream order when
//! packing flits (§4.3).

use lexi_core::bitstream::BitWriter;
use lexi_core::huffman::CodeBook;

/// Cycle-accurate encode of an exponent stream through M parallel lanes.
#[derive(Clone, Debug)]
pub struct EncodeReport {
    /// Cycles to push the whole stream through the lanes (⌈n/M⌉: each lane
    /// encodes one symbol/cycle).
    pub cycles: u64,
    /// Output payload bits (no header).
    pub bits: u64,
    /// Symbols encoded via the escape path.
    pub escapes: u64,
}

/// The M-lane encoder unit.
pub struct EncoderUnit {
    lanes: usize,
}

impl EncoderUnit {
    /// An encoder with `lanes` parallel LUTs (paper selects 10).
    pub fn new(lanes: usize) -> Self {
        assert!(lanes >= 1);
        EncoderUnit { lanes }
    }

    /// Encode `exponents` with `book`, returning the payload bitstream and
    /// the cycle report.
    pub fn encode(&self, exponents: &[u8], book: &CodeBook) -> (Vec<u8>, EncodeReport) {
        let mut w = BitWriter::new();
        let mut escapes = 0u64;
        for &e in exponents {
            if book.code(e).is_none() {
                escapes += 1;
            }
            book.encode_symbol(e, &mut w);
        }
        let bits = w.len_bits() as u64;
        let cycles = (exponents.len() as u64).div_ceil(self.lanes as u64);
        (
            w.into_bytes(),
            EncodeReport {
                cycles,
                bits,
                escapes,
            },
        )
    }

    /// Sustained throughput in exponents per cycle (≡ lanes).
    pub fn throughput(&self) -> usize {
        self.lanes
    }

    /// Effective encoder occupancy, codec cycles per symbol across all
    /// lanes (the exact reciprocal of [`EncoderUnit::throughput`]: each
    /// lane retires one single-cycle LUT lookup per cycle, so M lanes
    /// sustain M symbols/cycle — there is no per-symbol stall term on
    /// the encode side, unlike the decoder's probe-fill average). The
    /// ingress codec ports (`lexi-noc::ingress`) and the analytic
    /// engine's encode-occupancy charge both use this figure.
    pub fn cycles_per_symbol(&self) -> f64 {
        1.0 / self.lanes as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use lexi_core::proptest::check;
    use lexi_core::stats::Histogram;

    #[test]
    fn bit_exact_with_core() {
        check("hw encode == sw encode", 60, |g| {
            let n = g.usize(1..3000);
            let a = g.usize(1..50);
            let data = g.skewed_bytes(n, a);
            let hist = Histogram::from_bytes(&data);
            let book = CodeBook::lexi_default(&hist).unwrap();

            let (hw_bytes, report) = EncoderUnit::new(10).encode(&data, &book);

            let mut w = BitWriter::new();
            for &e in &data {
                book.encode_symbol(e, &mut w);
            }
            assert_eq!(report.bits as usize, w.len_bits());
            assert_eq!(hw_bytes, w.into_bytes());
        });
    }

    #[test]
    fn lanes_scale_throughput() {
        let data = vec![127u8; 1000];
        let hist = Histogram::from_bytes(&data);
        let book = CodeBook::lexi_default(&hist).unwrap();
        let (_, r1) = EncoderUnit::new(1).encode(&data, &book);
        let (_, r10) = EncoderUnit::new(10).encode(&data, &book);
        assert_eq!(r1.cycles, 1000);
        assert_eq!(r10.cycles, 100);
    }

    #[test]
    fn cycles_per_symbol_is_reciprocal_throughput() {
        // The occupancy figure must agree with the cycle-exact encode
        // report on lane-aligned streams: n symbols × cps == cycles.
        for lanes in [1usize, 4, 10, 16] {
            let u = EncoderUnit::new(lanes);
            assert!((u.cycles_per_symbol() - 1.0 / lanes as f64).abs() < 1e-12);
            let n = lanes * 25;
            let data = vec![127u8; n];
            let hist = Histogram::from_bytes(&data);
            let book = CodeBook::lexi_default(&hist).unwrap();
            let (_, r) = u.encode(&data, &book);
            assert_eq!(r.cycles as f64, n as f64 * u.cycles_per_symbol());
        }
    }

    #[test]
    fn escape_counting() {
        // Alphabet of 40 with a 32-cap → 8 escaped symbols.
        let data: Vec<u8> = (0..40u8).flat_map(|s| vec![s; (41 - s) as usize]).collect();
        let hist = Histogram::from_bytes(&data);
        let book = CodeBook::lexi_default(&hist).unwrap();
        let (_, r) = EncoderUnit::new(4).encode(&data, &book);
        let expected: u64 = data.iter().filter(|&&e| book.code(e).is_none()).count() as u64;
        assert_eq!(r.escapes, expected);
        assert!(r.escapes > 0);
    }
}
