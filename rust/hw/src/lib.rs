//! # lexi-hw — cycle-accurate model of the LEXI codec hardware
//!
//! This crate models the microarchitecture of Fig. 3 of the paper at cycle
//! granularity, bit-exactly against the `lexi-core` software codecs:
//!
//! * [`lane_cache`] — per-lane local frequency caches (8-entry, FIFO
//!   eviction) that accelerate histogram construction (paper §4.2.1).
//! * [`arbiter`] — the 3-cycle-grant arbiter serializing lane evictions
//!   into the single-ported global histogram.
//! * [`histogram_unit`] — M lanes + arbiter + global histogram, stepped one
//!   cycle at a time; reports ingestion latency and per-lane hit rates
//!   (Figs. 4 and 5).
//! * [`bitonic`] — the 15-stage parallel bitonic sorting network for ≤32
//!   elements (paper §4.2.2 step 1).
//! * [`tree_builder`] — priority-queue Huffman construction, 31-cycle worst
//!   case (step 2), emitting code lengths for canonical assignment.
//! * [`encoder`] — LUT programming (32 cycles) + M-lane single-cycle
//!   encode, producing bitstreams identical to `lexi-core` (step 3, §4.3).
//! * [`decoder`] — the multi-stage LUT decoder (8/16/24/32-bit prefixes,
//!   8 length-class entries per stage) with per-symbol stage latency and
//!   parallel decode lanes (§4.4).
//! * [`compressor`] — the assembled egress pipeline: 512-sample histogram
//!   phase → 78-cycle codebook pipeline → streaming encode.
//! * [`area_power`] — GF 22 nm area/power model calibrated to the paper's
//!   Table 4, with Stillmaker–Baas scaling to the 16 nm Simba node.

pub mod arbiter;
pub mod area_power;
pub mod bitonic;
pub mod compressor;
pub mod decoder;
pub mod encoder;
pub mod histogram_unit;
pub mod lane_cache;
pub mod tree_builder;

/// Clock frequency the paper synthesizes at (1 GHz): 1 cycle = 1 ns.
pub const CLOCK_GHZ: f64 = 1.0;

/// Convert cycles to nanoseconds at the synthesis clock.
#[inline]
pub fn cycles_to_ns(cycles: u64) -> f64 {
    cycles as f64 / CLOCK_GHZ
}
