//! Minimal offline shim for the `anyhow` API surface the `lexi` crate
//! uses: [`Error`], [`Result`], [`anyhow!`], [`bail!`], and the
//! [`Context`] extension trait.
//!
//! Semantics mirror the real crate where it matters to callers:
//!
//! * `Error` converts from any `std::error::Error` via `?` (and therefore
//!   must not implement `std::error::Error` itself — same coherence trick
//!   the real crate relies on).
//! * `.context(..)` / `.with_context(..)` prepend a layer; `{:#}` (and
//!   `{:#?}`) render the whole chain `context: cause`.
//!
//! Error payloads are eagerly stringified — no downcasting, no backtraces.
//! That is all this repository needs; swap in the real crate if more of
//! the API becomes necessary.

use std::fmt;

/// A stringified error with optional context layers (outermost first).
pub struct Error {
    layers: Vec<String>,
}

impl Error {
    /// Build from a displayable message (what `anyhow!` expands to).
    pub fn msg(message: impl fmt::Display) -> Self {
        Error {
            layers: vec![message.to_string()],
        }
    }

    /// Prepend a context layer.
    pub fn context(mut self, context: impl fmt::Display) -> Self {
        self.layers.insert(0, context.to_string());
        self
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: full chain, outermost context first.
            write!(f, "{}", self.layers.join(": "))
        } else {
            write!(f, "{}", self.layers[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.layers[0])?;
        if self.layers.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for layer in &self.layers[1..] {
                write!(f, "\n    {layer}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// `anyhow`-style result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Context-attaching extension for results (and options).
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Wrap the error with a lazily built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        // `{:#}` keeps the full chain when E is itself an anyhow Error.
        self.map_err(|e| Error::msg(format!("{e:#}")).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{e:#}")).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(format!("{e}"), "missing thing");
    }

    #[test]
    fn context_layers_render_in_alternate() {
        let e: Result<()> = std::result::Result::<(), _>::Err(io_err()).context("opening config");
        let e = e.unwrap_err();
        assert_eq!(format!("{e}"), "opening config");
        assert_eq!(format!("{e:#}"), "opening config: missing thing");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("bad value {}", 7);
        assert_eq!(format!("{e}"), "bad value 7");
        fn f() -> Result<()> {
            bail!("nope: {}", "reason")
        }
        assert_eq!(format!("{}", f().unwrap_err()), "nope: reason");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("empty").unwrap_err();
        assert_eq!(format!("{e}"), "empty");
    }
}
