//! The Simba-style 6×6 chiplet array (paper §5.1).
//!
//! A homogeneous mesh of compute chiplets plus memory chiplets at the mesh
//! edge (package-level DRAM/HBM attach points). Model blocks are mapped
//! round-robin across compute chiplets; memory endpoints resolve to the
//! memory chiplet nearest the referencing block's chiplet.

use lexi_models::traffic::Endpoint;
use lexi_models::ModelConfig;
use lexi_noc::{Mesh, NodeId};

/// The chiplet system.
#[derive(Clone, Debug)]
pub struct SimbaSystem {
    pub mesh: Mesh,
    /// Nodes hosting memory controllers (edge-attached).
    pub memory_nodes: Vec<NodeId>,
    /// Remaining nodes, in mapping order.
    pub compute_nodes: Vec<NodeId>,
}

impl SimbaSystem {
    /// The paper's 6×6 array with four edge-center memory chiplets
    /// (west/east column centers — HBM PHYs live on package edges).
    pub fn paper_default() -> Self {
        Self::new(Mesh::simba_6x6(), &[(0, 2), (0, 3), (5, 2), (5, 3)])
    }

    /// Custom array: `memory_xy` lists memory-chiplet coordinates.
    pub fn new(mesh: Mesh, memory_xy: &[(u16, u16)]) -> Self {
        let memory_nodes: Vec<NodeId> = memory_xy.iter().map(|&(x, y)| mesh.node(x, y)).collect();
        assert!(!memory_nodes.is_empty(), "need at least one memory chiplet");
        let compute_nodes: Vec<NodeId> = (0..mesh.len() as u16)
            .map(NodeId)
            .filter(|n| !memory_nodes.contains(n))
            .collect();
        SimbaSystem {
            mesh,
            memory_nodes,
            compute_nodes,
        }
    }

    /// Chiplet hosting block `layer` (round-robin over compute chiplets,
    /// consecutive blocks on neighbouring mapping slots).
    pub fn block_node(&self, layer: usize) -> NodeId {
        self.compute_nodes[layer % self.compute_nodes.len()]
    }

    /// Memory chiplet nearest to `node`.
    pub fn nearest_memory(&self, node: NodeId) -> NodeId {
        *self
            .memory_nodes
            .iter()
            .min_by_key(|&&m| self.mesh.hops(node, m))
            .expect("memory nodes non-empty")
    }

    /// Resolve a logical endpoint for a transfer touching `layer`.
    pub fn resolve(&self, ep: Endpoint, layer: usize) -> NodeId {
        match ep {
            Endpoint::Block(l) => self.block_node(l),
            Endpoint::Memory => self.nearest_memory(self.block_node(layer)),
        }
    }

    /// Mesh hops between the resolved endpoints of a (src, dst) pair.
    pub fn hops(&self, src: Endpoint, dst: Endpoint, layer: usize) -> u32 {
        self.mesh
            .hops(self.resolve(src, layer), self.resolve(dst, layer))
    }

    /// Sanity: can this system host the model (≥1 compute chiplet)?
    pub fn fits(&self, _cfg: &ModelConfig) -> bool {
        !self.compute_nodes.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use lexi_models::ModelScale;

    #[test]
    fn paper_array_shape() {
        let s = SimbaSystem::paper_default();
        assert_eq!(s.mesh.len(), 36);
        assert_eq!(s.memory_nodes.len(), 4);
        assert_eq!(s.compute_nodes.len(), 32);
    }

    #[test]
    fn blocks_map_round_robin() {
        let s = SimbaSystem::paper_default();
        let cfg = ModelConfig::qwen(ModelScale::Paper);
        assert!(s.fits(&cfg));
        let n0 = s.block_node(0);
        let n32 = s.block_node(32);
        assert_eq!(n0, n32); // wraps after 32 compute chiplets
        assert_ne!(s.block_node(0), s.block_node(1));
    }

    #[test]
    fn nearest_memory_is_minimal() {
        let s = SimbaSystem::paper_default();
        for layer in 0..8 {
            let b = s.block_node(layer);
            let m = s.nearest_memory(b);
            for &other in &s.memory_nodes {
                assert!(s.mesh.hops(b, m) <= s.mesh.hops(b, other));
            }
        }
    }

    #[test]
    fn memory_nodes_excluded_from_compute() {
        let s = SimbaSystem::paper_default();
        for m in &s.memory_nodes {
            assert!(!s.compute_nodes.contains(m));
        }
    }
}
