//! Interconnect energy model.
//!
//! The paper's Table 4 reports codec *power*; the flip side of LEXI's
//! pitch is that moving fewer bits saves link energy far in excess of
//! what the codecs burn. This module quantifies that: link energy per bit
//! (interposer SerDes + wire), codec energy per compressed/decompressed
//! value, and the net energy balance of a workload.
//!
//! Link energy constants follow published interposer numbers (≈0.5–1
//! pJ/bit for organic/silicon interposer links; we default to 0.8 pJ/bit,
//! the mid-range used in Simba-class studies). Codec energy derives from
//! the Table 4 power at 1 GHz and the measured throughput (10 values /
//! cycle across lanes).

use crate::compression::{CompressionMode, CrTable};
use crate::simba::SimbaSystem;
use lexi_models::corpus::Corpus;
use lexi_models::traffic::{self, TransferKind};
use lexi_models::ModelConfig;

/// Energy model parameters.
#[derive(Clone, Copy, Debug)]
pub struct EnergyModel {
    /// Inter-chiplet link energy, pJ per bit **per hop** (every traversed
    /// link segment + router burns this; codecs pay only at endpoints —
    /// that asymmetry is why compression wins on energy).
    pub link_pj_per_bit: f64,
    /// Compressor energy per value (10 lanes @ 25.13 mW ≈ 2.5 pJ/value at
    /// 10 values/ns).
    pub compress_pj_per_value: f64,
    /// Decompressor energy per value (20.3 mW across 10 lanes).
    pub decompress_pj_per_value: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        // Codec: Table 4 power totals at 1 GHz, 10 values/cycle.
        // Compress side = local caches (2.5) + hist/codegen (5.23) +
        // enc LUTs (17.4) = 25.13 mW → 25.13 pJ/ns ÷ 10 values/ns.
        EnergyModel {
            link_pj_per_bit: 0.8,
            compress_pj_per_value: 2.513,
            decompress_pj_per_value: 2.03,
        }
    }
}

/// Energy report for one workload.
#[derive(Clone, Debug)]
pub struct EnergyReport {
    pub mode: CompressionMode,
    /// Link energy, µJ.
    pub link_uj: f64,
    /// Codec energy (compress + decompress), µJ.
    pub codec_uj: f64,
}

impl EnergyReport {
    /// Total interconnect energy, µJ.
    pub fn total_uj(&self) -> f64 {
        self.link_uj + self.codec_uj
    }
}

impl EnergyModel {
    /// Evaluate the energy of a full inference under `mode` on `system`
    /// (hop counts come from the XY routes between resolved endpoints),
    /// with the paper's all-Huffman codec policy.
    pub fn run(
        &self,
        system: &SimbaSystem,
        cfg: &ModelConfig,
        corpus: &Corpus,
        mode: CompressionMode,
        crs: &CrTable,
    ) -> EnergyReport {
        self.run_with_policy(system, cfg, corpus, mode, crs, lexi_models::CodecPolicy::lexi_default())
    }

    /// Same, under an explicit per-kind codec policy (ISSUE 5 satellite:
    /// wire bytes route through the `ExpCodec` registry like the
    /// engine's, not the legacy Huffman-only path).
    pub fn run_with_policy(
        &self,
        system: &SimbaSystem,
        cfg: &ModelConfig,
        corpus: &Corpus,
        mode: CompressionMode,
        crs: &CrTable,
        policy: lexi_models::CodecPolicy,
    ) -> EnergyReport {
        let transfers = traffic::full_inference(cfg, corpus);
        let mut link_pj = 0.0;
        let mut codec_pj = 0.0;
        for t in &transfers {
            let codec = policy.codec_for(t.kind);
            let wire_bits = crs.wire_bytes_for(codec, t.bytes, t.kind, mode) as f64 * 8.0;
            let hops = system.hops(t.src, t.dst, t.layer).max(1) as f64;
            link_pj += wire_bits * self.link_pj_per_bit * hops;
            if mode.compresses(t.kind) {
                let values = t.bytes as f64 / 2.0; // BF16
                // Weights compress offline: only decompression energy.
                if t.kind != TransferKind::Weights {
                    codec_pj += values * self.compress_pj_per_value;
                }
                codec_pj += values * self.decompress_pj_per_value;
            }
        }
        EnergyReport {
            mode,
            link_uj: link_pj / 1e6,
            codec_uj: codec_pj / 1e6,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use lexi_models::ModelScale;

    use crate::simba::SimbaSystem;

    fn setup() -> (SimbaSystem, ModelConfig, Corpus, CrTable) {
        let cfg = ModelConfig::qwen(ModelScale::Paper);
        let crs = CrTable::measure(&cfg, 42);
        (
            SimbaSystem::paper_default(),
            cfg,
            Corpus::wikitext2(),
            crs,
        )
    }

    #[test]
    fn lexi_saves_net_energy() {
        // The codec burn must be far below the link savings — otherwise
        // the whole scheme is pointless.
        let (sys, cfg, corpus, crs) = setup();
        let m = EnergyModel::default();
        let unc = m.run(&sys, &cfg, &corpus, CompressionMode::Uncompressed, &crs);
        let lexi = m.run(&sys, &cfg, &corpus, CompressionMode::Lexi, &crs);
        assert!(lexi.total_uj() < unc.total_uj());
        let savings = 1.0 - lexi.total_uj() / unc.total_uj();
        assert!((0.20..0.45).contains(&savings), "savings {savings:.3}");
        // Codec energy well below what it saves on the links.
        let link_saved = unc.link_uj - lexi.link_uj;
        assert!(
            lexi.codec_uj < 0.5 * link_saved,
            "codec {} vs saved {}",
            lexi.codec_uj,
            link_saved
        );
    }

    #[test]
    fn uncompressed_burns_no_codec_energy() {
        let (sys, cfg, corpus, crs) = setup();
        let r =
            EnergyModel::default().run(&sys, &cfg, &corpus, CompressionMode::Uncompressed, &crs);
        assert_eq!(r.codec_uj, 0.0);
    }

    #[test]
    fn weights_only_skips_runtime_compress_energy() {
        let (sys, cfg, corpus, crs) = setup();
        let m = EnergyModel::default();
        let wo = m.run(&sys, &cfg, &corpus, CompressionMode::WeightsOnly, &crs);
        let lexi = m.run(&sys, &cfg, &corpus, CompressionMode::Lexi, &crs);
        assert!(wo.codec_uj < lexi.codec_uj);
        assert!(wo.codec_uj > 0.0);
    }
}
