//! Per-block compute-latency model.
//!
//! LEXI never changes arithmetic (paper §5.3: "computation latency remains
//! identical in uncompressed and compressed settings"), so a simple
//! roofline model suffices: block latency = FLOPs / chiplet throughput.
//! The default matches a Simba-class inference chiplet (≈2 TFLOP/s BF16).

use lexi_models::config::ModelConfig;
use lexi_models::corpus::Corpus;

/// Compute model parameters.
#[derive(Clone, Copy, Debug)]
pub struct ComputeModel {
    /// Sustained BF16 throughput per chiplet, TFLOP/s.
    pub chiplet_tflops: f64,
}

impl Default for ComputeModel {
    fn default() -> Self {
        ComputeModel { chiplet_tflops: 2.0 }
    }
}

impl ComputeModel {
    /// Nanoseconds for `flops` on one chiplet.
    #[inline]
    pub fn ns_for_flops(&self, flops: u64) -> f64 {
        // TFLOP/s = 1e3 FLOP/ns.
        flops as f64 / (self.chiplet_tflops * 1e3)
    }

    /// Compute time of one decode step: blocks execute in a pipeline but a
    /// single request is serial across layers.
    pub fn decode_step_ns(&self, cfg: &ModelConfig, context_len: u64) -> f64 {
        cfg.blocks
            .iter()
            .map(|&k| self.ns_for_flops(cfg.block_flops_per_token(k, context_len)))
            .sum()
    }

    /// Compute time of the prefill phase. Tokens pipeline across layers,
    /// so the bound is the per-chiplet work: tokens × per-block time, for
    /// the busiest block assignment (uniform here → sum over layers once,
    /// times tokens, divided by the pipeline overlap ≈ layer count when
    /// tokens ≫ layers — net: tokens × max-block time + fill/drain).
    pub fn prefill_ns(&self, cfg: &ModelConfig, corpus: &Corpus) -> f64 {
        let n = corpus.input_tokens as u64;
        let per_token: Vec<f64> = cfg
            .blocks
            .iter()
            .map(|&k| self.ns_for_flops(cfg.block_flops_per_token(k, corpus.input_tokens as u64)))
            .collect();
        let bottleneck = per_token.iter().cloned().fold(0.0f64, f64::max);
        let fill: f64 = per_token.iter().sum();
        n as f64 * bottleneck + fill
    }

    /// Total compute for a full inference.
    pub fn total_ns(&self, cfg: &ModelConfig, corpus: &Corpus) -> f64 {
        let mut t = self.prefill_ns(cfg, corpus);
        for step in 0..corpus.output_tokens as u64 {
            t += self.decode_step_ns(cfg, corpus.input_tokens as u64 + step);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use lexi_models::ModelScale;

    #[test]
    fn bigger_models_compute_longer() {
        let m = ComputeModel::default();
        let corpus = Corpus::wikitext2();
        let j = m.total_ns(&ModelConfig::jamba(ModelScale::Paper), &corpus);
        let q = m.total_ns(&ModelConfig::qwen(ModelScale::Paper), &corpus);
        assert!(q > j, "qwen {q} jamba {j}");
    }

    #[test]
    fn decode_step_grows_with_context_for_attention() {
        let m = ComputeModel::default();
        let cfg = ModelConfig::qwen(ModelScale::Paper);
        assert!(m.decode_step_ns(&cfg, 2048) > m.decode_step_ns(&cfg, 128));
    }

    #[test]
    fn throughput_scales_inverse() {
        let cfg = ModelConfig::qwen(ModelScale::Paper);
        let corpus = Corpus::wikitext2();
        let slow = ComputeModel { chiplet_tflops: 1.0 }.total_ns(&cfg, &corpus);
        let fast = ComputeModel { chiplet_tflops: 4.0 }.total_ns(&cfg, &corpus);
        assert!((slow / fast - 4.0).abs() < 0.01);
    }
}
