//! Open-loop trace-driven multi-tenant serving simulator (ISSUE 9).
//!
//! The paper evaluates LEXI on single inference requests; a serving
//! deployment sees *streams* of them. This module drives the analytic
//! [`Engine`] with seeded open-loop arrival traces — Poisson or bursty
//! (2-state Markov-modulated Poisson) — over a mixed Jamba/Zamba/Qwen
//! fleet, each request a prefill + decode session whose K/V-cache
//! stream carries a per-tenant codebook (exercised through the real v2
//! [`LaneCodec`] wire format and a shared `lexi-hw` lane cache under
//! codebook churn). Three robustness layers ride on top:
//!
//! 1. **Deadline-aware admission** — every serving node owns a bounded
//!    admission queue; a request whose queue is full retries under the
//!    capped-backoff [`RetryConfig`] budget and then sheds with the
//!    typed [`Error::Shed`]; a request whose *predicted* sojourn already
//!    exceeds its deadline sheds immediately (waiting cannot shrink an
//!    absolute backlog). Load-shedding is therefore typed and counted,
//!    never an unbounded queue.
//! 2. **Congestion-driven degradation with hysteresis** — the
//!    [`DegradeController`] watches sustained encode/decode codec-port
//!    occupancy; tripping it force-degrades the K/V class to `Raw`
//!    through [`Engine::force_degrade`] (dropping its codec-port work
//!    entirely), and calm windows earn a single-transfer recovery probe
//!    that restores the codec via [`Engine::record_recovery`]. The
//!    two-threshold band plus the flap guard keep an oscillating load
//!    from making the policy oscillate with it.
//! 3. **Chaos soak** — [`run_chaos`] replays the same admission loop
//!    against the *cycle-level* `lexi-noc` network with the ISSUE 6/7
//!    fault machinery live (BER corruption, drops, duplicates,
//!    permanent link kills), closing each request over
//!    [`Network::try_inject`] backpressure and asserting the stall
//!    watchdog stays silent and credits are conserved.
//!
//! **The resolution identity.** Every offered request resolves exactly
//! once: `offered == delivered + shed + dropped + unreachable`
//! ([`ServingStats::consistent`]). `shed_deadline` is the subset of
//! `shed` refused for a predicted deadline miss; `deadline_missed` is
//! an *overlay* on `delivered` (late deliveries — only chaos faults or
//! shed-off overload can produce them) and is excluded from goodput.
//!
//! **Determinism.** All randomness flows from one seeded
//! `lexi_core::prng::Rng`, and every per-request draw (arrival gap,
//! burst-chain step, tenant, node) is consumed in a fixed order that
//! does **not** depend on the offered load — so a load sweep at a fixed
//! seed scales the same arrival trace, and p99 latency is monotone in
//! load by the pathwise Lindley argument. Identical seeds replay
//! identical [`ServingStats`], including across
//! `lexi_core::pool::run_sharded` thread counts.

use crate::compression::{CompressionMode, CrTable};
use crate::engine::Engine;
use crate::xval;
use lexi_core::batch::LaneCodec;
use lexi_core::error::Error;
use lexi_core::huffman::CodeBook;
use lexi_core::prng::Rng;
use lexi_core::stats::Histogram;
use lexi_hw::lane_cache::{LaneCache, PressureStats};
use lexi_models::activations;
use lexi_models::corpus::Corpus;
use lexi_models::traffic::{self, Endpoint, Phase, TransferKind, TransferSpec};
use lexi_models::{DegradeAction, DegradeController, HysteresisPolicy, ModelConfig, ModelScale};
use lexi_noc::{
    FaultModel, Network, NodeId, PacketSpec, RetryConfig, SimStats, StallReport, VcUsage,
};
use std::collections::VecDeque;

/// Arrival process shape.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceKind {
    /// Memoryless arrivals at the configured mean rate.
    Poisson,
    /// 2-state MMPP: calm/burst phases with [`BURST_FACTOR`]× the calm
    /// rate inside bursts, switched by a seeded Markov chain. The mean
    /// rate matches the Poisson trace at the same load.
    Burst,
}

impl TraceKind {
    /// Parse a CLI token.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "poisson" => Some(TraceKind::Poisson),
            "burst" => Some(TraceKind::Burst),
            _ => None,
        }
    }
}

/// Burst-phase rate multiplier of the MMPP trace.
pub const BURST_FACTOR: f64 = 4.0;
/// Per-arrival probability of entering a burst from calm.
pub const P_ENTER: f64 = 0.05;
/// Per-arrival probability of leaving a burst.
pub const P_EXIT: f64 = 0.2;
/// Stationary burst fraction `P_ENTER / (P_ENTER + P_EXIT)` and the
/// resulting mean-rate factor `1 + (BURST_FACTOR - 1) * fraction` the
/// calm rate is divided by so the MMPP mean matches the Poisson trace.
pub const BURST_MEAN_FACTOR: f64 = 1.0 + (BURST_FACTOR - 1.0) * (P_ENTER / (P_ENTER + P_EXIT));

/// A load surge over the head of the trace (used to script
/// degrade→recover round trips): the first `fraction` of requests
/// arrive at `multiplier`× the configured load.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Surge {
    pub fraction: f64,
    pub multiplier: f64,
}

/// Serving-workload parameters.
#[derive(Clone, Debug)]
pub struct ServingConfig {
    pub trace: TraceKind,
    /// Offered load as a fraction of fleet service capacity (1.0 =
    /// arrivals exactly match what the nodes can drain).
    pub load: f64,
    pub requests: usize,
    /// Per-request deadline; 0 = auto (25× the fleet mean session).
    pub deadline_ns: u64,
    pub seed: u64,
    /// Serving nodes (each a single-server bounded FIFO queue).
    pub nodes: usize,
    /// Admission-queue bound per node.
    pub queue_depth: usize,
    /// Decode tokens per session (session = prefill + tokens × step).
    pub decode_tokens: u32,
    /// `false` = shed-off baseline: no admission control at all (the
    /// unbounded-queue strawman the bench compares against).
    pub admission: bool,
    /// Client retry budget/backoff for queue-full refusals, in units of
    /// `mean_service / 8` per backoff step (the paper-default base of 8
    /// thus backs off one mean service time first).
    pub retry: RetryConfig,
    pub mode: CompressionMode,
    pub hysteresis: HysteresisPolicy,
    /// Arrivals per controller observation window.
    pub window: usize,
    pub surge: Option<Surge>,
    pub scale: ModelScale,
}

impl ServingConfig {
    /// Mixed three-tenant fleet at a moderate operating point.
    pub fn paper_default() -> Self {
        ServingConfig {
            trace: TraceKind::Poisson,
            load: 0.7,
            requests: 4000,
            deadline_ns: 0,
            seed: 9,
            nodes: 8,
            queue_depth: 16,
            decode_tokens: 32,
            admission: true,
            retry: RetryConfig::paper_default(),
            mode: CompressionMode::Lexi,
            hysteresis: HysteresisPolicy::paper_default(),
            window: 64,
            surge: None,
            scale: ModelScale::Tiny,
        }
    }
}

/// Outcome counters and latency digest of one serving run. Every field
/// is a pure function of the seed and config — [`PartialEq`] equality
/// between runs is the determinism contract.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ServingStats {
    pub offered: u64,
    pub delivered: u64,
    /// Typed [`Error::Shed`] refusals (includes `shed_deadline`).
    pub shed: u64,
    /// Subset of `shed`: refused because the predicted sojourn already
    /// exceeded the deadline (waiting cannot cure an absolute backlog).
    pub shed_deadline: u64,
    /// Chaos mode only: packets lost after the NACK-retry budget.
    pub dropped: u64,
    /// Chaos mode only: destination severed by permanent link failures.
    pub unreachable: u64,
    /// Overlay on `delivered`: completed *after* the deadline (late
    /// deliveries count against goodput but still resolve the request).
    pub deadline_missed: u64,
    /// Client admission retries consumed (not extra offered requests).
    pub retries: u64,
    pub degrades: u64,
    pub recoveries: u64,
    pub probes: u64,
    /// Controller transition log: `(window index, now degraded?)`.
    pub transitions: Vec<(u64, bool)>,
    pub p50_ns: u64,
    pub p99_ns: u64,
    pub p999_ns: u64,
    pub max_ns: u64,
    /// On-time deliveries per second of simulated span.
    pub goodput_rps: f64,
    /// First arrival to last completion.
    pub span_ns: u64,
    /// Shared lane-cache pressure under multi-tenant codebook churn.
    pub cache: PressureStats,
}

impl ServingStats {
    /// Requests that resolved to a terminal outcome.
    pub fn total_resolved(&self) -> u64 {
        self.delivered + self.shed + self.dropped + self.unreachable
    }

    /// The ISSUE 9 invariants: every request resolves exactly once and
    /// the overlay counters stay subsets of their bases.
    pub fn consistent(&self) -> bool {
        self.offered == self.total_resolved()
            && self.shed_deadline <= self.shed
            && self.deadline_missed <= self.delivered
    }
}

/// Per-tenant precomputed costs, indexed `[healthy, degraded]` by the
/// K/V codec state.
#[derive(Clone, Debug)]
struct TenantCost {
    /// Full-session service time (prefill + decode_tokens × step).
    service_ns: [f64; 2],
    /// Codec-port busy time the session charges (encode + decode
    /// makespans + runtime-Huffman startups). Zero for Raw classes —
    /// degrading K/V removes its share entirely.
    codec_ns: [f64; 2],
    /// v2 per-tenant `LaneStream` wire bytes (codebook + lanes), pinned
    /// by the encode/decode round trip at construction.
    wire_bytes: u64,
    /// Exponent pool feeding the shared lane cache per admitted request.
    exponents: Vec<u8>,
}

/// One serving node: a single-server FIFO with absolute completion
/// times. `completions` holds in-flight + queued completion stamps;
/// entries ≤ the observation time are popped lazily.
#[derive(Clone, Debug, Default)]
struct NodeQueue {
    busy_until: f64,
    completions: VecDeque<f64>,
}

/// How one admission attempt resolved.
enum Admit {
    /// Admitted; completion time.
    At(f64),
    /// Refused with the typed error; `true` = predicted deadline miss.
    Refused(Error, bool),
}

/// The serving simulator. [`ServingSim::new`] does the expensive
/// one-time setup (CR tables, per-tenant service tables and codebook
/// round trips); [`ServingSim::run`] re-derives all mutable state from
/// the seed, so repeated runs replay identically.
pub struct ServingSim {
    cfg: ServingConfig,
    /// The engine whose [`CodecPolicy`](lexi_models::CodecPolicy) the
    /// controller toggles — [`Engine::degraded_kinds`] is the
    /// observable round-trip surface.
    pub engine: Engine,
    tenants: Vec<TenantCost>,
    mean_service_ns: f64,
    /// Fleet codec-port capacity share: mean codec busy per mean
    /// service second. Normalizes port occupancy so a load of 1.0 reads
    /// as ≈1.0 through the (much faster) codec ports.
    codec_capacity: f64,
    deadline_ns: u64,
    /// Healthy-state cost of the single K/V recovery-probe transfer.
    probe_ns: f64,
}

/// Codec-port busy time one transfer charges under the engine's
/// current policy: encode + decode makespans plus the runtime-Huffman
/// startup. Zero when the transfer ships Raw (uncompressed classes and
/// degraded ones never touch the ports).
fn codec_busy_ns(engine: &Engine, crs: &CrTable, t: &TransferSpec, mode: CompressionMode) -> f64 {
    if !mode.compresses(t.kind) {
        return 0.0;
    }
    use lexi_core::codec::CodecKind;
    let codec = engine.codec_policy.codec_for(t.kind);
    if codec == CodecKind::Raw {
        return 0.0;
    }
    let mut ns = engine.decode_makespan_ns(t, crs) + engine.encode_makespan_ns(t);
    if codec == CodecKind::Huffman && t.kind != TransferKind::Weights {
        ns += engine.huffman_startup_ns();
    }
    ns
}

/// The small K/V transfer used as the recovery probe and the chaos
/// per-request payload: 2048 BF16 bytes fits one NoC packet even raw.
fn kv_probe_spec() -> TransferSpec {
    TransferSpec {
        phase: Phase::Decode(0),
        layer: 0,
        kind: TransferKind::KvCache,
        src: Endpoint::Memory,
        dst: Endpoint::Block(0),
        bytes: 2048,
    }
}

impl ServingSim {
    /// Build the fleet: measure CR tables, price every tenant session
    /// in both codec states, and round-trip each tenant's codebook
    /// through the v2 lane wire format.
    pub fn new(cfg: ServingConfig) -> Self {
        assert!(cfg.nodes >= 1, "need at least one serving node");
        assert!(cfg.window >= 1, "need at least one arrival per window");
        assert!(cfg.load > 0.0, "offered load must be positive");
        let corpus = Corpus::wikitext2();
        let fleet = [
            ModelConfig::jamba(cfg.scale),
            ModelConfig::zamba(cfg.scale),
            ModelConfig::qwen(cfg.scale),
        ];
        let mut engine = Engine::paper_default();
        let lane_codec = LaneCodec::new(16).expect("16 lanes within MAX_LANES");
        let mut tenants = Vec::with_capacity(fleet.len());
        let mut probe_ns = 0.0;
        for (i, mc) in fleet.iter().enumerate() {
            let crs = CrTable::measure(mc, cfg.seed ^ (i as u64 + 1));
            // Per-tenant codebook from this tenant's own K/V exponent
            // distribution, round-tripped through the v2 LaneStream
            // format — the wire bytes are what its sessions ship.
            let exps = activations::sample_exponents(
                mc,
                0,
                TransferKind::KvCache,
                cfg.seed ^ (0x9e3779b9 * (i as u64 + 1)),
                4096,
            );
            let book = CodeBook::lexi_default(&Histogram::from_bytes(&exps))
                .expect("non-empty exponent stream builds a codebook");
            let stream = lane_codec.encode(&exps, &book);
            let back = LaneCodec::decode_lockstep(&stream, &book)
                .expect("own-book lockstep decode is lossless");
            assert_eq!(back, exps, "tenant {i} codebook round trip");
            let mut cost = TenantCost {
                service_ns: [0.0; 2],
                codec_ns: [0.0; 2],
                wire_bytes: stream.wire_bytes() as u64,
                exponents: exps,
            };
            for state in 0..2 {
                if state == 1 {
                    engine.force_degrade(TransferKind::KvCache);
                }
                let mut service = 0.0;
                let mut codec = 0.0;
                for t in traffic::prefill(mc, &corpus) {
                    service += engine.transfer_ns(&t, cfg.mode, &crs);
                    codec += codec_busy_ns(&engine, &crs, &t, cfg.mode);
                }
                let mut step = 0.0;
                let mut step_codec = 0.0;
                for t in traffic::decode_step(mc, &corpus, 0) {
                    step += engine.transfer_ns(&t, cfg.mode, &crs);
                    step_codec += codec_busy_ns(&engine, &crs, &t, cfg.mode);
                }
                cost.service_ns[state] = service + f64::from(cfg.decode_tokens) * step;
                cost.codec_ns[state] = codec + f64::from(cfg.decode_tokens) * step_codec;
                if state == 1 {
                    engine.record_recovery(TransferKind::KvCache);
                }
            }
            if i == 0 {
                probe_ns = engine.transfer_ns(&kv_probe_spec(), cfg.mode, &crs);
            }
            tenants.push(cost);
        }
        let mean_service_ns =
            tenants.iter().map(|t| t.service_ns[0]).sum::<f64>() / tenants.len() as f64;
        let mean_codec_ns =
            tenants.iter().map(|t| t.codec_ns[0]).sum::<f64>() / tenants.len() as f64;
        let codec_capacity = (mean_codec_ns / mean_service_ns).max(1e-9);
        let deadline_ns = if cfg.deadline_ns == 0 {
            (25.0 * mean_service_ns).round() as u64
        } else {
            cfg.deadline_ns
        };
        ServingSim {
            cfg,
            engine,
            tenants,
            mean_service_ns,
            codec_capacity,
            deadline_ns,
            probe_ns,
        }
    }

    /// The deadline the run enforces (resolves the 0 = auto default).
    pub fn resolved_deadline_ns(&self) -> u64 {
        self.deadline_ns
    }

    /// Fleet mean session service time, healthy state.
    pub fn mean_service_ns(&self) -> f64 {
        self.mean_service_ns
    }

    /// One admission attempt against `queues[node]` at absolute time
    /// `at` for a request that arrived at `t` (≤ `at` after backoff).
    fn try_admit(
        &self,
        queues: &mut [NodeQueue],
        node: usize,
        t: f64,
        at: f64,
        service: f64,
    ) -> Admit {
        let q = &mut queues[node];
        while q.completions.front().is_some_and(|&c| c <= at) {
            q.completions.pop_front();
        }
        let depth = q.completions.len();
        let completion = q.busy_until.max(at) + service;
        if self.cfg.admission {
            let over_deadline = completion - t > self.deadline_ns as f64;
            if over_deadline || depth >= self.cfg.queue_depth {
                return Admit::Refused(
                    Error::Shed {
                        node: node as u16,
                        depth,
                        deadline_ns: self.deadline_ns,
                    },
                    over_deadline,
                );
            }
        }
        q.busy_until = completion;
        q.completions.push_back(completion);
        Admit::At(completion)
    }

    /// Run the trace and fold it into [`ServingStats`]. All mutable
    /// state is rebuilt from the seed: calling `run` twice replays the
    /// identical result (the determinism property test pins this).
    pub fn run(&mut self) -> ServingStats {
        let cfg = self.cfg.clone();
        // A previous run may have ended degraded; the controller and
        // policy always start a run healthy.
        self.engine.record_recovery(TransferKind::KvCache);
        let mut controller = DegradeController::new(cfg.hysteresis);
        let mut rng = Rng::new(cfg.seed);
        let mut queues = vec![NodeQueue::default(); cfg.nodes];
        let mut cache = LaneCache::new(8);
        let mut stats = ServingStats {
            offered: cfg.requests as u64,
            ..ServingStats::default()
        };
        let mut latencies: Vec<f64> = Vec::with_capacity(cfg.requests);
        let mut span_end = 0.0f64;

        // Mean inter-arrival gap: fleet capacity is `nodes` sessions in
        // parallel, so offered = load × capacity ⇒ gap = mean service /
        // (nodes × load). The MMPP trace divides its calm rate by
        // BURST_MEAN_FACTOR so its mean matches.
        let base_gap = self.mean_service_ns / (cfg.nodes as f64 * cfg.load);
        let surge_n = cfg
            .surge
            .map(|s| (s.fraction * cfg.requests as f64) as usize)
            .unwrap_or(0);
        let backoff_unit_ns = self.mean_service_ns / 8.0;

        let mut now = 0.0f64;
        let mut in_burst = false;
        let mut state = 0usize; // 0 healthy, 1 degraded (K/V codec)
        let mut window_start = 0.0f64;
        let mut window_arrivals = 0usize;
        let mut window_codec_ns = 0.0f64;
        // What the same window would have charged with the K/V codec
        // restored — the probe's view of whether recovery would re-trip.
        let mut window_codec_restored_ns = 0.0f64;
        let mut last_restored_occ = 0.0f64;
        let mut windows = 0u64;
        let mut probe_node = 0usize;

        for k in 0..cfg.requests {
            // Fixed per-request draw order keeps the RNG stream (and so
            // the whole arrival trace shape) independent of `load`.
            let u_state = rng.uniform();
            let u_gap = rng.uniform();
            let tenant = rng.below(self.tenants.len() as u64) as usize;
            let node = rng.below(cfg.nodes as u64) as usize;

            let mut gap_mean = match cfg.trace {
                TraceKind::Poisson => base_gap,
                TraceKind::Burst => {
                    in_burst = if in_burst {
                        u_state >= P_EXIT
                    } else {
                        u_state < P_ENTER
                    };
                    let calm = base_gap * BURST_MEAN_FACTOR;
                    if in_burst { calm / BURST_FACTOR } else { calm }
                }
            };
            if k < surge_n {
                gap_mean /= cfg.surge.expect("surge_n > 0 implies surge").multiplier;
            }
            now += -(1.0 - u_gap).ln() * gap_mean;

            let service = self.tenants[tenant].service_ns[state];
            let mut at = now;
            let mut attempt = 0u32;
            let outcome = loop {
                match self.try_admit(&mut queues, node, now, at, service) {
                    Admit::At(c) => break Ok(c),
                    Admit::Refused(e, deadline) => {
                        // A predicted deadline miss only worsens with
                        // waiting (absolute backlog); queue-full may
                        // clear, so only it earns the retry budget.
                        if deadline || attempt >= cfg.retry.budget {
                            break Err((e, deadline));
                        }
                        attempt += 1;
                        stats.retries += 1;
                        at += cfg.retry.backoff(attempt) as f64 * backoff_unit_ns;
                    }
                }
            };
            match outcome {
                Ok(completion) => {
                    stats.delivered += 1;
                    let sojourn = completion - now;
                    if sojourn > self.deadline_ns as f64 {
                        stats.deadline_missed += 1;
                    }
                    latencies.push(sojourn);
                    span_end = span_end.max(completion);
                    window_codec_ns += self.tenants[tenant].codec_ns[state];
                    window_codec_restored_ns += self.tenants[tenant].codec_ns[0];
                    // Multi-tenant codebook pressure on the shared lane
                    // cache: a slice of this tenant's exponent stream.
                    let pool = &self.tenants[tenant].exponents;
                    let off = (k * 8) % (pool.len() - 8);
                    for &e in &pool[off..off + 8] {
                        cache.access(e);
                    }
                }
                Err((Error::Shed { .. }, deadline)) => {
                    stats.shed += 1;
                    if deadline {
                        stats.shed_deadline += 1;
                    }
                }
                Err((e, _)) => unreachable!("admission only sheds: {e}"),
            }

            window_arrivals += 1;
            if window_arrivals == cfg.window {
                windows += 1;
                let span = (now - window_start).max(1.0);
                let norm = self.codec_capacity * cfg.nodes as f64 * span;
                let occ = (window_codec_ns / norm).min(4.0);
                last_restored_occ = (window_codec_restored_ns / norm).min(4.0);
                match controller.on_window(TransferKind::KvCache, occ, 0) {
                    DegradeAction::Degrade => {
                        state = 1;
                        self.engine.force_degrade(TransferKind::KvCache);
                        stats.transitions.push((windows, true));
                    }
                    DegradeAction::Probe => {
                        // One compressed K/V transfer tests the waters:
                        // healthy only if (a) a round-robin node would
                        // meet the deadline with it right now AND (b)
                        // restoring the codec would not immediately
                        // push port occupancy back over the calm line —
                        // admission keeps queues bounded, so (a) alone
                        // would pass under sustained overload and flap.
                        let n = probe_node % cfg.nodes;
                        probe_node += 1;
                        let sojourn = queues[n].busy_until.max(now) + self.probe_ns - now;
                        let healthy = sojourn <= self.deadline_ns as f64
                            && last_restored_occ <= cfg.hysteresis.occupancy_low;
                        if controller.on_probe_result(TransferKind::KvCache, healthy)
                            == DegradeAction::Recover
                        {
                            state = 0;
                            self.engine.record_recovery(TransferKind::KvCache);
                            stats.transitions.push((windows, false));
                        }
                    }
                    DegradeAction::None | DegradeAction::Recover => {}
                }
                window_start = now;
                window_arrivals = 0;
                window_codec_ns = 0.0;
                window_codec_restored_ns = 0.0;
            }
        }

        let (d, r, p) = controller.counts(TransferKind::KvCache);
        stats.degrades = d;
        stats.recoveries = r;
        stats.probes = p;
        stats.cache = cache.pressure();
        let mut sorted: Vec<u64> = latencies.iter().map(|&l| l.round() as u64).collect();
        sorted.sort_unstable();
        stats.p50_ns = pct(&sorted, 50, 100);
        stats.p99_ns = pct(&sorted, 99, 100);
        stats.p999_ns = pct(&sorted, 999, 1000);
        stats.max_ns = sorted.last().copied().unwrap_or(0);
        stats.span_ns = span_end.max(now).round() as u64;
        let on_time = stats.delivered - stats.deadline_missed;
        stats.goodput_rps = if stats.span_ns == 0 {
            0.0
        } else {
            on_time as f64 / (stats.span_ns as f64 * 1e-9)
        };
        debug_assert!(stats.consistent(), "resolution identity: {stats:?}");
        stats
    }

    /// Per-tenant v2 `LaneStream` wire bytes (codebook + lane payload).
    pub fn tenant_wire_bytes(&self) -> Vec<u64> {
        self.tenants.iter().map(|t| t.wire_bytes).collect()
    }
}

/// `sorted[(len-1) * num / den]`, 0 on empty.
fn pct(sorted: &[u64], num: u64, den: u64) -> u64 {
    if sorted.is_empty() {
        0
    } else {
        sorted[((sorted.len() - 1) as u64 * num / den) as usize]
    }
}

/// Chaos-soak parameters: the serving admission loop closed over the
/// *cycle-level* fault-injected network.
#[derive(Clone, Debug)]
pub struct ChaosConfig {
    pub seed: u64,
    pub requests: usize,
    /// Mean inter-arrival gap in network cycles.
    pub mean_gap_cycles: f64,
    pub deadline_ns: u64,
    /// BER/drop/dup probabilities plus scheduled permanent link kills
    /// and the NACK-retry policy, all in one seeded model.
    pub fault: FaultModel,
    pub max_cycles: u64,
    /// Stitched packages of the engine's mesh (ISSUE 10); 1 = the flat
    /// mesh the PR 9 soak ran on. At > 1 each request additionally
    /// draws a destination package, so K/V streams cross the
    /// gateway-row boundary links (the legacy draw order is untouched
    /// at 1 — seeded PR 9 traces replay bit-identically).
    pub packages: u8,
    /// Virtual channels per link; 1 = the PR 9 single-lane router.
    pub vcs: u8,
}

/// What the chaos soak resolved, plus the cycle-level evidence.
#[derive(Clone, Debug, PartialEq)]
pub struct ChaosReport {
    pub serving: ServingStats,
    pub noc: SimStats,
    /// Credit-conservation violations found by the post-drain audit
    /// (the invariant is 0 — at `vcs > 1` the audit checks every VC
    /// lane independently).
    pub credit_violations: usize,
    /// Per-VC activity after the drain (ISSUE 10): one entry per VC,
    /// all `buffered` fields 0 once the run completed.
    pub vc_usage: Vec<VcUsage>,
}

/// Drive seeded Poisson K/V-transfer arrivals through the cycle-level
/// network with the full ISSUE 6/7 fault machinery live. Each request
/// is one codec-tagged packet; [`Network::try_inject`] backpressure
/// maps to client retries under the network's own [`RetryConfig`] and
/// then to a typed [`Error::Shed`]. Errs iff the zero-progress
/// watchdog fires — the soak asserts it never does.
pub fn run_chaos(
    engine: &Engine,
    crs: &CrTable,
    cfg: &ChaosConfig,
) -> Result<ChaosReport, StallReport> {
    let packages = cfg.packages.max(1);
    let mut net: Network = xval::serving_network_on(
        engine,
        crs,
        TransferKind::KvCache,
        Some(cfg.fault.clone()),
        packages,
        cfg.vcs.max(1),
    );
    let retry = net.retry_config();
    let mode = CompressionMode::Lexi;
    let t = kv_probe_spec();

    // Pre-draw the whole arrival trace (gap, src memory node, dst
    // compute node — plus a destination package when stitched) so the
    // RNG stream is fixed up front. The package draw happens only at
    // `packages > 1`, so flat-mesh traces keep the PR 9 draw order.
    let mut rng = Rng::new(cfg.seed);
    let mem = &engine.system.memory_nodes;
    let compute = &engine.system.compute_nodes;
    let pkg_stride = engine.system.mesh.len() as u16;
    let mut arrivals: Vec<(u64, PacketSpec)> = Vec::with_capacity(cfg.requests);
    let mut now_f = 0.0f64;
    for _ in 0..cfg.requests {
        let u = rng.uniform();
        let src = mem[rng.below(mem.len() as u64) as usize];
        let mut dst = compute[rng.below(compute.len() as u64) as usize];
        if packages > 1 {
            let pkg = rng.below(packages as u64) as u16;
            dst = NodeId(dst.0 + pkg * pkg_stride);
        }
        now_f += -(1.0 - u).ln() * cfg.mean_gap_cycles;
        let specs = xval::tagged_specs_between(engine, crs, &t, mode, src, dst, 0);
        assert_eq!(specs.len(), 1, "2048-byte K/V transfer is one packet");
        arrivals.push((now_f.round() as u64, specs.into_iter().next().unwrap()));
    }

    let mut stats = ServingStats {
        offered: cfg.requests as u64,
        ..ServingStats::default()
    };
    // (ready_cycle, attempt, spec) — client-side backoff queue.
    let mut retry_q: VecDeque<(u64, u32, PacketSpec)> = VecDeque::new();
    let mut next = 0usize;
    while next < arrivals.len() || !retry_q.is_empty() {
        let now = net.now();
        // Due retries resolve before new arrivals (they are older).
        for _ in 0..retry_q.len() {
            let (ready, attempt, spec) = retry_q.pop_front().unwrap();
            if ready > now {
                retry_q.push_back((ready, attempt, spec));
                continue;
            }
            let mut s = spec.clone();
            s.inject_at = now;
            match net.try_inject(s) {
                Ok(()) => {}
                Err(Error::IngressSaturated { node, depth }) => {
                    if attempt < retry.budget {
                        stats.retries += 1;
                        retry_q.push_back((now + retry.backoff(attempt + 1), attempt + 1, spec));
                    } else {
                        stats.shed += 1;
                        let _typed = Error::Shed {
                            node,
                            depth,
                            deadline_ns: cfg.deadline_ns,
                        };
                    }
                }
                Err(Error::Unreachable { .. }) => stats.unreachable += 1,
                Err(e) => unreachable!("try_inject: {e}"),
            }
        }
        while next < arrivals.len() && arrivals[next].0 <= now {
            let mut s = arrivals[next].1.clone();
            s.inject_at = now;
            match net.try_inject(s) {
                Ok(()) => {}
                Err(Error::IngressSaturated { node, depth }) => {
                    if retry.budget > 0 {
                        stats.retries += 1;
                        retry_q.push_back((now + retry.backoff(1), 1, arrivals[next].1.clone()));
                    } else {
                        stats.shed += 1;
                        let _typed = Error::Shed {
                            node,
                            depth,
                            deadline_ns: cfg.deadline_ns,
                        };
                    }
                }
                Err(Error::Unreachable { .. }) => stats.unreachable += 1,
                Err(e) => unreachable!("try_inject: {e}"),
            }
            next += 1;
        }
        net.step();
        if net.now() > cfg.max_cycles {
            // Arrival phase overran the budget — surface as a stall so
            // the soak fails loudly instead of spinning.
            break;
        }
    }
    let noc = net.try_run_to_completion(cfg.max_cycles)?;
    let credit_violations = net.audit_credits().len();
    let vc_usage = net.vc_usage();

    stats.delivered = noc.delivered_packets;
    stats.dropped = noc.packets_dropped;
    stats.unreachable += noc.packets_unreachable;
    let cycle_ns = engine.cycle_ns();
    let mut lat: Vec<u64> = Vec::with_capacity(net.records.len());
    for r in &net.records {
        let ns = ((r.eject_cycle - r.spec.inject_at) as f64 * cycle_ns).round() as u64;
        if ns > cfg.deadline_ns {
            stats.deadline_missed += 1;
        }
        lat.push(ns);
    }
    lat.sort_unstable();
    stats.p50_ns = pct(&lat, 50, 100);
    stats.p99_ns = pct(&lat, 99, 100);
    stats.p999_ns = pct(&lat, 999, 1000);
    stats.max_ns = lat.last().copied().unwrap_or(0);
    stats.span_ns = (noc.completion_cycle as f64 * cycle_ns).round() as u64;
    let on_time = stats.delivered - stats.deadline_missed;
    stats.goodput_rps = if stats.span_ns == 0 {
        0.0
    } else {
        on_time as f64 / (stats.span_ns as f64 * 1e-9)
    };
    Ok(ChaosReport {
        serving: stats,
        noc,
        credit_violations,
        vc_usage,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use lexi_core::pool::run_sharded;
    use lexi_noc::NodeId;

    fn quick(load: f64, seed: u64) -> ServingConfig {
        ServingConfig {
            load,
            requests: 1500,
            seed,
            ..ServingConfig::paper_default()
        }
    }

    /// A controller that can never trip — isolates pure queueing.
    fn no_controller(mut cfg: ServingConfig) -> ServingConfig {
        cfg.hysteresis.occupancy_high = 1e12;
        cfg.hysteresis.strike_threshold = u32::MAX;
        cfg
    }

    #[test]
    fn p99_is_monotone_in_load_and_identity_holds() {
        // Same seed, rising load: the arrival trace is the same shape
        // (gaps scale linearly), so by the pathwise Lindley recursion
        // every queue only gets worse — p99 must be non-decreasing.
        let mut prev = 0u64;
        let mut prev_p50 = 0u64;
        for &load in &[0.3, 0.5, 0.7, 0.9] {
            // Shed-free configuration (deep queues, loose deadline):
            // shedding at higher loads would truncate the tail and
            // break the pathwise comparison this test pins.
            let mut cfg = no_controller(quick(load, 42));
            cfg.queue_depth = 10_000;
            cfg.deadline_ns = u64::MAX / 2;
            let mut sim = ServingSim::new(cfg);
            let s = sim.run();
            assert!(s.consistent(), "identity at load {load}: {s:?}");
            assert_eq!(s.shed, 0, "no sheds below saturation at depth 64");
            assert_eq!(s.dropped + s.unreachable, 0, "analytic mode");
            assert_eq!(
                s.deadline_missed, 0,
                "admission prediction keeps deliveries on time"
            );
            assert!(
                s.p99_ns >= prev && s.p50_ns >= prev_p50,
                "p99 {} < {prev} (or p50 {} < {prev_p50}) at load {load}",
                s.p99_ns,
                s.p50_ns,
            );
            prev = s.p99_ns;
            prev_p50 = s.p50_ns;
        }
    }

    #[test]
    fn beyond_saturation_sheds_are_typed_and_counted() {
        let mut sim = ServingSim::new(no_controller(quick(1.6, 7)));
        let s = sim.run();
        assert!(s.consistent(), "{s:?}");
        assert!(s.shed > 0, "load 1.6 must shed: {s:?}");
        assert!(s.retries > 0, "queue-full refusals earn retries first");
        assert_eq!(s.deadline_missed, 0, "admitted ⇒ on time in analytic mode");
        // The typed error is what admission hands back.
        let e = Error::Shed {
            node: 3,
            depth: 16,
            deadline_ns: 1000,
        };
        assert_eq!(
            e.to_string(),
            "request shed at node 3: admission queue depth 16 cannot meet the 1000 ns deadline"
        );
        // Shed-off strawman: everything delivered, but late — the
        // deadline misses surface as the overlay counter instead.
        let mut off = no_controller(quick(1.6, 7));
        off.admission = false;
        let s_off = ServingSim::new(off).run();
        assert!(s_off.consistent());
        assert_eq!(s_off.shed, 0);
        assert_eq!(s_off.delivered, s_off.offered);
        assert!(
            s_off.deadline_missed > 0,
            "unbounded queues at load 1.6 must run late: {s_off:?}"
        );
        assert!(s_off.p99_ns > s.p99_ns, "shedding bounds the tail");
    }

    #[test]
    fn burst_trace_same_mean_fatter_tail() {
        // The MMPP trace matches the Poisson mean rate but batches
        // arrivals — at the same load its p99 can only be worse (same
        // capacity, bursty offered process).
        let shed_free = |trace: TraceKind| {
            let mut cfg = no_controller(quick(0.7, 11));
            cfg.trace = trace;
            cfg.queue_depth = 10_000;
            cfg.deadline_ns = u64::MAX / 2;
            cfg
        };
        let mut poisson = ServingSim::new(shed_free(TraceKind::Poisson));
        let mut burst = ServingSim::new(shed_free(TraceKind::Burst));
        let sp = poisson.run();
        let sb = burst.run();
        assert!(sb.consistent() && sp.consistent());
        assert!(
            sb.p99_ns > sp.p99_ns,
            "burst p99 {} ≤ poisson p99 {}",
            sb.p99_ns,
            sp.p99_ns
        );
    }

    #[test]
    fn identical_seeds_replay_identical_stats_across_shards() {
        // Satellite 2: bit-identical stats — including shed / degrade /
        // recover counters — across repeated runs and across
        // run_sharded thread counts.
        let cfg_for = |seed: u64| {
            let mut c = quick(1.1, seed);
            c.surge = Some(Surge {
                fraction: 0.4,
                multiplier: 1.4,
            });
            c
        };
        let base: Vec<ServingStats> = (0..3)
            .map(|s| {
                let mut sim = ServingSim::new(cfg_for(s));
                let first = sim.run();
                // Reusing the sim replays identically too.
                assert_eq!(first, sim.run(), "seed {s} re-run drifted");
                first
            })
            .collect();
        for threads in [1usize, 2, 8] {
            let got = run_sharded(3, threads, |i| ServingSim::new(cfg_for(i as u64)).run());
            assert_eq!(got, base, "thread count {threads} changed results");
        }
    }

    #[test]
    fn surge_degrades_then_calm_recovers_visibly() {
        // Satellite 3 (integration): a hot head then a calm tail walks
        // the controller through degrade → probe → recover, observable
        // through the transition log AND Engine::degraded_kinds.
        let mut cfg = quick(0.35, 5);
        cfg.requests = 6000;
        cfg.surge = Some(Surge {
            fraction: 0.3,
            multiplier: 4.0,
        });
        let mut sim = ServingSim::new(cfg);
        let s = sim.run();
        assert!(s.consistent());
        assert!(s.degrades >= 1, "surge must trip the controller: {s:?}");
        assert!(s.recoveries >= 1, "calm tail must recover: {s:?}");
        assert!(s.probes >= s.recoveries);
        assert_eq!(
            s.transitions.first().map(|&(_, d)| d),
            Some(true),
            "first transition is the degrade"
        );
        assert_eq!(
            s.transitions.last().map(|&(_, d)| d),
            Some(false),
            "run ends recovered"
        );
        assert!(
            sim.engine.degraded_kinds().is_empty(),
            "engine policy restored after recovery"
        );
        // No flapping: consecutive transitions are at least the
        // hysteresis window apart on the controller clock.
        let guard = u64::from(sim.cfg.hysteresis.hysteresis_windows);
        for pair in s.transitions.windows(2) {
            assert!(
                pair[1].0 - pair[0].0 >= guard,
                "transitions too close: {:?}",
                s.transitions
            );
        }
        // Ending degraded is equally observable: sustained overload.
        let mut hot = quick(1.4, 5);
        hot.requests = 3000;
        let mut hot_sim = ServingSim::new(hot);
        let hs = hot_sim.run();
        assert!(hs.degrades >= 1, "{hs:?}");
        assert_eq!(
            hot_sim.engine.degraded_kinds(),
            vec![TransferKind::KvCache],
            "sustained overload leaves K/V degraded"
        );
    }

    #[test]
    fn tenant_codebooks_pressure_the_shared_lane_cache() {
        let mut sim = ServingSim::new(quick(0.5, 3));
        let wires = sim.tenant_wire_bytes();
        assert_eq!(wires.len(), 3);
        assert!(wires.iter().all(|&w| w > 0));
        let s = sim.run();
        let total = s.cache.hits + s.cache.misses;
        assert_eq!(total, s.delivered * 8, "8 exponents per admitted request");
        assert!(s.cache.evictions > 0, "three tenants churn an 8-entry cache");
        assert!(s.cache.evictions <= s.cache.misses);
    }

    #[test]
    fn chaos_soak_faults_linkdown_load_three_seeds() {
        // The full ISSUE 9 soak: BER + drops + dups + two permanent
        // link kills under sustained load, three seeds. Invariants: the
        // watchdog never fires, credits are conserved, every request
        // resolves exactly once, and the whole thing replays.
        let cfg_model = ModelConfig::qwen(ModelScale::Tiny);
        let engine = Engine::paper_default();
        let crs = CrTable::measure(&cfg_model, 0xC4A05);
        for seed in [1u64, 2, 3] {
            let fault = FaultModel::new(seed)
                .with_ber(2e-6)
                .with_drop(0.002)
                .with_dup(0.002)
                .with_link_down(NodeId(7), NodeId(8), 400)
                .with_link_down(NodeId(14), NodeId(20), 900);
            let chaos = ChaosConfig {
                seed,
                requests: 150,
                mean_gap_cycles: 40.0,
                deadline_ns: 40_000,
                fault,
                max_cycles: 5_000_000,
                packages: 1,
                vcs: 1,
            };
            let rep = run_chaos(&engine, &crs, &chaos).unwrap_or_else(|stall| {
                panic!("seed {seed}: watchdog fired: {stall}");
            });
            assert_eq!(rep.credit_violations, 0, "seed {seed}");
            let s = &rep.serving;
            assert!(s.consistent(), "seed {seed}: {s:?}");
            assert_eq!(s.offered, 150);
            assert!(s.delivered > 0, "seed {seed} delivered nothing");
            assert!(
                rep.noc.flits_corrupted + rep.noc.flits_dropped + rep.noc.flits_duplicated > 0,
                "seed {seed}: faults never fired"
            );
            assert_eq!(rep.noc.links_down, 2, "seed {seed}");
            // Deterministic replay of the full fault storm.
            let again = run_chaos(&engine, &crs, &chaos).expect("replay");
            assert_eq!(again, rep, "seed {seed} replay drifted");
        }
    }

    #[test]
    fn chaos_soak_on_stitched_multipackage_with_vcs() {
        // The PR 9 soak re-run on the ISSUE 10 fabric: 2 stitched
        // packages of the engine's 6×6 mesh, 2 VCs (payload on the
        // adaptive lane, VC 0 the up*/down* escape), BER + drops + dups
        // + one permanent link kill per package. Invariants: the
        // watchdog (including the per-VC starvation check) stays
        // silent, the per-VC credit audit is clean, every request
        // resolves exactly once, cross-package traffic actually flows,
        // and the whole storm replays bit-identically.
        let cfg_model = ModelConfig::qwen(ModelScale::Tiny);
        let engine = Engine::paper_default();
        let crs = CrTable::measure(&cfg_model, 0xC4A05);
        // 43↔49: an interior North-South link of package 1 ((1,1)–(1,2)
        // at stride 36); 7↔8 the same PR 9 kill inside package 0.
        let fault = FaultModel::new(5)
            .with_ber(2e-6)
            .with_drop(0.002)
            .with_dup(0.002)
            .with_link_down(NodeId(7), NodeId(8), 400)
            .with_link_down(NodeId(43), NodeId(49), 900);
        let chaos = ChaosConfig {
            seed: 5,
            requests: 150,
            mean_gap_cycles: 40.0,
            deadline_ns: 60_000,
            fault,
            max_cycles: 8_000_000,
            packages: 2,
            vcs: 2,
        };
        let rep = run_chaos(&engine, &crs, &chaos).unwrap_or_else(|stall| {
            panic!("multipackage watchdog fired: {stall}");
        });
        assert_eq!(rep.credit_violations, 0, "per-VC credit audit");
        let s = &rep.serving;
        assert!(s.consistent(), "resolution identity: {s:?}");
        assert_eq!(s.offered, 150);
        assert!(s.delivered > 0, "delivered nothing");
        assert_eq!(rep.noc.links_down, 2);
        assert!(
            rep.noc.flits_corrupted + rep.noc.flits_dropped + rep.noc.flits_duplicated > 0,
            "faults never fired"
        );
        // Per-VC evidence: the adaptive lane (VC 1) carried the payload
        // — unpinned packets never inject on the escape lane — and both
        // lanes drained to zero occupancy (anything left buffered after
        // completion would be a leak the credit audit might miss).
        assert_eq!(rep.vc_usage.len(), 2);
        assert!(rep.vc_usage.iter().all(|u| u.buffered == 0), "{:?}", rep.vc_usage);
        assert!(
            rep.vc_usage[1].delivered_flits > 0,
            "adaptive VC sat idle: {:?}",
            rep.vc_usage
        );
        let again = run_chaos(&engine, &crs, &chaos).expect("replay");
        assert_eq!(again, rep, "multipackage replay drifted");
    }
}
