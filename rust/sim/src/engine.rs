//! The end-to-end inference engine (paper §5.3).
//!
//! Full paper-scale workloads run through an analytic latency model (a
//! cycle-accurate walk over ~10⁹ NoI cycles is not tractable); the model
//! is cross-validated against the `lexi-noc` cycle simulator on small
//! windows by the [`crate::xval`] harness (ISSUE 5): the same transfer
//! replays through [`Engine::transfer_ns`] and through a codec-tagged
//! `Network` with egress decoder ports, with agreement pinned to 15% on
//! uncongested windows for every mode/policy and divergence *reported*
//! under congestion. `benches/perf_noc.rs` prints the same comparison.
//!
//! Per transfer: wire size under the compression mode (measured ratios),
//! wormhole latency = serialization flits + XY hops, plus the one-time
//! per-layer codec startup when compressing at runtime. A single inference
//! request is serial along the layer chain, so phase latency is the sum
//! over its transfers — matching the paper's "communication latency"
//! definition.
//!
//! **Makespan coupling (ISSUE 2):** compressed transfers additionally
//! consult the *measured* multi-lane decoder model. `CrTable::measure`
//! runs `lexi-hw`'s `DecoderUnit::decode_lane_stream` over representative
//! streams and caches the slowest-lane makespan per `(codec, kind,
//! lanes)`; [`Engine::transfer_ns`] converts that into a decode time for
//! the transfer's symbol count at [`Engine::decoder_lanes`] /
//! [`Engine::codec_ghz`]. Decoding is pipelined behind serialization
//! (symbols stream through the LUT lanes as flits arrive), so the
//! transfer only pays the *excess* of the decode makespan over the wire
//! time — zero when the lanes sustain line rate (the paper's operating
//! point), positive when an under-provisioned decoder throttles the link.
//! ISSUE 4 fronts the measured unit with the **multi-symbol LUT**
//! (grouped decode, > 1 symbol/lane/cycle on paper-entropy streams) and
//! charges the per-codebook table fill ([`Engine::lut_fill_cycles`] at
//! the codec clock) alongside the codebook startup, so the faster
//! makespans aren't free.
//!
//! **Codec policy (ISSUE 3):** [`Engine::codec_policy`] picks *which*
//! `ExpCodec` each traffic kind travels under when a mode compresses it
//! at all — wire bytes, decode makespan, and the codebook startup all
//! follow the policy's codec (only Huffman has a codebook pipeline; Raw
//! decodes for free). The default all-Huffman policy reproduces the
//! paper's numbers exactly.

use crate::compression::{CompressionMode, CrTable};
use crate::compute::ComputeModel;
use crate::simba::SimbaSystem;
use lexi_core::codec::CodecKind;
use lexi_models::corpus::Corpus;
use lexi_models::traffic::{self, Phase, TransferKind, TransferSpec};
use lexi_models::{CodecPolicy, DegradePolicy, DegradeTracker, ModelConfig};
use lexi_noc::traffic as noc_traffic;
use std::collections::HashMap;

/// Engine parameters.
#[derive(Clone, Debug)]
pub struct Engine {
    pub system: SimbaSystem,
    /// Flit width in bits (paper: 128).
    pub flit_bits: u32,
    /// Link bandwidth in Gbps (paper: 100).
    pub link_gbps: f64,
    pub compute: ComputeModel,
    /// One-time codebook-pipeline latency charged per runtime-compressed
    /// transfer (our measured 81-cycle worst case + sampling window at
    /// 1 GHz codec clock ≈ 170 ns; negligible against ms-scale layers).
    /// Only the Huffman codec has a codebook pipeline; BDI and Raw
    /// transfers never pay it.
    pub codec_startup_ns: f64,
    /// One-time multi-symbol LUT fill charged per runtime-compressed
    /// Huffman transfer (ISSUE 4): the receiver refills its 2^11-entry
    /// front table for every new codebook, `MultiLutSpec::fill_cycles()`
    /// ≈ 32 cycles. In **codec cycles**, converted at
    /// [`Engine::codec_ghz`] when charged (≈ 32 ns at the default
    /// 1 GHz), so it tracks the codec clock like the decode makespan
    /// does. Charged alongside [`Engine::codec_startup_ns`] so the sim
    /// doesn't get the grouped decode makespans for free; weights
    /// (offline-compressed, LUTs stream in with the data) and
    /// non-Huffman codecs never pay it.
    pub lut_fill_cycles: f64,
    /// Parallel LUT decoder lanes at each receiver. The paper's ten lanes
    /// saturate the link on stage-1-resident streams; sixteen keeps the
    /// measured makespan below the wire time on ESC-heavy layers too, so
    /// the default operating point matches the paper's claim that decode
    /// never throttles the link.
    pub decoder_lanes: usize,
    /// Parallel encode-LUT lanes at each sender (ISSUE 7 — the ingress
    /// twin of `decoder_lanes`). Encoding streams *into* the wire the
    /// way decode streams behind it, so a transfer pays only the excess
    /// of the encode makespan over the wire time. Sixteen single-cycle
    /// lanes (1/16 ns per symbol at 1 GHz) stay strictly under the wire
    /// time at any wire ratio < 2.56 — and exponent-only coding of
    /// 16-bit values caps the whole-transfer ratio below 2 — so the
    /// default operating point charges zero encode excess and the
    /// paper-point numbers are bit-identical to the pre-ingress engine.
    pub encoder_lanes: usize,
    /// Codec clock, GHz (Fig 6 latencies assume 1 cycle ≈ 1 ns).
    pub codec_ghz: f64,
    /// Which codec each traffic class travels under when compressed
    /// (ISSUE 3). The paper point is Huffman everywhere; swapping e.g.
    /// SSM state to BDI turns `run_modes` into a mixed-codec Table 3.
    pub codec_policy: CodecPolicy,
    /// Graceful-degradation threshold (ISSUE 6): decode failures a
    /// traffic class absorbs before [`Engine::record_decode_failures`]
    /// rewrites its codec to Raw.
    pub degrade: DegradePolicy,
    /// Per-kind decode-failure accounting backing `degrade`.
    degrade_tracker: DegradeTracker,
}

impl Engine {
    /// Paper operating point.
    pub fn paper_default() -> Self {
        Engine {
            system: SimbaSystem::paper_default(),
            flit_bits: 128,
            link_gbps: 100.0,
            compute: ComputeModel::default(),
            codec_startup_ns: 170.0,
            lut_fill_cycles: lexi_hw::decoder::MultiLutSpec::paper_default().fill_cycles()
                as f64,
            decoder_lanes: 16,
            encoder_lanes: 16,
            codec_ghz: 1.0,
            codec_policy: CodecPolicy::lexi_default(),
            degrade: DegradePolicy::paper_default(),
            degrade_tracker: DegradeTracker::new(),
        }
    }

    /// The paper engine under a different per-kind codec policy.
    pub fn with_policy(policy: CodecPolicy) -> Self {
        Engine {
            codec_policy: policy,
            ..Self::paper_default()
        }
    }

    /// Report `n` decode failures for `kind` (CRC NACKs that survived
    /// the NoC's retry budget, i.e. `SimStats::packets_dropped` on that
    /// class). Once the [`DegradePolicy`] threshold is reached the
    /// engine's [`CodecPolicy`] entry for the kind falls back to Raw —
    /// losslessness is preserved by *not compressing* rather than by
    /// stalling on retransmissions. Returns `true` iff this call
    /// degraded the class.
    pub fn record_decode_failures(&mut self, kind: TransferKind, n: u64) -> bool {
        let mut flipped = false;
        for _ in 0..n {
            flipped |= self
                .degrade_tracker
                .record_failure(kind, self.degrade, &mut self.codec_policy);
        }
        flipped
    }

    /// Decode failures recorded against `kind` so far.
    pub fn decode_failures(&self, kind: TransferKind) -> u32 {
        self.degrade_tracker.failures(kind)
    }

    /// Traffic classes degraded to Raw so far ([`TransferKind::ALL`]
    /// order) — the engine-stat surface for `lexi noc --ber` and
    /// reports.
    pub fn degraded_kinds(&self) -> Vec<TransferKind> {
        self.degrade_tracker.degraded_kinds()
    }

    /// Degrade `kind` to Raw immediately (ISSUE 9): the congestion
    /// controller — not a decode failure — decided the class must stop
    /// paying codec startup. The displaced codec is remembered for
    /// [`Engine::record_recovery`]. Returns `true` iff this call
    /// flipped the class.
    pub fn force_degrade(&mut self, kind: TransferKind) -> bool {
        self.degrade_tracker
            .force_degrade(kind, &mut self.codec_policy)
    }

    /// Restore a degraded class after a successful recovery probe
    /// (ISSUE 9): the codec it ran before degradation comes back and
    /// its strike count is zeroed. Returns `true` iff the class was
    /// degraded.
    pub fn record_recovery(&mut self, kind: TransferKind) -> bool {
        self.degrade_tracker.recover(kind, &mut self.codec_policy)
    }

    /// Duration of one flit on a link, ns.
    pub fn cycle_ns(&self) -> f64 {
        self.flit_bits as f64 / self.link_gbps
    }

    /// Total per-transfer Huffman startup: codebook pipeline + the
    /// multi-symbol LUT fill at the codec clock (ISSUE 4). What a
    /// runtime-compressed Huffman transfer pays before its decoder
    /// streams at line rate.
    pub fn huffman_startup_ns(&self) -> f64 {
        self.codec_startup_ns + self.lut_fill_cycles / self.codec_ghz
    }

    /// Flits a transfer occupies on every link of its route under
    /// `mode`: wire bytes segmented into `MAX_PACKET_BITS` NoC packets,
    /// each rounded up to whole flits — exactly what the cycle-level
    /// simulator ships (`lexi_noc::traffic::transfer_flits`).
    pub fn transfer_wire_flits(
        &self,
        t: &TransferSpec,
        mode: CompressionMode,
        crs: &CrTable,
    ) -> u64 {
        let codec = self.codec_policy.codec_for(t.kind);
        let wire_bits = crs.wire_bytes_for(codec, t.bytes, t.kind, mode) * 8;
        noc_traffic::transfer_flits(wire_bits, self.flit_bits, noc_traffic::MAX_PACKET_BITS)
    }

    /// Receiver-side decode makespan for a compressed transfer of `kind`,
    /// from the measured `(codec, kind, lanes)` cache: symbols ×
    /// cycles-per-symbol ÷ codec clock. The codec is the one this
    /// engine's [`CodecPolicy`] assigns to the kind.
    pub fn decode_makespan_ns(&self, t: &TransferSpec, crs: &CrTable) -> f64 {
        // One BF16 value (2 bytes) → one exponent symbol through the LUTs.
        let symbols = (t.bytes / 2).max(1);
        let codec = self.codec_policy.codec_for(t.kind);
        symbols as f64
            * crs.decode_cycles_per_symbol_for(codec, t.kind, self.decoder_lanes)
            / self.codec_ghz
    }

    /// Sender-side encode makespan for a compressed transfer of `kind`
    /// (ISSUE 7): symbols through [`Engine::encoder_lanes`] single-cycle
    /// encode-LUT lanes ([`lexi_hw::encoder::EncoderUnit`]) at the codec
    /// clock. Raw never touches the encoder.
    pub fn encode_makespan_ns(&self, t: &TransferSpec) -> f64 {
        let codec = self.codec_policy.codec_for(t.kind);
        if codec == CodecKind::Raw {
            return 0.0;
        }
        let symbols = (t.bytes / 2).max(1);
        let cps =
            lexi_hw::encoder::EncoderUnit::new(self.encoder_lanes.max(1)).cycles_per_symbol();
        symbols as f64 * cps / self.codec_ghz
    }

    /// Latency of one transfer under `mode`, with the codec chosen per
    /// kind by [`Engine::codec_policy`].
    pub fn transfer_ns(&self, t: &TransferSpec, mode: CompressionMode, crs: &CrTable) -> f64 {
        let codec = self.codec_policy.codec_for(t.kind);
        let wire_bytes = crs.wire_bytes_for(codec, t.bytes, t.kind, mode);
        let bits = wire_bytes * 8;
        let flits = bits.div_ceil(self.flit_bits as u64).max(1);
        let hops = self.system.hops(t.src, t.dst, t.layer) as u64;
        let wire_ns = flits as f64 * self.cycle_ns();
        let mut ns = wire_ns + hops as f64 * self.cycle_ns();
        if mode.compresses(t.kind) {
            // Makespan coupling: decode streams behind the arriving
            // flits, so only its excess over the wire time is exposed.
            let decode_ns = self.decode_makespan_ns(t, crs);
            if decode_ns > wire_ns {
                ns += decode_ns - wire_ns;
            }
            // Encode-side symmetry (ISSUE 7): the sender's encoder
            // streams into the wire, so only *its* excess over the wire
            // time is exposed too. Weights are compressed offline — no
            // runtime encoder in the path.
            if t.kind != TransferKind::Weights {
                let encode_ns = self.encode_makespan_ns(t);
                if encode_ns > wire_ns {
                    ns += encode_ns - wire_ns;
                }
            }
            // Runtime compression pays the codebook startup plus the
            // multi-symbol LUT fill (ISSUE 4); weights are compressed
            // offline (decompression LUTs stream in with the data), and
            // only Huffman has a codebook pipeline at all.
            if t.kind != TransferKind::Weights && codec == CodecKind::Huffman {
                ns += self.huffman_startup_ns();
            }
        }
        ns
    }

    /// Run a full inference; returns the latency report.
    pub fn run(
        &self,
        cfg: &ModelConfig,
        corpus: &Corpus,
        mode: CompressionMode,
        crs: &CrTable,
    ) -> E2eReport {
        let transfers = traffic::full_inference(cfg, corpus);
        let mut by_kind: HashMap<TransferKind, f64> = HashMap::new();
        let mut by_phase: HashMap<&'static str, f64> = HashMap::new();
        let mut comm_ns = 0.0;
        for t in &transfers {
            let ns = self.transfer_ns(t, mode, crs);
            comm_ns += ns;
            *by_kind.entry(t.kind).or_insert(0.0) += ns;
            *by_phase.entry(phase_name(t.phase)).or_insert(0.0) += ns;
        }
        let compute_ns = self.compute.total_ns(cfg, corpus);
        E2eReport {
            mode,
            comm_ns,
            compute_ns,
            by_kind,
            by_phase,
        }
    }

    /// Run all three modes (Table 3 row set).
    pub fn run_modes(&self, cfg: &ModelConfig, corpus: &Corpus, crs: &CrTable) -> Vec<E2eReport> {
        CompressionMode::ALL
            .iter()
            .map(|&m| self.run(cfg, corpus, m, crs))
            .collect()
    }
}

/// Multi-request (serving-style) report: `n` concurrent requests share
/// the NoI; decode throughput is bound by the busiest link.
#[derive(Clone, Debug)]
pub struct ConcurrentReport {
    pub mode: CompressionMode,
    pub n_requests: usize,
    /// Per-decode-step latency of one request running alone, ns.
    pub solo_step_ns: f64,
    /// Per-decode-step latency with n requests sharing the NoI, ns.
    pub shared_step_ns: f64,
    /// Aggregate decode throughput, tokens/s.
    pub tokens_per_s: f64,
}

impl Engine {
    /// Model `n_requests` concurrent single-token decode streams (the
    /// serving regime): each request's step is a serial chain, but the
    /// busiest directed link bounds how fast n chains can interleave.
    /// LEXI's wire reduction raises exactly that ceiling.
    pub fn run_concurrent(
        &self,
        cfg: &ModelConfig,
        corpus: &Corpus,
        mode: CompressionMode,
        crs: &CrTable,
        n_requests: usize,
    ) -> ConcurrentReport {
        let transfers = traffic::decode_step(cfg, corpus, 0);
        // One request's serial chain.
        let solo_step_ns: f64 = transfers
            .iter()
            .map(|t| self.transfer_ns(t, mode, crs))
            .sum();
        // Per-directed-link occupancy of one request's step (XY routes),
        // in **flits**: each transfer is segmented into NoC packets and
        // every packet rounds up to whole flits independently — the same
        // quantization (head/tail framing included) the cycle simulator
        // pays. (Regression, ISSUE 5: the old fractional
        // `busiest_bits / flit_bits` pricing undercharged the link and
        // let the concurrent ceiling drift from the cycle sim.)
        let mut link_flits: HashMap<(u16, u16), u64> = HashMap::new();
        for t in &transfers {
            let flits = self.transfer_wire_flits(t, mode, crs);
            let mut at = self.system.resolve(t.src, t.layer);
            let dst = self.system.resolve(t.dst, t.layer);
            while at != dst {
                let port = self.system.mesh.route_xy(at, dst);
                let next = self
                    .system
                    .mesh
                    .neighbour(at, port)
                    .expect("XY stays in-mesh");
                *link_flits.entry((at.0, next.0)).or_insert(0) += flits;
                at = next;
            }
        }
        let busiest_flits = link_flits.values().copied().max().unwrap_or(0);
        let bottleneck_ns = busiest_flits as f64 * n_requests as f64 * self.cycle_ns();
        // Compute also serializes per chiplet across requests.
        let compute_ns = self
            .compute
            .decode_step_ns(cfg, corpus.input_tokens as u64)
            * n_requests as f64;
        let shared_step_ns = solo_step_ns.max(bottleneck_ns).max(compute_ns);
        ConcurrentReport {
            mode,
            n_requests,
            solo_step_ns,
            shared_step_ns,
            tokens_per_s: n_requests as f64 / (shared_step_ns * 1e-9),
        }
    }
}

fn phase_name(p: Phase) -> &'static str {
    match p {
        Phase::WeightLoad => "weight-load",
        Phase::Prefill => "prefill",
        Phase::Decode(_) => "decode",
    }
}

/// End-to-end latency report.
#[derive(Clone, Debug)]
pub struct E2eReport {
    pub mode: CompressionMode,
    pub comm_ns: f64,
    pub compute_ns: f64,
    pub by_kind: HashMap<TransferKind, f64>,
    pub by_phase: HashMap<&'static str, f64>,
}

impl E2eReport {
    /// End-to-end latency (comm + compute; LEXI leaves compute unchanged).
    pub fn e2e_ns(&self) -> f64 {
        self.comm_ns + self.compute_ns
    }

    /// Communication share of end-to-end time.
    pub fn comm_fraction(&self) -> f64 {
        self.comm_ns / self.e2e_ns()
    }

    /// Milliseconds helper.
    pub fn comm_ms(&self) -> f64 {
        self.comm_ns / 1e6
    }

    /// Milliseconds helper.
    pub fn e2e_ms(&self) -> f64 {
        self.e2e_ns() / 1e6
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use lexi_models::ModelScale;
    use lexi_noc::traffic::segment_transfer;
    use lexi_noc::{Network, NetworkConfig, PacketSpec};

    fn setup(cfg: &ModelConfig) -> (Engine, CrTable) {
        (Engine::paper_default(), CrTable::measure(cfg, 42))
    }

    #[test]
    fn lexi_reduces_comm_in_paper_band() {
        // Table 3: LEXI cuts communication latency 33–45%.
        for cfg in ModelConfig::paper_models() {
            let (eng, crs) = setup(&cfg);
            for corpus in Corpus::all() {
                let unc = eng.run(&cfg, &corpus, CompressionMode::Uncompressed, &crs);
                let lexi = eng.run(&cfg, &corpus, CompressionMode::Lexi, &crs);
                let red = 1.0 - lexi.comm_ns / unc.comm_ns;
                assert!(
                    (0.25..0.50).contains(&red),
                    "{} {}: comm reduction {red:.3}",
                    cfg.name,
                    corpus.name
                );
            }
        }
    }

    #[test]
    fn weights_only_barely_helps() {
        // Table 3: compressed-weights-only ≈ 0.2–7% reduction.
        let cfg = ModelConfig::qwen(ModelScale::Paper);
        let (eng, crs) = setup(&cfg);
        let corpus = Corpus::wikitext2();
        let unc = eng.run(&cfg, &corpus, CompressionMode::Uncompressed, &crs);
        let w = eng.run(&cfg, &corpus, CompressionMode::WeightsOnly, &crs);
        let red = 1.0 - w.comm_ns / unc.comm_ns;
        assert!((0.0..0.10).contains(&red), "reduction {red:.4}");
    }

    #[test]
    fn comm_dominates_e2e_uncompressed() {
        // Paper: communication is 68–95% of end-to-end latency.
        for cfg in ModelConfig::paper_models() {
            let (eng, crs) = setup(&cfg);
            let r = eng.run(&cfg, &Corpus::wikitext2(), CompressionMode::Uncompressed, &crs);
            assert!(
                r.comm_fraction() > 0.55,
                "{}: comm fraction {:.3}",
                cfg.name,
                r.comm_fraction()
            );
        }
    }

    #[test]
    fn e2e_reduction_in_paper_band() {
        // Fig 7: 30–35% end-to-end reduction.
        for cfg in ModelConfig::paper_models() {
            let (eng, crs) = setup(&cfg);
            for corpus in Corpus::all() {
                let unc = eng.run(&cfg, &corpus, CompressionMode::Uncompressed, &crs);
                let lexi = eng.run(&cfg, &corpus, CompressionMode::Lexi, &crs);
                let red = 1.0 - lexi.e2e_ns() / unc.e2e_ns();
                assert!(
                    (0.20..0.45).contains(&red),
                    "{} {}: e2e reduction {red:.3}",
                    cfg.name,
                    corpus.name
                );
            }
        }
    }

    #[test]
    fn concurrency_saturates_and_lexi_lifts_the_ceiling() {
        // Serving regime: throughput grows with batch until the busiest
        // link saturates; LEXI's wire reduction raises the saturated
        // throughput by ~the wire ratio.
        let cfg = ModelConfig::qwen(ModelScale::Paper);
        let (eng, crs) = setup(&cfg);
        let corpus = Corpus::wikitext2();
        let tp = |mode, n| eng.run_concurrent(&cfg, &corpus, mode, &crs, n).tokens_per_s;

        // Monotone non-decreasing in n, with diminishing returns.
        let t1 = tp(CompressionMode::Uncompressed, 1);
        let t8 = tp(CompressionMode::Uncompressed, 8);
        let t64 = tp(CompressionMode::Uncompressed, 64);
        assert!(t8 >= t1 * 0.99);
        assert!(t64 <= t8 * 8.0);

        // At saturation, LEXI outperforms by roughly the wire ratio.
        let unc = tp(CompressionMode::Uncompressed, 64);
        let lexi = tp(CompressionMode::Lexi, 64);
        let gain = lexi / unc;
        assert!((1.2..1.8).contains(&gain), "gain {gain:.3}");
    }

    #[test]
    fn underprovisioned_decoder_throttles_compressed_transfers_only() {
        // Makespan coupling: with one decode lane the measured makespan
        // exceeds the wire time and the transfer pays the difference;
        // uncompressed transfers never touch the decoder model.
        let cfg = ModelConfig::qwen(ModelScale::Paper);
        let (eng, crs) = setup(&cfg);
        let mut starved = eng.clone();
        starved.decoder_lanes = 1;
        let corpus = Corpus::wikitext2();
        let transfers = traffic::decode_step(&cfg, &corpus, 0);
        // Largest transfer: big enough that per-transfer startup
        // constants are noise next to the per-symbol decode time.
        let t = transfers
            .iter()
            .filter(|t| t.bytes > 4096)
            .max_by_key(|t| t.bytes)
            .expect("a sizable transfer exists");

        let unc_full = eng.transfer_ns(t, CompressionMode::Uncompressed, &crs);
        let unc_starved = starved.transfer_ns(t, CompressionMode::Uncompressed, &crs);
        assert_eq!(unc_full, unc_starved, "uncompressed path consulted the decoder");

        let lexi_full = eng.transfer_ns(t, CompressionMode::Lexi, &crs);
        let lexi_starved = starved.transfer_ns(t, CompressionMode::Lexi, &crs);
        assert!(
            lexi_starved > lexi_full * 2.0,
            "1 lane ({lexi_starved:.0} ns) should be decode-bound vs 16 ({lexi_full:.0} ns)"
        );
        // ISSUE 4: a single 1 GHz lane now drains up to LUT_MAX_SYMS
        // symbols per probe-cycle, so the floor is a *quarter* symbol-ns
        // per symbol — and the grouped decode must visibly beat the old
        // ≥ 1 cycle/symbol bound (the faster makespans reached the
        // engine), while staying decode-bound.
        let symbols = (t.bytes / 2) as f64;
        assert!(lexi_starved >= symbols / lexi_core::lut::LUT_MAX_SYMS as f64);
        assert!(
            lexi_starved < symbols,
            "1-lane transfer ({lexi_starved:.0} ns) shows no multi-symbol speedup \
             over the 1 cycle/symbol floor ({symbols:.0} ns)"
        );
    }

    #[test]
    fn paper_point_encoder_is_invisible() {
        // ISSUE 7 pin: at the default 16 encode lanes the encode
        // makespan never exceeds the wire time (wire ratio < 2 <
        // 2.56), so the paper-point latencies are bit-identical to an
        // engine whose encoder is infinitely fast — the encode-side
        // refactor must not move any pinned number.
        let cfg = ModelConfig::qwen(ModelScale::Paper);
        let (eng, crs) = setup(&cfg);
        let mut free = eng.clone();
        free.encoder_lanes = 1 << 20; // effectively zero-cost encode
        let corpus = Corpus::wikitext2();
        for t in traffic::decode_step(&cfg, &corpus, 0) {
            for mode in CompressionMode::ALL {
                assert_eq!(
                    eng.transfer_ns(&t, mode, &crs),
                    free.transfer_ns(&t, mode, &crs),
                    "{:?} {mode:?}: encode excess charged at the paper point",
                    t.kind
                );
            }
        }
    }

    #[test]
    fn underprovisioned_encoder_throttles_compressed_transfers_only() {
        // One encode lane (1 ns/symbol at 1 GHz) is far above the
        // per-symbol wire time: compressed non-weight transfers become
        // encode-bound; uncompressed transfers and offline-compressed
        // weights never touch the runtime encoder.
        let cfg = ModelConfig::qwen(ModelScale::Paper);
        let (eng, crs) = setup(&cfg);
        let mut starved = eng.clone();
        starved.encoder_lanes = 1;
        let corpus = Corpus::wikitext2();
        let transfers = traffic::decode_step(&cfg, &corpus, 0);
        let t = transfers
            .iter()
            .filter(|t| t.kind != TransferKind::Weights && t.bytes > 4096)
            .max_by_key(|t| t.bytes)
            .expect("a sizable non-weight transfer exists");

        let unc_full = eng.transfer_ns(t, CompressionMode::Uncompressed, &crs);
        let unc_starved = starved.transfer_ns(t, CompressionMode::Uncompressed, &crs);
        assert_eq!(unc_full, unc_starved, "uncompressed path consulted the encoder");

        let lexi_full = eng.transfer_ns(t, CompressionMode::Lexi, &crs);
        let lexi_starved = starved.transfer_ns(t, CompressionMode::Lexi, &crs);
        assert!(
            lexi_starved > lexi_full * 2.0,
            "1 lane ({lexi_starved:.0} ns) should be encode-bound vs 16 ({lexi_full:.0} ns)"
        );
        // The bound is the encode makespan itself: symbols × 1 ns.
        let symbols = (t.bytes / 2) as f64;
        assert!(lexi_starved >= symbols);

        // Weights: compressed offline, encode-free at any lane count.
        for w in transfers.iter().filter(|t| t.kind == TransferKind::Weights) {
            assert_eq!(
                eng.transfer_ns(w, CompressionMode::Lexi, &crs),
                starved.transfer_ns(w, CompressionMode::Lexi, &crs),
                "weights paid a runtime encode"
            );
        }
    }

    #[test]
    fn line_rate_decoder_stays_hidden_behind_the_wire() {
        // At the paper operating point the decode makespan is pipelined
        // behind serialization: the coupled latency must stay within a
        // few percent of the wire-only latency for every compressed
        // transfer kind.
        let cfg = ModelConfig::qwen(ModelScale::Paper);
        let (eng, crs) = setup(&cfg);
        let corpus = Corpus::wikitext2();
        for t in traffic::decode_step(&cfg, &corpus, 0) {
            let coupled = eng.transfer_ns(&t, CompressionMode::Lexi, &crs);
            let wire_bytes = crs.wire_bytes(t.bytes, t.kind, CompressionMode::Lexi);
            let flits = (wire_bytes * 8).div_ceil(eng.flit_bits as u64).max(1);
            let hops = eng.system.hops(t.src, t.dst, t.layer) as u64;
            let wire_only = (flits + hops) as f64 * eng.cycle_ns()
                + if t.kind != TransferKind::Weights {
                    // Codebook pipeline + LUT fill (ISSUE 4).
                    eng.huffman_startup_ns()
                } else {
                    0.0
                };
            assert!(
                coupled <= wire_only * 1.10 + 1.0,
                "{:?}: coupled {coupled:.0} ns vs wire {wire_only:.0} ns",
                t.kind
            );
        }
    }

    #[test]
    fn lut_fill_charged_on_runtime_huffman_transfers_only() {
        // ISSUE 4: the multi-symbol table refill is a real startup cost —
        // exactly lut_fill_cycles/codec_ghz ns per runtime Huffman transfer,
        // never on weights (offline LUTs), never under a Raw policy.
        let cfg = ModelConfig::qwen(ModelScale::Paper);
        let (eng, crs) = setup(&cfg);
        assert!(eng.lut_fill_cycles > 0.0, "default engine must charge the fill");
        let fill_ns = eng.lut_fill_cycles / eng.codec_ghz;
        let mut free = eng.clone();
        free.lut_fill_cycles = 0.0;
        let corpus = Corpus::wikitext2();
        for t in traffic::decode_step(&cfg, &corpus, 0) {
            let a = eng.transfer_ns(&t, CompressionMode::Lexi, &crs);
            let b = free.transfer_ns(&t, CompressionMode::Lexi, &crs);
            if t.kind == TransferKind::Weights {
                assert_eq!(a, b, "{:?}: weights paid the runtime fill", t.kind);
            } else {
                assert!(
                    (a - b - fill_ns).abs() < 1e-9,
                    "{:?}: fill charge {} ≠ {fill_ns}",
                    t.kind,
                    a - b,
                );
            }
            // Uncompressed transfers never touch codec startup at all.
            let u1 = eng.transfer_ns(&t, CompressionMode::Uncompressed, &crs);
            let u2 = free.transfer_ns(&t, CompressionMode::Uncompressed, &crs);
            assert_eq!(u1, u2);
        }
        let raw = Engine::with_policy(CodecPolicy::uniform(CodecKind::Raw));
        let mut raw_free = raw.clone();
        raw_free.lut_fill_cycles = 0.0;
        for t in traffic::decode_step(&cfg, &corpus, 0) {
            assert_eq!(
                raw.transfer_ns(&t, CompressionMode::Lexi, &crs),
                raw_free.transfer_ns(&t, CompressionMode::Lexi, &crs),
                "raw transfers must not pay the Huffman LUT fill"
            );
        }
        // The fill is cycles at the codec clock: doubling the clock
        // halves its ns cost (unlike the fixed-ns codebook startup).
        let mut fast = eng.clone();
        fast.codec_ghz = 2.0;
        assert!(
            (fast.huffman_startup_ns() - (eng.codec_startup_ns + fill_ns / 2.0)).abs() < 1e-9,
            "LUT fill does not track the codec clock"
        );
    }

    #[test]
    fn raw_policy_neutralizes_compression() {
        // A uniform Raw policy under the Lexi mode must land within a
        // couple of percent of the uncompressed run (raw packing pays a
        // head flit per transfer, so it can only be slightly *worse*),
        // and must never pay the Huffman codebook startup.
        let cfg = ModelConfig::qwen(ModelScale::Paper);
        let (eng, crs) = setup(&cfg);
        let raw = Engine::with_policy(CodecPolicy::uniform(CodecKind::Raw));
        let corpus = Corpus::wikitext2();
        let unc = eng.run(&cfg, &corpus, CompressionMode::Uncompressed, &crs);
        let r = raw.run(&cfg, &corpus, CompressionMode::Lexi, &crs);
        let rel = r.comm_ns / unc.comm_ns;
        assert!((0.99..1.05).contains(&rel), "raw/unc comm ratio {rel:.4}");
    }

    #[test]
    fn codec_policies_order_like_their_wire_ratios() {
        // Mixed-codec Table 3 (ISSUE 3): all-Huffman < bdi-state hybrid
        // ≤ all-BDI < all-Raw ≈ uncompressed, on a hybrid model with SSM
        // traffic.
        let cfg = ModelConfig::jamba(ModelScale::Paper);
        let (_, crs) = setup(&cfg);
        let corpus = Corpus::wikitext2();
        let comm = |policy: CodecPolicy| {
            Engine::with_policy(policy)
                .run(&cfg, &corpus, CompressionMode::Lexi, &crs)
                .comm_ns
        };
        let huff = comm(CodecPolicy::lexi_default());
        let hybrid = comm(CodecPolicy::bdi_state());
        let bdi = comm(CodecPolicy::uniform(CodecKind::Bdi));
        let raw = comm(CodecPolicy::uniform(CodecKind::Raw));
        assert!(huff < hybrid, "huffman {huff:.0} vs hybrid {hybrid:.0}");
        assert!(hybrid <= bdi, "hybrid {hybrid:.0} vs bdi {bdi:.0}");
        assert!(bdi < raw, "bdi {bdi:.0} vs raw {raw:.0}");
    }

    #[test]
    fn default_policy_is_the_paper_point() {
        // The codec-policy refactor must not move the paper operating
        // point: an explicitly-all-Huffman engine is bit-for-bit the
        // default engine.
        let cfg = ModelConfig::qwen(ModelScale::Paper);
        let (eng, crs) = setup(&cfg);
        let explicit = Engine::with_policy(CodecPolicy::uniform(CodecKind::Huffman));
        let corpus = Corpus::wikitext2();
        for mode in CompressionMode::ALL {
            let a = eng.run(&cfg, &corpus, mode, &crs);
            let b = explicit.run(&cfg, &corpus, mode, &crs);
            assert_eq!(a.comm_ns, b.comm_ns, "{mode:?}");
        }
    }

    #[test]
    fn decode_failures_degrade_one_kind_to_raw_gracefully() {
        // ISSUE 6: after the DegradePolicy threshold, the failing class
        // stops being compressed (Raw), other classes keep their codec
        // bit-for-bit, and an engine with no recorded failures is the
        // untouched paper point.
        let cfg = ModelConfig::qwen(ModelScale::Paper);
        let (eng, crs) = setup(&cfg);
        let mut faulty = eng.clone();
        // Below the three-strike default: nothing changes.
        assert!(!faulty.record_decode_failures(TransferKind::Activation, 2));
        assert_eq!(faulty.codec_policy, eng.codec_policy);
        assert!(faulty.degraded_kinds().is_empty());
        // Third strike flips activations — and only activations.
        assert!(faulty.record_decode_failures(TransferKind::Activation, 1));
        assert_eq!(
            faulty.codec_policy.codec_for(TransferKind::Activation),
            CodecKind::Raw
        );
        assert_eq!(
            faulty.codec_policy.codec_for(TransferKind::KvCache),
            CodecKind::Huffman
        );
        assert_eq!(faulty.degraded_kinds(), vec![TransferKind::Activation]);
        assert_eq!(faulty.decode_failures(TransferKind::Activation), 3);
        // Degraded activations ship more wire flits (compression is
        // off); untouched kinds price identically.
        let corpus = Corpus::wikitext2();
        for t in traffic::decode_step(&cfg, &corpus, 0) {
            let a = eng.transfer_wire_flits(&t, CompressionMode::Lexi, &crs);
            let b = faulty.transfer_wire_flits(&t, CompressionMode::Lexi, &crs);
            if t.kind == TransferKind::Activation {
                if t.bytes > 4096 {
                    assert!(b > a, "{} bytes: raw {b} ≤ huffman {a} flits", t.bytes);
                }
            } else {
                assert_eq!(a, b, "{:?} repriced by an activation degrade", t.kind);
            }
        }
        // Degradation is graceful, not destructive: the run completes
        // and only the activation share moves.
        let base = eng.run(&cfg, &corpus, CompressionMode::Lexi, &crs);
        let deg = faulty.run(&cfg, &corpus, CompressionMode::Lexi, &crs);
        assert_eq!(
            base.by_kind[&TransferKind::KvCache],
            deg.by_kind[&TransferKind::KvCache]
        );
        assert_ne!(
            base.by_kind[&TransferKind::Activation],
            deg.by_kind[&TransferKind::Activation]
        );
    }

    #[test]
    fn forced_degrade_round_trip_restores_the_paper_point() {
        // ISSUE 9: congestion-driven degrade + probe-driven recovery
        // must be lossless on the engine — after the round trip every
        // price equals the untouched paper point bit-for-bit.
        let cfg = ModelConfig::jamba(ModelScale::Paper);
        let (eng, crs) = setup(&cfg);
        let corpus = Corpus::wikitext2();
        let mut hot = eng.clone();
        assert!(hot.force_degrade(TransferKind::KvCache));
        assert!(!hot.force_degrade(TransferKind::KvCache), "idempotent");
        assert_eq!(hot.degraded_kinds(), vec![TransferKind::KvCache]);
        assert_eq!(
            hot.codec_policy.codec_for(TransferKind::KvCache),
            CodecKind::Raw
        );
        // Degraded KV is cheaper per small transfer (no Huffman
        // startup): that is the congestion-relief mechanism the
        // serving controller relies on.
        let mut kv = traffic::decode_step(&cfg, &corpus, 0)
            .into_iter()
            .find(|t| t.kind == TransferKind::KvCache)
            .expect("jamba decode step has a KV transfer");
        kv.bytes = 2048;
        assert!(
            hot.transfer_ns(&kv, CompressionMode::Lexi, &crs)
                < eng.transfer_ns(&kv, CompressionMode::Lexi, &crs),
            "raw small KV should undercut huffman startup"
        );
        assert!(hot.record_recovery(TransferKind::KvCache));
        assert!(!hot.record_recovery(TransferKind::KvCache), "idempotent");
        assert!(hot.degraded_kinds().is_empty());
        assert_eq!(hot.codec_policy, eng.codec_policy);
        assert_eq!(hot.decode_failures(TransferKind::KvCache), 0);
        let base = eng.run(&cfg, &corpus, CompressionMode::Lexi, &crs);
        let back = hot.run(&cfg, &corpus, CompressionMode::Lexi, &crs);
        assert_eq!(base.by_kind, back.by_kind);
    }

    #[test]
    fn concurrent_ceiling_charges_packet_quantized_flits() {
        // Regression (ISSUE 5 satellite): run_concurrent priced the
        // busiest link as fractional `busiest_bits / flit_bits` while
        // transfer_ns (and the cycle-level NoC) quantize per packet —
        // the ceiling must charge whole flits per segmented packet.
        use lexi_noc::NodeId;
        use lexi_noc::traffic::MAX_PACKET_BITS;
        let cfg = ModelConfig::qwen(ModelScale::Paper);
        let (eng, crs) = setup(&cfg);
        let corpus = Corpus::wikitext2();
        let transfers = traffic::decode_step(&cfg, &corpus, 0);
        // Transfer-by-transfer: the engine's flit pricing equals the
        // cycle simulator's segmentation arithmetic exactly.
        for t in &transfers {
            for mode in CompressionMode::ALL {
                let codec = eng.codec_policy.codec_for(t.kind);
                let wire_bits = crs.wire_bytes_for(codec, t.bytes, t.kind, mode) * 8;
                let want: u64 =
                    segment_transfer(NodeId(0), NodeId(1), wire_bits, 0, MAX_PACKET_BITS)
                        .iter()
                        .map(|s| s.flits(eng.flit_bits) as u64)
                        .sum();
                assert_eq!(
                    eng.transfer_wire_flits(t, mode, &crs),
                    want,
                    "{:?} {mode:?}",
                    t.kind
                );
            }
        }
        // Link-level: replay the route walk with both pricings; the
        // quantized ceiling is strictly higher (real transfers are not
        // flit-multiples) and is what run_concurrent now reports.
        let mode = CompressionMode::Lexi;
        let mut link_bits: HashMap<(u16, u16), u64> = HashMap::new();
        let mut link_flits: HashMap<(u16, u16), u64> = HashMap::new();
        for t in &transfers {
            let codec = eng.codec_policy.codec_for(t.kind);
            let wire_bits = crs.wire_bytes_for(codec, t.bytes, t.kind, mode) * 8;
            let flits = eng.transfer_wire_flits(t, mode, &crs);
            let mut at = eng.system.resolve(t.src, t.layer);
            let dst = eng.system.resolve(t.dst, t.layer);
            while at != dst {
                let port = eng.system.mesh.route_xy(at, dst);
                let next = eng.system.mesh.neighbour(at, port).expect("in-mesh");
                *link_bits.entry((at.0, next.0)).or_insert(0) += wire_bits;
                *link_flits.entry((at.0, next.0)).or_insert(0) += flits;
                at = next;
            }
        }
        let n = 256usize;
        let frac_ns = link_bits.values().copied().max().unwrap() as f64 * n as f64
            / eng.flit_bits as f64
            * eng.cycle_ns();
        let quant_ns =
            link_flits.values().copied().max().unwrap() as f64 * n as f64 * eng.cycle_ns();
        assert!(
            quant_ns > frac_ns,
            "quantization should cost extra flits ({quant_ns} vs {frac_ns})"
        );
        let rep = eng.run_concurrent(&cfg, &corpus, mode, &crs, n);
        assert!(
            rep.shared_step_ns >= quant_ns - 1e-6,
            "ceiling {} below the quantized link bound {quant_ns}",
            rep.shared_step_ns
        );
    }

    #[test]
    fn analytic_matches_cycle_sim_for_single_transfer() {
        // Cross-validation: one uncongested transfer's analytic latency
        // must match the cycle-accurate NoC within 20%.
        let cfg = ModelConfig::jamba(ModelScale::Tiny);
        let (eng, crs) = setup(&cfg);
        let corpus = Corpus::wikitext2();
        let transfers = traffic::decode_step(&cfg, &corpus, 0);
        let t = transfers
            .iter()
            .find(|t| t.bytes > 4096)
            .expect("a sizable transfer exists");

        let analytic_ns = eng.transfer_ns(t, CompressionMode::Uncompressed, &crs);

        let ncfg = NetworkConfig::paper_default();
        let src = eng.system.resolve(t.src, t.layer);
        let dst = eng.system.resolve(t.dst, t.layer);
        let specs: Vec<PacketSpec> = segment_transfer(src, dst, t.bytes * 8, 0, u64::MAX);
        let mut net = Network::new(ncfg);
        net.schedule_packets(&specs);
        let stats = net.run_to_completion(10_000_000);
        let cycle_ns = stats.cycles as f64 * ncfg.cycle_ns();

        let err = (analytic_ns - cycle_ns).abs() / cycle_ns;
        assert!(
            err < 0.2,
            "analytic {analytic_ns:.1} ns vs cycle {cycle_ns:.1} ns (err {err:.3})"
        );
    }
}
