//! # lexi-sim — Simba chiplet system model and end-to-end engine
//!
//! Glues the substrates together into the paper's evaluation platform
//! (§5.1): a 6×6 homogeneous chiplet array on a 2D-mesh NoI with 100 Gbps
//! links, block-level kernel mapping, memory chiplets holding weights and
//! hybrid caches, and LEXI codecs at every router ingress/egress.
//!
//! * [`simba`] — the array: memory-node placement, block→chiplet mapping,
//!   endpoint resolution.
//! * [`compression`] — compression modes (uncompressed / weights-only /
//!   LEXI) and measured per-kind wire ratios (value-level, including sign
//!   + mantissa passthrough and flit framing).
//! * [`compute`] — per-block compute-latency model (keeps computation
//!   constant across modes, as the paper notes).
//! * [`engine`] — the end-to-end analytic engine (full paper-scale
//!   workloads) with a cycle-accurate NoC cross-check for small windows.
//! * [`xval`] — the analytic ↔ cycle cross-validation harness (ISSUE 5):
//!   replays the same transfers through `Engine::transfer_ns` and a
//!   codec-tagged `lexi-noc` network with egress decoder ports, pinning
//!   the agreement bands.
//! * [`serving`] — open-loop trace-driven multi-tenant serving (ISSUE 9):
//!   seeded Poisson/bursty arrivals over a mixed fleet with
//!   deadline-aware admission (typed load-shedding + capped-backoff
//!   retries), hysteresis-controlled congestion degradation, and a
//!   chaos soak over the fault-injected cycle-level network.

pub mod compression;
pub mod compute;
pub mod energy;
pub mod engine;
pub mod serving;
pub mod simba;
pub mod xval;

pub use compression::{CompressionMode, CrTable};
pub use engine::{E2eReport, Engine};
pub use serving::{
    run_chaos, ChaosConfig, ChaosReport, ServingConfig, ServingSim, ServingStats, Surge, TraceKind,
};
pub use simba::SimbaSystem;
pub use xval::XvalReport;
