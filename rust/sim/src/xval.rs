//! Analytic ↔ cycle-level cross-validation (ISSUE 5).
//!
//! The analytic [`Engine`] prices full paper-scale workloads; the
//! `lexi-noc` cycle simulator walks individual flits. This module replays
//! the **same transfer** through both — [`Engine::transfer_ns`] on one
//! side, a codec-tagged [`Network`] with egress decoder ports on the
//! other — and reports the disagreement, making the engine's
//! cross-validation claim checkable instead of asserted.
//!
//! Agreement contract (pinned by the tests below):
//!
//! * **Uncongested single transfers** agree within
//!   [`UNCONGESTED_BAND`] (15%) for every [`CompressionMode`] and for
//!   both the all-Huffman default and the BDI-state mixed policy. Both
//!   models charge the same wire bytes, the same measured decoder rate
//!   (`CrTable::decode_cycles_per_symbol_for` at the engine's lane
//!   count) and the same runtime-Huffman startup, so the residual is
//!   pipeline constants (≈ hops + a few cycles) over ≥ hundreds of
//!   flits.
//! * **Decode-bound direction** agrees: at `decoder_lanes = 1` both
//!   models stretch a compressed transfer well past its wire time (the
//!   cycle sim via egress backpressure, the engine via makespan
//!   coupling); at the 16-lane paper point both sit at line rate.
//! * **Congestion diverges, and is reported**: the analytic model has no
//!   contention term, so hotspot replays are expected outside the band —
//!   [`XvalReport::congested`] marks them and [`XvalReport::in_band`]
//!   is only claimed for uncongested runs.

use crate::compression::{CompressionMode, CrTable};
use crate::engine::Engine;
use lexi_core::codec::CodecKind;
use lexi_models::traffic::{TransferKind, TransferSpec};
use lexi_noc::traffic::{segment_transfer, segment_transfer_tagged, MAX_PACKET_BITS};
use lexi_noc::{
    CodecTag, EgressCodecConfig, FaultModel, IngressCodecConfig, MultiPackage, Network,
    NetworkConfig, NodeId, PacketSpec, Topo, Topology,
};

/// Maximum relative disagreement tolerated on uncongested
/// single-transfer windows.
pub const UNCONGESTED_BAND: f64 = 0.15;

/// One analytic-vs-cycle comparison.
#[derive(Clone, Debug)]
pub struct XvalReport {
    pub mode: CompressionMode,
    pub kind: TransferKind,
    /// Codec the engine's policy assigned to this kind.
    pub codec: CodecKind,
    /// Uncompressed transfer size, bytes.
    pub bytes: u64,
    pub analytic_ns: f64,
    pub cycle_ns: f64,
    /// Egress decoder stall cycles observed in the cycle run.
    pub decode_stall_cycles: u64,
    /// Ingress encoder stall cycles observed in the cycle run (ISSUE 7)
    /// — 0 unless the replay attached ingress codec ports
    /// ([`replay_transfer_duplex`]).
    pub encode_stall_cycles: u64,
    /// Packet retransmissions the cycle run needed (ISSUE 6) — 0 when
    /// no fault model is attached or its rates are zero.
    pub retries: u64,
    /// Packets the cycle run abandoned after the retry budget.
    pub dropped: u64,
    /// Wormholes severed mid-flight by a permanent link failure and
    /// truncated for retry (ISSUE 7).
    pub truncated: u64,
    /// Packets whose destination was disconnected by permanent link
    /// failures — typed loss, never a hang (ISSUE 7).
    pub unreachable: u64,
    /// Replayed under deliberate contention: divergence is expected and
    /// reported, not bounded.
    pub congested: bool,
}

impl XvalReport {
    /// Relative disagreement, cycle-referenced.
    pub fn rel_err(&self) -> f64 {
        if self.cycle_ns == 0.0 {
            if self.analytic_ns == 0.0 {
                0.0
            } else {
                f64::INFINITY
            }
        } else {
            (self.analytic_ns - self.cycle_ns).abs() / self.cycle_ns
        }
    }

    /// Does this (uncongested) replay meet the agreement contract?
    pub fn in_band(&self) -> bool {
        !self.congested && self.rel_err() < UNCONGESTED_BAND
    }

    /// One human-readable row (benches and the congestion report).
    pub fn row(&self) -> String {
        format!(
            "{:?}/{:?} ({} B, {:?}): analytic {:.0} ns vs cycle {:.0} ns, err {:.1}%{}",
            self.mode,
            self.kind,
            self.bytes,
            self.codec,
            self.analytic_ns,
            self.cycle_ns,
            self.rel_err() * 100.0,
            if self.congested { " [congested]" } else { "" }
        ) + &if self.retries > 0 || self.dropped > 0 {
            format!(" [retries {}, dropped {}]", self.retries, self.dropped)
        } else {
            String::new()
        } + &if self.truncated > 0 || self.unreachable > 0 {
            format!(
                " [truncated {}, unreachable {}]",
                self.truncated, self.unreachable
            )
        } else {
            String::new()
        }
    }
}

/// The cycle-sim twin of an engine's link parameters (single-VC flat
/// mesh — the pre-ISSUE-10 operating point, bit-for-bit).
pub fn network_config_for(engine: &Engine) -> NetworkConfig {
    NetworkConfig {
        topo: Topo::Mesh(engine.system.mesh),
        vcs: 1,
        flit_bits: engine.flit_bits,
        link_gbps: engine.link_gbps,
        buf_depth: 4,
    }
}

/// [`network_config_for`] with `vcs` virtual channels (ISSUE 10). The
/// buffer budget scales with the channel count so every VC lane keeps
/// ≥ 2 credits: sustaining one flit per cycle needs one credit in
/// flight plus one returning, so a 1-credit lane would halve the link
/// rate and put even an uncongested replay out of band — a flow-control
/// artefact, not a modelling disagreement. At `vcs = 1` this is exactly
/// [`network_config_for`].
pub fn vc_network_config_for(engine: &Engine, vcs: u8) -> NetworkConfig {
    let mut cfg = network_config_for(engine).with_vcs(vcs);
    cfg.buf_depth = cfg.buf_depth.max(2 * vcs as u32);
    cfg
}

/// The egress decoder config matching what [`Engine::transfer_ns`]
/// charges for `kind`: measured effective rates at the engine's lane
/// count for every codec, and the engine's runtime-Huffman startup.
pub fn egress_config_for(engine: &Engine, crs: &CrTable, kind: TransferKind) -> EgressCodecConfig {
    let mut cfg = EgressCodecConfig::nominal(engine.decoder_lanes, engine.codec_ghz);
    cfg.startup_ns = engine.huffman_startup_ns();
    for codec in CodecKind::ALL {
        cfg.set_rate(
            codec,
            crs.decode_cycles_per_symbol_for(codec, kind, engine.decoder_lanes),
        );
    }
    cfg
}

/// The ingress encoder config matching what
/// [`Engine::encode_makespan_ns`] charges (ISSUE 7): the engine's
/// encoder lane count at its codec clock, with the codebook-pipeline
/// share of the runtime-Huffman startup
/// ([`Engine::codec_startup_ns`]).
pub fn ingress_config_for(engine: &Engine) -> IngressCodecConfig {
    let mut cfg = IngressCodecConfig::nominal(engine.encoder_lanes, engine.codec_ghz);
    cfg.startup_ns = engine.codec_startup_ns;
    cfg
}

/// Matched ingress + egress configs for a **duplex** replay. The
/// runtime-Huffman startup is split so the pair charges
/// [`Engine::huffman_startup_ns`] exactly once per packet: the
/// codebook-pipeline share at the encoder (head injection), the
/// LUT-fill share at the decoder (head ejection) — the split the
/// `lexi-noc` ingress tests pin.
pub fn duplex_configs_for(
    engine: &Engine,
    crs: &CrTable,
    kind: TransferKind,
) -> (IngressCodecConfig, EgressCodecConfig) {
    let icfg = ingress_config_for(engine);
    let mut ecfg = egress_config_for(engine, crs, kind);
    ecfg.startup_ns = (engine.huffman_startup_ns() - icfg.startup_ns).max(0.0);
    (icfg, ecfg)
}

/// A full-duplex network matched to this engine for serving-trace
/// replays (ISSUE 9): ingress + egress codec ports split the
/// runtime-Huffman startup the way [`duplex_configs_for`] pins, an
/// optional fault model brings the ISSUE 6/7 machinery (BER, drops,
/// dups, permanent link kills, NACK retry policy), and the default
/// zero-progress watchdog stays armed. `lexi_sim::serving::run_chaos`
/// closes its admission loop over this network's
/// [`Network::try_inject`] backpressure.
pub fn serving_network(
    engine: &Engine,
    crs: &CrTable,
    kind: TransferKind,
    fault: Option<FaultModel>,
) -> Network {
    serving_network_on(engine, crs, kind, fault, 1, 1)
}

/// [`serving_network`] generalized over the ISSUE 10 axes: `vcs`
/// virtual channels and, at `packages > 1`, a stitched multi-package
/// array of the engine's mesh (endpoints `0..mesh.len()` stay package
/// 0, so engine-resolved sources remain valid and cross-package
/// destinations are the caller's projection). At `(1, 1)` this is
/// exactly [`serving_network`], bit for bit.
pub fn serving_network_on(
    engine: &Engine,
    crs: &CrTable,
    kind: TransferKind,
    fault: Option<FaultModel>,
    packages: u8,
    vcs: u8,
) -> Network {
    let (icfg, ecfg) = duplex_configs_for(engine, crs, kind);
    let mut ncfg = vc_network_config_for(engine, vcs);
    if packages > 1 {
        let mesh = engine.system.mesh;
        ncfg.topo = Topo::MultiPackage(MultiPackage::new(packages, mesh.cols, mesh.rows));
    }
    let mut net = Network::with_egress(ncfg, ecfg);
    net.set_ingress_config(icfg);
    if let Some(f) = fault {
        net.set_fault_model(f);
    }
    net
}

/// The [`CodecTag`] a transfer travels under through this engine's
/// policy, or `None` when `mode` leaves it uncompressed: one exponent
/// symbol per BF16 value, runtime-book startup on non-weight Huffman.
/// The single source of the tagging rule — every replayer (this
/// harness, `noc_explorer`, `e2e_inference`) goes through it.
pub fn transfer_tag(engine: &Engine, t: &TransferSpec, mode: CompressionMode) -> Option<CodecTag> {
    if !mode.compresses(t.kind) {
        return None;
    }
    let codec = engine.codec_policy.codec_for(t.kind);
    Some(CodecTag {
        kind: codec,
        symbols: (t.bytes / 2).max(1),
        runtime_book: t.kind != TransferKind::Weights && codec == CodecKind::Huffman,
    })
}

/// The codec-tagged packet set a transfer becomes on the wire under
/// `mode` and the engine's policy, between explicit mesh endpoints
/// (callers with their own system mapping — e.g. `noc_explorer`'s mesh
/// sweep — resolve `src`/`dst` themselves).
pub fn tagged_specs_between(
    engine: &Engine,
    crs: &CrTable,
    t: &TransferSpec,
    mode: CompressionMode,
    src: NodeId,
    dst: NodeId,
    inject_at: u64,
) -> Vec<PacketSpec> {
    let codec = engine.codec_policy.codec_for(t.kind);
    let wire_bits = crs.wire_bytes_for(codec, t.bytes, t.kind, mode) * 8;
    match transfer_tag(engine, t, mode) {
        None => segment_transfer(src, dst, wire_bits, inject_at, MAX_PACKET_BITS),
        Some(tag) => {
            segment_transfer_tagged(src, dst, wire_bits, inject_at, MAX_PACKET_BITS, tag)
        }
    }
}

/// [`tagged_specs_between`] with the endpoints resolved by the engine's
/// own chiplet mapping.
pub fn tagged_specs(
    engine: &Engine,
    crs: &CrTable,
    t: &TransferSpec,
    mode: CompressionMode,
    inject_at: u64,
) -> Vec<PacketSpec> {
    let src = engine.system.resolve(t.src, t.layer);
    let dst = engine.system.resolve(t.dst, t.layer);
    tagged_specs_between(engine, crs, t, mode, src, dst, inject_at)
}

/// Replay one uncongested transfer through both models.
pub fn replay_transfer(
    engine: &Engine,
    crs: &CrTable,
    t: &TransferSpec,
    mode: CompressionMode,
) -> XvalReport {
    replay_transfer_with_faults(engine, crs, t, mode, None)
}

/// [`replay_transfer`] with an optional link fault model on the cycle
/// side (ISSUE 6). The analytic estimate stays the fault-free price —
/// retry/backoff inflation shows up as reported divergence, exactly
/// like congestion does. `BER = 0` (or `None`) must reproduce
/// [`replay_transfer`] numerically, which the tests pin.
pub fn replay_transfer_with_faults(
    engine: &Engine,
    crs: &CrTable,
    t: &TransferSpec,
    mode: CompressionMode,
    fault: Option<FaultModel>,
) -> XvalReport {
    let analytic_ns = engine.transfer_ns(t, mode, crs);
    let ncfg = network_config_for(engine);
    let mut net = Network::with_egress(ncfg, egress_config_for(engine, crs, t.kind));
    if let Some(f) = fault {
        net.set_fault_model(f);
    }
    net.schedule_packets(&tagged_specs(engine, crs, t, mode, 0));
    let stats = net.run_to_completion(100_000_000);
    XvalReport {
        mode,
        kind: t.kind,
        codec: engine.codec_policy.codec_for(t.kind),
        bytes: t.bytes,
        analytic_ns,
        cycle_ns: stats.completion_cycle as f64 * ncfg.cycle_ns(),
        decode_stall_cycles: stats.decode_stall_cycles,
        encode_stall_cycles: stats.encode_stall_cycles,
        retries: stats.packet_retries,
        dropped: stats.packets_dropped,
        truncated: stats.packets_truncated,
        unreachable: stats.packets_unreachable,
        congested: false,
    }
}

/// [`replay_transfer`] on the **virtual-channel router** (ISSUE 10):
/// the same transfer, the same egress decoder ports, but the cycle side
/// runs [`vc_network_config_for`] with `vcs` channels — packets spread
/// across the adaptive VCs (VC 0 stays the escape lane) and the
/// round-robin output arbiter interleaves the lanes on each physical
/// link. The analytic estimate is untouched, so the report checks that
/// VC multiplexing is latency-neutral on an uncongested window: the
/// link still moves one flit per cycle regardless of how many lanes
/// share it. At `vcs = 1` this is numerically [`replay_transfer`].
pub fn replay_transfer_vc(
    engine: &Engine,
    crs: &CrTable,
    t: &TransferSpec,
    mode: CompressionMode,
    vcs: u8,
) -> XvalReport {
    let analytic_ns = engine.transfer_ns(t, mode, crs);
    let ncfg = vc_network_config_for(engine, vcs);
    let mut net = Network::with_egress(ncfg, egress_config_for(engine, crs, t.kind));
    net.schedule_packets(&tagged_specs(engine, crs, t, mode, 0));
    let stats = net.run_to_completion(100_000_000);
    XvalReport {
        mode,
        kind: t.kind,
        codec: engine.codec_policy.codec_for(t.kind),
        bytes: t.bytes,
        analytic_ns,
        cycle_ns: stats.completion_cycle as f64 * ncfg.cycle_ns(),
        decode_stall_cycles: stats.decode_stall_cycles,
        encode_stall_cycles: stats.encode_stall_cycles,
        retries: stats.packet_retries,
        dropped: stats.packets_dropped,
        truncated: stats.packets_truncated,
        unreachable: stats.packets_unreachable,
        congested: false,
    }
}

/// Replay one uncongested transfer across a **2-package stitched
/// topology** (ISSUE 10): `packages` copies of the engine's mesh joined
/// by gateway-row boundary links, with the source in package 0 and the
/// destination projected into the last package so the worm crosses
/// every stitch. The analytic side is [`Engine::transfer_ns`] (which
/// prices the flat-mesh pair) plus one router cycle per *extra* hop of
/// the stitched path over the flat-mesh path — hop pipeline depth is
/// the only term the engine's mesh-resident model misses, and on a
/// transfer of hundreds of flits it is a sub-percent correction. Runs
/// at `vcs ≥ 2` so payload rides the adaptive channels over the
/// gateway-directed baseline route ([`Topology::route_r`]) while VC 0
/// keeps the up*/down* escape lane open underneath.
pub fn replay_transfer_multipackage(
    engine: &Engine,
    crs: &CrTable,
    t: &TransferSpec,
    mode: CompressionMode,
    packages: u8,
    vcs: u8,
) -> XvalReport {
    assert!(packages >= 2, "a stitched replay needs at least 2 packages");
    assert!(vcs >= 2, "payload must ride adaptive VCs above the escape lane");
    let mesh = engine.system.mesh;
    let topo = Topo::MultiPackage(MultiPackage::new(packages, mesh.cols, mesh.rows));
    let mut ncfg = vc_network_config_for(engine, vcs);
    ncfg.topo = topo;
    let src = engine.system.resolve(t.src, t.layer);
    let dst0 = engine.system.resolve(t.dst, t.layer);
    // Project the destination into the far package (same in-package
    // coordinates), forcing the worm across every boundary stitch.
    let dst = NodeId(dst0.0 + (packages as u16 - 1) * mesh.len() as u16);
    let extra_hops = topo.hops(src, dst).saturating_sub(mesh.hops(src, dst0));
    let analytic_ns = engine.transfer_ns(t, mode, crs) + extra_hops as f64 * ncfg.cycle_ns();
    let mut net = Network::with_egress(ncfg, egress_config_for(engine, crs, t.kind));
    net.schedule_packets(&tagged_specs_between(engine, crs, t, mode, src, dst, 0));
    let stats = net.run_to_completion(100_000_000);
    XvalReport {
        mode,
        kind: t.kind,
        codec: engine.codec_policy.codec_for(t.kind),
        bytes: t.bytes,
        analytic_ns,
        cycle_ns: stats.completion_cycle as f64 * ncfg.cycle_ns(),
        decode_stall_cycles: stats.decode_stall_cycles,
        encode_stall_cycles: stats.encode_stall_cycles,
        retries: stats.packet_retries,
        dropped: stats.packets_dropped,
        truncated: stats.packets_truncated,
        unreachable: stats.packets_unreachable,
        congested: false,
    }
}

/// Replay one uncongested transfer with **both** codec ports attached
/// (ISSUE 7): injection paced by the ingress encoder, ejection by the
/// egress decoder, startup split across the two so it is charged once.
/// The analytic side stays [`Engine::transfer_ns`], whose encode-side
/// makespan coupling mirrors the same encoder model — so ingress-bound
/// windows must cross-validate exactly like decode-bound ones do.
pub fn replay_transfer_duplex(
    engine: &Engine,
    crs: &CrTable,
    t: &TransferSpec,
    mode: CompressionMode,
    fault: Option<FaultModel>,
) -> XvalReport {
    let analytic_ns = engine.transfer_ns(t, mode, crs);
    let ncfg = network_config_for(engine);
    let (icfg, ecfg) = duplex_configs_for(engine, crs, t.kind);
    let mut net = Network::with_egress(ncfg, ecfg);
    net.set_ingress_config(icfg);
    if let Some(f) = fault {
        net.set_fault_model(f);
    }
    net.schedule_packets(&tagged_specs(engine, crs, t, mode, 0));
    let stats = net.run_to_completion(100_000_000);
    XvalReport {
        mode,
        kind: t.kind,
        codec: engine.codec_policy.codec_for(t.kind),
        bytes: t.bytes,
        analytic_ns,
        cycle_ns: stats.completion_cycle as f64 * ncfg.cycle_ns(),
        decode_stall_cycles: stats.decode_stall_cycles,
        encode_stall_cycles: stats.encode_stall_cycles,
        retries: stats.packet_retries,
        dropped: stats.packets_dropped,
        truncated: stats.packets_truncated,
        unreachable: stats.packets_unreachable,
        congested: false,
    }
}

/// Replay a transfer with `senders` copies converging on its destination
/// simultaneously (hotspot). The analytic side stays the **solo**
/// estimate — the divergence between the two is the report, not a bug:
/// the analytic model carries no contention term, which is exactly where
/// the cycle simulator earns its keep.
pub fn replay_hotspot(
    engine: &Engine,
    crs: &CrTable,
    t: &TransferSpec,
    mode: CompressionMode,
    senders: usize,
) -> XvalReport {
    let ncfg = network_config_for(engine);
    let dst = engine.system.resolve(t.dst, t.layer);
    let mut net = Network::with_egress(ncfg, egress_config_for(engine, crs, t.kind));
    let sources: Vec<NodeId> = engine
        .system
        .compute_nodes
        .iter()
        .copied()
        .filter(|&n| n != dst)
        .take(senders.max(1))
        .collect();
    for src_node in &sources {
        let mut specs = tagged_specs(engine, crs, t, mode, 0);
        for s in &mut specs {
            s.src = *src_node;
            s.dest = dst;
        }
        net.schedule_packets(&specs);
    }
    let stats = net.run_to_completion(1_000_000_000);
    // The window's drain time: with every sender converging on one
    // ejection port, the last chain completes ~senders× later than the
    // solo analytic estimate — that gap is the report.
    XvalReport {
        mode,
        kind: t.kind,
        codec: engine.codec_policy.codec_for(t.kind),
        bytes: t.bytes,
        analytic_ns: engine.transfer_ns(t, mode, crs),
        cycle_ns: stats.completion_cycle as f64 * ncfg.cycle_ns(),
        decode_stall_cycles: stats.decode_stall_cycles,
        encode_stall_cycles: stats.encode_stall_cycles,
        retries: stats.packet_retries,
        dropped: stats.packets_dropped,
        truncated: stats.packets_truncated,
        unreachable: stats.packets_unreachable,
        congested: true,
    }
}

/// Cross-validate a set of transfers under one mode; one report each.
pub fn cross_validate(
    engine: &Engine,
    crs: &CrTable,
    transfers: &[TransferSpec],
    mode: CompressionMode,
) -> Vec<XvalReport> {
    transfers
        .iter()
        .map(|t| replay_transfer(engine, crs, t, mode))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use lexi_models::corpus::Corpus;
    use lexi_models::{traffic, CodecPolicy, ModelConfig, ModelScale};

    /// Sizable uncongested windows: the largest transfer of each kind
    /// across one decode step plus the weight load (startup constants
    /// are noise at this size). On the tiny models this yields KV-cache,
    /// SSM-state and weight windows; per-token activations are too small
    /// to pin a percentage band on and are exercised by the full-step
    /// replays elsewhere.
    fn windows(cfg: &ModelConfig) -> Vec<TransferSpec> {
        let mut ts = traffic::decode_step(cfg, &Corpus::wikitext2(), 0);
        ts.extend(traffic::weight_load(cfg));
        TransferKind::ALL
            .iter()
            .filter_map(|&k| {
                ts.iter()
                    .filter(|t| t.kind == k && t.bytes > 4096)
                    .max_by_key(|t| t.bytes)
                    .copied()
            })
            .collect()
    }

    #[test]
    fn uncongested_agreement_within_band_all_modes_and_policies() {
        // The acceptance pin: every CompressionMode × {Huffman-default,
        // BDI-state} policy, uncongested sizable transfers, ≤ 15%.
        let cfg = ModelConfig::jamba(ModelScale::Tiny);
        let crs = CrTable::measure(&cfg, 42);
        let wins = windows(&cfg);
        assert!(
            wins.iter().any(|t| t.kind == TransferKind::SsmState),
            "hybrid model must exercise the SSM-state (BDI) path"
        );
        for policy in [CodecPolicy::lexi_default(), CodecPolicy::bdi_state()] {
            let engine = Engine::with_policy(policy);
            for mode in CompressionMode::ALL {
                for r in cross_validate(&engine, &crs, &wins, mode) {
                    assert!(
                        r.in_band(),
                        "out of band: {} (policy {policy:?})",
                        r.row()
                    );
                }
            }
        }
    }

    #[test]
    fn decode_bound_direction_agrees_between_models() {
        // decoder_lanes = 1: both models must stretch the compressed
        // transfer well past line rate — the egress port visibly stalls
        // the link in cycles, the engine via makespan coupling — and the
        // two decode-bound estimates still agree within the band.
        let cfg = ModelConfig::jamba(ModelScale::Tiny);
        let crs = CrTable::measure(&cfg, 42);
        let t = *windows(&cfg)
            .iter()
            .find(|t| t.kind == TransferKind::KvCache)
            .expect("sizable KV-cache transfer");

        let full = Engine::paper_default();
        let mut starved = Engine::paper_default();
        starved.decoder_lanes = 1;

        let r16 = replay_transfer(&full, &crs, &t, CompressionMode::Lexi);
        let r1 = replay_transfer(&starved, &crs, &t, CompressionMode::Lexi);

        // Same direction, both models: one lane is decode-bound.
        assert!(
            r1.analytic_ns > r16.analytic_ns * 1.5,
            "analytic not decode-bound: {} vs {}",
            r1.analytic_ns,
            r16.analytic_ns
        );
        assert!(
            r1.cycle_ns > r16.cycle_ns * 1.5,
            "cycle sim not decode-bound: {} vs {}",
            r1.cycle_ns,
            r16.cycle_ns
        );
        // The stall is visible in cycles, not just in the total.
        assert!(
            r1.decode_stall_cycles > r16.decode_stall_cycles,
            "1-lane egress did not stall more than 16-lane ({} vs {})",
            r1.decode_stall_cycles,
            r16.decode_stall_cycles
        );
        // And the decode-bound window still cross-validates.
        assert!(r1.in_band(), "decode-bound replay out of band: {}", r1.row());
        assert!(r16.in_band(), "line-rate replay out of band: {}", r16.row());
    }

    #[test]
    fn paper_point_sustains_line_rate_in_cycles() {
        // The paper's §4.4 claim, now demonstrated in cycles: at 16
        // lanes the egress decoder never stalls the link beyond the
        // one-time codebook startup.
        let cfg = ModelConfig::jamba(ModelScale::Tiny);
        let crs = CrTable::measure(&cfg, 42);
        let engine = Engine::paper_default();
        let ncfg = network_config_for(&engine);
        let startup_cycles = (engine.huffman_startup_ns() / ncfg.cycle_ns()).ceil() as u64;
        for t in windows(&cfg) {
            let r = replay_transfer(&engine, &crs, &t, CompressionMode::Lexi);
            assert!(
                r.decode_stall_cycles <= startup_cycles + 2,
                "{}: {} stall cycles exceed the startup allowance {}",
                r.row(),
                r.decode_stall_cycles,
                startup_cycles
            );
        }
    }

    #[test]
    fn congestion_diverges_and_is_reported() {
        // Hotspot replay: the analytic model has no contention term, so
        // the cycle sim must land far outside the band — and the report
        // says so instead of hiding it.
        let cfg = ModelConfig::jamba(ModelScale::Tiny);
        let crs = CrTable::measure(&cfg, 42);
        let engine = Engine::paper_default();
        let t = *windows(&cfg)
            .iter()
            .find(|t| t.kind == TransferKind::KvCache)
            .expect("sizable transfer");
        let r = replay_hotspot(&engine, &crs, &t, CompressionMode::Lexi, 8);
        assert!(r.congested);
        assert!(!r.in_band(), "congested replay claims the band: {}", r.row());
        assert!(
            r.cycle_ns > r.analytic_ns * (1.0 + UNCONGESTED_BAND),
            "contention did not slow the cycle sim: {}",
            r.row()
        );
    }

    #[test]
    fn uncompressed_packets_ship_untagged() {
        let cfg = ModelConfig::jamba(ModelScale::Tiny);
        let crs = CrTable::measure(&cfg, 42);
        let engine = Engine::paper_default();
        let t = windows(&cfg)[0];
        for s in tagged_specs(&engine, &crs, &t, CompressionMode::Uncompressed, 0) {
            assert!(s.codec.is_none());
        }
        let tagged = tagged_specs(&engine, &crs, &t, CompressionMode::Lexi, 0);
        assert!(tagged.iter().all(|s| s.codec.is_some()));
        let syms: u64 = tagged.iter().map(|s| s.codec.unwrap().symbols).sum();
        assert_eq!(syms, (t.bytes / 2).max(1));
    }

    #[test]
    fn zero_ber_fault_model_reproduces_the_fault_free_replay() {
        // ISSUE 6 acceptance pin: attaching an inert fault model must
        // keep every xval number bit-identical — BER = 0 is the same
        // simulation, not a near miss.
        let cfg = ModelConfig::jamba(ModelScale::Tiny);
        let crs = CrTable::measure(&cfg, 42);
        let engine = Engine::paper_default();
        for t in windows(&cfg) {
            for mode in CompressionMode::ALL {
                let clean = replay_transfer(&engine, &crs, &t, mode);
                let inert = replay_transfer_with_faults(
                    &engine,
                    &crs,
                    &t,
                    mode,
                    Some(FaultModel::new(7)),
                );
                assert_eq!(clean.analytic_ns, inert.analytic_ns);
                assert_eq!(clean.cycle_ns, inert.cycle_ns, "{}", clean.row());
                assert_eq!(clean.decode_stall_cycles, inert.decode_stall_cycles);
                assert_eq!(inert.retries, 0);
                assert_eq!(inert.dropped, 0);
            }
        }
    }

    #[test]
    fn seeded_ber_replay_is_deterministic_and_never_faster() {
        let cfg = ModelConfig::jamba(ModelScale::Tiny);
        let crs = CrTable::measure(&cfg, 42);
        let engine = Engine::paper_default();
        let t = *windows(&cfg)
            .iter()
            .find(|t| t.kind == TransferKind::KvCache)
            .expect("sizable KV-cache transfer");
        let clean = replay_transfer(&engine, &crs, &t, CompressionMode::Lexi);
        let run = || {
            replay_transfer_with_faults(
                &engine,
                &crs,
                &t,
                CompressionMode::Lexi,
                Some(FaultModel::new(13).with_ber(1e-5)),
            )
        };
        let a = run();
        let b = run();
        assert_eq!(a.cycle_ns, b.cycle_ns, "same seed diverged");
        assert_eq!(a.retries, b.retries);
        assert_eq!(a.dropped, b.dropped);
        // Retry backoff and repeat trips can only stretch the window.
        assert!(a.cycle_ns >= clean.cycle_ns, "{} < {}", a.cycle_ns, clean.cycle_ns);
    }

    #[test]
    fn duplex_replay_stays_in_band_and_charges_startup_once() {
        // ISSUE 7: attaching the ingress encoder alongside the egress
        // decoder must not break cross-validation. At the 16-lane paper
        // point the encoder sits under line rate, and the startup split
        // (codebook share at inject, LUT-fill share at eject) sums to
        // the engine's single charge — so the duplex replay stays in
        // band and lands near the egress-only replay. A double-charged
        // startup would add ~133 cycles per packet and fail both pins.
        let cfg = ModelConfig::jamba(ModelScale::Tiny);
        let crs = CrTable::measure(&cfg, 42);
        let engine = Engine::paper_default();
        let ncfg = network_config_for(&engine);
        let (icfg, ecfg) = duplex_configs_for(&engine, &crs, TransferKind::KvCache);
        assert!(
            (icfg.startup_ns + ecfg.startup_ns - engine.huffman_startup_ns()).abs() < 1e-9,
            "startup split must sum to the engine's single charge"
        );
        for t in windows(&cfg) {
            let solo = replay_transfer(&engine, &crs, &t, CompressionMode::Lexi);
            let duplex =
                replay_transfer_duplex(&engine, &crs, &t, CompressionMode::Lexi, None);
            assert!(duplex.in_band(), "duplex out of band: {}", duplex.row());
            let npkts = tagged_specs(&engine, &crs, &t, CompressionMode::Lexi, 0).len();
            let tol = (64 * npkts.max(1)) as f64 * ncfg.cycle_ns();
            assert!(
                (duplex.cycle_ns - solo.cycle_ns).abs() <= tol,
                "duplex replay drifted from egress-only by more than the \
                 startup-relocation allowance: {} vs {} (tol {tol} ns)",
                duplex.row(),
                solo.row()
            );
        }
    }

    #[test]
    fn inert_fault_duplex_replay_is_bit_identical() {
        // The ISSUE 6 zero-BER pin extends to the duplex path: an inert
        // fault model is the same simulation.
        let cfg = ModelConfig::jamba(ModelScale::Tiny);
        let crs = CrTable::measure(&cfg, 42);
        let engine = Engine::paper_default();
        let t = *windows(&cfg)
            .iter()
            .find(|t| t.kind == TransferKind::KvCache)
            .expect("sizable KV-cache transfer");
        let clean = replay_transfer_duplex(&engine, &crs, &t, CompressionMode::Lexi, None);
        let inert = replay_transfer_duplex(
            &engine,
            &crs,
            &t,
            CompressionMode::Lexi,
            Some(FaultModel::new(7)),
        );
        assert_eq!(clean.cycle_ns, inert.cycle_ns);
        assert_eq!(clean.encode_stall_cycles, inert.encode_stall_cycles);
        assert_eq!(clean.decode_stall_cycles, inert.decode_stall_cycles);
        assert_eq!(inert.retries, 0);
        assert_eq!(inert.truncated, 0);
        assert_eq!(inert.unreachable, 0);
    }

    #[test]
    fn ingress_bound_direction_agrees_between_models() {
        // encoder_lanes = 1 (ISSUE 7 acceptance): both models must
        // stretch a compressed transfer well past line rate — the
        // ingress port visibly throttles injection in cycles, the
        // engine via encode-makespan coupling — and the two
        // encode-bound estimates still agree within the band.
        let cfg = ModelConfig::jamba(ModelScale::Tiny);
        let crs = CrTable::measure(&cfg, 42);
        let t = *windows(&cfg)
            .iter()
            .find(|t| t.kind == TransferKind::KvCache)
            .expect("sizable KV-cache transfer");

        let full = Engine::paper_default();
        let mut starved = Engine::paper_default();
        starved.encoder_lanes = 1;

        let r16 = replay_transfer_duplex(&full, &crs, &t, CompressionMode::Lexi, None);
        let r1 = replay_transfer_duplex(&starved, &crs, &t, CompressionMode::Lexi, None);

        // Same direction, both models: one lane is encode-bound.
        assert!(
            r1.analytic_ns > r16.analytic_ns * 1.5,
            "analytic not encode-bound: {} vs {}",
            r1.analytic_ns,
            r16.analytic_ns
        );
        assert!(
            r1.cycle_ns > r16.cycle_ns * 1.5,
            "cycle sim not encode-bound: {} vs {}",
            r1.cycle_ns,
            r16.cycle_ns
        );
        // The throttle is visible in cycles, not just in the total.
        assert!(
            r1.encode_stall_cycles > r16.encode_stall_cycles,
            "1-lane ingress did not stall more than 16-lane ({} vs {})",
            r1.encode_stall_cycles,
            r16.encode_stall_cycles
        );
        // And the encode-bound window still cross-validates.
        assert!(r1.in_band(), "encode-bound replay out of band: {}", r1.row());
        assert!(r16.in_band(), "line-rate replay out of band: {}", r16.row());
    }

    #[test]
    fn link_down_mid_transfer_recovers_and_is_deterministic() {
        // ISSUE 7: killing the transfer's first XY link mid-flight must
        // truncate the severed wormhole, retry it, and deliver the whole
        // window over the escape route — slower, deterministic, nothing
        // dropped or hung.
        let cfg = ModelConfig::jamba(ModelScale::Tiny);
        let crs = CrTable::measure(&cfg, 42);
        let engine = Engine::paper_default();
        let t = *windows(&cfg)
            .iter()
            .find(|t| t.kind == TransferKind::KvCache)
            .expect("sizable KV-cache transfer");
        let src = engine.system.resolve(t.src, t.layer);
        let dst = engine.system.resolve(t.dst, t.layer);
        assert_ne!(src, dst, "KV window must cross the mesh");
        let mesh = engine.system.mesh;
        let hop = mesh
            .neighbour(src, mesh.route_xy(src, dst))
            .expect("first XY hop exists");

        let clean = replay_transfer(&engine, &crs, &t, CompressionMode::Lexi);
        let run = || {
            replay_transfer_with_faults(
                &engine,
                &crs,
                &t,
                CompressionMode::Lexi,
                Some(FaultModel::new(3).with_link_down(src, hop, 64)),
            )
        };
        let a = run();
        let b = run();
        assert_eq!(a.cycle_ns, b.cycle_ns, "same link-down schedule diverged");
        assert_eq!(a.truncated, b.truncated);
        assert_eq!(a.dropped, 0, "recovery must not exhaust the budget: {}", a.row());
        assert_eq!(a.unreachable, 0, "mesh stays connected: {}", a.row());
        assert!(
            a.truncated >= 1 && a.retries >= 1,
            "cycle-64 cut must sever an in-flight wormhole: {}",
            a.row()
        );
        // The detour + retry can only stretch the window.
        assert!(a.cycle_ns >= clean.cycle_ns, "{} < {}", a.cycle_ns, clean.cycle_ns);
    }

    #[test]
    fn severed_destination_is_reported_unreachable_in_replay() {
        // Cutting every link around the destination before injection:
        // the replay terminates (never hangs) and reports every packet
        // as typed-unreachable.
        let cfg = ModelConfig::jamba(ModelScale::Tiny);
        let crs = CrTable::measure(&cfg, 42);
        let engine = Engine::paper_default();
        let t = *windows(&cfg)
            .iter()
            .find(|t| t.kind == TransferKind::KvCache)
            .expect("sizable KV-cache transfer");
        let dst = engine.system.resolve(t.dst, t.layer);
        let mesh = engine.system.mesh;
        let mut fault = FaultModel::new(9);
        for port in lexi_noc::topology::Port::ALL {
            if let Some(nb) = mesh.neighbour(dst, port) {
                fault = fault.with_link_down(dst, nb, 0);
            }
        }
        let npkts = tagged_specs(&engine, &crs, &t, CompressionMode::Lexi, 0).len() as u64;
        assert!(npkts > 0);
        let r = replay_transfer_with_faults(&engine, &crs, &t, CompressionMode::Lexi, Some(fault));
        assert_eq!(r.unreachable, npkts, "every packet typed-unreachable: {}", r.row());
        assert_eq!(r.dropped, 0);
    }

    #[test]
    fn vc_replay_stays_in_band_and_vc1_reproduces_the_flat_replay() {
        // ISSUE 10 acceptance: the VC router cross-validates. At vcs = 1
        // the config is bit-identical to the flat replay, so every
        // report field must match exactly; at vcs ∈ {2, 4} the payload
        // spreads over the adaptive lanes and the physical link still
        // moves one flit per cycle, so the same uncongested windows stay
        // inside the 15% band.
        let cfg = ModelConfig::jamba(ModelScale::Tiny);
        let crs = CrTable::measure(&cfg, 42);
        let engine = Engine::paper_default();
        for t in windows(&cfg) {
            let flat = replay_transfer(&engine, &crs, &t, CompressionMode::Lexi);
            let one = replay_transfer_vc(&engine, &crs, &t, CompressionMode::Lexi, 1);
            assert_eq!(one.cycle_ns, flat.cycle_ns, "vcs=1 diverged: {}", one.row());
            assert_eq!(one.analytic_ns, flat.analytic_ns);
            assert_eq!(one.decode_stall_cycles, flat.decode_stall_cycles);
            for vcs in [2u8, 4] {
                let r = replay_transfer_vc(&engine, &crs, &t, CompressionMode::Lexi, vcs);
                assert!(r.in_band(), "vcs={vcs} out of band: {}", r.row());
                assert_eq!(r.dropped, 0);
                assert_eq!(r.unreachable, 0);
            }
        }
    }

    #[test]
    fn multipackage_replay_crosses_the_stitch_in_band_and_deterministically() {
        // ISSUE 10 acceptance: a 2-package stitched replay — source in
        // package 0, destination projected into package 1 so the worm
        // rides a gateway-row boundary link — still agrees with the
        // analytic estimate (flat-mesh price + per-extra-hop pipeline
        // correction) within the band, delivers everything, and is
        // bit-deterministic run to run.
        let cfg = ModelConfig::jamba(ModelScale::Tiny);
        let crs = CrTable::measure(&cfg, 42);
        let engine = Engine::paper_default();
        let t = *windows(&cfg)
            .iter()
            .find(|t| t.kind == TransferKind::KvCache)
            .expect("sizable KV-cache transfer");
        let run = || replay_transfer_multipackage(&engine, &crs, &t, CompressionMode::Lexi, 2, 2);
        let a = run();
        let b = run();
        assert_eq!(a.cycle_ns, b.cycle_ns, "stitched replay diverged run to run");
        assert_eq!(a.decode_stall_cycles, b.decode_stall_cycles);
        assert!(a.in_band(), "stitched replay out of band: {}", a.row());
        assert_eq!(a.dropped, 0, "{}", a.row());
        assert_eq!(a.unreachable, 0, "destination must be stitch-reachable: {}", a.row());
        assert_eq!(a.retries, 0, "no fault model attached: {}", a.row());
        // The stitched window cannot beat the flat-mesh one: the path
        // only gets longer.
        let flat = replay_transfer(&engine, &crs, &t, CompressionMode::Lexi);
        assert!(
            a.cycle_ns >= flat.cycle_ns,
            "stitched {} ns beat flat {} ns",
            a.cycle_ns,
            flat.cycle_ns
        );
    }
}
