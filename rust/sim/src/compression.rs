//! Compression modes and measured wire ratios.
//!
//! Table 3 compares three settings: uncompressed, compressed weights only
//! (offline), and full LEXI (offline weights + on-the-fly activations and
//! hybrid caches). The wire ratio of each traffic class is *measured* by
//! running the actual codec + flit packetizer over representative streams
//! (synthetic at paper scale, real tensors at tiny scale via the runtime),
//! not assumed.
//!
//! The measurement path routes through the §Perf batch engine
//! (`lexi_core::batch`) via `compress_exponents` / `flit::pack`; the
//! batch rewire is bit-identical to the scalar oracle, so every ratio in
//! this table is unchanged — pinned by
//! `batch_rewire_preserves_compressed_sizes` below.
//!
//! Beyond ratios, [`CrTable`] also carries the **decoder makespan
//! model** (ISSUE 2): `DecoderUnit::decode_lane_stream` is run over a
//! representative stream per kind at each [`CACHED_LANES`] count, and
//! the slowest-lane makespan per symbol is cached for
//! `Engine::transfer_ns` to couple transfer latency to the real decoder
//! instead of analytic per-kind ratios only.

use lexi_core::batch::LaneCodec;
use lexi_core::bf16::FieldStreams;
use lexi_core::flit::{self, FlitFormat};
use lexi_core::huffman::{self, CodeBook};
use lexi_core::stats::Histogram;
use lexi_core::Bf16;
use lexi_hw::decoder::{DecoderConfig, DecoderUnit};
use lexi_models::activations;
use lexi_models::traffic::TransferKind;
use lexi_models::weights::WeightStream;
use lexi_models::ModelConfig;
use std::collections::HashMap;

/// The three evaluated settings.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CompressionMode {
    Uncompressed,
    WeightsOnly,
    Lexi,
}

impl CompressionMode {
    /// All modes, Table 3 row order.
    pub const ALL: [CompressionMode; 3] = [
        CompressionMode::Uncompressed,
        CompressionMode::WeightsOnly,
        CompressionMode::Lexi,
    ];

    /// Is `kind` compressed under this mode?
    pub fn compresses(self, kind: TransferKind) -> bool {
        match self {
            CompressionMode::Uncompressed => false,
            CompressionMode::WeightsOnly => kind == TransferKind::Weights,
            CompressionMode::Lexi => true,
        }
    }
}

/// Measured ratios for one traffic class.
#[derive(Clone, Copy, Debug)]
pub struct KindRatios {
    /// Exponent-stream CR (8 bits → 8/cr), header included — Table 2's
    /// metric.
    pub exponent_cr: f64,
    /// Whole-transfer wire ratio including sign/mantissa passthrough and
    /// flit framing: uncompressed flits / LEXI flits.
    pub wire_ratio: f64,
}

/// Per-kind measured ratios for one model, plus the measured decoder
/// makespan model the engine's transfer latency couples to (ISSUE 2).
#[derive(Clone, Debug)]
pub struct CrTable {
    pub ratios: HashMap<TransferKind, KindRatios>,
    /// Measured `DecoderUnit::decode_lane_stream` makespans, cached per
    /// `(kind, lanes)`: effective decoder **cycles per transferred
    /// symbol** with `lanes` parallel LUT decoders (slowest-lane makespan
    /// ÷ total symbols). Empty for tables built from runtime profiles
    /// ([`CrTable::from_ratios`]); lookups then fall back to the
    /// paper-nominal latency.
    pub decode_cycles: HashMap<(TransferKind, usize), f64>,
}

/// Sample size per (kind, layer) for ratio measurement. The streams are
/// i.i.d. within a layer, so a 16 K sample pins the ratio to ±1%.
const SAMPLE: usize = 16 * 1024;

/// Sample size for the decoder-makespan measurement (per kind; the
/// makespan-per-symbol statistic stabilizes faster than the ratios).
const DECODE_SAMPLE: usize = 8 * 1024;

/// Lane counts the makespan model is measured at. Lookups at other lane
/// counts scale inverse-linearly from the nearest measured point.
pub const CACHED_LANES: [usize; 5] = [1, 2, 4, 8, 16];

/// Fig 6's 4-stage average (≈1.16 cycles/symbol): the fallback when a
/// table carries no makespan measurements.
const NOMINAL_CYCLES_PER_SYMBOL: f64 = 1.16;

impl CrTable {
    /// Measure ratios for `cfg` by running the codec over synthetic
    /// streams of each kind across several layers, and the decoder
    /// makespan model by running the cycle-accurate multi-lane LUT unit
    /// (`lexi-hw`) over a representative stream per kind at each
    /// [`CACHED_LANES`] count.
    pub fn measure(cfg: &ModelConfig, seed: u64) -> Self {
        let mut ratios = HashMap::new();
        let mut decode_cycles = HashMap::new();
        let layers: Vec<usize> = pick_layers(cfg);
        let unit = DecoderUnit::new(DecoderConfig::paper_default()).expect("paper config valid");
        for kind in [
            TransferKind::Weights,
            TransferKind::Activation,
            TransferKind::KvCache,
            TransferKind::SsmState,
        ] {
            let mut exp_cr = 0.0;
            let mut wire = 0.0;
            let mut mid_exps: Vec<u8> = Vec::new();
            for (i, &layer) in layers.iter().enumerate() {
                let values: Vec<Bf16> = match kind {
                    TransferKind::Weights => {
                        let mut s = WeightStream::for_block(cfg, layer, seed);
                        let mut v = s.next_values(SAMPLE);
                        if v.len() < SAMPLE {
                            // Tiny blocks: repeat the stream.
                            while v.len() < SAMPLE {
                                let mut s2 = WeightStream::for_block(cfg, layer, seed ^ 1);
                                v.extend(s2.next_values(SAMPLE - v.len()));
                            }
                        }
                        v
                    }
                    _ => synth_values(cfg, layer, kind, seed),
                };
                let (e, w) = measure_streams(&values);
                exp_cr += e;
                wire += w;
                // The middle layer doubles as the makespan-model sample.
                if i == layers.len() / 2 {
                    mid_exps = FieldStreams::split(&values)
                        .exponents
                        .into_iter()
                        .take(DECODE_SAMPLE)
                        .collect();
                }
            }
            let n = layers.len() as f64;
            ratios.insert(
                kind,
                KindRatios {
                    exponent_cr: exp_cr / n,
                    wire_ratio: wire / n,
                },
            );
            // Decoder makespan per symbol at each cached lane count.
            if !mid_exps.is_empty() {
                let hist = Histogram::from_bytes(&mid_exps);
                let book = CodeBook::lexi_default(&hist).expect("non-empty");
                for lanes in CACHED_LANES {
                    let stream = LaneCodec::new(lanes)
                        .expect("cached lane count valid")
                        .encode(&mid_exps, &book);
                    let (_, rep) = unit
                        .decode_lane_stream(&stream, &book)
                        .expect("measured stream decodes");
                    decode_cycles.insert(
                        (kind, lanes),
                        rep.makespan as f64 / mid_exps.len() as f64,
                    );
                }
            }
        }
        CrTable {
            ratios,
            decode_cycles,
        }
    }

    /// A table from externally measured ratios (e.g. the runtime
    /// coordinator's tensor profiles) with no decoder-makespan cache;
    /// [`decode_cycles_per_symbol`] falls back to the paper-nominal
    /// latency.
    ///
    /// [`decode_cycles_per_symbol`]: CrTable::decode_cycles_per_symbol
    pub fn from_ratios(ratios: HashMap<TransferKind, KindRatios>) -> Self {
        CrTable {
            ratios,
            decode_cycles: HashMap::new(),
        }
    }

    /// Wire bytes for a transfer of `bytes` of `kind` under `mode`.
    pub fn wire_bytes(&self, bytes: u64, kind: TransferKind, mode: CompressionMode) -> u64 {
        if !mode.compresses(kind) {
            return bytes;
        }
        let r = self.ratios[&kind].wire_ratio;
        ((bytes as f64 / r).ceil() as u64).max(1)
    }

    /// Exponent CR of a kind (Table 2 reporting).
    pub fn exponent_cr(&self, kind: TransferKind) -> f64 {
        self.ratios[&kind].exponent_cr
    }

    /// Measured decoder cycles per transferred symbol with `lanes`
    /// parallel decoders: an exact cache hit when `lanes` is in
    /// [`CACHED_LANES`], otherwise the nearest measured point scaled
    /// inverse-linearly (lane throughput is ~linear until the link
    /// saturates), or the paper-nominal Fig 6 latency when no
    /// measurements exist at all.
    pub fn decode_cycles_per_symbol(&self, kind: TransferKind, lanes: usize) -> f64 {
        let lanes = lanes.max(1);
        if let Some(&c) = self.decode_cycles.get(&(kind, lanes)) {
            return c;
        }
        // Walk CACHED_LANES in its fixed order (not the HashMap, whose
        // iteration order is randomized per process): deterministic
        // nearest-point selection, ties resolved to the smaller count.
        let mut best: Option<(usize, f64)> = None;
        for l in CACHED_LANES {
            let Some(&c) = self.decode_cycles.get(&(kind, l)) else {
                continue;
            };
            let closer = match best {
                None => true,
                Some((bl, _)) => {
                    (l as i64 - lanes as i64).abs() < (bl as i64 - lanes as i64).abs()
                }
            };
            if closer {
                best = Some((l, c));
            }
        }
        match best {
            Some((l, c)) => c * l as f64 / lanes as f64,
            None => NOMINAL_CYCLES_PER_SYMBOL / lanes as f64,
        }
    }
}

/// Representative layers: first, middle, last.
fn pick_layers(cfg: &ModelConfig) -> Vec<usize> {
    let n = cfg.blocks.len();
    let mut v = vec![0, n / 2, n - 1];
    v.dedup();
    v
}

fn synth_values(cfg: &ModelConfig, layer: usize, kind: TransferKind, seed: u64) -> Vec<Bf16> {
    // Re-synthesize full values (not just exponents) so the flit packer
    // sees realistic sign/mantissa fields too.
    let exps = activations::sample_exponents(cfg, layer, kind, seed, SAMPLE);
    let mut rng = lexi_core::prng::Rng::new(seed ^ 0xabcd);
    exps.iter()
        .map(|&e| {
            Bf16::from_fields(
                (rng.next_u32() & 1) as u8,
                e,
                (rng.next_u32() & 0x7f) as u8,
            )
        })
        .collect()
}

/// (exponent CR, wire ratio) for one value sample.
fn measure_streams(values: &[Bf16]) -> (f64, f64) {
    let streams = FieldStreams::split(values);
    let block = huffman::compress_exponents(&streams.exponents).expect("non-empty");
    let exp_cr = block.ratio();

    let hist = Histogram::from_bytes(&streams.exponents);
    let book = CodeBook::lexi_default(&hist).expect("non-empty");
    let format = FlitFormat::new(128).expect("valid format");
    let transfer = flit::pack(&streams, &book, format).expect("packable");
    (exp_cr, transfer.ratio_vs_uncompressed())
}

#[cfg(test)]
mod tests {
    use super::*;
    use lexi_models::ModelScale;

    #[test]
    fn lexi_exponent_cr_in_paper_band() {
        // Table 2: LEXI ≈ 3.07–3.14× on weights.
        for cfg in ModelConfig::paper_models() {
            let t = CrTable::measure(&cfg, 42);
            let cr = t.exponent_cr(TransferKind::Weights);
            assert!((2.3..4.2).contains(&cr), "{}: CR {cr}", cfg.name);
        }
    }

    #[test]
    fn wire_ratio_between_1_and_2() {
        // Exponent-only coding of 16-bit values caps the wire ratio at
        // 16/8 = 2×; framing keeps it below that.
        let cfg = ModelConfig::qwen(ModelScale::Paper);
        let t = CrTable::measure(&cfg, 42);
        for (kind, r) in &t.ratios {
            assert!(
                (1.05..2.0).contains(&r.wire_ratio),
                "{kind:?}: wire {}",
                r.wire_ratio
            );
        }
    }

    #[test]
    fn modes_gate_kinds() {
        let cfg = ModelConfig::qwen(ModelScale::Paper);
        let t = CrTable::measure(&cfg, 1);
        let b = 1_000_000u64;
        assert_eq!(
            t.wire_bytes(b, TransferKind::KvCache, CompressionMode::Uncompressed),
            b
        );
        assert_eq!(
            t.wire_bytes(b, TransferKind::KvCache, CompressionMode::WeightsOnly),
            b
        );
        assert!(t.wire_bytes(b, TransferKind::KvCache, CompressionMode::Lexi) < b);
        assert!(t.wire_bytes(b, TransferKind::Weights, CompressionMode::WeightsOnly) < b);
    }

    #[test]
    fn batch_rewire_preserves_compressed_sizes() {
        // The ISSUE-1 acceptance gate: compressed sizes (and therefore
        // every CR table) must be byte-identical to the scalar path.
        let cfg = ModelConfig::jamba(ModelScale::Paper);
        for kind in [TransferKind::Activation, TransferKind::KvCache] {
            let exps = activations::sample_exponents(&cfg, 0, kind, 9, 40_000);
            let hist = Histogram::from_bytes(&exps);
            let book = CodeBook::lexi_default(&hist).unwrap();
            // Scalar oracle: header + count + one encode_symbol per exponent.
            let mut w = lexi_core::bitstream::BitWriter::new();
            book.write_header(&mut w);
            w.put(exps.len() as u64, 32);
            for &e in &exps {
                book.encode_symbol(e, &mut w);
            }
            let want_bits = w.len_bits();
            let want_bytes = w.into_bytes();
            let block = huffman::compress_with_book(&exps, &book).unwrap();
            assert_eq!(block.bits, want_bits, "{kind:?}");
            assert_eq!(block.bytes, want_bytes, "{kind:?}");
        }
    }

    #[test]
    fn measurement_is_deterministic() {
        let cfg = ModelConfig::jamba(ModelScale::Paper);
        let a = CrTable::measure(&cfg, 7);
        let b = CrTable::measure(&cfg, 7);
        assert_eq!(
            a.exponent_cr(TransferKind::Activation),
            b.exponent_cr(TransferKind::Activation)
        );
        assert_eq!(
            a.decode_cycles_per_symbol(TransferKind::Activation, 8),
            b.decode_cycles_per_symbol(TransferKind::Activation, 8)
        );
    }

    #[test]
    fn decode_cache_covers_all_kinds_and_scales_with_lanes() {
        let cfg = ModelConfig::qwen(ModelScale::Paper);
        let t = CrTable::measure(&cfg, 42);
        for kind in [
            TransferKind::Weights,
            TransferKind::Activation,
            TransferKind::KvCache,
            TransferKind::SsmState,
        ] {
            for lanes in CACHED_LANES {
                assert!(
                    t.decode_cycles.contains_key(&(kind, lanes)),
                    "{kind:?} lanes {lanes} missing from cache"
                );
            }
            // Per-symbol occupancy shrinks ~linearly as lanes grow
            // (round-robin keeps lanes balanced on i.i.d. streams).
            let c1 = t.decode_cycles_per_symbol(kind, 1);
            let c8 = t.decode_cycles_per_symbol(kind, 8);
            assert!(c1 >= 1.0, "{kind:?}: 1-lane {c1} below 1 cycle/symbol");
            assert!(
                c8 < c1 / 4.0,
                "{kind:?}: 8 lanes ({c8}) not ≥4× faster than 1 ({c1})"
            );
            // Uncached lane counts interpolate from the nearest point.
            let c12 = t.decode_cycles_per_symbol(kind, 12);
            assert!(c12 > 0.0 && c12 < c8);
        }
    }

    #[test]
    fn ratio_only_tables_fall_back_to_nominal_latency() {
        let cfg = ModelConfig::qwen(ModelScale::Paper);
        let measured = CrTable::measure(&cfg, 42);
        let bare = CrTable::from_ratios(measured.ratios.clone());
        assert!(bare.decode_cycles.is_empty());
        let c = bare.decode_cycles_per_symbol(TransferKind::Activation, 8);
        // Nominal 1.16 cycles split across 8 lanes.
        assert!((c - 1.16 / 8.0).abs() < 1e-9, "fallback {c}");
    }
}
