//! Compression modes and measured wire ratios.
//!
//! Table 3 compares three settings: uncompressed, compressed weights only
//! (offline), and full LEXI (offline weights + on-the-fly activations and
//! hybrid caches). The wire ratio of each traffic class is *measured* by
//! running the actual codec + flit packetizer over representative streams
//! (synthetic at paper scale, real tensors at tiny scale via the runtime),
//! not assumed.
//!
//! **Codec-parametric (ISSUE 3):** every measurement routes through the
//! pluggable [`ExpCodec`] layer (`lexi_core::codec`) — no direct
//! `huffman::compress_exponents` call remains here. [`CrTable`] carries
//! ratios per `(codec, kind)` and decoder makespans per
//! `(codec, kind, lanes)`, so `Engine` can price transfers under any
//! [`CodecPolicy`](lexi_models::CodecPolicy). The Huffman column is
//! bit-identical to the pre-trait path (the trait wraps the same batch
//! engine; pinned by `batch_rewire_preserves_compressed_sizes` and
//! `huffman_via_trait_matches_direct_path` below).
//!
//! Decoder cost models per codec:
//! * `Huffman` — the measured cycle-accurate multi-lane LUT unit
//!   (`lexi-hw::DecoderUnit::decode_lane_stream`, slowest-lane makespan);
//! * `Bdi` — a simple per-block model (`bdi::block_decode_cycles`: tag +
//!   base fetches plus one cycle per delta), blocks round-robined over
//!   the lanes;
//! * `Raw` — zero (passthrough).

use lexi_core::batch::LaneCodec;
use lexi_core::bdi;
use lexi_core::bf16::FieldStreams;
use lexi_core::codec::CodecKind;
use lexi_core::flit::{self, FlitFormat};
use lexi_core::huffman::CodeBook;
use lexi_core::stats::Histogram;
use lexi_core::Bf16;
use lexi_hw::decoder::{DecoderConfig, DecoderUnit, MultiLutSpec};
use lexi_models::activations;
use lexi_models::traffic::TransferKind;
use lexi_models::weights::WeightStream;
use lexi_models::ModelConfig;
use std::collections::HashMap;

/// The three evaluated settings.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CompressionMode {
    Uncompressed,
    WeightsOnly,
    Lexi,
}

impl CompressionMode {
    /// All modes, Table 3 row order.
    pub const ALL: [CompressionMode; 3] = [
        CompressionMode::Uncompressed,
        CompressionMode::WeightsOnly,
        CompressionMode::Lexi,
    ];

    /// Is `kind` compressed under this mode?
    pub fn compresses(self, kind: TransferKind) -> bool {
        match self {
            CompressionMode::Uncompressed => false,
            CompressionMode::WeightsOnly => kind == TransferKind::Weights,
            CompressionMode::Lexi => true,
        }
    }
}

/// Measured ratios for one traffic class under one codec.
#[derive(Clone, Copy, Debug)]
pub struct KindRatios {
    /// Exponent-stream CR (8 bits → 8/cr), header included — Table 2's
    /// metric.
    pub exponent_cr: f64,
    /// Whole-transfer wire ratio including sign/mantissa passthrough and
    /// flit framing: uncompressed flits / coded flits.
    pub wire_ratio: f64,
}

/// Per-`(codec, kind)` measured ratios for one model, plus the measured
/// decoder makespan model the engine's transfer latency couples to
/// (ISSUE 2, now keyed by codec too — ISSUE 3).
#[derive(Clone, Debug)]
pub struct CrTable {
    pub ratios: HashMap<(CodecKind, TransferKind), KindRatios>,
    /// Decoder **cycles per transferred symbol** with `lanes` parallel
    /// decoders, per `(codec, kind, lanes)`. Huffman entries are measured
    /// on the cycle-accurate **multi-symbol** LUT unit (slowest-lane
    /// makespan ÷ symbols; grouped probes emit up to `LUT_MAX_SYMS`
    /// exponents per cycle — ISSUE 4),
    /// BDI entries come from the per-block cost model, Raw entries are
    /// zero. Empty for tables built from runtime profiles
    /// ([`CrTable::from_ratios`]); lookups then fall back to nominal
    /// per-codec latencies.
    pub decode_cycles: HashMap<(CodecKind, TransferKind, usize), f64>,
}

/// Sample size per (kind, layer) for ratio measurement. The streams are
/// i.i.d. within a layer, so a 16 K sample pins the ratio to ±1%.
const SAMPLE: usize = 16 * 1024;

/// Sample size for the decoder-makespan measurement (per kind; the
/// makespan-per-symbol statistic stabilizes faster than the ratios).
const DECODE_SAMPLE: usize = 8 * 1024;

/// Lane counts the makespan model is measured at. Lookups at other lane
/// counts scale inverse-linearly from the nearest measured point.
pub const CACHED_LANES: [usize; 5] = [1, 2, 4, 8, 16];

/// Fig 6's 4-stage average (≈1.16 cycles/symbol): the Huffman fallback
/// when a table carries no makespan measurements.
const NOMINAL_CYCLES_PER_SYMBOL: f64 = 1.16;

/// BDI fallback: a full 32-element delta block costs 2 + 32 cycles under
/// the per-block model → 34/32 ≈ 1.0625 cycles/symbol.
const BDI_NOMINAL_CYCLES_PER_SYMBOL: f64 = 1.0625;

impl CrTable {
    /// Measure ratios for `cfg` by running every registered codec over
    /// synthetic streams of each kind across several layers, and the
    /// decoder makespan model per codec: the cycle-accurate multi-lane
    /// LUT unit (`lexi-hw`) for Huffman, the per-block cost model for
    /// BDI, zero for Raw — each at every [`CACHED_LANES`] count.
    ///
    /// Calibration is **single-thread by design** (ISSUE 8): the cached
    /// makespans model one hardware decoder unit, so they come from the
    /// sequential `decode_lane_stream` replay. The host-side parallel
    /// paths (`decode_lane_stream_par`, `LaneCodec::decode_par`,
    /// `compress_exponents_par`) only change software wall-clock —
    /// their reports are defined to be identical to the sequential
    /// ones — and are benched as separate `perf_codec` rows, never
    /// substituted into this cycle model.
    pub fn measure(cfg: &ModelConfig, seed: u64) -> Self {
        let mut ratios = HashMap::new();
        let mut decode_cycles = HashMap::new();
        let layers: Vec<usize> = pick_layers(cfg);
        // ISSUE 4: the measured unit fronts its lanes with the
        // multi-symbol LUT, so cached makespans reflect grouped decode
        // (> 1 symbol/lane/cycle on paper-entropy streams). The engine
        // charges the matching table-fill latency at transfer startup.
        let unit = DecoderUnit::with_multi(
            DecoderConfig::paper_default(),
            MultiLutSpec::paper_default(),
        )
        .expect("paper config valid");
        let format = FlitFormat::new(128).expect("valid format");
        for kind in TransferKind::ALL {
            let mut sums: HashMap<CodecKind, (f64, f64)> = HashMap::new();
            let mut mid_exps: Vec<u8> = Vec::new();
            for (i, &layer) in layers.iter().enumerate() {
                let values: Vec<Bf16> = match kind {
                    TransferKind::Weights => {
                        let mut s = WeightStream::for_block(cfg, layer, seed);
                        let mut v = s.next_values(SAMPLE);
                        if v.len() < SAMPLE {
                            // Tiny blocks: repeat the stream.
                            while v.len() < SAMPLE {
                                let mut s2 = WeightStream::for_block(cfg, layer, seed ^ 1);
                                v.extend(s2.next_values(SAMPLE - v.len()));
                            }
                        }
                        v
                    }
                    _ => synth_values(cfg, layer, kind, seed),
                };
                let streams = FieldStreams::split(&values);
                let book = CodeBook::lexi_default(&Histogram::from_bytes(&streams.exponents))
                    .expect("non-empty");
                for codec in CodecKind::ALL {
                    let exp_cr = codec
                        .codec()
                        .encode(&streams.exponents)
                        .expect("non-empty")
                        .ratio();
                    let wire = flit::pack_codec(&streams, codec, Some(&book), format)
                        .expect("packable")
                        .ratio_vs_uncompressed();
                    let e = sums.entry(codec).or_insert((0.0, 0.0));
                    e.0 += exp_cr;
                    e.1 += wire;
                }
                // The middle layer doubles as the makespan-model sample.
                if i == layers.len() / 2 {
                    mid_exps = streams
                        .exponents
                        .into_iter()
                        .take(DECODE_SAMPLE)
                        .collect();
                }
            }
            let n = layers.len() as f64;
            for codec in CodecKind::ALL {
                let (exp_cr, wire) = sums[&codec];
                ratios.insert(
                    (codec, kind),
                    KindRatios {
                        exponent_cr: exp_cr / n,
                        wire_ratio: wire / n,
                    },
                );
            }
            // Decoder makespan per symbol at each cached lane count.
            if !mid_exps.is_empty() {
                let hist = Histogram::from_bytes(&mid_exps);
                let book = CodeBook::lexi_default(&hist).expect("non-empty");
                let bdi_costs = bdi::block_decode_cycles(&mid_exps);
                for lanes in CACHED_LANES {
                    let stream = LaneCodec::new(lanes)
                        .expect("cached lane count valid")
                        .encode(&mid_exps, &book);
                    let (_, rep) = unit
                        .decode_lane_stream(&stream, &book)
                        .expect("measured stream decodes");
                    decode_cycles.insert(
                        (CodecKind::Huffman, kind, lanes),
                        rep.makespan as f64 / mid_exps.len() as f64,
                    );
                    decode_cycles.insert(
                        (CodecKind::Bdi, kind, lanes),
                        bdi_makespan_per_symbol(&bdi_costs, mid_exps.len(), lanes),
                    );
                    decode_cycles.insert((CodecKind::Raw, kind, lanes), 0.0);
                }
            }
        }
        CrTable {
            ratios,
            decode_cycles,
        }
    }

    /// A table from externally measured **Huffman** ratios (e.g. the
    /// runtime coordinator's tensor profiles) with no decoder-makespan
    /// cache. Raw entries are synthesized at 1.0× (passthrough is exact);
    /// any other unmeasured codec reads 1.0× on lookup (no measured
    /// benefit is claimed for a codec nobody ran — see
    /// [`wire_ratio_for`]), and [`decode_cycles_per_symbol_for`] falls
    /// back to the per-codec nominal latencies.
    ///
    /// [`wire_ratio_for`]: CrTable::wire_ratio_for
    /// [`decode_cycles_per_symbol_for`]: CrTable::decode_cycles_per_symbol_for
    pub fn from_ratios(huffman: HashMap<TransferKind, KindRatios>) -> Self {
        let mut ratios = HashMap::new();
        for (kind, r) in huffman {
            ratios.insert((CodecKind::Huffman, kind), r);
            ratios.insert(
                (CodecKind::Raw, kind),
                KindRatios {
                    exponent_cr: 1.0,
                    wire_ratio: 1.0,
                },
            );
        }
        CrTable {
            ratios,
            decode_cycles: HashMap::new(),
        }
    }

    /// Wire bytes for a transfer of `bytes` of `kind` under `mode`, with
    /// the paper's (Huffman) codec.
    pub fn wire_bytes(&self, bytes: u64, kind: TransferKind, mode: CompressionMode) -> u64 {
        self.wire_bytes_for(CodecKind::Huffman, bytes, kind, mode)
    }

    /// Wire bytes under an explicit codec (what [`Engine`] calls per its
    /// [`CodecPolicy`](lexi_models::CodecPolicy)).
    ///
    /// [`Engine`]: crate::engine::Engine
    pub fn wire_bytes_for(
        &self,
        codec: CodecKind,
        bytes: u64,
        kind: TransferKind,
        mode: CompressionMode,
    ) -> u64 {
        if !mode.compresses(kind) {
            return bytes;
        }
        let r = self.wire_ratio_for(codec, kind);
        ((bytes as f64 / r).ceil() as u64).max(1)
    }

    /// Measured wire ratio of `(codec, kind)`; an unmeasured pair reads
    /// 1.0 (no compression claimed). Borrowing another codec's measured
    /// ratio here would be dishonest: a BDI policy on a ratio-only table
    /// would inherit Huffman's *better* wire ratio while being charged
    /// BDI's *cheaper* decode model, and read as strictly superior —
    /// the opposite of the measured ordering.
    pub fn wire_ratio_for(&self, codec: CodecKind, kind: TransferKind) -> f64 {
        self.ratios
            .get(&(codec, kind))
            .map(|r| r.wire_ratio)
            .unwrap_or(1.0)
    }

    /// Exponent CR of a kind under the paper's codec (Table 2 reporting).
    pub fn exponent_cr(&self, kind: TransferKind) -> f64 {
        self.exponent_cr_for(CodecKind::Huffman, kind)
    }

    /// Exponent CR of `(codec, kind)` (same unmeasured-reads-1.0 rule
    /// as [`wire_ratio_for`]).
    ///
    /// [`wire_ratio_for`]: CrTable::wire_ratio_for
    pub fn exponent_cr_for(&self, codec: CodecKind, kind: TransferKind) -> f64 {
        self.ratios
            .get(&(codec, kind))
            .map(|r| r.exponent_cr)
            .unwrap_or(1.0)
    }

    /// Paper-codec decode occupancy (compat shim over
    /// [`decode_cycles_per_symbol_for`]).
    ///
    /// [`decode_cycles_per_symbol_for`]: CrTable::decode_cycles_per_symbol_for
    pub fn decode_cycles_per_symbol(&self, kind: TransferKind, lanes: usize) -> f64 {
        self.decode_cycles_per_symbol_for(CodecKind::Huffman, kind, lanes)
    }

    /// Decoder cycles per transferred symbol for `(codec, kind)` with
    /// `lanes` parallel decoders: an exact cache hit when `lanes` is in
    /// [`CACHED_LANES`], otherwise the nearest measured point scaled
    /// inverse-linearly (lane throughput is ~linear until the link
    /// saturates), or the per-codec nominal latency when no measurements
    /// exist at all. Raw always decodes for free.
    pub fn decode_cycles_per_symbol_for(
        &self,
        codec: CodecKind,
        kind: TransferKind,
        lanes: usize,
    ) -> f64 {
        if codec == CodecKind::Raw {
            return 0.0;
        }
        let lanes = lanes.max(1);
        if let Some(&c) = self.decode_cycles.get(&(codec, kind, lanes)) {
            return c;
        }
        // Walk CACHED_LANES in its fixed order (not the HashMap, whose
        // iteration order is randomized per process): deterministic
        // nearest-point selection, ties resolved to the smaller count.
        let mut best: Option<(usize, f64)> = None;
        for l in CACHED_LANES {
            let Some(&c) = self.decode_cycles.get(&(codec, kind, l)) else {
                continue;
            };
            let closer = match best {
                None => true,
                Some((bl, _)) => {
                    (l as i64 - lanes as i64).abs() < (bl as i64 - lanes as i64).abs()
                }
            };
            if closer {
                best = Some((l, c));
            }
        }
        match best {
            Some((l, c)) => c * l as f64 / lanes as f64,
            None => {
                let nominal = match codec {
                    CodecKind::Bdi => BDI_NOMINAL_CYCLES_PER_SYMBOL,
                    _ => NOMINAL_CYCLES_PER_SYMBOL,
                };
                nominal / lanes as f64
            }
        }
    }
}

/// Slowest-lane BDI decode makespan per symbol: blocks dealt round-robin
/// to `lanes` sequential block decoders, each block priced by the simple
/// tag/base/delta cost model.
fn bdi_makespan_per_symbol(block_costs: &[u64], symbols: usize, lanes: usize) -> f64 {
    if symbols == 0 || block_costs.is_empty() {
        return 0.0;
    }
    let lanes = lanes.max(1);
    let mut lane_cycles = vec![0u64; lanes];
    for (i, &c) in block_costs.iter().enumerate() {
        lane_cycles[i % lanes] += c;
    }
    *lane_cycles.iter().max().expect("non-empty") as f64 / symbols as f64
}

/// Representative layers: first, middle, last.
fn pick_layers(cfg: &ModelConfig) -> Vec<usize> {
    let n = cfg.blocks.len();
    let mut v = vec![0, n / 2, n - 1];
    v.dedup();
    v
}

fn synth_values(cfg: &ModelConfig, layer: usize, kind: TransferKind, seed: u64) -> Vec<Bf16> {
    // Re-synthesize full values (not just exponents) so the flit packer
    // sees realistic sign/mantissa fields too.
    let exps = activations::sample_exponents(cfg, layer, kind, seed, SAMPLE);
    let mut rng = lexi_core::prng::Rng::new(seed ^ 0xabcd);
    exps.iter()
        .map(|&e| {
            Bf16::from_fields(
                (rng.next_u32() & 1) as u8,
                e,
                (rng.next_u32() & 0x7f) as u8,
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use lexi_core::huffman;
    use lexi_models::ModelScale;

    #[test]
    fn lexi_exponent_cr_in_paper_band() {
        // Table 2: LEXI ≈ 3.07–3.14× on weights.
        for cfg in ModelConfig::paper_models() {
            let t = CrTable::measure(&cfg, 42);
            let cr = t.exponent_cr(TransferKind::Weights);
            assert!((2.3..4.2).contains(&cr), "{}: CR {cr}", cfg.name);
        }
    }

    #[test]
    fn wire_ratio_between_1_and_2() {
        // Exponent-only coding of 16-bit values caps the wire ratio at
        // 16/8 = 2×; framing keeps it below that. BDI sits between Raw
        // and Huffman, and Raw pays only the head flit (just under 1×).
        let cfg = ModelConfig::qwen(ModelScale::Paper);
        let t = CrTable::measure(&cfg, 42);
        for kind in TransferKind::ALL {
            let h = t.wire_ratio_for(CodecKind::Huffman, kind);
            let b = t.wire_ratio_for(CodecKind::Bdi, kind);
            let r = t.wire_ratio_for(CodecKind::Raw, kind);
            assert!((1.05..2.0).contains(&h), "{kind:?}: huffman wire {h}");
            assert!(b > 1.0 && b < h, "{kind:?}: bdi wire {b} vs huffman {h}");
            assert!((0.9..=1.0).contains(&r), "{kind:?}: raw wire {r}");
        }
    }

    #[test]
    fn modes_gate_kinds() {
        let cfg = ModelConfig::qwen(ModelScale::Paper);
        let t = CrTable::measure(&cfg, 1);
        let b = 1_000_000u64;
        assert_eq!(
            t.wire_bytes(b, TransferKind::KvCache, CompressionMode::Uncompressed),
            b
        );
        assert_eq!(
            t.wire_bytes(b, TransferKind::KvCache, CompressionMode::WeightsOnly),
            b
        );
        assert!(t.wire_bytes(b, TransferKind::KvCache, CompressionMode::Lexi) < b);
        assert!(t.wire_bytes(b, TransferKind::Weights, CompressionMode::WeightsOnly) < b);
        // A raw policy never shrinks the transfer, whatever the mode.
        assert!(
            t.wire_bytes_for(CodecKind::Raw, b, TransferKind::KvCache, CompressionMode::Lexi)
                >= b
        );
    }

    #[test]
    fn batch_rewire_preserves_compressed_sizes() {
        // The ISSUE-1 acceptance gate: compressed sizes (and therefore
        // every CR table) must be byte-identical to the scalar path.
        let cfg = ModelConfig::jamba(ModelScale::Paper);
        for kind in [TransferKind::Activation, TransferKind::KvCache] {
            let exps = activations::sample_exponents(&cfg, 0, kind, 9, 40_000);
            let hist = Histogram::from_bytes(&exps);
            let book = CodeBook::lexi_default(&hist).unwrap();
            // Scalar oracle: header + count + one encode_symbol per exponent.
            let mut w = lexi_core::bitstream::BitWriter::new();
            book.write_header(&mut w);
            w.put(exps.len() as u64, 32);
            for &e in &exps {
                book.encode_symbol(e, &mut w);
            }
            let want_bits = w.len_bits();
            let want_bytes = w.into_bytes();
            let block = huffman::compress_with_book(&exps, &book).unwrap();
            assert_eq!(block.bits, want_bits, "{kind:?}");
            assert_eq!(block.bytes, want_bytes, "{kind:?}");
        }
    }

    #[test]
    fn huffman_via_trait_matches_direct_path() {
        // ISSUE 3 acceptance: the trait route the CrTable now measures
        // through is byte-identical to the direct compress_exponents
        // call it replaced.
        let cfg = ModelConfig::jamba(ModelScale::Paper);
        let exps = activations::sample_exponents(&cfg, 0, TransferKind::Activation, 9, 40_000);
        let direct = huffman::compress_exponents(&exps).unwrap();
        let via = CodecKind::Huffman.codec().encode(&exps).unwrap();
        assert_eq!(via.bytes, direct.bytes);
        assert_eq!(via.bits, direct.bits);
        assert_eq!(via.ratio(), direct.ratio());
    }

    #[test]
    fn measurement_is_deterministic() {
        let cfg = ModelConfig::jamba(ModelScale::Paper);
        let a = CrTable::measure(&cfg, 7);
        let b = CrTable::measure(&cfg, 7);
        assert_eq!(
            a.exponent_cr(TransferKind::Activation),
            b.exponent_cr(TransferKind::Activation)
        );
        assert_eq!(
            a.decode_cycles_per_symbol(TransferKind::Activation, 8),
            b.decode_cycles_per_symbol(TransferKind::Activation, 8)
        );
        assert_eq!(
            a.decode_cycles_per_symbol_for(CodecKind::Bdi, TransferKind::SsmState, 4),
            b.decode_cycles_per_symbol_for(CodecKind::Bdi, TransferKind::SsmState, 4)
        );
    }

    #[test]
    fn decode_cache_covers_all_codecs_kinds_and_scales_with_lanes() {
        let cfg = ModelConfig::qwen(ModelScale::Paper);
        let t = CrTable::measure(&cfg, 42);
        for kind in TransferKind::ALL {
            for codec in CodecKind::ALL {
                for lanes in CACHED_LANES {
                    assert!(
                        t.decode_cycles.contains_key(&(codec, kind, lanes)),
                        "{codec:?} {kind:?} lanes {lanes} missing from cache"
                    );
                }
            }
            // Per-symbol occupancy shrinks ~linearly as lanes grow
            // (round-robin keeps lanes balanced on i.i.d. streams).
            let c1 = t.decode_cycles_per_symbol(kind, 1);
            let c8 = t.decode_cycles_per_symbol(kind, 8);
            // ISSUE 4: the multi-symbol LUT unit groups ≤ LUT_MAX_SYMS
            // codewords per probe-cycle, so 1-lane occupancy now sits
            // *below* the old ≥ 1 cycle/symbol floor on paper-entropy
            // streams — but can never beat the group-size bound.
            assert!(
                c1 >= 1.0 / lexi_core::lut::LUT_MAX_SYMS as f64,
                "{kind:?}: 1-lane {c1} beats the {}-symbol probe bound",
                lexi_core::lut::LUT_MAX_SYMS
            );
            assert!(
                c1 < 1.0,
                "{kind:?}: 1-lane {c1} shows no multi-symbol grouping"
            );
            assert!(
                c8 < c1 / 4.0,
                "{kind:?}: 8 lanes ({c8}) not ≥4× faster than 1 ({c1})"
            );
            // Uncached lane counts interpolate from the nearest point.
            let c12 = t.decode_cycles_per_symbol(kind, 12);
            assert!(c12 > 0.0 && c12 < c8);
            // BDI: positive, near the per-block model's ~1.06
            // cycles/symbol at one lane, and lane-scaling.
            let b1 = t.decode_cycles_per_symbol_for(CodecKind::Bdi, kind, 1);
            let b8 = t.decode_cycles_per_symbol_for(CodecKind::Bdi, kind, 8);
            assert!((1.0..1.3).contains(&b1), "{kind:?}: bdi 1-lane {b1}");
            assert!(b8 < b1 / 4.0, "{kind:?}: bdi 8-lane {b8} vs {b1}");
            // Raw decodes for free at every lane count.
            assert_eq!(t.decode_cycles_per_symbol_for(CodecKind::Raw, kind, 1), 0.0);
            assert_eq!(t.decode_cycles_per_symbol_for(CodecKind::Raw, kind, 16), 0.0);
        }
    }

    #[test]
    fn ratio_only_tables_fall_back_per_codec() {
        let cfg = ModelConfig::qwen(ModelScale::Paper);
        let measured = CrTable::measure(&cfg, 42);
        let mut huffman_ratios = HashMap::new();
        for kind in TransferKind::ALL {
            huffman_ratios.insert(
                kind,
                measured.ratios[&(CodecKind::Huffman, kind)],
            );
        }
        let bare = CrTable::from_ratios(huffman_ratios);
        assert!(bare.decode_cycles.is_empty());
        // Nominal 1.16 cycles split across 8 lanes.
        let c = bare.decode_cycles_per_symbol(TransferKind::Activation, 8);
        assert!((c - 1.16 / 8.0).abs() < 1e-9, "fallback {c}");
        // BDI falls back to its per-block nominal, Raw to zero.
        let b = bare.decode_cycles_per_symbol_for(CodecKind::Bdi, TransferKind::Activation, 8);
        assert!((b - 1.0625 / 8.0).abs() < 1e-9, "bdi fallback {b}");
        assert_eq!(
            bare.decode_cycles_per_symbol_for(CodecKind::Raw, TransferKind::Activation, 8),
            0.0
        );
        // Ratio lookups: Raw synthesized at 1.0; unmeasured BDI also
        // reads 1.0 — it must not inherit Huffman's better wire ratio
        // while being charged BDI's cheaper decode model.
        assert_eq!(bare.wire_ratio_for(CodecKind::Raw, TransferKind::KvCache), 1.0);
        assert_eq!(bare.wire_ratio_for(CodecKind::Bdi, TransferKind::KvCache), 1.0);
        assert!(bare.wire_ratio_for(CodecKind::Huffman, TransferKind::KvCache) > 1.0);
    }

    #[test]
    fn bdi_makespan_model_balances_lanes() {
        // 8 equal blocks over 4 lanes → 2 blocks per lane exactly.
        let costs = vec![34u64; 8];
        let per1 = bdi_makespan_per_symbol(&costs, 256, 1);
        let per4 = bdi_makespan_per_symbol(&costs, 256, 4);
        assert!((per1 - 34.0 * 8.0 / 256.0).abs() < 1e-12);
        assert!((per4 - 34.0 * 2.0 / 256.0).abs() < 1e-12);
        assert_eq!(bdi_makespan_per_symbol(&[], 0, 4), 0.0);
    }
}
