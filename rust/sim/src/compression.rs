//! Compression modes and measured wire ratios.
//!
//! Table 3 compares three settings: uncompressed, compressed weights only
//! (offline), and full LEXI (offline weights + on-the-fly activations and
//! hybrid caches). The wire ratio of each traffic class is *measured* by
//! running the actual codec + flit packetizer over representative streams
//! (synthetic at paper scale, real tensors at tiny scale via the runtime),
//! not assumed.
//!
//! The measurement path routes through the §Perf batch engine
//! (`lexi_core::batch`) via `compress_exponents` / `flit::pack`; the
//! batch rewire is bit-identical to the scalar oracle, so every ratio in
//! this table is unchanged — pinned by
//! `batch_rewire_preserves_compressed_sizes` below.

use lexi_core::bf16::FieldStreams;
use lexi_core::flit::{self, FlitFormat};
use lexi_core::huffman::{self, CodeBook};
use lexi_core::stats::Histogram;
use lexi_core::Bf16;
use lexi_models::activations;
use lexi_models::traffic::TransferKind;
use lexi_models::weights::WeightStream;
use lexi_models::ModelConfig;
use std::collections::HashMap;

/// The three evaluated settings.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CompressionMode {
    Uncompressed,
    WeightsOnly,
    Lexi,
}

impl CompressionMode {
    /// All modes, Table 3 row order.
    pub const ALL: [CompressionMode; 3] = [
        CompressionMode::Uncompressed,
        CompressionMode::WeightsOnly,
        CompressionMode::Lexi,
    ];

    /// Is `kind` compressed under this mode?
    pub fn compresses(self, kind: TransferKind) -> bool {
        match self {
            CompressionMode::Uncompressed => false,
            CompressionMode::WeightsOnly => kind == TransferKind::Weights,
            CompressionMode::Lexi => true,
        }
    }
}

/// Measured ratios for one traffic class.
#[derive(Clone, Copy, Debug)]
pub struct KindRatios {
    /// Exponent-stream CR (8 bits → 8/cr), header included — Table 2's
    /// metric.
    pub exponent_cr: f64,
    /// Whole-transfer wire ratio including sign/mantissa passthrough and
    /// flit framing: uncompressed flits / LEXI flits.
    pub wire_ratio: f64,
}

/// Per-kind measured ratios for one model.
#[derive(Clone, Debug)]
pub struct CrTable {
    pub ratios: HashMap<TransferKind, KindRatios>,
}

/// Sample size per (kind, layer) for ratio measurement. The streams are
/// i.i.d. within a layer, so a 16 K sample pins the ratio to ±1%.
const SAMPLE: usize = 16 * 1024;

impl CrTable {
    /// Measure ratios for `cfg` by running the codec over synthetic
    /// streams of each kind across several layers.
    pub fn measure(cfg: &ModelConfig, seed: u64) -> Self {
        let mut ratios = HashMap::new();
        let layers: Vec<usize> = pick_layers(cfg);
        for kind in [
            TransferKind::Weights,
            TransferKind::Activation,
            TransferKind::KvCache,
            TransferKind::SsmState,
        ] {
            let mut exp_cr = 0.0;
            let mut wire = 0.0;
            for &layer in &layers {
                let values: Vec<Bf16> = match kind {
                    TransferKind::Weights => {
                        let mut s = WeightStream::for_block(cfg, layer, seed);
                        let mut v = s.next_values(SAMPLE);
                        if v.len() < SAMPLE {
                            // Tiny blocks: repeat the stream.
                            while v.len() < SAMPLE {
                                let mut s2 = WeightStream::for_block(cfg, layer, seed ^ 1);
                                v.extend(s2.next_values(SAMPLE - v.len()));
                            }
                        }
                        v
                    }
                    _ => synth_values(cfg, layer, kind, seed),
                };
                let (e, w) = measure_streams(&values);
                exp_cr += e;
                wire += w;
            }
            let n = layers.len() as f64;
            ratios.insert(
                kind,
                KindRatios {
                    exponent_cr: exp_cr / n,
                    wire_ratio: wire / n,
                },
            );
        }
        CrTable { ratios }
    }

    /// Wire bytes for a transfer of `bytes` of `kind` under `mode`.
    pub fn wire_bytes(&self, bytes: u64, kind: TransferKind, mode: CompressionMode) -> u64 {
        if !mode.compresses(kind) {
            return bytes;
        }
        let r = self.ratios[&kind].wire_ratio;
        ((bytes as f64 / r).ceil() as u64).max(1)
    }

    /// Exponent CR of a kind (Table 2 reporting).
    pub fn exponent_cr(&self, kind: TransferKind) -> f64 {
        self.ratios[&kind].exponent_cr
    }
}

/// Representative layers: first, middle, last.
fn pick_layers(cfg: &ModelConfig) -> Vec<usize> {
    let n = cfg.blocks.len();
    let mut v = vec![0, n / 2, n - 1];
    v.dedup();
    v
}

fn synth_values(cfg: &ModelConfig, layer: usize, kind: TransferKind, seed: u64) -> Vec<Bf16> {
    // Re-synthesize full values (not just exponents) so the flit packer
    // sees realistic sign/mantissa fields too.
    let exps = activations::sample_exponents(cfg, layer, kind, seed, SAMPLE);
    let mut rng = lexi_core::prng::Rng::new(seed ^ 0xabcd);
    exps.iter()
        .map(|&e| {
            Bf16::from_fields(
                (rng.next_u32() & 1) as u8,
                e,
                (rng.next_u32() & 0x7f) as u8,
            )
        })
        .collect()
}

/// (exponent CR, wire ratio) for one value sample.
fn measure_streams(values: &[Bf16]) -> (f64, f64) {
    let streams = FieldStreams::split(values);
    let block = huffman::compress_exponents(&streams.exponents).expect("non-empty");
    let exp_cr = block.ratio();

    let hist = Histogram::from_bytes(&streams.exponents);
    let book = CodeBook::lexi_default(&hist).expect("non-empty");
    let format = FlitFormat::new(128).expect("valid format");
    let transfer = flit::pack(&streams, &book, format).expect("packable");
    (exp_cr, transfer.ratio_vs_uncompressed())
}

#[cfg(test)]
mod tests {
    use super::*;
    use lexi_models::ModelScale;

    #[test]
    fn lexi_exponent_cr_in_paper_band() {
        // Table 2: LEXI ≈ 3.07–3.14× on weights.
        for cfg in ModelConfig::paper_models() {
            let t = CrTable::measure(&cfg, 42);
            let cr = t.exponent_cr(TransferKind::Weights);
            assert!((2.3..4.2).contains(&cr), "{}: CR {cr}", cfg.name);
        }
    }

    #[test]
    fn wire_ratio_between_1_and_2() {
        // Exponent-only coding of 16-bit values caps the wire ratio at
        // 16/8 = 2×; framing keeps it below that.
        let cfg = ModelConfig::qwen(ModelScale::Paper);
        let t = CrTable::measure(&cfg, 42);
        for (kind, r) in &t.ratios {
            assert!(
                (1.05..2.0).contains(&r.wire_ratio),
                "{kind:?}: wire {}",
                r.wire_ratio
            );
        }
    }

    #[test]
    fn modes_gate_kinds() {
        let cfg = ModelConfig::qwen(ModelScale::Paper);
        let t = CrTable::measure(&cfg, 1);
        let b = 1_000_000u64;
        assert_eq!(
            t.wire_bytes(b, TransferKind::KvCache, CompressionMode::Uncompressed),
            b
        );
        assert_eq!(
            t.wire_bytes(b, TransferKind::KvCache, CompressionMode::WeightsOnly),
            b
        );
        assert!(t.wire_bytes(b, TransferKind::KvCache, CompressionMode::Lexi) < b);
        assert!(t.wire_bytes(b, TransferKind::Weights, CompressionMode::WeightsOnly) < b);
    }

    #[test]
    fn batch_rewire_preserves_compressed_sizes() {
        // The ISSUE-1 acceptance gate: compressed sizes (and therefore
        // every CR table) must be byte-identical to the scalar path.
        let cfg = ModelConfig::jamba(ModelScale::Paper);
        for kind in [TransferKind::Activation, TransferKind::KvCache] {
            let exps = activations::sample_exponents(&cfg, 0, kind, 9, 40_000);
            let hist = Histogram::from_bytes(&exps);
            let book = CodeBook::lexi_default(&hist).unwrap();
            // Scalar oracle: header + count + one encode_symbol per exponent.
            let mut w = lexi_core::bitstream::BitWriter::new();
            book.write_header(&mut w);
            w.put(exps.len() as u64, 32);
            for &e in &exps {
                book.encode_symbol(e, &mut w);
            }
            let want_bits = w.len_bits();
            let want_bytes = w.into_bytes();
            let block = huffman::compress_with_book(&exps, &book).unwrap();
            assert_eq!(block.bits, want_bits, "{kind:?}");
            assert_eq!(block.bytes, want_bytes, "{kind:?}");
        }
    }

    #[test]
    fn measurement_is_deterministic() {
        let cfg = ModelConfig::jamba(ModelScale::Paper);
        let a = CrTable::measure(&cfg, 7);
        let b = CrTable::measure(&cfg, 7);
        assert_eq!(
            a.exponent_cr(TransferKind::Activation),
            b.exponent_cr(TransferKind::Activation)
        );
    }
}
