//! `lexi` binary entrypoint. See `cli` for the command set.

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = lexi::cli::run(args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
