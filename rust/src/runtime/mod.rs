//! PJRT runtime: load AOT HLO-text artifacts and execute them.
//!
//! The bridge from the Rust L3 coordinator to the JAX/Pallas-authored
//! model: `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute`. HLO *text* is the interchange format
//! (see `python/compile/aot.py` for why). Python never runs here.
//!
//! The `xla` crate is not in the offline crate set, so the PJRT half is
//! gated behind the `pjrt` feature: the manifest/tensor layer always
//! compiles, while the default build ships a [`Runtime`] stub that
//! errors at construction. Everything downstream (coordinator, CLI)
//! compiles against the same signatures either way.

use crate::json::Json;
use anyhow::{anyhow, Context, Result};
#[cfg(not(feature = "pjrt"))]
use anyhow::bail;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Shape + dtype of one runtime tensor.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    fn from_json(j: &Json) -> Result<Self> {
        let shape = j
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing shape"))?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        let dtype = j
            .get("dtype")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("missing dtype"))?
            .to_string();
        Ok(TensorSpec { shape, dtype })
    }

    /// Total element count.
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One executable's signature.
#[derive(Clone, Debug)]
pub struct ExecSpec {
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub output_names: Vec<String>,
}

impl ExecSpec {
    fn from_json(j: &Json) -> Result<Self> {
        let specs = |key: &str| -> Result<Vec<TensorSpec>> {
            j.get(key)
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("missing {key}"))?
                .iter()
                .map(TensorSpec::from_json)
                .collect()
        };
        Ok(ExecSpec {
            file: j
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("missing file"))?
                .to_string(),
            inputs: specs("inputs")?,
            outputs: specs("outputs")?,
            output_names: j
                .get("output_names")
                .and_then(Json::as_arr)
                .map(|a| {
                    a.iter()
                        .filter_map(|x| x.as_str().map(str::to_string))
                        .collect()
                })
                .unwrap_or_default(),
        })
    }
}

/// Per-model manifest entry.
#[derive(Clone, Debug)]
pub struct ModelManifest {
    pub name: String,
    pub seq_in: usize,
    pub out_max: usize,
    pub max_seq: usize,
    pub vocab: usize,
    pub d_model: usize,
    pub blocks: Vec<String>,
    pub prefill: ExecSpec,
    pub decode: ExecSpec,
}

/// The parsed `manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: HashMap<String, ModelManifest>,
}

impl Manifest {
    /// Load `manifest.json` from the artifacts directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json (run `make artifacts`)", dir.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        let mut models = HashMap::new();
        for (name, entry) in j.as_obj().ok_or_else(|| anyhow!("manifest not an object"))? {
            let usize_of = |key: &str| -> Result<usize> {
                entry
                    .get(key)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("missing {key}"))
            };
            models.insert(
                name.clone(),
                ModelManifest {
                    name: name.clone(),
                    seq_in: usize_of("seq_in")?,
                    out_max: usize_of("out_max")?,
                    max_seq: usize_of("max_seq")?,
                    vocab: usize_of("vocab")?,
                    d_model: usize_of("d_model")?,
                    blocks: entry
                        .get("blocks")
                        .and_then(Json::as_arr)
                        .ok_or_else(|| anyhow!("missing blocks"))?
                        .iter()
                        .filter_map(|b| b.as_str().map(str::to_string))
                        .collect(),
                    prefill: ExecSpec::from_json(
                        entry.get("prefill").ok_or_else(|| anyhow!("missing prefill"))?,
                    )?,
                    decode: ExecSpec::from_json(
                        entry.get("decode").ok_or_else(|| anyhow!("missing decode"))?,
                    )?,
                },
            );
        }
        Ok(Manifest { dir, models })
    }
}

/// A dense f32 tensor moving across the runtime boundary.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    /// Zero tensor of a spec's shape.
    pub fn zeros(spec: &TensorSpec) -> Self {
        Tensor {
            shape: spec.shape.clone(),
            data: vec![0.0; spec.elements()],
        }
    }

    /// Elements.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// Is the tensor empty (zero-sized dimension)?
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// View as BF16 values (exact: the model bf16-quantizes its outputs).
    pub fn to_bf16(&self) -> Vec<lexi_core::Bf16> {
        self.data.iter().map(|&x| lexi_core::Bf16::from_f32(x)).collect()
    }

    #[cfg(feature = "pjrt")]
    fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        if self.data.is_empty() {
            return Ok(xla::Literal::create_from_shape(
                xla::PrimitiveType::F32,
                &self.shape,
            ));
        }
        Ok(xla::Literal::vec1(&self.data).reshape(&dims)?)
    }
}

/// Prefill outputs (order fixed by the AOT manifest).
#[derive(Clone, Debug)]
pub struct PrefillOut {
    pub logits: Tensor,
    pub acts: Tensor,
    pub kv: Tensor,
    pub ssm: Tensor,
    pub conv: Tensor,
}

/// Decode-step outputs.
#[derive(Clone, Debug)]
pub struct DecodeOut {
    pub logits: Tensor,
    pub acts: Tensor,
    pub kv: Tensor,
    pub ssm: Tensor,
    pub conv: Tensor,
}

/// The PJRT CPU runtime.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    client: xla::PjRtClient,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        Ok(Runtime {
            client: xla::PjRtClient::cpu()?,
        })
    }

    /// Platform string (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile both executables of `model`.
    pub fn load_model(&self, manifest: &Manifest, model: &str) -> Result<LoadedModel> {
        let mm = manifest
            .models
            .get(model)
            .ok_or_else(|| anyhow!("model '{model}' not in manifest"))?
            .clone();
        let compile = |file: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = manifest.dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            Ok(self.client.compile(&comp)?)
        };
        let prefill = compile(&mm.prefill.file).context("compiling prefill")?;
        let decode = compile(&mm.decode.file).context("compiling decode")?;
        Ok(LoadedModel {
            manifest: mm,
            prefill,
            decode,
        })
    }
}

/// A compiled model pair (prefill + decode).
#[cfg(feature = "pjrt")]
pub struct LoadedModel {
    pub manifest: ModelManifest,
    prefill: xla::PjRtLoadedExecutable,
    decode: xla::PjRtLoadedExecutable,
}

#[cfg(feature = "pjrt")]
impl LoadedModel {
    /// Run prefill over `tokens` (must be exactly `seq_in` long).
    pub fn run_prefill(&self, tokens: &[i32]) -> Result<PrefillOut> {
        if tokens.len() != self.manifest.seq_in {
            anyhow::bail!(
                "prefill expects {} tokens, got {}",
                self.manifest.seq_in,
                tokens.len()
            );
        }
        let input = xla::Literal::vec1(tokens);
        let outs = self.execute(&self.prefill, vec![input], &self.manifest.prefill)?;
        let mut it = outs.into_iter();
        Ok(PrefillOut {
            logits: it.next().ok_or_else(|| anyhow!("missing logits"))?,
            acts: it.next().ok_or_else(|| anyhow!("missing acts"))?,
            kv: it.next().ok_or_else(|| anyhow!("missing kv"))?,
            ssm: it.next().ok_or_else(|| anyhow!("missing ssm"))?,
            conv: it.next().ok_or_else(|| anyhow!("missing conv"))?,
        })
    }

    /// Run one decode step at absolute position `pos`.
    pub fn run_decode(
        &self,
        token: i32,
        pos: i32,
        kv: &Tensor,
        ssm: &Tensor,
        conv: &Tensor,
    ) -> Result<DecodeOut> {
        let inputs = vec![
            xla::Literal::scalar(token),
            xla::Literal::scalar(pos),
            kv.to_literal()?,
            ssm.to_literal()?,
            conv.to_literal()?,
        ];
        let outs = self.execute(&self.decode, inputs, &self.manifest.decode)?;
        let mut it = outs.into_iter();
        Ok(DecodeOut {
            logits: it.next().ok_or_else(|| anyhow!("missing logits"))?,
            acts: it.next().ok_or_else(|| anyhow!("missing acts"))?,
            kv: it.next().ok_or_else(|| anyhow!("missing kv"))?,
            ssm: it.next().ok_or_else(|| anyhow!("missing ssm"))?,
            conv: it.next().ok_or_else(|| anyhow!("missing conv"))?,
        })
    }

    fn execute(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: Vec<xla::Literal>,
        spec: &ExecSpec,
    ) -> Result<Vec<Tensor>> {
        let result = exe.execute::<xla::Literal>(&inputs)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        if parts.len() != spec.outputs.len() {
            anyhow::bail!(
                "expected {} outputs, got {}",
                spec.outputs.len(),
                parts.len()
            );
        }
        parts
            .into_iter()
            .zip(&spec.outputs)
            .map(|(lit, ospec)| {
                let data = if ospec.elements() == 0 {
                    Vec::new()
                } else {
                    lit.to_vec::<f32>()?
                };
                if data.len() != ospec.elements() {
                    anyhow::bail!(
                        "output elements {} != spec {}",
                        data.len(),
                        ospec.elements()
                    );
                }
                Ok(Tensor {
                    shape: ospec.shape.clone(),
                    data,
                })
            })
            .collect()
    }
}

#[cfg(not(feature = "pjrt"))]
const NO_PJRT: &str =
    "this build has no PJRT runtime: rebuild with `--features pjrt` (requires the `xla` crate, \
     absent from the offline crate set)";

/// Stub runtime compiled when the `pjrt` feature is off: construction
/// fails with a clear message, so `lexi profile` and the runtime_e2e
/// tests (which skip without artifacts anyway) degrade gracefully.
#[cfg(not(feature = "pjrt"))]
pub struct Runtime {
    _private: (),
}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    /// Fails: the PJRT client is unavailable in this build.
    pub fn cpu() -> Result<Self> {
        bail!("{NO_PJRT}")
    }

    /// Platform string (diagnostics).
    pub fn platform(&self) -> String {
        "stub (no pjrt)".to_string()
    }

    /// Fails: the PJRT client is unavailable in this build.
    pub fn load_model(&self, _manifest: &Manifest, _model: &str) -> Result<LoadedModel> {
        bail!("{NO_PJRT}")
    }
}

/// Stub compiled model: carries the manifest so coordinator code
/// typechecks; execution paths error.
#[cfg(not(feature = "pjrt"))]
pub struct LoadedModel {
    pub manifest: ModelManifest,
}

#[cfg(not(feature = "pjrt"))]
impl LoadedModel {
    /// Fails: no executable is loaded in a stub build.
    pub fn run_prefill(&self, _tokens: &[i32]) -> Result<PrefillOut> {
        bail!("{NO_PJRT}")
    }

    /// Fails: no executable is loaded in a stub build.
    pub fn run_decode(
        &self,
        _token: i32,
        _pos: i32,
        _kv: &Tensor,
        _ssm: &Tensor,
        _conv: &Tensor,
    ) -> Result<DecodeOut> {
        bail!("{NO_PJRT}")
    }
}

/// Greedy argmax over logits.
pub fn argmax(logits: &Tensor) -> i32 {
    logits
        .data
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
        .map(|(i, _)| i as i32)
        .unwrap_or(0)
}
