//! The `lexi` command-line driver (hand-rolled args: no clap offline).
//!
//! ```text
//! lexi profile  [--model jamba] [--decode 8] [--artifacts DIR]
//! lexi e2e      [--scale paper|tiny] [--model NAME|all] [--dataset wikitext2|c4|all]
//! lexi table2
//! lexi hw
//! lexi noc      [--pattern uniform|transpose|hotspot] [--mesh 6x6]
//!               [--topology mesh|cmesh|multipackage] [--packages P] [--conc C]
//!               [--vcs N]
//!               [--egress LANES] [--ingress LANES] [--codec huffman|bdi|raw]
//!               [--ber RATE] [--drop P] [--dup P] [--fault-seed N]
//!               [--link-down A-B[@CYCLE]] [--watchdog N]
//! lexi dse      [--what hitrate|codebook|decoder|codec] [--model jamba]
//! lexi serve    [--trace poisson|burst] [--load F] [--deadline NS] [--seed S]
//! ```

use crate::coordinator::Session;
use crate::runtime::{Manifest, Runtime};
use anyhow::{anyhow, bail, Result};
use lexi_bench::{fmt_ns, fmt_ratio, Table};
use lexi_core::codec::CodecKind;
use lexi_hw::area_power::{AreaPower, LexiHwConfig};
use lexi_hw::decoder::DecoderConfig;
use lexi_hw::histogram_unit::{HistConfig, HistogramUnit};
use lexi_models::corpus::Corpus;
use lexi_models::traffic::TransferKind;
use lexi_models::weights::WeightStream;
use lexi_models::{CodecPolicy, DegradePolicy, DegradeTracker, ModelConfig, ModelScale};
use lexi_noc::{
    CMesh, FaultModel, Mesh, MultiPackage, Network, NetworkConfig, NodeId, RetryConfig, Topo,
    Topology,
};
use lexi_sim::compression::{CompressionMode, CrTable};
use lexi_sim::engine::Engine;
use lexi_sim::serving::{ServingConfig, ServingSim, ServingStats, TraceKind};
use std::collections::HashMap;

/// Parsed flags: `--key value` pairs after the subcommand.
pub struct Flags {
    map: HashMap<String, String>,
}

impl Flags {
    /// Parse from raw args (after the subcommand).
    pub fn parse(args: &[String]) -> Result<Self> {
        let mut map = HashMap::new();
        let mut i = 0;
        while i < args.len() {
            let key = args[i]
                .strip_prefix("--")
                .ok_or_else(|| anyhow!("expected --flag, got '{}'", args[i]))?;
            let val = args
                .get(i + 1)
                .ok_or_else(|| anyhow!("--{key} needs a value"))?;
            map.insert(key.to_string(), val.clone());
            i += 2;
        }
        Ok(Flags { map })
    }

    /// Flag value or default.
    pub fn get<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.map.get(key).map(String::as_str).unwrap_or(default)
    }

    /// Numeric flag.
    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.map.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{key}: {e}")),
        }
    }

    /// Float flag (e.g. `--ber 1e-6`).
    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.map.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{key}: {e}")),
        }
    }
}

/// Entry point used by `main`.
pub fn run(args: Vec<String>) -> Result<()> {
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let flags = Flags::parse(args.get(1..).unwrap_or(&[]))?;
    match cmd {
        "profile" => cmd_profile(&flags),
        "e2e" => cmd_e2e(&flags),
        "table2" => cmd_table2(),
        "hw" => cmd_hw(),
        "noc" => cmd_noc(&flags),
        "dse" => cmd_dse(&flags),
        "energy" => cmd_energy(&flags),
        "serve" => cmd_serve(&flags),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            bail!("unknown command '{other}'")
        }
    }
}

fn print_help() {
    println!(
        "lexi — lossless BF16 exponent coding for inter-chiplet communication\n\
         \n\
         commands:\n\
         \x20 profile  --model jamba|zamba|qwen --decode N --artifacts DIR\n\
         \x20          run the AOT model via PJRT; profile real exponent streams\n\
         \x20 e2e      --scale paper|tiny --model NAME|all --dataset wikitext2|c4|all\n\
         \x20          Table 3 / Fig 7: comm + end-to-end latency per mode\n\
         \x20 table2   exponent CR comparison (RLE / BDI / LEXI) on weights\n\
         \x20 hw       Table 4: area/power breakdown (GF 22 nm + 16 nm scaling)\n\
         \x20 noc      --pattern uniform|transpose|hotspot — cycle-accurate NoI run\n\
         \x20          (--topology mesh|cmesh|multipackage --packages P --conc C:\n\
         \x20          router graph — flat mesh, concentrated mesh with C endpoints\n\
         \x20          per router, or P stitched packages joined on gateway rows;\n\
         \x20          --vcs N: virtual-channel router — VC 0 is the deadlock-free\n\
         \x20          escape lane, VCs >= 1 route adaptively, with per-VC report\n\
         \x20          lines and credit audit;\n\
         \x20          --egress LANES --codec huffman|bdi|raw: egress codec ports;\n\
         \x20          --ingress LANES: ingress encoder pacing with a bounded NI\n\
         \x20          queue — saturation is counted backpressure, never growth;\n\
         \x20          --ber RATE --drop P --dup P --fault-seed N: seeded link\n\
         \x20          faults with CRC NACK + bounded retry and degradation report;\n\
         \x20          --link-down A-B[@CYCLE]: permanent link failure — severed\n\
         \x20          wormholes truncate + retry over escape routes, or report\n\
         \x20          typed unreachability; --watchdog N: stall watchdog window\n\
         \x20          in cycles — a hung run terminates with a stall report;\n\
         \x20          --retry-budget N --backoff-cap C: NACK-recovery envelope,\n\
         \x20          defaults pinned to the paper schedule)\n\
         \x20 dse      --what hitrate|codebook|decoder|codec — design-space sweeps\n\
         \x20          (Figs 4-6; 'codec' prints the per-kind Huffman/BDI/Raw table)\n\
         \x20 energy   interconnect energy per inference (link vs codec)\n\
         \x20 serve    --requests N — concurrent-decode throughput ceiling, or\n\
         \x20          --trace poisson|burst --load F --deadline NS --seed S:\n\
         \x20          open-loop multi-tenant serving with deadline-aware\n\
         \x20          admission, hysteresis degradation + probe recovery\n\
         \x20          (--nodes N --queue-depth D --admission on|off\n\
         \x20          --retry-budget N --backoff-cap C)"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flags_parse_pairs() {
        let args: Vec<String> = ["--model", "jamba", "--decode", "8"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let f = Flags::parse(&args).unwrap();
        assert_eq!(f.get("model", "x"), "jamba");
        assert_eq!(f.get_usize("decode", 0).unwrap(), 8);
        assert_eq!(f.get("missing", "dflt"), "dflt");
    }

    #[test]
    fn flags_parse_floats() {
        let args: Vec<String> = ["--ber", "1e-6", "--drop", "0.25"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let f = Flags::parse(&args).unwrap();
        assert_eq!(f.get_f64("ber", 0.0).unwrap(), 1e-6);
        assert_eq!(f.get_f64("drop", 0.0).unwrap(), 0.25);
        assert_eq!(f.get_f64("dup", 0.125).unwrap(), 0.125);
        let bad: Vec<String> = vec!["--ber".into(), "lots".into()];
        assert!(Flags::parse(&bad).unwrap().get_f64("ber", 0.0).is_err());
    }

    #[test]
    fn flags_reject_malformed() {
        let bad1: Vec<String> = vec!["model".into()];
        assert!(Flags::parse(&bad1).is_err());
        let bad2: Vec<String> = vec!["--model".into()];
        assert!(Flags::parse(&bad2).is_err());
        let bad3: Vec<String> = vec!["--n".into(), "abc".into()];
        assert!(Flags::parse(&bad3).unwrap().get_usize("n", 0).is_err());
    }

    #[test]
    fn unknown_command_errors() {
        assert!(run(vec!["frobnicate".into()]).is_err());
        assert!(run(vec!["help".into()]).is_ok());
    }

    fn run_noc(args: &[&str]) -> Result<()> {
        let mut v = vec!["noc".to_string()];
        v.extend(args.iter().map(|s| s.to_string()));
        run(v)
    }

    #[test]
    fn noc_topology_and_vc_flags_are_validated() {
        // Every bad combination is a typed CLI error before the
        // simulator is even built (ISSUE 10 satellite).
        assert!(run_noc(&["--topology", "ring"]).is_err());
        assert!(run_noc(&["--vcs", "0"]).is_err());
        assert!(run_noc(&["--vcs", "99"]).is_err());
        assert!(run_noc(&["--topology", "multipackage", "--packages", "1"]).is_err());
        assert!(run_noc(&["--topology", "cmesh", "--conc", "0"]).is_err());
        assert!(run_noc(&["--topology", "cmesh", "--pattern", "transpose"]).is_err());
        // Non-adjacent pair on the flat 6x6 mesh (0 and 7 are diagonal).
        assert!(run_noc(&["--link-down", "0-7"]).is_err());
        // 36 exists only once a second package is stitched on.
        assert!(run_noc(&["--link-down", "5-36"]).is_err());
        // A non-gateway boundary pair is not a link even when stitched:
        // row 1 of a 6-row package carries no inter-package stitch.
        assert!(run_noc(&[
            "--topology",
            "multipackage",
            "--link-down",
            "11-42"
        ])
        .is_err());
    }

    #[test]
    fn noc_runs_stitched_multipackage_with_vcs_and_gateway_kill() {
        // End-to-end: 2 stitched 6x6 packages, 2 VCs, one gateway
        // stitch (5↔36, row 0) killed mid-run — the other gateway row
        // keeps the array connected, so the run completes and prints
        // the per-VC report lines.
        assert!(run_noc(&[
            "--topology",
            "multipackage",
            "--packages",
            "2",
            "--vcs",
            "2",
            "--count",
            "80",
            "--link-down",
            "5-36@200"
        ])
        .is_ok());
    }

    #[test]
    fn noc_runs_concentrated_mesh() {
        assert!(run_noc(&["--topology", "cmesh", "--conc", "2", "--count", "40"]).is_ok());
    }
}

// --- profile ---------------------------------------------------------------

fn cmd_profile(flags: &Flags) -> Result<()> {
    let model = flags.get("model", "jamba");
    let steps = flags.get_usize("decode", 8)?;
    let artifacts = flags.get("artifacts", "artifacts");

    let manifest = Manifest::load(artifacts)?;
    let rt = Runtime::cpu()?;
    eprintln!("pjrt platform: {}", rt.platform());
    let loaded = rt.load_model(&manifest, model)?;
    let mm = loaded.manifest.clone();
    let corpus = Corpus::wikitext2();
    let tokens: Vec<i32> = corpus
        .tokens(mm.vocab, 7)
        .iter()
        .take(mm.seq_in)
        .map(|&t| t as i32)
        .collect();

    let session = Session::new(loaded);
    let report = session.run(&tokens, steps)?;

    println!(
        "\nmodel={} prompt={} generated={:?}",
        report.model, report.prompt_len, report.generated
    );
    let mut t = Table::new(&[
        "stream", "kind", "values", "H(exp)", "H(mant)", "#exp", "LEXI", "RLE", "BDI", "wire",
    ]);
    for p in &report.profiles {
        t.row(vec![
            p.name.clone(),
            format!("{:?}", p.kind),
            p.count.to_string(),
            format!("{:.2}", p.exp_entropy),
            format!("{:.2}", p.mant_entropy),
            p.exp_distinct.to_string(),
            fmt_ratio(p.lexi_cr),
            fmt_ratio(p.rle_cr),
            fmt_ratio(p.bdi_cr),
            fmt_ratio(p.wire_ratio),
        ]);
    }
    t.print();
    println!(
        "\nmean exponent entropy: {:.2} bits (paper: <3 bits)",
        report.mean_exp_entropy()
    );
    Ok(())
}

// --- e2e (Table 3 / Fig 7) ---------------------------------------------------

fn cmd_e2e(flags: &Flags) -> Result<()> {
    let scale = match flags.get("scale", "paper") {
        "paper" => ModelScale::Paper,
        "tiny" => ModelScale::Tiny,
        other => bail!("unknown scale '{other}'"),
    };
    let model_sel = flags.get("model", "all");
    let ds_sel = flags.get("dataset", "all");

    let models: Vec<ModelConfig> = [
        ModelConfig::jamba(scale),
        ModelConfig::zamba(scale),
        ModelConfig::qwen(scale),
    ]
    .into_iter()
    .filter(|m| model_sel == "all" || m.name.contains(model_sel))
    .collect();
    if models.is_empty() {
        bail!("no model matches '{model_sel}'");
    }
    let corpora: Vec<Corpus> = Corpus::all()
        .into_iter()
        .filter(|c| ds_sel == "all" || c.name.contains(ds_sel))
        .collect();

    let engine = Engine::paper_default();
    let mut t3 = Table::new(&["dataset", "method", "model", "comm (ms)", "e2e (ms)", "comm %"]);
    for corpus in &corpora {
        for cfg in &models {
            let crs = CrTable::measure(cfg, 42);
            for mode in CompressionMode::ALL {
                let r = engine.run(cfg, corpus, mode, &crs);
                t3.row(vec![
                    corpus.name.into(),
                    format!("{mode:?}"),
                    cfg.name.into(),
                    format!("{:.2}", r.comm_ms()),
                    format!("{:.2}", r.e2e_ms()),
                    format!("{:.0}%", r.comm_fraction() * 100.0),
                ]);
            }
        }
    }
    t3.print();

    println!("\nreductions vs uncompressed (paper: comm 33-45%, e2e 30-35%):");
    let mut t7 = Table::new(&["dataset", "model", "comm red.", "e2e red."]);
    for corpus in &corpora {
        for cfg in &models {
            let crs = CrTable::measure(cfg, 42);
            let unc = engine.run(cfg, corpus, CompressionMode::Uncompressed, &crs);
            let lexi = engine.run(cfg, corpus, CompressionMode::Lexi, &crs);
            t7.row(vec![
                corpus.name.into(),
                cfg.name.into(),
                format!("{:.1}%", (1.0 - lexi.comm_ns / unc.comm_ns) * 100.0),
                format!("{:.1}%", (1.0 - lexi.e2e_ns() / unc.e2e_ns()) * 100.0),
            ]);
        }
    }
    t7.print();
    Ok(())
}

// --- table2 ------------------------------------------------------------------

fn cmd_table2() -> Result<()> {
    let mut t = Table::new(&["model", "Base", "RLE", "BDI", "LEXI"]);
    for cfg in ModelConfig::paper_models() {
        let mut lexi = 0.0;
        let mut rle_r = 0.0;
        let mut bdi_r = 0.0;
        let layers = [0usize, cfg.blocks.len() / 2, cfg.blocks.len() - 1];
        for &layer in &layers {
            let exps = WeightStream::sample_exponents(&cfg, layer, 42, 200_000);
            // Compressors dispatch through the ExpCodec registry; RLE is
            // a Table 2 baseline only and stays a direct call.
            lexi += CodecKind::Huffman.codec().encode(&exps)?.ratio();
            rle_r += lexi_core::rle::coding_ratio(&exps);
            bdi_r += CodecKind::Bdi.codec().coding_ratio(&exps);
        }
        let n = layers.len() as f64;
        t.row(vec![
            cfg.name.into(),
            "1.00×".into(),
            fmt_ratio(rle_r / n),
            fmt_ratio(bdi_r / n),
            fmt_ratio(lexi / n),
        ]);
    }
    t.print();
    Ok(())
}

// --- hw (Table 4) --------------------------------------------------------------

fn cmd_hw() -> Result<()> {
    let bp = AreaPower::of(&LexiHwConfig::paper_default());
    let mut t = Table::new(&["component", "area (µm²)", "power (mW)", "lanes", "total area", "total power"]);
    for item in &bp.items {
        t.row(vec![
            item.name.into(),
            format!("{:.2}", item.unit_area_um2),
            format!("{:.2}", item.unit_power_mw),
            format!("×{}", item.count),
            format!("{:.1}", item.total_area_um2()),
            format!("{:.2}", item.total_power_mw()),
        ]);
    }
    t.print();
    println!(
        "\ntotal: {:.1} µm² @22nm, {:.2} mW; {:.1} µm² @16nm; {:.3}% of a 6 mm² Simba chiplet",
        bp.total_area_um2(),
        bp.total_power_mw(),
        bp.total_area_16nm_um2(),
        bp.chiplet_overhead_pct()
    );
    Ok(())
}

// --- noc -------------------------------------------------------------------------

fn cmd_noc(flags: &Flags) -> Result<()> {
    let mesh_s = flags.get("mesh", "6x6");
    let (cols, rows) = mesh_s
        .split_once('x')
        .and_then(|(a, b)| Some((a.parse().ok()?, b.parse().ok()?)))
        .ok_or_else(|| anyhow!("bad --mesh '{mesh_s}' (want CxR)"))?;
    let mesh = Mesh::new(cols, rows);
    // --topology picks the router graph the CxR grid becomes (ISSUE 10):
    // the flat mesh, a concentrated mesh with --conc endpoints per
    // router, or --packages stitched copies joined on gateway rows.
    let topo_s = flags.get("topology", "mesh");
    let packages = flags.get_usize("packages", 2)?;
    let conc = flags.get_usize("conc", 2)?;
    let topo = match topo_s {
        "mesh" => Topo::Mesh(mesh),
        "cmesh" => {
            if !(1..=255).contains(&conc) {
                bail!("--conc {conc}: want 1..=255");
            }
            Topo::CMesh(CMesh::new(cols, rows, conc as u8))
        }
        "multipackage" => {
            if !(2..=255).contains(&packages) {
                bail!("--packages {packages}: a stitched array wants 2..=255");
            }
            Topo::MultiPackage(MultiPackage::new(packages as u8, cols, rows))
        }
        other => bail!("unknown --topology '{other}' (want mesh|cmesh|multipackage)"),
    };
    // --vcs N runs the virtual-channel router (ISSUE 10): VC 0 is the
    // deadlock-free escape lane, VCs ≥ 1 route adaptively. The buffer
    // budget grows with the lane count so every VC keeps ≥ 2 credits
    // (line rate needs one credit in flight plus one returning).
    let vcs = flags.get_usize("vcs", 1)?;
    if !(1..=lexi_noc::MAX_VCS as usize).contains(&vcs) {
        bail!("--vcs {vcs}: want 1..={}", lexi_noc::MAX_VCS);
    }
    let mut cfg = NetworkConfig {
        topo,
        vcs: vcs as u8,
        ..NetworkConfig::paper_default()
    };
    cfg.buf_depth = cfg.buf_depth.max(2 * vcs as u32);
    let pattern = flags.get("pattern", "uniform");
    let size_bits = flags.get_usize("size-bits", 128 * 64)? as u64;
    let count = flags.get_usize("count", 500)?;
    // --egress LANES routes ejection through the codec ports (ISSUE 5):
    // packets are tagged with --codec (default huffman) and drained at
    // the nominal decoder rate for that lane count.
    let egress_lanes = flags.get_usize("egress", 0)?;
    // --ingress LANES paces injection through the encoder model with a
    // bounded NI queue (ISSUE 7).
    let ingress_lanes = flags.get_usize("ingress", 0)?;
    let codec = CodecKind::parse(flags.get("codec", "huffman"))
        .map_err(|e| anyhow!("--codec: {e}"))?;
    // --ber/--drop/--dup attach the seeded link fault model (ISSUE 6):
    // corrupted packets are NACKed by the egress CRC check and
    // retransmitted with exponential backoff, bounded by the retry
    // budget — losses are counted, never silent.
    let ber = flags.get_f64("ber", 0.0)?;
    let drop_p = flags.get_f64("drop", 0.0)?;
    let dup_p = flags.get_f64("dup", 0.0)?;
    let fault_seed = flags.get_usize("fault-seed", 0xFA17)? as u64;
    // --retry-budget/--backoff-cap tune the NACK-recovery envelope
    // (ISSUE 9): defaults reproduce the pinned paper-default schedule
    // bit-for-bit, so existing runs are unchanged.
    let retry_default = RetryConfig::paper_default();
    let retry = RetryConfig {
        budget: flags.get_usize("retry-budget", retry_default.budget as usize)? as u32,
        backoff_cap: flags.get_usize("backoff-cap", retry_default.backoff_cap as usize)? as u64,
        ..retry_default
    };
    // --watchdog N overrides the stall-watchdog window (ISSUE 7).
    let watchdog = flags.get_usize("watchdog", 0)?;
    // --link-down A-B[@CYCLE] schedules permanent link failures
    // (ISSUE 7); comma-separated for several. Endpoint range and
    // adjacency are validated against the chosen *topology* (gateway
    // stitches included) so a typo is a CLI error, not a simulator
    // panic.
    let link_down_s = flags.get("link-down", "");
    let mut link_downs: Vec<(NodeId, NodeId, u64)> = Vec::new();
    if !link_down_s.is_empty() {
        for part in link_down_s.split(',') {
            let (pair, at) = match part.split_once('@') {
                Some((p, c)) => (
                    p,
                    c.parse::<u64>()
                        .map_err(|e| anyhow!("--link-down '{part}': {e}"))?,
                ),
                None => (part, 0),
            };
            let (a, b) = pair
                .split_once('-')
                .and_then(|(a, b)| Some((a.parse::<u16>().ok()?, b.parse::<u16>().ok()?)))
                .ok_or_else(|| anyhow!("bad --link-down '{part}' (want A-B or A-B@CYCLE)"))?;
            if a as usize >= topo.len() || b as usize >= topo.len() {
                bail!(
                    "--link-down {a}-{b}: node out of range for the {} endpoints of \
                     this {topo_s} topology",
                    topo.len()
                );
            }
            let (na, nb) = (NodeId(a), NodeId(b));
            let (ra, rb) = (topo.router_of(na), topo.router_of(nb));
            let adjacent = ra != rb
                && lexi_noc::topology::Port::ALL
                    .iter()
                    .any(|&p| topo.neighbour_r(ra, p) == Some(rb));
            if !adjacent {
                bail!("--link-down {a}-{b}: not a link of the {mesh_s} {topo_s} topology");
            }
            link_downs.push((na, nb, at));
        }
    }

    let mut specs = match pattern {
        "uniform" => {
            let mut rng = lexi_core::prng::Rng::new(1);
            lexi_noc::traffic::uniform_random(topo, count, size_bits, 0.25, &mut rng)
        }
        "transpose" => {
            if topo.as_mesh().is_none() {
                bail!("--pattern transpose is defined on --topology mesh only");
            }
            lexi_noc::traffic::transpose(mesh, size_bits)
        }
        "hotspot" => lexi_noc::traffic::hotspot(topo, NodeId(0), size_bits),
        other => bail!("unknown pattern '{other}'"),
    };
    if egress_lanes > 0 || ingress_lanes > 0 {
        // ~10 wire bits per exponent symbol at the paper wire ratio
        // (coded exponent + sign/mantissa passthrough per BF16 value).
        lexi_noc::traffic::tag_packets(&mut specs, codec, 10.0, true);
    }
    let mut net = if egress_lanes > 0 {
        Network::with_egress(
            cfg,
            lexi_noc::EgressCodecConfig::nominal(egress_lanes, 1.0),
        )
    } else {
        Network::new(cfg)
    };
    if ingress_lanes > 0 {
        net.set_ingress_config(lexi_noc::IngressCodecConfig::nominal(ingress_lanes, 1.0));
    }
    if watchdog > 0 {
        net.set_watchdog(watchdog as u64);
    }
    let mut fault = FaultModel::new(fault_seed)
        .with_ber(ber)
        .with_drop(drop_p)
        .with_dup(dup_p)
        .with_retry(retry);
    let faults_on = fault.enabled();
    for &(a, b, at) in &link_downs {
        fault = fault.with_link_down(a, b, at);
    }
    if faults_on || !link_downs.is_empty() {
        net.set_fault_model(fault);
    }
    let n = specs.len();
    // User-controlled flags can produce invalid tagged specs (e.g.
    // --size-bits 0): surface the validation error as a CLI error, not
    // a panic.
    net.try_schedule_packets(&specs)
        .map_err(|e| anyhow!("invalid packet specs: {e}"))?;
    // A stall (credit leak, dead route, zero-rate port) terminates with
    // a typed report instead of hanging the CLI (ISSUE 7).
    let stats = match net.try_run_to_completion(50_000_000) {
        Ok(stats) => stats,
        Err(report) => {
            eprintln!("{report}");
            bail!("simulation stalled after {} idle cycles", report.stalled_for);
        }
    };
    let topo_desc = match topo {
        Topo::Mesh(_) => format!("mesh={mesh_s}"),
        Topo::CMesh(c) => format!("cmesh={mesh_s}x{}", c.conc),
        Topo::MultiPackage(mp) => format!("multipackage={}x{mesh_s}", mp.packages),
    };
    println!(
        "pattern={pattern} {topo_desc} vcs={vcs}: {n} packets, {} flits, {} cycles ({})",
        stats.delivered_flits,
        stats.cycles,
        fmt_ns(stats.cycles as f64 * cfg.cycle_ns())
    );
    println!(
        "avg latency {:.1} cycles (+{:.1} NI queueing), max {}, link util {:.1}%",
        stats.avg_latency(),
        stats.avg_queueing(),
        stats.max_latency,
        stats.link_utilization(net.link_count()) * 100.0
    );
    if vcs > 1 {
        // Per-VC report (ISSUE 10): how the escape lane (VC 0) and the
        // adaptive lanes split the work, plus the post-drain credit
        // audit restricted to each lane.
        let audit = net.audit_credits();
        for u in net.vc_usage() {
            let lane_violations = audit.iter().filter(|v| v.vc == u.vc).count();
            println!(
                "vc {} ({}): {} flits ejected, {} hops, {} buffered, \
                 last progress cycle {}, credit violations {}",
                u.vc,
                if u.vc == 0 { "escape" } else { "adaptive" },
                u.delivered_flits,
                u.flit_hops,
                u.buffered,
                u.last_progress,
                lane_violations
            );
        }
    }
    if egress_lanes > 0 {
        println!(
            "egress ({egress_lanes}-lane {}): {} symbols decoded, {} stall cycles, \
             completion cycle {}",
            codec.name(),
            stats.delivered_symbols,
            stats.decode_stall_cycles,
            stats.completion_cycle
        );
    }
    if ingress_lanes > 0 {
        println!(
            "ingress ({ingress_lanes}-lane {}): {} encode stall cycles, \
             {} injection deferrals at the bounded NI",
            codec.name(),
            stats.encode_stall_cycles,
            stats.injections_refused
        );
    }
    if !link_downs.is_empty() {
        println!(
            "link failures: {} applied — {} wormholes truncated, \
             {} packets rerouted-or-retried, {} unreachable",
            stats.links_down,
            stats.packets_truncated,
            stats.packet_retries,
            stats.packets_unreachable
        );
    }
    if faults_on {
        println!(
            "faults (seed {fault_seed}, ber {ber:.1e}, drop {drop_p}, dup {dup_p}): \
             {} corrupted / {} dropped / {} duplicated flits",
            stats.flits_corrupted, stats.flits_dropped, stats.flits_duplicated
        );
        println!(
            "recovery: {} packet retries, {} packets dropped after the \
             {}-retry budget (backoff cap {} cycles)",
            stats.packet_retries,
            stats.packets_dropped,
            retry.budget,
            retry.backoff_cap
        );
        // Graceful degradation (ISSUE 6): every NACK is a decode
        // failure against the class this traffic stands in for
        // (activations — the runtime-compressed kind); at the
        // DegradePolicy threshold the per-kind codec policy falls back
        // to Raw rather than stalling on retransmissions forever.
        let mut policy = CodecPolicy::lexi_default();
        let mut tracker = DegradeTracker::new();
        let dp = DegradePolicy::paper_default();
        let before = policy.describe();
        for _ in 0..(stats.packet_retries + stats.packets_dropped) {
            tracker.record_failure(TransferKind::Activation, dp, &mut policy);
        }
        let degraded = tracker.degraded_kinds();
        if degraded.is_empty() {
            println!(
                "degradation: none — policy stays [{before}] \
                 ({} failures < threshold {})",
                tracker.failures(TransferKind::Activation),
                dp.failure_threshold
            );
        } else {
            println!(
                "degradation: {degraded:?} fell back to raw — policy \
                 [{before}] -> [{}]",
                policy.describe()
            );
        }
    }
    Ok(())
}

// --- dse (Figs 4/5/6) --------------------------------------------------------------

fn cmd_dse(flags: &Flags) -> Result<()> {
    match flags.get("what", "hitrate") {
        "hitrate" => {
            let mut t = Table::new(&["depth", "jamba", "zamba", "qwen"]);
            let streams: Vec<Vec<u8>> = ModelConfig::paper_models()
                .iter()
                .map(|cfg| WeightStream::sample_exponents(cfg, 0, 9, 100_000))
                .collect();
            for depth in [1usize, 2, 4, 8, 16, 32] {
                let mut row = vec![depth.to_string()];
                for s in &streams {
                    let mut cache = lexi_hw::lane_cache::LaneCache::new(depth);
                    for &e in s {
                        cache.access(e);
                    }
                    row.push(format!("{:.1}%", cache.hit_rate() * 100.0));
                }
                t.row(row);
            }
            t.print();
        }
        "codebook" => {
            let cfg0 = ModelConfig::jamba(ModelScale::Paper);
            let exps = WeightStream::sample_exponents(&cfg0, 0, 9, 512);
            let mut t = Table::new(&["lanes", "depth", "cache KiB", "latency (ns)"]);
            for (lanes, depth) in [
                (1usize, 4usize),
                (1, 8),
                (2, 8),
                (4, 8),
                (8, 8),
                (10, 8),
                (16, 8),
                (32, 8),
                (32, 16),
            ] {
                let hc = HistConfig { lanes, depth };
                let r = HistogramUnit::new(hc).run(&exps);
                t.row(vec![
                    lanes.to_string(),
                    depth.to_string(),
                    format!("{:.3}", hc.cache_bytes() as f64 / 1024.0),
                    format!("{}", r.cycles),
                ]);
            }
            t.print();
        }
        "codec" => {
            // ISSUE 3: per-kind codec comparison from one measured
            // CrTable — every number routes through the ExpCodec trait.
            let model = flags.get("model", "jamba");
            let cfg = match model {
                "jamba" => ModelConfig::jamba(ModelScale::Paper),
                "zamba" => ModelConfig::zamba(ModelScale::Paper),
                "qwen" => ModelConfig::qwen(ModelScale::Paper),
                other => bail!("unknown model '{other}'"),
            };
            let engine = Engine::paper_default();
            let crs = CrTable::measure(&cfg, 42);
            println!("codec comparison per traffic kind ({model}, paper scale):");
            let mut t = Table::new(&[
                "kind",
                "codec",
                "exp CR",
                "wire ratio",
                &format!("dec cyc/sym @{} lanes", engine.decoder_lanes),
            ]);
            for kind in TransferKind::ALL {
                for codec in CodecKind::ALL {
                    t.row(vec![
                        format!("{kind:?}"),
                        codec.name().into(),
                        fmt_ratio(crs.exponent_cr_for(codec, kind)),
                        fmt_ratio(crs.wire_ratio_for(codec, kind)),
                        format!(
                            "{:.3}",
                            crs.decode_cycles_per_symbol_for(codec, kind, engine.decoder_lanes)
                        ),
                    ]);
                }
            }
            t.print();

            println!("\nmixed-codec operating points (full inference, Lexi mode):");
            let corpus = Corpus::wikitext2();
            let unc = engine.run(&cfg, &corpus, CompressionMode::Uncompressed, &crs);
            let mut tp = Table::new(&["policy", "comm (ms)", "comm red."]);
            for (name, policy) in [
                ("all-huffman (paper)", CodecPolicy::lexi_default()),
                ("bdi-state hybrid", CodecPolicy::bdi_state()),
                ("all-bdi", CodecPolicy::uniform(CodecKind::Bdi)),
                ("all-raw", CodecPolicy::uniform(CodecKind::Raw)),
            ] {
                let r = Engine::with_policy(policy).run(
                    &cfg,
                    &corpus,
                    CompressionMode::Lexi,
                    &crs,
                );
                tp.row(vec![
                    format!("{name} ({})", policy.describe()),
                    format!("{:.2}", r.comm_ms()),
                    format!("{:.1}%", (1.0 - r.comm_ns / unc.comm_ns) * 100.0),
                ]);
            }
            tp.print();
        }
        "decoder" => {
            let mut t = Table::new(&["config", "area (µm²)", "avg ns / 10 exps"]);
            let cfg0 = ModelConfig::jamba(ModelScale::Paper);
            let exps = WeightStream::sample_exponents(&cfg0, 0, 9, 50_000);
            let hist = lexi_core::stats::Histogram::from_bytes(&exps);
            let book = lexi_core::huffman::CodeBook::lexi_default(&hist)?;
            let mut w = lexi_core::bitstream::BitWriter::new();
            for &e in &exps {
                book.encode_symbol(e, &mut w);
            }
            let bits = w.len_bits();
            let bytes = w.into_bytes();
            for (name, dc) in [
                ("1-stage 32b", DecoderConfig::monolithic()),
                (
                    "2-stage 16/32",
                    DecoderConfig {
                        stage_bits: vec![16, 32],
                        entries_per_stage: 16,
                    },
                ),
                (
                    "3-stage 11/22/32",
                    DecoderConfig {
                        stage_bits: vec![11, 22, 32],
                        entries_per_stage: 11,
                    },
                ),
                ("4-stage 8/16/24/32", DecoderConfig::paper_default()),
            ] {
                let unit = lexi_hw::decoder::DecoderUnit::new(dc.clone())?;
                let mut r = lexi_core::bitstream::BitReader::with_len(&bytes, bits);
                let (_, rep) = unit.decode(&mut r, &book, exps.len())?;
                t.row(vec![
                    name.into(),
                    format!("{:.1}", lexi_hw::area_power::decoder_area_um2(&dc)),
                    format!("{:.2}", rep.avg_latency() * 10.0),
                ]);
            }
            t.print();
        }
        other => bail!("unknown dse target '{other}'"),
    }
    Ok(())
}

// --- energy (extension) -------------------------------------------------------

fn cmd_energy(_flags: &Flags) -> Result<()> {
    use lexi_sim::energy::EnergyModel;
    let engine = Engine::paper_default();
    let corpus = Corpus::wikitext2();
    let em = EnergyModel::default();
    let mut t = Table::new(&["model", "mode", "link (mJ)", "codec (mJ)", "saved"]);
    for cfg in ModelConfig::paper_models() {
        let crs = CrTable::measure(&cfg, 42);
        let unc = em.run(&engine.system, &cfg, &corpus, CompressionMode::Uncompressed, &crs);
        for mode in CompressionMode::ALL {
            let r = em.run(&engine.system, &cfg, &corpus, mode, &crs);
            t.row(vec![
                cfg.name.into(),
                format!("{mode:?}"),
                format!("{:.2}", r.link_uj / 1e3),
                format!("{:.3}", r.codec_uj / 1e3),
                format!("{:.1}%", (1.0 - r.total_uj() / unc.total_uj()) * 100.0),
            ]);
        }
    }
    t.print();
    Ok(())
}

// --- serve (extension + ISSUE 9 trace-driven mode) ----------------------------

fn cmd_serve(flags: &Flags) -> Result<()> {
    // `--trace` selects the open-loop multi-tenant serving simulator
    // (ISSUE 9); without it the legacy concurrent-decode ceiling sweep
    // runs unchanged.
    let trace_s = flags.get("trace", "");
    if trace_s.is_empty() {
        return cmd_serve_concurrent(flags);
    }
    let trace = TraceKind::parse(trace_s)
        .ok_or_else(|| anyhow!("bad --trace '{trace_s}' (want poisson|burst)"))?;
    let mut cfg = ServingConfig::paper_default();
    cfg.trace = trace;
    cfg.load = flags.get_f64("load", cfg.load)?;
    cfg.requests = flags.get_usize("requests", cfg.requests)?;
    cfg.deadline_ns = flags.get_usize("deadline", cfg.deadline_ns as usize)? as u64;
    cfg.seed = flags.get_usize("seed", cfg.seed as usize)? as u64;
    cfg.nodes = flags.get_usize("nodes", cfg.nodes)?;
    cfg.queue_depth = flags.get_usize("queue-depth", cfg.queue_depth)?;
    cfg.retry = RetryConfig {
        budget: flags.get_usize("retry-budget", cfg.retry.budget as usize)? as u32,
        backoff_cap: flags.get_usize("backoff-cap", cfg.retry.backoff_cap as usize)? as u64,
        ..cfg.retry
    };
    cfg.admission = match flags.get("admission", "on") {
        "on" => true,
        "off" => false,
        other => bail!("bad --admission '{other}' (want on|off)"),
    };
    if cfg.load <= 0.0 {
        bail!("--load must be positive");
    }

    let mut t = Table::new(&[
        "mode",
        "delivered",
        "shed (deadline)",
        "late",
        "p50",
        "p99",
        "p999",
        "goodput/s",
    ]);
    let mut lexi_detail: Option<(ServingStats, String, u64)> = None;
    for mode in [CompressionMode::Uncompressed, CompressionMode::Lexi] {
        let mut mc = cfg.clone();
        mc.mode = mode;
        let mut sim = ServingSim::new(mc);
        let stats = sim.run();
        t.row(vec![
            format!("{mode:?}"),
            stats.delivered.to_string(),
            format!("{} ({})", stats.shed, stats.shed_deadline),
            stats.deadline_missed.to_string(),
            fmt_ns(stats.p50_ns as f64),
            fmt_ns(stats.p99_ns as f64),
            fmt_ns(stats.p999_ns as f64),
            format!("{:.0}", stats.goodput_rps),
        ]);
        if mode == CompressionMode::Lexi {
            let degraded = sim.engine.degraded_kinds();
            let state = if degraded.is_empty() {
                "healthy".to_string()
            } else {
                format!("degraded {degraded:?}")
            };
            lexi_detail = Some((stats, state, sim.resolved_deadline_ns()));
        }
    }
    let (s, final_state, deadline_ns) = lexi_detail.expect("LEXI run always executes");
    println!(
        "trace={trace_s} load={} requests={} seed={} nodes={} deadline={}",
        cfg.load,
        cfg.requests,
        cfg.seed,
        cfg.nodes,
        fmt_ns(deadline_ns as f64)
    );
    t.print();
    println!(
        "resolution (LEXI): offered {} = delivered {} + shed {} \
         (every request resolves exactly once: {})",
        s.offered,
        s.delivered,
        s.shed,
        s.consistent()
    );
    println!(
        "admission: {} client retries consumed (budget {}, backoff cap {})",
        s.retries, cfg.retry.budget, cfg.retry.backoff_cap
    );
    println!(
        "controller: {} degrades / {} recoveries / {} probes; final codec state {}",
        s.degrades, s.recoveries, s.probes, final_state
    );
    if !s.transitions.is_empty() {
        println!("transitions (window, degraded?): {:?}", s.transitions);
    }
    let cache_total = (s.cache.hits + s.cache.misses).max(1);
    println!(
        "lane cache: {:.1}% hit, {} evictions ({:.1}% of accesses) under \
         per-tenant codebook churn",
        100.0 * s.cache.hits as f64 / cache_total as f64,
        s.cache.evictions,
        s.cache.eviction_rate() * 100.0
    );
    Ok(())
}

fn cmd_serve_concurrent(flags: &Flags) -> Result<()> {
    let max_req = flags.get_usize("requests", 64)?;
    let engine = Engine::paper_default();
    let corpus = Corpus::wikitext2();
    let cfg = ModelConfig::qwen(ModelScale::Paper);
    let crs = CrTable::measure(&cfg, 42);
    let mut t = Table::new(&["requests", "uncompressed tok/s", "LEXI tok/s", "gain"]);
    let mut n = 1usize;
    while n <= max_req {
        let unc = engine.run_concurrent(&cfg, &corpus, CompressionMode::Uncompressed, &crs, n);
        let lexi = engine.run_concurrent(&cfg, &corpus, CompressionMode::Lexi, &crs, n);
        t.row(vec![
            n.to_string(),
            format!("{:.0}", unc.tokens_per_s),
            format!("{:.0}", lexi.tokens_per_s),
            format!("{:.2}x", lexi.tokens_per_s / unc.tokens_per_s),
        ]);
        n *= 2;
    }
    t.print();
    Ok(())
}
