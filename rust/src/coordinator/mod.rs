//! The L3 inference coordinator.
//!
//! Owns the decode loop over the AOT-compiled model (the caches pass
//! through Rust every step — exactly the tensors that transit the
//! inter-chiplet network in the paper's system), profiles every captured
//! stream (Fig 1a on *real* numerics), runs the LEXI codec over them to
//! obtain measured compression/wire ratios, and feeds those into the
//! chiplet-system engine for end-to-end latency (Table 3 / Fig 7 at tiny
//! scale with real data).

use crate::runtime::{argmax, LoadedModel};
use anyhow::Result;
use lexi_core::bf16::FieldStreams;
use lexi_core::codec::CodecKind;
use lexi_core::flit::{self, FlitFormat};
use lexi_core::huffman::CodeBook;
use lexi_core::rle;
use lexi_core::stats::{FieldProfile, Histogram};
use lexi_core::Bf16;
use lexi_models::traffic::TransferKind;
use lexi_sim::compression::{CrTable, KindRatios};
use std::collections::HashMap;

/// Profile + codec results for one captured stream.
#[derive(Clone, Debug)]
pub struct TensorProfile {
    pub name: String,
    pub kind: TransferKind,
    pub count: usize,
    pub exp_entropy: f64,
    pub mant_entropy: f64,
    pub exp_distinct: usize,
    /// LEXI exponent CR (header included).
    pub lexi_cr: f64,
    /// RLE baseline exponent CR.
    pub rle_cr: f64,
    /// BDI baseline exponent CR.
    pub bdi_cr: f64,
    /// Whole-value wire ratio through the flit packer.
    pub wire_ratio: f64,
}

/// Everything one coordinated inference produced.
#[derive(Clone, Debug)]
pub struct SessionReport {
    pub model: String,
    pub prompt_len: usize,
    pub generated: Vec<i32>,
    pub profiles: Vec<TensorProfile>,
}

impl SessionReport {
    /// Average measured ratios per traffic kind → the engine's CrTable.
    pub fn measured_cr_table(&self) -> CrTable {
        let mut acc: HashMap<TransferKind, (f64, f64, usize)> = HashMap::new();
        for p in &self.profiles {
            let e = acc.entry(p.kind).or_insert((0.0, 0.0, 0));
            e.0 += p.lexi_cr;
            e.1 += p.wire_ratio;
            e.2 += 1;
        }
        let mut ratios = HashMap::new();
        for kind in TransferKind::ALL {
            // Kinds the tiny model lacks (e.g. SSM for qwen) fall back to
            // activation statistics — same layer-norm-bounded regime.
            let (cr, wire, n) = acc
                .get(&kind)
                .copied()
                .or_else(|| acc.get(&TransferKind::Activation).copied())
                .unwrap_or((3.0, 1.4, 1));
            let n = n.max(1) as f64;
            ratios.insert(
                kind,
                KindRatios {
                    exponent_cr: cr / n,
                    wire_ratio: wire / n,
                },
            );
        }
        // Runtime profiles carry no decoder-makespan measurements; the
        // engine falls back to the paper-nominal decode latency.
        CrTable::from_ratios(ratios)
    }

    /// Aggregate exponent entropy across all captured streams.
    pub fn mean_exp_entropy(&self) -> f64 {
        if self.profiles.is_empty() {
            return 0.0;
        }
        self.profiles.iter().map(|p| p.exp_entropy).sum::<f64>() / self.profiles.len() as f64
    }
}

/// The coordinator.
pub struct Session {
    pub model: LoadedModel,
}

impl Session {
    /// Wrap a loaded model.
    pub fn new(model: LoadedModel) -> Self {
        Session { model }
    }

    /// Run prefill + `n_decode` greedy decode steps, profiling every
    /// boundary tensor.
    pub fn run(&self, tokens: &[i32], n_decode: usize) -> Result<SessionReport> {
        let mm = &self.model.manifest;
        assert!(
            n_decode <= mm.out_max,
            "decode steps {n_decode} exceed cache budget {}",
            mm.out_max
        );
        let pre = self.model.run_prefill(tokens)?;

        let mut profiles = Vec::new();
        // --- per-layer prefill activations [L, S, D] ----------------------
        let (l, s, d) = (mm.blocks.len(), mm.seq_in, mm.d_model);
        for layer in 0..l {
            let slice = &pre.acts.data[layer * s * d..(layer + 1) * s * d];
            profiles.push(profile_stream(
                format!("prefill/act/layer{layer}"),
                TransferKind::Activation,
                slice,
            ));
        }
        // --- caches (valid prefix only for KV) -----------------------------
        if !pre.kv.is_empty() {
            let a = pre.kv.shape[0];
            let kvd = pre.kv.shape[3];
            let max = pre.kv.shape[2];
            for ai in 0..a {
                let mut valid = Vec::with_capacity(2 * s * kvd);
                for half in 0..2 {
                    let base = ai * 2 * max * kvd + half * max * kvd;
                    valid.extend_from_slice(&pre.kv.data[base..base + s * kvd]);
                }
                profiles.push(profile_stream(
                    format!("prefill/kv/layer{ai}"),
                    TransferKind::KvCache,
                    &valid,
                ));
            }
        }
        if !pre.ssm.is_empty() {
            profiles.push(profile_stream(
                "prefill/ssm".into(),
                TransferKind::SsmState,
                &pre.ssm.data,
            ));
        }
        if !pre.conv.is_empty() {
            profiles.push(profile_stream(
                "prefill/conv".into(),
                TransferKind::SsmState,
                &pre.conv.data,
            ));
        }

        // --- decode loop ---------------------------------------------------
        let mut kv = pre.kv;
        let mut ssm = pre.ssm;
        let mut conv = pre.conv;
        let mut token = argmax(&pre.logits);
        let mut generated = Vec::with_capacity(n_decode);
        let mut decode_acts: Vec<f32> = Vec::new();
        for step in 0..n_decode {
            let pos = (mm.seq_in + step) as i32;
            let out = self.model.run_decode(token, pos, &kv, &ssm, &conv)?;
            decode_acts.extend_from_slice(&out.acts.data);
            kv = out.kv;
            ssm = out.ssm;
            conv = out.conv;
            token = argmax(&out.logits);
            generated.push(token);
        }
        if !decode_acts.is_empty() {
            profiles.push(profile_stream(
                "decode/acts".into(),
                TransferKind::Activation,
                &decode_acts,
            ));
        }
        if !ssm.is_empty() {
            profiles.push(profile_stream(
                "decode/ssm-final".into(),
                TransferKind::SsmState,
                &ssm.data,
            ));
        }

        Ok(SessionReport {
            model: mm.name.clone(),
            prompt_len: tokens.len(),
            generated,
            profiles,
        })
    }
}

/// Profile one f32 stream of bf16-representable values: entropies, codec
/// CRs (LEXI vs RLE vs BDI, the compressors routed through the
/// `ExpCodec` registry) and the flit-level wire ratio.
pub fn profile_stream(name: String, kind: TransferKind, data: &[f32]) -> TensorProfile {
    let values: Vec<Bf16> = data.iter().map(|&x| Bf16::from_f32(x)).collect();
    let profile = FieldProfile::of(&values);
    let streams = FieldStreams::split(&values);

    let lexi_cr = CodecKind::Huffman
        .codec()
        .encode(&streams.exponents)
        .map(|b| b.ratio())
        .unwrap_or(1.0);
    let rle_cr = rle::coding_ratio(&streams.exponents);
    let bdi_cr = CodecKind::Bdi.codec().coding_ratio(&streams.exponents);

    let wire_ratio = (|| -> lexi_core::Result<f64> {
        let hist = Histogram::from_bytes(&streams.exponents);
        let book = CodeBook::lexi_default(&hist)?;
        let format = FlitFormat::new(128)?;
        Ok(flit::pack(&streams, &book, format)?.ratio_vs_uncompressed())
    })()
    .unwrap_or(1.0);

    TensorProfile {
        name,
        kind,
        count: values.len(),
        exp_entropy: profile.exp_entropy_bits,
        mant_entropy: profile.mant_entropy_bits,
        exp_distinct: profile.exp_distinct,
        lexi_cr,
        rle_cr,
        bdi_cr,
        wire_ratio,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profile_stream_on_gaussian() {
        let mut rng = lexi_core::prng::Rng::new(5);
        let data: Vec<f32> = (0..20_000)
            .map(|_| {
                let v = Bf16::from_f32(rng.normal_with(0.0, 1.0) as f32);
                v.to_f32()
            })
            .collect();
        let p = profile_stream("test".into(), TransferKind::Activation, &data);
        assert!(p.exp_entropy < 4.5);
        assert!(p.lexi_cr > 1.8);
        assert!(p.rle_cr < 1.0, "rle expands: {}", p.rle_cr);
        assert!(p.bdi_cr > 1.0 && p.bdi_cr < p.lexi_cr);
        assert!(p.wire_ratio > 1.2);
    }

    #[test]
    fn measured_cr_table_fills_all_kinds() {
        let report = SessionReport {
            model: "t".into(),
            prompt_len: 1,
            generated: vec![],
            profiles: vec![TensorProfile {
                name: "a".into(),
                kind: TransferKind::Activation,
                count: 10,
                exp_entropy: 2.5,
                mant_entropy: 7.0,
                exp_distinct: 12,
                lexi_cr: 3.0,
                rle_cr: 0.6,
                bdi_cr: 2.4,
                wire_ratio: 1.5,
            }],
        };
        let t = report.measured_cr_table();
        for kind in TransferKind::ALL {
            assert!(t.ratios.contains_key(&(CodecKind::Huffman, kind)));
            // Ratio-only tables synthesize the Raw column at 1.0×.
            assert_eq!(t.wire_ratio_for(CodecKind::Raw, kind), 1.0);
        }
    }
}
