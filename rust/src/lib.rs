//! # lexi — LEXI: Lossless Exponent Coding for Efficient Inter-Chiplet
//! # Communication in Hybrid LLMs (paper reproduction)
//!
//! The top-level crate wires the substrates together:
//!
//! * [`runtime`] — PJRT CPU runtime loading the AOT HLO-text artifacts
//!   produced by `python/compile/aot.py` (L2/L1 never run at inference).
//! * [`coordinator`] — the L3 inference coordinator: decode loop, tensor
//!   capture, profiling, measured compression ratios.
//! * [`cli`] — the `lexi` command-line driver.
//! * [`json`] — minimal JSON for `artifacts/manifest.json`.
//!
//! Library crates: `lexi-core` (codecs), `lexi-hw` (cycle-accurate codec
//! hardware), `lexi-noc` (NoI simulator), `lexi-models` (model configs +
//! synthetic tensors), `lexi-sim` (Simba system + e2e engine),
//! `lexi-bench` (bench harness).

pub mod cli;
pub mod coordinator;
pub mod json;
pub mod runtime;

pub use lexi_bench as bench;
pub use lexi_core as core;
pub use lexi_hw as hw;
pub use lexi_models as models;
pub use lexi_noc as noc;
pub use lexi_sim as sim;
