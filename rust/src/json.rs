//! Minimal JSON parser for `artifacts/manifest.json`.
//!
//! The offline crate set has no `serde`/`serde_json`, so this is a small,
//! tested recursive-descent parser covering the JSON the AOT pipeline
//! emits (objects, arrays, strings, numbers, booleans, null). Not a
//! general-purpose library: no surrogate-pair unescaping, numbers parse
//! through `f64`.

use std::collections::HashMap;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(HashMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing data at byte {}", p.pos));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// String content.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric content.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Integer content (exact for |n| < 2^53).
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// Array content.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Object content.
    pub fn as_obj(&self) -> Option<&HashMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other, self.pos)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = HashMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                other => return Err(format!("expected ',' or '}}', got {other:?}")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                other => return Err(format!("expected ',' or ']', got {other:?}")),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err("unterminated string".into()),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or("bad \\u escape")? as char;
                            code = code * 16 + c.to_digit(16).ok_or("bad hex")?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    other => return Err(format!("bad escape {other:?}")),
                },
                Some(c) => {
                    // Collect the full UTF-8 sequence.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xf0 {
                            4
                        } else if c >= 0xe0 {
                            3
                        } else {
                            2
                        };
                        self.pos = start + len;
                        let chunk = self
                            .bytes
                            .get(start..start + len)
                            .ok_or("truncated utf8")?;
                        s.push_str(std::str::from_utf8(chunk).map_err(|e| e.to_string())?);
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        ) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).map_err(|e| e.to_string())?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number '{text}': {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
          "jamba": {
            "seq_in": 128,
            "blocks": ["mamba", "attention"],
            "prefill": {"file": "jamba_prefill.hlo.txt",
                        "outputs": [{"shape": [1024], "dtype": "float32"}]}
          }
        }"#;
        let j = Json::parse(doc).unwrap();
        let jamba = j.get("jamba").unwrap();
        assert_eq!(jamba.get("seq_in").unwrap().as_usize(), Some(128));
        assert_eq!(
            jamba.get("blocks").unwrap().as_arr().unwrap()[1].as_str(),
            Some("attention")
        );
        let shape = jamba
            .get("prefill")
            .unwrap()
            .get("outputs")
            .unwrap()
            .as_arr()
            .unwrap()[0]
            .get("shape")
            .unwrap();
        assert_eq!(shape.as_arr().unwrap()[0].as_usize(), Some(1024));
    }

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap().as_f64(), Some(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap().as_f64(), Some(-350.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(
            Json::parse(r#""a\nbA""#).unwrap().as_str(),
            Some("a\nbA")
        );
    }

    #[test]
    fn rejects_malformed() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn nested_arrays() {
        let j = Json::parse("[[1,2],[3,[4]]]").unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a[1].as_arr().unwrap()[1].as_arr().unwrap()[0].as_f64(), Some(4.0));
    }
}
