//! A 5-port wormhole router with credit-based flow control — the
//! **legacy single-VC reference** (pre-ISSUE-10).
//!
//! The live network now steps [`crate::vc::VcRouter`] through the
//! [`crate::input_control`] / [`crate::output_control`] split; this
//! module is kept as the executable specification the `vcs = 1`
//! stat-identity property test (`tests/vc1_equivalence.rs`) replays
//! against, and as the simplest statement of the arbitration rules.
//!
//! Per output port, a round-robin arbiter picks among input ports whose
//! head-of-line flit routes to it. A head flit locks the output to its
//! input until the tail passes (wormhole). Forwarding requires a credit
//! (free buffer slot) at the downstream input.
//!
//! Arbitration is **pure** (`&self`): the network may compute a grant
//! and then decline to act on it — the egress codec port does exactly
//! that when its decoder is backlogged (ISSUE 5) — and re-arbitrating
//! the next cycle reproduces the same decision with no state drift.

use crate::packet::Flit;
use crate::topology::{Port, NUM_PORTS};
use std::collections::VecDeque;

/// One input port's buffer.
#[derive(Debug, Default)]
pub struct InputBuffer {
    pub fifo: VecDeque<Flit>,
}

/// Per-output wormhole/arbitration state.
#[derive(Debug)]
pub struct OutputState {
    /// Input currently holding the wormhole lock.
    pub locked_to: Option<usize>,
    /// Packet whose wormhole holds the lock (ISSUE 7: identifies the
    /// severed worm when a permanent link failure cuts this output).
    pub locked_packet: Option<u64>,
    /// Credits = free slots in the downstream input buffer.
    pub credits: u32,
    /// Round-robin pointer for fairness.
    pub rr: usize,
    /// Flits forwarded through this output (utilization stat).
    pub forwarded: u64,
}

/// A router: 5 input buffers + 5 output states.
#[derive(Debug)]
pub struct Router {
    pub inputs: [InputBuffer; NUM_PORTS],
    pub outputs: [OutputState; NUM_PORTS],
}

impl Router {
    /// New router; `buf_depth` flit slots per input, so each output starts
    /// with `buf_depth` credits toward its downstream neighbour.
    pub fn new(buf_depth: u32) -> Self {
        Router {
            inputs: Default::default(),
            outputs: std::array::from_fn(|_| OutputState {
                locked_to: None,
                locked_packet: None,
                credits: buf_depth,
                rr: 0,
                forwarded: 0,
            }),
        }
    }

    /// Compute every output's grant in one pass (§Perf): each input's
    /// head-of-line flit is routed exactly once, then outputs consult the
    /// request vector under wormhole rules. The route function also sees
    /// the input port index (ISSUE 7): escape routing after a permanent
    /// link failure derives the up*/down* phase from where a flit came
    /// in, with no per-packet routing state.
    pub fn arbitrate_all(
        &self,
        now: u64,
        route: impl Fn(usize, &Flit) -> Port,
    ) -> [Option<usize>; NUM_PORTS] {
        // requests[inp] = (output the HoL flit wants, is_head).
        let mut requests: [Option<(Port, bool)>; NUM_PORTS] = [None; NUM_PORTS];
        for (inp, buf) in self.inputs.iter().enumerate() {
            if let Some(hol) = buf.fifo.front() {
                if hol.ready_at <= now {
                    requests[inp] = Some((route(inp, hol), hol.is_head()));
                }
            }
        }
        let mut grants = [None; NUM_PORTS];
        for &out in &Port::ALL {
            let o = &self.outputs[out as usize];
            grants[out as usize] = if let Some(inp) = o.locked_to {
                match requests[inp] {
                    Some((want, _)) if want == out => Some(inp),
                    _ => None,
                }
            } else {
                (0..NUM_PORTS)
                    .map(|k| (o.rr + k) % NUM_PORTS)
                    .find(|&inp| matches!(requests[inp], Some((want, true)) if want == out))
            };
        }
        grants
    }

    /// Pick the input to serve for `out` this cycle under wormhole rules:
    /// the locked input if any, else round-robin among inputs whose HoL
    /// flit (ready by `now`) requests `out` (per `route` lookup).
    pub fn arbitrate(
        &self,
        out: Port,
        now: u64,
        route: impl Fn(usize, &Flit) -> Port,
    ) -> Option<usize> {
        self.arbitrate_all(now, route)[out as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::packet::FlitKind;
    use crate::topology::NodeId;

    fn flit(kind: FlitKind, ready: u64) -> Flit {
        Flit {
            packet_id: 1,
            kind,
            src: NodeId(0),
            dest: NodeId(1),
            seq: 0,
            vc: 0,
            ready_at: ready,
            codec: None,
        }
    }

    #[test]
    fn lock_holds_until_tail() {
        let mut r = Router::new(4);
        r.inputs[1].fifo.push_back(flit(FlitKind::Head, 0));
        let pick = r.arbitrate(Port::East, 0, |_, _| Port::East);
        assert_eq!(pick, Some(1));
        // Lock to input 1; a competing head on input 2 must not win.
        r.outputs[Port::East as usize].locked_to = Some(1);
        r.inputs[2].fifo.push_back(flit(FlitKind::Head, 0));
        r.inputs[1].fifo.clear();
        r.inputs[1].fifo.push_back(flit(FlitKind::Body, 0));
        assert_eq!(r.arbitrate(Port::East, 0, |_, _| Port::East), Some(1));
    }

    #[test]
    fn body_without_lock_cannot_start() {
        let mut r = Router::new(4);
        r.inputs[0].fifo.push_back(flit(FlitKind::Body, 0));
        assert_eq!(r.arbitrate(Port::East, 0, |_, _| Port::East), None);
    }

    #[test]
    fn declined_grant_replays_identically() {
        // The egress port may refuse a Local grant (decoder backlogged);
        // the arbiter must be side-effect-free so the same grant replays
        // next cycle, wormhole lock and RR pointer untouched.
        let mut r = Router::new(4);
        r.inputs[2].fifo.push_back(flit(FlitKind::Head, 0));
        r.outputs[Port::Local as usize].rr = 1;
        let g1 = r.arbitrate_all(0, |_, _| Port::Local);
        let g2 = r.arbitrate_all(0, |_, _| Port::Local);
        assert_eq!(g1[Port::Local as usize], Some(2));
        assert_eq!(g1, g2);
        assert_eq!(r.outputs[Port::Local as usize].locked_to, None);
        assert_eq!(r.outputs[Port::Local as usize].rr, 1);
        // Mid-packet (lock held) the refusal is equally replayable.
        r.outputs[Port::Local as usize].locked_to = Some(2);
        r.inputs[2].fifo.clear();
        r.inputs[2].fifo.push_back(flit(FlitKind::Body, 0));
        let g3 = r.arbitrate_all(0, |_, _| Port::Local);
        assert_eq!(g3[Port::Local as usize], Some(2));
        assert_eq!(r.outputs[Port::Local as usize].locked_to, Some(2));
    }

    #[test]
    fn not_ready_flit_waits() {
        let mut r = Router::new(4);
        r.inputs[0].fifo.push_back(flit(FlitKind::Head, 5));
        assert_eq!(r.arbitrate(Port::East, 0, |_, _| Port::East), None);
        assert_eq!(r.arbitrate(Port::East, 5, |_, _| Port::East), Some(0));
    }
}
