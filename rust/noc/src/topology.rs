//! Topologies: the flat 2D mesh, a concentrated mesh, and stitched
//! multi-package arrays — plus the [`Topology`] trait the router-level
//! code is written against (ISSUE 10).
//!
//! The network distinguishes **routers** (switching elements holding
//! input buffers and output credits) from **endpoint nodes** (NIs that
//! inject and eject packets). On the flat mesh they coincide one-to-one;
//! a concentrated mesh hangs `conc` endpoints off each router's shared
//! Local port (bsg_wormhole_concentrator-style); a multi-package
//! topology stitches `packages` identical meshes through a few
//! boundary links on designated gateway rows (bsg_mesh_stitch-style) —
//! the inter-chiplet links whose codec ports carry the traffic the
//! paper targets.

/// A node index in a 2D mesh (row-major).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub u16);

/// Router port directions. `Local` is the NI (network-interface) port.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
#[repr(usize)]
pub enum Port {
    Local = 0,
    North = 1,
    South = 2,
    East = 3,
    West = 4,
}

/// Number of ports per router.
pub const NUM_PORTS: usize = 5;

impl Port {
    /// All ports, index-aligned with the `repr`.
    pub const ALL: [Port; NUM_PORTS] = [Port::Local, Port::North, Port::South, Port::East, Port::West];

    /// The port a neighbouring router receives on when we send via `self`.
    pub fn opposite(self) -> Port {
        match self {
            Port::Local => Port::Local,
            Port::North => Port::South,
            Port::South => Port::North,
            Port::East => Port::West,
            Port::West => Port::East,
        }
    }
}

/// A `cols × rows` 2D mesh (the paper's NoI is 6×6).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Mesh {
    pub cols: u16,
    pub rows: u16,
}

impl Mesh {
    /// Construct a mesh; panics on degenerate sizes.
    pub fn new(cols: u16, rows: u16) -> Self {
        assert!(cols >= 1 && rows >= 1, "mesh must be at least 1x1");
        Mesh { cols, rows }
    }

    /// The paper's 6×6 Simba-style array.
    pub fn simba_6x6() -> Self {
        Mesh::new(6, 6)
    }

    /// Total nodes.
    pub fn len(&self) -> usize {
        self.cols as usize * self.rows as usize
    }

    /// True for the degenerate 0-node mesh (never constructable).
    pub fn is_empty(&self) -> bool {
        false
    }

    /// (x, y) of a node.
    pub fn coords(&self, n: NodeId) -> (u16, u16) {
        (n.0 % self.cols, n.0 / self.cols)
    }

    /// Node at (x, y).
    pub fn node(&self, x: u16, y: u16) -> NodeId {
        debug_assert!(x < self.cols && y < self.rows);
        NodeId(y * self.cols + x)
    }

    /// Manhattan hop distance.
    pub fn hops(&self, a: NodeId, b: NodeId) -> u32 {
        let (ax, ay) = self.coords(a);
        let (bx, by) = self.coords(b);
        (ax.abs_diff(bx) + ay.abs_diff(by)) as u32
    }

    /// Dimension-ordered (XY) routing: next output port from `at` toward
    /// `dest`. X first, then Y; `Local` when arrived.
    pub fn route_xy(&self, at: NodeId, dest: NodeId) -> Port {
        let (ax, ay) = self.coords(at);
        let (dx, dy) = self.coords(dest);
        if ax < dx {
            Port::East
        } else if ax > dx {
            Port::West
        } else if ay < dy {
            Port::South
        } else if ay > dy {
            Port::North
        } else {
            Port::Local
        }
    }

    /// Neighbour of `n` through `port`, if within the mesh.
    pub fn neighbour(&self, n: NodeId, port: Port) -> Option<NodeId> {
        let (x, y) = self.coords(n);
        match port {
            Port::Local => None,
            Port::North => (y > 0).then(|| self.node(x, y - 1)),
            Port::South => (y + 1 < self.rows).then(|| self.node(x, y + 1)),
            Port::East => (x + 1 < self.cols).then(|| self.node(x + 1, y)),
            Port::West => (x > 0).then(|| self.node(x - 1, y)),
        }
    }
}

/// Router-graph + endpoint contract every topology satisfies (ISSUE 10).
///
/// Contract:
/// * routers are indexed `0..routers()`, endpoints `0..len()`;
/// * `router_of` / `node_at` form a bijection between endpoints and
///   `(router, slot < conc())` pairs;
/// * `neighbour_r` is symmetric: `neighbour_r(a, p) == Some(b)` ⇔
///   `neighbour_r(b, p.opposite()) == Some(a)` (links are bidirected);
/// * `route_r` is deterministic, returns `Local` iff `at == dest`, and
///   every step stays on a live `neighbour_r` edge. It is the *baseline*
///   discipline only — deadlock freedom is the escape channel's job
///   ([`crate::reroute`]), not the route function's, except on the flat
///   mesh where XY is deadlock-free by itself.
pub trait Topology {
    /// Number of routers (switching elements).
    fn routers(&self) -> usize;
    /// Number of endpoint nodes (NIs).
    fn len(&self) -> usize;
    /// Endpoints per router (concentration factor).
    fn conc(&self) -> u8 {
        1
    }
    /// Router an endpoint hangs off.
    fn router_of(&self, n: NodeId) -> usize;
    /// Endpoint in `slot` (< `conc()`) of a router.
    fn node_at(&self, router: usize, slot: u8) -> NodeId;
    /// Neighbour router through `port`, if the link exists.
    fn neighbour_r(&self, at: usize, port: Port) -> Option<usize>;
    /// Deterministic baseline next hop between routers (`Local` when
    /// `at == dest`).
    fn route_r(&self, at: usize, dest: usize) -> Port;
    /// Total *directed* links (for utilization denominators).
    fn link_count(&self) -> u64;
    /// Hop distance between two endpoints' routers along `route_r`.
    fn hops(&self, a: NodeId, b: NodeId) -> u32 {
        let (mut at, dest) = (self.router_of(a), self.router_of(b));
        let mut hops = 0u32;
        while at != dest {
            let p = self.route_r(at, dest);
            at = self.neighbour_r(at, p).expect("route_r stays on live links");
            hops += 1;
            debug_assert!(hops as usize <= 4 * self.routers(), "routing loop");
        }
        hops
    }
}

impl Topology for Mesh {
    fn routers(&self) -> usize {
        self.len()
    }
    fn len(&self) -> usize {
        Mesh::len(self)
    }
    fn router_of(&self, n: NodeId) -> usize {
        n.0 as usize
    }
    fn node_at(&self, router: usize, slot: u8) -> NodeId {
        debug_assert_eq!(slot, 0);
        NodeId(router as u16)
    }
    fn neighbour_r(&self, at: usize, port: Port) -> Option<usize> {
        self.neighbour(NodeId(at as u16), port).map(|n| n.0 as usize)
    }
    fn route_r(&self, at: usize, dest: usize) -> Port {
        self.route_xy(NodeId(at as u16), NodeId(dest as u16))
    }
    fn link_count(&self) -> u64 {
        let (c, r) = (self.cols as u64, self.rows as u64);
        2 * (r * (c - 1) + c * (r - 1))
    }
    fn hops(&self, a: NodeId, b: NodeId) -> u32 {
        Mesh::hops(self, a, b)
    }
}

/// A concentrated mesh: a `cols × rows` router grid with `conc`
/// endpoints per router sharing its Local port
/// (bsg_wormhole_concentrator-style). Endpoint `n` is slot `n % conc`
/// of router `n / conc`; injection round-robins among a router's NIs
/// (one flit per router-cycle through the shared port).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CMesh {
    pub cols: u16,
    pub rows: u16,
    pub conc: u8,
}

impl CMesh {
    /// Construct; panics on degenerate sizes.
    pub fn new(cols: u16, rows: u16, conc: u8) -> Self {
        assert!(cols >= 1 && rows >= 1, "cmesh must be at least 1x1");
        assert!(conc >= 1, "concentration factor must be >= 1");
        CMesh { cols, rows, conc }
    }

    fn grid(&self) -> Mesh {
        Mesh {
            cols: self.cols,
            rows: self.rows,
        }
    }
}

impl Topology for CMesh {
    fn routers(&self) -> usize {
        self.cols as usize * self.rows as usize
    }
    fn len(&self) -> usize {
        self.routers() * self.conc as usize
    }
    fn conc(&self) -> u8 {
        self.conc
    }
    fn router_of(&self, n: NodeId) -> usize {
        n.0 as usize / self.conc as usize
    }
    fn node_at(&self, router: usize, slot: u8) -> NodeId {
        debug_assert!(slot < self.conc);
        NodeId((router * self.conc as usize + slot as usize) as u16)
    }
    fn neighbour_r(&self, at: usize, port: Port) -> Option<usize> {
        self.grid().neighbour_r(at, port)
    }
    fn route_r(&self, at: usize, dest: usize) -> Port {
        self.grid().route_r(at, dest)
    }
    fn link_count(&self) -> u64 {
        Topology::link_count(&self.grid())
    }
}

/// `packages` identical `cols × rows` meshes laid out west-to-east and
/// stitched through inter-package links on *gateway rows* only
/// (bsg_mesh_stitch-style): the east edge of package `k` connects to
/// the west edge of package `k+1` on rows 0 and `rows/2` — a few wide
/// boundary links, not a full edge, which is exactly where the paper's
/// inter-chiplet codec ports sit.
///
/// Baseline routing ([`Topology::route_r`]) goes XY within a package
/// and gateway-directed across packages; it is *not* deadlock-free on
/// its own (crossing traffic can cycle through the shared gateways), so
/// the network permanently installs up*/down* escape tables for this
/// topology — VC 0 (or all traffic at `vcs = 1`) follows them.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MultiPackage {
    pub packages: u8,
    pub cols: u16,
    pub rows: u16,
}

impl MultiPackage {
    /// Construct; panics on degenerate sizes.
    pub fn new(packages: u8, cols: u16, rows: u16) -> Self {
        assert!(packages >= 1, "need at least one package");
        assert!(cols >= 1 && rows >= 1, "package mesh must be at least 1x1");
        MultiPackage {
            packages,
            cols,
            rows,
        }
    }

    /// Routers per package.
    pub fn package_size(&self) -> usize {
        self.cols as usize * self.rows as usize
    }

    /// Is `row` a gateway row (carries an inter-package link)?
    pub fn is_gateway(&self, row: u16) -> bool {
        row == 0 || row == self.rows / 2
    }

    /// Number of gateway rows (1 when the two coincide on a 1-row mesh).
    pub fn gateway_rows(&self) -> u64 {
        if self.rows / 2 == 0 {
            1
        } else {
            2
        }
    }

    /// (package, x, y) of a router index.
    pub fn split(&self, r: usize) -> (usize, u16, u16) {
        let ps = self.package_size();
        let local = (r % ps) as u16;
        (r / ps, local % self.cols, local / self.cols)
    }

    /// Router index at (package, x, y).
    pub fn join(&self, pkg: usize, x: u16, y: u16) -> usize {
        debug_assert!(x < self.cols && y < self.rows);
        pkg * self.package_size() + (y * self.cols + x) as usize
    }

    fn grid(&self) -> Mesh {
        Mesh {
            cols: self.cols,
            rows: self.rows,
        }
    }
}

impl Topology for MultiPackage {
    fn routers(&self) -> usize {
        self.packages as usize * self.package_size()
    }
    fn len(&self) -> usize {
        self.routers()
    }
    fn router_of(&self, n: NodeId) -> usize {
        n.0 as usize
    }
    fn node_at(&self, router: usize, slot: u8) -> NodeId {
        debug_assert_eq!(slot, 0);
        NodeId(router as u16)
    }
    fn neighbour_r(&self, at: usize, port: Port) -> Option<usize> {
        let (pkg, x, y) = self.split(at);
        // Inter-package boundary links exist only on gateway rows.
        match port {
            Port::East if x + 1 == self.cols => (self.is_gateway(y)
                && pkg + 1 < self.packages as usize)
                .then(|| self.join(pkg + 1, 0, y)),
            Port::West if x == 0 => {
                (self.is_gateway(y) && pkg > 0).then(|| self.join(pkg - 1, self.cols - 1, y))
            }
            _ => self
                .grid()
                .neighbour_r((y * self.cols + x) as usize, port)
                .map(|local| pkg * self.package_size() + local),
        }
    }
    fn route_r(&self, at: usize, dest: usize) -> Port {
        let (apkg, ax, ay) = self.split(at);
        let (dpkg, dx, dy) = self.split(dest);
        if apkg == dpkg {
            return self
                .grid()
                .route_xy(self.grid().node(ax, ay), self.grid().node(dx, dy));
        }
        // Cross-package: reach the nearest gateway row, ride it to the
        // boundary column, cross, repeat.
        if !self.is_gateway(ay) {
            let g = if ay.abs_diff(0) <= ay.abs_diff(self.rows / 2) {
                0
            } else {
                self.rows / 2
            };
            return if g < ay { Port::North } else { Port::South };
        }
        if dpkg > apkg {
            Port::East
        } else {
            Port::West
        }
    }
    fn link_count(&self) -> u64 {
        let per_pkg = Topology::link_count(&self.grid());
        per_pkg * self.packages as u64 + 2 * self.gateway_rows() * (self.packages as u64 - 1)
    }
}

/// The topology a [`crate::network::Network`] is built over: a closed
/// enum (rather than a trait object) so [`crate::network::NetworkConfig`]
/// stays `Copy` and the router hot path stays monomorphic.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Topo {
    Mesh(Mesh),
    CMesh(CMesh),
    MultiPackage(MultiPackage),
}

impl Topo {
    /// The paper's 6×6 flat mesh.
    pub fn simba_6x6() -> Self {
        Topo::Mesh(Mesh::simba_6x6())
    }

    /// CLI/report name.
    pub fn name(&self) -> &'static str {
        match self {
            Topo::Mesh(_) => "mesh",
            Topo::CMesh(_) => "cmesh",
            Topo::MultiPackage(_) => "multipackage",
        }
    }

    /// The flat mesh, when this is one (legacy callers).
    pub fn as_mesh(&self) -> Option<Mesh> {
        match self {
            Topo::Mesh(m) => Some(*m),
            _ => None,
        }
    }

    /// Does the baseline `route_r` discipline need the escape channel
    /// to be deadlock-free? XY on a flat/concentrated mesh is safe by
    /// itself; gateway-directed multi-package routing is not.
    pub fn needs_escape(&self) -> bool {
        matches!(self, Topo::MultiPackage(_))
    }
}

impl Topology for Topo {
    fn routers(&self) -> usize {
        match self {
            Topo::Mesh(t) => t.routers(),
            Topo::CMesh(t) => t.routers(),
            Topo::MultiPackage(t) => t.routers(),
        }
    }
    fn len(&self) -> usize {
        match self {
            Topo::Mesh(t) => Topology::len(t),
            Topo::CMesh(t) => Topology::len(t),
            Topo::MultiPackage(t) => Topology::len(t),
        }
    }
    fn conc(&self) -> u8 {
        match self {
            Topo::Mesh(t) => t.conc(),
            Topo::CMesh(t) => t.conc(),
            Topo::MultiPackage(t) => t.conc(),
        }
    }
    fn router_of(&self, n: NodeId) -> usize {
        match self {
            Topo::Mesh(t) => t.router_of(n),
            Topo::CMesh(t) => t.router_of(n),
            Topo::MultiPackage(t) => t.router_of(n),
        }
    }
    fn node_at(&self, router: usize, slot: u8) -> NodeId {
        match self {
            Topo::Mesh(t) => t.node_at(router, slot),
            Topo::CMesh(t) => t.node_at(router, slot),
            Topo::MultiPackage(t) => t.node_at(router, slot),
        }
    }
    fn neighbour_r(&self, at: usize, port: Port) -> Option<usize> {
        match self {
            Topo::Mesh(t) => t.neighbour_r(at, port),
            Topo::CMesh(t) => t.neighbour_r(at, port),
            Topo::MultiPackage(t) => t.neighbour_r(at, port),
        }
    }
    fn route_r(&self, at: usize, dest: usize) -> Port {
        match self {
            Topo::Mesh(t) => t.route_r(at, dest),
            Topo::CMesh(t) => t.route_r(at, dest),
            Topo::MultiPackage(t) => t.route_r(at, dest),
        }
    }
    fn link_count(&self) -> u64 {
        match self {
            Topo::Mesh(t) => Topology::link_count(t),
            Topo::CMesh(t) => Topology::link_count(t),
            Topo::MultiPackage(t) => Topology::link_count(t),
        }
    }
    fn hops(&self, a: NodeId, b: NodeId) -> u32 {
        match self {
            Topo::Mesh(t) => Topology::hops(t, a, b),
            Topo::CMesh(t) => t.hops(a, b),
            Topo::MultiPackage(t) => t.hops(a, b),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coords_roundtrip() {
        let m = Mesh::simba_6x6();
        for i in 0..m.len() as u16 {
            let (x, y) = m.coords(NodeId(i));
            assert_eq!(m.node(x, y), NodeId(i));
        }
    }

    #[test]
    fn xy_route_reaches_dest() {
        let m = Mesh::new(5, 7);
        for a in 0..m.len() as u16 {
            for b in 0..m.len() as u16 {
                let (mut at, dest) = (NodeId(a), NodeId(b));
                let mut steps = 0;
                loop {
                    let p = m.route_xy(at, dest);
                    if p == Port::Local {
                        break;
                    }
                    at = m.neighbour(at, p).expect("XY route stays in-mesh");
                    steps += 1;
                    assert!(steps <= m.hops(NodeId(a), dest), "non-minimal route");
                }
                assert_eq!(at, dest);
                assert_eq!(steps, m.hops(NodeId(a), dest));
            }
        }
    }

    #[test]
    fn opposite_ports() {
        assert_eq!(Port::North.opposite(), Port::South);
        assert_eq!(Port::East.opposite(), Port::West);
        assert_eq!(Port::West.opposite(), Port::East);
        assert_eq!(Port::South.opposite(), Port::North);
    }

    #[test]
    fn x_before_y() {
        let m = Mesh::new(4, 4);
        // From (0,0) to (2,2): first move must be East.
        assert_eq!(m.route_xy(m.node(0, 0), m.node(2, 2)), Port::East);
        // From (2,0) to (2,2): X aligned → South.
        assert_eq!(m.route_xy(m.node(2, 0), m.node(2, 2)), Port::South);
    }

    /// Shared contract checks: neighbour symmetry, endpoint↔(router,
    /// slot) bijection, and `route_r` reaching every destination.
    fn check_contract<T: Topology>(t: &T) {
        for r in 0..t.routers() {
            for &p in &Port::ALL[1..] {
                if let Some(nb) = t.neighbour_r(r, p) {
                    assert_eq!(
                        t.neighbour_r(nb, p.opposite()),
                        Some(r),
                        "asymmetric link {r} {p:?}"
                    );
                }
            }
            for slot in 0..t.conc() {
                let n = t.node_at(r, slot);
                assert_eq!(t.router_of(n), r);
            }
        }
        for n in 0..t.len() as u16 {
            let r = t.router_of(NodeId(n));
            assert!(r < t.routers());
        }
        for a in 0..t.routers() {
            for b in 0..t.routers() {
                let (mut at, mut steps) = (a, 0u32);
                while at != b {
                    let p = t.route_r(at, b);
                    assert_ne!(p, Port::Local, "route_r stalled before dest");
                    at = t.neighbour_r(at, p).expect("route over a live link");
                    steps += 1;
                    assert!(steps as usize <= 4 * t.routers(), "routing loop");
                }
                assert_eq!(t.route_r(b, b), Port::Local);
            }
        }
        // Directed links counted by enumeration must match link_count().
        let mut links = 0u64;
        for r in 0..t.routers() {
            for &p in &Port::ALL[1..] {
                if t.neighbour_r(r, p).is_some() {
                    links += 1;
                }
            }
        }
        assert_eq!(links, t.link_count());
    }

    #[test]
    fn mesh_satisfies_topology_contract() {
        check_contract(&Mesh::new(4, 3));
        check_contract(&Mesh::new(1, 5));
    }

    #[test]
    fn cmesh_concentrates_endpoints() {
        let c = CMesh::new(3, 3, 4);
        check_contract(&c);
        assert_eq!(Topology::len(&c), 36);
        assert_eq!(c.routers(), 9);
        // 4 endpoints per router, slots round-trip.
        assert_eq!(c.router_of(NodeId(0)), 0);
        assert_eq!(c.router_of(NodeId(3)), 0);
        assert_eq!(c.router_of(NodeId(4)), 1);
        assert_eq!(c.node_at(2, 1), NodeId(9));
        // Router-grid links only: same count as the bare 3x3 mesh.
        assert_eq!(Topology::link_count(&c), Topology::link_count(&Mesh::new(3, 3)));
        // Endpoints on the same router are 0 hops apart.
        assert_eq!(c.hops(NodeId(0), NodeId(3)), 0);
    }

    #[test]
    fn multipackage_stitches_on_gateway_rows_only() {
        let mp = MultiPackage::new(2, 4, 4);
        check_contract(&mp);
        assert_eq!(mp.routers(), 32);
        // Gateway rows of a 4-row package: 0 and 2.
        assert!(mp.is_gateway(0) && mp.is_gateway(2));
        assert!(!mp.is_gateway(1) && !mp.is_gateway(3));
        // East edge of package 0, gateway row → west edge of package 1.
        let gw = mp.join(0, 3, 2);
        assert_eq!(mp.neighbour_r(gw, Port::East), Some(mp.join(1, 0, 2)));
        // Non-gateway row: no boundary link.
        assert_eq!(mp.neighbour_r(mp.join(0, 3, 1), Port::East), None);
        // Link count: two 4x4 meshes + 2 gateway rows × 2 directions.
        assert_eq!(
            Topology::link_count(&mp),
            2 * Topology::link_count(&Mesh::new(4, 4)) + 4
        );
    }

    #[test]
    fn multipackage_route_crosses_via_gateways() {
        let mp = MultiPackage::new(3, 4, 4);
        // From a non-gateway row the route first seeks the nearest
        // gateway row, then rides East through each boundary.
        let src = mp.join(0, 1, 3); // row 3 → nearest gateway is row 2
        assert_eq!(mp.route_r(src, mp.join(2, 1, 1)), Port::North);
        let on_gw = mp.join(0, 3, 0);
        assert_eq!(mp.route_r(on_gw, mp.join(1, 0, 0)), Port::East);
        // Westbound symmetric.
        assert_eq!(mp.route_r(mp.join(2, 0, 0), mp.join(0, 0, 0)), Port::West);
        // Hop count via the walk matches the route discipline end to
        // end: 1 North to the gateway row, 2 East + cross, 3 East +
        // cross, then 2 hops inside the last package.
        assert_eq!(mp.hops(NodeId(src as u16), NodeId(mp.join(2, 1, 1) as u16)), 10);
    }

    #[test]
    fn topo_enum_dispatches() {
        let t = Topo::simba_6x6();
        assert_eq!(t.name(), "mesh");
        assert_eq!(Topology::len(&t), 36);
        assert!(!t.needs_escape());
        assert_eq!(t.as_mesh(), Some(Mesh::simba_6x6()));
        let mp = Topo::MultiPackage(MultiPackage::new(2, 6, 6));
        assert_eq!(mp.name(), "multipackage");
        assert!(mp.needs_escape());
        assert_eq!(mp.as_mesh(), None);
        assert_eq!(Topology::len(&mp), 72);
        let cm = Topo::CMesh(CMesh::new(3, 3, 2));
        assert_eq!(cm.name(), "cmesh");
        assert_eq!(cm.conc(), 2);
    }
}
