//! 2D mesh topology and dimension-ordered routing.

/// A node index in a 2D mesh (row-major).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub u16);

/// Router port directions. `Local` is the NI (network-interface) port.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
#[repr(usize)]
pub enum Port {
    Local = 0,
    North = 1,
    South = 2,
    East = 3,
    West = 4,
}

/// Number of ports per router.
pub const NUM_PORTS: usize = 5;

impl Port {
    /// All ports, index-aligned with the `repr`.
    pub const ALL: [Port; NUM_PORTS] = [Port::Local, Port::North, Port::South, Port::East, Port::West];

    /// The port a neighbouring router receives on when we send via `self`.
    pub fn opposite(self) -> Port {
        match self {
            Port::Local => Port::Local,
            Port::North => Port::South,
            Port::South => Port::North,
            Port::East => Port::West,
            Port::West => Port::East,
        }
    }
}

/// A `cols × rows` 2D mesh (the paper's NoI is 6×6).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Mesh {
    pub cols: u16,
    pub rows: u16,
}

impl Mesh {
    /// Construct a mesh; panics on degenerate sizes.
    pub fn new(cols: u16, rows: u16) -> Self {
        assert!(cols >= 1 && rows >= 1, "mesh must be at least 1x1");
        Mesh { cols, rows }
    }

    /// The paper's 6×6 Simba-style array.
    pub fn simba_6x6() -> Self {
        Mesh::new(6, 6)
    }

    /// Total nodes.
    pub fn len(&self) -> usize {
        self.cols as usize * self.rows as usize
    }

    /// True for the degenerate 0-node mesh (never constructable).
    pub fn is_empty(&self) -> bool {
        false
    }

    /// (x, y) of a node.
    pub fn coords(&self, n: NodeId) -> (u16, u16) {
        (n.0 % self.cols, n.0 / self.cols)
    }

    /// Node at (x, y).
    pub fn node(&self, x: u16, y: u16) -> NodeId {
        debug_assert!(x < self.cols && y < self.rows);
        NodeId(y * self.cols + x)
    }

    /// Manhattan hop distance.
    pub fn hops(&self, a: NodeId, b: NodeId) -> u32 {
        let (ax, ay) = self.coords(a);
        let (bx, by) = self.coords(b);
        (ax.abs_diff(bx) + ay.abs_diff(by)) as u32
    }

    /// Dimension-ordered (XY) routing: next output port from `at` toward
    /// `dest`. X first, then Y; `Local` when arrived.
    pub fn route_xy(&self, at: NodeId, dest: NodeId) -> Port {
        let (ax, ay) = self.coords(at);
        let (dx, dy) = self.coords(dest);
        if ax < dx {
            Port::East
        } else if ax > dx {
            Port::West
        } else if ay < dy {
            Port::South
        } else if ay > dy {
            Port::North
        } else {
            Port::Local
        }
    }

    /// Neighbour of `n` through `port`, if within the mesh.
    pub fn neighbour(&self, n: NodeId, port: Port) -> Option<NodeId> {
        let (x, y) = self.coords(n);
        match port {
            Port::Local => None,
            Port::North => (y > 0).then(|| self.node(x, y - 1)),
            Port::South => (y + 1 < self.rows).then(|| self.node(x, y + 1)),
            Port::East => (x + 1 < self.cols).then(|| self.node(x + 1, y)),
            Port::West => (x > 0).then(|| self.node(x - 1, y)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coords_roundtrip() {
        let m = Mesh::simba_6x6();
        for i in 0..m.len() as u16 {
            let (x, y) = m.coords(NodeId(i));
            assert_eq!(m.node(x, y), NodeId(i));
        }
    }

    #[test]
    fn xy_route_reaches_dest() {
        let m = Mesh::new(5, 7);
        for a in 0..m.len() as u16 {
            for b in 0..m.len() as u16 {
                let (mut at, dest) = (NodeId(a), NodeId(b));
                let mut steps = 0;
                loop {
                    let p = m.route_xy(at, dest);
                    if p == Port::Local {
                        break;
                    }
                    at = m.neighbour(at, p).expect("XY route stays in-mesh");
                    steps += 1;
                    assert!(steps <= m.hops(NodeId(a), dest), "non-minimal route");
                }
                assert_eq!(at, dest);
                assert_eq!(steps, m.hops(NodeId(a), dest));
            }
        }
    }

    #[test]
    fn opposite_ports() {
        assert_eq!(Port::North.opposite(), Port::South);
        assert_eq!(Port::East.opposite(), Port::West);
        assert_eq!(Port::West.opposite(), Port::East);
        assert_eq!(Port::South.opposite(), Port::North);
    }

    #[test]
    fn x_before_y() {
        let m = Mesh::new(4, 4);
        // From (0,0) to (2,2): first move must be East.
        assert_eq!(m.route_xy(m.node(0, 0), m.node(2, 2)), Port::East);
        // From (2,0) to (2,2): X aligned → South.
        assert_eq!(m.route_xy(m.node(2, 0), m.node(2, 2)), Port::South);
    }
}
