//! Input control: route computation + VC allocation per input VC
//! (ISSUE 10, bsg_wormhole_router-style input side).
//!
//! For every head-of-line flit the input controller answers one
//! question: *which (output port, output VC) does this flit want this
//! cycle?* The answer feeds [`crate::output_control`]'s switch
//! arbitration; nothing here mutates state, so a declined grant (no
//! credit, backlogged egress decoder, faulted link) replays identically
//! next cycle.
//!
//! Disciplines, in order:
//!
//! * **`vcs = 1` (legacy)** — exactly the pre-refactor router: XY (or
//!   the topology's baseline route) while healthy, and the
//!   all-or-nothing up*/down* switch once any permanent link failure
//!   installed escape tables. Output VC is always 0.
//! * **VC 0 of a multi-VC router** — the always-on escape channel:
//!   up*/down* table hops with the phase implied by the arrival port.
//!   Escape flits never leave VC 0 (conservative, keeps the escape
//!   dependency graph closed).
//! * **VCs ≥ 1 (adaptive)** — the topology's baseline route on the
//!   same VC index; when that lane is held by another worm, out of
//!   credits, or the link is dead, the head *falls back* to the escape
//!   channel, entering it
//!   fresh (up phase, like an NI injection — the flit has not used any
//!   escape resource yet, so the up*/down* invariant is preserved).
//!   Body/tail flits never re-route: they follow the lane their head
//!   locked.

use crate::packet::Flit;
use crate::reroute::{EscapeRoutes, LinkState};
use crate::topology::{Port, Topo, Topology, NUM_PORTS};
use crate::vc::VcOutput;

/// Borrowed routing context for one arbitration pass: everything
/// [`RouteCtx::desired`] needs besides the router's own state.
pub struct RouteCtx<'a> {
    pub topo: Topo,
    /// Escape tables: `None` only on a healthy single-VC mesh/cmesh
    /// (pure XY, ISSUE 7 behaviour). Always present when `vcs > 1` or
    /// the topology needs the escape channel for deadlock freedom.
    pub escape: Option<&'a EscapeRoutes>,
    /// Dead directed outputs per router.
    pub down: &'a LinkState,
    pub vcs: u8,
}

impl RouteCtx<'_> {
    /// The `(output port, output VC)` the head-of-line flit of
    /// `(inp, in_vc)` at router `at` requests this cycle, or `None`
    /// when it cannot move (body without a lock — e.g. freshly
    /// truncated — or an escape flit with no legal continuation, which
    /// link-down handling truncates).
    pub fn desired(
        &self,
        at: usize,
        inp: usize,
        in_vc: u8,
        flit: &Flit,
        outputs: &[VcOutput; NUM_PORTS],
    ) -> Option<(Port, u8)> {
        let dest = self.topo.router_of(flit.dest);
        if self.vcs == 1 {
            // Legacy single-VC disciplines, bit-for-bit (ISSUE 5/7).
            let want = match self.escape {
                None => self.topo.route_r(at, dest),
                Some(esc) => esc
                    .next_hop(at, inp, dest)
                    .expect("unroutable flits are truncated at link-down time"),
            };
            return Some((want, 0));
        }

        if !flit.is_head() {
            // Wormhole continuation: follow the lane the head locked
            // from this (input port, input VC). `None` only transiently
            // (the packet was just truncated under us).
            for (out, o) in outputs.iter().enumerate() {
                for (ovc, lane) in o.lanes.iter().enumerate() {
                    if lane.locked_to == Some((inp, in_vc))
                        && lane.locked_packet == Some(flit.packet_id)
                    {
                        return Some((Port::ALL[out], ovc as u8));
                    }
                }
            }
            return None;
        }

        let esc = self.escape.expect("escape tables installed when vcs > 1");
        if in_vc == 0 {
            // Escape channel: up*/down* hop, stay on VC 0.
            return esc.next_hop(at, inp, dest).map(|p| (p, 0));
        }

        // Adaptive head: baseline route on its own VC index…
        let want = self.topo.route_r(at, dest);
        if want == Port::Local {
            return Some((Port::Local, in_vc));
        }
        let lane = &outputs[want as usize].lanes[in_vc as usize];
        // The head must not camp on a lane it cannot enter *now*: a
        // held or credit-starved lane diverts to escape, otherwise a
        // cycle of adaptive worms each waiting on credits held by the
        // next worm's buffered bodies would deadlock with the escape
        // channel sitting idle (Duato: blocked heads must always be
        // able to reach the escape resource).
        if !self.down[at][want as usize] && lane.locked_to.is_none() && lane.credits > 0 {
            return Some((want, in_vc));
        }
        // …falling back to the escape channel when the lane is held or
        // the link is dead. Entry is fresh (up phase, like an NI
        // injection): the flit has consumed no escape resource yet.
        esc.next_hop(at, Port::Local as usize, dest).map(|p| (p, 0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::packet::FlitKind;
    use crate::reroute::EscapeRoutes;
    use crate::topology::{Mesh, NodeId};
    use crate::vc::VcRouter;

    fn flit(kind: FlitKind, dest: u16, vc: u8) -> Flit {
        Flit {
            packet_id: 7,
            kind,
            src: NodeId(0),
            dest: NodeId(dest),
            seq: 0,
            vc,
            ready_at: 0,
            codec: None,
        }
    }

    fn ctx_parts(vcs: u8) -> (Topo, LinkState, Option<EscapeRoutes>) {
        let topo = Topo::Mesh(Mesh::new(3, 3));
        let down: LinkState = vec![[false; NUM_PORTS]; topo.routers()];
        let esc = (vcs > 1).then(|| EscapeRoutes::compute(topo, &down));
        (topo, down, esc)
    }

    #[test]
    fn vc1_routes_pure_xy_with_no_tables() {
        let (topo, down, _) = ctx_parts(1);
        let ctx = RouteCtx {
            topo,
            escape: None,
            down: &down,
            vcs: 1,
        };
        let r = VcRouter::new(4, 1);
        // Node 0 → node 2: X first ⇒ East, VC 0.
        let f = flit(FlitKind::Head, 2, 0);
        assert_eq!(ctx.desired(0, 0, 0, &f, &r.outputs), Some((Port::East, 0)));
        // Bodies route identically (deterministic XY) — the legacy
        // arbiter re-routes every flit.
        let b = flit(FlitKind::Body, 2, 0);
        assert_eq!(ctx.desired(0, 0, 0, &b, &r.outputs), Some((Port::East, 0)));
    }

    #[test]
    fn adaptive_head_falls_back_to_escape_when_lane_held() {
        let (topo, down, esc) = ctx_parts(2);
        let ctx = RouteCtx {
            topo,
            escape: esc.as_ref(),
            down: &down,
            vcs: 2,
        };
        let mut r = VcRouter::new(4, 2);
        let f = flit(FlitKind::Head, 2, 1);
        // Lane free: adaptive VC 1 keeps its index on the XY port.
        assert_eq!(ctx.desired(0, 0, 1, &f, &r.outputs), Some((Port::East, 1)));
        // Another worm holds (East, VC 1): fall back to escape VC 0.
        r.outputs[Port::East as usize].lanes[1].locked_to = Some((2, 1));
        r.outputs[Port::East as usize].lanes[1].locked_packet = Some(99);
        let (p, v) = ctx.desired(0, 0, 1, &f, &r.outputs).unwrap();
        assert_eq!(v, 0, "fallback enters the escape channel");
        assert_eq!(
            Some(p),
            esc.as_ref().unwrap().next_hop(0, Port::Local as usize, 2)
        );
        // A free but credit-starved lane diverts too (deadlock
        // freedom: blocked heads must reach the escape resource).
        let mut starved = VcRouter::new(4, 2);
        starved.outputs[Port::East as usize].lanes[1].credits = 0;
        let (_, v) = ctx.desired(0, 0, 1, &f, &starved.outputs).unwrap();
        assert_eq!(v, 0, "zero-credit lane must not be camped on");
    }

    #[test]
    fn bodies_follow_their_lock_and_escape_stays_on_vc0() {
        let (topo, down, esc) = ctx_parts(2);
        let ctx = RouteCtx {
            topo,
            escape: esc.as_ref(),
            down: &down,
            vcs: 2,
        };
        let mut r = VcRouter::new(4, 2);
        // Head locked (South, VC 0) from (North input, VC 1): the body
        // follows it regardless of what XY would say.
        r.outputs[Port::South as usize].lanes[0].locked_to = Some((Port::North as usize, 1));
        r.outputs[Port::South as usize].lanes[0].locked_packet = Some(7);
        let b = flit(FlitKind::Body, 2, 1);
        assert_eq!(
            ctx.desired(4, Port::North as usize, 1, &b, &r.outputs),
            Some((Port::South, 0))
        );
        // A body with no lock anywhere cannot move.
        let orphan = flit(FlitKind::Tail, 2, 0);
        let clean = VcRouter::new(4, 2);
        assert_eq!(ctx.desired(4, 0, 0, &orphan, &clean.outputs), None);
        // Escape heads take table hops on VC 0.
        let e = flit(FlitKind::Head, 8, 0);
        let (p, v) = ctx
            .desired(0, Port::Local as usize, 0, &e, &clean.outputs)
            .unwrap();
        assert_eq!(v, 0);
        assert_eq!(
            Some(p),
            esc.as_ref().unwrap().next_hop(0, Port::Local as usize, 8)
        );
    }

    #[test]
    fn dead_link_diverts_adaptive_heads() {
        let (topo, mut down, esc0) = ctx_parts(2);
        // Kill 0→East (and the reverse) and rebuild tables.
        down[0][Port::East as usize] = true;
        down[1][Port::West as usize] = true;
        let esc = EscapeRoutes::compute(topo, &down);
        let _ = esc0;
        let ctx = RouteCtx {
            topo,
            escape: Some(&esc),
            down: &down,
            vcs: 2,
        };
        let r = VcRouter::new(4, 2);
        let f = flit(FlitKind::Head, 2, 1);
        let (p, v) = ctx.desired(0, 0, 1, &f, &r.outputs).unwrap();
        assert_eq!(v, 0, "dead baseline link forces the escape channel");
        assert_ne!(p, Port::East);
    }
}
