//! Deadlock-safe escape routing around permanent link failures (ISSUE 7).
//!
//! Healthy meshes route dimension-ordered (XY): X moves then Y moves,
//! never returning to X — the classic two-phase discipline whose channel
//! dependency graph is acyclic. A dead link breaks XY (the one legal
//! path may be severed), so the network switches **every** packet to the
//! generalization of the same idea: **up*/down* routing** over the
//! surviving link graph. A BFS spanning tree is rooted at the
//! lowest-numbered node of each component; a directed link is *up* if it
//! moves strictly rootward in `(level, id)` order, *down* otherwise. A
//! legal path takes zero or more up links followed by zero or more down
//! links — never down-then-up. Up-phase hops strictly decrease the rank
//! and down-phase hops strictly increase it, so no cycle of channel
//! waits can close: the discipline is deadlock-free on any connected
//! subgraph, exactly like X-then-Y is on the full mesh.
//!
//! A flit's phase needs no per-packet state: it is implied by the port
//! it arrived on (injected at the NI → still in the up phase; arrived
//! over a down link → committed to the down phase). Next hops are
//! precomputed per `(phase, node, dest)` by BFS over the phase-state
//! graph, so the hot path stays a table lookup. Tables are rebuilt only
//! when a link dies — never on the healthy fast path, which keeps pure
//! XY untouched.

use crate::topology::{NodeId, Port, Topo, Topology, NUM_PORTS};

/// Routing phase of an in-flight flit under up*/down* rules.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// May still take up links (or switch to down at any hop).
    Up = 0,
    /// Has taken a down link: down links only from here on.
    Down = 1,
}

/// Per-node dead-output map: `down[node][port]` = that directed output
/// is severed. Link kills are symmetric (both directions die together).
pub type LinkState = Vec<[bool; NUM_PORTS]>;

/// Precomputed up*/down* next-hop tables over the surviving links.
///
/// Since ISSUE 10 the tables are computed over any [`Topo`]'s router
/// graph (flat mesh, concentrated mesh, stitched multi-package) and
/// indexed by *router* id. They serve two masters: the all-or-nothing
/// reroute switch after a permanent link failure on single-VC
/// networks (ISSUE 7 behaviour, unchanged), and the always-on escape
/// channel VC 0 of a multi-VC router, which adaptive heads fall back
/// to when their preferred lane is held.
#[derive(Clone, Debug)]
pub struct EscapeRoutes {
    topo: Topo,
    n: usize,
    /// Connected-component id per node (over live links).
    comp: Vec<u32>,
    /// Tree order: `level * n + id` from each component's BFS root
    /// (lowest id). Lower rank = strictly rootward.
    rank: Vec<u32>,
    /// `next[(phase * n + at) * n + dest]` → output port, `None` when no
    /// legal continuation exists from that state.
    next: Vec<Option<Port>>,
}

impl EscapeRoutes {
    /// Build tables for `topo`'s router graph with the given dead links.
    pub fn compute(topo: Topo, down: &LinkState) -> Self {
        let n = topo.routers();
        debug_assert_eq!(down.len(), n);
        let live = |u: usize, p: Port| -> Option<usize> {
            if down[u][p as usize] {
                return None;
            }
            topo.neighbour_r(u, p)
        };

        // BFS levels + components, roots at the lowest unvisited id.
        let (mut comp, mut level) = (vec![u32::MAX; n], vec![0u32; n]);
        let mut queue = std::collections::VecDeque::new();
        let mut ncomp = 0u32;
        for root in 0..n {
            if comp[root] != u32::MAX {
                continue;
            }
            comp[root] = ncomp;
            queue.push_back(root);
            while let Some(u) = queue.pop_front() {
                for &p in &Port::ALL[1..] {
                    if let Some(v) = live(u, p) {
                        if comp[v] == u32::MAX {
                            comp[v] = ncomp;
                            level[v] = level[u] + 1;
                            queue.push_back(v);
                        }
                    }
                }
            }
            ncomp += 1;
        }
        let rank: Vec<u32> = (0..n).map(|u| level[u] * n as u32 + u as u32).collect();

        // Per-dest backward BFS over (node, phase) states. Forward
        // edges: up link keeps Up; down link enters/keeps Down.
        let mut next: Vec<Option<Port>> = vec![None; 2 * n * n];
        let mut dist = vec![u32::MAX; 2 * n];
        for dest in 0..n {
            dist.fill(u32::MAX);
            dist[dest] = 0; // (dest, Up)
            dist[n + dest] = 0; // (dest, Down)
            queue.push_back(dest);
            queue.push_back(n + dest);
            while let Some(s) = queue.pop_front() {
                let (v, down_phase) = (s % n, s >= n);
                // Predecessor u sends to v over u's output p_out; from
                // v's side the link is v's port q (symmetric liveness).
                for &q in &Port::ALL[1..] {
                    if let Some(u) = live(v, q) {
                        let is_up = rank[v] < rank[u]; // u→v moves rootward
                        let preds: &[usize] = if is_up {
                            if down_phase {
                                continue; // an up link only reaches Up states
                            }
                            &[0] // only (u, Up) may take an up link
                        } else {
                            if !down_phase {
                                continue; // a down link always lands in Down
                            }
                            &[0, 1] // both phases may take a down link
                        };
                        for &ph in preds {
                            let ps = ph * n + u;
                            if dist[ps] == u32::MAX {
                                dist[ps] = dist[s] + 1;
                                queue.push_back(ps);
                            }
                        }
                    }
                }
            }
            // Greedy next hop per (node, phase): the live legal port
            // whose target state is closest to dest (first port wins
            // ties — deterministic).
            for at in 0..n {
                for ph in 0..2usize {
                    let idx = (ph * n + at) * n + dest;
                    if at == dest {
                        next[idx] = Some(Port::Local);
                        continue;
                    }
                    if dist[ph * n + at] == u32::MAX {
                        continue;
                    }
                    let want = dist[ph * n + at] - 1;
                    next[idx] = Port::ALL[1..].iter().copied().find(|&p| {
                        live(at, p).is_some_and(|v| {
                            let is_up = rank[v] < rank[at];
                            if is_up && ph == 1 {
                                return false;
                            }
                            let tgt = if is_up { v } else { n + v };
                            dist[tgt] == want
                        })
                    });
                    debug_assert!(next[idx].is_some(), "finite dist must yield a hop");
                }
            }
        }
        EscapeRoutes {
            topo,
            n,
            comp,
            rank,
            next,
        }
    }

    /// Are endpoints `a` and `b` on routers of the same live component?
    pub fn reachable(&self, a: NodeId, b: NodeId) -> bool {
        self.comp[self.topo.router_of(a)] == self.comp[self.topo.router_of(b)]
    }

    /// Phase implied by the input port a flit occupies at `at`: NI
    /// injection is still up-phase; arrival over a down link commits to
    /// the down phase.
    pub fn phase_of(&self, at: usize, inp: usize) -> Phase {
        if inp == Port::Local as usize {
            return Phase::Up;
        }
        let from = self
            .topo
            .neighbour_r(at, Port::ALL[inp])
            .expect("buffered flit arrived over a real link");
        if self.rank[at] < self.rank[from] {
            Phase::Up // the hop here moved rootward
        } else {
            Phase::Down
        }
    }

    /// Table next hop for a flit sitting in input `inp` of router `at`
    /// bound for router `dest`; `None` when no legal continuation
    /// exists (severed component or a down-phase flit stranded below
    /// its turn point — the network truncates and retries such packets
    /// from the source).
    pub fn next_hop(&self, at: usize, inp: usize, dest: usize) -> Option<Port> {
        let ph = self.phase_of(at, inp) as usize;
        self.next[(ph * self.n + at) * self.n + dest]
    }

    /// Hop count of the table path `src → dest` entered fresh (NI
    /// injection, up phase); `None` when unreachable. This is the exact
    /// distance a packet travels when *all* routing follows the tables
    /// — the analytic side of `sim::xval` charges it for topologies
    /// that route on escape tables from construction.
    pub fn path_hops(&self, src: usize, dest: usize) -> Option<u32> {
        let (mut at, mut inp, mut hops) = (src, Port::Local as usize, 0u32);
        loop {
            let p = self.next_hop(at, inp, dest)?;
            if p == Port::Local {
                return Some(hops);
            }
            at = self.topo.neighbour_r(at, p).expect("table hop is live");
            inp = p.opposite() as usize;
            hops += 1;
            debug_assert!(hops as usize <= 4 * self.n, "table walk loop");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{Mesh, MultiPackage};

    fn no_down<T: Topology>(t: &T) -> LinkState {
        vec![[false; NUM_PORTS]; t.routers()]
    }

    fn cut(down: &mut LinkState, topo: Topo, a: usize, b: usize) {
        for &p in &Port::ALL[1..] {
            if topo.neighbour_r(a, p) == Some(b) {
                down[a][p as usize] = true;
                down[b][p.opposite() as usize] = true;
                return;
            }
        }
        panic!("not adjacent");
    }

    /// Walk the tables from src to dest like the router would (phase
    /// from the arrival port), asserting legality; returns hop count.
    fn walk(r: &EscapeRoutes, topo: Topo, down: &LinkState, src: usize, dest: usize) -> u32 {
        let (mut at, mut inp, mut hops) = (src, Port::Local as usize, 0u32);
        let mut gone_down = false;
        loop {
            let p = r.next_hop(at, inp, dest).expect("route exists");
            if p == Port::Local {
                assert_eq!(at, dest);
                return hops;
            }
            assert!(!down[at][p as usize], "routed over a dead link");
            let nxt = topo.neighbour_r(at, p).unwrap();
            // Phase discipline: once a hop increases rank (down), no
            // later hop may decrease it (up) — the deadlock-freedom
            // invariant.
            if r.rank[nxt] > r.rank[at] {
                gone_down = true;
            } else {
                assert!(!gone_down, "down-then-up violates up*/down*");
            }
            inp = p.opposite() as usize;
            at = nxt;
            hops += 1;
            assert!(hops as usize <= 4 * topo.routers(), "routing loop");
        }
    }

    #[test]
    fn healthy_mesh_routes_every_pair_monotonically() {
        let mesh = Mesh::new(4, 4);
        let topo = Topo::Mesh(mesh);
        let down = no_down(&topo);
        let r = EscapeRoutes::compute(topo, &down);
        for a in 0..16 {
            for b in 0..16 {
                assert!(r.reachable(NodeId(a as u16), NodeId(b as u16)));
                let h = walk(&r, topo, &down, a, b);
                assert!(h >= mesh.hops(NodeId(a as u16), NodeId(b as u16)));
                assert_eq!(r.path_hops(a, b), Some(h));
            }
        }
    }

    #[test]
    fn cut_link_is_avoided_and_all_pairs_still_route() {
        let topo = Topo::Mesh(Mesh::new(4, 4));
        let mut down = no_down(&topo);
        cut(&mut down, topo, 5, 6);
        cut(&mut down, topo, 9, 10);
        let r = EscapeRoutes::compute(topo, &down);
        for a in 0..16 {
            for b in 0..16 {
                assert!(r.reachable(NodeId(a as u16), NodeId(b as u16)));
                walk(&r, topo, &down, a, b);
            }
        }
    }

    #[test]
    fn isolated_node_reports_unreachable() {
        // Corner node 0 of a 3x3 has exactly two links; cut both.
        let topo = Topo::Mesh(Mesh::new(3, 3));
        let mut down = no_down(&topo);
        cut(&mut down, topo, 0, 1);
        cut(&mut down, topo, 0, 3);
        let r = EscapeRoutes::compute(topo, &down);
        for b in 1..9 {
            assert!(!r.reachable(NodeId(0), NodeId(b as u16)));
            assert_eq!(r.next_hop(0, Port::Local as usize, b), None);
            assert_eq!(r.path_hops(0, b), None);
        }
        // The surviving 8-node component still fully routes.
        for a in 1..9 {
            for b in 1..9 {
                assert!(r.reachable(NodeId(a as u16), NodeId(b as u16)));
                walk(&r, topo, &down, a, b);
            }
        }
    }

    #[test]
    fn down_phase_flit_can_be_stranded() {
        // A flit that already committed to the down phase may have no
        // legal continuation toward a dest that needs an up hop — the
        // caller must truncate-and-retry it. From the up phase the same
        // (node, dest) pair routes fine.
        let topo = Topo::Mesh(Mesh::new(3, 3));
        let r = EscapeRoutes::compute(topo, &no_down(&topo));
        let mut stranded = 0;
        for at in 0..9 {
            for inp in 1..NUM_PORTS {
                if topo.neighbour_r(at, Port::ALL[inp]).is_none() {
                    continue;
                }
                for dest in 0..9 {
                    if r.next_hop(at, inp, dest).is_none() {
                        assert_eq!(r.phase_of(at, inp), Phase::Down);
                        stranded += 1;
                    }
                }
            }
        }
        assert!(stranded > 0, "expected some stranded down-phase states");
    }

    #[test]
    fn phase_from_arrival_port() {
        let topo = Topo::Mesh(Mesh::new(3, 3));
        let r = EscapeRoutes::compute(topo, &no_down(&topo));
        // Node 4 (center): arriving from node 1 (its North port) moved
        // away from root 0 → Down; NI injection is Up.
        assert_eq!(r.phase_of(4, Port::Local as usize), Phase::Up);
        assert_eq!(r.phase_of(4, Port::North as usize), Phase::Down);
        // Node 1 arriving from 4 (via its South port) moved rootward → Up.
        assert_eq!(r.phase_of(1, Port::South as usize), Phase::Up);
    }

    #[test]
    fn multipackage_tables_route_across_the_stitch() {
        // Escape tables over a 2-package 4x4 stitch: every router pair
        // routes legally through the few gateway links, healthy and
        // with one gateway severed.
        let mp = MultiPackage::new(2, 4, 4);
        let topo = Topo::MultiPackage(mp);
        let down = no_down(&topo);
        let r = EscapeRoutes::compute(topo, &down);
        for a in 0..topo.routers() {
            for b in 0..topo.routers() {
                assert!(r.reachable(NodeId(a as u16), NodeId(b as u16)));
                walk(&r, topo, &down, a, b);
            }
        }
        // Kill the row-0 gateway: the row-2 gateway keeps both
        // packages connected.
        let mut cutd = no_down(&topo);
        cut(&mut cutd, topo, mp.join(0, 3, 0), mp.join(1, 0, 0));
        let r2 = EscapeRoutes::compute(topo, &cutd);
        for a in 0..topo.routers() {
            for b in 0..topo.routers() {
                assert!(r2.reachable(NodeId(a as u16), NodeId(b as u16)));
                walk(&r2, topo, &cutd, a, b);
            }
        }
        // Cross-package table paths are at least as long as the walk
        // to the nearest gateway.
        let h = r.path_hops(mp.join(0, 1, 3), mp.join(1, 1, 3)).unwrap();
        assert!(h >= 4, "must detour through a gateway row: {h}");
    }
}
