//! Traffic generation: synthetic patterns for validation and transfer
//! segmentation for trace-driven runs.

use crate::packet::PacketSpec;
use crate::topology::{Mesh, NodeId};
use lexi_core::prng::Rng;

/// Maximum packet payload used when segmenting large transfers (bits).
/// 4 KiB messages keep router state small while amortizing head/tail
/// overhead — typical for NoI DMA engines.
pub const MAX_PACKET_BITS: u64 = 4096 * 8;

/// Segment one logical transfer of `size_bits` into packet specs.
pub fn segment_transfer(
    src: NodeId,
    dest: NodeId,
    size_bits: u64,
    inject_at: u64,
    max_packet_bits: u64,
) -> Vec<PacketSpec> {
    assert!(max_packet_bits > 0);
    let mut out = Vec::new();
    let mut remaining = size_bits.max(1);
    while remaining > 0 {
        let take = remaining.min(max_packet_bits);
        out.push(PacketSpec {
            src,
            dest,
            size_bits: take,
            inject_at,
        });
        remaining -= take;
    }
    out
}

/// Uniform-random traffic: `count` packets of `size_bits`, injected at a
/// given rate (packets per cycle across the whole mesh).
pub fn uniform_random(
    mesh: Mesh,
    count: usize,
    size_bits: u64,
    packets_per_cycle: f64,
    rng: &mut Rng,
) -> Vec<PacketSpec> {
    let n = mesh.len() as u64;
    let mut out = Vec::with_capacity(count);
    let mut t = 0.0f64;
    for _ in 0..count {
        let src = NodeId(rng.below(n) as u16);
        let mut dest = NodeId(rng.below(n) as u16);
        while dest == src {
            dest = NodeId(rng.below(n) as u16);
        }
        out.push(PacketSpec {
            src,
            dest,
            size_bits,
            inject_at: t as u64,
        });
        t += 1.0 / packets_per_cycle;
    }
    out
}

/// Transpose pattern: node (x,y) sends to (y,x).
pub fn transpose(mesh: Mesh, size_bits: u64) -> Vec<PacketSpec> {
    assert_eq!(mesh.cols, mesh.rows, "transpose needs a square mesh");
    (0..mesh.len() as u16)
        .filter_map(|i| {
            let (x, y) = mesh.coords(NodeId(i));
            let dest = mesh.node(y, x);
            (dest != NodeId(i)).then_some(PacketSpec {
                src: NodeId(i),
                dest,
                size_bits,
                inject_at: 0,
            })
        })
        .collect()
}

/// Hotspot: all nodes send to one sink.
pub fn hotspot(mesh: Mesh, sink: NodeId, size_bits: u64) -> Vec<PacketSpec> {
    (0..mesh.len() as u16)
        .filter(|&i| NodeId(i) != sink)
        .map(|i| PacketSpec {
            src: NodeId(i),
            dest: sink,
            size_bits,
            inject_at: 0,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::{Network, NetworkConfig};
    use lexi_core::proptest::check;

    #[test]
    fn segmentation_conserves_bits() {
        check("segment conserves bits", 100, |g| {
            let size = g.u64(1..50_000_000);
            let parts = segment_transfer(NodeId(0), NodeId(5), size, 7, MAX_PACKET_BITS);
            assert_eq!(parts.iter().map(|p| p.size_bits).sum::<u64>(), size);
            assert!(parts
                .iter()
                .all(|p| p.size_bits <= MAX_PACKET_BITS && p.inject_at == 7));
        });
    }

    #[test]
    fn transpose_delivers_everywhere() {
        let mesh = Mesh::new(4, 4);
        let specs = transpose(mesh, 128 * 4);
        let mut net = Network::new(NetworkConfig {
            mesh,
            flit_bits: 128,
            link_gbps: 100.0,
            buf_depth: 4,
        });
        let n = specs.len() as u64;
        net.schedule_packets(&specs);
        let stats = net.run_to_completion(100_000);
        assert_eq!(stats.delivered_packets, n);
    }

    #[test]
    fn prop_random_traffic_all_delivered() {
        check("uniform random delivered", 10, |g| {
            let mesh = Mesh::new(4, 4);
            let count = g.usize(1..120);
            let specs = uniform_random(mesh, count, 128 * 2, 0.5, g.rng());
            let mut net = Network::new(NetworkConfig {
                mesh,
                flit_bits: 128,
                link_gbps: 100.0,
                buf_depth: 4,
            });
            net.schedule_packets(&specs);
            let stats = net.run_to_completion(1_000_000);
            assert_eq!(stats.delivered_packets, count as u64);
        });
    }
}
