//! Traffic generation: synthetic patterns for validation and transfer
//! segmentation for trace-driven runs.
//!
//! Codec-aware callers (ISSUE 5) use [`segment_transfer_tagged`] to
//! produce [`CodecTag`]-carrying specs — the per-node egress decoder
//! ports then drain them at the measured decoder rate — and
//! [`tag_packets`] to tag synthetic patterns wholesale.

use crate::packet::{CodecTag, PacketSpec};
use crate::topology::{Mesh, NodeId, Topo, Topology};
use lexi_core::codec::CodecKind;
use lexi_core::prng::Rng;

/// Maximum packet payload used when segmenting large transfers (bits).
/// 4 KiB messages keep router state small while amortizing head/tail
/// overhead — typical for NoI DMA engines.
pub const MAX_PACKET_BITS: u64 = 4096 * 8;

/// Segment one logical transfer of `size_bits` into packet specs. An
/// empty transfer produces **no packets** (regression, ISSUE 5: the old
/// `size_bits.max(1)` fabricated a phantom 1-bit packet and broke bit
/// conservation).
pub fn segment_transfer(
    src: NodeId,
    dest: NodeId,
    size_bits: u64,
    inject_at: u64,
    max_packet_bits: u64,
) -> Vec<PacketSpec> {
    assert!(max_packet_bits > 0);
    let mut out = Vec::new();
    let mut remaining = size_bits;
    while remaining > 0 {
        let take = remaining.min(max_packet_bits);
        out.push(PacketSpec::new(src, dest, take, inject_at));
        remaining -= take;
    }
    out
}

/// Segment one codec-coded transfer into **tagged** packet specs:
/// `wire_bits` of coded payload carrying `tag.symbols` exponent symbols
/// in total. Symbols are apportioned to packets in proportion to their
/// wire bits (cumulative rounding — the per-packet counts sum exactly to
/// `tag.symbols`), and the runtime-book startup flag is set on the
/// *first* packet only: the codebook ships once per transfer, so only
/// the leading flits pay the codebook-pipeline + LUT-fill stall.
pub fn segment_transfer_tagged(
    src: NodeId,
    dest: NodeId,
    wire_bits: u64,
    inject_at: u64,
    max_packet_bits: u64,
    tag: CodecTag,
) -> Vec<PacketSpec> {
    let mut parts = segment_transfer(src, dest, wire_bits, inject_at, max_packet_bits);
    let mut acc_bits = 0u64;
    let mut assigned = 0u64;
    for (i, p) in parts.iter_mut().enumerate() {
        acc_bits += p.size_bits;
        // Cumulative proportional share, exact at the last packet.
        let want = (tag.symbols as u128 * acc_bits as u128 / wire_bits.max(1) as u128) as u64;
        let symbols = want - assigned;
        assigned = want;
        *p = p.tagged(CodecTag {
            kind: tag.kind,
            symbols,
            runtime_book: tag.runtime_book && i == 0,
        });
    }
    parts
}

/// Total flits a transfer of `wire_bits` occupies once segmented into
/// `max_packet_bits` packets of `flit_bits` flits — the **per-packet**
/// flit quantization the cycle-level NoC actually pays (each packet
/// rounds up to whole flits independently). The analytic engine's
/// concurrent-link pricing uses this so its ceiling and the cycle sim
/// agree (ISSUE 5 satellite).
pub fn transfer_flits(wire_bits: u64, flit_bits: u32, max_packet_bits: u64) -> u64 {
    assert!(max_packet_bits > 0);
    if wire_bits == 0 {
        return 0;
    }
    let fb = flit_bits as u64;
    let full = wire_bits / max_packet_bits;
    let rem = wire_bits % max_packet_bits;
    full * max_packet_bits.div_ceil(fb) + if rem > 0 { rem.div_ceil(fb) } else { 0 }
}

/// Tag every spec in a synthetic pattern with `codec`: each packet is an
/// independent message whose symbol count is its wire bits divided by
/// the average **wire** bits per exponent symbol (≈ 10 at the paper
/// point: `8 / CR ≈ 2.7` coded exponent bits plus 9 sign/mantissa
/// passthrough bits per BF16 value), capped at one symbol per wire bit.
pub fn tag_packets(
    specs: &mut [PacketSpec],
    codec: CodecKind,
    coded_bits_per_symbol: f64,
    runtime_book: bool,
) {
    assert!(coded_bits_per_symbol > 0.0);
    for s in specs.iter_mut() {
        let symbols =
            ((s.size_bits as f64 / coded_bits_per_symbol) as u64).min(s.size_bits);
        *s = s.tagged(CodecTag {
            kind: codec,
            symbols,
            runtime_book,
        });
    }
}

/// Uniform-random traffic: `count` packets of `size_bits`, injected at a
/// given rate (packets per cycle across the whole topology). Endpoints
/// are drawn over [`Topology::len`], so concentrated and multi-package
/// topologies (ISSUE 10) get uniform load per *endpoint*.
pub fn uniform_random(
    topo: Topo,
    count: usize,
    size_bits: u64,
    packets_per_cycle: f64,
    rng: &mut Rng,
) -> Vec<PacketSpec> {
    let n = topo.len() as u64;
    let mut out = Vec::with_capacity(count);
    let mut t = 0.0f64;
    for _ in 0..count {
        let src = NodeId(rng.below(n) as u16);
        let mut dest = NodeId(rng.below(n) as u16);
        while dest == src {
            dest = NodeId(rng.below(n) as u16);
        }
        out.push(PacketSpec::new(src, dest, size_bits, t as u64));
        t += 1.0 / packets_per_cycle;
    }
    out
}

/// Transpose pattern: node (x,y) sends to (y,x).
pub fn transpose(mesh: Mesh, size_bits: u64) -> Vec<PacketSpec> {
    assert_eq!(mesh.cols, mesh.rows, "transpose needs a square mesh");
    (0..mesh.len() as u16)
        .filter_map(|i| {
            let (x, y) = mesh.coords(NodeId(i));
            let dest = mesh.node(y, x);
            (dest != NodeId(i)).then_some(PacketSpec::new(NodeId(i), dest, size_bits, 0))
        })
        .collect()
}

/// Hotspot: all endpoints send to one sink.
pub fn hotspot(topo: Topo, sink: NodeId, size_bits: u64) -> Vec<PacketSpec> {
    (0..topo.len() as u16)
        .filter(|&i| NodeId(i) != sink)
        .map(|i| PacketSpec::new(NodeId(i), sink, size_bits, 0))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::{Network, NetworkConfig};
    use lexi_core::proptest::check;

    #[test]
    fn segmentation_conserves_bits() {
        // Generator includes 0 (regression, ISSUE 5): an empty transfer
        // must produce no packets, not a phantom 1-bit one.
        check("segment conserves bits", 100, |g| {
            let size = g.u64(0..50_000_000);
            let parts = segment_transfer(NodeId(0), NodeId(5), size, 7, MAX_PACKET_BITS);
            assert_eq!(parts.iter().map(|p| p.size_bits).sum::<u64>(), size);
            if size == 0 {
                assert!(parts.is_empty(), "zero-size transfer fabricated packets");
            }
            assert!(parts
                .iter()
                .all(|p| p.size_bits > 0
                    && p.size_bits <= MAX_PACKET_BITS
                    && p.inject_at == 7));
        });
    }

    #[test]
    fn empty_transfer_produces_no_packets() {
        assert!(segment_transfer(NodeId(0), NodeId(5), 0, 0, MAX_PACKET_BITS).is_empty());
        let tag = CodecTag {
            kind: CodecKind::Huffman,
            symbols: 0,
            runtime_book: true,
        };
        assert!(
            segment_transfer_tagged(NodeId(0), NodeId(5), 0, 0, MAX_PACKET_BITS, tag).is_empty()
        );
    }

    #[test]
    fn tagged_segmentation_conserves_bits_and_symbols() {
        check("tagged segment conserves", 100, |g| {
            let bits = g.u64(1..10_000_000);
            let symbols = g.u64(0..bits.min(1 << 22) + 1);
            let tag = CodecTag {
                kind: CodecKind::Huffman,
                symbols,
                runtime_book: true,
            };
            let parts =
                segment_transfer_tagged(NodeId(1), NodeId(9), bits, 3, MAX_PACKET_BITS, tag);
            assert_eq!(parts.iter().map(|p| p.size_bits).sum::<u64>(), bits);
            assert_eq!(
                parts
                    .iter()
                    .map(|p| p.codec.expect("tagged").symbols)
                    .sum::<u64>(),
                symbols
            );
            // Every packet's tag is individually schedulable (symbols ≤
            // wire bits) and startup rides the first packet only.
            for (i, p) in parts.iter().enumerate() {
                let t = p.codec.expect("tagged");
                assert!(t.symbols <= p.size_bits, "packet {i} over-tagged");
                assert_eq!(t.runtime_book, i == 0);
                assert_eq!(t.kind, CodecKind::Huffman);
            }
        });
    }

    #[test]
    fn transfer_flits_matches_segmented_specs() {
        // The closed form must equal what the cycle sim actually pays.
        check("transfer_flits == Σ spec.flits", 200, |g| {
            let bits = g.u64(0..5_000_000);
            let from_specs: u64 = segment_transfer(NodeId(0), NodeId(1), bits, 0, MAX_PACKET_BITS)
                .iter()
                .map(|s| s.flits(128) as u64)
                .sum();
            assert_eq!(transfer_flits(bits, 128, MAX_PACKET_BITS), from_specs);
        });
        assert_eq!(transfer_flits(0, 128, MAX_PACKET_BITS), 0);
        // Per-packet quantization charges more than the fractional bits.
        let bits = MAX_PACKET_BITS + 1;
        assert_eq!(
            transfer_flits(bits, 128, MAX_PACKET_BITS),
            MAX_PACKET_BITS / 128 + 1
        );
    }

    #[test]
    fn tag_packets_caps_symbols() {
        let mut specs = vec![PacketSpec::new(NodeId(0), NodeId(1), 100, 0)];
        tag_packets(&mut specs, CodecKind::Bdi, 0.5, false);
        let t = specs[0].codec.unwrap();
        assert_eq!(t.symbols, 100, "symbols must cap at wire bits");
        assert_eq!(t.kind, CodecKind::Bdi);
    }

    #[test]
    fn transpose_delivers_everywhere() {
        let mesh = Mesh::new(4, 4);
        let specs = transpose(mesh, 128 * 4);
        let mut net = Network::new(NetworkConfig::for_topo(Topo::Mesh(mesh)));
        let n = specs.len() as u64;
        net.schedule_packets(&specs);
        let stats = net.run_to_completion(100_000);
        assert_eq!(stats.delivered_packets, n);
    }

    #[test]
    fn prop_random_traffic_all_delivered() {
        check("uniform random delivered", 10, |g| {
            let topo = Topo::Mesh(Mesh::new(4, 4));
            let count = g.usize(1..120);
            let specs = uniform_random(topo, count, 128 * 2, 0.5, g.rng());
            let mut net = Network::new(NetworkConfig::for_topo(topo));
            net.schedule_packets(&specs);
            let stats = net.run_to_completion(1_000_000);
            assert_eq!(stats.delivered_packets, count as u64);
        });
    }
}
