//! # lexi-noc — cycle-level network-on-interposer simulator
//!
//! The paper models inter-chiplet transfers with a modified cycle-accurate
//! HeteroGarnet (gem5). That simulator is not available offline, so this
//! crate provides the same abstraction level from scratch:
//!
//! * [`topology`] — the [`topology::Topology`] trait with flat 2D mesh,
//!   concentrated mesh (several endpoints per router), and multi-package
//!   stitched meshes (ISSUE 10), all under the `Copy` enum
//!   [`topology::Topo`]; XY / gateway-directed baseline routing.
//! * [`packet`] — packets and flits (head/body/tail framing).
//! * [`vc`] — per-virtual-channel router state (ISSUE 10): per-VC input
//!   FIFOs and output lanes with the `buf_depth` credit budget
//!   partitioned across VCs.
//! * [`input_control`] — route computation + VC allocation: VC 0 is the
//!   deadlock-free up*/down* escape channel, VCs ≥ 1 route adaptively
//!   with escape fallback; `vcs = 1` reproduces the legacy router.
//! * [`output_control`] — switch allocation (flat round-robin over
//!   input port × input VC, iSLIP-lite one-grant-per-input) and
//!   wormhole lock bookkeeping.
//! * [`router`] — the legacy single-VC wormhole router, kept as the
//!   executable reference the `vcs = 1` stat-identity test pins.
//! * [`network`] — the cycle loop: inject → route/forward → eject, with
//!   per-packet latency, per-link utilization, and per-VC statistics.
//! * [`traffic`] — synthetic patterns (uniform, transpose, hotspot) for
//!   validation plus trace-driven injection for the chiplet system model.
//! * [`egress`] — per-node egress codec ports (ISSUE 5): codec-tagged
//!   packets drain through the measured multi-lane LUT decoder rate with
//!   startup stalls and backpressure, instead of the codec-blind
//!   1 flit/cycle ejection.
//! * [`fault`] — deterministic seeded link-fault injection (ISSUE 6):
//!   BER-driven flit corruption, drops, and duplicates at link
//!   traversal, with NACK-at-egress retransmission (bounded
//!   [`fault::RETRY_BUDGET`], exponential backoff) handled by
//!   [`network::Network`] and charged to packet latency; plus scheduled
//!   **permanent link failures** (ISSUE 7) recovered by wormhole
//!   truncation + retry and escape rerouting.
//! * [`ingress`] — per-node ingress codec ports (ISSUE 7): injection is
//!   paced by the encoder occupancy model with compressor startup on
//!   runtime-Huffman heads, and the NI queue is bounded — saturation is
//!   a typed refusal, never silent queue growth.
//! * [`reroute`] — deadlock-safe up*/down* escape routing tables used
//!   when permanent link failures break XY; typed unreachability when a
//!   destination is severed.
//!
//! A [`network::Network`] step loop can no longer hang (ISSUE 7): a
//! watchdog detects zero-progress cycles, audits per-VC credit
//! conservation, flags starved virtual channels
//! ([`network::StallCause::VcStarvation`]), and terminates with a typed
//! [`network::StallReport`].
//!
//! Links are parameterized in Gbps; with the paper's 100 Gbps NoI links
//! and 128-bit flits, one network cycle is 1.28 ns.

pub mod egress;
pub mod fault;
pub mod ingress;
pub mod input_control;
pub mod network;
pub mod output_control;
pub mod packet;
pub mod reroute;
pub mod router;
pub mod topology;
pub mod traffic;
pub mod vc;

pub use egress::{EgressCodecConfig, EgressPort};
pub use fault::{FaultModel, LinkDown, RetryConfig};
pub use ingress::{IngressCodecConfig, IngressPort};
pub use input_control::RouteCtx;
pub use network::{
    CreditViolation, Network, NetworkConfig, SimStats, StallCause, StallReport, StuckPacket,
    VcUsage, DEFAULT_WATCHDOG_CYCLES,
};
pub use output_control::Grant;
pub use packet::{CodecTag, Flit, FlitKind, PacketRecord, PacketSpec};
pub use reroute::EscapeRoutes;
pub use topology::{CMesh, Mesh, MultiPackage, NodeId, Port, Topo, Topology};
pub use vc::{credit_share, VcRouter, MAX_VCS};
