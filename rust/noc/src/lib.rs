//! # lexi-noc — cycle-level 2D-mesh network-on-interposer simulator
//!
//! The paper models inter-chiplet transfers with a modified cycle-accurate
//! HeteroGarnet (gem5). That simulator is not available offline, so this
//! crate provides the same abstraction level from scratch:
//!
//! * [`topology`] — 2D mesh coordinates and dimension-ordered (XY) routing.
//! * [`packet`] — packets and flits (head/body/tail framing).
//! * [`router`] — 5-port wormhole routers with credit-based flow control
//!   and round-robin output arbitration.
//! * [`network`] — the cycle loop: inject → route/forward → eject, with
//!   per-packet latency and per-link utilization statistics.
//! * [`traffic`] — synthetic patterns (uniform, transpose, hotspot) for
//!   validation plus trace-driven injection for the chiplet system model.
//! * [`egress`] — per-node egress codec ports (ISSUE 5): codec-tagged
//!   packets drain through the measured multi-lane LUT decoder rate with
//!   startup stalls and backpressure, instead of the codec-blind
//!   1 flit/cycle ejection.
//! * [`fault`] — deterministic seeded link-fault injection (ISSUE 6):
//!   BER-driven flit corruption, drops, and duplicates at link
//!   traversal, with NACK-at-egress retransmission (bounded
//!   [`fault::RETRY_BUDGET`], exponential backoff) handled by
//!   [`network::Network`] and charged to packet latency.
//!
//! Links are parameterized in Gbps; with the paper's 100 Gbps NoI links
//! and 128-bit flits, one network cycle is 1.28 ns.

pub mod egress;
pub mod fault;
pub mod network;
pub mod packet;
pub mod router;
pub mod topology;
pub mod traffic;

pub use egress::{EgressCodecConfig, EgressPort};
pub use fault::FaultModel;
pub use network::{Network, NetworkConfig, SimStats};
pub use packet::{CodecTag, Flit, FlitKind, PacketRecord, PacketSpec};
pub use topology::{Mesh, NodeId};
