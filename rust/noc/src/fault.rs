//! Deterministic link-fault injection (ISSUE 6).
//!
//! Real inter-chiplet links have non-zero bit-error rates; LEXI's
//! lossless contract must survive them. This module is the *injection*
//! half of the fault-tolerance story: a seeded [`FaultModel`] that, at
//! each link traversal, can
//!
//! * **corrupt** a flit (flip ≥ 1 payload bit, probability
//!   `1 − (1 − BER)^flit_bits` — the chance at least one of the flit's
//!   bits flips at the configured bit-error rate),
//! * **drop** it (the flit stays at the FIFO head and retries next
//!   cycle — link-level ARQ, so a wormhole body can never vanish from
//!   the middle of a packet), or
//! * **duplicate** it (one extra cycle of link occupancy; the receiver
//!   squashes the copy).
//!
//! Everything is driven by one [`lexi_core::prng::Rng`] stream, so a
//! `(seed, schedule)` pair replays bit-identically — the property the
//! `sim::xval` BER pins and the retry-accounting tests rely on.
//!
//! The *recovery* half (NACK at tail ejection, bounded retry with
//! exponential backoff, [`RETRY_BUDGET`]) lives in
//! [`crate::network::Network`]; the detection half (CRC-16) in
//! `lexi-core::integrity`.

use crate::topology::NodeId;
use lexi_core::prng::Rng;

/// One scheduled permanent link kill: the bidirectional link between
/// two adjacent nodes dies at the start of cycle `at` and never comes
/// back (ISSUE 7). Recovery is the network's job: severed wormholes are
/// truncated and NACK-retried, and routing switches to deadlock-safe
/// up*/down* escape tables around the failure.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LinkDown {
    pub a: NodeId,
    pub b: NodeId,
    pub at: u64,
}

/// Maximum retransmissions per packet before the NoC reports it
/// dropped. Four attempts at BER ≤ 1e-4 per flit puts the residual
/// undelivered probability below 1e-16 for paper-sized packets.
pub const RETRY_BUDGET: u32 = 4;

/// Retransmission backoff in cycles before retry attempt `attempt`
/// (1-based): exponential `8 · 2^(attempt−1)`, capped at 256 cycles so
/// budget exhaustion is reached in bounded sim time. Equivalent to
/// [`RetryConfig::paper_default`]`.backoff(attempt)` — the configurable
/// form ISSUE 9 added; this free function is the fixed paper point.
pub fn retry_backoff(attempt: u32) -> u64 {
    RetryConfig::paper_default().backoff(attempt)
}

/// Configurable NACK-retry policy (ISSUE 9 satellite): the budget and
/// exponential-backoff shape that were hard-wired as [`RETRY_BUDGET`] /
/// `8·2^(attempt−1)` capped at 256 since ISSUE 6. The default is
/// bit-identical to the old constants (pinned by test); the CLI exposes
/// `--retry-budget N --backoff-cap C`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RetryConfig {
    /// Maximum retransmissions per packet before the loss is reported
    /// as dropped (typed, never silent).
    pub budget: u32,
    /// Backoff before attempt 1, in cycles; doubles per attempt.
    pub backoff_base: u64,
    /// Backoff ceiling in cycles, so budget exhaustion stays bounded.
    pub backoff_cap: u64,
}

impl RetryConfig {
    /// The ISSUE 6 constants: budget 4, base 8, cap 256.
    pub fn paper_default() -> Self {
        RetryConfig {
            budget: RETRY_BUDGET,
            backoff_base: 8,
            backoff_cap: 256,
        }
    }

    /// Backoff in cycles before retry attempt `attempt` (1-based):
    /// `base · 2^(attempt−1)`, saturating, capped at `backoff_cap`.
    pub fn backoff(&self, attempt: u32) -> u64 {
        let shift = attempt.saturating_sub(1).min(32);
        self.backoff_base
            .saturating_mul(1u64 << shift)
            .min(self.backoff_cap)
    }

    /// Total backoff stall across a fully exhausted budget, in cycles —
    /// the worst-case quiet spell the watchdog window must tolerate and
    /// the deadline accounting charges a retried request.
    pub fn max_total_backoff(&self) -> u64 {
        (1..=self.budget).map(|a| self.backoff(a)).sum()
    }
}

impl Default for RetryConfig {
    fn default() -> Self {
        Self::paper_default()
    }
}

/// Seeded fault injector for NoC links.
#[derive(Clone, Debug)]
pub struct FaultModel {
    seed: u64,
    ber: f64,
    drop_prob: f64,
    dup_prob: f64,
    link_downs: Vec<LinkDown>,
    retry: RetryConfig,
    rng: Rng,
}

impl FaultModel {
    /// A fault model with every fault probability at zero (attachable
    /// but inert — the zero-BER hot path the perf gate pins).
    pub fn new(seed: u64) -> Self {
        FaultModel {
            seed,
            ber: 0.0,
            drop_prob: 0.0,
            dup_prob: 0.0,
            link_downs: Vec::new(),
            retry: RetryConfig::paper_default(),
            rng: Rng::new(seed),
        }
    }

    /// Set the per-bit error rate (clamped to `0.0..=1.0`).
    pub fn with_ber(mut self, ber: f64) -> Self {
        self.ber = ber.clamp(0.0, 1.0);
        self
    }

    /// Set the per-traversal flit drop probability.
    pub fn with_drop(mut self, p: f64) -> Self {
        self.drop_prob = p.clamp(0.0, 1.0);
        self
    }

    /// Set the per-traversal flit duplication probability.
    pub fn with_dup(mut self, p: f64) -> Self {
        self.dup_prob = p.clamp(0.0, 1.0);
        self
    }

    /// Schedule a permanent kill of the `a`↔`b` link at cycle `at`
    /// (both directions; the pair must be mesh-adjacent — the network
    /// validates on attach).
    pub fn with_link_down(mut self, a: NodeId, b: NodeId, at: u64) -> Self {
        self.link_downs.push(LinkDown { a, b, at });
        self.link_downs.sort_by_key(|e| e.at);
        self
    }

    /// Override the NACK-retry budget/backoff this model's network
    /// should honour (ISSUE 9). The default is the ISSUE 6 paper point.
    pub fn with_retry(mut self, retry: RetryConfig) -> Self {
        self.retry = retry;
        self
    }

    /// The retry policy packets under this model travel with. The
    /// network copies it on [`set_fault_model`](crate::Network::set_fault_model).
    pub fn retry(&self) -> RetryConfig {
        self.retry
    }

    /// Scheduled permanent link failures, ascending by cycle.
    pub fn link_downs(&self) -> &[LinkDown] {
        &self.link_downs
    }

    /// The seed this model replays from.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Configured bit-error rate.
    pub fn ber(&self) -> f64 {
        self.ber
    }

    /// Configured per-traversal drop probability (the stall-cause
    /// diagnosis reads this: `drop_prob == 1` is a dead link in
    /// transient clothing).
    pub fn drop_prob(&self) -> f64 {
        self.drop_prob
    }

    /// True if any *transient* fault probability is non-zero. The
    /// network checks this once per step, so an attached-but-inert
    /// model costs one branch per cycle, not one per flit. (Permanent
    /// link-downs are not gated on this: they apply on schedule even
    /// from an otherwise-inert model.)
    pub fn enabled(&self) -> bool {
        self.ber > 0.0 || self.drop_prob > 0.0 || self.dup_prob > 0.0
    }

    /// Does this link traversal corrupt a `flit_bits`-wide flit?
    /// P = 1 − (1 − BER)^flit_bits.
    pub fn corrupts(&mut self, flit_bits: u32) -> bool {
        if self.ber <= 0.0 {
            return false;
        }
        let p = 1.0 - (1.0 - self.ber).powi(flit_bits as i32);
        self.rng.chance(p)
    }

    /// Does this link traversal drop the flit (forcing a 1-cycle
    /// link-level retry)?
    pub fn drops(&mut self) -> bool {
        self.drop_prob > 0.0 && self.rng.chance(self.drop_prob)
    }

    /// Does this link traversal emit a duplicate (one extra cycle of
    /// occupancy downstream)?
    pub fn duplicates(&mut self) -> bool {
        self.dup_prob > 0.0 && self.rng.chance(self.dup_prob)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_replays_identically() {
        let mut a = FaultModel::new(42).with_ber(1e-3).with_drop(0.01).with_dup(0.005);
        let mut b = FaultModel::new(42).with_ber(1e-3).with_drop(0.01).with_dup(0.005);
        for _ in 0..10_000 {
            assert_eq!(a.corrupts(128), b.corrupts(128));
            assert_eq!(a.drops(), b.drops());
            assert_eq!(a.duplicates(), b.duplicates());
        }
    }

    #[test]
    fn zero_rates_never_fire_and_report_disabled() {
        let mut f = FaultModel::new(7);
        assert!(!f.enabled());
        for _ in 0..1000 {
            assert!(!f.corrupts(128));
            assert!(!f.drops());
            assert!(!f.duplicates());
        }
        assert!(FaultModel::new(7).with_ber(1e-9).enabled());
        assert!(FaultModel::new(7).with_drop(0.1).enabled());
        assert!(FaultModel::new(7).with_dup(0.1).enabled());
    }

    #[test]
    fn corruption_rate_tracks_flit_width() {
        // P = 1 − (1−ber)^bits grows with flit width; at ber=1e-4 and
        // 128-bit flits, P ≈ 1.27% — check the empirical rate lands in
        // a loose band, and that 256-bit flits roughly double it.
        let trials = 200_000u32;
        let rate = |bits: u32, seed: u64| {
            let mut f = FaultModel::new(seed).with_ber(1e-4);
            (0..trials).filter(|_| f.corrupts(bits)).count() as f64 / trials as f64
        };
        let r128 = rate(128, 1);
        let r256 = rate(256, 2);
        assert!((0.010..0.016).contains(&r128), "128-bit rate {r128}");
        assert!((1.7..2.3).contains(&(r256 / r128)), "width scaling {}", r256 / r128);
    }

    #[test]
    fn link_downs_sort_by_cycle_and_leave_model_inert() {
        let f = FaultModel::new(9)
            .with_link_down(NodeId(3), NodeId(4), 500)
            .with_link_down(NodeId(0), NodeId(1), 100);
        assert_eq!(f.link_downs().len(), 2);
        assert_eq!(f.link_downs()[0].at, 100);
        assert_eq!(f.link_downs()[1].at, 500);
        // Permanent failures alone don't arm the per-flit transient
        // path (zero-overhead healthy stepping stays intact).
        assert!(!f.enabled());
    }

    #[test]
    fn retry_config_default_is_bit_identical_to_the_issue6_constants() {
        // ISSUE 9 satellite pin: making the budget/backoff configurable
        // must not move the default by one cycle.
        let cfg = RetryConfig::paper_default();
        assert_eq!(cfg.budget, RETRY_BUDGET);
        for attempt in (0..64).chain([u32::MAX - 1, u32::MAX]) {
            assert_eq!(
                cfg.backoff(attempt),
                (8u64 << attempt.saturating_sub(1).min(32)).min(256),
                "attempt {attempt}"
            );
            assert_eq!(cfg.backoff(attempt), retry_backoff(attempt));
        }
        assert_eq!(cfg.max_total_backoff(), 8 + 16 + 32 + 64);
        assert_eq!(FaultModel::new(1).retry(), cfg);
    }

    #[test]
    fn retry_config_override_shapes_budget_and_cap() {
        let cfg = RetryConfig {
            budget: 2,
            backoff_base: 4,
            backoff_cap: 10,
        };
        assert_eq!(cfg.backoff(1), 4);
        assert_eq!(cfg.backoff(2), 8);
        assert_eq!(cfg.backoff(3), 10); // capped
        assert_eq!(cfg.backoff(u32::MAX), 10); // saturating, no overflow
        assert_eq!(cfg.max_total_backoff(), 4 + 8);
        let f = FaultModel::new(5).with_retry(cfg);
        assert_eq!(f.retry(), cfg);
        // Retry policy alone never arms the per-flit transient path.
        assert!(!f.enabled());
    }

    #[test]
    fn backoff_is_exponential_and_capped() {
        assert_eq!(retry_backoff(1), 8);
        assert_eq!(retry_backoff(2), 16);
        assert_eq!(retry_backoff(3), 32);
        assert_eq!(retry_backoff(4), 64);
        assert_eq!(retry_backoff(7), 256); // cap
        assert_eq!(retry_backoff(u32::MAX), 256); // no shift overflow
        // Total stall across a full budget is bounded and small relative
        // to sim horizons.
        let total: u64 = (1..=RETRY_BUDGET).map(retry_backoff).sum();
        assert_eq!(total, 8 + 16 + 32 + 64);
    }
}
