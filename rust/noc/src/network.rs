//! The assembled mesh network and its cycle loop.
//!
//! Injection → wormhole forwarding → ejection, with credit-based flow
//! control and XY routing. Flits are generated lazily at the network
//! interface (a multi-megabyte transfer does not materialize millions of
//! flit structs up front), and `ready_at` stamping guarantees one hop per
//! cycle regardless of router iteration order.

use crate::packet::{Flit, FlitKind, PacketRecord, PacketSpec};
use crate::router::Router;
use crate::topology::{Mesh, NodeId, Port, NUM_PORTS};
use std::collections::VecDeque;

/// Network configuration.
#[derive(Clone, Copy, Debug)]
pub struct NetworkConfig {
    pub mesh: Mesh,
    /// Flit width in bits (paper setup: 128-bit flits).
    pub flit_bits: u32,
    /// Raw link bandwidth in Gbps (paper: 100 Gbps NoI links).
    pub link_gbps: f64,
    /// Input-buffer depth per router port, in flits.
    pub buf_depth: u32,
}

impl NetworkConfig {
    /// The paper's NoI operating point on a 6×6 mesh.
    pub fn paper_default() -> Self {
        NetworkConfig {
            mesh: Mesh::simba_6x6(),
            flit_bits: 128,
            link_gbps: 100.0,
            buf_depth: 4,
        }
    }

    /// Wall-clock duration of one network cycle in ns (one flit per link
    /// per cycle ⇒ cycle = flit_bits / link rate).
    pub fn cycle_ns(&self) -> f64 {
        self.flit_bits as f64 / self.link_gbps
    }
}

/// A packet queued at a network interface, flits emitted lazily.
#[derive(Clone, Copy, Debug)]
struct Pending {
    id: u64,
    spec: PacketSpec,
    total_flits: u32,
    emitted: u32,
}

/// Aggregate simulation statistics.
#[derive(Clone, Debug, Default)]
pub struct SimStats {
    pub delivered_packets: u64,
    pub delivered_flits: u64,
    pub flit_hops: u64,
    pub cycles: u64,
    pub sum_latency: u64,
    pub max_latency: u64,
}

impl SimStats {
    /// Mean packet latency in cycles.
    pub fn avg_latency(&self) -> f64 {
        if self.delivered_packets == 0 {
            0.0
        } else {
            self.sum_latency as f64 / self.delivered_packets as f64
        }
    }

    /// Network-wide average link utilization given the link count.
    pub fn link_utilization(&self, links: u64) -> f64 {
        if self.cycles == 0 || links == 0 {
            0.0
        } else {
            self.flit_hops as f64 / (links * self.cycles) as f64
        }
    }
}

/// The simulator.
pub struct Network {
    pub cfg: NetworkConfig,
    routers: Vec<Router>,
    /// Per-node: packets not yet fully injected, FIFO.
    ni_queues: Vec<VecDeque<Pending>>,
    /// Packets scheduled for the future, sorted descending by inject_at
    /// (pop from the back).
    schedule: Vec<PacketSpec>,
    /// Per-packet bookkeeping (id → (spec, total)).
    meta: std::collections::HashMap<u64, (PacketSpec, u32)>,
    /// Completion records.
    pub records: Vec<PacketRecord>,
    now: u64,
    next_id: u64,
    stats: SimStats,
}

impl Network {
    /// Build an idle network.
    pub fn new(cfg: NetworkConfig) -> Self {
        let n = cfg.mesh.len();
        Network {
            cfg,
            routers: (0..n).map(|_| Router::new(cfg.buf_depth)).collect(),
            ni_queues: vec![VecDeque::new(); n],
            schedule: Vec::new(),
            meta: std::collections::HashMap::new(),
            records: Vec::new(),
            now: 0,
            next_id: 0,
            stats: SimStats::default(),
        }
    }

    /// Schedule a set of packets (any order).
    pub fn schedule_packets(&mut self, specs: &[PacketSpec]) {
        self.schedule.extend_from_slice(specs);
        // Descending by inject time so due packets pop O(1) from the back.
        self.schedule
            .sort_by_key(|s| std::cmp::Reverse(s.inject_at));
    }

    /// Current cycle.
    pub fn now(&self) -> u64 {
        self.now
    }

    /// Are all queues, buffers and schedules empty?
    ///
    /// O(1): every activated packet holds a `meta` entry until its tail
    /// ejects, so outstanding work ⇔ `schedule` or `meta` non-empty. The
    /// exhaustive buffer walk survives as a debug assertion.
    pub fn drained(&self) -> bool {
        let done = self.schedule.is_empty() && self.meta.is_empty();
        debug_assert!(
            !done
                || (self.ni_queues.iter().all(|q| q.is_empty())
                    && self
                        .routers
                        .iter()
                        .all(|r| r.inputs.iter().all(|b| b.fifo.is_empty()))),
            "meta empty but flits still buffered"
        );
        done
    }

    /// Advance one cycle.
    pub fn step(&mut self) {
        let mesh = self.cfg.mesh;

        // --- 1. activation of scheduled packets --------------------------
        while let Some(last) = self.schedule.last() {
            if last.inject_at > self.now {
                break;
            }
            let spec = self.schedule.pop().expect("non-empty");
            let id = self.next_id;
            self.next_id += 1;
            let total = spec.flits(self.cfg.flit_bits);
            self.meta.insert(id, (spec, total));
            self.ni_queues[spec.src.0 as usize].push_back(Pending {
                id,
                spec,
                total_flits: total,
                emitted: 0,
            });
        }

        // --- 2. injection: one flit per node per cycle --------------------
        for (node, q) in self.ni_queues.iter_mut().enumerate() {
            if let Some(p) = q.front_mut() {
                let local_in = &mut self.routers[node].inputs[Port::Local as usize];
                if (local_in.fifo.len() as u32) < self.cfg.buf_depth {
                    let seq = p.emitted;
                    let kind = match (seq, p.total_flits) {
                        (0, 1) => FlitKind::Single,
                        (0, _) => FlitKind::Head,
                        (s, t) if s + 1 == t => FlitKind::Tail,
                        _ => FlitKind::Body,
                    };
                    local_in.fifo.push_back(Flit {
                        packet_id: p.id,
                        kind,
                        src: p.spec.src,
                        dest: p.spec.dest,
                        seq,
                        ready_at: self.now + 1,
                    });
                    p.emitted += 1;
                    if p.emitted == p.total_flits {
                        q.pop_front();
                    }
                }
            }
        }

        // --- 3. forwarding / ejection -------------------------------------
        for node in 0..self.routers.len() {
            // §Perf: idle routers (all input FIFOs empty) skip arbitration
            // entirely — a large win under sparse/hotspot traffic.
            if self.routers[node].inputs.iter().all(|b| b.fifo.is_empty()) {
                continue;
            }
            let at = NodeId(node as u16);
            let grants =
                self.routers[node].arbitrate_all(self.now, |f| mesh.route_xy(at, f.dest));
            for &out in &Port::ALL {
                let Some(inp) = grants[out as usize] else { continue };

                if out == Port::Local {
                    // Ejection: always accepted, one flit/cycle.
                    let flit = self.routers[node].inputs[inp]
                        .fifo
                        .pop_front()
                        .expect("arbitrated input non-empty");
                    self.credit_return(at, inp);
                    self.update_lock(node, out, inp, &flit);
                    self.stats.delivered_flits += 1;
                    if flit.is_tail() {
                        let (spec, total) = self.meta.remove(&flit.packet_id).expect("meta");
                        let rec = PacketRecord {
                            spec,
                            inject_cycle: spec.inject_at,
                            eject_cycle: self.now + 1,
                            flits: total,
                        };
                        self.stats.delivered_packets += 1;
                        self.stats.sum_latency += rec.latency();
                        self.stats.max_latency = self.stats.max_latency.max(rec.latency());
                        self.records.push(rec);
                    }
                    continue;
                }

                // Link traversal: need a credit downstream.
                if self.routers[node].outputs[out as usize].credits == 0 {
                    continue;
                }
                let Some(nb) = mesh.neighbour(at, out) else {
                    unreachable!("XY routing never exits the mesh");
                };
                let mut flit = self.routers[node].inputs[inp]
                    .fifo
                    .pop_front()
                    .expect("arbitrated input non-empty");
                self.credit_return(at, inp);
                self.update_lock(node, out, inp, &flit);
                self.routers[node].outputs[out as usize].credits -= 1;
                self.routers[node].outputs[out as usize].forwarded += 1;
                self.stats.flit_hops += 1;
                flit.ready_at = self.now + 1;
                self.routers[nb.0 as usize].inputs[out.opposite() as usize]
                    .fifo
                    .push_back(flit);
            }
        }

        self.now += 1;
        self.stats.cycles = self.now;
    }

    /// Run until every scheduled packet is delivered (or `max_cycles`).
    /// Returns stats; panics if the network failed to drain in time.
    pub fn run_to_completion(&mut self, max_cycles: u64) -> SimStats {
        while !self.drained() {
            assert!(
                self.now < max_cycles,
                "network failed to drain within {max_cycles} cycles \
                 ({} packets outstanding)",
                self.meta.len()
            );
            self.step();
        }
        self.stats.clone()
    }

    /// Stats so far.
    pub fn stats(&self) -> &SimStats {
        &self.stats
    }

    /// Total directed links in the mesh (for utilization).
    pub fn link_count(&self) -> u64 {
        let (c, r) = (self.cfg.mesh.cols as u64, self.cfg.mesh.rows as u64);
        2 * (r * (c - 1) + c * (r - 1))
    }

    /// A flit left `inp` of router `at`: return one credit upstream.
    fn credit_return(&mut self, at: NodeId, inp: usize) {
        if inp == Port::Local as usize {
            return; // NI injection checks occupancy directly.
        }
        let in_port = Port::ALL[inp];
        // The upstream neighbour sits in the direction of the input port
        // and fed us through its opposite output.
        if let Some(up) = self.cfg.mesh.neighbour(at, in_port) {
            let up_out = in_port.opposite() as usize;
            self.routers[up.0 as usize].outputs[up_out].credits += 1;
        }
    }

    /// Wormhole lock bookkeeping after forwarding `flit` inp→out.
    fn update_lock(&mut self, node: usize, out: Port, inp: usize, flit: &Flit) {
        let o = &mut self.routers[node].outputs[out as usize];
        if flit.is_tail() {
            o.locked_to = None;
            o.rr = (inp + 1) % NUM_PORTS;
        } else {
            o.locked_to = Some(inp);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg_4x4() -> NetworkConfig {
        NetworkConfig {
            mesh: Mesh::new(4, 4),
            flit_bits: 128,
            link_gbps: 100.0,
            buf_depth: 4,
        }
    }

    #[test]
    fn single_packet_minimal_latency() {
        let cfg = cfg_4x4();
        let mut net = Network::new(cfg);
        let spec = PacketSpec {
            src: NodeId(0),
            dest: NodeId(3), // 3 hops east
            size_bits: 128 * 4,
            inject_at: 0,
        };
        net.schedule_packets(&[spec]);
        let stats = net.run_to_completion(1000);
        assert_eq!(stats.delivered_packets, 1);
        let rec = net.records[0];
        // Lower bound: injection (1) + hops (3) + serialization (3 more
        // flits) + ejection; exact value depends on the pipeline model —
        // assert a tight band, not an exact constant.
        let lb = 3 + 4 - 1;
        assert!(
            (lb..lb + 8).contains(&rec.latency()),
            "latency {}",
            rec.latency()
        );
    }

    #[test]
    fn self_send_delivers() {
        let mut net = Network::new(cfg_4x4());
        net.schedule_packets(&[PacketSpec {
            src: NodeId(5),
            dest: NodeId(5),
            size_bits: 64,
            inject_at: 0,
        }]);
        let stats = net.run_to_completion(100);
        assert_eq!(stats.delivered_packets, 1);
    }

    #[test]
    fn all_packets_delivered_under_load() {
        let mut net = Network::new(cfg_4x4());
        let mut specs = Vec::new();
        for i in 0..16u16 {
            for j in 0..16u16 {
                if i != j {
                    specs.push(PacketSpec {
                        src: NodeId(i),
                        dest: NodeId(j),
                        size_bits: 128 * 3,
                        inject_at: (i as u64) * 2,
                    });
                }
            }
        }
        let n = specs.len() as u64;
        let mut net2 = Network::new(cfg_4x4());
        net2.schedule_packets(&specs);
        let stats = net2.run_to_completion(100_000);
        assert_eq!(stats.delivered_packets, n);
        assert_eq!(stats.delivered_flits, n * 3);
        let _ = &mut net;
    }

    #[test]
    fn wormhole_packets_arrive_contiguously() {
        // With wormhole switching + XY routing, a destination receives each
        // packet's flits in order (seq strictly increasing per packet).
        let mut net = Network::new(cfg_4x4());
        let specs: Vec<PacketSpec> = (0..8u16)
            .map(|i| PacketSpec {
                src: NodeId(i),
                dest: NodeId(15),
                size_bits: 128 * 8,
                inject_at: 0,
            })
            .collect();
        net.schedule_packets(&specs);
        net.run_to_completion(10_000);
        assert_eq!(net.records.len(), 8);
    }

    #[test]
    fn congestion_raises_latency() {
        // Hotspot: everyone sends to node 0 — latency must exceed the
        // uncongested single-sender case.
        let solo = {
            let mut net = Network::new(cfg_4x4());
            net.schedule_packets(&[PacketSpec {
                src: NodeId(15),
                dest: NodeId(0),
                size_bits: 128 * 16,
                inject_at: 0,
            }]);
            net.run_to_completion(10_000).avg_latency()
        };
        let hot = {
            let mut net = Network::new(cfg_4x4());
            let specs: Vec<PacketSpec> = (1..16u16)
                .map(|i| PacketSpec {
                    src: NodeId(i),
                    dest: NodeId(0),
                    size_bits: 128 * 16,
                    inject_at: 0,
                })
                .collect();
            net.schedule_packets(&specs);
            net.run_to_completion(100_000).avg_latency()
        };
        assert!(hot > solo * 2.0, "solo {solo} hot {hot}");
    }

    #[test]
    fn throughput_bounded_by_bisection() {
        // Uniform random cannot exceed ~1 flit/cycle/link utilization.
        let mut net = Network::new(cfg_4x4());
        let mut specs = Vec::new();
        for k in 0..400u64 {
            specs.push(PacketSpec {
                src: NodeId((k * 7 % 16) as u16),
                dest: NodeId((k * 11 % 16) as u16),
                size_bits: 128 * 4,
                inject_at: k / 8,
            });
        }
        let specs: Vec<_> = specs
            .into_iter()
            .filter(|s| s.src != s.dest)
            .collect();
        let links = {
            let n = Network::new(cfg_4x4());
            n.link_count()
        };
        net.schedule_packets(&specs);
        let stats = net.run_to_completion(1_000_000);
        assert!(stats.link_utilization(links) <= 1.0);
    }

    #[test]
    fn cycle_ns_matches_paper_link() {
        let cfg = NetworkConfig::paper_default();
        assert!((cfg.cycle_ns() - 1.28).abs() < 1e-9);
    }
}
