//! The assembled network and its cycle loop (ISSUE 10: the stepping
//! scaffold over the VC-aware input/output control split).
//!
//! The router logic that used to live in this monolith is now layered:
//!
//! * [`crate::vc`] — per-VC input FIFOs, output lanes (wormhole locks +
//!   credit counters per VC), and the [`credit_share`] partition of each
//!   link's `buf_depth` across VCs.
//! * [`crate::input_control`] — route computation + VC allocation per
//!   input VC: VC 0 is the always-on deadlock-free up*/down* escape
//!   channel, VCs ≥ 1 route adaptively with escape fallback, and
//!   `vcs = 1` reproduces the legacy XY / all-or-nothing-escape router.
//! * [`crate::output_control`] — switch allocation (flat round-robin
//!   over input-port × input-VC, one grant per physical output and per
//!   physical input) and wormhole lock bookkeeping.
//! * `watchdog` (a `#[path]` child module of this one, so it keeps
//!   access to the private simulator state) — the stall/deadlock
//!   diagnosis layer: [`VcUsage`] snapshots, the per-VC credit audit,
//!   starvation detection, and [`StallReport`] assembly.
//!
//! `Network` composes those with everything this file always owned:
//! injection → forwarding → ejection ordering, lazy flit emission at
//! the NIs, egress/ingress codec ports (ISSUE 5/7), seeded link faults
//! with NACK retransmission (ISSUE 6), permanent link failures with
//! truncation + escape recovery (ISSUE 7), and the stall/deadlock
//! watchdog — now with a per-VC credit audit and a
//! [`StallCause::VcStarvation`] verdict.
//!
//! **Topologies (ISSUE 10):** the network is built over a
//! [`Topo`] — flat mesh, concentrated mesh (several endpoints per
//! router), or multi-package stitched meshes (gateway-row links between
//! packages). Multi-package routing is not XY-safe across the stitch,
//! so those networks install the escape tables from construction even
//! at `vcs = 1`.
//!
//! **Stat identity:** with `vcs = 1` on a mesh, every discipline below
//! collapses to the pre-refactor single-VC router field-for-field —
//! grants regardless of credits (declined at traversal), the same
//! round-robin order, the same fault-draw order — which the
//! `vc1_equivalence` differential test pins against a reimplementation
//! of the legacy step loop.

use crate::egress::{self, EgressCodecConfig, EgressPort};
use crate::fault::{FaultModel, LinkDown, RetryConfig};
use crate::ingress::{IngressCodecConfig, IngressPort};
use crate::input_control::RouteCtx;
use crate::output_control::{self, Grant};
use crate::packet::{Flit, FlitKind, PacketRecord, PacketSpec};
use crate::reroute::{EscapeRoutes, LinkState};
use crate::topology::{Mesh, NodeId, Port, Topo, Topology, NUM_PORTS};
use crate::vc::{credit_share, VcRouter, MAX_VCS};
use lexi_core::error::{Error, Result};
use std::collections::VecDeque;

/// Network configuration.
#[derive(Clone, Copy, Debug)]
pub struct NetworkConfig {
    /// Topology the routers are wired in (ISSUE 10).
    pub topo: Topo,
    /// Virtual channels per link (ISSUE 10). `1` is the legacy
    /// single-VC router, stat-identical to the pre-VC implementation;
    /// ≥ 2 adds the always-on VC 0 escape channel plus adaptive VCs.
    pub vcs: u8,
    /// Flit width in bits (paper setup: 128-bit flits).
    pub flit_bits: u32,
    /// Raw link bandwidth in Gbps (paper: 100 Gbps NoI links).
    pub link_gbps: f64,
    /// Input-buffer depth per router port, in flits — partitioned
    /// across VCs by [`credit_share`].
    pub buf_depth: u32,
}

impl NetworkConfig {
    /// The paper's NoI operating point on a 6×6 mesh.
    pub fn paper_default() -> Self {
        Self::for_topo(Topo::Mesh(Mesh::simba_6x6()))
    }

    /// The paper operating point (128-bit flits, 100 Gbps links,
    /// 4-deep buffers, single VC) on an arbitrary topology.
    pub fn for_topo(topo: Topo) -> Self {
        NetworkConfig {
            topo,
            vcs: 1,
            flit_bits: 128,
            link_gbps: 100.0,
            buf_depth: 4,
        }
    }

    /// The same configuration with `vcs` virtual channels.
    pub fn with_vcs(mut self, vcs: u8) -> Self {
        self.vcs = vcs;
        self
    }

    /// Wall-clock duration of one network cycle in ns (one flit per link
    /// per cycle ⇒ cycle = flit_bits / link rate).
    pub fn cycle_ns(&self) -> f64 {
        self.flit_bits as f64 / self.link_gbps
    }
}

/// A packet queued at a network interface, flits emitted lazily.
#[derive(Clone, Copy, Debug)]
struct Pending {
    id: u64,
    spec: PacketSpec,
    total_flits: u32,
    emitted: u32,
    /// Injection VC (ISSUE 10): `spec.vc` clamped to the network, or
    /// the default policy — VC 0 single-VC, adaptive spread otherwise.
    vc: u8,
}

/// Per-packet bookkeeping from activation to tail ejection.
#[derive(Clone, Copy, Debug)]
struct PacketMeta {
    spec: PacketSpec,
    total_flits: u32,
    /// Cycle the head flit actually entered the network (`None` while
    /// still queued at the NI) — the latency clock starts here, not at
    /// the scheduled `spec.inject_at` (that gap is queueing delay).
    head_inject: Option<u64>,
    /// Ejection cycles spent blocked behind the egress decoder.
    decode_stalls: u64,
    /// Injection cycles spent blocked behind the ingress encoder.
    encode_stalls: u64,
    /// A link fault flipped payload bits in one of this packet's flits;
    /// the egress CRC check will NACK the tail instead of recording
    /// delivery.
    corrupted: bool,
    /// How many retransmissions preceded this attempt (0 = original).
    attempt: u32,
    /// Head-injection cycle of the *original* attempt, carried across
    /// retransmissions so retry backoff + repeat trips land in latency.
    first_inject: Option<u64>,
}

/// A NACKed packet awaiting its retransmission slot.
#[derive(Clone, Copy, Debug)]
struct RetryEntry {
    spec: PacketSpec,
    /// Cycle at which the retransmission re-enters the NI queue.
    due: u64,
    /// 1-based retransmission attempt this entry represents.
    attempt: u32,
    /// Original head-injection cycle (see [`PacketMeta::first_inject`]).
    first_inject: u64,
}

/// Aggregate simulation statistics.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SimStats {
    pub delivered_packets: u64,
    pub delivered_flits: u64,
    /// Exponent symbols carried by delivered codec-tagged packets.
    pub delivered_symbols: u64,
    pub flit_hops: u64,
    pub cycles: u64,
    pub sum_latency: u64,
    pub max_latency: u64,
    /// Σ per-packet source-NI queueing (scheduled → actual head inject).
    pub sum_queueing: u64,
    /// Ejection cycles refused by backlogged egress decoders.
    pub decode_stall_cycles: u64,
    /// Injection cycles refused by backlogged ingress encoders
    /// (ISSUE 7): the NI had a flit ready but the encoder's `busy_until`
    /// horizon was over a cycle ahead.
    pub encode_stall_cycles: u64,
    /// Injection attempts refused because the bounded NI queue was full
    /// (scheduled-arrival deferrals + [`Network::try_inject`] refusals).
    pub injections_refused: u64,
    /// Cycle by which every delivered packet — including its egress
    /// decode tail — has completed. ≥ `cycles` when the decoder is still
    /// draining after the last tail ejects.
    pub completion_cycle: u64,
    /// Flits whose payload a link fault corrupted in transit (ISSUE 6).
    pub flits_corrupted: u64,
    /// Link traversals that ate the flit (retried next cycle at the
    /// FIFO head — link-level ARQ).
    pub flits_dropped: u64,
    /// Link traversals that emitted a squashed duplicate (one extra
    /// cycle of downstream occupancy).
    pub flits_duplicated: u64,
    /// Packet retransmissions scheduled after an egress-CRC NACK.
    pub packet_retries: u64,
    /// Packets abandoned after exhausting the [`RetryConfig`] budget
    /// of retransmissions — reported, never silently lost.
    pub packets_dropped: u64,
    /// Permanent link failures applied so far (ISSUE 7).
    pub links_down: u64,
    /// Wormholes truncated by a permanent link failure: in-flight flits
    /// discarded (credits returned), the packet NACK-retried under the
    /// retry budget or reported dropped/unreachable.
    pub packets_truncated: u64,
    /// Packets abandoned because no live route to their destination
    /// exists (component severed by link failures) — typed, never
    /// silent; the specs are kept in [`Network::unreachable_packets`].
    pub packets_unreachable: u64,
    /// Per-router fault events on outbound links (corrupt + drop +
    /// dup), indexed by router. Sized at construction; empty only for a
    /// default-constructed `SimStats`.
    pub link_faults: Vec<u64>,
}

impl SimStats {
    /// Mean packet latency in cycles.
    pub fn avg_latency(&self) -> f64 {
        if self.delivered_packets == 0 {
            0.0
        } else {
            self.sum_latency as f64 / self.delivered_packets as f64
        }
    }

    /// Mean source-NI queueing delay in cycles.
    pub fn avg_queueing(&self) -> f64 {
        if self.delivered_packets == 0 {
            0.0
        } else {
            self.sum_queueing as f64 / self.delivered_packets as f64
        }
    }

    /// Network-wide average link utilization given the link count.
    pub fn link_utilization(&self, links: u64) -> f64 {
        if self.cycles == 0 || links == 0 {
            0.0
        } else {
            self.flit_hops as f64 / (links * self.cycles) as f64
        }
    }
}

// The watchdog/diagnosis layer and the unit tests are child modules in
// sibling files (`#[path]`): they keep access to the private simulator
// state above without bloating this scaffold back into a monolith.
#[path = "watchdog.rs"]
mod watchdog;
pub use watchdog::{
    CreditViolation, StallCause, StallReport, StuckPacket, VcUsage,
    DEFAULT_WATCHDOG_CYCLES,
};

/// The simulator.
pub struct Network {
    pub cfg: NetworkConfig,
    /// One VC router per topology *router* (≠ endpoint on concentrated
    /// topologies).
    routers: Vec<VcRouter>,
    /// Per-endpoint: packets not yet fully injected, FIFO.
    ni_queues: Vec<VecDeque<Pending>>,
    /// Per-router round-robin over its concentrated endpoints: which
    /// NI gets the next injection slot (always 0 at concentration 1).
    ni_rr: Vec<u8>,
    /// Packets scheduled for the future, sorted descending by inject_at
    /// (pop from the back).
    schedule: Vec<PacketSpec>,
    /// Per-packet bookkeeping (id → meta).
    meta: std::collections::HashMap<u64, PacketMeta>,
    /// Egress decoder model; `None` = codec-blind 1-flit/cycle ejection.
    egress_cfg: Option<EgressCodecConfig>,
    /// Per-endpoint egress decoder state.
    egress: Vec<EgressPort>,
    /// Seeded link-fault injector; `None` = ideal lossless links.
    fault: Option<FaultModel>,
    /// NACKed packets waiting out their retransmission backoff.
    retry_queue: Vec<RetryEntry>,
    /// NACK-retry budget/backoff policy (ISSUE 9): defaults to the
    /// ISSUE 6 paper point; [`Network::set_fault_model`] adopts the
    /// attached model's policy, [`Network::set_retry_config`] overrides.
    retry: RetryConfig,
    /// Ingress encoder model; `None` = codec-blind unbounded-NI
    /// injection (ISSUE 7).
    ingress_cfg: Option<IngressCodecConfig>,
    /// Per-endpoint ingress encoder state.
    ingress: Vec<IngressPort>,
    /// Scheduled permanent link failures not yet applied (ascending).
    pending_link_downs: Vec<LinkDown>,
    /// `down[router][port]` = that directed output is permanently dead.
    down: LinkState,
    /// Escape routing tables. Installed from construction when
    /// `vcs > 1` (VC 0 escape channel) or the topology needs them for
    /// baseline deadlock freedom (multi-package); on a single-VC mesh
    /// they appear at the first link failure, exactly as before.
    escape: Option<EscapeRoutes>,
    /// Specs abandoned because their destination was severed.
    unreachable: Vec<PacketSpec>,
    /// Zero-progress window before the watchdog fires; `None` uses
    /// [`DEFAULT_WATCHDOG_CYCLES`].
    watchdog_cycles: Option<u64>,
    /// Cycle of the last observed global progress.
    last_progress: u64,
    /// Per-VC buffered-flit population (starvation watchdog, O(1) to
    /// maintain on each flit movement).
    vc_occ: Vec<u64>,
    /// Per-VC cycle of last movement.
    vc_progress: Vec<u64>,
    /// Per-VC link traversals (CLI report).
    vc_hops: Vec<u64>,
    /// Per-VC ejected flits (CLI report).
    vc_delivered: Vec<u64>,
    /// Completion records.
    pub records: Vec<PacketRecord>,
    now: u64,
    next_id: u64,
    stats: SimStats,
}

impl Network {
    /// Build an idle network with codec-blind ejection.
    pub fn new(cfg: NetworkConfig) -> Self {
        assert!(
            (1..=MAX_VCS).contains(&cfg.vcs),
            "vcs must be in 1..={MAX_VCS}"
        );
        assert!(
            cfg.buf_depth >= cfg.vcs as u32,
            "buf_depth {} cannot give every one of {} VCs a credit",
            cfg.buf_depth,
            cfg.vcs
        );
        let nodes = cfg.topo.len();
        let routers = cfg.topo.routers();
        let down: LinkState = vec![[false; NUM_PORTS]; routers];
        // Multi-VC networks route VC 0 on the escape tables from cycle
        // 0; multi-package topologies additionally need them for
        // baseline deadlock freedom even single-VC.
        let escape = (cfg.vcs > 1 || cfg.topo.needs_escape())
            .then(|| EscapeRoutes::compute(cfg.topo, &down));
        Network {
            cfg,
            routers: (0..routers)
                .map(|_| VcRouter::new(cfg.buf_depth, cfg.vcs))
                .collect(),
            ni_queues: vec![VecDeque::new(); nodes],
            ni_rr: vec![0; routers],
            schedule: Vec::new(),
            meta: std::collections::HashMap::new(),
            egress_cfg: None,
            egress: vec![EgressPort::default(); nodes],
            fault: None,
            retry_queue: Vec::new(),
            retry: RetryConfig::paper_default(),
            ingress_cfg: None,
            ingress: vec![IngressPort::default(); nodes],
            pending_link_downs: Vec::new(),
            down,
            escape,
            unreachable: Vec::new(),
            watchdog_cycles: None,
            last_progress: 0,
            vc_occ: vec![0; cfg.vcs as usize],
            vc_progress: vec![0; cfg.vcs as usize],
            vc_hops: vec![0; cfg.vcs as usize],
            vc_delivered: vec![0; cfg.vcs as usize],
            records: Vec::new(),
            now: 0,
            next_id: 0,
            stats: SimStats {
                link_faults: vec![0; routers],
                ..SimStats::default()
            },
        }
    }

    /// Build a network whose Local ports drain codec-tagged packets
    /// through the egress decoder model.
    pub fn with_egress(cfg: NetworkConfig, egress: EgressCodecConfig) -> Self {
        let mut net = Self::new(cfg);
        net.egress_cfg = Some(egress);
        net
    }

    /// Build a network whose links run through a seeded fault injector.
    pub fn with_faults(cfg: NetworkConfig, fault: FaultModel) -> Self {
        let mut net = Self::new(cfg);
        net.fault = Some(fault);
        net
    }

    /// Build a network that paces injection through the ingress encoder
    /// model (ISSUE 7) — the encode-side mirror of
    /// [`Network::with_egress`].
    pub fn with_ingress(cfg: NetworkConfig, ingress: IngressCodecConfig) -> Self {
        let mut net = Self::new(cfg);
        net.ingress_cfg = Some(ingress);
        net
    }

    /// Attach (or replace) the ingress encoder config. Composes with
    /// egress + faults for full-duplex codec ports.
    pub fn set_ingress_config(&mut self, ingress: IngressCodecConfig) {
        self.ingress_cfg = Some(ingress);
    }

    /// Attach (or replace) the link fault model. Composes with
    /// [`Network::with_egress`] — the CLI builds egress + faults.
    /// Scheduled permanent link failures are ingested here; every pair
    /// must be topology-adjacent (programmer error otherwise — the CLI
    /// validates untrusted input before building the model).
    pub fn set_fault_model(&mut self, fault: FaultModel) {
        for e in fault.link_downs() {
            assert!(
                self.adjacent_port(e.a, e.b).is_some(),
                "link-down pair {}-{} is not adjacent in the topology",
                e.a.0,
                e.b.0
            );
        }
        self.pending_link_downs = fault.link_downs().to_vec();
        self.retry = fault.retry();
        self.fault = Some(fault);
    }

    /// Override the NACK-retry budget/backoff policy directly (without
    /// attaching a fault model). Retries also arise from permanent
    /// link-down truncation, so the policy matters even fault-model-free.
    pub fn set_retry_config(&mut self, retry: RetryConfig) {
        self.retry = retry;
    }

    /// The active NACK-retry policy.
    pub fn retry_config(&self) -> RetryConfig {
        self.retry
    }

    /// The output port of `a`'s router that reaches `b`'s router, if
    /// the two are adjacent (`None` for co-located endpoints of one
    /// concentrated router — there is no link between them).
    fn adjacent_port(&self, a: NodeId, b: NodeId) -> Option<Port> {
        let (ra, rb) = (self.cfg.topo.router_of(a), self.cfg.topo.router_of(b));
        Port::ALL[1..]
            .iter()
            .copied()
            .find(|&p| self.cfg.topo.neighbour_r(ra, p) == Some(rb))
    }

    /// The installed fault model, if any.
    pub fn fault_model(&self) -> Option<&FaultModel> {
        self.fault.as_ref()
    }

    /// The installed egress decoder config, if any.
    pub fn egress_config(&self) -> Option<&EgressCodecConfig> {
        self.egress_cfg.as_ref()
    }

    /// Per-endpoint egress decoder state (read-only view for tests/tools).
    pub fn egress_ports(&self) -> &[EgressPort] {
        &self.egress
    }

    /// The installed ingress encoder config, if any.
    pub fn ingress_config(&self) -> Option<&IngressCodecConfig> {
        self.ingress_cfg.as_ref()
    }

    /// Per-endpoint ingress encoder state (read-only view for tests/tools).
    pub fn ingress_ports(&self) -> &[IngressPort] {
        &self.ingress
    }

    /// Override the zero-progress watchdog window, in cycles.
    pub fn set_watchdog(&mut self, cycles: u64) {
        self.watchdog_cycles = Some(cycles.max(1));
    }

    /// Specs abandoned because their destination became unreachable
    /// (typed counterpart of [`SimStats::packets_unreachable`]).
    pub fn unreachable_packets(&self) -> &[PacketSpec] {
        &self.unreachable
    }

    /// Schedule packets after validating their codec tags: a tag whose
    /// symbol count exceeds the packet's wire bits (every coded symbol
    /// costs at least one bit) or that rides a zero-size packet is
    /// rejected up front — a bogus count must never reach the egress
    /// cost model and mis-charge the decoder.
    pub fn try_schedule_packets(&mut self, specs: &[PacketSpec]) -> Result<()> {
        for (i, s) in specs.iter().enumerate() {
            self.validate_spec(s, i)?;
        }
        self.schedule.extend_from_slice(specs);
        // Descending by inject time so due packets pop O(1) from the back.
        self.schedule
            .sort_by_key(|s| std::cmp::Reverse(s.inject_at));
        Ok(())
    }

    /// Tag sanity plus, once escape tables exist with dead links,
    /// live-route existence — a packet to a severed destination is
    /// refused up front rather than admitted and purged later.
    fn validate_spec(&self, s: &PacketSpec, i: usize) -> Result<()> {
        if let Some(tag) = s.codec {
            if s.size_bits == 0 {
                return Err(Error::InvalidParameter(format!(
                    "packet {i}: codec tag on a zero-size packet"
                )));
            }
            if tag.symbols > s.size_bits {
                return Err(Error::InvalidParameter(format!(
                    "packet {i}: {} symbols cannot fit in {} wire bits \
                     (≥ 1 coded bit per symbol)",
                    tag.symbols, s.size_bits
                )));
            }
        }
        if let Some(esc) = &self.escape {
            if !esc.reachable(s.src, s.dest) {
                return Err(Error::Unreachable {
                    src: s.src.0,
                    dest: s.dest.0,
                });
            }
        }
        Ok(())
    }

    /// Schedule a set of packets (any order). Panics on invalid codec
    /// tags; use [`Network::try_schedule_packets`] for untrusted specs.
    pub fn schedule_packets(&mut self, specs: &[PacketSpec]) {
        self.try_schedule_packets(specs)
            .expect("valid packet specs");
    }

    /// Closed-loop injection (ISSUE 7): admit one packet *now* if its
    /// source NI has room, else refuse with a typed error so the
    /// traffic generator feels the backpressure immediately. Refusals
    /// are counted in [`SimStats::injections_refused`]; the caller
    /// retries on a later cycle. Without an ingress config the NI is
    /// unbounded and admission always succeeds.
    pub fn try_inject(&mut self, spec: PacketSpec) -> Result<()> {
        self.validate_spec(&spec, 0)?;
        if let Some(icfg) = &self.ingress_cfg {
            let depth = self.ni_queues[spec.src.0 as usize].len();
            if depth >= icfg.max_queue {
                self.stats.injections_refused += 1;
                return Err(Error::IngressSaturated {
                    node: spec.src.0,
                    depth,
                });
            }
        }
        // Clamp the scheduled time to "now": closed-loop callers decide
        // *when* by calling between steps, and a future stamp would
        // underflow the queueing-delay clock.
        let spec = PacketSpec {
            inject_at: spec.inject_at.min(self.now),
            ..spec
        };
        self.activate(spec, 0, None);
        Ok(())
    }

    /// Injection VC for a packet (ISSUE 10): the spec's pin clamped to
    /// the network, else VC 0 single-VC, else an adaptive VC (≥ 1)
    /// spread deterministically by packet id.
    fn inject_vc(&self, spec: &PacketSpec, id: u64) -> u8 {
        match spec.vc {
            Some(v) => v.min(self.cfg.vcs - 1),
            None if self.cfg.vcs == 1 => 0,
            None => 1 + (id % (self.cfg.vcs as u64 - 1)) as u8,
        }
    }

    /// Materialize one packet at its source NI: meta entry + lazy-flit
    /// pending record. Shared by scheduled activation, retransmission,
    /// and closed-loop injection.
    fn activate(&mut self, spec: PacketSpec, attempt: u32, first_inject: Option<u64>) {
        let id = self.next_id;
        self.next_id += 1;
        let total = spec.flits(self.cfg.flit_bits);
        self.meta.insert(
            id,
            PacketMeta {
                spec,
                total_flits: total,
                head_inject: None,
                decode_stalls: 0,
                encode_stalls: 0,
                corrupted: false,
                attempt,
                first_inject,
            },
        );
        let vc = self.inject_vc(&spec, id);
        self.ni_queues[spec.src.0 as usize].push_back(Pending {
            id,
            spec,
            total_flits: total,
            emitted: 0,
            vc,
        });
    }

    /// Current cycle.
    pub fn now(&self) -> u64 {
        self.now
    }

    /// Are all queues, buffers, schedules and retry backoffs empty?
    ///
    /// O(1): every activated packet holds a `meta` entry until its tail
    /// ejects, so outstanding work ⇔ `schedule`, `meta` or `retry_queue`
    /// non-empty. The exhaustive buffer walk survives as a debug
    /// assertion.
    pub fn drained(&self) -> bool {
        let done =
            self.schedule.is_empty() && self.meta.is_empty() && self.retry_queue.is_empty();
        debug_assert!(
            !done
                || (self.ni_queues.iter().all(|q| q.is_empty())
                    && self.routers.iter().all(|r| r.is_idle())),
            "meta empty but flits still buffered"
        );
        done
    }

    /// Advance one cycle.
    pub fn step(&mut self) {
        let topo = self.cfg.topo;
        let vcs = self.cfg.vcs;
        // One branch per step keeps the fault-off hot path at parity
        // with a fault-less build (perf gate: ≤1.05× the egress row).
        let faults_on = self.fault.as_ref().is_some_and(|f| f.enabled());
        // Watchdog progress observation (ISSUE 7): any flit movement,
        // packet activation or injection this cycle counts as progress.
        // Cheap counters only on the hot path — the heavy diagnosis
        // runs once, at fire time.
        let moved0 = self.stats.delivered_flits + self.stats.flit_hops;
        let id0 = self.next_id;
        let mut progressed = false;

        // --- 0. scheduled permanent link failures (rare) ------------------
        if !self.pending_link_downs.is_empty() {
            while let Some(&e) = self.pending_link_downs.first() {
                if e.at > self.now {
                    break;
                }
                self.pending_link_downs.remove(0);
                // Truncation/purge *is* forward motion for the watchdog.
                progressed |= self.apply_link_down(e.a, e.b);
            }
        }

        // --- 1. activation of scheduled packets --------------------------
        // With ingress codec ports the NI queue is bounded: due
        // arrivals beyond the bound are deferred to later cycles
        // (refusals counted) instead of growing an unbounded queue.
        let mut deferred: Vec<PacketSpec> = Vec::new();
        while let Some(last) = self.schedule.last() {
            if last.inject_at > self.now {
                break;
            }
            let spec = self.schedule.pop().expect("non-empty");
            if let Some(icfg) = &self.ingress_cfg {
                if self.ni_queues[spec.src.0 as usize].len() >= icfg.max_queue {
                    self.stats.injections_refused += 1;
                    deferred.push(spec);
                    continue;
                }
            }
            self.activate(spec, 0, None);
        }
        if !deferred.is_empty() {
            // Re-append at the back: deferred specs are already due, so
            // they stay the schedule's minimum and pop first next cycle.
            self.schedule.extend(deferred);
        }

        // --- 1b. retransmissions whose backoff has elapsed ----------------
        if !self.retry_queue.is_empty() {
            let mut i = 0;
            while i < self.retry_queue.len() {
                if self.retry_queue[i].due > self.now {
                    i += 1;
                    continue;
                }
                let e = self.retry_queue.swap_remove(i);
                // Retries bypass the NI bound: their population is
                // bounded by already-admitted packets, and stalling
                // recovery would leak the bound into the retry budget.
                self.activate(e.spec, e.attempt, Some(e.first_inject));
            }
        }

        // --- 2. injection: one flit per *router* per cycle ----------------
        // Concentrated topologies share one Local port among `conc`
        // endpoints: a per-router round-robin picks the serving NI at
        // *packet* granularity — a partially-emitted worm must finish
        // before another slot injects, because interleaving two worms
        // in the shared Local FIFO head-of-line-deadlocks the second
        // head behind the first worm's unreleased lock. At
        // concentration 1 this is exactly the legacy per-node loop.
        let cycle_ns = self.cfg.cycle_ns();
        let conc = topo.conc() as usize;
        for r in 0..self.routers.len() {
            let mut chosen = None;
            for k in 0..conc {
                let slot = (self.ni_rr[r] as usize + k) % conc;
                let node = topo.node_at(r, slot as u8).0 as usize;
                match self.ni_queues[node].front() {
                    // A worm mid-emission owns the Local port outright.
                    Some(p) if p.emitted > 0 => {
                        chosen = Some((slot, node));
                        break;
                    }
                    Some(_) if chosen.is_none() => chosen = Some((slot, node)),
                    _ => {}
                }
            }
            let Some((slot, node)) = chosen else { continue };
            let q = &mut self.ni_queues[node];
            let p = q.front_mut().expect("chosen NI non-empty");
            // Room in the packet's VC FIFO at the router's Local port?
            if (self.routers[r].inputs[Port::Local as usize].fifos[p.vc as usize].len()
                as u32)
                >= credit_share(self.cfg.buf_depth, vcs, p.vc)
            {
                continue;
            }
            // Ingress codec port (ISSUE 7): a tagged flit must clear
            // the encoder before entering the network.
            let mut pace: Option<f64> = None;
            if let (Some(icfg), Some(tag)) = (self.ingress_cfg.as_ref(), p.spec.codec) {
                if !egress::ready(self.ingress[node].busy_until, self.now) {
                    // Encoder backlogged: the packet stays at the NI
                    // and the stall is counted, never silently
                    // absorbed.
                    self.ingress[node].stall_cycles += 1;
                    self.stats.encode_stall_cycles += 1;
                    self.meta
                        .get_mut(&p.id)
                        .expect("queued packet has meta")
                        .encode_stalls += 1;
                    continue;
                }
                // Startup (codebook build) is charged once, on the
                // head flit of the *first* attempt — a retransmission
                // replays the encoded stream.
                let charge_startup = p.emitted == 0 && self.meta[&p.id].attempt == 0;
                pace = Some(icfg.flit_cost_cycles(
                    &tag,
                    p.total_flits,
                    charge_startup,
                    cycle_ns,
                ));
            }
            let seq = p.emitted;
            let kind = match (seq, p.total_flits) {
                (0, 1) => FlitKind::Single,
                (0, _) => FlitKind::Head,
                (s, t) if s + 1 == t => FlitKind::Tail,
                _ => FlitKind::Body,
            };
            if seq == 0 {
                // The latency clock starts when the head actually
                // enters the network, not at the scheduled time.
                self.meta
                    .get_mut(&p.id)
                    .expect("activated packet has meta")
                    .head_inject = Some(self.now);
            }
            let vc = p.vc;
            self.routers[r].inputs[Port::Local as usize].fifos[vc as usize].push_back(
                Flit {
                    packet_id: p.id,
                    kind,
                    src: p.spec.src,
                    dest: p.spec.dest,
                    seq,
                    vc,
                    ready_at: self.now + 1,
                    codec: p.spec.codec,
                },
            );
            if let Some(cost) = pace {
                self.ingress[node].busy_until =
                    egress::accept(self.ingress[node].busy_until, self.now, cost);
            }
            progressed = true;
            self.vc_occ[vc as usize] += 1;
            self.vc_progress[vc as usize] = self.now;
            p.emitted += 1;
            if p.emitted == p.total_flits {
                q.pop_front();
                // Packet done: the round-robin hands the Local port to
                // the next concentrated endpoint.
                self.ni_rr[r] = ((slot + 1) % conc) as u8;
            }
        }

        // --- 3. forwarding / ejection -------------------------------------
        for node in 0..self.routers.len() {
            // §Perf: idle routers (all input FIFOs empty) skip arbitration
            // entirely — a large win under sparse/hotspot traffic.
            if self.routers[node].is_idle() {
                continue;
            }
            // Input control computes (output port, output VC) per input
            // VC; output control allocates the switch. Both are pure, so
            // a declined grant (no credit, backlogged decoder, faulted
            // link) replays identically next cycle.
            let grants = {
                let ctx = RouteCtx {
                    topo,
                    escape: self.escape.as_ref(),
                    down: &self.down,
                    vcs,
                };
                output_control::arbitrate_all(&self.routers[node], self.now, |inp, invc, f, outs| {
                    ctx.desired(node, inp, invc, f, outs)
                })
            };
            for &out in &Port::ALL {
                let Some(g) = grants[out as usize] else { continue };

                if out == Port::Local {
                    self.eject(node, g);
                    continue;
                }

                // Link traversal: need a credit on the output lane.
                if self.routers[node].outputs[out as usize].lanes[g.out_vc as usize].credits
                    == 0
                {
                    continue;
                }
                let Some(nb) = topo.neighbour_r(node, out) else {
                    unreachable!("routing never exits the topology");
                };
                if faults_on && self.fault.as_mut().expect("gated").drops() {
                    // The link ate the flit: it stays at the FIFO head and
                    // retries next cycle (link-level ARQ), so a wormhole
                    // body can never vanish from the middle of a packet.
                    self.stats.flits_dropped += 1;
                    self.stats.link_faults[node] += 1;
                    continue;
                }
                let mut flit = self.routers[node].inputs[g.inp].fifos[g.invc as usize]
                    .pop_front()
                    .expect("arbitrated input non-empty");
                self.credit_return(node, g.inp, g.invc);
                output_control::update_lock(
                    &mut self.routers[node].outputs[out as usize],
                    g.out_vc,
                    g.inp,
                    g.invc,
                    &flit,
                    vcs,
                );
                self.routers[node].outputs[out as usize].lanes[g.out_vc as usize].credits -=
                    1;
                self.routers[node].outputs[out as usize].forwarded += 1;
                self.stats.flit_hops += 1;
                self.vc_occ[g.invc as usize] -= 1;
                self.vc_occ[g.out_vc as usize] += 1;
                self.vc_hops[g.out_vc as usize] += 1;
                self.vc_progress[g.invc as usize] = self.now;
                self.vc_progress[g.out_vc as usize] = self.now;
                flit.ready_at = self.now + 1;
                flit.vc = g.out_vc;
                if faults_on {
                    let flit_bits = self.cfg.flit_bits;
                    if self.fault.as_mut().expect("gated").corrupts(flit_bits) {
                        // Payload bits flipped in flight. The per-lane CRC
                        // (lexi-core::integrity) catches it at egress
                        // decode; the tail ejection NACKs instead of
                        // recording delivery.
                        self.stats.flits_corrupted += 1;
                        self.stats.link_faults[node] += 1;
                        self.meta
                            .get_mut(&flit.packet_id)
                            .expect("in-flight packet has meta")
                            .corrupted = true;
                    }
                    if self.fault.as_mut().expect("gated").duplicates() {
                        // The receiver squashes the copy by sequence
                        // number; the echo costs one extra cycle of
                        // downstream occupancy.
                        self.stats.flits_duplicated += 1;
                        self.stats.link_faults[node] += 1;
                        flit.ready_at = self.now + 2;
                    }
                }
                self.routers[nb].inputs[out.opposite() as usize].fifos[g.out_vc as usize]
                    .push_back(flit);
            }
        }

        self.now += 1;
        self.stats.cycles = self.now;
        if progressed
            || self.stats.delivered_flits + self.stats.flit_hops != moved0
            || self.next_id != id0
        {
            self.last_progress = self.now;
        }
    }

    /// Ejection at `node`'s Local port under grant `g`: codec-blind
    /// packets drain 1 flit/cycle; tagged packets must clear the egress
    /// decoder of the *destination endpoint* first.
    fn eject(&mut self, node: usize, g: Grant) {
        let hol = *self.routers[node].inputs[g.inp].fifos[g.invc as usize]
            .front()
            .expect("arbitrated input non-empty");
        let ep = hol.dest.0 as usize;
        let mut decode_done: Option<f64> = None;
        if let (Some(ecfg), Some(tag)) = (self.egress_cfg, hol.codec) {
            let port = &mut self.egress[ep];
            if !egress::ready(port.busy_until, self.now) {
                // Decoder backlogged: the flit stays in the local input
                // buffer (no pop ⇒ no credit upstream ⇒ backpressure
                // into the mesh).
                port.stall_cycles += 1;
                self.stats.decode_stall_cycles += 1;
                self.meta
                    .get_mut(&hol.packet_id)
                    .expect("in-flight packet has meta")
                    .decode_stalls += 1;
                return;
            }
            let total = self.meta[&hol.packet_id].total_flits;
            let cost = ecfg.flit_cost_cycles(&tag, total, hol.is_head(), self.cfg.cycle_ns());
            port.busy_until = egress::accept(port.busy_until, self.now, cost);
            decode_done = Some(port.busy_until);
        }
        let flit = self.routers[node].inputs[g.inp].fifos[g.invc as usize]
            .pop_front()
            .expect("arbitrated input non-empty");
        self.credit_return(node, g.inp, g.invc);
        output_control::update_lock(
            &mut self.routers[node].outputs[Port::Local as usize],
            g.out_vc,
            g.inp,
            g.invc,
            &flit,
            self.cfg.vcs,
        );
        self.stats.delivered_flits += 1;
        self.vc_occ[g.invc as usize] -= 1;
        self.vc_delivered[g.invc as usize] += 1;
        self.vc_progress[g.invc as usize] = self.now;
        if flit.is_tail() {
            let m = self.meta.remove(&flit.packet_id).expect("meta");
            // Latency spans the *original* head injection —
            // retransmission backoff and repeat trips are charged to
            // the packet, not hidden.
            let inject_cycle = m
                .first_inject
                .or(m.head_inject)
                .expect("tail ejected before head injected");
            if m.corrupted {
                // NACK: the egress CRC check failed (the speculative
                // decode cost stays charged). Retransmit after an
                // exponential backoff, or report the loss once the
                // budget is spent — never hang, never silently deliver
                // garbage.
                if m.attempt < self.retry.budget {
                    let next = m.attempt + 1;
                    self.stats.packet_retries += 1;
                    self.retry_queue.push(RetryEntry {
                        spec: m.spec,
                        due: self.now + 1 + self.retry.backoff(next),
                        attempt: next,
                        first_inject: inject_cycle,
                    });
                } else {
                    self.stats.packets_dropped += 1;
                }
                return;
            }
            // A tagged packet completes when its decoder finishes the
            // tail flit's symbols, which can trail the ejection itself.
            let eject_cycle = match decode_done {
                Some(busy) => (self.now + 1).max(busy.ceil() as u64),
                None => self.now + 1,
            };
            let rec = PacketRecord {
                spec: m.spec,
                inject_cycle,
                eject_cycle,
                flits: m.total_flits,
                decode_stall_cycles: m.decode_stalls,
                encode_stall_cycles: m.encode_stalls,
                retries: m.attempt,
            };
            self.stats.delivered_packets += 1;
            self.stats.sum_latency += rec.latency();
            self.stats.max_latency = self.stats.max_latency.max(rec.latency());
            self.stats.sum_queueing += rec.queueing_delay();
            if let Some(tag) = m.spec.codec {
                self.stats.delivered_symbols += tag.symbols;
            }
            self.stats.completion_cycle = self.stats.completion_cycle.max(eject_cycle);
            self.records.push(rec);
        }
    }

    /// Run until every scheduled packet is delivered (or `max_cycles`).
    /// Returns stats; panics with the [`StallReport`] if the network
    /// wedges or fails to drain in time — use
    /// [`Network::try_run_to_completion`] to handle stalls as values.
    pub fn run_to_completion(&mut self, max_cycles: u64) -> SimStats {
        match self.try_run_to_completion(max_cycles) {
            Ok(stats) => stats,
            Err(report) => panic!("network failed to drain: {report}"),
        }
    }

    /// Run until drained, the watchdog fires, or `max_cycles` elapse
    /// (ISSUE 7). The watchdog fires when nothing has moved for the
    /// watchdog window AND no scheduled arrival or retry backoff is
    /// still pending (a future-due entry is guaranteed progress, not a
    /// stall), so no input can make this loop forever. A multi-VC
    /// network additionally fires when one VC's buffered flits have not
    /// moved for a whole window while the rest of the network kept
    /// progressing ([`StallCause::VcStarvation`] — invisible to the
    /// global counter). On fire — or on timeout — the typed
    /// [`StallReport`] carries the stuck packets, a per-VC
    /// credit-conservation audit, and a suspected cause.
    pub fn try_run_to_completion(
        &mut self,
        max_cycles: u64,
    ) -> std::result::Result<SimStats, StallReport> {
        let window = self.watchdog_cycles.unwrap_or(DEFAULT_WATCHDOG_CYCLES);
        while !self.drained() {
            let stalled_for = self.now - self.last_progress;
            if stalled_for >= window && !self.future_work_pending() {
                return Err(self.diagnose(stalled_for, false));
            }
            if self.cfg.vcs > 1 {
                if let Some(vc) = self.starving_vc(window) {
                    return Err(self.build_report(stalled_for, StallCause::VcStarvation(vc)));
                }
            }
            if self.now >= max_cycles {
                return Err(self.diagnose(stalled_for, true));
            }
            self.step();
        }
        Ok(self.stats.clone())
    }

    /// Kill the `a`↔`b` link immediately (both directions). Prefer
    /// scheduling via [`FaultModel::with_link_down`]; this is the
    /// validated immediate-mode entry tests and tools share.
    pub fn down_link(&mut self, a: NodeId, b: NodeId) -> Result<()> {
        if self.adjacent_port(a, b).is_none() {
            return Err(Error::InvalidParameter(format!(
                "link-down pair {}-{} is not adjacent in the topology",
                a.0, b.0
            )));
        }
        self.apply_link_down(a, b);
        Ok(())
    }

    /// Apply one permanent link failure: mark both directions dead,
    /// rebuild the escape tables, truncate severed/unroutable worms,
    /// purge newly-unreachable packets. Returns true if anything
    /// changed (truncation counts as watchdog progress). Idempotent.
    fn apply_link_down(&mut self, a: NodeId, b: NodeId) -> bool {
        let topo = self.cfg.topo;
        let vcs = self.cfg.vcs;
        let (ra, rb) = (topo.router_of(a), topo.router_of(b));
        let pab = self.adjacent_port(a, b).expect("validated adjacency");
        let pba = pab.opposite();
        if self.down[ra][pab as usize] {
            return false; // already dead
        }
        self.down[ra][pab as usize] = true;
        self.down[rb][pba as usize] = true;
        self.stats.links_down += 1;

        // New escape tables over the survivor topology; VC 0 (and, on
        // single-VC networks, everything) follows them from here on.
        self.escape = Some(EscapeRoutes::compute(topo, &self.down));

        let (victims, purge, sched_gone, retry_gone) = {
            let esc = self.escape.as_ref().expect("just installed");
            // Victims: (1) worms locked through the dead directed
            // links (any lane); (2) flits with no legal continuation —
            // single-VC / escape-channel flits stranded down-phase or
            // disconnected, adaptive flits only if their destination is
            // disconnected (they may always re-enter the escape channel
            // fresh); (3) escape-lane worms whose locked output no
            // longer matches the rebuilt table hop — forwarding those
            // would break the up*/down* order mid-worm. Adaptive-lane
            // locks need no table check: their bodies follow the lock,
            // and a dead locked output is already case (1).
            let mut victims: Vec<u64> = Vec::new();
            for (u, pout) in [(ra, pab), (rb, pba)] {
                for lane in &self.routers[u].outputs[pout as usize].lanes {
                    if let Some(pid) = lane.locked_packet {
                        victims.push(pid);
                    }
                }
            }
            for (node, r) in self.routers.iter().enumerate() {
                for (inp, buf) in r.inputs.iter().enumerate() {
                    for fifo in &buf.fifos {
                        for f in fifo {
                            let dest_r = topo.router_of(f.dest);
                            let doomed = if vcs == 1 || f.vc == 0 {
                                esc.next_hop(node, inp, dest_r).is_none()
                            } else {
                                esc.next_hop(node, Port::Local as usize, dest_r).is_none()
                            };
                            if doomed {
                                victims.push(f.packet_id);
                            }
                        }
                    }
                }
                for (out, o) in r.outputs.iter().enumerate() {
                    for (ovc, lane) in o.lanes.iter().enumerate() {
                        if vcs > 1 && ovc != 0 {
                            continue;
                        }
                        let (Some(pid), Some((linp, _))) = (lane.locked_packet, lane.locked_to)
                        else {
                            continue;
                        };
                        let Some(m) = self.meta.get(&pid) else { continue };
                        let dest_r = topo.router_of(m.spec.dest);
                        if esc.next_hop(node, linp, dest_r) != Some(Port::ALL[out]) {
                            victims.push(pid);
                        }
                    }
                }
            }
            victims.sort_unstable();
            victims.dedup();

            // Packets waiting at NIs or in the schedule/retry queue
            // whose destination is now severed: typed unreachability.
            let mut purge: Vec<u64> = Vec::new();
            for q in &self.ni_queues {
                for p in q {
                    if !esc.reachable(p.spec.src, p.spec.dest) {
                        purge.push(p.id);
                    }
                }
            }
            let sched = std::mem::take(&mut self.schedule);
            let (sched_keep, sched_gone): (Vec<_>, Vec<_>) = sched
                .into_iter()
                .partition(|s| esc.reachable(s.src, s.dest));
            self.schedule = sched_keep;
            let retries = std::mem::take(&mut self.retry_queue);
            let (retry_keep, retry_gone): (Vec<_>, Vec<_>) = retries
                .into_iter()
                .partition(|e| esc.reachable(e.spec.src, e.spec.dest));
            self.retry_queue = retry_keep;
            (victims, purge, sched_gone, retry_gone)
        };

        let progressed = !victims.is_empty()
            || !purge.is_empty()
            || !sched_gone.is_empty()
            || !retry_gone.is_empty();
        for s in sched_gone {
            self.stats.packets_unreachable += 1;
            self.unreachable.push(s);
        }
        for e in retry_gone {
            self.stats.packets_unreachable += 1;
            self.unreachable.push(e.spec);
        }
        for pid in victims.into_iter().chain(purge) {
            self.truncate_packet(pid);
        }
        progressed
    }

    /// Drain every trace of packet `pid` from the network: buffered
    /// flits are discarded with their credits returned to the exact VC
    /// lane (so per-VC conservation holds through the failure),
    /// wormhole locks are released, and the NI remainder is dropped.
    /// The packet is then NACK-retried under the retry budget — or
    /// reported unreachable/dropped. Exactly the ISSUE 6 recovery path,
    /// entered from a cut instead of a CRC failure.
    fn truncate_packet(&mut self, pid: u64) {
        let Some(m) = self.meta.remove(&pid) else {
            return; // already truncated in this application
        };
        for node in 0..self.routers.len() {
            for inp in 0..NUM_PORTS {
                for vc in 0..self.cfg.vcs {
                    let removed = {
                        let fifo = &mut self.routers[node].inputs[inp].fifos[vc as usize];
                        let before = fifo.len();
                        fifo.retain(|f| f.packet_id != pid);
                        before - fifo.len()
                    };
                    self.vc_occ[vc as usize] -= removed as u64;
                    for _ in 0..removed {
                        self.credit_return(node, inp, vc);
                    }
                }
            }
            for o in self.routers[node].outputs.iter_mut() {
                for lane in o.lanes.iter_mut() {
                    if lane.locked_packet == Some(pid) {
                        lane.locked_to = None;
                        lane.locked_packet = None;
                    }
                }
            }
        }
        self.ni_queues[m.spec.src.0 as usize].retain(|p| p.id != pid);
        if m.head_inject.is_some() {
            // Only a packet with flits in flight was truly truncated; a
            // purged never-injected packet is just unreachable.
            self.stats.packets_truncated += 1;
        }
        let reachable = self
            .escape
            .as_ref()
            .map_or(true, |e| e.reachable(m.spec.src, m.spec.dest));
        if !reachable {
            self.stats.packets_unreachable += 1;
            self.unreachable.push(m.spec);
        } else if m.attempt < self.retry.budget {
            let next = m.attempt + 1;
            self.stats.packet_retries += 1;
            self.retry_queue.push(RetryEntry {
                spec: m.spec,
                due: self.now + 1 + self.retry.backoff(next),
                attempt: next,
                first_inject: m.first_inject.or(m.head_inject).unwrap_or(self.now),
            });
        } else {
            self.stats.packets_dropped += 1;
        }
    }

    /// Stats so far.
    pub fn stats(&self) -> &SimStats {
        &self.stats
    }

    /// Total directed links in the topology (for utilization).
    pub fn link_count(&self) -> u64 {
        self.cfg.topo.link_count()
    }

    /// A flit left VC `vc` of input `inp` at router `at`: return one
    /// credit to the matching upstream lane.
    fn credit_return(&mut self, at: usize, inp: usize, vc: u8) {
        if inp == Port::Local as usize {
            return; // NI injection checks occupancy directly.
        }
        let in_port = Port::ALL[inp];
        // The upstream neighbour sits in the direction of the input port
        // and fed us through its opposite output.
        if let Some(up) = self.cfg.topo.neighbour_r(at, in_port) {
            let up_out = in_port.opposite() as usize;
            self.routers[up].outputs[up_out].lanes[vc as usize].credits += 1;
        }
    }

    /// Test-only: overwrite the `ready_at` of every buffered flit of
    /// packet `pid` (wedges it without breaking credit accounting —
    /// the starvation-watchdog regression uses this).
    #[cfg(test)]
    fn freeze_packet_for_test(&mut self, pid: u64, until: u64) -> usize {
        let mut frozen = 0;
        for r in &mut self.routers {
            for buf in &mut r.inputs {
                for fifo in &mut buf.fifos {
                    for f in fifo.iter_mut().filter(|f| f.packet_id == pid) {
                        f.ready_at = until;
                        frozen += 1;
                    }
                }
            }
        }
        frozen
    }
}

#[cfg(test)]
#[path = "network_tests.rs"]
mod tests;
