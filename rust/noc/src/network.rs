//! The assembled mesh network and its cycle loop.
//!
//! Injection → wormhole forwarding → ejection, with credit-based flow
//! control and XY routing. Flits are generated lazily at the network
//! interface (a multi-megabyte transfer does not materialize millions of
//! flit structs up front), and `ready_at` stamping guarantees one hop per
//! cycle regardless of router iteration order.
//!
//! **Egress codec ports (ISSUE 5):** a network built with
//! [`Network::with_egress`] drains codec-tagged packets through a
//! per-node [`EgressPort`] at the configured decoder rate instead of the
//! unconditional 1 flit/cycle: a backlogged decoder refuses the ejection
//! grant, the flit stays in the local input buffer, no credit returns
//! upstream, and the stall backpressures into the mesh like any full
//! buffer. Untagged packets (and networks without an egress config) keep
//! the codec-blind ejection path bit-for-bit.
//!
//! **Fault-injected links (ISSUE 6):** a network built with
//! [`Network::with_faults`] (or [`Network::set_fault_model`]) passes
//! every link traversal through a seeded [`FaultModel`]. A *dropped*
//! flit stays at its FIFO head and retries next cycle (link-level ARQ —
//! a wormhole body can never vanish mid-packet); a *corrupted* flit
//! marks its packet dirty so the egress CRC check NACKs the tail, which
//! schedules a retransmission after an exponential backoff (bounded by
//! [`RETRY_BUDGET`], after which the loss is reported in
//! [`SimStats::packets_dropped`]); a *duplicated* flit costs one extra
//! cycle of downstream occupancy (the receiver squashes the copy by
//! sequence number). Retransmission latency — backoff plus the repeat
//! trip — is charged to the packet: its record keeps the *original*
//! head-injection cycle. With no model attached (or all rates zero) the
//! hot path pays one branch per step.

use crate::egress::{self, EgressCodecConfig, EgressPort};
use crate::fault::{retry_backoff, FaultModel, RETRY_BUDGET};
use crate::packet::{Flit, FlitKind, PacketRecord, PacketSpec};
use crate::router::Router;
use crate::topology::{Mesh, NodeId, Port, NUM_PORTS};
use lexi_core::error::{Error, Result};
use std::collections::VecDeque;

/// Network configuration.
#[derive(Clone, Copy, Debug)]
pub struct NetworkConfig {
    pub mesh: Mesh,
    /// Flit width in bits (paper setup: 128-bit flits).
    pub flit_bits: u32,
    /// Raw link bandwidth in Gbps (paper: 100 Gbps NoI links).
    pub link_gbps: f64,
    /// Input-buffer depth per router port, in flits.
    pub buf_depth: u32,
}

impl NetworkConfig {
    /// The paper's NoI operating point on a 6×6 mesh.
    pub fn paper_default() -> Self {
        NetworkConfig {
            mesh: Mesh::simba_6x6(),
            flit_bits: 128,
            link_gbps: 100.0,
            buf_depth: 4,
        }
    }

    /// Wall-clock duration of one network cycle in ns (one flit per link
    /// per cycle ⇒ cycle = flit_bits / link rate).
    pub fn cycle_ns(&self) -> f64 {
        self.flit_bits as f64 / self.link_gbps
    }
}

/// A packet queued at a network interface, flits emitted lazily.
#[derive(Clone, Copy, Debug)]
struct Pending {
    id: u64,
    spec: PacketSpec,
    total_flits: u32,
    emitted: u32,
}

/// Per-packet bookkeeping from activation to tail ejection.
#[derive(Clone, Copy, Debug)]
struct PacketMeta {
    spec: PacketSpec,
    total_flits: u32,
    /// Cycle the head flit actually entered the network (`None` while
    /// still queued at the NI) — the latency clock starts here, not at
    /// the scheduled `spec.inject_at` (that gap is queueing delay).
    head_inject: Option<u64>,
    /// Ejection cycles spent blocked behind the egress decoder.
    decode_stalls: u64,
    /// A link fault flipped payload bits in one of this packet's flits;
    /// the egress CRC check will NACK the tail instead of recording
    /// delivery.
    corrupted: bool,
    /// How many retransmissions preceded this attempt (0 = original).
    attempt: u32,
    /// Head-injection cycle of the *original* attempt, carried across
    /// retransmissions so retry backoff + repeat trips land in latency.
    first_inject: Option<u64>,
}

/// A NACKed packet awaiting its retransmission slot.
#[derive(Clone, Copy, Debug)]
struct RetryEntry {
    spec: PacketSpec,
    /// Cycle at which the retransmission re-enters the NI queue.
    due: u64,
    /// 1-based retransmission attempt this entry represents.
    attempt: u32,
    /// Original head-injection cycle (see [`PacketMeta::first_inject`]).
    first_inject: u64,
}

/// Aggregate simulation statistics.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SimStats {
    pub delivered_packets: u64,
    pub delivered_flits: u64,
    /// Exponent symbols carried by delivered codec-tagged packets.
    pub delivered_symbols: u64,
    pub flit_hops: u64,
    pub cycles: u64,
    pub sum_latency: u64,
    pub max_latency: u64,
    /// Σ per-packet source-NI queueing (scheduled → actual head inject).
    pub sum_queueing: u64,
    /// Ejection cycles refused by backlogged egress decoders.
    pub decode_stall_cycles: u64,
    /// Cycle by which every delivered packet — including its egress
    /// decode tail — has completed. ≥ `cycles` when the decoder is still
    /// draining after the last tail ejects.
    pub completion_cycle: u64,
    /// Flits whose payload a link fault corrupted in transit (ISSUE 6).
    pub flits_corrupted: u64,
    /// Link traversals that ate the flit (retried next cycle at the
    /// FIFO head — link-level ARQ).
    pub flits_dropped: u64,
    /// Link traversals that emitted a squashed duplicate (one extra
    /// cycle of downstream occupancy).
    pub flits_duplicated: u64,
    /// Packet retransmissions scheduled after an egress-CRC NACK.
    pub packet_retries: u64,
    /// Packets abandoned after exhausting [`RETRY_BUDGET`]
    /// retransmissions — reported, never silently lost.
    pub packets_dropped: u64,
    /// Per-node fault events on outbound links (corrupt + drop + dup),
    /// indexed like the mesh. Sized at construction; empty only for a
    /// default-constructed `SimStats`.
    pub link_faults: Vec<u64>,
}

impl SimStats {
    /// Mean packet latency in cycles.
    pub fn avg_latency(&self) -> f64 {
        if self.delivered_packets == 0 {
            0.0
        } else {
            self.sum_latency as f64 / self.delivered_packets as f64
        }
    }

    /// Mean source-NI queueing delay in cycles.
    pub fn avg_queueing(&self) -> f64 {
        if self.delivered_packets == 0 {
            0.0
        } else {
            self.sum_queueing as f64 / self.delivered_packets as f64
        }
    }

    /// Network-wide average link utilization given the link count.
    pub fn link_utilization(&self, links: u64) -> f64 {
        if self.cycles == 0 || links == 0 {
            0.0
        } else {
            self.flit_hops as f64 / (links * self.cycles) as f64
        }
    }
}

/// The simulator.
pub struct Network {
    pub cfg: NetworkConfig,
    routers: Vec<Router>,
    /// Per-node: packets not yet fully injected, FIFO.
    ni_queues: Vec<VecDeque<Pending>>,
    /// Packets scheduled for the future, sorted descending by inject_at
    /// (pop from the back).
    schedule: Vec<PacketSpec>,
    /// Per-packet bookkeeping (id → meta).
    meta: std::collections::HashMap<u64, PacketMeta>,
    /// Egress decoder model; `None` = codec-blind 1-flit/cycle ejection.
    egress_cfg: Option<EgressCodecConfig>,
    /// Per-node egress decoder state (parallel to `routers`).
    egress: Vec<EgressPort>,
    /// Seeded link-fault injector; `None` = ideal lossless links.
    fault: Option<FaultModel>,
    /// NACKed packets waiting out their retransmission backoff.
    retry_queue: Vec<RetryEntry>,
    /// Completion records.
    pub records: Vec<PacketRecord>,
    now: u64,
    next_id: u64,
    stats: SimStats,
}

impl Network {
    /// Build an idle network with codec-blind ejection.
    pub fn new(cfg: NetworkConfig) -> Self {
        let n = cfg.mesh.len();
        Network {
            cfg,
            routers: (0..n).map(|_| Router::new(cfg.buf_depth)).collect(),
            ni_queues: vec![VecDeque::new(); n],
            schedule: Vec::new(),
            meta: std::collections::HashMap::new(),
            egress_cfg: None,
            egress: vec![EgressPort::default(); n],
            fault: None,
            retry_queue: Vec::new(),
            records: Vec::new(),
            now: 0,
            next_id: 0,
            stats: SimStats {
                link_faults: vec![0; n],
                ..SimStats::default()
            },
        }
    }

    /// Build a network whose Local ports drain codec-tagged packets
    /// through the egress decoder model.
    pub fn with_egress(cfg: NetworkConfig, egress: EgressCodecConfig) -> Self {
        let mut net = Self::new(cfg);
        net.egress_cfg = Some(egress);
        net
    }

    /// Build a network whose links run through a seeded fault injector.
    pub fn with_faults(cfg: NetworkConfig, fault: FaultModel) -> Self {
        let mut net = Self::new(cfg);
        net.fault = Some(fault);
        net
    }

    /// Attach (or replace) the link fault model. Composes with
    /// [`Network::with_egress`] — the CLI builds egress + faults.
    pub fn set_fault_model(&mut self, fault: FaultModel) {
        self.fault = Some(fault);
    }

    /// The installed fault model, if any.
    pub fn fault_model(&self) -> Option<&FaultModel> {
        self.fault.as_ref()
    }

    /// The installed egress decoder config, if any.
    pub fn egress_config(&self) -> Option<&EgressCodecConfig> {
        self.egress_cfg.as_ref()
    }

    /// Per-node egress decoder state (read-only view for tests/tools).
    pub fn egress_ports(&self) -> &[EgressPort] {
        &self.egress
    }

    /// Schedule packets after validating their codec tags: a tag whose
    /// symbol count exceeds the packet's wire bits (every coded symbol
    /// costs at least one bit) or that rides a zero-size packet is
    /// rejected up front — a bogus count must never reach the egress
    /// cost model and mis-charge the decoder.
    pub fn try_schedule_packets(&mut self, specs: &[PacketSpec]) -> Result<()> {
        for (i, s) in specs.iter().enumerate() {
            if let Some(tag) = s.codec {
                if s.size_bits == 0 {
                    return Err(Error::InvalidParameter(format!(
                        "packet {i}: codec tag on a zero-size packet"
                    )));
                }
                if tag.symbols > s.size_bits {
                    return Err(Error::InvalidParameter(format!(
                        "packet {i}: {} symbols cannot fit in {} wire bits \
                         (≥ 1 coded bit per symbol)",
                        tag.symbols, s.size_bits
                    )));
                }
            }
        }
        self.schedule.extend_from_slice(specs);
        // Descending by inject time so due packets pop O(1) from the back.
        self.schedule
            .sort_by_key(|s| std::cmp::Reverse(s.inject_at));
        Ok(())
    }

    /// Schedule a set of packets (any order). Panics on invalid codec
    /// tags; use [`Network::try_schedule_packets`] for untrusted specs.
    pub fn schedule_packets(&mut self, specs: &[PacketSpec]) {
        self.try_schedule_packets(specs)
            .expect("valid packet specs");
    }

    /// Current cycle.
    pub fn now(&self) -> u64 {
        self.now
    }

    /// Are all queues, buffers, schedules and retry backoffs empty?
    ///
    /// O(1): every activated packet holds a `meta` entry until its tail
    /// ejects, so outstanding work ⇔ `schedule`, `meta` or `retry_queue`
    /// non-empty. The exhaustive buffer walk survives as a debug
    /// assertion.
    pub fn drained(&self) -> bool {
        let done =
            self.schedule.is_empty() && self.meta.is_empty() && self.retry_queue.is_empty();
        debug_assert!(
            !done
                || (self.ni_queues.iter().all(|q| q.is_empty())
                    && self
                        .routers
                        .iter()
                        .all(|r| r.inputs.iter().all(|b| b.fifo.is_empty()))),
            "meta empty but flits still buffered"
        );
        done
    }

    /// Advance one cycle.
    pub fn step(&mut self) {
        let mesh = self.cfg.mesh;
        // One branch per step keeps the fault-off hot path at parity
        // with a fault-less build (perf gate: ≤1.05× the egress row).
        let faults_on = self.fault.as_ref().is_some_and(|f| f.enabled());

        // --- 1. activation of scheduled packets --------------------------
        while let Some(last) = self.schedule.last() {
            if last.inject_at > self.now {
                break;
            }
            let spec = self.schedule.pop().expect("non-empty");
            let id = self.next_id;
            self.next_id += 1;
            let total = spec.flits(self.cfg.flit_bits);
            self.meta.insert(
                id,
                PacketMeta {
                    spec,
                    total_flits: total,
                    head_inject: None,
                    decode_stalls: 0,
                    corrupted: false,
                    attempt: 0,
                    first_inject: None,
                },
            );
            self.ni_queues[spec.src.0 as usize].push_back(Pending {
                id,
                spec,
                total_flits: total,
                emitted: 0,
            });
        }

        // --- 1b. retransmissions whose backoff has elapsed ----------------
        if !self.retry_queue.is_empty() {
            let mut i = 0;
            while i < self.retry_queue.len() {
                if self.retry_queue[i].due > self.now {
                    i += 1;
                    continue;
                }
                let e = self.retry_queue.swap_remove(i);
                let id = self.next_id;
                self.next_id += 1;
                let total = e.spec.flits(self.cfg.flit_bits);
                self.meta.insert(
                    id,
                    PacketMeta {
                        spec: e.spec,
                        total_flits: total,
                        head_inject: None,
                        decode_stalls: 0,
                        corrupted: false,
                        attempt: e.attempt,
                        first_inject: Some(e.first_inject),
                    },
                );
                self.ni_queues[e.spec.src.0 as usize].push_back(Pending {
                    id,
                    spec: e.spec,
                    total_flits: total,
                    emitted: 0,
                });
            }
        }

        // --- 2. injection: one flit per node per cycle --------------------
        for (node, q) in self.ni_queues.iter_mut().enumerate() {
            if let Some(p) = q.front_mut() {
                let local_in = &mut self.routers[node].inputs[Port::Local as usize];
                if (local_in.fifo.len() as u32) < self.cfg.buf_depth {
                    let seq = p.emitted;
                    let kind = match (seq, p.total_flits) {
                        (0, 1) => FlitKind::Single,
                        (0, _) => FlitKind::Head,
                        (s, t) if s + 1 == t => FlitKind::Tail,
                        _ => FlitKind::Body,
                    };
                    if seq == 0 {
                        // The latency clock starts when the head actually
                        // enters the network, not at the scheduled time.
                        self.meta
                            .get_mut(&p.id)
                            .expect("activated packet has meta")
                            .head_inject = Some(self.now);
                    }
                    local_in.fifo.push_back(Flit {
                        packet_id: p.id,
                        kind,
                        src: p.spec.src,
                        dest: p.spec.dest,
                        seq,
                        ready_at: self.now + 1,
                        codec: p.spec.codec,
                    });
                    p.emitted += 1;
                    if p.emitted == p.total_flits {
                        q.pop_front();
                    }
                }
            }
        }

        // --- 3. forwarding / ejection -------------------------------------
        for node in 0..self.routers.len() {
            // §Perf: idle routers (all input FIFOs empty) skip arbitration
            // entirely — a large win under sparse/hotspot traffic.
            if self.routers[node].inputs.iter().all(|b| b.fifo.is_empty()) {
                continue;
            }
            let at = NodeId(node as u16);
            let grants =
                self.routers[node].arbitrate_all(self.now, |f| mesh.route_xy(at, f.dest));
            for &out in &Port::ALL {
                let Some(inp) = grants[out as usize] else { continue };

                if out == Port::Local {
                    // Ejection: codec-blind packets drain 1 flit/cycle;
                    // tagged packets must clear the egress decoder first.
                    let hol = *self.routers[node].inputs[inp]
                        .fifo
                        .front()
                        .expect("arbitrated input non-empty");
                    let mut decode_done: Option<f64> = None;
                    if let (Some(ecfg), Some(tag)) = (self.egress_cfg, hol.codec) {
                        let port = &mut self.egress[node];
                        if !egress::ready(port.busy_until, self.now) {
                            // Decoder backlogged: the flit stays in the
                            // local input buffer (no pop ⇒ no credit
                            // upstream ⇒ backpressure into the mesh).
                            port.stall_cycles += 1;
                            self.stats.decode_stall_cycles += 1;
                            self.meta
                                .get_mut(&hol.packet_id)
                                .expect("in-flight packet has meta")
                                .decode_stalls += 1;
                            continue;
                        }
                        let total = self.meta[&hol.packet_id].total_flits;
                        let cost = ecfg.flit_cost_cycles(
                            &tag,
                            total,
                            hol.is_head(),
                            self.cfg.cycle_ns(),
                        );
                        port.busy_until = egress::accept(port.busy_until, self.now, cost);
                        decode_done = Some(port.busy_until);
                    }
                    let flit = self.routers[node].inputs[inp]
                        .fifo
                        .pop_front()
                        .expect("arbitrated input non-empty");
                    self.credit_return(at, inp);
                    self.update_lock(node, out, inp, &flit);
                    self.stats.delivered_flits += 1;
                    if flit.is_tail() {
                        let m = self.meta.remove(&flit.packet_id).expect("meta");
                        // Latency spans the *original* head injection —
                        // retransmission backoff and repeat trips are
                        // charged to the packet, not hidden.
                        let inject_cycle = m
                            .first_inject
                            .or(m.head_inject)
                            .expect("tail ejected before head injected");
                        if m.corrupted {
                            // NACK: the egress CRC check failed (the
                            // speculative decode cost stays charged).
                            // Retransmit after an exponential backoff, or
                            // report the loss once the budget is spent —
                            // never hang, never silently deliver garbage.
                            if m.attempt < RETRY_BUDGET {
                                let next = m.attempt + 1;
                                self.stats.packet_retries += 1;
                                self.retry_queue.push(RetryEntry {
                                    spec: m.spec,
                                    due: self.now + 1 + retry_backoff(next),
                                    attempt: next,
                                    first_inject: inject_cycle,
                                });
                            } else {
                                self.stats.packets_dropped += 1;
                            }
                            continue;
                        }
                        // A tagged packet completes when its decoder
                        // finishes the tail flit's symbols, which can
                        // trail the ejection itself.
                        let eject_cycle = match decode_done {
                            Some(busy) => (self.now + 1).max(busy.ceil() as u64),
                            None => self.now + 1,
                        };
                        let rec = PacketRecord {
                            spec: m.spec,
                            inject_cycle,
                            eject_cycle,
                            flits: m.total_flits,
                            decode_stall_cycles: m.decode_stalls,
                            retries: m.attempt,
                        };
                        self.stats.delivered_packets += 1;
                        self.stats.sum_latency += rec.latency();
                        self.stats.max_latency = self.stats.max_latency.max(rec.latency());
                        self.stats.sum_queueing += rec.queueing_delay();
                        if let Some(tag) = m.spec.codec {
                            self.stats.delivered_symbols += tag.symbols;
                        }
                        self.stats.completion_cycle =
                            self.stats.completion_cycle.max(eject_cycle);
                        self.records.push(rec);
                    }
                    continue;
                }

                // Link traversal: need a credit downstream.
                if self.routers[node].outputs[out as usize].credits == 0 {
                    continue;
                }
                let Some(nb) = mesh.neighbour(at, out) else {
                    unreachable!("XY routing never exits the mesh");
                };
                if faults_on && self.fault.as_mut().expect("gated").drops() {
                    // The link ate the flit: it stays at the FIFO head and
                    // retries next cycle (link-level ARQ), so a wormhole
                    // body can never vanish from the middle of a packet.
                    self.stats.flits_dropped += 1;
                    self.stats.link_faults[node] += 1;
                    continue;
                }
                let mut flit = self.routers[node].inputs[inp]
                    .fifo
                    .pop_front()
                    .expect("arbitrated input non-empty");
                self.credit_return(at, inp);
                self.update_lock(node, out, inp, &flit);
                self.routers[node].outputs[out as usize].credits -= 1;
                self.routers[node].outputs[out as usize].forwarded += 1;
                self.stats.flit_hops += 1;
                flit.ready_at = self.now + 1;
                if faults_on {
                    let flit_bits = self.cfg.flit_bits;
                    if self.fault.as_mut().expect("gated").corrupts(flit_bits) {
                        // Payload bits flipped in flight. The per-lane CRC
                        // (lexi-core::integrity) catches it at egress
                        // decode; the tail ejection NACKs instead of
                        // recording delivery.
                        self.stats.flits_corrupted += 1;
                        self.stats.link_faults[node] += 1;
                        self.meta
                            .get_mut(&flit.packet_id)
                            .expect("in-flight packet has meta")
                            .corrupted = true;
                    }
                    if self.fault.as_mut().expect("gated").duplicates() {
                        // The receiver squashes the copy by sequence
                        // number; the echo costs one extra cycle of
                        // downstream occupancy.
                        self.stats.flits_duplicated += 1;
                        self.stats.link_faults[node] += 1;
                        flit.ready_at = self.now + 2;
                    }
                }
                self.routers[nb.0 as usize].inputs[out.opposite() as usize]
                    .fifo
                    .push_back(flit);
            }
        }

        self.now += 1;
        self.stats.cycles = self.now;
    }

    /// Run until every scheduled packet is delivered (or `max_cycles`).
    /// Returns stats; panics if the network failed to drain in time.
    pub fn run_to_completion(&mut self, max_cycles: u64) -> SimStats {
        while !self.drained() {
            assert!(
                self.now < max_cycles,
                "network failed to drain within {max_cycles} cycles \
                 ({} packets outstanding)",
                self.meta.len()
            );
            self.step();
        }
        self.stats.clone()
    }

    /// Stats so far.
    pub fn stats(&self) -> &SimStats {
        &self.stats
    }

    /// Total directed links in the mesh (for utilization).
    pub fn link_count(&self) -> u64 {
        let (c, r) = (self.cfg.mesh.cols as u64, self.cfg.mesh.rows as u64);
        2 * (r * (c - 1) + c * (r - 1))
    }

    /// A flit left `inp` of router `at`: return one credit upstream.
    fn credit_return(&mut self, at: NodeId, inp: usize) {
        if inp == Port::Local as usize {
            return; // NI injection checks occupancy directly.
        }
        let in_port = Port::ALL[inp];
        // The upstream neighbour sits in the direction of the input port
        // and fed us through its opposite output.
        if let Some(up) = self.cfg.mesh.neighbour(at, in_port) {
            let up_out = in_port.opposite() as usize;
            self.routers[up.0 as usize].outputs[up_out].credits += 1;
        }
    }

    /// Wormhole lock bookkeeping after forwarding `flit` inp→out.
    fn update_lock(&mut self, node: usize, out: Port, inp: usize, flit: &Flit) {
        let o = &mut self.routers[node].outputs[out as usize];
        if flit.is_tail() {
            o.locked_to = None;
            o.rr = (inp + 1) % NUM_PORTS;
        } else {
            o.locked_to = Some(inp);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::packet::CodecTag;
    use lexi_core::codec::CodecKind;

    fn cfg_4x4() -> NetworkConfig {
        NetworkConfig {
            mesh: Mesh::new(4, 4),
            flit_bits: 128,
            link_gbps: 100.0,
            buf_depth: 4,
        }
    }

    #[test]
    fn single_packet_minimal_latency() {
        let cfg = cfg_4x4();
        let mut net = Network::new(cfg);
        let spec = PacketSpec::new(NodeId(0), NodeId(3), 128 * 4, 0); // 3 hops east
        net.schedule_packets(&[spec]);
        let stats = net.run_to_completion(1000);
        assert_eq!(stats.delivered_packets, 1);
        let rec = net.records[0];
        // Lower bound: injection (1) + hops (3) + serialization (3 more
        // flits) + ejection; exact value depends on the pipeline model —
        // assert a tight band, not an exact constant.
        let lb = 3 + 4 - 1;
        assert!(
            (lb..lb + 8).contains(&rec.latency()),
            "latency {}",
            rec.latency()
        );
        // No contention: the head injects the cycle it is scheduled.
        assert_eq!(rec.queueing_delay(), 0);
    }

    #[test]
    fn self_send_delivers() {
        let mut net = Network::new(cfg_4x4());
        net.schedule_packets(&[PacketSpec::new(NodeId(5), NodeId(5), 64, 0)]);
        let stats = net.run_to_completion(100);
        assert_eq!(stats.delivered_packets, 1);
    }

    #[test]
    fn all_packets_delivered_under_load() {
        let mut net = Network::new(cfg_4x4());
        let mut specs = Vec::new();
        for i in 0..16u16 {
            for j in 0..16u16 {
                if i != j {
                    specs.push(PacketSpec::new(
                        NodeId(i),
                        NodeId(j),
                        128 * 3,
                        (i as u64) * 2,
                    ));
                }
            }
        }
        let n = specs.len() as u64;
        let mut net2 = Network::new(cfg_4x4());
        net2.schedule_packets(&specs);
        let stats = net2.run_to_completion(100_000);
        assert_eq!(stats.delivered_packets, n);
        assert_eq!(stats.delivered_flits, n * 3);
        let _ = &mut net;
    }

    #[test]
    fn wormhole_packets_arrive_contiguously() {
        // With wormhole switching + XY routing, a destination receives each
        // packet's flits in order (seq strictly increasing per packet).
        let mut net = Network::new(cfg_4x4());
        let specs: Vec<PacketSpec> = (0..8u16)
            .map(|i| PacketSpec::new(NodeId(i), NodeId(15), 128 * 8, 0))
            .collect();
        net.schedule_packets(&specs);
        net.run_to_completion(10_000);
        assert_eq!(net.records.len(), 8);
    }

    #[test]
    fn congestion_raises_latency() {
        // Hotspot: everyone sends to node 0 — latency must exceed the
        // uncongested single-sender case.
        let solo = {
            let mut net = Network::new(cfg_4x4());
            net.schedule_packets(&[PacketSpec::new(NodeId(15), NodeId(0), 128 * 16, 0)]);
            net.run_to_completion(10_000).avg_latency()
        };
        let hot = {
            let mut net = Network::new(cfg_4x4());
            let specs: Vec<PacketSpec> = (1..16u16)
                .map(|i| PacketSpec::new(NodeId(i), NodeId(0), 128 * 16, 0))
                .collect();
            net.schedule_packets(&specs);
            net.run_to_completion(100_000).avg_latency()
        };
        assert!(hot > solo * 2.0, "solo {solo} hot {hot}");
    }

    #[test]
    fn throughput_bounded_by_bisection() {
        // Uniform random cannot exceed ~1 flit/cycle/link utilization.
        let mut net = Network::new(cfg_4x4());
        let mut specs = Vec::new();
        for k in 0..400u64 {
            specs.push(PacketSpec::new(
                NodeId((k * 7 % 16) as u16),
                NodeId((k * 11 % 16) as u16),
                128 * 4,
                k / 8,
            ));
        }
        let specs: Vec<_> = specs
            .into_iter()
            .filter(|s| s.src != s.dest)
            .collect();
        let links = {
            let n = Network::new(cfg_4x4());
            n.link_count()
        };
        net.schedule_packets(&specs);
        let stats = net.run_to_completion(1_000_000);
        assert!(stats.link_utilization(links) <= 1.0);
    }

    #[test]
    fn cycle_ns_matches_paper_link() {
        let cfg = NetworkConfig::paper_default();
        assert!((cfg.cycle_ns() - 1.28).abs() < 1e-9);
    }

    #[test]
    fn queueing_delay_excluded_from_latency() {
        // Regression (ISSUE 5 satellite): two packets from one source —
        // the second's head cannot inject until the first's 8 flits have
        // cleared the NI, and that wait must land in queueing_delay, not
        // in latency. (Previously inject_cycle was stamped with the
        // *scheduled* inject_at, silently folding NI queueing into
        // network latency.)
        let mut net = Network::new(cfg_4x4());
        let a = PacketSpec::new(NodeId(0), NodeId(3), 128 * 8, 0);
        let b = PacketSpec::new(NodeId(0), NodeId(3), 128 * 8, 0);
        net.schedule_packets(&[a, b]);
        let stats = net.run_to_completion(10_000);
        assert_eq!(stats.delivered_packets, 2);
        let first = net.records.iter().find(|r| r.queueing_delay() == 0).unwrap();
        let second = net.records.iter().find(|r| r.queueing_delay() > 0).unwrap();
        // Same route, same size, exclusive link ⇒ near-identical network
        // latency for both once queueing is separated out.
        assert!(
            second.latency() <= first.latency() + 2,
            "queueing leaked into latency: first {} vs second {}",
            first.latency(),
            second.latency()
        );
        // The second head waited for ~the first packet's serialization.
        assert!(
            (6..=10).contains(&second.queueing_delay()),
            "queueing {}",
            second.queueing_delay()
        );
        assert_eq!(
            stats.sum_queueing,
            net.records.iter().map(|r| r.queueing_delay()).sum::<u64>()
        );
    }

    fn huff_tag(symbols: u64, runtime_book: bool) -> CodecTag {
        CodecTag {
            kind: CodecKind::Huffman,
            symbols,
            runtime_book,
        }
    }

    #[test]
    fn bogus_codec_tags_rejected() {
        let mut net = Network::new(cfg_4x4());
        // More symbols than wire bits: impossible (≥ 1 bit/symbol).
        let bogus = PacketSpec::new(NodeId(0), NodeId(3), 128, 0).tagged(huff_tag(129, false));
        assert!(net.try_schedule_packets(&[bogus]).is_err());
        // Tag on a zero-size packet.
        let empty = PacketSpec::new(NodeId(0), NodeId(3), 0, 0).tagged(huff_tag(1, false));
        assert!(net.try_schedule_packets(&[empty]).is_err());
        // Nothing was scheduled; the network stays drained.
        assert!(net.drained());
        // A valid tag passes.
        let ok = PacketSpec::new(NodeId(0), NodeId(3), 128, 0).tagged(huff_tag(128, false));
        assert!(net.try_schedule_packets(&[ok]).is_ok());
    }

    #[test]
    fn line_rate_egress_matches_codec_blind_ejection() {
        // Paper point (16 lanes): tagged stepping must deliver in the
        // same cycle count as the codec-blind network (offline book ⇒
        // no startup, decoder hidden behind the wire).
        let spec = PacketSpec::new(NodeId(0), NodeId(15), 128 * 64, 0);
        let blind = {
            let mut net = Network::new(cfg_4x4());
            net.schedule_packets(&[spec]);
            net.run_to_completion(10_000)
        };
        let tagged = {
            let mut net =
                Network::with_egress(cfg_4x4(), EgressCodecConfig::paper_default());
            net.schedule_packets(&[spec.tagged(huff_tag(64 * 8, false))]);
            net.run_to_completion(10_000)
        };
        assert_eq!(blind.cycles, tagged.cycles);
        assert_eq!(tagged.decode_stall_cycles, 0);
        assert_eq!(tagged.delivered_symbols, 64 * 8);
        assert_eq!(tagged.completion_cycle, blind.completion_cycle);
    }

    #[test]
    fn starved_egress_stalls_the_link_and_backpressures() {
        // One decoder lane on a symbol-heavy packet: ejection throttles,
        // stall cycles accrue, and completion stretches to ~the decode
        // makespan instead of the wire time.
        let symbols = 64 * 16u64; // 16 symbols per flit
        let spec =
            PacketSpec::new(NodeId(0), NodeId(15), 128 * 64, 0).tagged(huff_tag(symbols, false));
        let ecfg = EgressCodecConfig::nominal(1, 1.0); // 1.16 cyc/sym at 1 lane
        let cycle_ns = cfg_4x4().cycle_ns();
        let mut net = Network::with_egress(cfg_4x4(), ecfg);
        net.schedule_packets(&[spec]);
        let stats = net.run_to_completion(100_000);
        assert_eq!(stats.delivered_packets, 1);
        assert!(stats.decode_stall_cycles > 0, "no backpressure observed");
        let rec = net.records[0];
        assert_eq!(rec.decode_stall_cycles, stats.decode_stall_cycles);
        // Decode-bound completion ≈ symbols × ns/sym ÷ cycle_ns.
        let decode_cycles = symbols as f64 * ecfg.ns_per_symbol(CodecKind::Huffman) / cycle_ns;
        let done = stats.completion_cycle as f64;
        assert!(
            done >= decode_cycles && done <= decode_cycles * 1.15 + 16.0,
            "completion {done} vs decode bound {decode_cycles}"
        );
    }

    #[test]
    fn runtime_book_startup_charged_on_head_flits() {
        // Identical packets, offline vs runtime book: the runtime one
        // completes later by ~the startup and stalls while the codebook
        // pipeline fills.
        let base = PacketSpec::new(NodeId(0), NodeId(15), 128 * 64, 0);
        let run = |runtime: bool| {
            let mut net =
                Network::with_egress(cfg_4x4(), EgressCodecConfig::paper_default());
            net.schedule_packets(&[base.tagged(huff_tag(64 * 8, runtime))]);
            net.run_to_completion(100_000)
        };
        let offline = run(false);
        let runtime = run(true);
        let cycle_ns = cfg_4x4().cycle_ns();
        let startup_cycles =
            (EgressCodecConfig::paper_default().startup_ns / cycle_ns).ceil() as u64;
        let delta = runtime.completion_cycle - offline.completion_cycle;
        assert!(
            delta >= startup_cycles - 1 && delta <= startup_cycles + 2,
            "startup delta {delta} vs expected {startup_cycles}"
        );
        assert!(runtime.decode_stall_cycles > 0);
        assert_eq!(offline.decode_stall_cycles, 0);
    }

    #[test]
    fn raw_tagged_packets_never_stall() {
        let spec = PacketSpec::new(NodeId(1), NodeId(14), 128 * 32, 0).tagged(CodecTag {
            kind: CodecKind::Raw,
            symbols: 32 * 16,
            runtime_book: false,
        });
        let mut net = Network::with_egress(cfg_4x4(), EgressCodecConfig::nominal(1, 1.0));
        let stats = net.run_to_completion_after(&[spec]);
        assert_eq!(stats.decode_stall_cycles, 0);
        assert_eq!(stats.delivered_symbols, 32 * 16);
    }

    impl Network {
        /// Test helper: schedule then run.
        fn run_to_completion_after(&mut self, specs: &[PacketSpec]) -> SimStats {
            self.schedule_packets(specs);
            self.run_to_completion(1_000_000)
        }
    }

    /// Uniform all-to-all load, 16 flits per packet (240 packets).
    fn uniform_16flit_specs() -> Vec<PacketSpec> {
        let mut specs = Vec::new();
        for i in 0..16u16 {
            for j in 0..16u16 {
                if i != j {
                    specs.push(PacketSpec::new(
                        NodeId(i),
                        NodeId(j),
                        128 * 16,
                        (i as u64) * 2,
                    ));
                }
            }
        }
        specs
    }

    #[test]
    fn inert_fault_model_is_stat_identical_to_none() {
        // A fault model attached at all-zero rates must not perturb the
        // simulation in any observable way — this is the zero-BER pin
        // that keeps `sim::xval` and the perf row honest.
        let specs = uniform_16flit_specs();
        let clean = {
            let mut net = Network::new(cfg_4x4());
            net.run_to_completion_after(&specs)
        };
        let inert = {
            let mut net = Network::with_faults(cfg_4x4(), FaultModel::new(3));
            net.run_to_completion_after(&specs)
        };
        assert_eq!(clean, inert);
        assert_eq!(inert.flits_corrupted, 0);
        assert_eq!(inert.packet_retries, 0);
    }

    #[test]
    fn seeded_fault_runs_replay_identically() {
        let run = || {
            let mut net = Network::with_faults(
                cfg_4x4(),
                FaultModel::new(99).with_ber(1e-4).with_dup(0.01),
            );
            net.run_to_completion_after(&uniform_16flit_specs())
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn ber_run_delivers_every_packet_exactly_once_with_backoff_in_latency() {
        // ISSUE 6 satellite: a BER-injected run must deliver all symbols
        // exactly once (corrupted attempts are NACKed and retransmitted,
        // never recorded), and each retried packet's latency must carry
        // at least its retransmission backoffs.
        let specs = uniform_16flit_specs();
        let n = specs.len() as u64;
        let clean = {
            let mut net = Network::new(cfg_4x4());
            net.run_to_completion_after(&specs)
        };
        let mut net = Network::with_faults(cfg_4x4(), FaultModel::new(11).with_ber(1e-5));
        let stats = net.run_to_completion_after(&specs);
        // At this seed/BER the budget is never exhausted: every packet
        // is delivered, each exactly once.
        assert_eq!(stats.delivered_packets + stats.packets_dropped, n);
        assert_eq!(net.records.len() as u64, stats.delivered_packets);
        assert!(stats.flits_corrupted > 0, "seeded BER run injected nothing");
        assert!(stats.packet_retries > 0, "no retransmissions observed");
        assert_eq!(
            stats.link_faults.iter().sum::<u64>(),
            stats.flits_corrupted + stats.flits_dropped + stats.flits_duplicated
        );
        // Retried packets pay backoff + repeat trip in *latency* (their
        // records keep the original head-injection cycle).
        let mut saw_retry = false;
        for r in net.records.iter().filter(|r| r.retries > 0) {
            saw_retry = true;
            let backoffs: u64 = (1..=r.retries).map(retry_backoff).sum();
            assert!(
                r.latency() >= backoffs,
                "retried packet latency {} below its backoff sum {backoffs}",
                r.latency()
            );
        }
        assert!(saw_retry || stats.packets_dropped > 0);
        // Faults can only make the run slower in aggregate.
        assert!(stats.sum_latency >= clean.sum_latency);
    }

    #[test]
    fn lossy_links_retry_at_head_and_still_deliver() {
        // Flit drops are link-level ARQ: the flit retries from the FIFO
        // head, so delivery is lossless and in-order — just slower.
        let spec = PacketSpec::new(NodeId(0), NodeId(15), 128 * 8, 0);
        let clean = {
            let mut net = Network::new(cfg_4x4());
            net.run_to_completion_after(&[spec])
        };
        let mut net = Network::with_faults(cfg_4x4(), FaultModel::new(5).with_drop(0.3));
        let stats = net.run_to_completion_after(&[spec]);
        assert_eq!(stats.delivered_packets, 1);
        assert!(stats.flits_dropped > 0, "seeded drop run dropped nothing");
        assert_eq!(stats.packets_dropped, 0);
        assert!(stats.sum_latency >= clean.sum_latency);
    }

    #[test]
    fn retry_budget_exhaustion_reports_drop_without_hanging() {
        // BER = 1.0 corrupts every traversal: the packet is NACKed on
        // all RETRY_BUDGET retransmissions and then reported dropped —
        // run_to_completion drains instead of spinning forever.
        let mut net = Network::with_faults(cfg_4x4(), FaultModel::new(1).with_ber(1.0));
        net.schedule_packets(&[PacketSpec::new(NodeId(0), NodeId(3), 128 * 4, 0)]);
        let stats = net.run_to_completion(10_000);
        assert!(net.drained());
        assert_eq!(stats.delivered_packets, 0);
        assert_eq!(stats.packets_dropped, 1);
        assert_eq!(stats.packet_retries, u64::from(RETRY_BUDGET));
        assert!(net.records.is_empty());
        // The exponential backoffs are cycle-accurate sim time.
        let backoffs: u64 = (1..=RETRY_BUDGET).map(retry_backoff).sum();
        assert!(
            stats.cycles >= backoffs,
            "cycles {} below backoff floor {backoffs}",
            stats.cycles
        );
    }

    #[test]
    fn duplicated_flits_cost_occupancy_but_deliver_once() {
        let specs = uniform_16flit_specs();
        let n = specs.len() as u64;
        let mut net = Network::with_faults(cfg_4x4(), FaultModel::new(21).with_dup(0.05));
        let stats = net.run_to_completion_after(&specs);
        assert_eq!(stats.delivered_packets, n);
        assert!(stats.flits_duplicated > 0, "seeded dup run duplicated nothing");
        // Duplicates never create packets or symbols.
        assert_eq!(net.records.len() as u64, n);
        assert_eq!(stats.packets_dropped, 0);
    }

    #[test]
    fn faulty_egress_network_keeps_symbol_accounting_exact() {
        // Corrupted attempts charge speculative decode work but never
        // count delivered symbols; once the retry lands, symbols are
        // counted exactly once.
        let symbols = 64 * 8u64;
        let spec =
            PacketSpec::new(NodeId(0), NodeId(15), 128 * 64, 0).tagged(huff_tag(symbols, false));
        let mut net = Network::with_egress(cfg_4x4(), EgressCodecConfig::paper_default());
        net.set_fault_model(FaultModel::new(17).with_ber(2e-4));
        let stats = net.run_to_completion_after(&[spec]);
        assert_eq!(stats.delivered_packets + stats.packets_dropped, 1);
        if stats.delivered_packets == 1 {
            assert_eq!(stats.delivered_symbols, symbols);
        } else {
            assert_eq!(stats.delivered_symbols, 0);
        }
    }
}
