//! The assembled mesh network and its cycle loop.
//!
//! Injection → wormhole forwarding → ejection, with credit-based flow
//! control and XY routing. Flits are generated lazily at the network
//! interface (a multi-megabyte transfer does not materialize millions of
//! flit structs up front), and `ready_at` stamping guarantees one hop per
//! cycle regardless of router iteration order.
//!
//! **Egress codec ports (ISSUE 5):** a network built with
//! [`Network::with_egress`] drains codec-tagged packets through a
//! per-node [`EgressPort`] at the configured decoder rate instead of the
//! unconditional 1 flit/cycle: a backlogged decoder refuses the ejection
//! grant, the flit stays in the local input buffer, no credit returns
//! upstream, and the stall backpressures into the mesh like any full
//! buffer. Untagged packets (and networks without an egress config) keep
//! the codec-blind ejection path bit-for-bit.
//!
//! **Fault-injected links (ISSUE 6):** a network built with
//! [`Network::with_faults`] (or [`Network::set_fault_model`]) passes
//! every link traversal through a seeded [`FaultModel`]. A *dropped*
//! flit stays at its FIFO head and retries next cycle (link-level ARQ —
//! a wormhole body can never vanish mid-packet); a *corrupted* flit
//! marks its packet dirty so the egress CRC check NACKs the tail, which
//! schedules a retransmission after an exponential backoff (bounded by
//! the [`RetryConfig`] budget — ISSUE 6's fixed
//! [`RETRY_BUDGET`](crate::fault::RETRY_BUDGET) until ISSUE 9 made it
//! configurable — after which the loss is reported in
//! [`SimStats::packets_dropped`]); a *duplicated* flit costs one extra
//! cycle of downstream occupancy (the receiver squashes the copy by
//! sequence number). Retransmission latency — backoff plus the repeat
//! trip — is charged to the packet: its record keeps the *original*
//! head-injection cycle. With no model attached (or all rates zero) the
//! hot path pays one branch per step.
//!
//! **Ingress codec ports (ISSUE 7):** a network with an
//! [`IngressCodecConfig`] paces injection through a per-node encoder
//! occupancy model ([`IngressPort`]), charges the compressor startup on
//! runtime-Huffman heads, and bounds every NI queue: scheduled arrivals
//! beyond the bound are deferred (counted in
//! [`SimStats::injections_refused`]) and the closed-loop
//! [`Network::try_inject`] refuses with a typed
//! `Error::IngressSaturated` — backpressure reaches the traffic
//! generator instead of an unbounded queue.
//!
//! **Watchdog (ISSUE 7):** the step loop tracks global progress (any
//! flit injected, forwarded, or ejected; any packet activated). If
//! nothing moves for the watchdog window — and no scheduled arrival or
//! retry backoff is still pending — [`Network::try_run_to_completion`]
//! terminates with a typed [`StallReport`]: the stuck packets with
//! their holding node/port, a per-link credit-conservation audit
//! (Σ credits + buffered flits == `buf_depth`), and a suspected cause.
//! No input can hang the simulator.
//!
//! **Permanent link failures (ISSUE 7):** [`FaultModel::with_link_down`]
//! kills a link at a scheduled cycle. The severed wormhole is truncated
//! (its buffered flits discarded with credits returned, the packet
//! NACK-retried under the ISSUE 6 budget) and all routing switches to
//! precomputed deadlock-safe up*/down* escape tables
//! ([`crate::reroute`]). Packets whose destination is disconnected are
//! reported in [`SimStats::packets_unreachable`] — delivered via
//! reroute or typed-unreachable, never silently lost, never hung.

use crate::egress::{self, EgressCodecConfig, EgressPort};
use crate::fault::{FaultModel, LinkDown, RetryConfig};
use crate::ingress::{IngressCodecConfig, IngressPort};
use crate::packet::{Flit, FlitKind, PacketRecord, PacketSpec};
use crate::reroute::{EscapeRoutes, LinkState};
use crate::router::Router;
use crate::topology::{Mesh, NodeId, Port, NUM_PORTS};
use lexi_core::error::{Error, Result};
use std::collections::VecDeque;
use std::fmt;

/// Network configuration.
#[derive(Clone, Copy, Debug)]
pub struct NetworkConfig {
    pub mesh: Mesh,
    /// Flit width in bits (paper setup: 128-bit flits).
    pub flit_bits: u32,
    /// Raw link bandwidth in Gbps (paper: 100 Gbps NoI links).
    pub link_gbps: f64,
    /// Input-buffer depth per router port, in flits.
    pub buf_depth: u32,
}

impl NetworkConfig {
    /// The paper's NoI operating point on a 6×6 mesh.
    pub fn paper_default() -> Self {
        NetworkConfig {
            mesh: Mesh::simba_6x6(),
            flit_bits: 128,
            link_gbps: 100.0,
            buf_depth: 4,
        }
    }

    /// Wall-clock duration of one network cycle in ns (one flit per link
    /// per cycle ⇒ cycle = flit_bits / link rate).
    pub fn cycle_ns(&self) -> f64 {
        self.flit_bits as f64 / self.link_gbps
    }
}

/// A packet queued at a network interface, flits emitted lazily.
#[derive(Clone, Copy, Debug)]
struct Pending {
    id: u64,
    spec: PacketSpec,
    total_flits: u32,
    emitted: u32,
}

/// Per-packet bookkeeping from activation to tail ejection.
#[derive(Clone, Copy, Debug)]
struct PacketMeta {
    spec: PacketSpec,
    total_flits: u32,
    /// Cycle the head flit actually entered the network (`None` while
    /// still queued at the NI) — the latency clock starts here, not at
    /// the scheduled `spec.inject_at` (that gap is queueing delay).
    head_inject: Option<u64>,
    /// Ejection cycles spent blocked behind the egress decoder.
    decode_stalls: u64,
    /// Injection cycles spent blocked behind the ingress encoder.
    encode_stalls: u64,
    /// A link fault flipped payload bits in one of this packet's flits;
    /// the egress CRC check will NACK the tail instead of recording
    /// delivery.
    corrupted: bool,
    /// How many retransmissions preceded this attempt (0 = original).
    attempt: u32,
    /// Head-injection cycle of the *original* attempt, carried across
    /// retransmissions so retry backoff + repeat trips land in latency.
    first_inject: Option<u64>,
}

/// A NACKed packet awaiting its retransmission slot.
#[derive(Clone, Copy, Debug)]
struct RetryEntry {
    spec: PacketSpec,
    /// Cycle at which the retransmission re-enters the NI queue.
    due: u64,
    /// 1-based retransmission attempt this entry represents.
    attempt: u32,
    /// Original head-injection cycle (see [`PacketMeta::first_inject`]).
    first_inject: u64,
}

/// Aggregate simulation statistics.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SimStats {
    pub delivered_packets: u64,
    pub delivered_flits: u64,
    /// Exponent symbols carried by delivered codec-tagged packets.
    pub delivered_symbols: u64,
    pub flit_hops: u64,
    pub cycles: u64,
    pub sum_latency: u64,
    pub max_latency: u64,
    /// Σ per-packet source-NI queueing (scheduled → actual head inject).
    pub sum_queueing: u64,
    /// Ejection cycles refused by backlogged egress decoders.
    pub decode_stall_cycles: u64,
    /// Injection cycles refused by backlogged ingress encoders
    /// (ISSUE 7): the NI had a flit ready but the encoder's `busy_until`
    /// horizon was over a cycle ahead.
    pub encode_stall_cycles: u64,
    /// Injection attempts refused because the bounded NI queue was full
    /// (scheduled-arrival deferrals + [`Network::try_inject`] refusals).
    pub injections_refused: u64,
    /// Cycle by which every delivered packet — including its egress
    /// decode tail — has completed. ≥ `cycles` when the decoder is still
    /// draining after the last tail ejects.
    pub completion_cycle: u64,
    /// Flits whose payload a link fault corrupted in transit (ISSUE 6).
    pub flits_corrupted: u64,
    /// Link traversals that ate the flit (retried next cycle at the
    /// FIFO head — link-level ARQ).
    pub flits_dropped: u64,
    /// Link traversals that emitted a squashed duplicate (one extra
    /// cycle of downstream occupancy).
    pub flits_duplicated: u64,
    /// Packet retransmissions scheduled after an egress-CRC NACK.
    pub packet_retries: u64,
    /// Packets abandoned after exhausting the [`RetryConfig`] budget
    /// of retransmissions — reported, never silently lost.
    pub packets_dropped: u64,
    /// Permanent link failures applied so far (ISSUE 7).
    pub links_down: u64,
    /// Wormholes truncated by a permanent link failure: in-flight flits
    /// discarded (credits returned), the packet NACK-retried under the
    /// retry budget or reported dropped/unreachable.
    pub packets_truncated: u64,
    /// Packets abandoned because no live route to their destination
    /// exists (component severed by link failures) — typed, never
    /// silent; the specs are kept in [`Network::unreachable_packets`].
    pub packets_unreachable: u64,
    /// Per-node fault events on outbound links (corrupt + drop + dup),
    /// indexed like the mesh. Sized at construction; empty only for a
    /// default-constructed `SimStats`.
    pub link_faults: Vec<u64>,
}

impl SimStats {
    /// Mean packet latency in cycles.
    pub fn avg_latency(&self) -> f64 {
        if self.delivered_packets == 0 {
            0.0
        } else {
            self.sum_latency as f64 / self.delivered_packets as f64
        }
    }

    /// Mean source-NI queueing delay in cycles.
    pub fn avg_queueing(&self) -> f64 {
        if self.delivered_packets == 0 {
            0.0
        } else {
            self.sum_queueing as f64 / self.delivered_packets as f64
        }
    }

    /// Network-wide average link utilization given the link count.
    pub fn link_utilization(&self, links: u64) -> f64 {
        if self.cycles == 0 || links == 0 {
            0.0
        } else {
            self.flit_hops as f64 / (links * self.cycles) as f64
        }
    }
}

/// Default zero-progress window (in cycles) before the watchdog fires:
/// comfortably beyond the longest legal quiet spell (the 256-cycle
/// retry-backoff cap, codec-port startups, deep congestion waves) while
/// still terminating a wedged run promptly.
pub const DEFAULT_WATCHDOG_CYCLES: u64 = 10_000;

/// One broken per-link credit invariant found by
/// [`Network::audit_credits`]: the upstream output's credits plus the
/// downstream input's buffered flits no longer sum to `buf_depth`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CreditViolation {
    /// Upstream node of the directed link.
    pub node: NodeId,
    /// Output port (= link direction) at the upstream node.
    pub out: Port,
    /// Credits the upstream output currently holds.
    pub credits: u32,
    /// Flits buffered at the downstream input.
    pub buffered: u32,
    /// The configured `buf_depth` the two must sum to.
    pub expected: u32,
}

/// A packet that was still live when the watchdog fired.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StuckPacket {
    pub id: u64,
    pub src: NodeId,
    pub dest: NodeId,
    /// Node holding the packet's foremost buffered flit (the source
    /// when nothing is buffered yet — still queued at the NI).
    pub node: NodeId,
    /// Input port holding that flit (`Local` when NI-queued).
    pub port: Port,
    /// Approximate cycle of the flit's last movement (`ready_at` − 1).
    pub since: u64,
}

/// The watchdog's suspected root cause, cheapest-to-check first.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StallCause {
    /// The credit audit found a link where credits + buffered flits no
    /// longer sum to `buf_depth` — flow control itself is broken.
    CreditLeak,
    /// An ingress/egress codec port's busy horizon is still ahead of
    /// sim time after a whole stall window: an effectively zero-rate
    /// port is refusing every grant.
    ZeroRatePort,
    /// A permanent link failure is in effect, or the fault model drops
    /// every traversal (`drop_prob == 1` — a dead link in transient
    /// clothing).
    DeadLink,
    /// No port or credit anomaly found: suspect a routing/lock cycle.
    RoutingCycle,
    /// `max_cycles` elapsed while the network was still making
    /// progress — an undersized horizon, not a wedge.
    SlowProgress,
}

/// Typed verdict from the stall/deadlock watchdog (ISSUE 7): why the
/// run terminated without draining, who was stuck where, and whether
/// credit conservation still held. Returned by
/// [`Network::try_run_to_completion`] instead of looping forever.
#[derive(Clone, Debug, PartialEq)]
pub struct StallReport {
    /// Cycle at which the watchdog fired.
    pub cycle: u64,
    /// Zero-progress cycles leading up to it.
    pub stalled_for: u64,
    pub cause: StallCause,
    /// Live packets and where each one's foremost flit is held.
    pub stuck_packets: Vec<StuckPacket>,
    /// Credit-conservation violations (empty = credits intact).
    pub credit_audit: Vec<CreditViolation>,
}

impl fmt::Display for StallReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "stall at cycle {}: no progress for {} cycles (suspected {:?}); \
             {} stuck packet(s), {} credit violation(s)",
            self.cycle,
            self.stalled_for,
            self.cause,
            self.stuck_packets.len(),
            self.credit_audit.len()
        )?;
        for p in self.stuck_packets.iter().take(8) {
            writeln!(
                f,
                "  packet {} {}->{} held at node {} port {:?} since cycle {}",
                p.id, p.src.0, p.dest.0, p.node.0, p.port, p.since
            )?;
        }
        if self.stuck_packets.len() > 8 {
            writeln!(f, "  ... {} more", self.stuck_packets.len() - 8)?;
        }
        for v in self.credit_audit.iter().take(4) {
            writeln!(
                f,
                "  credit leak: node {} {:?}: credits {} + buffered {} != {}",
                v.node.0, v.out, v.credits, v.buffered, v.expected
            )?;
        }
        Ok(())
    }
}

/// The simulator.
pub struct Network {
    pub cfg: NetworkConfig,
    routers: Vec<Router>,
    /// Per-node: packets not yet fully injected, FIFO.
    ni_queues: Vec<VecDeque<Pending>>,
    /// Packets scheduled for the future, sorted descending by inject_at
    /// (pop from the back).
    schedule: Vec<PacketSpec>,
    /// Per-packet bookkeeping (id → meta).
    meta: std::collections::HashMap<u64, PacketMeta>,
    /// Egress decoder model; `None` = codec-blind 1-flit/cycle ejection.
    egress_cfg: Option<EgressCodecConfig>,
    /// Per-node egress decoder state (parallel to `routers`).
    egress: Vec<EgressPort>,
    /// Seeded link-fault injector; `None` = ideal lossless links.
    fault: Option<FaultModel>,
    /// NACKed packets waiting out their retransmission backoff.
    retry_queue: Vec<RetryEntry>,
    /// NACK-retry budget/backoff policy (ISSUE 9): defaults to the
    /// ISSUE 6 paper point; [`Network::set_fault_model`] adopts the
    /// attached model's policy, [`Network::set_retry_config`] overrides.
    retry: RetryConfig,
    /// Ingress encoder model; `None` = codec-blind unbounded-NI
    /// injection (ISSUE 7).
    ingress_cfg: Option<IngressCodecConfig>,
    /// Per-node ingress encoder state (parallel to `routers`).
    ingress: Vec<IngressPort>,
    /// Scheduled permanent link failures not yet applied (ascending).
    pending_link_downs: Vec<LinkDown>,
    /// `down[node][port]` = that directed output is permanently dead.
    down: LinkState,
    /// Escape routing tables, installed at the first link failure; all
    /// routing then follows the tables (one discipline at a time).
    escape: Option<EscapeRoutes>,
    /// Specs abandoned because their destination was severed.
    unreachable: Vec<PacketSpec>,
    /// Zero-progress window before the watchdog fires; `None` uses
    /// [`DEFAULT_WATCHDOG_CYCLES`].
    watchdog_cycles: Option<u64>,
    /// Cycle of the last observed global progress.
    last_progress: u64,
    /// Completion records.
    pub records: Vec<PacketRecord>,
    now: u64,
    next_id: u64,
    stats: SimStats,
}

impl Network {
    /// Build an idle network with codec-blind ejection.
    pub fn new(cfg: NetworkConfig) -> Self {
        let n = cfg.mesh.len();
        Network {
            cfg,
            routers: (0..n).map(|_| Router::new(cfg.buf_depth)).collect(),
            ni_queues: vec![VecDeque::new(); n],
            schedule: Vec::new(),
            meta: std::collections::HashMap::new(),
            egress_cfg: None,
            egress: vec![EgressPort::default(); n],
            fault: None,
            retry_queue: Vec::new(),
            retry: RetryConfig::paper_default(),
            ingress_cfg: None,
            ingress: vec![IngressPort::default(); n],
            pending_link_downs: Vec::new(),
            down: vec![[false; NUM_PORTS]; n],
            escape: None,
            unreachable: Vec::new(),
            watchdog_cycles: None,
            last_progress: 0,
            records: Vec::new(),
            now: 0,
            next_id: 0,
            stats: SimStats {
                link_faults: vec![0; n],
                ..SimStats::default()
            },
        }
    }

    /// Build a network whose Local ports drain codec-tagged packets
    /// through the egress decoder model.
    pub fn with_egress(cfg: NetworkConfig, egress: EgressCodecConfig) -> Self {
        let mut net = Self::new(cfg);
        net.egress_cfg = Some(egress);
        net
    }

    /// Build a network whose links run through a seeded fault injector.
    pub fn with_faults(cfg: NetworkConfig, fault: FaultModel) -> Self {
        let mut net = Self::new(cfg);
        net.fault = Some(fault);
        net
    }

    /// Build a network that paces injection through the ingress encoder
    /// model (ISSUE 7) — the encode-side mirror of
    /// [`Network::with_egress`].
    pub fn with_ingress(cfg: NetworkConfig, ingress: IngressCodecConfig) -> Self {
        let mut net = Self::new(cfg);
        net.ingress_cfg = Some(ingress);
        net
    }

    /// Attach (or replace) the ingress encoder config. Composes with
    /// egress + faults for full-duplex codec ports.
    pub fn set_ingress_config(&mut self, ingress: IngressCodecConfig) {
        self.ingress_cfg = Some(ingress);
    }

    /// Attach (or replace) the link fault model. Composes with
    /// [`Network::with_egress`] — the CLI builds egress + faults.
    /// Scheduled permanent link failures are ingested here; every pair
    /// must be mesh-adjacent (programmer error otherwise — the CLI
    /// validates untrusted input before building the model).
    pub fn set_fault_model(&mut self, fault: FaultModel) {
        for e in fault.link_downs() {
            assert!(
                self.adjacent_port(e.a, e.b).is_some(),
                "link-down pair {}-{} is not mesh-adjacent",
                e.a.0,
                e.b.0
            );
        }
        self.pending_link_downs = fault.link_downs().to_vec();
        self.retry = fault.retry();
        self.fault = Some(fault);
    }

    /// Override the NACK-retry budget/backoff policy directly (without
    /// attaching a fault model). Retries also arise from permanent
    /// link-down truncation, so the policy matters even fault-model-free.
    pub fn set_retry_config(&mut self, retry: RetryConfig) {
        self.retry = retry;
    }

    /// The active NACK-retry policy.
    pub fn retry_config(&self) -> RetryConfig {
        self.retry
    }

    /// The output port of `a` that reaches `b`, if the two are adjacent.
    fn adjacent_port(&self, a: NodeId, b: NodeId) -> Option<Port> {
        Port::ALL[1..]
            .iter()
            .copied()
            .find(|&p| self.cfg.mesh.neighbour(a, p) == Some(b))
    }

    /// The installed fault model, if any.
    pub fn fault_model(&self) -> Option<&FaultModel> {
        self.fault.as_ref()
    }

    /// The installed egress decoder config, if any.
    pub fn egress_config(&self) -> Option<&EgressCodecConfig> {
        self.egress_cfg.as_ref()
    }

    /// Per-node egress decoder state (read-only view for tests/tools).
    pub fn egress_ports(&self) -> &[EgressPort] {
        &self.egress
    }

    /// The installed ingress encoder config, if any.
    pub fn ingress_config(&self) -> Option<&IngressCodecConfig> {
        self.ingress_cfg.as_ref()
    }

    /// Per-node ingress encoder state (read-only view for tests/tools).
    pub fn ingress_ports(&self) -> &[IngressPort] {
        &self.ingress
    }

    /// Override the zero-progress watchdog window, in cycles.
    pub fn set_watchdog(&mut self, cycles: u64) {
        self.watchdog_cycles = Some(cycles.max(1));
    }

    /// Specs abandoned because their destination became unreachable
    /// (typed counterpart of [`SimStats::packets_unreachable`]).
    pub fn unreachable_packets(&self) -> &[PacketSpec] {
        &self.unreachable
    }

    /// Schedule packets after validating their codec tags: a tag whose
    /// symbol count exceeds the packet's wire bits (every coded symbol
    /// costs at least one bit) or that rides a zero-size packet is
    /// rejected up front — a bogus count must never reach the egress
    /// cost model and mis-charge the decoder.
    pub fn try_schedule_packets(&mut self, specs: &[PacketSpec]) -> Result<()> {
        for (i, s) in specs.iter().enumerate() {
            self.validate_spec(s, i)?;
        }
        self.schedule.extend_from_slice(specs);
        // Descending by inject time so due packets pop O(1) from the back.
        self.schedule
            .sort_by_key(|s| std::cmp::Reverse(s.inject_at));
        Ok(())
    }

    /// Tag sanity plus, once any link has died, live-route existence —
    /// a packet to a severed destination is refused up front rather
    /// than admitted and purged later.
    fn validate_spec(&self, s: &PacketSpec, i: usize) -> Result<()> {
        if let Some(tag) = s.codec {
            if s.size_bits == 0 {
                return Err(Error::InvalidParameter(format!(
                    "packet {i}: codec tag on a zero-size packet"
                )));
            }
            if tag.symbols > s.size_bits {
                return Err(Error::InvalidParameter(format!(
                    "packet {i}: {} symbols cannot fit in {} wire bits \
                     (≥ 1 coded bit per symbol)",
                    tag.symbols, s.size_bits
                )));
            }
        }
        if let Some(esc) = &self.escape {
            if !esc.reachable(s.src, s.dest) {
                return Err(Error::Unreachable {
                    src: s.src.0,
                    dest: s.dest.0,
                });
            }
        }
        Ok(())
    }

    /// Schedule a set of packets (any order). Panics on invalid codec
    /// tags; use [`Network::try_schedule_packets`] for untrusted specs.
    pub fn schedule_packets(&mut self, specs: &[PacketSpec]) {
        self.try_schedule_packets(specs)
            .expect("valid packet specs");
    }

    /// Closed-loop injection (ISSUE 7): admit one packet *now* if its
    /// source NI has room, else refuse with a typed error so the
    /// traffic generator feels the backpressure immediately. Refusals
    /// are counted in [`SimStats::injections_refused`]; the caller
    /// retries on a later cycle. Without an ingress config the NI is
    /// unbounded and admission always succeeds.
    pub fn try_inject(&mut self, spec: PacketSpec) -> Result<()> {
        self.validate_spec(&spec, 0)?;
        if let Some(icfg) = &self.ingress_cfg {
            let depth = self.ni_queues[spec.src.0 as usize].len();
            if depth >= icfg.max_queue {
                self.stats.injections_refused += 1;
                return Err(Error::IngressSaturated {
                    node: spec.src.0,
                    depth,
                });
            }
        }
        // Clamp the scheduled time to "now": closed-loop callers decide
        // *when* by calling between steps, and a future stamp would
        // underflow the queueing-delay clock.
        let spec = PacketSpec {
            inject_at: spec.inject_at.min(self.now),
            ..spec
        };
        self.activate(spec, 0, None);
        Ok(())
    }

    /// Materialize one packet at its source NI: meta entry + lazy-flit
    /// pending record. Shared by scheduled activation, retransmission,
    /// and closed-loop injection.
    fn activate(&mut self, spec: PacketSpec, attempt: u32, first_inject: Option<u64>) {
        let id = self.next_id;
        self.next_id += 1;
        let total = spec.flits(self.cfg.flit_bits);
        self.meta.insert(
            id,
            PacketMeta {
                spec,
                total_flits: total,
                head_inject: None,
                decode_stalls: 0,
                encode_stalls: 0,
                corrupted: false,
                attempt,
                first_inject,
            },
        );
        self.ni_queues[spec.src.0 as usize].push_back(Pending {
            id,
            spec,
            total_flits: total,
            emitted: 0,
        });
    }

    /// Current cycle.
    pub fn now(&self) -> u64 {
        self.now
    }

    /// Are all queues, buffers, schedules and retry backoffs empty?
    ///
    /// O(1): every activated packet holds a `meta` entry until its tail
    /// ejects, so outstanding work ⇔ `schedule`, `meta` or `retry_queue`
    /// non-empty. The exhaustive buffer walk survives as a debug
    /// assertion.
    pub fn drained(&self) -> bool {
        let done =
            self.schedule.is_empty() && self.meta.is_empty() && self.retry_queue.is_empty();
        debug_assert!(
            !done
                || (self.ni_queues.iter().all(|q| q.is_empty())
                    && self
                        .routers
                        .iter()
                        .all(|r| r.inputs.iter().all(|b| b.fifo.is_empty()))),
            "meta empty but flits still buffered"
        );
        done
    }

    /// Advance one cycle.
    pub fn step(&mut self) {
        let mesh = self.cfg.mesh;
        // One branch per step keeps the fault-off hot path at parity
        // with a fault-less build (perf gate: ≤1.05× the egress row).
        let faults_on = self.fault.as_ref().is_some_and(|f| f.enabled());
        // Watchdog progress observation (ISSUE 7): any flit movement,
        // packet activation or injection this cycle counts as progress.
        // Cheap counters only on the hot path — the heavy diagnosis
        // runs once, at fire time.
        let moved0 = self.stats.delivered_flits + self.stats.flit_hops;
        let id0 = self.next_id;
        let mut progressed = false;

        // --- 0. scheduled permanent link failures (rare) ------------------
        if !self.pending_link_downs.is_empty() {
            while let Some(&e) = self.pending_link_downs.first() {
                if e.at > self.now {
                    break;
                }
                self.pending_link_downs.remove(0);
                // Truncation/purge *is* forward motion for the watchdog.
                progressed |= self.apply_link_down(e.a, e.b);
            }
        }

        // --- 1. activation of scheduled packets --------------------------
        // With ingress codec ports the NI queue is bounded: due
        // arrivals beyond the bound are deferred to later cycles
        // (refusals counted) instead of growing an unbounded queue.
        let mut deferred: Vec<PacketSpec> = Vec::new();
        while let Some(last) = self.schedule.last() {
            if last.inject_at > self.now {
                break;
            }
            let spec = self.schedule.pop().expect("non-empty");
            if let Some(icfg) = &self.ingress_cfg {
                if self.ni_queues[spec.src.0 as usize].len() >= icfg.max_queue {
                    self.stats.injections_refused += 1;
                    deferred.push(spec);
                    continue;
                }
            }
            self.activate(spec, 0, None);
        }
        if !deferred.is_empty() {
            // Re-append at the back: deferred specs are already due, so
            // they stay the schedule's minimum and pop first next cycle.
            self.schedule.extend(deferred);
        }

        // --- 1b. retransmissions whose backoff has elapsed ----------------
        if !self.retry_queue.is_empty() {
            let mut i = 0;
            while i < self.retry_queue.len() {
                if self.retry_queue[i].due > self.now {
                    i += 1;
                    continue;
                }
                let e = self.retry_queue.swap_remove(i);
                // Retries bypass the NI bound: their population is
                // bounded by already-admitted packets, and stalling
                // recovery would leak the bound into the retry budget.
                self.activate(e.spec, e.attempt, Some(e.first_inject));
            }
        }

        // --- 2. injection: one flit per node per cycle --------------------
        let cycle_ns = self.cfg.cycle_ns();
        for (node, q) in self.ni_queues.iter_mut().enumerate() {
            if let Some(p) = q.front_mut() {
                if (self.routers[node].inputs[Port::Local as usize].fifo.len() as u32)
                    < self.cfg.buf_depth
                {
                    // Ingress codec port (ISSUE 7): a tagged flit must
                    // clear the encoder before entering the network.
                    let mut pace: Option<f64> = None;
                    if let (Some(icfg), Some(tag)) = (self.ingress_cfg.as_ref(), p.spec.codec)
                    {
                        if !egress::ready(self.ingress[node].busy_until, self.now) {
                            // Encoder backlogged: the packet stays at
                            // the NI and the stall is counted, never
                            // silently absorbed.
                            self.ingress[node].stall_cycles += 1;
                            self.stats.encode_stall_cycles += 1;
                            self.meta
                                .get_mut(&p.id)
                                .expect("queued packet has meta")
                                .encode_stalls += 1;
                            continue;
                        }
                        // Startup (codebook build) is charged once, on
                        // the head flit of the *first* attempt — a
                        // retransmission replays the encoded stream.
                        let charge_startup =
                            p.emitted == 0 && self.meta[&p.id].attempt == 0;
                        pace = Some(icfg.flit_cost_cycles(
                            &tag,
                            p.total_flits,
                            charge_startup,
                            cycle_ns,
                        ));
                    }
                    let seq = p.emitted;
                    let kind = match (seq, p.total_flits) {
                        (0, 1) => FlitKind::Single,
                        (0, _) => FlitKind::Head,
                        (s, t) if s + 1 == t => FlitKind::Tail,
                        _ => FlitKind::Body,
                    };
                    if seq == 0 {
                        // The latency clock starts when the head actually
                        // enters the network, not at the scheduled time.
                        self.meta
                            .get_mut(&p.id)
                            .expect("activated packet has meta")
                            .head_inject = Some(self.now);
                    }
                    self.routers[node].inputs[Port::Local as usize]
                        .fifo
                        .push_back(Flit {
                            packet_id: p.id,
                            kind,
                            src: p.spec.src,
                            dest: p.spec.dest,
                            seq,
                            ready_at: self.now + 1,
                            codec: p.spec.codec,
                        });
                    if let Some(cost) = pace {
                        self.ingress[node].busy_until =
                            egress::accept(self.ingress[node].busy_until, self.now, cost);
                    }
                    progressed = true;
                    p.emitted += 1;
                    if p.emitted == p.total_flits {
                        q.pop_front();
                    }
                }
            }
        }

        // --- 3. forwarding / ejection -------------------------------------
        for node in 0..self.routers.len() {
            // §Perf: idle routers (all input FIFOs empty) skip arbitration
            // entirely — a large win under sparse/hotspot traffic.
            if self.routers[node].inputs.iter().all(|b| b.fifo.is_empty()) {
                continue;
            }
            let at = NodeId(node as u16);
            // Healthy mesh: pure XY (deadlock-free, zero table cost).
            // After any permanent link failure: every flit follows the
            // up*/down* escape tables — one routing discipline at a
            // time, or the two could form a cycle between them.
            let grants = match self.escape.as_ref() {
                None => self.routers[node]
                    .arbitrate_all(self.now, |_, f| mesh.route_xy(at, f.dest)),
                Some(esc) => self.routers[node].arbitrate_all(self.now, |inp, f| {
                    esc.next_hop(at, inp, f.dest)
                        .expect("unroutable flits are truncated at link-down time")
                }),
            };
            for &out in &Port::ALL {
                let Some(inp) = grants[out as usize] else { continue };

                if out == Port::Local {
                    // Ejection: codec-blind packets drain 1 flit/cycle;
                    // tagged packets must clear the egress decoder first.
                    let hol = *self.routers[node].inputs[inp]
                        .fifo
                        .front()
                        .expect("arbitrated input non-empty");
                    let mut decode_done: Option<f64> = None;
                    if let (Some(ecfg), Some(tag)) = (self.egress_cfg, hol.codec) {
                        let port = &mut self.egress[node];
                        if !egress::ready(port.busy_until, self.now) {
                            // Decoder backlogged: the flit stays in the
                            // local input buffer (no pop ⇒ no credit
                            // upstream ⇒ backpressure into the mesh).
                            port.stall_cycles += 1;
                            self.stats.decode_stall_cycles += 1;
                            self.meta
                                .get_mut(&hol.packet_id)
                                .expect("in-flight packet has meta")
                                .decode_stalls += 1;
                            continue;
                        }
                        let total = self.meta[&hol.packet_id].total_flits;
                        let cost = ecfg.flit_cost_cycles(
                            &tag,
                            total,
                            hol.is_head(),
                            self.cfg.cycle_ns(),
                        );
                        port.busy_until = egress::accept(port.busy_until, self.now, cost);
                        decode_done = Some(port.busy_until);
                    }
                    let flit = self.routers[node].inputs[inp]
                        .fifo
                        .pop_front()
                        .expect("arbitrated input non-empty");
                    self.credit_return(at, inp);
                    self.update_lock(node, out, inp, &flit);
                    self.stats.delivered_flits += 1;
                    if flit.is_tail() {
                        let m = self.meta.remove(&flit.packet_id).expect("meta");
                        // Latency spans the *original* head injection —
                        // retransmission backoff and repeat trips are
                        // charged to the packet, not hidden.
                        let inject_cycle = m
                            .first_inject
                            .or(m.head_inject)
                            .expect("tail ejected before head injected");
                        if m.corrupted {
                            // NACK: the egress CRC check failed (the
                            // speculative decode cost stays charged).
                            // Retransmit after an exponential backoff, or
                            // report the loss once the budget is spent —
                            // never hang, never silently deliver garbage.
                            if m.attempt < self.retry.budget {
                                let next = m.attempt + 1;
                                self.stats.packet_retries += 1;
                                self.retry_queue.push(RetryEntry {
                                    spec: m.spec,
                                    due: self.now + 1 + self.retry.backoff(next),
                                    attempt: next,
                                    first_inject: inject_cycle,
                                });
                            } else {
                                self.stats.packets_dropped += 1;
                            }
                            continue;
                        }
                        // A tagged packet completes when its decoder
                        // finishes the tail flit's symbols, which can
                        // trail the ejection itself.
                        let eject_cycle = match decode_done {
                            Some(busy) => (self.now + 1).max(busy.ceil() as u64),
                            None => self.now + 1,
                        };
                        let rec = PacketRecord {
                            spec: m.spec,
                            inject_cycle,
                            eject_cycle,
                            flits: m.total_flits,
                            decode_stall_cycles: m.decode_stalls,
                            encode_stall_cycles: m.encode_stalls,
                            retries: m.attempt,
                        };
                        self.stats.delivered_packets += 1;
                        self.stats.sum_latency += rec.latency();
                        self.stats.max_latency = self.stats.max_latency.max(rec.latency());
                        self.stats.sum_queueing += rec.queueing_delay();
                        if let Some(tag) = m.spec.codec {
                            self.stats.delivered_symbols += tag.symbols;
                        }
                        self.stats.completion_cycle =
                            self.stats.completion_cycle.max(eject_cycle);
                        self.records.push(rec);
                    }
                    continue;
                }

                // Link traversal: need a credit downstream.
                if self.routers[node].outputs[out as usize].credits == 0 {
                    continue;
                }
                let Some(nb) = mesh.neighbour(at, out) else {
                    unreachable!("routing never exits the mesh");
                };
                if faults_on && self.fault.as_mut().expect("gated").drops() {
                    // The link ate the flit: it stays at the FIFO head and
                    // retries next cycle (link-level ARQ), so a wormhole
                    // body can never vanish from the middle of a packet.
                    self.stats.flits_dropped += 1;
                    self.stats.link_faults[node] += 1;
                    continue;
                }
                let mut flit = self.routers[node].inputs[inp]
                    .fifo
                    .pop_front()
                    .expect("arbitrated input non-empty");
                self.credit_return(at, inp);
                self.update_lock(node, out, inp, &flit);
                self.routers[node].outputs[out as usize].credits -= 1;
                self.routers[node].outputs[out as usize].forwarded += 1;
                self.stats.flit_hops += 1;
                flit.ready_at = self.now + 1;
                if faults_on {
                    let flit_bits = self.cfg.flit_bits;
                    if self.fault.as_mut().expect("gated").corrupts(flit_bits) {
                        // Payload bits flipped in flight. The per-lane CRC
                        // (lexi-core::integrity) catches it at egress
                        // decode; the tail ejection NACKs instead of
                        // recording delivery.
                        self.stats.flits_corrupted += 1;
                        self.stats.link_faults[node] += 1;
                        self.meta
                            .get_mut(&flit.packet_id)
                            .expect("in-flight packet has meta")
                            .corrupted = true;
                    }
                    if self.fault.as_mut().expect("gated").duplicates() {
                        // The receiver squashes the copy by sequence
                        // number; the echo costs one extra cycle of
                        // downstream occupancy.
                        self.stats.flits_duplicated += 1;
                        self.stats.link_faults[node] += 1;
                        flit.ready_at = self.now + 2;
                    }
                }
                self.routers[nb.0 as usize].inputs[out.opposite() as usize]
                    .fifo
                    .push_back(flit);
            }
        }

        self.now += 1;
        self.stats.cycles = self.now;
        if progressed
            || self.stats.delivered_flits + self.stats.flit_hops != moved0
            || self.next_id != id0
        {
            self.last_progress = self.now;
        }
    }

    /// Run until every scheduled packet is delivered (or `max_cycles`).
    /// Returns stats; panics with the [`StallReport`] if the network
    /// wedges or fails to drain in time — use
    /// [`Network::try_run_to_completion`] to handle stalls as values.
    pub fn run_to_completion(&mut self, max_cycles: u64) -> SimStats {
        match self.try_run_to_completion(max_cycles) {
            Ok(stats) => stats,
            Err(report) => panic!("network failed to drain: {report}"),
        }
    }

    /// Run until drained, the watchdog fires, or `max_cycles` elapse
    /// (ISSUE 7). The watchdog fires when nothing has moved for the
    /// watchdog window AND no scheduled arrival or retry backoff is
    /// still pending (a future-due entry is guaranteed progress, not a
    /// stall), so no input can make this loop forever. On fire — or on
    /// timeout — the typed [`StallReport`] carries the stuck packets,
    /// a credit-conservation audit, and a suspected cause.
    pub fn try_run_to_completion(
        &mut self,
        max_cycles: u64,
    ) -> std::result::Result<SimStats, StallReport> {
        let window = self.watchdog_cycles.unwrap_or(DEFAULT_WATCHDOG_CYCLES);
        while !self.drained() {
            let stalled_for = self.now - self.last_progress;
            if stalled_for >= window && !self.future_work_pending() {
                return Err(self.diagnose(stalled_for, false));
            }
            if self.now >= max_cycles {
                return Err(self.diagnose(stalled_for, true));
            }
            self.step();
        }
        Ok(self.stats.clone())
    }

    /// A scheduled arrival or retry backoff strictly in the future is
    /// guaranteed forward motion — the watchdog must not fire over a
    /// quiet spell it can prove will end. Both horizons are bounded
    /// (backoff caps at 256 cycles; the schedule is finite), so this
    /// can never postpone a genuine-wedge verdict forever.
    fn future_work_pending(&self) -> bool {
        self.retry_queue.iter().any(|e| e.due > self.now)
            || self
                .schedule
                .last()
                .map_or(false, |s| s.inject_at > self.now)
    }

    /// Verify per-link credit conservation: for every directed link,
    /// the upstream output's credits plus the downstream input's
    /// buffered flits must equal `buf_depth`. Forwarding and credit
    /// return are same-cycle, and wormhole truncation returns credits
    /// for every discarded flit, so the invariant holds on *every*
    /// cycle — including across dead links.
    pub fn audit_credits(&self) -> Vec<CreditViolation> {
        let mut violations = Vec::new();
        for node in 0..self.routers.len() {
            let at = NodeId(node as u16);
            for &out in &Port::ALL[1..] {
                let Some(nb) = self.cfg.mesh.neighbour(at, out) else {
                    continue;
                };
                let credits = self.routers[node].outputs[out as usize].credits;
                let buffered = self.routers[nb.0 as usize].inputs
                    [out.opposite() as usize]
                    .fifo
                    .len() as u32;
                if credits + buffered != self.cfg.buf_depth {
                    violations.push(CreditViolation {
                        node: at,
                        out,
                        credits,
                        buffered,
                        expected: self.cfg.buf_depth,
                    });
                }
            }
        }
        violations
    }

    /// Build the fire-time [`StallReport`]: full credit audit, stuck
    /// packets with their holding node/port, and a cause heuristic —
    /// all deliberately off the hot path.
    fn diagnose(&self, stalled_for: u64, timed_out: bool) -> StallReport {
        let credit_audit = self.audit_credits();
        // Locate each live packet's foremost buffered flit.
        let mut loc: std::collections::HashMap<u64, (NodeId, Port, u32, u64)> =
            std::collections::HashMap::new();
        for (node, r) in self.routers.iter().enumerate() {
            for (inp, buf) in r.inputs.iter().enumerate() {
                for f in &buf.fifo {
                    let here = (NodeId(node as u16), Port::ALL[inp], f.seq, f.ready_at);
                    loc.entry(f.packet_id)
                        .and_modify(|e| {
                            if f.seq < e.2 {
                                *e = here;
                            }
                        })
                        .or_insert(here);
                }
            }
        }
        let mut stuck_packets: Vec<StuckPacket> = self
            .meta
            .iter()
            .map(|(&id, m)| {
                let (node, port, _, ready) = loc.get(&id).copied().unwrap_or((
                    m.spec.src,
                    Port::Local,
                    0,
                    m.head_inject.unwrap_or(m.spec.inject_at) + 1,
                ));
                StuckPacket {
                    id,
                    src: m.spec.src,
                    dest: m.spec.dest,
                    node,
                    port,
                    since: ready.saturating_sub(1),
                }
            })
            .collect();
        stuck_packets.sort_by_key(|s| s.id);
        let window = self.watchdog_cycles.unwrap_or(DEFAULT_WATCHDOG_CYCLES);
        let cause = if timed_out && stalled_for < window {
            StallCause::SlowProgress
        } else if !credit_audit.is_empty() {
            StallCause::CreditLeak
        } else if self.zero_rate_port_suspected() {
            StallCause::ZeroRatePort
        } else if self.stats.links_down > 0
            || self.fault.as_ref().map_or(false, |f| f.drop_prob() >= 1.0)
        {
            StallCause::DeadLink
        } else {
            StallCause::RoutingCycle
        };
        StallReport {
            cycle: self.now,
            stalled_for,
            cause,
            stuck_packets,
            credit_audit,
        }
    }

    /// A codec port whose busy horizon is still ahead of `now` after an
    /// entire zero-progress window never accepted during it: it is
    /// refusing every grant at an effectively zero rate.
    fn zero_rate_port_suspected(&self) -> bool {
        let horizon = self.now as f64;
        self.egress.iter().any(|p| p.busy_until > horizon)
            || self.ingress.iter().any(|p| p.busy_until > horizon)
    }

    /// Kill the `a`↔`b` link immediately (both directions). Prefer
    /// scheduling via [`FaultModel::with_link_down`]; this is the
    /// validated immediate-mode entry tests and tools share.
    pub fn down_link(&mut self, a: NodeId, b: NodeId) -> Result<()> {
        if self.adjacent_port(a, b).is_none() {
            return Err(Error::InvalidParameter(format!(
                "link-down pair {}-{} is not mesh-adjacent",
                a.0, b.0
            )));
        }
        self.apply_link_down(a, b);
        Ok(())
    }

    /// Apply one permanent link failure: mark both directions dead,
    /// rebuild the escape tables, truncate severed/unroutable worms,
    /// purge newly-unreachable packets. Returns true if anything
    /// changed (truncation counts as watchdog progress). Idempotent.
    fn apply_link_down(&mut self, a: NodeId, b: NodeId) -> bool {
        let pab = self.adjacent_port(a, b).expect("validated adjacency");
        let pba = pab.opposite();
        if self.down[a.0 as usize][pab as usize] {
            return false; // already dead
        }
        self.down[a.0 as usize][pab as usize] = true;
        self.down[b.0 as usize][pba as usize] = true;
        self.stats.links_down += 1;

        // New escape tables over the survivor topology; all routing
        // follows them from here on.
        self.escape = Some(EscapeRoutes::compute(self.cfg.mesh, &self.down));

        let (victims, purge, sched_gone, retry_gone) = {
            let esc = self.escape.as_ref().expect("just installed");
            // Victims: (1) worms locked through the dead directed
            // links; (2) flits with no legal escape continuation
            // (stranded down-phase, or destination severed); (3) worms
            // whose locked output no longer matches the table hop —
            // forwarding those would split the worm mid-body.
            let mut victims: Vec<u64> = Vec::new();
            for (u, pout) in [(a, pab), (b, pba)] {
                if let Some(pid) =
                    self.routers[u.0 as usize].outputs[pout as usize].locked_packet
                {
                    victims.push(pid);
                }
            }
            for (node, r) in self.routers.iter().enumerate() {
                let at = NodeId(node as u16);
                for (inp, buf) in r.inputs.iter().enumerate() {
                    for f in &buf.fifo {
                        if esc.next_hop(at, inp, f.dest).is_none() {
                            victims.push(f.packet_id);
                        }
                    }
                }
                for (out, o) in r.outputs.iter().enumerate() {
                    let (Some(pid), Some(inp)) = (o.locked_packet, o.locked_to) else {
                        continue;
                    };
                    let Some(m) = self.meta.get(&pid) else { continue };
                    if esc.next_hop(at, inp, m.spec.dest) != Some(Port::ALL[out]) {
                        victims.push(pid);
                    }
                }
            }
            victims.sort_unstable();
            victims.dedup();

            // Packets waiting at NIs or in the schedule/retry queue
            // whose destination is now severed: typed unreachability.
            let mut purge: Vec<u64> = Vec::new();
            for q in &self.ni_queues {
                for p in q {
                    if !esc.reachable(p.spec.src, p.spec.dest) {
                        purge.push(p.id);
                    }
                }
            }
            let sched = std::mem::take(&mut self.schedule);
            let (sched_keep, sched_gone): (Vec<_>, Vec<_>) = sched
                .into_iter()
                .partition(|s| esc.reachable(s.src, s.dest));
            self.schedule = sched_keep;
            let retries = std::mem::take(&mut self.retry_queue);
            let (retry_keep, retry_gone): (Vec<_>, Vec<_>) = retries
                .into_iter()
                .partition(|e| esc.reachable(e.spec.src, e.spec.dest));
            self.retry_queue = retry_keep;
            (victims, purge, sched_gone, retry_gone)
        };

        let progressed = !victims.is_empty()
            || !purge.is_empty()
            || !sched_gone.is_empty()
            || !retry_gone.is_empty();
        for s in sched_gone {
            self.stats.packets_unreachable += 1;
            self.unreachable.push(s);
        }
        for e in retry_gone {
            self.stats.packets_unreachable += 1;
            self.unreachable.push(e.spec);
        }
        for pid in victims.into_iter().chain(purge) {
            self.truncate_packet(pid);
        }
        progressed
    }

    /// Drain every trace of packet `pid` from the network: buffered
    /// flits are discarded with their credits returned (so per-link
    /// conservation holds through the failure), wormhole locks are
    /// released, and the NI remainder is dropped. The packet is then
    /// NACK-retried under the retry budget — or reported
    /// unreachable/dropped. Exactly the ISSUE 6 recovery path, entered
    /// from a cut instead of a CRC failure.
    fn truncate_packet(&mut self, pid: u64) {
        let Some(m) = self.meta.remove(&pid) else {
            return; // already truncated in this application
        };
        for node in 0..self.routers.len() {
            let at = NodeId(node as u16);
            for inp in 0..NUM_PORTS {
                let removed = {
                    let fifo = &mut self.routers[node].inputs[inp].fifo;
                    let before = fifo.len();
                    fifo.retain(|f| f.packet_id != pid);
                    before - fifo.len()
                };
                for _ in 0..removed {
                    self.credit_return(at, inp);
                }
            }
            for o in self.routers[node].outputs.iter_mut() {
                if o.locked_packet == Some(pid) {
                    o.locked_to = None;
                    o.locked_packet = None;
                }
            }
        }
        self.ni_queues[m.spec.src.0 as usize].retain(|p| p.id != pid);
        if m.head_inject.is_some() {
            // Only a packet with flits in flight was truly truncated; a
            // purged never-injected packet is just unreachable.
            self.stats.packets_truncated += 1;
        }
        let reachable = self
            .escape
            .as_ref()
            .map_or(true, |e| e.reachable(m.spec.src, m.spec.dest));
        if !reachable {
            self.stats.packets_unreachable += 1;
            self.unreachable.push(m.spec);
        } else if m.attempt < self.retry.budget {
            let next = m.attempt + 1;
            self.stats.packet_retries += 1;
            self.retry_queue.push(RetryEntry {
                spec: m.spec,
                due: self.now + 1 + self.retry.backoff(next),
                attempt: next,
                first_inject: m.first_inject.or(m.head_inject).unwrap_or(self.now),
            });
        } else {
            self.stats.packets_dropped += 1;
        }
    }

    /// Stats so far.
    pub fn stats(&self) -> &SimStats {
        &self.stats
    }

    /// Total directed links in the mesh (for utilization).
    pub fn link_count(&self) -> u64 {
        let (c, r) = (self.cfg.mesh.cols as u64, self.cfg.mesh.rows as u64);
        2 * (r * (c - 1) + c * (r - 1))
    }

    /// A flit left `inp` of router `at`: return one credit upstream.
    fn credit_return(&mut self, at: NodeId, inp: usize) {
        if inp == Port::Local as usize {
            return; // NI injection checks occupancy directly.
        }
        let in_port = Port::ALL[inp];
        // The upstream neighbour sits in the direction of the input port
        // and fed us through its opposite output.
        if let Some(up) = self.cfg.mesh.neighbour(at, in_port) {
            let up_out = in_port.opposite() as usize;
            self.routers[up.0 as usize].outputs[up_out].credits += 1;
        }
    }

    /// Wormhole lock bookkeeping after forwarding `flit` inp→out.
    fn update_lock(&mut self, node: usize, out: Port, inp: usize, flit: &Flit) {
        let o = &mut self.routers[node].outputs[out as usize];
        if flit.is_tail() {
            o.locked_to = None;
            o.locked_packet = None;
            o.rr = (inp + 1) % NUM_PORTS;
        } else {
            o.locked_to = Some(inp);
            o.locked_packet = Some(flit.packet_id);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fault::{retry_backoff, RETRY_BUDGET};
    use crate::packet::CodecTag;
    use lexi_core::codec::CodecKind;

    fn cfg_4x4() -> NetworkConfig {
        NetworkConfig {
            mesh: Mesh::new(4, 4),
            flit_bits: 128,
            link_gbps: 100.0,
            buf_depth: 4,
        }
    }

    #[test]
    fn single_packet_minimal_latency() {
        let cfg = cfg_4x4();
        let mut net = Network::new(cfg);
        let spec = PacketSpec::new(NodeId(0), NodeId(3), 128 * 4, 0); // 3 hops east
        net.schedule_packets(&[spec]);
        let stats = net.run_to_completion(1000);
        assert_eq!(stats.delivered_packets, 1);
        let rec = net.records[0];
        // Lower bound: injection (1) + hops (3) + serialization (3 more
        // flits) + ejection; exact value depends on the pipeline model —
        // assert a tight band, not an exact constant.
        let lb = 3 + 4 - 1;
        assert!(
            (lb..lb + 8).contains(&rec.latency()),
            "latency {}",
            rec.latency()
        );
        // No contention: the head injects the cycle it is scheduled.
        assert_eq!(rec.queueing_delay(), 0);
    }

    #[test]
    fn self_send_delivers() {
        let mut net = Network::new(cfg_4x4());
        net.schedule_packets(&[PacketSpec::new(NodeId(5), NodeId(5), 64, 0)]);
        let stats = net.run_to_completion(100);
        assert_eq!(stats.delivered_packets, 1);
    }

    #[test]
    fn all_packets_delivered_under_load() {
        let mut net = Network::new(cfg_4x4());
        let mut specs = Vec::new();
        for i in 0..16u16 {
            for j in 0..16u16 {
                if i != j {
                    specs.push(PacketSpec::new(
                        NodeId(i),
                        NodeId(j),
                        128 * 3,
                        (i as u64) * 2,
                    ));
                }
            }
        }
        let n = specs.len() as u64;
        let mut net2 = Network::new(cfg_4x4());
        net2.schedule_packets(&specs);
        let stats = net2.run_to_completion(100_000);
        assert_eq!(stats.delivered_packets, n);
        assert_eq!(stats.delivered_flits, n * 3);
        let _ = &mut net;
    }

    #[test]
    fn wormhole_packets_arrive_contiguously() {
        // With wormhole switching + XY routing, a destination receives each
        // packet's flits in order (seq strictly increasing per packet).
        let mut net = Network::new(cfg_4x4());
        let specs: Vec<PacketSpec> = (0..8u16)
            .map(|i| PacketSpec::new(NodeId(i), NodeId(15), 128 * 8, 0))
            .collect();
        net.schedule_packets(&specs);
        net.run_to_completion(10_000);
        assert_eq!(net.records.len(), 8);
    }

    #[test]
    fn congestion_raises_latency() {
        // Hotspot: everyone sends to node 0 — latency must exceed the
        // uncongested single-sender case.
        let solo = {
            let mut net = Network::new(cfg_4x4());
            net.schedule_packets(&[PacketSpec::new(NodeId(15), NodeId(0), 128 * 16, 0)]);
            net.run_to_completion(10_000).avg_latency()
        };
        let hot = {
            let mut net = Network::new(cfg_4x4());
            let specs: Vec<PacketSpec> = (1..16u16)
                .map(|i| PacketSpec::new(NodeId(i), NodeId(0), 128 * 16, 0))
                .collect();
            net.schedule_packets(&specs);
            net.run_to_completion(100_000).avg_latency()
        };
        assert!(hot > solo * 2.0, "solo {solo} hot {hot}");
    }

    #[test]
    fn throughput_bounded_by_bisection() {
        // Uniform random cannot exceed ~1 flit/cycle/link utilization.
        let mut net = Network::new(cfg_4x4());
        let mut specs = Vec::new();
        for k in 0..400u64 {
            specs.push(PacketSpec::new(
                NodeId((k * 7 % 16) as u16),
                NodeId((k * 11 % 16) as u16),
                128 * 4,
                k / 8,
            ));
        }
        let specs: Vec<_> = specs
            .into_iter()
            .filter(|s| s.src != s.dest)
            .collect();
        let links = {
            let n = Network::new(cfg_4x4());
            n.link_count()
        };
        net.schedule_packets(&specs);
        let stats = net.run_to_completion(1_000_000);
        assert!(stats.link_utilization(links) <= 1.0);
    }

    #[test]
    fn cycle_ns_matches_paper_link() {
        let cfg = NetworkConfig::paper_default();
        assert!((cfg.cycle_ns() - 1.28).abs() < 1e-9);
    }

    #[test]
    fn queueing_delay_excluded_from_latency() {
        // Regression (ISSUE 5 satellite): two packets from one source —
        // the second's head cannot inject until the first's 8 flits have
        // cleared the NI, and that wait must land in queueing_delay, not
        // in latency. (Previously inject_cycle was stamped with the
        // *scheduled* inject_at, silently folding NI queueing into
        // network latency.)
        let mut net = Network::new(cfg_4x4());
        let a = PacketSpec::new(NodeId(0), NodeId(3), 128 * 8, 0);
        let b = PacketSpec::new(NodeId(0), NodeId(3), 128 * 8, 0);
        net.schedule_packets(&[a, b]);
        let stats = net.run_to_completion(10_000);
        assert_eq!(stats.delivered_packets, 2);
        let first = net.records.iter().find(|r| r.queueing_delay() == 0).unwrap();
        let second = net.records.iter().find(|r| r.queueing_delay() > 0).unwrap();
        // Same route, same size, exclusive link ⇒ near-identical network
        // latency for both once queueing is separated out.
        assert!(
            second.latency() <= first.latency() + 2,
            "queueing leaked into latency: first {} vs second {}",
            first.latency(),
            second.latency()
        );
        // The second head waited for ~the first packet's serialization.
        assert!(
            (6..=10).contains(&second.queueing_delay()),
            "queueing {}",
            second.queueing_delay()
        );
        assert_eq!(
            stats.sum_queueing,
            net.records.iter().map(|r| r.queueing_delay()).sum::<u64>()
        );
    }

    fn huff_tag(symbols: u64, runtime_book: bool) -> CodecTag {
        CodecTag {
            kind: CodecKind::Huffman,
            symbols,
            runtime_book,
        }
    }

    #[test]
    fn bogus_codec_tags_rejected() {
        let mut net = Network::new(cfg_4x4());
        // More symbols than wire bits: impossible (≥ 1 bit/symbol).
        let bogus = PacketSpec::new(NodeId(0), NodeId(3), 128, 0).tagged(huff_tag(129, false));
        assert!(net.try_schedule_packets(&[bogus]).is_err());
        // Tag on a zero-size packet.
        let empty = PacketSpec::new(NodeId(0), NodeId(3), 0, 0).tagged(huff_tag(1, false));
        assert!(net.try_schedule_packets(&[empty]).is_err());
        // Nothing was scheduled; the network stays drained.
        assert!(net.drained());
        // A valid tag passes.
        let ok = PacketSpec::new(NodeId(0), NodeId(3), 128, 0).tagged(huff_tag(128, false));
        assert!(net.try_schedule_packets(&[ok]).is_ok());
    }

    #[test]
    fn line_rate_egress_matches_codec_blind_ejection() {
        // Paper point (16 lanes): tagged stepping must deliver in the
        // same cycle count as the codec-blind network (offline book ⇒
        // no startup, decoder hidden behind the wire).
        let spec = PacketSpec::new(NodeId(0), NodeId(15), 128 * 64, 0);
        let blind = {
            let mut net = Network::new(cfg_4x4());
            net.schedule_packets(&[spec]);
            net.run_to_completion(10_000)
        };
        let tagged = {
            let mut net =
                Network::with_egress(cfg_4x4(), EgressCodecConfig::paper_default());
            net.schedule_packets(&[spec.tagged(huff_tag(64 * 8, false))]);
            net.run_to_completion(10_000)
        };
        assert_eq!(blind.cycles, tagged.cycles);
        assert_eq!(tagged.decode_stall_cycles, 0);
        assert_eq!(tagged.delivered_symbols, 64 * 8);
        assert_eq!(tagged.completion_cycle, blind.completion_cycle);
    }

    #[test]
    fn starved_egress_stalls_the_link_and_backpressures() {
        // One decoder lane on a symbol-heavy packet: ejection throttles,
        // stall cycles accrue, and completion stretches to ~the decode
        // makespan instead of the wire time.
        let symbols = 64 * 16u64; // 16 symbols per flit
        let spec =
            PacketSpec::new(NodeId(0), NodeId(15), 128 * 64, 0).tagged(huff_tag(symbols, false));
        let ecfg = EgressCodecConfig::nominal(1, 1.0); // 1.16 cyc/sym at 1 lane
        let cycle_ns = cfg_4x4().cycle_ns();
        let mut net = Network::with_egress(cfg_4x4(), ecfg);
        net.schedule_packets(&[spec]);
        let stats = net.run_to_completion(100_000);
        assert_eq!(stats.delivered_packets, 1);
        assert!(stats.decode_stall_cycles > 0, "no backpressure observed");
        let rec = net.records[0];
        assert_eq!(rec.decode_stall_cycles, stats.decode_stall_cycles);
        // Decode-bound completion ≈ symbols × ns/sym ÷ cycle_ns.
        let decode_cycles = symbols as f64 * ecfg.ns_per_symbol(CodecKind::Huffman) / cycle_ns;
        let done = stats.completion_cycle as f64;
        assert!(
            done >= decode_cycles && done <= decode_cycles * 1.15 + 16.0,
            "completion {done} vs decode bound {decode_cycles}"
        );
    }

    #[test]
    fn runtime_book_startup_charged_on_head_flits() {
        // Identical packets, offline vs runtime book: the runtime one
        // completes later by ~the startup and stalls while the codebook
        // pipeline fills.
        let base = PacketSpec::new(NodeId(0), NodeId(15), 128 * 64, 0);
        let run = |runtime: bool| {
            let mut net =
                Network::with_egress(cfg_4x4(), EgressCodecConfig::paper_default());
            net.schedule_packets(&[base.tagged(huff_tag(64 * 8, runtime))]);
            net.run_to_completion(100_000)
        };
        let offline = run(false);
        let runtime = run(true);
        let cycle_ns = cfg_4x4().cycle_ns();
        let startup_cycles =
            (EgressCodecConfig::paper_default().startup_ns / cycle_ns).ceil() as u64;
        let delta = runtime.completion_cycle - offline.completion_cycle;
        assert!(
            delta >= startup_cycles - 1 && delta <= startup_cycles + 2,
            "startup delta {delta} vs expected {startup_cycles}"
        );
        assert!(runtime.decode_stall_cycles > 0);
        assert_eq!(offline.decode_stall_cycles, 0);
    }

    #[test]
    fn raw_tagged_packets_never_stall() {
        let spec = PacketSpec::new(NodeId(1), NodeId(14), 128 * 32, 0).tagged(CodecTag {
            kind: CodecKind::Raw,
            symbols: 32 * 16,
            runtime_book: false,
        });
        let mut net = Network::with_egress(cfg_4x4(), EgressCodecConfig::nominal(1, 1.0));
        let stats = net.run_to_completion_after(&[spec]);
        assert_eq!(stats.decode_stall_cycles, 0);
        assert_eq!(stats.delivered_symbols, 32 * 16);
    }

    impl Network {
        /// Test helper: schedule then run.
        fn run_to_completion_after(&mut self, specs: &[PacketSpec]) -> SimStats {
            self.schedule_packets(specs);
            self.run_to_completion(1_000_000)
        }
    }

    /// Uniform all-to-all load, 16 flits per packet (240 packets).
    fn uniform_16flit_specs() -> Vec<PacketSpec> {
        let mut specs = Vec::new();
        for i in 0..16u16 {
            for j in 0..16u16 {
                if i != j {
                    specs.push(PacketSpec::new(
                        NodeId(i),
                        NodeId(j),
                        128 * 16,
                        (i as u64) * 2,
                    ));
                }
            }
        }
        specs
    }

    #[test]
    fn inert_fault_model_is_stat_identical_to_none() {
        // A fault model attached at all-zero rates must not perturb the
        // simulation in any observable way — this is the zero-BER pin
        // that keeps `sim::xval` and the perf row honest.
        let specs = uniform_16flit_specs();
        let clean = {
            let mut net = Network::new(cfg_4x4());
            net.run_to_completion_after(&specs)
        };
        let inert = {
            let mut net = Network::with_faults(cfg_4x4(), FaultModel::new(3));
            net.run_to_completion_after(&specs)
        };
        assert_eq!(clean, inert);
        assert_eq!(inert.flits_corrupted, 0);
        assert_eq!(inert.packet_retries, 0);
    }

    #[test]
    fn seeded_fault_runs_replay_identically() {
        let run = || {
            let mut net = Network::with_faults(
                cfg_4x4(),
                FaultModel::new(99).with_ber(1e-4).with_dup(0.01),
            );
            net.run_to_completion_after(&uniform_16flit_specs())
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn ber_run_delivers_every_packet_exactly_once_with_backoff_in_latency() {
        // ISSUE 6 satellite: a BER-injected run must deliver all symbols
        // exactly once (corrupted attempts are NACKed and retransmitted,
        // never recorded), and each retried packet's latency must carry
        // at least its retransmission backoffs.
        let specs = uniform_16flit_specs();
        let n = specs.len() as u64;
        let clean = {
            let mut net = Network::new(cfg_4x4());
            net.run_to_completion_after(&specs)
        };
        let mut net = Network::with_faults(cfg_4x4(), FaultModel::new(11).with_ber(1e-5));
        let stats = net.run_to_completion_after(&specs);
        // At this seed/BER the budget is never exhausted: every packet
        // is delivered, each exactly once.
        assert_eq!(stats.delivered_packets + stats.packets_dropped, n);
        assert_eq!(net.records.len() as u64, stats.delivered_packets);
        assert!(stats.flits_corrupted > 0, "seeded BER run injected nothing");
        assert!(stats.packet_retries > 0, "no retransmissions observed");
        assert_eq!(
            stats.link_faults.iter().sum::<u64>(),
            stats.flits_corrupted + stats.flits_dropped + stats.flits_duplicated
        );
        // Retried packets pay backoff + repeat trip in *latency* (their
        // records keep the original head-injection cycle).
        let mut saw_retry = false;
        for r in net.records.iter().filter(|r| r.retries > 0) {
            saw_retry = true;
            let backoffs: u64 = (1..=r.retries).map(retry_backoff).sum();
            assert!(
                r.latency() >= backoffs,
                "retried packet latency {} below its backoff sum {backoffs}",
                r.latency()
            );
        }
        assert!(saw_retry || stats.packets_dropped > 0);
        // Faults can only make the run slower in aggregate.
        assert!(stats.sum_latency >= clean.sum_latency);
    }

    #[test]
    fn lossy_links_retry_at_head_and_still_deliver() {
        // Flit drops are link-level ARQ: the flit retries from the FIFO
        // head, so delivery is lossless and in-order — just slower.
        let spec = PacketSpec::new(NodeId(0), NodeId(15), 128 * 8, 0);
        let clean = {
            let mut net = Network::new(cfg_4x4());
            net.run_to_completion_after(&[spec])
        };
        let mut net = Network::with_faults(cfg_4x4(), FaultModel::new(5).with_drop(0.3));
        let stats = net.run_to_completion_after(&[spec]);
        assert_eq!(stats.delivered_packets, 1);
        assert!(stats.flits_dropped > 0, "seeded drop run dropped nothing");
        assert_eq!(stats.packets_dropped, 0);
        assert!(stats.sum_latency >= clean.sum_latency);
    }

    #[test]
    fn retry_budget_exhaustion_reports_drop_without_hanging() {
        // BER = 1.0 corrupts every traversal: the packet is NACKed on
        // all RETRY_BUDGET retransmissions and then reported dropped —
        // run_to_completion drains instead of spinning forever.
        let mut net = Network::with_faults(cfg_4x4(), FaultModel::new(1).with_ber(1.0));
        net.schedule_packets(&[PacketSpec::new(NodeId(0), NodeId(3), 128 * 4, 0)]);
        let stats = net.run_to_completion(10_000);
        assert!(net.drained());
        assert_eq!(stats.delivered_packets, 0);
        assert_eq!(stats.packets_dropped, 1);
        assert_eq!(stats.packet_retries, u64::from(RETRY_BUDGET));
        assert!(net.records.is_empty());
        // The exponential backoffs are cycle-accurate sim time.
        let backoffs: u64 = (1..=RETRY_BUDGET).map(retry_backoff).sum();
        assert!(
            stats.cycles >= backoffs,
            "cycles {} below backoff floor {backoffs}",
            stats.cycles
        );
    }

    #[test]
    fn retry_config_override_moves_the_drop_point_and_backoff_clock() {
        // ISSUE 9 satellite: the budget/backoff are knobs now. A budget
        // of 1 under BER=1.0 drops after a single retransmission; a
        // larger base/cap stretches the deterministic backoff clock.
        let run = |retry: RetryConfig| {
            let mut net = Network::with_faults(
                cfg_4x4(),
                FaultModel::new(1).with_ber(1.0).with_retry(retry),
            );
            assert_eq!(net.retry_config(), retry);
            net.schedule_packets(&[PacketSpec::new(NodeId(0), NodeId(3), 128 * 4, 0)]);
            net.run_to_completion(10_000)
        };
        let tight = run(RetryConfig {
            budget: 1,
            ..RetryConfig::paper_default()
        });
        assert_eq!(tight.packets_dropped, 1);
        assert_eq!(tight.packet_retries, 1);
        let slow = run(RetryConfig {
            backoff_base: 64,
            backoff_cap: 4096,
            ..RetryConfig::paper_default()
        });
        assert_eq!(slow.packet_retries, u64::from(RETRY_BUDGET));
        let floor: u64 = (1..=RETRY_BUDGET)
            .map(|a| (64u64 << (a - 1).min(32)).min(4096))
            .sum();
        assert!(
            slow.cycles >= floor,
            "cycles {} below stretched backoff floor {floor}",
            slow.cycles
        );
        // And the default path is bit-identical to the pre-knob network.
        let default_cfg = run(RetryConfig::paper_default());
        let mut legacy = Network::with_faults(cfg_4x4(), FaultModel::new(1).with_ber(1.0));
        legacy.schedule_packets(&[PacketSpec::new(NodeId(0), NodeId(3), 128 * 4, 0)]);
        assert_eq!(default_cfg, legacy.run_to_completion(10_000));
    }

    #[test]
    fn duplicated_flits_cost_occupancy_but_deliver_once() {
        let specs = uniform_16flit_specs();
        let n = specs.len() as u64;
        let mut net = Network::with_faults(cfg_4x4(), FaultModel::new(21).with_dup(0.05));
        let stats = net.run_to_completion_after(&specs);
        assert_eq!(stats.delivered_packets, n);
        assert!(stats.flits_duplicated > 0, "seeded dup run duplicated nothing");
        // Duplicates never create packets or symbols.
        assert_eq!(net.records.len() as u64, n);
        assert_eq!(stats.packets_dropped, 0);
    }

    #[test]
    fn faulty_egress_network_keeps_symbol_accounting_exact() {
        // Corrupted attempts charge speculative decode work but never
        // count delivered symbols; once the retry lands, symbols are
        // counted exactly once.
        let symbols = 64 * 8u64;
        let spec =
            PacketSpec::new(NodeId(0), NodeId(15), 128 * 64, 0).tagged(huff_tag(symbols, false));
        let mut net = Network::with_egress(cfg_4x4(), EgressCodecConfig::paper_default());
        net.set_fault_model(FaultModel::new(17).with_ber(2e-4));
        let stats = net.run_to_completion_after(&[spec]);
        assert_eq!(stats.delivered_packets + stats.packets_dropped, 1);
        if stats.delivered_packets == 1 {
            assert_eq!(stats.delivered_symbols, symbols);
        } else {
            assert_eq!(stats.delivered_symbols, 0);
        }
    }

    // ------------------------------------------------------------------
    // ISSUE 7: ingress codec ports
    // ------------------------------------------------------------------

    #[test]
    fn ingress_line_rate_matches_codec_blind_injection() {
        // Paper point (10 encode lanes): at ≤ ~12 symbols per flit the
        // encoder stays strictly behind the wire, so paced injection is
        // cycle-identical to the codec-blind network.
        let spec = PacketSpec::new(NodeId(0), NodeId(15), 128 * 64, 0);
        let blind = {
            let mut net = Network::new(cfg_4x4());
            net.run_to_completion_after(&[spec])
        };
        let paced = {
            let mut net =
                Network::with_ingress(cfg_4x4(), IngressCodecConfig::paper_default());
            net.run_to_completion_after(&[spec.tagged(huff_tag(64 * 8, false))])
        };
        assert_eq!(blind.cycles, paced.cycles);
        assert_eq!(blind.completion_cycle, paced.completion_cycle);
        assert_eq!(paced.encode_stall_cycles, 0);
        assert_eq!(paced.injections_refused, 0);
    }

    #[test]
    fn starved_ingress_throttles_injection_and_counts_stalls() {
        // One encode lane on a symbol-heavy packet: injection paces to
        // the encoder rate, stall cycles accrue at the NI, and
        // completion stretches to ~the encode makespan.
        let symbols = 64 * 16u64; // 16 symbols per flit
        let spec =
            PacketSpec::new(NodeId(0), NodeId(15), 128 * 64, 0).tagged(huff_tag(symbols, false));
        let icfg = IngressCodecConfig::nominal(1, 1.0); // 1 ns/symbol
        let cycle_ns = cfg_4x4().cycle_ns();
        let mut net = Network::with_ingress(cfg_4x4(), icfg);
        let stats = net.run_to_completion_after(&[spec]);
        assert_eq!(stats.delivered_packets, 1);
        assert!(stats.encode_stall_cycles > 0, "no encode backpressure observed");
        let rec = net.records[0];
        assert_eq!(rec.encode_stall_cycles, stats.encode_stall_cycles);
        // Encode-bound completion ≈ symbols × ns/sym ÷ cycle_ns (the
        // tail leaves the encoder a flit-cost early, hence the slack).
        let encode_cycles =
            symbols as f64 * icfg.ns_per_symbol(CodecKind::Huffman) / cycle_ns;
        let done = stats.completion_cycle as f64;
        assert!(
            done >= encode_cycles - 16.0 && done <= encode_cycles * 1.15 + 16.0,
            "completion {done} vs encode bound {encode_cycles}"
        );
    }

    #[test]
    fn ingress_startup_charged_once_on_runtime_head() {
        // Identical packets, offline vs runtime codebook: the runtime
        // one completes later by ~the compressor startup, charged once
        // on the head flit; followers stall at the NI while it drains.
        let base = PacketSpec::new(NodeId(0), NodeId(15), 128 * 64, 0);
        let run = |runtime: bool| {
            let mut net =
                Network::with_ingress(cfg_4x4(), IngressCodecConfig::paper_default());
            net.run_to_completion_after(&[base.tagged(huff_tag(64 * 8, runtime))])
        };
        let offline = run(false);
        let runtime = run(true);
        let cycle_ns = cfg_4x4().cycle_ns();
        let startup_cycles =
            (IngressCodecConfig::paper_default().startup_ns / cycle_ns).ceil() as u64;
        let delta = runtime.completion_cycle - offline.completion_cycle;
        assert!(
            delta >= startup_cycles - 1 && delta <= startup_cycles + 2,
            "startup delta {delta} vs expected {startup_cycles}"
        );
        assert!(runtime.encode_stall_cycles > 0);
        assert_eq!(offline.encode_stall_cycles, 0);
    }

    #[test]
    fn bounded_ni_admission_defers_and_counts() {
        // More same-source arrivals than the NI bound: the excess is
        // deferred cycle by cycle (refusals counted), yet every packet
        // is eventually delivered — bounded memory, no loss.
        let icfg = IngressCodecConfig::nominal(1, 1.0);
        assert_eq!(icfg.max_queue, crate::ingress::DEFAULT_MAX_QUEUE);
        let specs: Vec<PacketSpec> = (0..12)
            .map(|_| {
                PacketSpec::new(NodeId(0), NodeId(15), 128 * 8, 0)
                    .tagged(huff_tag(8 * 16, false))
            })
            .collect();
        let mut net = Network::with_ingress(cfg_4x4(), icfg);
        let stats = net.run_to_completion_after(&specs);
        assert_eq!(stats.delivered_packets, 12);
        assert!(stats.injections_refused > 0, "bound never engaged");
    }

    #[test]
    fn try_inject_backpressures_with_typed_refusal() {
        // Closed-loop generator: admission beyond the NI bound is a
        // typed IngressSaturated refusal, and room reopens as the
        // encoder drains — backpressure reaches the caller, not an
        // unbounded queue.
        let mut icfg = IngressCodecConfig::nominal(1, 1.0);
        icfg.max_queue = 2;
        let mut net = Network::with_ingress(cfg_4x4(), icfg);
        let spec =
            PacketSpec::new(NodeId(0), NodeId(15), 128 * 8, 0).tagged(huff_tag(8 * 16, false));
        assert!(net.try_inject(spec).is_ok());
        assert!(net.try_inject(spec).is_ok());
        match net.try_inject(spec) {
            Err(Error::IngressSaturated { node: 0, depth: 2 }) => {}
            other => panic!("expected typed saturation, got {other:?}"),
        }
        assert_eq!(net.stats().injections_refused, 1);
        // Drain enough for one packet to clear the NI, then retry.
        for _ in 0..1500 {
            net.step();
            if net.try_inject(spec).is_ok() {
                break;
            }
        }
        let stats = net.run_to_completion(100_000);
        assert_eq!(stats.delivered_packets, 3);
    }

    // ------------------------------------------------------------------
    // ISSUE 7: stall/deadlock watchdog
    // ------------------------------------------------------------------

    #[test]
    fn zero_rate_egress_terminates_with_stall_report() {
        // Regression: a decoder that never drains used to spin
        // run_to_completion to the horizon. The watchdog must terminate
        // promptly with a typed report naming the stuck packet and the
        // zero-rate port as the suspected cause.
        let mut ecfg = EgressCodecConfig::nominal(16, 1.0);
        ecfg.set_rate(CodecKind::Huffman, 1e12);
        let mut net = Network::with_egress(cfg_4x4(), ecfg);
        net.set_watchdog(200);
        net.schedule_packets(
            &[PacketSpec::new(NodeId(0), NodeId(3), 128 * 8, 0).tagged(huff_tag(64, false))],
        );
        let report = net
            .try_run_to_completion(1_000_000)
            .expect_err("a wedged run must not drain");
        assert_eq!(report.cause, StallCause::ZeroRatePort);
        assert_eq!(report.stuck_packets.len(), 1);
        assert_eq!(report.stuck_packets[0].dest, NodeId(3));
        assert!(report.credit_audit.is_empty(), "credits must still conserve");
        assert!(report.stalled_for >= 200);
        assert!(net.now() < 10_000, "watchdog fired late: {}", net.now());
        // The report renders human-readable.
        let text = format!("{report}");
        assert!(text.contains("ZeroRatePort"), "{text}");
    }

    #[test]
    fn drop_every_flit_terminates_with_dead_link_verdict() {
        // drop_prob = 1.0 is a dead link in transient clothing: no flit
        // ever traverses, no NACK ever fires (nothing reaches egress),
        // and pre-watchdog the step loop span forever.
        let mut net = Network::with_faults(cfg_4x4(), FaultModel::new(4).with_drop(1.0));
        net.set_watchdog(300);
        net.schedule_packets(&[PacketSpec::new(NodeId(0), NodeId(3), 128 * 4, 0)]);
        let report = net
            .try_run_to_completion(1_000_000)
            .expect_err("a dead link must trip the watchdog");
        assert_eq!(report.cause, StallCause::DeadLink);
        assert!(!report.stuck_packets.is_empty());
        assert!(report.credit_audit.is_empty());
    }

    #[test]
    fn watchdog_never_fires_on_healthy_sparse_traffic() {
        // Arrival gaps far beyond the watchdog window: future-due
        // schedule entries are provable progress, so a healthy mesh
        // must complete — quiet spells are not stalls.
        let mut net = Network::new(cfg_4x4());
        net.set_watchdog(64);
        let specs: Vec<PacketSpec> = (0..40u64)
            .map(|k| {
                PacketSpec::new(
                    NodeId((k * 3 % 16) as u16),
                    NodeId((k * 5 % 16) as u16),
                    128 * 4,
                    k * 200,
                )
            })
            .filter(|s| s.src != s.dest)
            .collect();
        let n = specs.len() as u64;
        net.schedule_packets(&specs);
        let stats = net
            .try_run_to_completion(100_000)
            .expect("healthy mesh must never trip the watchdog");
        assert_eq!(stats.delivered_packets, n);
    }

    #[test]
    fn credit_conservation_soak_under_faults_and_link_downs() {
        // Property soak (ISSUE 7 satellite): ≥ 10k cycles of seeded
        // random traffic × transient faults × two mid-run permanent
        // link failures — the per-link credit invariant must hold on
        // *every* cycle, and packet accounting must stay exact.
        let mut net = Network::new(cfg_4x4());
        net.set_fault_model(
            FaultModel::new(77)
                .with_ber(1e-4)
                .with_drop(0.02)
                .with_dup(0.01)
                .with_link_down(NodeId(5), NodeId(6), 3_000)
                .with_link_down(NodeId(9), NodeId(10), 7_000),
        );
        let mut specs = Vec::new();
        for k in 0..500u64 {
            let (s, d) = ((k * 7 % 16) as u16, ((k * 11 + 3) % 16) as u16);
            if s != d {
                specs.push(PacketSpec::new(NodeId(s), NodeId(d), 128 * 8, k * 25));
            }
        }
        let n = specs.len() as u64;
        net.schedule_packets(&specs);
        let mut cycles = 0u64;
        while !net.drained() {
            assert!(net.now() < 200_000, "soak failed to drain");
            net.step();
            cycles += 1;
            let v = net.audit_credits();
            assert!(
                v.is_empty(),
                "credit violation at cycle {}: {:?}",
                net.now(),
                v[0]
            );
        }
        assert!(cycles >= 10_000, "soak too short: {cycles} cycles");
        let stats = net.stats();
        assert_eq!(stats.links_down, 2);
        // A 4x4 mesh stays connected after these two cuts: every packet
        // is delivered or (budget-exhausted) reported dropped.
        assert_eq!(stats.packets_unreachable, 0);
        assert_eq!(stats.delivered_packets + stats.packets_dropped, n);
    }

    // ------------------------------------------------------------------
    // ISSUE 7: permanent link failures + adaptive recovery
    // ------------------------------------------------------------------

    #[test]
    fn link_down_truncates_worm_and_redelivers_via_reroute() {
        // Kill the 1↔2 link while a 16-flit worm 0→3 is strung across
        // it: the worm is truncated (credits returned), NACK-retried,
        // and the retry is delivered over the escape route.
        let mut net = Network::new(cfg_4x4());
        net.set_fault_model(FaultModel::new(1).with_link_down(NodeId(1), NodeId(2), 6));
        net.schedule_packets(&[PacketSpec::new(NodeId(0), NodeId(3), 128 * 16, 0)]);
        let stats = net.run_to_completion(10_000);
        assert_eq!(stats.delivered_packets, 1);
        assert_eq!(stats.links_down, 1);
        assert_eq!(stats.packets_truncated, 1);
        assert!(stats.packet_retries >= 1);
        assert_eq!(stats.packets_unreachable, 0);
        let rec = net.records[0];
        assert!(rec.retries >= 1, "delivery must be a logged retransmission");
        assert!(net.audit_credits().is_empty());
    }

    #[test]
    fn link_down_before_traffic_reroutes_without_truncation() {
        // The link dies before injection: no worm to cut — the packet
        // simply routes around the failure (longer than the 3-hop XY
        // path the cut removed).
        let mut net = Network::new(cfg_4x4());
        net.set_fault_model(FaultModel::new(1).with_link_down(NodeId(1), NodeId(2), 0));
        net.schedule_packets(&[PacketSpec::new(NodeId(0), NodeId(3), 128 * 16, 10)]);
        let stats = net.run_to_completion(10_000);
        assert_eq!(stats.delivered_packets, 1);
        assert_eq!(stats.packets_truncated, 0);
        assert_eq!(stats.packet_retries, 0);
        assert!(
            stats.flit_hops > 16 * 3,
            "escape path must be longer than the severed XY path: {} hops",
            stats.flit_hops
        );
    }

    #[test]
    fn severed_destination_is_typed_unreachable() {
        // Cut both links of corner node 0 (3x3): packets bound there
        // are reported unreachable — and the run still drains; packets
        // between surviving nodes still deliver.
        let cfg = NetworkConfig {
            mesh: Mesh::new(3, 3),
            flit_bits: 128,
            link_gbps: 100.0,
            buf_depth: 4,
        };
        let mut net = Network::new(cfg);
        net.set_fault_model(
            FaultModel::new(1)
                .with_link_down(NodeId(0), NodeId(1), 0)
                .with_link_down(NodeId(0), NodeId(3), 0),
        );
        net.schedule_packets(&[
            PacketSpec::new(NodeId(8), NodeId(0), 128 * 4, 5),
            PacketSpec::new(NodeId(8), NodeId(4), 128 * 4, 5),
        ]);
        let stats = net.run_to_completion(10_000);
        assert!(net.drained());
        assert_eq!(stats.delivered_packets, 1);
        assert_eq!(stats.packets_unreachable, 1);
        assert_eq!(net.unreachable_packets().len(), 1);
        assert_eq!(net.unreachable_packets()[0].dest, NodeId(0));
        // Scheduling into the severed island is now a typed refusal...
        let err = net
            .try_schedule_packets(&[PacketSpec::new(NodeId(8), NodeId(0), 128, 100)])
            .expect_err("severed dest must be refused");
        assert!(
            matches!(err, Error::Unreachable { src: 8, dest: 0 }),
            "{err:?}"
        );
        // ...and so is closed-loop injection.
        assert!(matches!(
            net.try_inject(PacketSpec::new(NodeId(3), NodeId(0), 128, 0)),
            Err(Error::Unreachable { .. })
        ));
    }

    #[test]
    fn duplex_codec_ports_compose_with_exact_accounting() {
        // Ingress AND egress ports starved (1 lane each): both stall
        // kinds are counted, and symbol accounting stays exact.
        let symbols = 64 * 16u64;
        let spec = PacketSpec::new(NodeId(0), NodeId(15), 128 * 64, 0)
            .tagged(huff_tag(symbols, true));
        let mut net = Network::with_egress(cfg_4x4(), EgressCodecConfig::nominal(1, 1.0));
        net.set_ingress_config(IngressCodecConfig::nominal(1, 1.0));
        let stats = net.run_to_completion_after(&[spec]);
        assert_eq!(stats.delivered_packets, 1);
        assert!(stats.encode_stall_cycles > 0);
        assert!(stats.decode_stall_cycles > 0);
        assert_eq!(stats.delivered_symbols, symbols);
        let rec = net.records[0];
        assert_eq!(rec.encode_stall_cycles, stats.encode_stall_cycles);
        assert_eq!(rec.decode_stall_cycles, stats.decode_stall_cycles);
    }
}
