//! Stall/deadlock watchdog & per-VC observability (ISSUE 7/10): the
//! diagnosis layer of [`Network`] — per-VC usage snapshots
//! ([`VcUsage`]), the per-VC credit-conservation audit, starvation
//! detection, and the typed [`StallReport`] assembled when a run fails
//! to drain. Split out of the `network.rs` monolith as a *child*
//! module of [`crate::network`] (via `#[path]`), so it reads the
//! simulator's internals without widening their visibility — none of
//! this is on the hot path except the O(vcs) starvation probe and the
//! O(1) progress counters the step loop maintains.

use super::Network;
use crate::topology::{NodeId, Port, Topology};
use crate::vc::credit_share;
use std::fmt;

/// Per-VC activity snapshot (ISSUE 10): the CLI's per-VC report lines
/// and the starvation watchdog read these.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct VcUsage {
    pub vc: u8,
    /// Flits ejected on this VC.
    pub delivered_flits: u64,
    /// Link traversals charged to this VC's credit lanes.
    pub flit_hops: u64,
    /// Flits currently buffered network-wide on this VC.
    pub buffered: u64,
    /// Cycle of this VC's last movement (inject, hop, or eject).
    pub last_progress: u64,
}

/// Default zero-progress window (in cycles) before the watchdog fires:
/// comfortably beyond the longest legal quiet spell (the 256-cycle
/// retry-backoff cap, codec-port startups, deep congestion waves) while
/// still terminating a wedged run promptly.
pub const DEFAULT_WATCHDOG_CYCLES: u64 = 10_000;

/// One broken per-VC credit invariant found by
/// [`Network::audit_credits`]: the upstream lane's credits plus the
/// downstream FIFO's buffered flits no longer sum to that VC's
/// [`credit_share`] of `buf_depth`. (Summed over a link's VCs the
/// shares give back the ISSUE 7 whole-link invariant.)
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CreditViolation {
    /// Upstream router of the directed link (endpoint id of the
    /// router's slot-0 node on concentrated topologies).
    pub node: NodeId,
    /// Output port (= link direction) at the upstream router.
    pub out: Port,
    /// Virtual channel whose lane broke the invariant (ISSUE 10).
    pub vc: u8,
    /// Credits the upstream lane currently holds.
    pub credits: u32,
    /// Flits buffered in the downstream VC FIFO.
    pub buffered: u32,
    /// The [`credit_share`] the two must sum to.
    pub expected: u32,
}

/// A packet that was still live when the watchdog fired.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StuckPacket {
    pub id: u64,
    pub src: NodeId,
    pub dest: NodeId,
    /// Router holding the packet's foremost buffered flit (the source
    /// when nothing is buffered yet — still queued at the NI).
    pub node: NodeId,
    /// Input port holding that flit (`Local` when NI-queued).
    pub port: Port,
    /// Approximate cycle of the flit's last movement (`ready_at` − 1).
    pub since: u64,
}

/// The watchdog's suspected root cause, cheapest-to-check first.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StallCause {
    /// The credit audit found a lane where credits + buffered flits no
    /// longer sum to its share of `buf_depth` — flow control itself is
    /// broken.
    CreditLeak,
    /// An ingress/egress codec port's busy horizon is still ahead of
    /// sim time after a whole stall window: an effectively zero-rate
    /// port is refusing every grant.
    ZeroRatePort,
    /// A permanent link failure is in effect, or the fault model drops
    /// every traversal (`drop_prob == 1` — a dead link in transient
    /// clothing).
    DeadLink,
    /// No port or credit anomaly found: suspect a routing/lock cycle.
    RoutingCycle,
    /// `max_cycles` elapsed while the network was still making
    /// progress — an undersized horizon, not a wedge.
    SlowProgress,
    /// ISSUE 10: the named VC holds buffered flits that have not moved
    /// for a whole watchdog window while *other* VCs kept progressing —
    /// per-class starvation the global progress counter cannot see.
    VcStarvation(u8),
}

/// Typed verdict from the stall/deadlock watchdog (ISSUE 7): why the
/// run terminated without draining, who was stuck where, and whether
/// credit conservation still held. Returned by
/// [`Network::try_run_to_completion`] instead of looping forever.
#[derive(Clone, Debug, PartialEq)]
pub struct StallReport {
    /// Cycle at which the watchdog fired.
    pub cycle: u64,
    /// Zero-progress cycles leading up to it (0 for a
    /// [`StallCause::VcStarvation`] verdict — the network as a whole
    /// was still moving).
    pub stalled_for: u64,
    pub cause: StallCause,
    /// Live packets and where each one's foremost flit is held.
    pub stuck_packets: Vec<StuckPacket>,
    /// Per-VC credit-conservation violations (empty = credits intact).
    pub credit_audit: Vec<CreditViolation>,
}

impl fmt::Display for StallReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "stall at cycle {}: no progress for {} cycles (suspected {:?}); \
             {} stuck packet(s), {} credit violation(s)",
            self.cycle,
            self.stalled_for,
            self.cause,
            self.stuck_packets.len(),
            self.credit_audit.len()
        )?;
        for p in self.stuck_packets.iter().take(8) {
            writeln!(
                f,
                "  packet {} {}->{} held at node {} port {:?} since cycle {}",
                p.id, p.src.0, p.dest.0, p.node.0, p.port, p.since
            )?;
        }
        if self.stuck_packets.len() > 8 {
            writeln!(f, "  ... {} more", self.stuck_packets.len() - 8)?;
        }
        for v in self.credit_audit.iter().take(4) {
            writeln!(
                f,
                "  credit leak: node {} {:?} vc {}: credits {} + buffered {} != {}",
                v.node.0, v.out, v.vc, v.credits, v.buffered, v.expected
            )?;
        }
        Ok(())
    }
}

impl Network {
    /// Per-VC activity snapshot (ISSUE 10): one entry per VC.
    pub fn vc_usage(&self) -> Vec<VcUsage> {
        (0..self.cfg.vcs)
            .map(|v| VcUsage {
                vc: v,
                delivered_flits: self.vc_delivered[v as usize],
                flit_hops: self.vc_hops[v as usize],
                buffered: self.vc_occ[v as usize],
                last_progress: self.vc_progress[v as usize],
            })
            .collect()
    }

    /// A VC with buffered flits none of which moved for ≥ `window`
    /// cycles (O(vcs) — counters maintained incrementally on the hot
    /// path).
    pub(super) fn starving_vc(&self, window: u64) -> Option<u8> {
        (0..self.cfg.vcs).find(|&v| {
            self.vc_occ[v as usize] > 0
                && self.now - self.vc_progress[v as usize] >= window
        })
    }

    /// A scheduled arrival or retry backoff strictly in the future is
    /// guaranteed forward motion — the watchdog must not fire over a
    /// quiet spell it can prove will end. Both horizons are bounded
    /// (backoff caps at 256 cycles; the schedule is finite), so this
    /// can never postpone a genuine-wedge verdict forever.
    pub(super) fn future_work_pending(&self) -> bool {
        self.retry_queue.iter().any(|e| e.due > self.now)
            || self
                .schedule
                .last()
                .map_or(false, |s| s.inject_at > self.now)
    }

    /// Verify per-VC credit conservation (ISSUE 10): for every directed
    /// link and every VC, the upstream lane's credits plus the
    /// downstream VC FIFO's occupancy must equal that VC's
    /// [`credit_share`] of `buf_depth`. Forwarding and credit return
    /// are same-cycle, and wormhole truncation returns credits to the
    /// exact lane of every discarded flit, so the invariant holds on
    /// *every* cycle — including across dead links. Σ over a link's VCs
    /// recovers the ISSUE 7 whole-link invariant.
    pub fn audit_credits(&self) -> Vec<CreditViolation> {
        let mut violations = Vec::new();
        for node in 0..self.routers.len() {
            for &out in &Port::ALL[1..] {
                let Some(nb) = self.cfg.topo.neighbour_r(node, out) else {
                    continue;
                };
                for vc in 0..self.cfg.vcs {
                    let credits =
                        self.routers[node].outputs[out as usize].lanes[vc as usize].credits;
                    let buffered = self.routers[nb].inputs[out.opposite() as usize].fifos
                        [vc as usize]
                        .len() as u32;
                    let expected = credit_share(self.cfg.buf_depth, self.cfg.vcs, vc);
                    if credits + buffered != expected {
                        violations.push(CreditViolation {
                            node: NodeId(node as u16),
                            out,
                            vc,
                            credits,
                            buffered,
                            expected,
                        });
                    }
                }
            }
        }
        violations
    }

    /// Fire-time diagnosis: pick the cause heuristically
    /// (cheapest-to-check first), then build the full report.
    pub(super) fn diagnose(&self, stalled_for: u64, timed_out: bool) -> StallReport {
        let credit_audit = self.audit_credits();
        let window = self.watchdog_cycles.unwrap_or(DEFAULT_WATCHDOG_CYCLES);
        let cause = if timed_out && stalled_for < window {
            StallCause::SlowProgress
        } else if !credit_audit.is_empty() {
            StallCause::CreditLeak
        } else if self.zero_rate_port_suspected() {
            StallCause::ZeroRatePort
        } else if self.stats.links_down > 0
            || self.fault.as_ref().map_or(false, |f| f.drop_prob() >= 1.0)
        {
            StallCause::DeadLink
        } else {
            StallCause::RoutingCycle
        };
        self.build_report_with_audit(stalled_for, cause, credit_audit)
    }

    /// Build a [`StallReport`] with a predetermined cause (the
    /// starvation watchdog knows its verdict already).
    pub(super) fn build_report(&self, stalled_for: u64, cause: StallCause) -> StallReport {
        let audit = self.audit_credits();
        self.build_report_with_audit(stalled_for, cause, audit)
    }

    /// Locate each live packet's foremost buffered flit and assemble
    /// the report — all deliberately off the hot path.
    fn build_report_with_audit(
        &self,
        stalled_for: u64,
        cause: StallCause,
        credit_audit: Vec<CreditViolation>,
    ) -> StallReport {
        let mut loc: std::collections::HashMap<u64, (NodeId, Port, u32, u64)> =
            std::collections::HashMap::new();
        for (node, r) in self.routers.iter().enumerate() {
            for (inp, buf) in r.inputs.iter().enumerate() {
                for fifo in &buf.fifos {
                    for f in fifo {
                        let here = (NodeId(node as u16), Port::ALL[inp], f.seq, f.ready_at);
                        loc.entry(f.packet_id)
                            .and_modify(|e| {
                                if f.seq < e.2 {
                                    *e = here;
                                }
                            })
                            .or_insert(here);
                    }
                }
            }
        }
        let mut stuck_packets: Vec<StuckPacket> = self
            .meta
            .iter()
            .map(|(&id, m)| {
                let (node, port, _, ready) = loc.get(&id).copied().unwrap_or((
                    m.spec.src,
                    Port::Local,
                    0,
                    m.head_inject.unwrap_or(m.spec.inject_at) + 1,
                ));
                StuckPacket {
                    id,
                    src: m.spec.src,
                    dest: m.spec.dest,
                    node,
                    port,
                    since: ready.saturating_sub(1),
                }
            })
            .collect();
        stuck_packets.sort_by_key(|s| s.id);
        StallReport {
            cycle: self.now,
            stalled_for,
            cause,
            stuck_packets,
            credit_audit,
        }
    }

    /// A codec port whose busy horizon is still ahead of `now` after an
    /// entire zero-progress window never accepted during it: it is
    /// refusing every grant at an effectively zero rate.
    fn zero_rate_port_suspected(&self) -> bool {
        let horizon = self.now as f64;
        self.egress.iter().any(|p| p.busy_until > horizon)
            || self.ingress.iter().any(|p| p.busy_until > horizon)
    }
}
