//! Egress codec ports (ISSUE 5, paper §4.4).
//!
//! The paper places LEXI codecs "at the ingress and egress ports of
//! network-on-chip routers", claiming the multi-lane LUT decoder sustains
//! the maximum link bandwidth. This module is the cycle-level twin of that
//! claim: every node's Local (ejection) port drains codec-tagged flits at
//! the **measured decoder rate** instead of the codec-blind 1 flit/cycle.
//!
//! The model is deliberately small so `tools/logic_check.py` §[11] can
//! mirror it line-for-line:
//!
//! * a node's decoder owns a fractional `busy_until` horizon (network
//!   cycles, `f64` — the codec clock need not divide the link clock);
//! * a flit may eject in cycle `now` iff the backlog is under one cycle
//!   ahead ([`ready`]: `busy_until < now + 1 − ε`), otherwise the flit
//!   stays in the local input buffer — no credit is returned upstream, so
//!   a slow decoder backpressures into the network exactly like a full
//!   buffer would;
//! * an accepted flit advances the horizon by its decode cost
//!   ([`accept`]: `busy_until = max(busy_until, now) + cost`), where the
//!   cost is the flit's symbol share through the lanes plus — on the
//!   *head* flit of a runtime-Huffman packet — the codebook-pipeline +
//!   multi-symbol-LUT-fill startup.
//!
//! With a line-rate decoder (cost ≤ 1 cycle/flit) the horizon never runs
//! ahead and ejection stays at 1 flit/cycle — the paper's operating
//! point. An under-provisioned decoder (e.g. one lane) throttles ejection
//! to one flit per `cost` cycles on average (fractional pacing: a
//! 1.5-cycle cost ejects 2 flits every 3 cycles, not 1 per ⌈1.5⌉).

use crate::packet::CodecTag;
use lexi_core::codec::CodecKind;
use lexi_core::huffman::CodeBook;
use lexi_hw::decoder::DecoderUnit;

/// Tolerance for the fractional-backlog comparison in [`ready`].
pub const EGRESS_EPS: f64 = 1e-9;

/// Nominal Huffman decoder occupancy at one lane (Fig 6's 4-stage
/// average) — the fallback when no measured rate is installed. Matches
/// `lexi-sim`'s `NOMINAL_CYCLES_PER_SYMBOL`.
pub const NOMINAL_HUFFMAN_CPS: f64 = 1.16;

/// Nominal BDI per-block decode cost per symbol (34 cycles / 32-symbol
/// block). Matches `lexi-sim`'s `BDI_NOMINAL_CYCLES_PER_SYMBOL`.
pub const NOMINAL_BDI_CPS: f64 = 1.0625;

/// Nominal codebook-pipeline startup, ns (81-cycle worst case +
/// sampling window at 1 GHz — a fixed wall-clock figure, like
/// `Engine::codec_startup_ns`).
pub const NOMINAL_CODEBOOK_STARTUP_NS: f64 = 170.0;

/// Nominal multi-symbol LUT fill, in **codec cycles** (2048 entries at
/// 64/cycle) — converted at the codec clock, like
/// `Engine::lut_fill_cycles`.
pub const NOMINAL_LUT_FILL_CYCLES: f64 = 32.0;

/// Nominal runtime-Huffman startup at the paper's 1 GHz codec clock.
/// Matches `Engine::huffman_startup_ns()` at the paper point; at other
/// clocks use [`EgressCodecConfig::nominal`], which converts the LUT
/// fill at `codec_ghz`.
pub const NOMINAL_STARTUP_NS: f64 = NOMINAL_CODEBOOK_STARTUP_NS + NOMINAL_LUT_FILL_CYCLES;

/// Egress decoder parameters for one network. Rates are **effective
/// across all lanes** (codec cycles per symbol with every lane running),
/// indexed by [`CodecKind::wire_tag`].
#[derive(Clone, Copy, Debug)]
pub struct EgressCodecConfig {
    /// Parallel LUT decoder lanes at each receiver (reporting only; the
    /// rates below already include lane parallelism).
    pub lanes: usize,
    /// Codec clock, GHz (converts codec cycles to ns).
    pub codec_ghz: f64,
    /// Effective decoder cycles per symbol per codec, all lanes
    /// combined, indexed by `CodecKind::wire_tag()`. Raw must be 0.
    pub cycles_per_symbol: [f64; 3],
    /// One-time startup charged on the head flit of each runtime-Huffman
    /// packet (codebook pipeline + multi-symbol LUT fill), ns.
    pub startup_ns: f64,
}

impl EgressCodecConfig {
    /// Nominal rates (Fig 6 Huffman average, BDI per-block model, free
    /// Raw) split inverse-linearly across `lanes`. The startup mirrors
    /// `Engine::huffman_startup_ns()`: a fixed-ns codebook pipeline
    /// plus the LUT fill converted at `codec_ghz`.
    pub fn nominal(lanes: usize, codec_ghz: f64) -> Self {
        let l = lanes.max(1) as f64;
        EgressCodecConfig {
            lanes: lanes.max(1),
            codec_ghz,
            cycles_per_symbol: [NOMINAL_HUFFMAN_CPS / l, NOMINAL_BDI_CPS / l, 0.0],
            startup_ns: NOMINAL_CODEBOOK_STARTUP_NS + NOMINAL_LUT_FILL_CYCLES / codec_ghz,
        }
    }

    /// The paper operating point: 16 lanes at 1 GHz.
    pub fn paper_default() -> Self {
        Self::nominal(16, 1.0)
    }

    /// Rates measured on the `lexi-hw` multi-symbol LUT unit for `book`:
    /// the Huffman lane rate is [`DecoderUnit::symbols_per_cycle`] (the
    /// front table's average probe fill — > 1 symbol/lane/cycle on
    /// paper-entropy books), split across `lanes`. BDI/Raw keep the
    /// nominal model (no LUT pipeline to measure).
    pub fn from_decoder(unit: &DecoderUnit, book: &CodeBook, lanes: usize, codec_ghz: f64) -> Self {
        let mut cfg = Self::nominal(lanes, codec_ghz);
        cfg.cycles_per_symbol[CodecKind::Huffman.wire_tag() as usize] =
            unit.cycles_per_symbol(book) / lanes.max(1) as f64;
        cfg
    }

    /// Install an externally measured effective rate (e.g. from
    /// `lexi-sim`'s `CrTable::decode_cycles_per_symbol_for` at this
    /// config's lane count) for one codec.
    pub fn set_rate(&mut self, kind: CodecKind, cycles_per_symbol: f64) -> &mut Self {
        self.cycles_per_symbol[kind.wire_tag() as usize] = cycles_per_symbol;
        self
    }

    /// Decoder ns per symbol for `kind`, all lanes combined.
    #[inline]
    pub fn ns_per_symbol(&self, kind: CodecKind) -> f64 {
        self.cycles_per_symbol[kind.wire_tag() as usize] / self.codec_ghz
    }

    /// Decode cost of one flit of a tagged packet, in **network cycles**:
    /// the packet's symbols are spread uniformly over its flits (the
    /// packer fills flits greedily, so per-flit symbol counts are within
    /// one of each other), plus the startup on a runtime-Huffman head.
    pub fn flit_cost_cycles(
        &self,
        tag: &CodecTag,
        total_flits: u32,
        is_head: bool,
        cycle_ns: f64,
    ) -> f64 {
        let sym_share = tag.symbols as f64 / total_flits.max(1) as f64;
        let mut cost_ns = sym_share * self.ns_per_symbol(tag.kind);
        if is_head && tag.runtime_book && tag.kind == CodecKind::Huffman {
            cost_ns += self.startup_ns;
        }
        cost_ns / cycle_ns
    }
}

/// May a flit eject in cycle `now` given the decoder backlog horizon?
/// (The backlog must be under one cycle ahead; `ε` absorbs float noise so
/// an exactly line-rate decoder never spuriously stalls.)
#[inline]
pub fn ready(busy_until: f64, now: u64) -> bool {
    busy_until < now as f64 + 1.0 - EGRESS_EPS
}

/// Advance the backlog horizon after accepting a flit of cost
/// `cost_cycles` in cycle `now`.
#[inline]
pub fn accept(busy_until: f64, now: u64, cost_cycles: f64) -> f64 {
    busy_until.max(now as f64) + cost_cycles
}

/// Per-node egress decoder state.
#[derive(Clone, Copy, Debug, Default)]
pub struct EgressPort {
    /// Network cycle (fractional) at which the decoder's current backlog
    /// is fully drained.
    pub busy_until: f64,
    /// Ejection attempts this port refused because the decoder was
    /// backlogged (aggregate over all packets).
    pub stall_cycles: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tag(kind: CodecKind, symbols: u64, runtime_book: bool) -> CodecTag {
        CodecTag {
            kind,
            symbols,
            runtime_book,
        }
    }

    /// Replay the accept/stall rule on a saturated ejection port (a flit
    /// always waiting) and return (completion_cycle, stalls).
    fn drain(flits: u32, cost_body: f64, cost_head: f64) -> (u64, u64) {
        let (mut busy, mut now, mut stalls, mut accepted) = (0.0f64, 0u64, 0u64, 0u32);
        while accepted < flits {
            if ready(busy, now) {
                let c = if accepted == 0 { cost_head } else { cost_body };
                busy = accept(busy, now, c);
                accepted += 1;
            } else {
                stalls += 1;
            }
            now += 1;
        }
        (now.max(busy.ceil() as u64), stalls)
    }

    #[test]
    fn line_rate_decoder_never_stalls() {
        // cost ≤ 1 cycle/flit ⇒ ejection stays at 1 flit/cycle, exactly
        // the paper's "sustains the maximum link bandwidth".
        for cost in [0.0, 0.25, 0.9, 1.0] {
            let (done, stalls) = drain(1000, cost, cost);
            assert_eq!(stalls, 0, "cost {cost}");
            assert_eq!(done, 1000, "cost {cost}");
        }
    }

    #[test]
    fn slow_decoder_throttles_fractionally() {
        // cost 1.5 ⇒ 2 flits per 3 cycles, not 1 per ⌈1.5⌉ = 2.
        let (done, stalls) = drain(1000, 1.5, 1.5);
        assert!((done as f64 - 1500.0).abs() <= 2.0, "done {done}");
        assert!(stalls > 0);
        // cost 4 ⇒ 1 flit per 4 cycles.
        let (done4, _) = drain(100, 4.0, 4.0);
        assert!((done4 as f64 - 400.0).abs() <= 4.0, "done {done4}");
    }

    #[test]
    fn startup_stalls_exactly_its_cycles() {
        // Line-rate body cost, 158-cycle head startup: completion is
        // flits + startup (the head's backlog must drain before the
        // following flits eject).
        let (done, stalls) = drain(100, 1.0, 1.0 + 158.0);
        assert_eq!(done, 100 + 158);
        assert_eq!(stalls, 158);
    }

    #[test]
    fn flit_cost_spreads_symbols_and_charges_startup_on_head_only() {
        let cfg = EgressCodecConfig::nominal(1, 1.0);
        let cycle_ns = 1.28;
        let t = tag(CodecKind::Huffman, 1000, true);
        let body = cfg.flit_cost_cycles(&t, 100, false, cycle_ns);
        let head = cfg.flit_cost_cycles(&t, 100, true, cycle_ns);
        // 10 symbols/flit × 1.16 ns/sym ÷ 1.28 ns/cycle.
        assert!((body - 10.0 * 1.16 / 1.28).abs() < 1e-9);
        assert!((head - body - NOMINAL_STARTUP_NS / 1.28).abs() < 1e-9);
        // Offline books (weights) and non-Huffman codecs skip startup.
        let offline = tag(CodecKind::Huffman, 1000, false);
        assert_eq!(
            cfg.flit_cost_cycles(&offline, 100, true, cycle_ns),
            cfg.flit_cost_cycles(&offline, 100, false, cycle_ns)
        );
        let bdi = tag(CodecKind::Bdi, 1000, true);
        assert_eq!(
            cfg.flit_cost_cycles(&bdi, 100, true, cycle_ns),
            cfg.flit_cost_cycles(&bdi, 100, false, cycle_ns)
        );
        // Raw decodes free at any lane count.
        let raw = tag(CodecKind::Raw, 1000, false);
        assert_eq!(cfg.flit_cost_cycles(&raw, 100, false, cycle_ns), 0.0);
    }

    #[test]
    fn paper_point_hides_decode_behind_the_wire() {
        // 16 lanes at 1 GHz, paper flit/link: at wire ratio ~1.6 a
        // 128-bit flit carries ~13 exponent symbols (0.1 symbols per
        // coded wire bit); even at a generous 16 symbols/flit the
        // per-flit cost stays ≤ 1 cycle — the decoder never throttles
        // the link at the paper operating point.
        let cfg = EgressCodecConfig::paper_default();
        let t = tag(CodecKind::Huffman, 16, false); // generous: 16 syms/flit
        let cost = cfg.flit_cost_cycles(&t, 1, false, 1.28);
        assert!(cost <= 1.0, "paper point stalls the link: {cost}");
    }

    #[test]
    fn measured_rates_install() {
        let mut cfg = EgressCodecConfig::nominal(4, 2.0);
        cfg.set_rate(CodecKind::Huffman, 0.08);
        assert!((cfg.ns_per_symbol(CodecKind::Huffman) - 0.04).abs() < 1e-12);
        assert_eq!(cfg.ns_per_symbol(CodecKind::Raw), 0.0);
        // The LUT-fill share of the startup tracks the codec clock
        // (mirrors Engine::huffman_startup_ns): 170 + 32/2 at 2 GHz.
        assert!((cfg.startup_ns - (170.0 + 16.0)).abs() < 1e-12);
        assert!(
            (EgressCodecConfig::paper_default().startup_ns - NOMINAL_STARTUP_NS).abs() < 1e-12
        );
    }
}
